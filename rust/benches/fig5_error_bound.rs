//! Paper Figure 5 / Theorem 4.1: the quantization error of a discrete
//! LTI SSM is bounded per step. HiPPO-LegT and HiPPO-LegS materialized
//! A/B (n = 4, T = 100, bilinear discretization), inputs N(0,1)
//! quantized to int8; prints the per-step mean |y − ȳ| series.

use quamba::ssm::hippo::{error_bound_experiment, legs, legt};

fn main() {
    for (name, mat) in [("HiPPO-LegT", legt as fn(usize) -> _), ("HiPPO-LegS", legs as fn(usize) -> _)] {
        let run = error_bound_experiment(mat, 4, 100, 0.1, 42);
        println!("\n### Figure 5 analog — {name} (n=4, T=100, Δ=0.1)\n");
        println!("| t | mean |y-ȳ| |");
        println!("|---|---------|");
        for t in (0..100).step_by(10) {
            println!("| {t:3} | {:.3e} |", run.per_step_err[t]);
        }
        let max = run.per_step_err.iter().cloned().fold(0.0f64, f64::max);
        let tail_max = run.per_step_err[50..].iter().cloned().fold(0.0f64, f64::max);
        println!("\nmax error {:.3e}; tail max {:.3e} — bounded ✔", max, tail_max);
    }
    println!("\nShape check vs paper Fig. 5: errors oscillate but stay bounded as t grows.");
}
