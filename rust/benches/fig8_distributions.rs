//! Paper Figures 3/8/12: layer-wise activation distributions of the
//! SSM input x and output (gated) tensors: absolute maxima, the p99
//! percentile, and the rotated-space maxima — showing (a) x is small
//! but its scale is skewed by a handful of values, (b) the output has
//! massive channel outliers growing toward later layers, (c) the
//! Hadamard transform crushes them.

use quamba::bench_support::{f2, open_runtime_or_skip, Table};
use quamba::data::load_stream;
use quamba::ssm::mamba::{MambaModel, MambaTier, QuantSites};

fn main() {
    let Some(rt) = open_runtime_or_skip("fig8_distributions") else { return };
    let mani = rt.manifest();
    let stream = load_stream(&mani.data["pile_eval"]).expect("stream");
    let toks = &stream[..256.min(stream.len())];
    for tinfo in mani.tiers.values() {
        if tinfo.name == "jamba" {
            continue;
        }
        let Ok(q) = rt.weight_qtz(&format!("{}_fp16", tinfo.name)) else { continue };
        let Ok(model) = MambaModel::from_qtz(
            MambaTier {
                name: tinfo.name.clone(),
                d_model: tinfo.d_model,
                n_layer: tinfo.n_layer,
                d_state: tinfo.d_state,
                d_conv: tinfo.d_conv,
                d_inner: tinfo.d_inner,
                dt_rank: tinfo.dt_rank,
                vocab: tinfo.vocab,
            },
            &q,
        ) else { continue };
        let mut taps = Vec::new();
        let _ = model.forward(toks, &QuantSites::none(), Some(&mut taps));
        let mut t = Table::new(
            &format!(
                "Figure 8/12 analog — activation ranges, tier {} ({})",
                tinfo.name, tinfo.paper_name
            ),
            &["layer", "|x| p99", "|x| max", "|y| max", "|gated| max", "|H·gated| max",
              "had. gain"],
        );
        for (i, tap) in taps.iter().enumerate() {
            let spread = tap.gated_absmax / tap.gated_h_absmax.max(1e-9)
                * (tinfo.d_inner as f32).sqrt();
            t.row(vec![
                i.to_string(),
                f2(tap.x_ssm_p99 as f64),
                f2(tap.x_ssm_absmax as f64),
                f2(tap.y_absmax as f64),
                f2(tap.gated_absmax as f64),
                f2(tap.gated_h_absmax as f64),
                f2(spread as f64),
            ]);
        }
        t.print();
    }
    println!("\nShape checks vs paper: |x| max ≫ |x| p99 (scale-skewing small outliers);\n\
              |gated| max grows with layer depth and tier size; H·gated max ≪ gated\n\
              max · √n (outliers spread into the rotated basis).");
}
