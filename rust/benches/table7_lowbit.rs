//! Paper Tables 7/8: low-bit-width methods ported from Transformers
//! (Quip#-like W2A16, QuaRot W4A4) fail to hold up on the SSM, while
//! Quamba's W8A8 stays near FP.

use quamba::bench_support::{f2, iters, open_runtime_or_skip, pct, Table};
use quamba::data::{load_stream, load_tasks};
use quamba::eval::{average_accuracy, perplexity, run_tasks};

fn main() {
    let Some(mut rt) = open_runtime_or_skip("table7_lowbit") else { return };
    let tier = "m2p8";
    if !rt.manifest().tiers.contains_key(tier) {
        println!("[skip] tier {tier} not built");
        return;
    }
    let wiki = load_stream(&rt.manifest().data["wiki_eval"]).expect("wiki");
    let tasks = load_tasks(&rt.manifest().data["tasks"]).expect("tasks");
    let rows = [
        ("fp16", "FP16"),
        ("w2a16_quip", "Quip#-SSM (W2A16)"),
        ("w4a4_quarot", "QuaRot-SSM (W4A4)"),
        ("quamba", "Quamba (W8A8)"),
    ];
    let mut t = Table::new(
        "Table 7/8 analog — low-bit methods on the largest tier",
        &["method", "wiki-synth ppl", "avg zero-shot acc"],
    );
    for (m, label) in rows {
        let ppl = perplexity(&mut rt, tier, m, &wiki, iters(8))
            .map(|r| f2(r.ppl))
            .unwrap_or_else(|_| "-".into());
        let acc = run_tasks(&mut rt, tier, m, &tasks, iters(30))
            .map(|r| pct(average_accuracy(&r)))
            .unwrap_or_else(|_| "-".into());
        t.row(vec![label.to_string(), ppl, acc]);
    }
    t.print();
    println!("\nShape check vs paper: W2A16/W4A4 degrade ≫ W8A8 Quamba.");
}
