//! Paper Tables 7/8 analog, served natively — no artifacts, never
//! skips. The original low-bit comparison needed the XLA runtime; the
//! native engine can stage it from a synthesized model: the same
//! weights and calibration stream at fp32, W8A8 and packed-nibble
//! W4A8, reporting teacher-forced perplexity on a held-out synthetic
//! stream plus served decode throughput through the real
//! `NativeEngine` for every tier.
//!
//! The paper's shape to reproduce: aggressive weight narrowing costs
//! model quality (W4A8 ppl drifts above W8A8, which stays near FP)
//! while buying density — half the GEMM weight bytes — and the engine
//! serves every tier through one identical code path.

use quamba::bench_support::{f2, Table};
use quamba::coordinator::{NativeEngine, NativeEngineConfig, Request, SamplingParams};
use quamba::ssm::{
    MambaModel, MambaState, MambaTier, QuantConfig, QuantizedMambaModel, StepModel, StepScratch,
};
use quamba::util::rng::Pcg32;

/// Teacher-forced perplexity of `stream` under `model`: one B=1
/// prefill, then mean next-token NLL over the log-softmaxed rows.
fn perplexity(model: &dyn StepModel, stream: &[u16]) -> f64 {
    let t = model.tier();
    let vocab = t.vocab;
    let mut st = MambaState::new_for(t, 1, model.quantized_conv_state());
    let mut scratch = StepScratch::new(1);
    let mut logits = Vec::new();
    model.prefill_into(stream, &mut st, &mut scratch, &mut logits);
    let n = stream.len() - 1;
    let mut nll = 0.0f64;
    for i in 0..n {
        let row = &logits[i * vocab..(i + 1) * vocab];
        let max = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let z: f64 = row.iter().map(|&l| f64::from(l - max).exp()).sum();
        nll -= f64::from(row[stream[i + 1] as usize] - max) - z.ln();
    }
    (nll / n as f64).exp()
}

/// Served greedy decode throughput for one tier through the engine.
fn tok_per_s(model: Box<dyn StepModel + Send + Sync>, vocab: usize) -> f64 {
    let mut eng = NativeEngine::new(model, NativeEngineConfig::default());
    let mut r = Pcg32::new(0x7AB7E);
    let (b, max_new) = (4usize, 48usize);
    for i in 0..b {
        let prompt: Vec<u16> = (0..16).map(|_| r.below(vocab as u32) as u16).collect();
        eng.submit(Request {
            id: (i + 1) as u64,
            prompt,
            max_new_tokens: max_new,
            params: SamplingParams::default(),
            stop_at_eos: false,
        });
    }
    let t0 = std::time::Instant::now();
    eng.run_to_completion().expect("decode run");
    (b * max_new) as f64 / t0.elapsed().as_secs_f64().max(1e-9)
}

fn main() {
    let tier = MambaTier {
        name: "edge64".into(),
        d_model: 64,
        n_layer: 4,
        d_state: 8,
        d_conv: 4,
        d_inner: 128,
        dt_rank: 8,
        vocab: 256,
    };
    let model = MambaModel::synthetic(tier.clone(), 7);
    let mut rng = Pcg32::new(0x5EED);
    let calib: Vec<u16> = (0..512).map(|_| rng.below(tier.vocab as u32) as u16).collect();
    // held-out eval stream: same distribution, disjoint draws
    let eval: Vec<u16> = (0..256).map(|_| rng.below(tier.vocab as u32) as u16).collect();
    let q8 = QuantizedMambaModel::from_model(&model, &calib, &QuantConfig::default());
    let q4 = QuantizedMambaModel::from_model(
        &model,
        &calib,
        &QuantConfig { weight_bits: 4, ..QuantConfig::default() },
    );
    let (w8_bytes, w4_bytes) = (q8.gemm_weight_bytes(), q4.gemm_weight_bytes());

    let ppl_fp = perplexity(&model, &eval);
    let ppl_q8 = perplexity(&q8, &eval);
    let ppl_q4 = perplexity(&q4, &eval);
    for (label, p) in [("fp32", ppl_fp), ("w8a8", ppl_q8), ("w4a8", ppl_q4)] {
        assert!(p.is_finite() && p > 0.0, "{label} perplexity degenerate: {p}");
    }

    let tps_fp = tok_per_s(Box::new(MambaModel::synthetic(tier.clone(), 7)), tier.vocab);
    let tps_q8 = tok_per_s(
        Box::new(QuantizedMambaModel::from_model(&model, &calib, &QuantConfig::default())),
        tier.vocab,
    );
    let tps_q4 = tok_per_s(
        Box::new(QuantizedMambaModel::from_model(
            &model,
            &calib,
            &QuantConfig { weight_bits: 4, ..QuantConfig::default() },
        )),
        tier.vocab,
    );

    let mut t = Table::new(
        &format!(
            "Table 7/8 analog — weight-width sweep on the native tier {} (T=256 eval stream)",
            tier.name
        ),
        &["method", "ppl", "ppl Δ vs fp32", "GEMM weight bytes", "served tok/s"],
    );
    t.row(vec!["FP32 reference".into(), f2(ppl_fp), f2(0.0), "-".into(), format!("{tps_fp:.0}")]);
    t.row(vec![
        "Quamba (W8A8)".into(),
        f2(ppl_q8),
        f2(ppl_q8 - ppl_fp),
        w8_bytes.to_string(),
        format!("{tps_q8:.0}"),
    ]);
    t.row(vec![
        "W4A8 packed nibble".into(),
        f2(ppl_q4),
        f2(ppl_q4 - ppl_fp),
        w4_bytes.to_string(),
        format!("{tps_q4:.0}"),
    ]);
    t.print();
    println!(
        "\nShape check vs paper: W8A8 stays near FP (Δppl {:+.3}); the nibble tier \
         trades quality (Δppl {:+.3}) for density ({} vs {} GEMM bytes).",
        ppl_q8 - ppl_fp,
        ppl_q4 - ppl_fp,
        w4_bytes,
        w8_bytes,
    );
}
