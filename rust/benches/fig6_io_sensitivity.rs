//! Paper Figure 6: sensitivity of quantizing the SSM input/output.
//! W8A8 everywhere else; the SSM I/O pair ranges over
//! {I8, FP}² — skipping y hurts less once Hadamard exists, skipping x
//! reveals the input sensitivity. Scored on lambada-synth.

use quamba::bench_support::{iters, open_runtime_or_skip, pct, Table};
use quamba::data::load_tasks;
use quamba::eval::run_tasks;

fn main() {
    let Some(mut rt) = open_runtime_or_skip("fig6_io_sensitivity") else { return };
    let tasks = load_tasks(&rt.manifest().data["tasks"]).expect("tasks");
    let lambada: Vec<_> = tasks.into_iter().filter(|t| t.name == "lambada_synth").collect();
    let tiers = quamba::bench_support::tier_order(&rt);
    let rows = [
        ("fp16", "FP16 (all fp)"),
        ("io_fp_fp", "W8A8, SSM I/O = FP/FP"),
        ("io_i8_fp", "W8A8, SSM I/O = I8/FP"),
        ("io_fp_i8", "W8A8, SSM I/O = FP/I8"),
        ("w8a8_static", "W8A8, SSM I/O = I8/I8 (naive)"),
        ("quamba", "Quamba (I8/I8 + clip + Hadamard)"),
    ];
    let max_ex = iters(60);
    let mut header = vec!["configuration".to_string()];
    header.extend(tiers.iter().cloned());
    let hdr: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
    let mut t = Table::new("Figure 6 analog — SSM I/O precision sensitivity, LAMBADA-synth", &hdr);
    for (m, label) in rows {
        let mut row = vec![label.to_string()];
        for tier in &tiers {
            match run_tasks(&mut rt, tier, m, &lambada, max_ex) {
                Ok(res) => row.push(pct(res[0].1)),
                Err(_) => row.push("-".into()),
            }
        }
        t.row(row);
    }
    t.print();
    println!("\nShape check vs paper: FP/I8 (quantized y, naive) hurts most without\n\
              Hadamard; I8/FP shows the x-sensitivity; Quamba closes both gaps.");
}
