//! Paper Figure 10 (+§I): per-tensor quantization sensitivity, Mamba
//! vs the iso-size Transformer, measured as last-word accuracy on
//! lambada-synth prompts through the rust reference simulators (which
//! can fake-quantize any single site — the instrument HLO graphs can't
//! easily provide).

use quamba::attn::{AttnModel, AttnQuantSites, AttnTier};
use quamba::bench_support::{iters, open_runtime_or_skip, pct, Table};
use quamba::data::{load_tasks, Example};
use quamba::ssm::mamba::{MambaModel, MambaTier, QuantSites};

fn main() {
    let Some(rt) = open_runtime_or_skip("fig10_tensor_sensitivity") else { return };
    let mani = rt.manifest();
    let tasks = load_tasks(&mani.data["tasks"]).expect("tasks");
    let lambada = tasks.iter().find(|t| t.name == "lambada_synth").expect("lambada");
    let n_ex = iters(30);
    let examples: Vec<(&Vec<u16>, u16)> = lambada
        .examples
        .iter()
        .take(n_ex)
        .filter_map(|e| match e {
            Example::ExactLast { prompt, target } => Some((prompt, target[0])),
            _ => None,
        })
        .collect();

    // --- Mamba side (largest tier available) ---
    let tier_name = mani
        .tiers
        .keys()
        .filter(|t| *t != "jamba")
        .last()
        .cloned()
        .unwrap();
    let tinfo = mani.tiers[&tier_name].clone();
    let q = rt.weight_qtz(&format!("{tier_name}_fp16")).expect("weights");
    let model = MambaModel::from_qtz(
        MambaTier {
            name: tinfo.name.clone(),
            d_model: tinfo.d_model,
            n_layer: tinfo.n_layer,
            d_state: tinfo.d_state,
            d_conv: tinfo.d_conv,
            d_inner: tinfo.d_inner,
            dt_rank: tinfo.dt_rank,
            vocab: tinfo.vocab,
        },
        &q,
    )
    .expect("model");

    let acc_mamba = |sites: &QuantSites| -> f64 {
        let mut hit = 0;
        for (prompt, target) in &examples {
            let logits = model.forward(prompt, sites, None);
            let v = tinfo.vocab;
            let row = &logits[(prompt.len() - 1) * v..prompt.len() * v];
            let arg = quamba::coordinator::sampler::argmax(row);
            if arg == *target as usize {
                hit += 1;
            }
        }
        hit as f64 / examples.len() as f64
    };

    let mut t = Table::new(
        &format!("Figure 10 analog — quantize ONE tensor, Mamba tier {tier_name}"),
        &["site", "lambada acc"],
    );
    t.row(vec!["none (fp32)".into(), pct(acc_mamba(&QuantSites::none()))]);
    let cases: Vec<(&str, Box<dyn Fn(&mut QuantSites)>)> = vec![
        ("x (SSM in)", Box::new(|s: &mut QuantSites| s.x_ssm = true)),
        ("y (SSM out)", Box::new(|s| s.y_out = true)),
        ("gated", Box::new(|s| s.gated = true)),
        ("B", Box::new(|s| s.b = true)),
        ("C", Box::new(|s| s.c = true)),
        ("dt", Box::new(|s| s.dt = true)),
        ("conv in", Box::new(|s| s.conv_in = true)),
    ];
    for (label, set) in cases {
        let mut s = QuantSites::none();
        set(&mut s);
        t.row(vec![label.into(), pct(acc_mamba(&s))]);
    }
    t.print();

    // --- Transformer side ---
    if let Some((pname, pt)) = mani.transformer_tiers.iter().next() {
        if let Ok(q) = rt.weight_qtz(&format!("{pname}_fp16")) {
            let am = AttnModel::from_qtz(
                AttnTier {
                    name: pt.name.clone(),
                    d_model: pt.d_model,
                    n_layer: pt.n_layer,
                    n_head: pt.n_head,
                    vocab: pt.vocab,
                },
                &q,
            )
            .expect("attn");
            let acc_attn = |sites: &AttnQuantSites| -> f64 {
                let mut hit = 0;
                for (prompt, target) in &examples {
                    let logits = am.forward(prompt, sites);
                    let v = pt.vocab;
                    let row = &logits[(prompt.len() - 1) * v..prompt.len() * v];
                    if quamba::coordinator::sampler::argmax(row) == *target as usize {
                        hit += 1;
                    }
                }
                hit as f64 / examples.len() as f64
            };
            let mut t2 = Table::new(
                &format!("Figure 10 analog — quantize ONE tensor, Transformer {pname}"),
                &["site", "lambada acc"],
            );
            t2.row(vec!["none (fp32)".into(), pct(acc_attn(&AttnQuantSites::none()))]);
            let cases: Vec<(&str, Box<dyn Fn(&mut AttnQuantSites)>)> = vec![
                ("h", Box::new(|s: &mut AttnQuantSites| s.h_in = true)),
                ("qkv", Box::new(|s| s.qkv = true)),
                ("attn y", Box::new(|s| s.attn_y = true)),
                ("mlp in", Box::new(|s| s.mlp_in = true)),
                ("h_d", Box::new(|s| s.h_d = true)),
            ];
            for (label, set) in cases {
                let mut s = AttnQuantSites::none();
                set(&mut s);
                t2.row(vec![label.into(), pct(acc_attn(&s))]);
            }
            t2.print();
        }
    }
    println!("\nShape check vs paper: the SSM x/y/gated sites cost accuracy; the\n\
              attention sites are robust (h_d is the transformer's sore spot).");
}
