//! §Perf — the native W8A8 batched decode engine vs the only
//! previously-available rust path (per-token full-sequence fp32
//! `forward`), plus kernel-level micro-benches for the PR-2 hot-path
//! rework. Runs with zero artifacts: the model is synthesized and
//! calibrated on the spot.
//!
//! Acceptance targets:
//! * (ISSUE 1) batched W8A8 decode steps at B=8 must be ≥2x faster
//!   than advancing the same 8 sequences by re-running the
//!   full-sequence fp32 forward per token;
//! * (ISSUE 2) reports the blocked-vs-naive int8 GEMM speedup and the
//!   batched-vs-stepwise quantized prefill speedup;
//! * (ISSUE 3) reports the **forced-scalar vs SIMD-dispatch** per-op
//!   speedups (blocked GEMM on decode/prefill shapes, fused i8 conv,
//!   W8A8 step) — acceptance: ≥1.5x on the blocked GEMM for at least
//!   one decode-shaped op when a SIMD backend is available;
//! * (ISSUE 4) warm-vs-cold TTFT through the prefix cache: two
//!   requests share a 512-token prefix; the warm one must run ≥2x
//!   fewer prefill token-steps (deterministic; wall-clock TTFT is
//!   recorded alongside as `ttft_cold` / `ttft_warm`);
//! * (ISSUE 8) the W4A8 packed-nibble tier: `gemm_w4a8` micro-bench
//!   (naive grouped oracle vs blocked nibble path), `decode_step_w4a8`
//!   and `tok_per_s_w4a8`/`tok_per_s_w8a8` — acceptance: W4A8 decode
//!   tokens/s ≥ W8A8, and the nibble tier stores EXACTLY half the
//!   W8A8 GEMM weight bytes (hard `assert_eq!`, not a report line);
//! * (ISSUE 9) the flight recorder: `decode_step_w8a8_engine` vs
//!   `decode_step_w8a8_traced` run the identical steady-state decode
//!   tick through `NativeEngine::step` with the trace ring off/on —
//!   acceptance: tracing overhead ≤ 2%;
//! * (ISSUE 10) self-speculative decoding: the plain W8A8 engine vs
//!   the same engine with its W4A8 twin drafting K=8 tokens/lane
//!   (`tok_per_s_spec`, `accept_len_mean`) — acceptance: spec greedy
//!   decode ≥1.5x plain tokens/s, streams bit-identical (hard
//!   `assert_eq!` in the bench, not a report line);
//! * persists the whole table to `BENCH_native_decode.json` (override
//!   the path with `QUAMBA_BENCH_JSON`) so CI can diff runs against
//!   the committed baseline (`tools/bench_diff.py`).

use quamba::bench_support::{bench_ms, burst_itl_max, f2, iters, ms, Table};
use quamba::coordinator::{NativeEngine, NativeEngineConfig, Request, SamplingParams};
use quamba::quant::qlinear::{
    matmul_i8, matmul_i8_blocked, matmul_i8_blocked_with, matmul_w4a8_ref, matmul_w4a8_with,
    PackedWeightI4, PackedWeightI8, I4_GROUP_K,
};
use quamba::quant::Kernels;
use quamba::ssm::mamba::QuantSites;
use quamba::ssm::{
    fused_conv_silu_i8_with, MambaModel, MambaState, MambaTier, QuantConfig, QuantizedMambaModel,
    StepModel, StepScratch,
};
use quamba::util::json;
use quamba::util::rng::Pcg32;

/// One machine-readable bench entry (op, shape, ms, speedup).
struct Entry {
    op: &'static str,
    shape: String,
    ms: f64,
    speedup: f64,
}

fn main() {
    let tier = MambaTier {
        name: "edge64".into(),
        d_model: 64,
        n_layer: 4,
        d_state: 8,
        d_conv: 4,
        d_inner: 128,
        dt_rank: 8,
        vocab: 256,
    };
    let model = MambaModel::synthetic(tier.clone(), 7);
    let mut rng = Pcg32::new(0x5EED);
    let calib: Vec<u16> = (0..512).map(|_| rng.below(tier.vocab as u32) as u16).collect();
    let qmodel = QuantizedMambaModel::from_model(&model, &calib, &QuantConfig::default());
    // ISSUE 8: same weights and calibration at the packed-nibble tier
    let q4model = QuantizedMambaModel::from_model(
        &model,
        &calib,
        &QuantConfig { weight_bits: 4, ..QuantConfig::default() },
    );
    // the tier's GEMM dims are all even, so the nibble tier stores
    // EXACTLY half the W8A8 weight bytes — asserted, not just reported
    let (w8_bytes, w4_bytes) = (qmodel.gemm_weight_bytes(), q4model.gemm_weight_bytes());
    assert_eq!(
        2 * w4_bytes,
        w8_bytes,
        "W4A8 must store exactly half the W8A8 GEMM weight bytes"
    );

    let ctx = 32usize; // context each sequence has already consumed
    let b = 8usize;
    let prompts: Vec<Vec<u16>> = (0..b)
        .map(|_| (0..ctx).map(|_| rng.below(tier.vocab as u32) as u16).collect())
        .collect();

    // batched states for the step paths (one B-lane state per model)
    let cpl = (tier.d_conv - 1) * tier.d_inner;
    let spl = tier.d_inner * tier.d_state;
    let pack = |m: &dyn StepModel| -> MambaState {
        let quantized = m.quantized_conv_state();
        let mut packed = MambaState::new_for(&tier, b, quantized);
        for (bi, p) in prompts.iter().enumerate() {
            let mut st = MambaState::new_for(&tier, 1, quantized);
            m.prefill(p, &mut st);
            // copy lane 0 of the single state into lane bi of the pack
            for li in 0..tier.n_layer {
                if quantized {
                    packed.conv_q[(li * b + bi) * cpl..(li * b + bi + 1) * cpl]
                        .copy_from_slice(&st.conv_q[li * cpl..(li + 1) * cpl]);
                } else {
                    packed.conv[(li * b + bi) * cpl..(li * b + bi + 1) * cpl]
                        .copy_from_slice(&st.conv[li * cpl..(li + 1) * cpl]);
                }
                packed.ssm[(li * b + bi) * spl..(li * b + bi + 1) * spl]
                    .copy_from_slice(&st.ssm[li * spl..(li + 1) * spl]);
            }
        }
        packed
    };

    let toks: Vec<u16> = (0..b).map(|_| rng.below(tier.vocab as u32) as u16).collect();

    // before: the pre-step() world — advance each sequence one token by
    // re-running the fp32 full-sequence forward over its whole prefix
    let sites = QuantSites::none();
    let before = bench_ms(1, iters(8), || {
        for p in &prompts {
            let lg = model.forward(p, &sites, None);
            std::hint::black_box(lg.len());
        }
    });

    // after (fp32): one batched stateful step for all 8 lanes
    let mut st_fp = pack(&model);
    let mut scratch = StepScratch::new(1);
    let mut logits = Vec::new();
    let fp_step = bench_ms(2, iters(40), || {
        model.step_into(&toks, &mut st_fp, &mut scratch, &mut logits);
        std::hint::black_box(logits.len());
    });

    // after (W8A8): the quantized zero-alloc batched step — the
    // deployment path
    let mut st_q = pack(&qmodel);
    let q_step = bench_ms(2, iters(40), || {
        qmodel.step_into(&toks, &mut st_q, &mut scratch, &mut logits);
        std::hint::black_box(logits.len());
    });

    // W4A8: the packed-nibble tier on the identical step path
    let mut st_q4 = pack(&q4model);
    let q4_step = bench_ms(2, iters(40), || {
        q4model.step_into(&toks, &mut st_q4, &mut scratch, &mut logits);
        std::hint::black_box(logits.len());
    });

    let mut t = Table::new(
        &format!("§Perf — native decode at B={b}, ctx={ctx}, tier {} (ms/advance-all)", tier.name),
        &["path", "ms", "speedup vs fp32 full-seq"],
    );
    t.row(vec!["fp32 full-seq forward ×8 (before)".into(), ms(before.mean), f2(1.0)]);
    t.row(vec![
        "fp32 batched step".into(),
        ms(fp_step.mean),
        format!("{}x", f2(before.mean / fp_step.mean)),
    ]);
    t.row(vec![
        "W8A8 batched step (zero-alloc, fused i8 conv)".into(),
        ms(q_step.mean),
        format!("{}x", f2(before.mean / q_step.mean)),
    ]);
    t.row(vec![
        format!("W4A8 batched step (packed nibble, {}B weights vs {}B)", w4_bytes, w8_bytes),
        ms(q4_step.mean),
        format!("{}x", f2(before.mean / q4_step.mean)),
    ]);
    t.print();

    // ---- kernel micro-bench: blocked vs naive int8 GEMM ----
    // decode-ish (M=B) and prefill-ish (M=T) shapes of this tier's
    // biggest projection (d_inner × 2·d_inner per layer step)
    let mut kernel_rows: Vec<(String, f64, f64)> = Vec::new();
    for (m, k, n) in [(b, tier.d_model, 2 * tier.d_inner), (64usize, tier.d_inner, 2 * tier.d_inner)]
    {
        let x_q: Vec<i8> = (0..m * k).map(|_| (rng.below(255) as i32 - 127) as i8).collect();
        let w_q: Vec<i8> = (0..k * n).map(|_| (rng.below(255) as i32 - 127) as i8).collect();
        let packed = PackedWeightI8::pack(&w_q, k, n);
        let mut acc = vec![0i32; m * n];
        let naive = bench_ms(3, iters(400), || {
            matmul_i8(&x_q, &w_q, m, k, n, &mut acc);
            std::hint::black_box(acc[0]);
        });
        let blocked = bench_ms(3, iters(400), || {
            matmul_i8_blocked(&x_q, &packed, m, &mut acc);
            std::hint::black_box(acc[0]);
        });
        kernel_rows.push((format!("{m}x{k}x{n}"), naive.mean, blocked.mean));
    }
    let mut kt = Table::new(
        "§Perf — int8 GEMM kernel: naive oracle vs blocked packed (ms/call)",
        &["shape (MxKxN)", "naive", "blocked", "speedup"],
    );
    for (shape, nv, bl) in &kernel_rows {
        kt.row(vec![shape.clone(), ms(*nv), ms(*bl), format!("{}x", f2(nv / bl))]);
    }
    kt.print();

    // ---- kernel micro-bench: W4A8 packed-nibble GEMM, same shapes ----
    // naive per-group oracle vs the blocked i4 fast path (bit-identical
    // outputs; half the weight bytes of the int8 rows above)
    let mut w4_rows: Vec<(String, f64, f64)> = Vec::new();
    for (m, k, n) in [(b, tier.d_model, 2 * tier.d_inner), (64usize, tier.d_inner, 2 * tier.d_inner)]
    {
        let x_q: Vec<i8> = (0..m * k).map(|_| (rng.below(255) as i32 - 127) as i8).collect();
        let w_q4: Vec<i8> = (0..k * n).map(|_| (rng.below(16) as i32 - 8) as i8).collect();
        let packed4 = PackedWeightI4::pack(&w_q4, k, n);
        let group_k = I4_GROUP_K;
        let n_groups = k.div_ceil(group_k);
        let g_scales: Vec<f32> = (0..n_groups * n).map(|_| 0.01 + rng.f32() * 0.01).collect();
        let s_x = 0.02f32;
        let mut fout = vec![0.0f32; m * n];
        let naive4 = bench_ms(3, iters(400), || {
            matmul_w4a8_ref(&x_q, &w_q4, &g_scales, group_k, s_x, m, k, n, &mut fout);
            std::hint::black_box(fout[0]);
        });
        let blocked4 = bench_ms(3, iters(400), || {
            matmul_w4a8_with(Kernels::auto(), &x_q, &packed4, &g_scales, group_k, s_x, m, &mut fout);
            std::hint::black_box(fout[0]);
        });
        w4_rows.push((format!("{m}x{k}x{n}"), naive4.mean, blocked4.mean));
    }
    let mut w4t = Table::new(
        "§Perf — W4A8 GEMM kernel: naive grouped oracle vs blocked nibble (ms/call)",
        &["shape (MxKxN)", "naive", "blocked", "speedup"],
    );
    for (shape, nv, bl) in &w4_rows {
        w4t.row(vec![shape.clone(), ms(*nv), ms(*bl), format!("{}x", f2(nv / bl))]);
    }
    w4t.print();

    // ---- kernel micro-bench: forced scalar vs SIMD dispatch ----
    // ISSUE 3: the explicit-SIMD layer must beat the forced-scalar
    // path by ≥1.5x on at least one decode-shaped GEMM (outputs are
    // bit-identical, so this is pure throughput)
    let kers_simd = Kernels::auto();
    let kers_scalar = Kernels::scalar();
    let simd_available = kers_simd.label() != kers_scalar.label();
    // (shape-label, M, K, N): decode GEMMs at B=8 + a prefill GEMM
    let simd_shapes = [
        ("in_proj decode", b, tier.d_model, 2 * tier.d_inner),
        ("out_proj decode", b, tier.d_inner, tier.d_model),
        ("in_proj prefill", 64usize, tier.d_model, 2 * tier.d_inner),
    ];
    let mut simd_rows: Vec<(String, f64, f64)> = Vec::new();
    for (label, m, k, n) in simd_shapes {
        let x_q: Vec<i8> = (0..m * k).map(|_| (rng.below(255) as i32 - 127) as i8).collect();
        let w_q: Vec<i8> = (0..k * n).map(|_| (rng.below(255) as i32 - 127) as i8).collect();
        let packed = PackedWeightI8::pack(&w_q, k, n);
        let mut acc = vec![0i32; m * n];
        let scalar = bench_ms(3, iters(400), || {
            matmul_i8_blocked_with(kers_scalar, &x_q, &packed, m, &mut acc);
            std::hint::black_box(acc[0]);
        });
        let simd = bench_ms(3, iters(400), || {
            matmul_i8_blocked_with(kers_simd, &x_q, &packed, m, &mut acc);
            std::hint::black_box(acc[0]);
        });
        simd_rows.push((format!("{m}x{k}x{n} ({label})"), scalar.mean, simd.mean));
    }
    // fused i8 conv, decode shape (B lanes of one token each)
    let (di, w) = (tier.d_inner, tier.d_conv);
    let conv_x: Vec<i8> = (0..b * di).map(|_| (rng.below(255) as i32 - 127) as i8).collect();
    let conv_w: Vec<i8> = (0..w * di).map(|_| (rng.below(255) as i32 - 127) as i8).collect();
    let conv_bias: Vec<f32> = (0..di).map(|_| rng.normal() * 0.1).collect();
    let conv_gx: Vec<f32> = (0..di).map(|_| 0.5 + rng.f32()).collect();
    let mut conv_hist = vec![0i8; b * (w - 1) * di];
    let mut conv_out = vec![0.0f32; di];
    let mut bench_conv = |kers: Kernels| {
        bench_ms(3, iters(400), || {
            for bi in 0..b {
                fused_conv_silu_i8_with(
                    kers,
                    &conv_x[bi * di..(bi + 1) * di],
                    &mut conv_hist[bi * (w - 1) * di..(bi + 1) * (w - 1) * di],
                    &conv_w,
                    &conv_bias,
                    &conv_gx,
                    0.013,
                    1,
                    di,
                    w,
                    &mut conv_out,
                );
            }
            std::hint::black_box(conv_out[0]);
        })
    };
    let conv_scalar = bench_conv(kers_scalar);
    let conv_simd = bench_conv(kers_simd);
    // whole W8A8 batched step, forced scalar vs SIMD dispatch
    let mut st_k = pack(&qmodel);
    let mut bench_step = |kers: Kernels| {
        let mut scr = StepScratch::with_kernels(1, kers);
        bench_ms(2, iters(40), || {
            qmodel.step_into(&toks, &mut st_k, &mut scr, &mut logits);
            std::hint::black_box(logits.len());
        })
    };
    let step_scalar = bench_step(kers_scalar);
    let step_simd = bench_step(kers_simd);
    let mut st = Table::new(
        &format!(
            "§Perf — scalar vs SIMD dispatch (kernels: {}; ms/call, bit-identical outputs)",
            kers_simd.label()
        ),
        &["op", "scalar", "simd", "speedup"],
    );
    for (shape, sc, si) in &simd_rows {
        st.row(vec![
            format!("gemm_i8 {shape}"),
            ms(*sc),
            ms(*si),
            format!("{}x", f2(sc / si)),
        ]);
    }
    st.row(vec![
        format!("conv_i8 B={b} di={di} w={w}"),
        ms(conv_scalar.mean),
        ms(conv_simd.mean),
        format!("{}x", f2(conv_scalar.mean / conv_simd.mean)),
    ]);
    st.row(vec![
        format!("w8a8_step B={b}"),
        ms(step_scalar.mean),
        ms(step_simd.mean),
        format!("{}x", f2(step_scalar.mean / step_simd.mean)),
    ]);
    st.print();

    // ---- quantized prefill: stepwise oracle vs full-sequence ----
    let pt = 64usize;
    let ptoks: Vec<u16> = (0..pt).map(|_| rng.below(tier.vocab as u32) as u16).collect();
    let mut st_pf = MambaState::new_quantized(&tier, 1);
    let stepwise = bench_ms(1, iters(10), || {
        let lg = qmodel.prefill_stepwise(&ptoks, &mut st_pf);
        std::hint::black_box(lg.len());
    });
    let mut pf_logits = Vec::new();
    let batched = bench_ms(1, iters(10), || {
        qmodel.prefill_into(&ptoks, &mut st_pf, &mut scratch, &mut pf_logits);
        std::hint::black_box(pf_logits.len());
    });
    let mut pf = Table::new(
        &format!("§Perf — W8A8 prefill over T={pt} (ms; bit-identical outputs)"),
        &["path", "ms", "speedup"],
    );
    pf.row(vec!["stepwise (before)".into(), ms(stepwise.mean), f2(1.0)]);
    pf.row(vec![
        "full-sequence (T×K batched GEMMs)".into(),
        ms(batched.mean),
        format!("{}x", f2(stepwise.mean / batched.mean)),
    ]);
    pf.print();

    // ---- prefix cache: warm vs cold TTFT over a shared 512-token prefix ----
    // ISSUE 4: the first request (cold) prefills the whole prompt and
    // leaves snapshots behind; the second (warm) shares the 512-token
    // prefix, restores the cached state and prefills only its own
    // suffix. Token-steps are the deterministic acceptance quantity;
    // wall-clock TTFT rides along in the JSON.
    let shared_len = 512usize;
    let suffix_len = 16usize;
    let shared: Vec<u16> =
        (0..shared_len).map(|_| rng.below(tier.vocab as u32) as u16).collect();
    let mut mk_prompt = || -> Vec<u16> {
        let mut p = shared.clone();
        p.extend((0..suffix_len).map(|_| rng.below(tier.vocab as u32) as u16));
        p
    };
    let cold_prompt = mk_prompt();
    let warm_prompt = mk_prompt();
    let q_cached = QuantizedMambaModel::from_model(&model, &calib, &QuantConfig::default());
    let mut eng = NativeEngine::new(
        Box::new(q_cached),
        NativeEngineConfig { cache_bytes: 8 << 20, snapshot_stride: 128, ..Default::default() },
    );
    let mk_req = |id: u64, prompt: Vec<u16>| Request {
        id,
        prompt,
        max_new_tokens: 1,
        params: SamplingParams::default(),
        stop_at_eos: false,
    };
    eng.submit(mk_req(1, cold_prompt.clone()));
    let cold_resp = eng.run_to_completion().unwrap().remove(0);
    eng.submit(mk_req(2, warm_prompt.clone()));
    let warm_resp = eng.run_to_completion().unwrap().remove(0);
    let cache_stats = eng.cache_stats().expect("cache is armed");
    let (ttft_cold, ttft_warm) = (cold_resp.ttft_ms, warm_resp.ttft_ms);
    let cold_steps = cold_prompt.len();
    let warm_steps = warm_prompt.len() - cache_stats.prefill_tokens_saved as usize;
    let step_ratio = cold_steps as f64 / warm_steps.max(1) as f64;
    let mut ct = Table::new(
        &format!(
            "§Perf — prefix cache: warm vs cold TTFT (shared {shared_len}-token prefix, \
             stride 128, hit rate {:.0}%)",
            100.0 * cache_stats.hit_rate()
        ),
        &["path", "prefill token-steps", "TTFT ms"],
    );
    ct.row(vec!["cold (miss: full prompt)".into(), cold_steps.to_string(), ms(ttft_cold)]);
    ct.row(vec!["warm (hit: suffix only)".into(), warm_steps.to_string(), ms(ttft_warm)]);
    ct.print();

    // ---- serving latency percentiles through the unified scheduler ----
    // ISSUE 5 satellite: per-request TTFT and pooled inter-token gaps
    // recorded by the engine metrics, exported as trajectory keys
    // (ttft_p50 / itl_p95) so scheduler regressions show up in CI.
    let q_serve = QuantizedMambaModel::from_model(&model, &calib, &QuantConfig::default());
    let mut serve_eng = NativeEngine::new(
        Box::new(q_serve),
        NativeEngineConfig { prefill_chunk: 64, ..Default::default() },
    );
    let n_serve = 16usize;
    for i in 0..n_serve as u64 {
        let plen = 16 + (i as usize % 3) * 8;
        let prompt: Vec<u16> =
            (0..plen).map(|_| rng.below(tier.vocab as u32) as u16).collect();
        serve_eng.submit(Request {
            id: i,
            prompt,
            max_new_tokens: 8,
            params: SamplingParams::default(),
            stop_at_eos: false,
        });
    }
    serve_eng.run_to_completion().unwrap();
    let ttft_sum = serve_eng.metrics.ttft_summary();
    let itl_sum = serve_eng.metrics.itl_summary();

    // ---- burst: long prompts landing mid-decode, chunked vs not ----
    // ISSUE 5 acceptance: with prefill_chunk=64 the max inter-token
    // gap of already-decoding requests must be strictly lower than
    // with unchunked prefill (both run the identical workload and
    // produce identical tokens — the scheduler only moves latency).
    // The harness is the shared `bench_support::burst_itl_max`, so
    // `serve_batch --burst` demos the exact workload CI tracks.
    let (burst_n, burst_len, chunk) = (2usize, 512usize, 64usize);
    let mk_qm = || QuantizedMambaModel::from_model(&model, &calib, &QuantConfig::default());
    let gap_chunked = burst_itl_max(
        Box::new(mk_qm()),
        NativeEngineConfig { prefill_chunk: chunk, ..Default::default() },
        4,
        48,
        burst_n,
        burst_len,
        0xB5A7,
    )
    .unwrap();
    let gap_unchunked = burst_itl_max(
        Box::new(mk_qm()),
        NativeEngineConfig::default(),
        4,
        48,
        burst_n,
        burst_len,
        0xB5A7,
    )
    .unwrap();
    let mut bt = Table::new(
        &format!(
            "§Perf — unified scheduler: serving latency (n={n_serve}) + \
             {burst_n}×{burst_len}-token burst ITL"
        ),
        &["quantity", "ms"],
    );
    bt.row(vec!["TTFT p50 (chunk=64)".into(), ms(ttft_sum.p50)]);
    bt.row(vec!["ITL p95 (chunk=64)".into(), ms(itl_sum.p95)]);
    bt.row(vec![format!("burst max ITL gap, chunk={chunk}"), ms(gap_chunked)]);
    bt.row(vec!["burst max ITL gap, unchunked".into(), ms(gap_unchunked)]);
    bt.print();

    // ---- flight recorder: traced vs untraced engine decode tick ----
    // ISSUE 9 acceptance: with the recorder armed (`trace: true`) the
    // steady-state decode tick through the full `NativeEngine::step`
    // path may cost at most 2% more than the untraced engine. The span
    // ring is preallocated and each record is one clock read + one
    // `Copy` store, so tracing must be effectively free at tick
    // granularity. Identical prompts, never-finishing lanes: after the
    // warmup ticks both engines run pure B=8 decode rounds.
    let trace_prompts: Vec<Vec<u16>> = (0..b)
        .map(|_| (0..ctx).map(|_| rng.below(tier.vocab as u32) as u16).collect())
        .collect();
    let mk_traced_eng = |trace: bool| {
        let mut eng = NativeEngine::new(
            Box::new(mk_qm()),
            NativeEngineConfig { trace, ..Default::default() },
        );
        for (i, prompt) in trace_prompts.iter().enumerate() {
            eng.submit(Request {
                id: (i + 1) as u64,
                prompt: prompt.clone(),
                max_new_tokens: 1 << 20, // never finishes inside the bench window
                params: SamplingParams::default(),
                stop_at_eos: false,
            });
        }
        eng
    };
    let mut eng_plain = mk_traced_eng(false);
    let tick_plain = bench_ms(8, iters(160), || {
        let done = eng_plain.step().expect("untraced engine tick");
        std::hint::black_box(done.len());
    });
    let mut eng_traced = mk_traced_eng(true);
    let tick_traced = bench_ms(8, iters(160), || {
        let done = eng_traced.step().expect("traced engine tick");
        std::hint::black_box(done.len());
    });
    let spans_recorded =
        eng_traced.trace_ring().map(|r| r.total_recorded()).unwrap_or(0);
    assert!(spans_recorded > 0, "traced engine recorded no spans — the 2% claim would be vacuous");
    let trace_overhead_pct = 100.0 * (tick_traced.mean / tick_plain.mean - 1.0);
    let mut tt = Table::new(
        &format!("§Perf — flight recorder: engine decode tick at B={b} (ms/tick)"),
        &["path", "ms", "overhead"],
    );
    tt.row(vec!["trace off (engine baseline)".into(), ms(tick_plain.mean), f2(0.0) + "%"]);
    tt.row(vec![
        format!("trace on ({spans_recorded} spans recorded)"),
        ms(tick_traced.mean),
        format!("{}%", f2(trace_overhead_pct)),
    ]);
    tt.print();

    // ---- speculative decoding: plain vs spec engine, greedy B=8 ----
    // ISSUE 10: the W4A8 twin drafts K tokens per lane; the target
    // verifies all K+1 positions in ONE batched prefill and rolls the
    // lane's O(1) snapshot back on the first rejection. The drafts are
    // quantization-close to the target, so greedy acceptance is high
    // and the engine amortizes K+1 stepwise target passes into one
    // batched read of the weights. Streams are asserted bit-identical
    // — the speedup is pure scheduling, not sampling drift.
    let (spec_b, spec_k, spec_new) = (8usize, 8usize, 96usize);
    let spec_prompts: Vec<Vec<u16>> = (0..spec_b)
        .map(|_| (0..16).map(|_| rng.below(tier.vocab as u32) as u16).collect())
        .collect();
    let mk_spec_reqs = || -> Vec<Request> {
        spec_prompts
            .iter()
            .enumerate()
            .map(|(i, p)| Request {
                id: (i + 1) as u64,
                prompt: p.clone(),
                max_new_tokens: spec_new,
                params: SamplingParams::default(), // greedy
                stop_at_eos: false,
            })
            .collect()
    };
    let mk_q4 = || {
        QuantizedMambaModel::from_model(
            &model,
            &calib,
            &QuantConfig { weight_bits: 4, ..QuantConfig::default() },
        )
    };
    let mut plain_eng = NativeEngine::new(Box::new(mk_qm()), NativeEngineConfig::default());
    for r in mk_spec_reqs() {
        plain_eng.submit(r);
    }
    let t0 = std::time::Instant::now();
    let mut plain_out = plain_eng.run_to_completion().expect("plain decode run");
    let plain_s = t0.elapsed().as_secs_f64();
    plain_out.sort_by_key(|r| r.id);
    let mut spec_eng = NativeEngine::with_draft(
        Box::new(mk_qm()),
        Box::new(mk_q4()),
        NativeEngineConfig { spec_tokens: spec_k, ..Default::default() },
    );
    for r in mk_spec_reqs() {
        spec_eng.submit(r);
    }
    let t0 = std::time::Instant::now();
    let mut spec_out = spec_eng.run_to_completion().expect("spec decode run");
    let spec_s = t0.elapsed().as_secs_f64();
    spec_out.sort_by_key(|r| r.id);
    for (a, s) in plain_out.iter().zip(&spec_out) {
        assert_eq!(
            (a.id, &a.tokens),
            (s.id, &s.tokens),
            "speculative decoding changed the token stream"
        );
    }
    let spec_total = (spec_b * spec_new) as f64;
    let tok_s_plain_dec = spec_total / plain_s.max(1e-9);
    let tok_s_spec = spec_total / spec_s.max(1e-9);
    let spec_speedup = tok_s_spec / tok_s_plain_dec.max(1e-9);
    let accept_len_mean = spec_eng.metrics.spec_accept_len_mean();
    let mut spt = Table::new(
        &format!(
            "§Perf — speculative decoding: greedy B={spec_b}, K={spec_k}, \
             {spec_new} tokens/lane (streams bit-identical, asserted)"
        ),
        &["path", "tok/s", "mean accept len"],
    );
    spt.row(vec!["plain W8A8 decode".into(), format!("{tok_s_plain_dec:.0}"), "-".into()]);
    spt.row(vec![
        format!("spec W8A8 + W4A8 draft (K={spec_k})"),
        format!("{tok_s_spec:.0}"),
        f2(accept_len_mean),
    ]);
    spt.print();

    let speedup = before.mean / q_step.mean;
    println!(
        "\nacceptance (≥2x W8A8 batched step vs per-token fp32 full-seq at B=8): {} ({:.2}x)",
        if speedup >= 2.0 { "PASS" } else { "FAIL" },
        speedup
    );
    // ISSUE 8: the nibble tier must not pay for its density — decode
    // throughput at least matches W8A8 on the standard bench tier
    let tok_s_w8 = b as f64 * 1000.0 / q_step.mean;
    let tok_s_w4 = b as f64 * 1000.0 / q4_step.mean;
    println!(
        "acceptance (W4A8 decode tokens/s ≥ W8A8 at B={b}, tier {}): {} \
         ({:.0} vs {:.0} tok/s; weight bytes {w4_bytes} vs {w8_bytes}, exactly half)",
        tier.name,
        if tok_s_w4 >= tok_s_w8 { "PASS" } else { "FAIL" },
        tok_s_w4,
        tok_s_w8,
    );
    println!(
        "kernel: blocked int8 GEMM {:.2}x vs naive (decode shape); prefill: full-seq {:.2}x vs stepwise",
        kernel_rows[0].1 / kernel_rows[0].2,
        stepwise.mean / batched.mean
    );
    // the ISSUE 3 criterion is decode-shaped: exclude the prefill row
    let best_gemm_simd = simd_rows
        .iter()
        .filter(|(shape, _, _)| shape.contains("decode"))
        .map(|(_, sc, si)| sc / si)
        .fold(0.0f64, f64::max);
    if simd_available {
        println!(
            "acceptance (≥1.5x scalar→SIMD on a decode-shaped blocked GEMM, kernels={}): {} ({:.2}x best)",
            kers_simd.label(),
            if best_gemm_simd >= 1.5 { "PASS" } else { "FAIL" },
            best_gemm_simd
        );
    } else {
        println!("acceptance (≥1.5x scalar→SIMD blocked GEMM): n/a — no SIMD backend on this machine");
    }
    println!(
        "acceptance (≥2x fewer prefill token-steps warm vs cold, shared {shared_len}-token prefix): {} \
         ({:.1}x fewer: {cold_steps} vs {warm_steps} steps; {} tokens saved; wall-clock TTFT {:.2}x)",
        if step_ratio >= 2.0 { "PASS" } else { "FAIL" },
        step_ratio,
        cache_stats.prefill_tokens_saved,
        ttft_cold / ttft_warm.max(1e-9),
    );
    println!(
        "acceptance (chunked prefill bounds decode ITL under a {burst_n}x{burst_len}-token burst): {} \
         (max gap {:.3} ms at chunk={chunk} vs {:.3} ms unchunked, {:.1}x lower)",
        if gap_chunked < gap_unchunked { "PASS" } else { "FAIL" },
        gap_chunked,
        gap_unchunked,
        gap_unchunked / gap_chunked.max(1e-9),
    );
    println!(
        "acceptance (flight-recorder tracing overhead ≤ 2% on the B={b} engine decode tick): {} \
         ({:+.2}%: {:.4} ms traced vs {:.4} ms untraced, {spans_recorded} spans)",
        if trace_overhead_pct <= 2.0 { "PASS" } else { "FAIL" },
        trace_overhead_pct,
        tick_traced.mean,
        tick_plain.mean,
    );
    println!(
        "acceptance (spec decode ≥1.5x plain greedy tokens/s at B={spec_b}, K={spec_k}): {} \
         ({:.2}x: {:.0} vs {:.0} tok/s; mean acceptance length {:.2}; streams bit-identical)",
        if spec_speedup >= 1.5 { "PASS" } else { "FAIL" },
        spec_speedup,
        tok_s_spec,
        tok_s_plain_dec,
        accept_len_mean,
    );

    // ---- machine-readable trajectory ----
    let mut entries = vec![
        Entry {
            op: "decode_fp32_fullseq_before",
            shape: format!("B={b} ctx={ctx} tier={}", tier.name),
            ms: before.mean,
            speedup: 1.0,
        },
        Entry {
            op: "decode_step_fp32",
            shape: format!("B={b} tier={}", tier.name),
            ms: fp_step.mean,
            speedup: before.mean / fp_step.mean,
        },
        Entry {
            op: "decode_step_w8a8",
            shape: format!("B={b} tier={}", tier.name),
            ms: q_step.mean,
            speedup: before.mean / q_step.mean,
        },
        Entry {
            op: "decode_step_w4a8",
            shape: format!("B={b} tier={}", tier.name),
            ms: q4_step.mean,
            speedup: before.mean / q4_step.mean,
        },
        // per-token decode latency; `speedup` carries the tokens/s
        // reading (the W4A8-vs-W8A8 acceptance quantity)
        Entry {
            op: "tok_per_s_w8a8",
            shape: format!("B={b} tier={}", tier.name),
            ms: q_step.mean / b as f64,
            speedup: tok_s_w8,
        },
        Entry {
            op: "tok_per_s_w4a8",
            shape: format!("B={b} tier={}", tier.name),
            ms: q4_step.mean / b as f64,
            speedup: tok_s_w4,
        },
        Entry {
            op: "prefill_w8a8_stepwise",
            shape: format!("T={pt} tier={}", tier.name),
            ms: stepwise.mean,
            speedup: 1.0,
        },
        Entry {
            op: "prefill_w8a8_fullseq",
            shape: format!("T={pt} tier={}", tier.name),
            ms: batched.mean,
            speedup: stepwise.mean / batched.mean,
        },
    ];
    for (shape, nv, bl) in &kernel_rows {
        entries.push(Entry {
            op: "gemm_i8_blocked",
            shape: shape.clone(),
            ms: *bl,
            speedup: nv / bl,
        });
    }
    // W4A8 nibble GEMM rows: audited against MAX_SAFE_K_I4 (the op
    // name contains "w4a8", which selects the i4 bound in quamba_audit)
    for (shape, nv, bl) in &w4_rows {
        entries.push(Entry {
            op: "gemm_w4a8",
            shape: shape.clone(),
            ms: *bl,
            speedup: nv / bl,
        });
    }
    // scalar→SIMD per-op speedups (speedup = forced-scalar ms / SIMD
    // ms; 1.0x everywhere when no SIMD backend exists on this machine)
    for (shape, sc, si) in &simd_rows {
        entries.push(Entry {
            op: "gemm_i8_blocked_simd",
            shape: shape.clone(),
            ms: *si,
            speedup: sc / si,
        });
    }
    entries.push(Entry {
        op: "conv_i8_fused_simd",
        shape: format!("B={b} di={di} w={w}"),
        ms: conv_simd.mean,
        speedup: conv_scalar.mean / conv_simd.mean,
    });
    entries.push(Entry {
        op: "w8a8_step_simd",
        shape: format!("B={b} tier={}", tier.name),
        ms: step_simd.mean,
        speedup: step_scalar.mean / step_simd.mean,
    });
    // warm/cold TTFT through the prefix cache. `speedup` on the warm
    // entry is the deterministic token-step ratio (cold steps / warm
    // steps), not a timing ratio — the acceptance quantity.
    entries.push(Entry {
        op: "ttft_cold",
        shape: format!("T={} shared={shared_len} tier={}", cold_prompt.len(), tier.name),
        ms: ttft_cold,
        speedup: 1.0,
    });
    entries.push(Entry {
        op: "ttft_warm",
        shape: format!("T={} shared={shared_len} tier={}", warm_prompt.len(), tier.name),
        ms: ttft_warm,
        speedup: step_ratio,
    });
    // unified-scheduler serving keys (ISSUE 5): TTFT p50 and pooled
    // ITL p95 of a small served workload, plus the burst max-gap pair.
    // `speedup` on the chunked burst entry is the unchunked/chunked
    // gap ratio — the quantity the chunking win is measured by.
    entries.push(Entry {
        op: "ttft_p50",
        shape: format!("serve n={n_serve} chunk=64 tier={}", tier.name),
        ms: ttft_sum.p50,
        speedup: 1.0,
    });
    entries.push(Entry {
        op: "itl_p95",
        shape: format!("serve n={n_serve} chunk=64 tier={}", tier.name),
        ms: itl_sum.p95,
        speedup: 1.0,
    });
    entries.push(Entry {
        op: "burst_itl_max",
        shape: format!("chunk={chunk} burst={burst_n}x{burst_len} tier={}", tier.name),
        ms: gap_chunked,
        speedup: gap_unchunked / gap_chunked.max(1e-9),
    });
    entries.push(Entry {
        op: "burst_itl_max",
        shape: format!("chunk=inf burst={burst_n}x{burst_len} tier={}", tier.name),
        ms: gap_unchunked,
        speedup: 1.0,
    });
    // flight-recorder pair (ISSUE 9). `speedup` on the traced entry is
    // untraced/traced tick time — ≥ 0.98 is the ≤2%-overhead acceptance
    entries.push(Entry {
        op: "decode_step_w8a8_engine",
        shape: format!("B={b} tier={}", tier.name),
        ms: tick_plain.mean,
        speedup: 1.0,
    });
    entries.push(Entry {
        op: "decode_step_w8a8_traced",
        shape: format!("B={b} tier={}", tier.name),
        ms: tick_traced.mean,
        speedup: tick_plain.mean / tick_traced.mean,
    });
    // speculative decoding (ISSUE 10). Same convention as the other
    // tok_per_s_* keys: ms = per-token latency, speedup = tokens/s.
    // accept_len_mean carries the mean acceptance length in `ms` (a
    // count, not a time) and the spec/plain throughput ratio in
    // `speedup` — the two acceptance quantities of the spec path.
    entries.push(Entry {
        op: "tok_per_s_spec",
        shape: format!("B={spec_b} K={spec_k} draft=w4a8 tier={}", tier.name),
        ms: 1000.0 * spec_s / spec_total,
        speedup: tok_s_spec,
    });
    entries.push(Entry {
        op: "accept_len_mean",
        shape: format!("B={spec_b} K={spec_k} draft=w4a8 tier={}", tier.name),
        ms: accept_len_mean,
        speedup: spec_speedup,
    });
    let path = std::env::var("QUAMBA_BENCH_JSON")
        .unwrap_or_else(|_| "BENCH_native_decode.json".to_string());
    let doc = json::obj(vec![
        ("bench", json::s("native_decode")),
        ("tier", json::s(&tier.name)),
        ("kernels", json::s(kers_simd.label())),
        (
            "entries",
            json::arr(
                entries
                    .iter()
                    .map(|e| {
                        json::obj(vec![
                            ("op", json::s(e.op)),
                            ("shape", json::s(&e.shape)),
                            ("ms", json::num(e.ms)),
                            ("speedup", json::num(e.speedup)),
                        ])
                    })
                    .collect(),
            ),
        ),
    ]);
    match std::fs::write(&path, json::write(&doc) + "\n") {
        Ok(()) => println!("wrote {path}"),
        Err(e) => println!("[warn] could not write {path}: {e}"),
    }
    println!("Recorded in EXPERIMENTS.md §Perf (native backend).");
}
