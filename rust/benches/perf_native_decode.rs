//! §Perf — the native W8A8 batched decode engine vs the only
//! previously-available rust path (per-token full-sequence fp32
//! `forward`), plus kernel-level micro-benches for the PR-2 hot-path
//! rework. Runs with zero artifacts: the model is synthesized and
//! calibrated on the spot.
//!
//! Acceptance targets:
//! * (ISSUE 1) batched W8A8 decode steps at B=8 must be ≥2x faster
//!   than advancing the same 8 sequences by re-running the
//!   full-sequence fp32 forward per token;
//! * (ISSUE 2) reports the blocked-vs-naive int8 GEMM speedup and the
//!   batched-vs-stepwise quantized prefill speedup, and persists the
//!   whole table to `BENCH_native_decode.json` (override the path with
//!   `QUAMBA_BENCH_JSON`) so future PRs can track regressions
//!   machine-readably.

use quamba::bench_support::{bench_ms, f2, iters, ms, Table};
use quamba::quant::qlinear::{matmul_i8, matmul_i8_blocked, PackedWeightI8};
use quamba::ssm::mamba::QuantSites;
use quamba::ssm::{
    MambaModel, MambaState, MambaTier, QuantConfig, QuantizedMambaModel, StepModel, StepScratch,
};
use quamba::util::json;
use quamba::util::rng::Pcg32;

/// One machine-readable bench entry (op, shape, ms, speedup).
struct Entry {
    op: &'static str,
    shape: String,
    ms: f64,
    speedup: f64,
}

fn main() {
    let tier = MambaTier {
        name: "edge64".into(),
        d_model: 64,
        n_layer: 4,
        d_state: 8,
        d_conv: 4,
        d_inner: 128,
        dt_rank: 8,
        vocab: 256,
    };
    let model = MambaModel::synthetic(tier.clone(), 7);
    let mut rng = Pcg32::new(0x5EED);
    let calib: Vec<u16> = (0..512).map(|_| rng.below(tier.vocab as u32) as u16).collect();
    let qmodel = QuantizedMambaModel::from_model(&model, &calib, &QuantConfig::default());

    let ctx = 32usize; // context each sequence has already consumed
    let b = 8usize;
    let prompts: Vec<Vec<u16>> = (0..b)
        .map(|_| (0..ctx).map(|_| rng.below(tier.vocab as u32) as u16).collect())
        .collect();

    // batched states for the step paths (one B-lane state per model)
    let cpl = (tier.d_conv - 1) * tier.d_inner;
    let spl = tier.d_inner * tier.d_state;
    let pack = |m: &dyn StepModel| -> MambaState {
        let quantized = m.quantized_conv_state();
        let mut packed = MambaState::new_for(&tier, b, quantized);
        for (bi, p) in prompts.iter().enumerate() {
            let mut st = MambaState::new_for(&tier, 1, quantized);
            m.prefill(p, &mut st);
            // copy lane 0 of the single state into lane bi of the pack
            for li in 0..tier.n_layer {
                if quantized {
                    packed.conv_q[(li * b + bi) * cpl..(li * b + bi + 1) * cpl]
                        .copy_from_slice(&st.conv_q[li * cpl..(li + 1) * cpl]);
                } else {
                    packed.conv[(li * b + bi) * cpl..(li * b + bi + 1) * cpl]
                        .copy_from_slice(&st.conv[li * cpl..(li + 1) * cpl]);
                }
                packed.ssm[(li * b + bi) * spl..(li * b + bi + 1) * spl]
                    .copy_from_slice(&st.ssm[li * spl..(li + 1) * spl]);
            }
        }
        packed
    };

    let toks: Vec<u16> = (0..b).map(|_| rng.below(tier.vocab as u32) as u16).collect();

    // before: the pre-step() world — advance each sequence one token by
    // re-running the fp32 full-sequence forward over its whole prefix
    let sites = QuantSites::none();
    let before = bench_ms(1, iters(8), || {
        for p in &prompts {
            let lg = model.forward(p, &sites, None);
            std::hint::black_box(lg.len());
        }
    });

    // after (fp32): one batched stateful step for all 8 lanes
    let mut st_fp = pack(&model);
    let mut scratch = StepScratch::new(1);
    let mut logits = Vec::new();
    let fp_step = bench_ms(2, iters(40), || {
        model.step_into(&toks, &mut st_fp, &mut scratch, &mut logits);
        std::hint::black_box(logits.len());
    });

    // after (W8A8): the quantized zero-alloc batched step — the
    // deployment path
    let mut st_q = pack(&qmodel);
    let q_step = bench_ms(2, iters(40), || {
        qmodel.step_into(&toks, &mut st_q, &mut scratch, &mut logits);
        std::hint::black_box(logits.len());
    });

    let mut t = Table::new(
        &format!("§Perf — native decode at B={b}, ctx={ctx}, tier {} (ms/advance-all)", tier.name),
        &["path", "ms", "speedup vs fp32 full-seq"],
    );
    t.row(vec!["fp32 full-seq forward ×8 (before)".into(), ms(before.mean), f2(1.0)]);
    t.row(vec![
        "fp32 batched step".into(),
        ms(fp_step.mean),
        format!("{}x", f2(before.mean / fp_step.mean)),
    ]);
    t.row(vec![
        "W8A8 batched step (zero-alloc, fused i8 conv)".into(),
        ms(q_step.mean),
        format!("{}x", f2(before.mean / q_step.mean)),
    ]);
    t.print();

    // ---- kernel micro-bench: blocked vs naive int8 GEMM ----
    // decode-ish (M=B) and prefill-ish (M=T) shapes of this tier's
    // biggest projection (d_inner × 2·d_inner per layer step)
    let mut kernel_rows: Vec<(String, f64, f64)> = Vec::new();
    for (m, k, n) in [(b, tier.d_model, 2 * tier.d_inner), (64usize, tier.d_inner, 2 * tier.d_inner)]
    {
        let x_q: Vec<i8> = (0..m * k).map(|_| (rng.below(255) as i32 - 127) as i8).collect();
        let w_q: Vec<i8> = (0..k * n).map(|_| (rng.below(255) as i32 - 127) as i8).collect();
        let packed = PackedWeightI8::pack(&w_q, k, n);
        let mut acc = vec![0i32; m * n];
        let naive = bench_ms(3, iters(400), || {
            matmul_i8(&x_q, &w_q, m, k, n, &mut acc);
            std::hint::black_box(acc[0]);
        });
        let blocked = bench_ms(3, iters(400), || {
            matmul_i8_blocked(&x_q, &packed, m, &mut acc);
            std::hint::black_box(acc[0]);
        });
        kernel_rows.push((format!("{m}x{k}x{n}"), naive.mean, blocked.mean));
    }
    let mut kt = Table::new(
        "§Perf — int8 GEMM kernel: naive oracle vs blocked packed (ms/call)",
        &["shape (MxKxN)", "naive", "blocked", "speedup"],
    );
    for (shape, nv, bl) in &kernel_rows {
        kt.row(vec![shape.clone(), ms(*nv), ms(*bl), format!("{}x", f2(nv / bl))]);
    }
    kt.print();

    // ---- quantized prefill: stepwise oracle vs full-sequence ----
    let pt = 64usize;
    let ptoks: Vec<u16> = (0..pt).map(|_| rng.below(tier.vocab as u32) as u16).collect();
    let mut st_pf = MambaState::new_quantized(&tier, 1);
    let stepwise = bench_ms(1, iters(10), || {
        let lg = qmodel.prefill_stepwise(&ptoks, &mut st_pf);
        std::hint::black_box(lg.len());
    });
    let mut pf_logits = Vec::new();
    let batched = bench_ms(1, iters(10), || {
        qmodel.prefill_into(&ptoks, &mut st_pf, &mut scratch, &mut pf_logits);
        std::hint::black_box(pf_logits.len());
    });
    let mut pf = Table::new(
        &format!("§Perf — W8A8 prefill over T={pt} (ms; bit-identical outputs)"),
        &["path", "ms", "speedup"],
    );
    pf.row(vec!["stepwise (before)".into(), ms(stepwise.mean), f2(1.0)]);
    pf.row(vec![
        "full-sequence (T×K batched GEMMs)".into(),
        ms(batched.mean),
        format!("{}x", f2(stepwise.mean / batched.mean)),
    ]);
    pf.print();

    let speedup = before.mean / q_step.mean;
    println!(
        "\nacceptance (≥2x W8A8 batched step vs per-token fp32 full-seq at B=8): {} ({:.2}x)",
        if speedup >= 2.0 { "PASS" } else { "FAIL" },
        speedup
    );
    println!(
        "kernel: blocked int8 GEMM {:.2}x vs naive (decode shape); prefill: full-seq {:.2}x vs stepwise",
        kernel_rows[0].1 / kernel_rows[0].2,
        stepwise.mean / batched.mean
    );

    // ---- machine-readable trajectory ----
    let mut entries = vec![
        Entry {
            op: "decode_fp32_fullseq_before",
            shape: format!("B={b} ctx={ctx} tier={}", tier.name),
            ms: before.mean,
            speedup: 1.0,
        },
        Entry {
            op: "decode_step_fp32",
            shape: format!("B={b} tier={}", tier.name),
            ms: fp_step.mean,
            speedup: before.mean / fp_step.mean,
        },
        Entry {
            op: "decode_step_w8a8",
            shape: format!("B={b} tier={}", tier.name),
            ms: q_step.mean,
            speedup: before.mean / q_step.mean,
        },
        Entry {
            op: "prefill_w8a8_stepwise",
            shape: format!("T={pt} tier={}", tier.name),
            ms: stepwise.mean,
            speedup: 1.0,
        },
        Entry {
            op: "prefill_w8a8_fullseq",
            shape: format!("T={pt} tier={}", tier.name),
            ms: batched.mean,
            speedup: stepwise.mean / batched.mean,
        },
    ];
    for (shape, nv, bl) in &kernel_rows {
        entries.push(Entry {
            op: "gemm_i8_blocked",
            shape: shape.clone(),
            ms: *bl,
            speedup: nv / bl,
        });
    }
    let path = std::env::var("QUAMBA_BENCH_JSON")
        .unwrap_or_else(|_| "BENCH_native_decode.json".to_string());
    let doc = json::obj(vec![
        ("bench", json::s("native_decode")),
        ("tier", json::s(&tier.name)),
        (
            "entries",
            json::arr(
                entries
                    .iter()
                    .map(|e| {
                        json::obj(vec![
                            ("op", json::s(e.op)),
                            ("shape", json::s(&e.shape)),
                            ("ms", json::num(e.ms)),
                            ("speedup", json::num(e.speedup)),
                        ])
                    })
                    .collect(),
            ),
        ),
    ]);
    match std::fs::write(&path, json::write(&doc) + "\n") {
        Ok(()) => println!("wrote {path}"),
        Err(e) => println!("[warn] could not write {path}: {e}"),
    }
    println!("Recorded in EXPERIMENTS.md §Perf (native backend).");
}
