//! §Perf — the native W8A8 batched decode engine vs the only
//! previously-available rust path (per-token full-sequence fp32
//! `forward`). Runs with zero artifacts: the model is synthesized and
//! calibrated on the spot.
//!
//! Acceptance target (ISSUE 1): batched W8A8 decode steps at B=8 must
//! be ≥2x faster than advancing the same 8 sequences by re-running the
//! full-sequence fp32 forward per token.

use quamba::bench_support::{bench_ms, f2, iters, ms, Table};
use quamba::ssm::mamba::QuantSites;
use quamba::ssm::{MambaModel, MambaState, MambaTier, QuantConfig, QuantizedMambaModel, StepModel};
use quamba::util::rng::Pcg32;

fn main() {
    let tier = MambaTier {
        name: "edge64".into(),
        d_model: 64,
        n_layer: 4,
        d_state: 8,
        d_conv: 4,
        d_inner: 128,
        dt_rank: 8,
        vocab: 256,
    };
    let model = MambaModel::synthetic(tier.clone(), 7);
    let mut rng = Pcg32::new(0x5EED);
    let calib: Vec<u16> = (0..512).map(|_| rng.below(tier.vocab as u32) as u16).collect();
    let qmodel = QuantizedMambaModel::from_model(&model, &calib, &QuantConfig::default());

    let ctx = 32usize; // context each sequence has already consumed
    let b = 8usize;
    let prompts: Vec<Vec<u16>> = (0..b)
        .map(|_| (0..ctx).map(|_| rng.below(tier.vocab as u32) as u16).collect())
        .collect();

    // batched states for the step paths (one B-lane state per model)
    let pack = |m: &dyn StepModel| -> MambaState {
        let mut packed = MambaState::new(&tier, b);
        for (bi, p) in prompts.iter().enumerate() {
            let mut st = MambaState::new(&tier, 1);
            m.prefill(p, &mut st);
            let (c, s) = st.into_raw();
            // copy lane 0 of the single state into lane bi of the pack
            let cpl = (tier.d_conv - 1) * tier.d_inner;
            let spl = tier.d_inner * tier.d_state;
            for li in 0..tier.n_layer {
                packed.conv[(li * b + bi) * cpl..(li * b + bi + 1) * cpl]
                    .copy_from_slice(&c[li * cpl..(li + 1) * cpl]);
                packed.ssm[(li * b + bi) * spl..(li * b + bi + 1) * spl]
                    .copy_from_slice(&s[li * spl..(li + 1) * spl]);
            }
        }
        packed
    };

    let toks: Vec<u16> = (0..b).map(|_| rng.below(tier.vocab as u32) as u16).collect();

    // before: the pre-step() world — advance each sequence one token by
    // re-running the fp32 full-sequence forward over its whole prefix
    let sites = QuantSites::none();
    let before = bench_ms(1, iters(8), || {
        for p in &prompts {
            let lg = model.forward(p, &sites, None);
            std::hint::black_box(lg.len());
        }
    });

    // after (fp32): one batched stateful step for all 8 lanes
    let mut st_fp = pack(&model);
    let fp_step = bench_ms(2, iters(40), || {
        let lg = model.step(&toks, &mut st_fp);
        std::hint::black_box(lg.len());
    });

    // after (W8A8): the quantized batched step — the deployment path
    let mut st_q = pack(&qmodel);
    let q_step = bench_ms(2, iters(40), || {
        let lg = qmodel.step(&toks, &mut st_q);
        std::hint::black_box(lg.len());
    });

    let mut t = Table::new(
        &format!("§Perf — native decode at B={b}, ctx={ctx}, tier {} (ms/advance-all)", tier.name),
        &["path", "ms", "speedup vs fp32 full-seq"],
    );
    t.row(vec!["fp32 full-seq forward ×8 (before)".into(), ms(before.mean), f2(1.0)]);
    t.row(vec![
        "fp32 batched step (this PR)".into(),
        ms(fp_step.mean),
        format!("{}x", f2(before.mean / fp_step.mean)),
    ]);
    t.row(vec![
        "W8A8 batched step (this PR)".into(),
        ms(q_step.mean),
        format!("{}x", f2(before.mean / q_step.mean)),
    ]);
    t.print();
    let speedup = before.mean / q_step.mean;
    println!(
        "\nacceptance (≥2x W8A8 batched step vs per-token fp32 full-seq at B=8): {} ({:.2}x)",
        if speedup >= 2.0 { "PASS" } else { "FAIL" },
        speedup
    );
    println!("Recorded in EXPERIMENTS.md §Perf (native backend).");
}
