//! Paper Table 6: sensitivity to the percentile p used to clip the SSM
//! input x, scored on lambada-synth. Expected shape: p=99 over-clips
//! (catastrophic for small tiers); high percentiles best for small
//! models, slightly lower for the largest (more outliers to clip).

use quamba::bench_support::{iters, open_runtime_or_skip, pct, Table};
use quamba::data::load_tasks;
use quamba::eval::run_tasks;

fn main() {
    let Some(mut rt) = open_runtime_or_skip("table6_percentile") else { return };
    let tasks = load_tasks(&rt.manifest().data["tasks"]).expect("tasks");
    let lambada: Vec<_> = tasks.into_iter().filter(|t| t.name == "lambada_synth").collect();
    let tiers = quamba::bench_support::tier_order(&rt);
    let cols = [
        ("quamba_p99", "p=99"),
        ("quamba_p99_9", "99.9"),
        ("quamba_p99_99", "99.99"),
        ("quamba", "99.999"),
    ];
    let max_ex = iters(60);
    let mut header = vec!["size".to_string()];
    header.extend(cols.iter().map(|(_, l)| l.to_string()));
    let hdr: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
    let mut t = Table::new("Table 6 analog — percentile sweep, LAMBADA-synth accuracy", &hdr);
    for tier in &tiers {
        let mut row = vec![tier.clone()];
        for (m, _) in cols {
            match run_tasks(&mut rt, tier, m, &lambada, max_ex) {
                Ok(res) => row.push(pct(res[0].1)),
                Err(_) => row.push("-".into()),
            }
        }
        t.row(row);
    }
    t.print();
}
