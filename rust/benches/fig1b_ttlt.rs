//! Paper Figure 1(b): time-to-last-token (prefill L/2 + generate L/2)
//! as a function of sequence length, Mamba-FP vs Quamba vs the
//! Pythia-like Transformer. The SSM advantage widens with length (no
//! KV cache, constant-size state updates).

use quamba::bench_support::{iters, ms, open_runtime_or_skip, Table};
use quamba::tensor::{DType, Tensor};

fn main() {
    let Some(mut rt) = open_runtime_or_skip("fig1b_ttlt") else { return };
    let tier = "m2p8";
    let ttier = "p2p8";
    let Some(tinfo) = rt.manifest().tiers.get(tier).cloned() else {
        println!("[skip] {tier} missing");
        return;
    };
    let seqs: Vec<usize> = {
        let mut s: Vec<usize> = rt
            .manifest()
            .graphs
            .values()
            .filter(|g| g.tier == tier && g.kind == "prefill" && g.batch == 1)
            .map(|g| g.seq)
            .collect();
        s.sort_unstable();
        s.dedup();
        s
    };
    let mut header = vec!["system".to_string()];
    header.extend(seqs.iter().map(|s| format!("L={} (pre {} + gen {})", 2 * s, s, s)));
    let hdr: Vec<&str> = header.iter().map(|x| x.as_str()).collect();
    let mut t = Table::new("Figure 1(b) analog — TTLT (ms) vs sequence length", &hdr);

    for method in ["fp16", "quamba"] {
        let mut row = vec![format!("mamba/{method}")];
        for &seq in &seqs {
            row.push(mamba_ttlt(&mut rt, tier, &tinfo, method, seq).map(ms).unwrap_or("-".into()));
        }
        t.row(row);
    }
    if let Some(pt) = rt.manifest().transformer_tiers.get(ttier).cloned() {
        let mut row = vec![format!("pythia/fp16 (KV cache)")];
        for &seq in &seqs {
            row.push(pythia_ttlt(&mut rt, ttier, &pt, seq).map(ms).unwrap_or("-".into()));
        }
        t.row(row);
    }
    t.print();
    println!("\nShape check vs paper: SSM TTLT grows ~linearly; transformer decode cost\n\
              grows with live context, widening the gap at long L.");
}

fn mamba_ttlt(
    rt: &mut quamba::runtime::Runtime,
    tier: &str,
    tinfo: &quamba::config::TierInfo,
    method: &str,
    seq: usize,
) -> Option<f64> {
    let pf = rt.manifest().find_graph(tier, method, "prefill", 1, Some(seq))?;
    if pf.seq != seq {
        return None;
    }
    let pf = pf.name.clone();
    let dec = rt.manifest().find_graph(tier, method, "decode", 1, None)?.name.clone();
    rt.load(&pf).ok()?;
    rt.load(&dec).ok()?;
    let reps = iters(3);
    let mut total = 0.0;
    for _ in 0..reps {
        let t0 = std::time::Instant::now();
        let toks: Vec<i32> = (0..seq as i32).map(|i| (i % 200) + 4).collect();
        let tok = Tensor::from_i32(&[1, seq], &toks);
        let conv = Tensor::zeros(DType::F32, &[tinfo.n_layer, 1, tinfo.d_conv - 1, tinfo.d_inner]);
        let ssm = Tensor::zeros(DType::F32, &[tinfo.n_layer, 1, tinfo.d_inner, tinfo.d_state]);
        let out = rt.execute(&pf, &[tok, conv, ssm]).ok()?;
        let (mut conv, mut ssm) = (out[1].clone(), out[2].clone());
        // generate `seq` tokens
        for i in 0..seq {
            let tok = Tensor::from_i32(&[1, 1], &[((i % 200) + 4) as i32]);
            let out = rt.execute(&dec, &[tok, conv, ssm]).ok()?;
            conv = out[1].clone();
            ssm = out[2].clone();
        }
        total += t0.elapsed().as_secs_f64() * 1e3;
    }
    Some(total / reps as f64)
}

fn pythia_ttlt(
    rt: &mut quamba::runtime::Runtime,
    tier: &str,
    pt: &quamba::config::TransformerTierInfo,
    seq: usize,
) -> Option<f64> {
    let pf = rt.manifest().find_graph(tier, "fp16", "prefill", 1, Some(seq))?;
    if pf.seq != seq {
        return None;
    }
    let pf = pf.name.clone();
    let dec = rt.manifest().find_graph(tier, "fp16", "decode", 1, None)?.name.clone();
    rt.load(&pf).ok()?;
    rt.load(&dec).ok()?;
    let shape = [pt.n_layer, 1, pt.max_ctx, pt.n_head, pt.d_model / pt.n_head];
    let reps = iters(2);
    let mut total = 0.0;
    for _ in 0..reps {
        let t0 = std::time::Instant::now();
        let toks: Vec<i32> = (0..seq as i32).map(|i| (i % 200) + 4).collect();
        let tok = Tensor::from_i32(&[1, seq], &toks);
        let k = Tensor::zeros(DType::F32, &shape);
        let v = Tensor::zeros(DType::F32, &shape);
        let clen = Tensor::from_i32(&[], &[0]);
        let out = rt.execute(&pf, &[tok, k, v, clen]).ok()?;
        let (mut k, mut v) = (out[1].clone(), out[2].clone());
        for i in 0..seq {
            let pos = (seq + i).min(pt.max_ctx - 1);
            let tok = Tensor::from_i32(&[1, 1], &[((i % 200) + 4) as i32]);
            let clen = Tensor::from_i32(&[], &[pos as i32]);
            let out = rt.execute(&dec, &[tok, k, v, clen]).ok()?;
            k = out[1].clone();
            v = out[2].clone();
        }
        total += t0.elapsed().as_secs_f64() * 1e3;
    }
    Some(total / reps as f64)
}
