//! Paper Table 1: latency profile — model size, decode TPOT (L=1) and
//! prefill TTFT for L ∈ {512, 1024, 2048}, per method, on the m2p8
//! tier. The paper's two testbeds (A5000, Orin Nano) become one CPU
//! PJRT backend; the *shape* (who is faster, how it scales with L, the
//! ~2× size reduction) is the reproduced quantity.

use quamba::bench_support::{bench_ms, have_graph, iters, ms, open_runtime_or_skip, Table};
use quamba::tensor::{DType, Tensor};

fn main() {
    let Some(mut rt) = open_runtime_or_skip("table1_latency") else { return };
    let tier = std::env::var("QUAMBA_TIER").unwrap_or_else(|_| "m2p8".into());
    let methods = ["smoothquant", "quarot", "quamba", "fp16", "w8a8_static"];
    let tinfo = match rt.manifest().tiers.get(&tier) {
        Some(t) => t.clone(),
        None => {
            println!("[skip] tier {tier} not in artifacts");
            return;
        }
    };
    let seqs: Vec<usize> = {
        let mut s: Vec<usize> = rt
            .manifest()
            .graphs
            .values()
            .filter(|g| g.tier == tier && g.kind == "prefill" && g.batch == 1)
            .map(|g| g.seq)
            .collect();
        s.sort_unstable();
        s.dedup();
        s
    };
    let mut header = vec!["method".to_string(), "size (MB)".to_string(), "L=1".to_string()];
    header.extend(seqs.iter().map(|s| format!("L={s}")));
    let hdr: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
    let mut table = Table::new(
        &format!("Table 1 analog — latency (ms), tier {tier} ({})", tinfo.paper_name),
        &hdr,
    );
    let mut fp_row: Vec<f64> = Vec::new();
    let mut quamba_row: Vec<f64> = Vec::new();
    for m in methods {
        if !have_graph(&rt, &tier, m, "decode") {
            continue;
        }
        let mut cells = vec![m.to_string()];
        let size = rt
            .model_bytes(&format!("{tier}_{m}"))
            .map(|b| format!("{:.2}", b as f64 / 1e6))
            .unwrap_or_else(|| "-".into());
        cells.push(size);
        let mut lat_values = Vec::new();
        // decode (TPOT, L=1)
        if let Some(g) = rt.manifest().find_graph(&tier, m, "decode", 1, None) {
            let gname = g.name.clone();
            rt.load(&gname).expect("compile");
            let tok = Tensor::from_i32(&[1, 1], &[5]);
            let conv = Tensor::zeros(DType::F32, &[tinfo.n_layer, 1, tinfo.d_conv - 1, tinfo.d_inner]);
            let ssm = Tensor::zeros(DType::F32, &[tinfo.n_layer, 1, tinfo.d_inner, tinfo.d_state]);
            let s = bench_ms(3, iters(30), || {
                rt.execute(&gname, &[tok.clone(), conv.clone(), ssm.clone()]).unwrap();
            });
            cells.push(ms(s.mean));
            lat_values.push(s.mean);
        } else {
            cells.push("-".into());
            lat_values.push(f64::NAN);
        }
        // prefill per sequence length
        for &seq in &seqs {
            if let Some(g) = rt.manifest().find_graph(&tier, m, "prefill", 1, Some(seq)) {
                if g.seq != seq {
                    cells.push("-".into());
                    lat_values.push(f64::NAN);
                    continue;
                }
                let gname = g.name.clone();
                rt.load(&gname).expect("compile");
                let toks: Vec<i32> = (0..seq as i32).map(|i| (i % 200) + 4).collect();
                let tok = Tensor::from_i32(&[1, seq], &toks);
                let conv = Tensor::zeros(DType::F32, &[tinfo.n_layer, 1, tinfo.d_conv - 1, tinfo.d_inner]);
                let ssm = Tensor::zeros(DType::F32, &[tinfo.n_layer, 1, tinfo.d_inner, tinfo.d_state]);
                let s = bench_ms(1, iters(8), || {
                    rt.execute(&gname, &[tok.clone(), conv.clone(), ssm.clone()]).unwrap();
                });
                cells.push(ms(s.mean));
                lat_values.push(s.mean);
            } else {
                cells.push("-".into());
                lat_values.push(f64::NAN);
            }
        }
        if m == "fp16" {
            fp_row = lat_values.clone();
        }
        if m == "quamba" {
            quamba_row = lat_values.clone();
        }
        table.row(cells);
    }
    table.print();
    if !fp_row.is_empty() && !quamba_row.is_empty() {
        let mut red = vec!["quamba reduction".to_string(), "-".to_string()];
        for (f, q) in fp_row.iter().zip(&quamba_row) {
            red.push(if f.is_nan() || q.is_nan() {
                "-".into()
            } else {
                format!("{:.2}x", f / q)
            });
        }
        let mut t2 = Table::new("Quamba reduction vs FP baseline", &["", "", ""]);
        t2.header = {
            let mut h = vec!["".to_string(), "size".to_string(), "L=1".to_string()];
            h.extend(seqs.iter().map(|s| format!("L={s}")));
            h
        };
        t2.row(red);
        t2.print();
    }
}
