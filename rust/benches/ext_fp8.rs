//! Extension experiment (paper §F "other alternatives"): FP8 minifloat
//! formats (E4M3 / E5M2, the Hopper-native types the paper suggests as
//! future work) as the SSM-input quantizer, compared against int8
//! minmax and int8 percentile on lambada-synth through the rust
//! reference simulator. Exponent formats keep the small-magnitude x
//! values that outlier-skewed uniform grids crush — they should land
//! between minmax-int8 and percentile-int8 (or beat both).

use quamba::bench_support::{iters, open_runtime_or_skip, pct, Table};
use quamba::coordinator::sampler::argmax;
use quamba::data::{load_tasks, Example};
use quamba::ssm::mamba::{MambaModel, MambaTier, QuantSites};

fn main() {
    let Some(rt) = open_runtime_or_skip("ext_fp8") else { return };
    let mani = rt.manifest();
    let tier_name = mani.tiers.keys().filter(|t| *t != "jamba").last().cloned().unwrap();
    let tinfo = mani.tiers[&tier_name].clone();
    let q = rt.weight_qtz(&format!("{tier_name}_fp16")).expect("weights");
    let model = MambaModel::from_qtz(
        MambaTier {
            name: tinfo.name.clone(),
            d_model: tinfo.d_model,
            n_layer: tinfo.n_layer,
            d_state: tinfo.d_state,
            d_conv: tinfo.d_conv,
            d_inner: tinfo.d_inner,
            dt_rank: tinfo.dt_rank,
            vocab: tinfo.vocab,
        },
        &q,
    )
    .expect("model");
    let tasks = load_tasks(&mani.data["tasks"]).expect("tasks");
    let lambada = tasks.iter().find(|t| t.name == "lambada_synth").unwrap();
    let examples: Vec<(&Vec<u16>, u16)> = lambada
        .examples
        .iter()
        .take(iters(40))
        .filter_map(|e| match e {
            Example::ExactLast { prompt, target } => Some((prompt, target[0])),
            _ => None,
        })
        .collect();
    let acc = |sites: &QuantSites| -> f64 {
        let mut hit = 0;
        for (prompt, target) in &examples {
            let logits = model.forward(prompt, sites, None);
            let v = tinfo.vocab;
            if argmax(&logits[(prompt.len() - 1) * v..prompt.len() * v]) == *target as usize {
                hit += 1;
            }
        }
        hit as f64 / examples.len() as f64
    };
    let mut t = Table::new(
        &format!("Extension — FP8 SSM-input formats, tier {tier_name} (paper §F)"),
        &["x-site format", "lambada acc"],
    );
    t.row(vec!["fp32 (none)".into(), pct(acc(&QuantSites::none()))]);
    let mk = |f: &dyn Fn(&mut QuantSites)| {
        let mut s = QuantSites::none();
        s.x_ssm = true;
        f(&mut s);
        s
    };
    t.row(vec!["int8 minmax".into(), pct(acc(&mk(&|_| ())))]);
    t.row(vec![
        "int8 percentile 99.9".into(),
        pct(acc(&mk(&|s| s.x_percentile = 99.9))),
    ]);
    t.row(vec!["FP8 E4M3".into(), pct(acc(&mk(&|s| s.x_fp8 = Some((4, 3)))))]);
    t.row(vec!["FP8 E5M2".into(), pct(acc(&mk(&|s| s.x_fp8 = Some((5, 2)))))]);
    t.print();
    println!("\nConjecture check (paper §F): exponent formats handle the skewed x\n\
              distribution without clipping tuning.");
}
