//! Paper Table 4: quantizing the Jamba-like hybrid — which combination
//! of per-block-type quantizers (attention / Mamba / MoE) keeps the
//! model usable. Expected shape: LLM.int8 on attention+MoE is fine;
//! LLM.int8 naively on Mamba fails; Quamba-on-Mamba recovers.

use quamba::bench_support::{iters, open_runtime_or_skip, pct, Table};
use quamba::data::load_tasks;
use quamba::eval::run_tasks;

fn main() {
    let Some(mut rt) = open_runtime_or_skip("table4_jamba") else { return };
    let combos = [
        ("fp_fp_fp", "FP16 / FP16 / FP16"),
        ("int8_fp_int8", "LLM.int8 / FP16 / LLM.int8"),
        ("smq_fp_int8", "SmQ / FP16 / LLM.int8"),
        ("int8_int8_int8", "LLM.int8 / LLM.int8 / LLM.int8"),
        ("smq_quamba_int8", "SmQ / Quamba / LLM.int8"),
        ("int8_quamba_int8", "LLM.int8 / Quamba / LLM.int8"),
    ];
    let tasks = load_tasks(&rt.manifest().data["tasks"]).expect("tasks");
    let lambada: Vec<_> = tasks.into_iter().filter(|t| t.name == "lambada_synth").collect();
    if lambada.is_empty() {
        println!("[skip] lambada_synth task missing");
        return;
    }
    let max_ex = iters(60);
    let mut t = Table::new(
        "Table 4 analog — Jamba hybrid, LAMBADA-synth accuracy",
        &["self-attention / mamba / moe", "accuracy"],
    );
    for (mname, label) in combos {
        match run_tasks(&mut rt, "jamba", mname, &lambada, max_ex) {
            Ok(res) => t.row(vec![label.to_string(), pct(res[0].1)]),
            Err(_) => t.row(vec![label.to_string(), "- (artifact missing)".into()]),
        }
    }
    t.print();
    println!("\nShape check vs paper: int8/int8/int8 degrades hard; */Quamba/* recovers.");
}
