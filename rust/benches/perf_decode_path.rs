//! §Perf instrumentation: the decode hot path, before vs after.
//!
//! `Runtime::execute` (the "before": Tensor carriers — per-element
//! byte packing on both sides of every call) vs `Runtime::execute_lit`
//! (the "after": typed literals, single memcpy per operand). Also
//! reports the pure state gather/scatter cost and the sampling cost,
//! so EXPERIMENTS.md §Perf can attribute the step budget.

use quamba::bench_support::{bench_ms, iters, ms, open_runtime_or_skip, Table};
use quamba::config::TierInfo;
use quamba::coordinator::state::SsmStatePool;
use quamba::runtime::{lit_from_f32, lit_from_i32};
use quamba::tensor::{DType, Tensor};

fn main() {
    let Some(mut rt) = open_runtime_or_skip("perf_decode_path") else { return };
    let tier = std::env::var("QUAMBA_TIER").unwrap_or_else(|_| "m2p8".into());
    let Some(tinfo): Option<TierInfo> = rt.manifest().tiers.get(&tier).cloned() else {
        println!("[skip] tier {tier} missing");
        return;
    };
    let method = "quamba";
    let mut t = Table::new(
        &format!("§Perf — decode step paths, tier {tier}/{method} (ms)"),
        &["batch", "tensor path (before)", "literal path (after)", "gather+scatter", "speedup"],
    );
    for b in [1usize, 2, 4, 8] {
        let Some(g) = rt.manifest().find_graph(&tier, method, "decode", b, None) else { continue };
        let gname = g.name.clone();
        rt.load(&gname).expect("compile");
        let (l, w1, di, n) = (tinfo.n_layer, tinfo.d_conv - 1, tinfo.d_inner, tinfo.d_state);
        let toks = vec![5i32; b];
        let conv_v = vec![0.0f32; l * b * w1 * di];
        let ssm_v = vec![0.0f32; l * b * di * n];

        // before: Tensor carriers
        let tok_t = Tensor::from_i32(&[b, 1], &toks);
        let conv_t = Tensor::zeros(DType::F32, &[l, b, w1, di]);
        let ssm_t = Tensor::zeros(DType::F32, &[l, b, di, n]);
        let before = bench_ms(3, iters(30), || {
            rt.execute(&gname, &[tok_t.clone(), conv_t.clone(), ssm_t.clone()]).unwrap();
        });

        // after: literal carriers (fresh literals per step, like the engine)
        let after = bench_ms(3, iters(30), || {
            let inputs = [
                lit_from_i32(&[b, 1], &toks).unwrap(),
                lit_from_f32(&[l, b, w1, di], &conv_v).unwrap(),
                lit_from_f32(&[l, b, di, n], &ssm_v).unwrap(),
            ];
            rt.execute_lit(&gname, &inputs).unwrap();
        });

        // pure pool overhead at this batch
        let mut pool = SsmStatePool::new(&tinfo, b.max(1));
        let slots: Vec<usize> = (0..b).map(|_| pool.alloc().unwrap()).collect();
        let gs = bench_ms(3, iters(100), || {
            let (c, s) = pool.gather_raw(&slots, b);
            pool.scatter_raw(&slots, b, &c, &s);
        });

        t.row(vec![
            b.to_string(),
            ms(before.mean),
            ms(after.mean),
            ms(gs.mean),
            format!("{:.2}x", before.mean / after.mean),
        ]);
    }
    t.print();
    println!("\nRecorded in EXPERIMENTS.md §Perf (L3).");
}
