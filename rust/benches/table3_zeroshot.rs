//! Paper Table 3: zero-shot accuracy on the six-task suite across the
//! Mamba family × quantization methods (likelihood scoring through the
//! deployed quantized graphs — same code path as serving).

use quamba::bench_support::{iters, open_runtime_or_skip, pct, Table};
use quamba::data::load_tasks;
use quamba::eval::{average_accuracy, run_tasks};

fn main() {
    let Some(mut rt) = open_runtime_or_skip("table3_zeroshot") else { return };
    let tasks = load_tasks(&rt.manifest().data["tasks"]).expect("tasks");
    let tiers = quamba::bench_support::tier_order(&rt);
    let methods = ["fp16", "w8a8_dynamic", "w8a8_static", "smoothquant", "quarot", "quamba"];
    let max_ex = iters(40);

    for tier in &tiers {
        let mut header: Vec<String> = vec!["method".into()];
        header.extend(tasks.iter().map(|t| t.name.replace("_synth", "")));
        header.push("avg".into());
        let hdr: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
        let mut table = Table::new(&format!("Table 3 analog — zero-shot accuracy, tier {tier}"), &hdr);
        for m in methods {
            match run_tasks(&mut rt, tier, m, &tasks, max_ex) {
                Ok(res) => {
                    let mut row = vec![m.to_string()];
                    row.extend(res.iter().map(|(_, a)| pct(*a)));
                    row.push(pct(average_accuracy(&res)));
                    table.row(row);
                }
                Err(_) => {}
            }
        }
        table.print();
    }
}
