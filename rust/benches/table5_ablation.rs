//! Paper Table 5: component ablation. Average zero-shot accuracy for
//! naive W8A8, + input percentile clipping, + output Hadamard, and the
//! full Quamba recipe, across all tiers. Expected ordering:
//! W8A8 < +InPer < +OutHad < Quamba ≈ FP16.

use quamba::bench_support::{iters, open_runtime_or_skip, pct, Table};
use quamba::data::load_tasks;
use quamba::eval::{average_accuracy, run_tasks};

fn main() {
    let Some(mut rt) = open_runtime_or_skip("table5_ablation") else { return };
    let tasks = load_tasks(&rt.manifest().data["tasks"]).expect("tasks");
    let tiers = quamba::bench_support::tier_order(&rt);
    let cols = [
        ("fp16", "FP16"),
        ("w8a8_static", "W8A8"),
        ("quamba_inper", "+ In Per."),
        ("quamba_outhad", "+ Out Had."),
        ("quamba", "Quamba"),
    ];
    let max_ex = iters(40);
    let mut header = vec!["size".to_string()];
    header.extend(cols.iter().map(|(_, l)| l.to_string()));
    let hdr: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
    let mut t = Table::new("Table 5 analog — ablation, avg zero-shot accuracy", &hdr);
    for tier in &tiers {
        let mut row = vec![tier.clone()];
        for (m, _) in cols {
            match run_tasks(&mut rt, tier, m, &tasks, max_ex) {
                Ok(res) => row.push(pct(average_accuracy(&res))),
                Err(_) => row.push("-".into()),
            }
        }
        t.row(row);
    }
    t.print();
    println!("\nShape check vs paper: W8A8 < +InPer < +OutHad < Quamba.");
}
