//! Extension experiment (paper §D future work): "layers closer to the
//! model output have larger outlier values, suggesting that different
//! quantization schemes can be applied to the earlier layers."
//!
//! We probe it: quantize the SSM I/O of (a) all layers, (b) only the
//! first half, (c) only the last half — with and without the Hadamard
//! treatment — and score lambada-synth. If the paper's conjecture
//! holds, quantizing only EARLY layers costs much less than only LATE
//! layers, and the gap shrinks once the Hadamard rotation handles the
//! late-layer outliers.

use quamba::bench_support::{iters, open_runtime_or_skip, pct, Table};
use quamba::coordinator::sampler::argmax;
use quamba::data::{load_tasks, Example};
use quamba::ssm::mamba::{MambaModel, MambaTier, QuantSites};

fn main() {
    let Some(rt) = open_runtime_or_skip("ext_layerwise") else { return };
    let mani = rt.manifest();
    let tier_name = mani.tiers.keys().filter(|t| *t != "jamba").last().cloned().unwrap();
    let tinfo = mani.tiers[&tier_name].clone();
    let q = rt.weight_qtz(&format!("{tier_name}_fp16")).expect("weights");
    let model = MambaModel::from_qtz(
        MambaTier {
            name: tinfo.name.clone(),
            d_model: tinfo.d_model,
            n_layer: tinfo.n_layer,
            d_state: tinfo.d_state,
            d_conv: tinfo.d_conv,
            d_inner: tinfo.d_inner,
            dt_rank: tinfo.dt_rank,
            vocab: tinfo.vocab,
        },
        &q,
    )
    .expect("model");
    let tasks = load_tasks(&mani.data["tasks"]).expect("tasks");
    let lambada = tasks.iter().find(|t| t.name == "lambada_synth").unwrap();
    let examples: Vec<(&Vec<u16>, u16)> = lambada
        .examples
        .iter()
        .take(iters(30))
        .filter_map(|e| match e {
            Example::ExactLast { prompt, target } => Some((prompt, target[0])),
            _ => None,
        })
        .collect();
    let acc = |sites: &QuantSites| -> f64 {
        let mut hit = 0;
        for (prompt, target) in &examples {
            let logits = model.forward(prompt, sites, None);
            let v = tinfo.vocab;
            if argmax(&logits[(prompt.len() - 1) * v..prompt.len() * v]) == *target as usize {
                hit += 1;
            }
        }
        hit as f64 / examples.len() as f64
    };
    let l = tinfo.n_layer;
    let early: Vec<bool> = (0..l).map(|i| i < l / 2).collect();
    let late: Vec<bool> = (0..l).map(|i| i >= l / 2).collect();
    let base = |mask: Option<Vec<bool>>, had: bool| QuantSites {
        bits: 8,
        x_ssm: true,
        gated: true,
        x_percentile: 100.0,
        y_hadamard: had,
        layer_mask: mask,
        ..Default::default()
    };
    let mut t = Table::new(
        &format!("Extension — layer-selective SSM I/O quantization, tier {tier_name}"),
        &["configuration", "naive", "+ Hadamard on y"],
    );
    t.row(vec!["fp32 (none)".into(), pct(acc(&QuantSites::none())), "-".into()]);
    for (label, mask) in [
        ("all layers", None),
        ("early half only", Some(early)),
        ("late half only", Some(late)),
    ] {
        t.row(vec![
            label.to_string(),
            pct(acc(&base(mask.clone(), false))),
            pct(acc(&base(mask, true))),
        ]);
    }
    t.print();
    println!("\nConjecture check (paper §D): late-layer quantization should cost more\n\
              than early-layer (bigger outliers), and Hadamard should close it.");
}
