//! Paper Table 9: alternative 8-bit quantizers for the SSM input x
//! (everything else per the Quamba recipe): dynamic, asymmetric
//! percentile, log2, and the shipped symmetric percentile. Scored on
//! lambada-synth across tiers.

use quamba::bench_support::{iters, open_runtime_or_skip, pct, Table};
use quamba::data::load_tasks;
use quamba::eval::run_tasks;

fn main() {
    let Some(mut rt) = open_runtime_or_skip("table9_input_quant") else { return };
    let tasks = load_tasks(&rt.manifest().data["tasks"]).expect("tasks");
    let lambada: Vec<_> = tasks.into_iter().filter(|t| t.name == "lambada_synth").collect();
    let tiers = quamba::bench_support::tier_order(&rt);
    let cols = [
        ("fp16", "FP16"),
        ("t9_dyn", "MinMax Sym. (dynamic)"),
        ("quamba_outhad", "MinMax Sym. (static)"),
        ("t9_log2", "MinMax Sym. Log2"),
        ("t9_asym", "MinMax Asym."),
        ("quamba", "MinMax Sym. Per. (ours)"),
    ];
    let max_ex = iters(60);
    let mut header = vec!["x-quantizer".to_string()];
    header.extend(tiers.iter().cloned());
    let hdr: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
    let mut t = Table::new("Table 9 analog — SSM-input quantizers, LAMBADA-synth accuracy", &hdr);
    for (m, label) in cols {
        let mut row = vec![label.to_string()];
        for tier in &tiers {
            match run_tasks(&mut rt, tier, m, &lambada, max_ex) {
                Ok(res) => row.push(pct(res[0].1)),
                Err(_) => row.push("-".into()),
            }
        }
        t.row(row);
    }
    t.print();
}
