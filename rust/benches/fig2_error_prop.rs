//! Paper Figure 2: quantization-error propagation. Fake-quantize one
//! tensor site at a time in the rust reference models and measure the
//! relative error at the block output — SSMs (the x tensor especially)
//! amplify the error through the recurrence; self-attention barely
//! reacts.

use quamba::attn::{AttnModel, AttnQuantSites, AttnTier};
use quamba::bench_support::{f2, open_runtime_or_skip, Table};
use quamba::data::load_stream;
use quamba::ssm::mamba::{MambaModel, MambaTier, QuantSites};

fn rel_err(a: &[f32], b: &[f32]) -> f64 {
    let num: f64 = a.iter().zip(b).map(|(x, y)| ((x - y) as f64).powi(2)).sum();
    let den: f64 = a.iter().map(|x| (*x as f64).powi(2)).sum();
    (num / den.max(1e-12)).sqrt()
}

fn main() {
    let Some(rt) = open_runtime_or_skip("fig2_error_prop") else { return };
    let mani = rt.manifest();
    let tier_name = mani.tiers.keys().find(|t| *t != "jamba").cloned().unwrap();
    let tinfo = mani.tiers[&tier_name].clone();
    let q = rt.weight_qtz(&format!("{tier_name}_fp16")).expect("weights");
    let model = MambaModel::from_qtz(
        MambaTier {
            name: tinfo.name.clone(),
            d_model: tinfo.d_model,
            n_layer: tinfo.n_layer,
            d_state: tinfo.d_state,
            d_conv: tinfo.d_conv,
            d_inner: tinfo.d_inner,
            dt_rank: tinfo.dt_rank,
            vocab: tinfo.vocab,
        },
        &q,
    )
    .expect("model");
    let stream = load_stream(&mani.data["pile_eval"]).expect("stream");
    let toks = &stream[..128.min(stream.len())];
    let clean = model.forward(toks, &QuantSites::none(), None);

    let mut t = Table::new(
        "Figure 2 analog — relative logit error when quantizing one site (Mamba)",
        &["site", "rel. error"],
    );
    let sites: Vec<(&str, Box<dyn Fn(&mut QuantSites)>)> = vec![
        ("x (SSM input)", Box::new(|s: &mut QuantSites| s.x_ssm = true)),
        ("y (SSM output)", Box::new(|s| s.y_out = true)),
        ("B", Box::new(|s| s.b = true)),
        ("C", Box::new(|s| s.c = true)),
        ("dt", Box::new(|s| s.dt = true)),
        ("conv input", Box::new(|s| s.conv_in = true)),
        ("gated (out_proj in)", Box::new(|s| s.gated = true)),
        ("gated + Hadamard", Box::new(|s| {
            s.gated = true;
            s.y_hadamard = true;
        })),
        ("x w/ percentile 99.9", Box::new(|s| {
            s.x_ssm = true;
            s.x_percentile = 99.9;
        })),
    ];
    for (label, setter) in sites {
        let mut s = QuantSites::none();
        setter(&mut s);
        let out = model.forward(toks, &s, None);
        t.row(vec![label.to_string(), f2(rel_err(&clean, &out))]);
    }
    t.print();

    // Transformer comparison (if the baseline tier was built)
    if let Some((pname, pt)) = mani.transformer_tiers.iter().next() {
        if let Ok(q) = rt.weight_qtz(&format!("{pname}_fp16")) {
            let am = AttnModel::from_qtz(
                AttnTier {
                    name: pt.name.clone(),
                    d_model: pt.d_model,
                    n_layer: pt.n_layer,
                    n_head: pt.n_head,
                    vocab: pt.vocab,
                },
                &q,
            )
            .expect("attn model");
            let clean = am.forward(toks, &AttnQuantSites::none());
            let mut t2 = Table::new(
                "Figure 2 analog — same experiment, self-attention",
                &["site", "rel. error"],
            );
            let asites: Vec<(&str, Box<dyn Fn(&mut AttnQuantSites)>)> = vec![
                ("h (attn input)", Box::new(|s: &mut AttnQuantSites| s.h_in = true)),
                ("qkv", Box::new(|s| s.qkv = true)),
                ("attn output y", Box::new(|s| s.attn_y = true)),
                ("mlp input", Box::new(|s| s.mlp_in = true)),
                ("h_d (mlp hidden)", Box::new(|s| s.h_d = true)),
            ];
            for (label, setter) in asites {
                let mut s = AttnQuantSites::none();
                setter(&mut s);
                let out = am.forward(toks, &s);
                t2.row(vec![label.to_string(), f2(rel_err(&clean, &out))]);
            }
            t2.print();
        }
    }
    println!("\nShape check vs paper: SSM x/y sites dominate; attention sites are flat;\n\
              percentile clipping and the Hadamard rotation shrink the big two.");
}
