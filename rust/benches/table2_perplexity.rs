//! Paper Table 2: perplexity of every quantization method across the
//! Mamba tier family, on the two held-out synthetic corpora
//! (wiki-synth ↔ WikiText2, pile-synth ↔ Pile). Expected shape: naive
//! static collapses, dynamic degrades, SmQ-SSM partially recovers,
//! QuaRot-SSM ≈ Quamba ≈ FP.

use quamba::bench_support::{f2, iters, open_runtime_or_skip, Table};
use quamba::data::load_stream;
use quamba::eval::perplexity;

fn main() {
    let Some(mut rt) = open_runtime_or_skip("table2_perplexity") else { return };
    let wiki = load_stream(&rt.manifest().data["wiki_eval"]).expect("wiki stream");
    let pile = load_stream(&rt.manifest().data["pile_eval"]).expect("pile stream");
    let tiers = quamba::bench_support::tier_order(&rt);
    let methods = ["fp16", "w8a8_dynamic", "w8a8_static", "smoothquant", "quarot", "quamba"];
    let windows = iters(12);

    for stream_name in ["wiki-synth", "pile-synth"] {
        let stream = if stream_name == "wiki-synth" { &wiki } else { &pile };
        let mut header = vec!["method".to_string()];
        header.extend(tiers.iter().cloned());
        let hdr: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
        let mut t = Table::new(
            &format!("Table 2 analog — {stream_name} perplexity (lower is better)"),
            &hdr,
        );
        for m in methods {
            let mut row = vec![m.to_string()];
            for tier in &tiers {
                match perplexity(&mut rt, tier, m, stream, windows) {
                    Ok(r) => row.push(f2(r.ppl)),
                    Err(_) => row.push("-".into()),
                }
            }
            t.row(row);
        }
        t.print();
    }
    println!("\nShape checks vs paper: static ≫ dynamic > smq > (quarot ≈ quamba ≈ fp16)");
}
