//! Paper Figure 1(a): the accuracy-vs-latency Pareto frontier. For
//! each method on the largest tier, measure decode TPOT and average
//! zero-shot accuracy; Quamba should sit on the frontier (QuaRot-SSM
//! matches accuracy but pays the extra-transform latency).

use quamba::bench_support::{bench_ms, iters, ms, open_runtime_or_skip, pct, Table};
use quamba::data::load_tasks;
use quamba::eval::{average_accuracy, run_tasks};
use quamba::tensor::{DType, Tensor};

fn main() {
    let Some(mut rt) = open_runtime_or_skip("fig1a_pareto") else { return };
    let tier = std::env::var("QUAMBA_TIER").unwrap_or_else(|_| "m2p8".into());
    let Some(tinfo) = rt.manifest().tiers.get(&tier).cloned() else {
        println!("[skip] tier {tier} missing");
        return;
    };
    let tasks = load_tasks(&rt.manifest().data["tasks"]).expect("tasks");
    let methods = ["fp16", "w8a8_static", "w8a8_dynamic", "smoothquant", "quarot", "quamba"];
    let mut t = Table::new(
        &format!("Figure 1(a) analog — accuracy vs TPOT, tier {tier}"),
        &["method", "TPOT (ms)", "avg acc", "size (MB)"],
    );
    let mut points: Vec<(String, f64, f64)> = Vec::new();
    for m in methods {
        let Some(g) = rt.manifest().find_graph(&tier, m, "decode", 1, None) else { continue };
        let gname = g.name.clone();
        rt.load(&gname).expect("compile");
        let tok = Tensor::from_i32(&[1, 1], &[5]);
        let conv = Tensor::zeros(DType::F32, &[tinfo.n_layer, 1, tinfo.d_conv - 1, tinfo.d_inner]);
        let ssm = Tensor::zeros(DType::F32, &[tinfo.n_layer, 1, tinfo.d_inner, tinfo.d_state]);
        let lat = bench_ms(3, iters(30), || {
            rt.execute(&gname, &[tok.clone(), conv.clone(), ssm.clone()]).unwrap();
        });
        let acc = run_tasks(&mut rt, &tier, m, &tasks, iters(30))
            .map(|r| average_accuracy(&r))
            .unwrap_or(f64::NAN);
        let size = rt
            .model_bytes(&format!("{tier}_{m}"))
            .map(|b| format!("{:.2}", b as f64 / 1e6))
            .unwrap_or_else(|| "-".into());
        t.row(vec![m.to_string(), ms(lat.mean), pct(acc), size]);
        points.push((m.to_string(), lat.mean, acc));
    }
    t.print();
    // report who is Pareto-optimal (no point with both lower latency
    // and higher accuracy)
    let frontier: Vec<&str> = points
        .iter()
        .filter(|(_, l, a)| {
            !points.iter().any(|(_, l2, a2)| l2 < l && a2 > a)
        })
        .map(|(m, _, _)| m.as_str())
        .collect();
    println!("\nPareto frontier: {frontier:?}");
}
