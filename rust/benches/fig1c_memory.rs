//! Paper Figure 1(c): per-request memory vs context length. The SSM
//! state is constant; the transformer KV cache grows linearly. Both
//! pools are the coordinator's real state managers, so these are the
//! bytes the serving engine actually allocates, plus the resident
//! model bytes per precision.

use quamba::bench_support::{open_runtime_or_skip, Table};
use quamba::coordinator::state::{KvCachePool, SsmStatePool};

fn main() {
    let Some(rt) = open_runtime_or_skip("fig1c_memory") else { return };
    let mani = rt.manifest();
    let ctxs = [128usize, 256, 512, 1024, 2048];

    let mut header = vec!["system (per-request state)".to_string()];
    header.extend(ctxs.iter().map(|c| format!("ctx={c}")));
    let hdr: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
    let mut t = Table::new("Figure 1(c) analog — per-request state bytes vs context (KB)", &hdr);

    for tier in mani.tiers.values().filter(|t| t.name != "jamba") {
        let pool = SsmStatePool::new(tier, 1);
        let kb = pool.bytes_per_request() as f64 / 1024.0;
        let mut row = vec![format!("mamba {} (constant)", tier.name)];
        for _ in ctxs {
            row.push(format!("{kb:.1}"));
        }
        t.row(row);
    }
    for pt in mani.transformer_tiers.values() {
        let pool = KvCachePool::new(pt, 1, usize::MAX);
        let mut row = vec![format!("pythia {} (KV cache)", pt.name)];
        for &c in &ctxs {
            row.push(format!("{:.1}", pool.bytes_per_request(c) as f64 / 1024.0));
        }
        t.row(row);
    }
    t.print();

    // resident model bytes per precision (the other Figure 1(c) axis)
    let mut t2 = Table::new("Resident model bytes (MB)", &["bundle", "fp32", "quamba W8A8", "ratio"]);
    for tier in mani.tiers.keys().filter(|t| *t != "jamba") {
        let fp = mani.weights.get(&format!("{tier}_fp16")).map(|w| w.bytes);
        let q = mani.weights.get(&format!("{tier}_quamba")).map(|w| w.bytes);
        if let (Some(fp), Some(q)) = (fp, q) {
            t2.row(vec![
                tier.clone(),
                format!("{:.2}", fp as f64 / 1e6),
                format!("{:.2}", q as f64 / 1e6),
                format!("{:.2}x", fp as f64 / q as f64),
            ]);
        }
    }
    t2.print();
    println!("\nShape check vs paper: SSM rows flat in ctx; KV rows linear; W8A8 ≈ half(+) size.");
}
