//! Failure injection: corrupted artifacts must produce errors, never
//! UB/garbage. (These run without a real artifact tree.)

use std::fs;
use std::path::PathBuf;

use quamba::config::Manifest;
use quamba::runtime::Runtime;
use quamba::tensor::qtz;

fn scratch(name: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("quamba_fail_{name}"));
    let _ = fs::remove_dir_all(&d);
    fs::create_dir_all(d.join("graphs")).unwrap();
    fs::create_dir_all(d.join("weights")).unwrap();
    d
}

fn write_manifest(dir: &PathBuf, body: &str) {
    fs::write(dir.join("manifest.json"), body).unwrap();
}

const MANIFEST_ONE_GRAPH: &str = r#"{
  "vocab_size": 256, "quick": true,
  "graphs": {"g1": {"file": "graphs/g1.hlo.txt", "family": "mamba",
     "tier": "t", "method": "fp16", "kind": "decode", "batch": 1, "seq": 1,
     "weights": "wb"}},
  "weights": {"wb": {"file": "weights/wb.qtz", "params": ["w"], "bytes": 4}},
  "tiers": {"t": {"paper_name": "T", "d_model": 4, "n_layer": 1, "d_state": 2,
     "d_conv": 2, "d_inner": 8, "dt_rank": 1, "vocab": 256, "n_params": 1}},
  "data": {}
}"#;

#[test]
fn missing_manifest_is_a_clean_error() {
    let d = scratch("nomanifest");
    let err = Runtime::new(&d).err().expect("must fail");
    let msg = format!("{err:#}");
    assert!(msg.contains("manifest"), "unhelpful error: {msg}");
}

#[test]
fn truncated_manifest_is_a_clean_error() {
    let d = scratch("truncmanifest");
    write_manifest(&d, r#"{"graphs": {"x": "#);
    assert!(Manifest::load(&d).is_err());
}

#[test]
fn missing_hlo_file_is_a_clean_error() {
    let d = scratch("nohlo");
    write_manifest(&d, MANIFEST_ONE_GRAPH);
    qtz::save(
        &d.join("weights/wb.qtz"),
        &[("w".to_string(), quamba::tensor::Tensor::from_f32(&[1], &[1.0]))],
    )
    .unwrap();
    let mut rt = Runtime::new(&d).expect("runtime opens (lazy loading)");
    let err = rt.load("g1").err().expect("must fail");
    assert!(format!("{err:#}").contains("g1"));
}

#[test]
fn garbage_hlo_text_is_a_clean_error() {
    let d = scratch("badhlo");
    write_manifest(&d, MANIFEST_ONE_GRAPH);
    fs::write(d.join("graphs/g1.hlo.txt"), "this is not HLO").unwrap();
    qtz::save(
        &d.join("weights/wb.qtz"),
        &[("w".to_string(), quamba::tensor::Tensor::from_f32(&[1], &[1.0]))],
    )
    .unwrap();
    let mut rt = Runtime::new(&d).unwrap();
    assert!(rt.load("g1").is_err());
}

#[test]
fn missing_weight_tensor_is_a_clean_error() {
    let d = scratch("noweight");
    write_manifest(&d, MANIFEST_ONE_GRAPH);
    // valid-but-wrong qtz: contains `other`, not `w`
    fs::write(d.join("graphs/g1.hlo.txt"), "HloModule m\nENTRY e { ROOT c = f32[] constant(0) }")
        .unwrap();
    qtz::save(
        &d.join("weights/wb.qtz"),
        &[("other".to_string(), quamba::tensor::Tensor::from_f32(&[1], &[1.0]))],
    )
    .unwrap();
    let mut rt = Runtime::new(&d).unwrap();
    let err = rt.load("g1").err().expect("must fail");
    let msg = format!("{err:#}");
    assert!(msg.contains("missing weight"), "{msg}");
}

#[test]
fn corrupted_qtz_is_a_clean_error() {
    let d = scratch("badqtz");
    write_manifest(&d, MANIFEST_ONE_GRAPH);
    fs::write(d.join("graphs/g1.hlo.txt"), "HloModule m\nENTRY e { ROOT c = f32[] constant(0) }")
        .unwrap();
    fs::write(d.join("weights/wb.qtz"), b"QTZ1\xff\xff\xff\xff").unwrap();
    let mut rt = Runtime::new(&d).unwrap();
    assert!(rt.load("g1").is_err());
}

#[test]
fn qtz_truncated_payload_rejected() {
    // header promises more bytes than exist
    let mut bytes = Vec::new();
    bytes.extend_from_slice(b"QTZ1");
    bytes.extend_from_slice(&1u32.to_le_bytes());
    bytes.extend_from_slice(&1u16.to_le_bytes());
    bytes.push(b'w');
    bytes.push(0); // dtype f32
    bytes.push(1); // ndim 1
    bytes.extend_from_slice(&100u32.to_le_bytes()); // 100 elements...
    bytes.extend_from_slice(&[0u8; 8]); // ...but only 8 bytes
    assert!(qtz::load_bytes(&bytes).is_err());
}

#[test]
fn engine_requires_decode_graphs() {
    use quamba::coordinator::engine::{Engine, EngineConfig};
    let d = scratch("nodecode");
    write_manifest(
        &d,
        r#"{"vocab_size": 256, "quick": true, "graphs": {},
            "weights": {}, "tiers": {"t": {"paper_name": "T", "d_model": 4,
            "n_layer": 1, "d_state": 2, "d_conv": 2, "d_inner": 8,
            "dt_rank": 1, "vocab": 256, "n_params": 1}}, "data": {}}"#,
    );
    let rt = Runtime::new(&d).unwrap();
    let err = Engine::new(rt, EngineConfig::new("t", "fp16")).err().expect("must fail");
    assert!(format!("{err:#}").contains("no decode graphs"));
}

#[test]
fn engine_rejects_unknown_tier() {
    use quamba::coordinator::engine::{Engine, EngineConfig};
    let d = scratch("notier");
    write_manifest(
        &d,
        r#"{"vocab_size": 256, "quick": true, "graphs": {}, "weights": {},
            "tiers": {}, "data": {}}"#,
    );
    let rt = Runtime::new(&d).unwrap();
    assert!(Engine::new(rt, EngineConfig::new("ghost", "fp16")).is_err());
}
