//! Kernel parity property tests (tier-1):
//!
//! * blocked int8 GEMM is **bit-exact** vs the naive `matmul_i8`
//!   oracle across shapes where K and N are not multiples of the
//!   block/unroll widths — on **every** dispatch backend this machine
//!   can run (scalar always; AVX2/NEON where detected);
//! * the fused integer depthwise conv matches a dequantized f64
//!   reference within a magnitude-scaled tolerance, chunked calls
//!   compose bit-exactly with one full call, and every backend matches
//!   the scalar one bit-for-bit;
//! * threaded batched steps (fp32 and W8A8) are bit-identical to
//!   single-threaded ones, logits and state;
//! * W8A8 greedy decode produces the **same token stream** under every
//!   forced kernel backend (ISSUE 3 satellite);
//! * the W4A8 packed-nibble tier (ISSUE 8): `PackedWeightI4` roundtrip
//!   over random i4 codes (odd K, K off the group grid), per-group
//!   dequant **bit-parity** of the blocked i4 GEMM vs the retained
//!   naive oracle on every backend, and W4A8 greedy/threaded decode
//!   bit-identical across backends and thread counts.

use quamba::quant::qlinear::{
    matmul_i8, matmul_i8_blocked, matmul_i8_blocked_with, matmul_w4a8_ref, matmul_w4a8_with,
    PackedWeightI4, PackedWeightI8,
};
use quamba::quant::Kernels;
use quamba::ssm::{
    fused_conv_silu_i8, fused_conv_silu_i8_with, MambaModel, MambaState, MambaTier, QuantConfig,
    QuantizedMambaModel, StepModel, StepScratch,
};
use quamba::util::rng::Pcg32;

fn rand_i8(r: &mut Pcg32, n: usize) -> Vec<i8> {
    (0..n).map(|_| (r.below(255) as i32 - 127) as i8).collect()
}

#[test]
fn blocked_gemm_bit_exact_vs_naive_over_random_odd_shapes() {
    // ISSUE 2 acceptance: property sweep with K, N deliberately off
    // the 16-wide block / 4-wide unroll grid (plus random shapes)
    let mut r = Pcg32::new(0xB10C);
    let mut cases: Vec<(usize, usize, usize)> = vec![
        (1, 1, 1),
        (1, 3, 17),
        (2, 4, 16),
        (3, 5, 15),
        (7, 19, 31),
        (8, 16, 16),
        (5, 33, 47),
        (4, 127, 129),
        (1, 255, 13),
    ];
    for _ in 0..40 {
        cases.push((
            1 + r.below(9) as usize,
            1 + r.below(70) as usize,
            1 + r.below(70) as usize,
        ));
    }
    for (m, k, n) in cases {
        let x_q = rand_i8(&mut r, m * k);
        let w_q = rand_i8(&mut r, k * n);
        let mut want = vec![0i32; m * n];
        matmul_i8(&x_q, &w_q, m, k, n, &mut want);
        let packed = PackedWeightI8::pack(&w_q, k, n);
        let mut got = vec![7i32; m * n]; // poison: kernel must overwrite fully
        matmul_i8_blocked(&x_q, &packed, m, &mut got);
        assert_eq!(want, got, "GEMM mismatch at shape ({m},{k},{n})");
        // ISSUE 3 acceptance: every dispatch backend is bit-exact vs
        // the naive oracle on the same odd shapes
        for backend in Kernels::available() {
            got.fill(7);
            matmul_i8_blocked_with(Kernels::for_backend(backend), &x_q, &packed, m, &mut got);
            assert_eq!(
                want,
                got,
                "GEMM mismatch on backend {} at shape ({m},{k},{n})",
                backend.label()
            );
        }
    }
}

#[test]
fn fused_i8_conv_matches_dequantized_reference() {
    // the integer-accumulate conv must agree with the dequantized
    // conv (old `_conv_live_q` semantics) up to f32 rounding: the
    // tolerance is scaled to the output magnitude, orders of magnitude
    // below any indexing/windowing bug
    let mut r = Pcg32::new(0xC0DE);
    for (di, w, tl) in [(4usize, 4usize, 9usize), (3, 2, 5), (8, 4, 1), (5, 3, 12)] {
        let hw = w - 1;
        let x_q = rand_i8(&mut r, tl * di);
        let w_q = rand_i8(&mut r, w * di);
        let hist0 = rand_i8(&mut r, hw * di);
        let bias: Vec<f32> = (0..di).map(|_| r.normal() * 0.1).collect();
        let gx: Vec<f32> = (0..di).map(|_| 0.5 + r.f32()).collect();
        let s = 0.013f32;
        let mut hist = hist0.clone();
        let mut out = vec![0.0f32; tl * di];
        fused_conv_silu_i8(&x_q, &mut hist, &w_q, &bias, &gx, s, tl, di, w, &mut out);
        for ti in 0..tl {
            for ch in 0..di {
                // f64 reference over the dequantized window
                let mut acc = 0.0f64;
                for j in 0..w {
                    let src = ti as isize - hw as isize + j as isize;
                    let v = if src >= 0 {
                        x_q[src as usize * di + ch] as f64
                    } else {
                        hist0[(src + hw as isize) as usize * di + ch] as f64
                    };
                    acc += v * w_q[j * di + ch] as f64;
                }
                let pre = acc * s as f64 + bias[ch] as f64;
                let silu = pre / (1.0 + (-pre).exp());
                let want = (silu * gx[ch] as f64) as f32;
                let got = out[ti * di + ch];
                let tol = 1e-5f32 * (1.0 + want.abs());
                assert!(
                    (want - got).abs() <= tol,
                    "conv (di={di},w={w}) t={ti} ch={ch}: {want} vs {got}"
                );
            }
        }
        // window slide: history must hold the last hw input rows' codes
        for row in 0..hw {
            for ch in 0..di {
                let want = if tl + row >= hw && tl + row - hw < tl {
                    x_q[(tl + row - hw) * di + ch]
                } else {
                    hist0[(tl + row) * di + ch]
                };
                assert_eq!(hist[row * di + ch], want, "hist slide row {row} ch {ch}");
            }
        }
    }
}

#[test]
fn fused_i8_conv_chunks_compose_bit_exactly() {
    // integer accumulation makes chunked == full an exact equality,
    // which is what makes stepwise and full-sequence quantized prefill
    // bit-identical
    let mut r = Pcg32::new(0xCC);
    let (di, w, tl) = (6usize, 4usize, 11usize);
    let x_q = rand_i8(&mut r, tl * di);
    let w_q = rand_i8(&mut r, w * di);
    let bias: Vec<f32> = (0..di).map(|_| r.normal() * 0.1).collect();
    let gx = vec![1.0f32; di];
    let s = 0.02f32;
    let mut hist_full = vec![0i8; (w - 1) * di];
    let mut full = vec![0.0f32; tl * di];
    fused_conv_silu_i8(&x_q, &mut hist_full, &w_q, &bias, &gx, s, tl, di, w, &mut full);
    let mut hist_step = vec![0i8; (w - 1) * di];
    let mut got = Vec::new();
    for ti in 0..tl {
        let mut one = vec![0.0f32; di];
        fused_conv_silu_i8(
            &x_q[ti * di..(ti + 1) * di],
            &mut hist_step,
            &w_q,
            &bias,
            &gx,
            s,
            1,
            di,
            w,
            &mut one,
        );
        got.extend(one);
    }
    for (i, (a, b)) in full.iter().zip(&got).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "t={} ch={}", i / di, i % di);
    }
    assert_eq!(hist_full, hist_step, "carried windows diverged");
}

#[test]
fn fused_i8_conv_bit_identical_across_backends() {
    // the SIMD MAC reorders nothing observable: integer accumulation
    // is exact and the silu epilogue is per-element, so every backend
    // must reproduce the scalar one to the bit (outputs AND the
    // carried window codes)
    let mut r = Pcg32::new(0xD15B);
    for (di, w, tl) in [(4usize, 4usize, 9usize), (33, 3, 5), (130, 4, 3), (8, 2, 1)] {
        let hw = w - 1;
        let x_q = rand_i8(&mut r, tl * di);
        let w_q = rand_i8(&mut r, w * di);
        let hist0 = rand_i8(&mut r, hw * di);
        let bias: Vec<f32> = (0..di).map(|_| r.normal() * 0.1).collect();
        let gx: Vec<f32> = (0..di).map(|_| 0.5 + r.f32()).collect();
        let s = 0.017f32;
        let run = |kers: Kernels| {
            let mut hist = hist0.clone();
            let mut out = vec![0.0f32; tl * di];
            fused_conv_silu_i8_with(
                kers, &x_q, &mut hist, &w_q, &bias, &gx, s, tl, di, w, &mut out,
            );
            (hist, out)
        };
        let (h0, o0) = run(Kernels::scalar());
        for backend in Kernels::available() {
            let (h1, o1) = run(Kernels::for_backend(backend));
            assert_eq!(h0, h1, "conv window codes diverged on {}", backend.label());
            for (i, (a, b)) in o0.iter().zip(&o1).enumerate() {
                assert_eq!(
                    a.to_bits(),
                    b.to_bits(),
                    "conv output diverged on {} (di={di},w={w}) at {i}",
                    backend.label()
                );
            }
        }
    }
}

fn parity_tier() -> MambaTier {
    MambaTier {
        name: "parity".into(),
        d_model: 16,
        n_layer: 2,
        d_state: 4,
        d_conv: 4,
        d_inner: 32,
        dt_rank: 4,
        vocab: 32,
    }
}

/// Run `steps` batched steps from a zero state and return (all logits
/// bits, final conv/conv_q/ssm) for exact comparison.
#[allow(clippy::type_complexity)]
fn run_steps(
    model: &dyn StepModel,
    b: usize,
    threads: usize,
    steps: usize,
) -> (Vec<u32>, Vec<f32>, Vec<i8>, Vec<u32>) {
    let tier = model.tier().clone();
    let mut st = MambaState::new_for(&tier, b, model.quantized_conv_state());
    let mut scratch = StepScratch::new(threads);
    let mut logits = Vec::new();
    let mut all_bits = Vec::new();
    for si in 0..steps {
        let toks: Vec<u16> =
            (0..b).map(|bi| ((si * 5 + bi * 3) % tier.vocab) as u16).collect();
        model.step_into(&toks, &mut st, &mut scratch, &mut logits);
        all_bits.extend(logits.iter().map(|v| v.to_bits()));
    }
    let ssm_bits = st.ssm.iter().map(|v| v.to_bits()).collect();
    (all_bits, st.conv, st.conv_q, ssm_bits)
}

#[test]
fn threaded_step_bit_identical_to_sequential() {
    // ISSUE 2 acceptance: scratch.threads > 1 changes nothing but
    // wall-clock — logits and state match bit-for-bit (fp32 and W8A8)
    let tier = parity_tier();
    let fp = MambaModel::synthetic(tier.clone(), 7);
    let calib: Vec<u16> = (0..96u16).map(|i| i % tier.vocab as u16).collect();
    let qm = QuantizedMambaModel::from_model(&fp, &calib, &QuantConfig::default());
    let models: [(&str, &dyn StepModel); 2] = [("fp32", &fp), ("w8a8", &qm)];
    for (name, m) in models {
        let seq = run_steps(m, 5, 1, 4);
        for threads in [2usize, 3, 8] {
            let par = run_steps(m, 5, threads, 4);
            assert_eq!(seq.0, par.0, "{name}: logits diverged at threads={threads}");
            assert_eq!(seq.1, par.1, "{name}: f32 conv state diverged at threads={threads}");
            assert_eq!(seq.2, par.2, "{name}: conv codes diverged at threads={threads}");
            assert_eq!(seq.3, par.3, "{name}: ssm state diverged at threads={threads}");
        }
    }
}

/// Greedy W8A8 decode through `prefill_into`/`step_into` with a forced
/// kernel backend; returns the token stream plus every logit's bits.
fn greedy_with_kernels(
    model: &QuantizedMambaModel,
    prompt: &[u16],
    steps: usize,
    kers: Kernels,
) -> (Vec<u16>, Vec<u32>) {
    let tier = model.tier().clone();
    let v = tier.vocab;
    let mut st = MambaState::new_quantized(&tier, 1);
    let mut scratch = StepScratch::with_kernels(1, kers);
    let mut logits = Vec::new();
    model.prefill_into(prompt, &mut st, &mut scratch, &mut logits);
    let mut bits: Vec<u32> = logits.iter().map(|x| x.to_bits()).collect();
    let argmax = |row: &[f32]| -> u16 {
        let mut best = 0usize;
        for (i, x) in row.iter().enumerate() {
            if *x > row[best] {
                best = i;
            }
        }
        best as u16
    };
    let mut toks = vec![argmax(&logits[(prompt.len() - 1) * v..prompt.len() * v])];
    for _ in 1..steps {
        let t = [*toks.last().unwrap()];
        model.step_into(&t, &mut st, &mut scratch, &mut logits);
        bits.extend(logits.iter().map(|x| x.to_bits()));
        toks.push(argmax(&logits[..v]));
    }
    (toks, bits)
}

fn rand_i4(r: &mut Pcg32, n: usize) -> Vec<i8> {
    (0..n).map(|_| (r.below(16) as i32 - 8) as i8).collect()
}

#[test]
fn packed_i4_roundtrip_over_random_codes_and_odd_shapes() {
    // ISSUE 8 satellite: pack → unpack is the identity for every i4
    // code, including odd K (pad nibble in the last byte row) and K
    // not a multiple of the group size; plus fixed shapes hitting the
    // block-tail and single-element corners
    let mut r = Pcg32::new(0x1D40);
    let mut cases: Vec<(usize, usize)> =
        vec![(1, 1), (5, 3), (7, 16), (127, 17), (129, 33), (128, 16), (2, 1)];
    for _ in 0..30 {
        cases.push((1 + r.below(200) as usize, 1 + r.below(40) as usize));
    }
    for (k, n) in cases {
        let w_q4 = rand_i4(&mut r, k * n);
        let packed = PackedWeightI4::pack(&w_q4, k, n);
        for p in 0..k {
            for j in 0..n {
                assert_eq!(
                    packed.code(p, j),
                    w_q4[p * n + j],
                    "roundtrip mismatch at ({p},{j}) of shape ({k},{n})"
                );
            }
        }
    }
}

#[test]
fn w4a8_gemm_bit_exact_vs_naive_oracle_every_backend() {
    // ISSUE 8 satellite: per-group dequant bit-parity of the blocked
    // i4 GEMM vs the naive decode-then-multiply oracle, swept across
    // every available backend with K odd / off the group grid and N
    // off the block grid
    let mut r = Pcg32::new(0x4A8B);
    let mut cases: Vec<(usize, usize, usize, usize)> = vec![
        (1, 1, 1, 2),
        (1, 3, 17, 2),
        (3, 5, 15, 4),
        (7, 19, 31, 8),
        (8, 16, 16, 16),
        (5, 129, 47, 64),   // last group odd
        (4, 130, 20, 64),   // last group length 2
        (2, 127, 13, 128),  // single odd short group
        (6, 256, 24, 128),  // exact group multiples
    ];
    for _ in 0..30 {
        cases.push((
            1 + r.below(9) as usize,
            1 + r.below(150) as usize,
            1 + r.below(40) as usize,
            2 * (1 + r.below(32) as usize), // even group in [2, 64]
        ));
    }
    for (m, k, n, group_k) in cases {
        let x_q = rand_i8(&mut r, m * k);
        let w_q4 = rand_i4(&mut r, k * n);
        let n_groups = k.div_ceil(group_k);
        let scales: Vec<f32> =
            (0..n_groups * n).map(|_| 0.002 + 0.001 * r.below(64) as f32).collect();
        let s_x = 0.017f32;
        let mut want = vec![0.0f32; m * n];
        matmul_w4a8_ref(&x_q, &w_q4, &scales, group_k, s_x, m, k, n, &mut want);
        let packed = PackedWeightI4::pack(&w_q4, k, n);
        for backend in Kernels::available() {
            let mut got = vec![7.0f32; m * n]; // poison
            matmul_w4a8_with(
                Kernels::for_backend(backend),
                &x_q,
                &packed,
                &scales,
                group_k,
                s_x,
                m,
                &mut got,
            );
            for (i, (a, b)) in want.iter().zip(&got).enumerate() {
                assert_eq!(
                    a.to_bits(),
                    b.to_bits(),
                    "W4A8 mismatch on {} at shape ({m},{k},{n}) g{group_k} elem {i}: {a} vs {b}",
                    backend.label()
                );
            }
        }
    }
}

#[test]
fn w4a8_threaded_step_bit_identical_to_sequential() {
    // the W4A8 twin of the threads sweep: scratch.threads > 1 moves
    // wall-clock only, at 4-bit weights too
    let tier = parity_tier();
    let fp = MambaModel::synthetic(tier.clone(), 7);
    let calib: Vec<u16> = (0..96u16).map(|i| i % tier.vocab as u16).collect();
    let cfg = QuantConfig { weight_bits: 4, ..QuantConfig::default() };
    let qm = QuantizedMambaModel::from_model(&fp, &calib, &cfg);
    let seq = run_steps(&qm, 5, 1, 4);
    for threads in [2usize, 3, 8] {
        let par = run_steps(&qm, 5, threads, 4);
        assert_eq!(seq.0, par.0, "w4a8: logits diverged at threads={threads}");
        assert_eq!(seq.2, par.2, "w4a8: conv codes diverged at threads={threads}");
        assert_eq!(seq.3, par.3, "w4a8: ssm state diverged at threads={threads}");
    }
}

#[test]
fn w4a8_greedy_tokens_bit_identical_across_kernel_backends() {
    // the W4A8 twin of the backend-parity run: the nibble GEMM's exact
    // per-group accumulation + fixed f32 epilogue order means a
    // backend switch can never move a 4-bit-weight model either
    let tier = parity_tier();
    let model = MambaModel::synthetic(tier.clone(), 7);
    let mut r = Pcg32::new(7 ^ 0x1234);
    let calib: Vec<u16> = (0..256).map(|_| r.below(tier.vocab as u32) as u16).collect();
    let cfg = QuantConfig { weight_bits: 4, ..QuantConfig::default() };
    let qm = QuantizedMambaModel::from_model(&model, &calib, &cfg);
    let prompt: Vec<u16> = (0..8).map(|_| r.below(tier.vocab as u32) as u16).collect();
    let (toks0, bits0) = greedy_with_kernels(&qm, &prompt, 48, Kernels::scalar());
    for backend in Kernels::available() {
        let (toks, bits) = greedy_with_kernels(&qm, &prompt, 48, Kernels::for_backend(backend));
        assert_eq!(toks0, toks, "W4A8 greedy tokens diverged on backend {}", backend.label());
        assert_eq!(bits0, bits, "W4A8 logit bits diverged on backend {}", backend.label());
    }
}

#[test]
fn w8a8_greedy_tokens_bit_identical_across_kernel_backends() {
    // ISSUE 3 satellite acceptance: the W8A8 greedy-token parity run,
    // repeated once per dispatch backend (forced scalar vs every
    // detected SIMD path), must produce identical tokens AND identical
    // logit bits — proving a backend switch can never move the model
    let tier = parity_tier();
    let model = MambaModel::synthetic(tier.clone(), 7);
    let mut r = Pcg32::new(7 ^ 0x1234);
    let calib: Vec<u16> = (0..256).map(|_| r.below(tier.vocab as u32) as u16).collect();
    let qm = QuantizedMambaModel::from_model(&model, &calib, &QuantConfig::default());
    let prompt: Vec<u16> = (0..8).map(|_| r.below(tier.vocab as u32) as u16).collect();
    let (toks0, bits0) = greedy_with_kernels(&qm, &prompt, 48, Kernels::scalar());
    for backend in Kernels::available() {
        let (toks, bits) = greedy_with_kernels(&qm, &prompt, 48, Kernels::for_backend(backend));
        assert_eq!(toks0, toks, "greedy tokens diverged on backend {}", backend.label());
        assert_eq!(bits0, bits, "logit bits diverged on backend {}", backend.label());
    }
}
