//! ISSUE 5 acceptance: the unified chunked-prefill scheduler may move
//! *latency*, never *tokens*.
//!
//! Two layers of evidence:
//!
//! * **model level** — `StepModel::prefill_batch_into` over a ragged
//!   (B, T) batch is bit-identical, lane by lane, to running each
//!   lane's chunk through the per-request `prefill_resume_into`
//!   oracle (valid logits rows AND final state), for the fp32
//!   reference and the W8A8 + W4A8 models under every available
//!   kernel backend — including lanes mid-prompt (carried conv window / scan
//!   state) and maximally ragged pads;
//! * **engine level** — the served token streams are identical across
//!   `prefill_chunk ∈ {1, 3, 16, ∞}`, `threads ∈ {1, 3}`, cache
//!   on/off, forced scalar + every detected SIMD backend, and tight
//!   `max_tokens_per_tick` budgets, for greedy AND temperature
//!   sampling (per-request RNG streams make scheduling order
//!   unobservable).

use quamba::coordinator::{NativeEngine, NativeEngineConfig, Request, SamplingParams};
use quamba::quant::{KernelBackend, Kernels};
use quamba::ssm::{
    MambaModel, MambaState, MambaTier, QuantConfig, QuantizedMambaModel, StepModel, StepScratch,
};
use quamba::util::rng::Pcg32;

fn tier() -> MambaTier {
    MambaTier {
        name: "chunk".into(),
        d_model: 16,
        n_layer: 2,
        d_state: 4,
        d_conv: 4,
        d_inner: 32,
        dt_rank: 4,
        vocab: 32,
    }
}

fn fp32_model(seed: u64) -> MambaModel {
    MambaModel::synthetic(tier(), seed)
}

fn w8a8_model(seed: u64) -> QuantizedMambaModel {
    let t = tier();
    let model = MambaModel::synthetic(t.clone(), seed);
    let mut r = Pcg32::new(seed ^ 0xC0DE);
    let calib: Vec<u16> = (0..256).map(|_| r.below(t.vocab as u32) as u16).collect();
    QuantizedMambaModel::from_model(&model, &calib, &QuantConfig::default())
}

/// Same weights/calibration as [`w8a8_model`], served at 4-bit
/// packed-nibble weights (ISSUE 8 sweep twin).
fn w4a8_model(seed: u64) -> QuantizedMambaModel {
    let t = tier();
    let model = MambaModel::synthetic(t.clone(), seed);
    let mut r = Pcg32::new(seed ^ 0xC0DE);
    let calib: Vec<u16> = (0..256).map(|_| r.below(t.vocab as u32) as u16).collect();
    let cfg = QuantConfig { weight_bits: 4, ..QuantConfig::default() };
    QuantizedMambaModel::from_model(&model, &calib, &cfg)
}

fn assert_bits_eq(a: &[f32], b: &[f32], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length mismatch");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{what}[{i}]: {x} vs {y}");
    }
}

/// Drive `lanes` independent prompts through `prefill_batch_into` in
/// ragged rounds (each lane advances by its own per-round chunk
/// length) and bit-compare every lane against the per-request
/// `prefill_into`/`prefill_resume_into` oracle.
fn assert_batched_prefill_matches_oracle(model: &dyn StepModel, kers: Kernels, seed: u64) {
    let t = model.tier().clone();
    let quantized = model.quantized_conv_state();
    let v = t.vocab;
    let mut r = Pcg32::new(seed);
    let b = 2 + r.below(3) as usize; // 2..=4 lanes
    let prompts: Vec<Vec<u16>> = (0..b)
        .map(|_| {
            let len = 6 + r.below(28) as usize;
            (0..len).map(|_| r.below(v as u32) as u16).collect()
        })
        .collect();

    // oracle: per-request one-shot prefill
    let mut scratch = StepScratch::with_kernels(1, kers);
    let mut oracle_states = Vec::new();
    let mut oracle_logits: Vec<Vec<f32>> = Vec::new();
    for p in &prompts {
        let mut st = MambaState::new_for(&t, 1, quantized);
        let mut lg = Vec::new();
        model.prefill_into(p, &mut st, &mut scratch, &mut lg);
        oracle_states.push(st);
        oracle_logits.push(lg);
    }

    // batched: advance all lanes in ragged rounds until every prompt
    // is consumed; collect each lane's valid logits rows
    let mut state = MambaState::new_for(&t, b, quantized);
    let mut next = vec![0usize; b];
    let mut got_logits: Vec<Vec<f32>> = vec![Vec::new(); b];
    let mut batch_scratch = StepScratch::with_kernels(1, kers);
    let mut logits = Vec::new();
    while (0..b).any(|bi| next[bi] < prompts[bi].len()) {
        // random per-lane chunk lengths; lanes already done sit out
        let mut lanes: Vec<usize> = Vec::new();
        let mut chunks: Vec<&[u16]> = Vec::new();
        for bi in 0..b {
            let rem = prompts[bi].len() - next[bi];
            if rem == 0 || (lanes.len() > 1 && r.f32() < 0.25) {
                continue; // exercise partial participation too
            }
            let take = 1 + (r.below(7) as usize).min(rem - 1);
            lanes.push(bi);
            chunks.push(&prompts[bi][next[bi]..next[bi] + take]);
        }
        if lanes.is_empty() {
            continue;
        }
        // pack the participating lanes' states into a fresh sub-state
        // (lane-major copy, mirrors the engine's pool gather)
        let nb = lanes.len();
        let mut sub = MambaState::new_for(&t, nb, quantized);
        for (si, &bi) in lanes.iter().enumerate() {
            copy_lane(&t, &mut sub, si, &state, bi, quantized);
        }
        model.prefill_batch_into(&chunks, &mut sub, &mut batch_scratch, &mut logits);
        let t_max = chunks.iter().map(|c| c.len()).max().unwrap();
        for (si, &bi) in lanes.iter().enumerate() {
            let tl = chunks[si].len();
            got_logits[bi]
                .extend_from_slice(&logits[si * t_max * v..(si * t_max + tl) * v]);
            next[bi] += tl;
            copy_lane(&t, &mut state, bi, &sub, si, quantized);
        }
    }

    for bi in 0..b {
        assert_bits_eq(
            &oracle_logits[bi],
            &got_logits[bi],
            &format!("lane {bi} logits (seed {seed})"),
        );
        // final state equality, lane by lane
        let mut single = MambaState::new_for(&t, 1, quantized);
        copy_lane(&t, &mut single, 0, &state, bi, quantized);
        assert_eq!(oracle_states[bi].conv_q, single.conv_q, "lane {bi} conv codes");
        assert_bits_eq(&oracle_states[bi].conv, &single.conv, &format!("lane {bi} conv"));
        assert_bits_eq(&oracle_states[bi].ssm, &single.ssm, &format!("lane {bi} ssm"));
    }
}

/// Copy one lane's per-layer state from `src[sbi]` into `dst[dbi]`
/// (layout helper for the pack/unpack the engine's pool does).
fn copy_lane(
    t: &MambaTier,
    dst: &mut MambaState,
    dbi: usize,
    src: &MambaState,
    sbi: usize,
    quantized: bool,
) {
    let cpl = (t.d_conv - 1) * t.d_inner;
    let spl = t.d_inner * t.d_state;
    let (db, sb) = (dst.b, src.b);
    for li in 0..t.n_layer {
        if quantized {
            dst.conv_q[(li * db + dbi) * cpl..(li * db + dbi + 1) * cpl]
                .copy_from_slice(&src.conv_q[(li * sb + sbi) * cpl..(li * sb + sbi + 1) * cpl]);
        } else {
            dst.conv[(li * db + dbi) * cpl..(li * db + dbi + 1) * cpl]
                .copy_from_slice(&src.conv[(li * sb + sbi) * cpl..(li * sb + sbi + 1) * cpl]);
        }
        dst.ssm[(li * db + dbi) * spl..(li * db + dbi + 1) * spl]
            .copy_from_slice(&src.ssm[(li * sb + sbi) * spl..(li * sb + sbi + 1) * spl]);
    }
}

#[test]
fn prop_batched_prefill_bit_identical_to_per_request_oracle() {
    let fp = fp32_model(7);
    let qm = w8a8_model(7);
    let q4 = w4a8_model(7);
    for seed in 0..12u64 {
        assert_batched_prefill_matches_oracle(&fp, Kernels::scalar(), 0xBA7C4 ^ seed);
        for backend in Kernels::available() {
            assert_batched_prefill_matches_oracle(
                &qm,
                Kernels::for_backend(backend),
                0xBA7C4 ^ seed,
            );
            assert_batched_prefill_matches_oracle(
                &q4,
                Kernels::for_backend(backend),
                0xBA7C4 ^ seed,
            );
        }
    }
}

#[test]
fn single_lane_batch_is_exactly_the_resume_path() {
    // B=1 prefill_batch_into must equal prefill_resume_into bit for
    // bit (the W8A8 impl routes both through one body; the fp32 impl
    // is a separate scratch-based path — hold it to the same bits)
    let t = tier();
    for quantized in [false, true] {
        let fp = fp32_model(3);
        let qm = w8a8_model(3);
        let model: &dyn StepModel = if quantized { &qm } else { &fp };
        let mut r = Pcg32::new(0x51);
        let prompt: Vec<u16> = (0..24).map(|_| r.below(t.vocab as u32) as u16).collect();
        let mut scratch = StepScratch::new(1);
        let mut st_a = MambaState::new_for(&t, 1, quantized);
        let mut lg_a = Vec::new();
        model.prefill_into(&prompt[..10], &mut st_a, &mut scratch, &mut lg_a);
        model.prefill_resume_into(&prompt[10..], &mut st_a, &mut scratch, &mut lg_a);
        let mut st_b = MambaState::new_for(&t, 1, quantized);
        let mut lg_b = Vec::new();
        model.prefill_into(&prompt[..10], &mut st_b, &mut scratch, &mut lg_b);
        model.prefill_batch_into(&[&prompt[10..]], &mut st_b, &mut scratch, &mut lg_b);
        assert_bits_eq(&lg_a, &lg_b, "resume vs single-lane batch logits");
        assert_eq!(st_a.conv_q, st_b.conv_q);
        assert_bits_eq(&st_a.conv, &st_b.conv, "conv");
        assert_bits_eq(&st_a.ssm, &st_b.ssm, "ssm");
    }
}

/// Mixed serving workload with long prompts (so chunking actually
/// spans many ticks), shared prefixes (so the cache hits), greedy and
/// temperature requests side by side.
fn workload(seed: u64) -> Vec<Request> {
    let t = tier();
    let v = t.vocab as u32;
    let mut r = Pcg32::new(seed ^ 0xF00);
    let shared: Vec<u16> = (0..9).map(|_| r.below(v) as u16).collect();
    let mut reqs = Vec::new();
    for i in 0..12u64 {
        let len = match i % 3 {
            0 => 3 + r.below(5) as usize,        // short
            1 => 20 + r.below(20) as usize,      // long (chunking bites)
            _ => 40 + r.below(9) as usize,       // longer
        };
        let mut prompt = if i % 4 == 0 { shared.clone() } else { Vec::new() };
        while prompt.len() < len {
            prompt.push(r.below(v) as u16);
        }
        let temperature = if i % 2 == 0 { 0.0 } else { 0.8 };
        reqs.push(Request {
            id: i,
            prompt,
            max_new_tokens: 3 + (i as usize) % 5,
            params: SamplingParams {
                temperature,
                top_k: if temperature > 0.0 { 8 } else { 0 },
                seed: i ^ 0x5,
                ..Default::default()
            },
            stop_at_eos: false,
        });
    }
    reqs
}

fn run(cfg: NativeEngineConfig, quantized: bool, seed: u64) -> Vec<(u64, Vec<u16>)> {
    let mut eng = if quantized {
        NativeEngine::new(Box::new(w8a8_model(seed)), cfg)
    } else {
        NativeEngine::new(Box::new(fp32_model(seed)), cfg)
    };
    for req in workload(seed) {
        eng.submit(req);
    }
    let mut done: Vec<(u64, Vec<u16>)> = eng
        .run_to_completion()
        .unwrap()
        .into_iter()
        .map(|r| (r.id, r.tokens))
        .collect();
    done.sort_by_key(|(id, _)| *id);
    done
}

fn run_w4(cfg: NativeEngineConfig, seed: u64) -> Vec<(u64, Vec<u16>)> {
    let mut eng = NativeEngine::new(Box::new(w4a8_model(seed)), cfg);
    for req in workload(seed) {
        eng.submit(req);
    }
    let mut done: Vec<(u64, Vec<u16>)> = eng
        .run_to_completion()
        .unwrap()
        .into_iter()
        .map(|r| (r.id, r.tokens))
        .collect();
    done.sort_by_key(|(id, _)| *id);
    done
}

#[test]
fn prop_chunk_size_never_changes_tokens() {
    // THE tentpole acceptance sweep: chunk ∈ {∞, 1, 3, 16} ×
    // threads {1, 3} × cache off/on(stride 3) must serve identical
    // token streams (greedy AND temperature requests), fp32 and W8A8
    for quantized in [false, true] {
        for seed in [2u64, 19] {
            let baseline = run(NativeEngineConfig::default(), quantized, seed);
            for chunk in [0usize, 1, 3, 16] {
                for threads in [1usize, 3] {
                    for cache_bytes in [0usize, 1 << 20] {
                        let cfg = NativeEngineConfig {
                            prefill_chunk: chunk,
                            threads,
                            cache_bytes,
                            snapshot_stride: if cache_bytes > 0 { 3 } else { 0 },
                            ..Default::default()
                        };
                        let got = run(cfg, quantized, seed);
                        assert_eq!(
                            baseline, got,
                            "tokens moved (quantized={quantized} seed={seed} chunk={chunk} \
                             threads={threads} cache={cache_bytes})"
                        );
                    }
                }
            }
        }
    }
}

#[test]
fn forced_kernel_backends_identical_under_chunking() {
    let want = run(
        NativeEngineConfig {
            prefill_chunk: 5,
            cache_bytes: 1 << 20,
            snapshot_stride: 4,
            kernel_backend: Some(KernelBackend::Scalar),
            ..Default::default()
        },
        true,
        11,
    );
    for backend in Kernels::available() {
        let got = run(
            NativeEngineConfig {
                prefill_chunk: 5,
                cache_bytes: 1 << 20,
                snapshot_stride: 4,
                kernel_backend: Some(backend),
                ..Default::default()
            },
            true,
            11,
        );
        assert_eq!(want, got, "backend {} changed chunked tokens", backend.label());
    }
}

#[test]
fn w4a8_chunk_threads_cache_never_change_tokens() {
    // ISSUE 8 satellite: the W4A8 tier gets the same engine-level
    // guarantee as W8A8 — chunk ∈ {∞, 1, 3, 16} × threads {1, 3} ×
    // cache off/on(stride 3) serve identical greedy AND temperature
    // token streams (workload() mixes both).
    for seed in [2u64, 19] {
        let baseline = run_w4(NativeEngineConfig::default(), seed);
        for chunk in [0usize, 1, 3, 16] {
            for threads in [1usize, 3] {
                for cache_bytes in [0usize, 1 << 20] {
                    let cfg = NativeEngineConfig {
                        prefill_chunk: chunk,
                        threads,
                        cache_bytes,
                        snapshot_stride: if cache_bytes > 0 { 3 } else { 0 },
                        ..Default::default()
                    };
                    let got = run_w4(cfg, seed);
                    assert_eq!(
                        baseline, got,
                        "W4A8 tokens moved (seed={seed} chunk={chunk} \
                         threads={threads} cache={cache_bytes})"
                    );
                }
            }
        }
    }
}

#[test]
fn w4a8_forced_kernel_backends_identical_under_chunking() {
    let base = NativeEngineConfig {
        prefill_chunk: 5,
        cache_bytes: 1 << 20,
        snapshot_stride: 4,
        kernel_backend: Some(KernelBackend::Scalar),
        ..Default::default()
    };
    let want = run_w4(base.clone(), 11);
    for backend in Kernels::available() {
        let cfg = NativeEngineConfig { kernel_backend: Some(backend), ..base.clone() };
        let got = run_w4(cfg, 11);
        assert_eq!(want, got, "W4A8 backend {} changed chunked tokens", backend.label());
    }
}

/// Serve [`workload`] through a speculative engine: W8A8 target plus
/// a draft twin (the W4A8 sibling by default, the fp32 reference when
/// `fp32_draft`), proposing `spec_tokens` tokens per lane per round.
fn run_spec(
    cfg: NativeEngineConfig,
    spec_tokens: usize,
    fp32_draft: bool,
    seed: u64,
) -> Vec<(u64, Vec<u16>)> {
    let cfg = NativeEngineConfig { spec_tokens, ..cfg };
    let draft: Box<dyn StepModel + Send + Sync> = if fp32_draft {
        Box::new(fp32_model(seed))
    } else {
        Box::new(w4a8_model(seed))
    };
    let mut eng = NativeEngine::with_draft(Box::new(w8a8_model(seed)), draft, cfg);
    for req in workload(seed) {
        eng.submit(req);
    }
    let mut done: Vec<(u64, Vec<u16>)> = eng
        .run_to_completion()
        .unwrap()
        .into_iter()
        .map(|r| (r.id, r.tokens))
        .collect();
    done.sort_by_key(|(id, _)| *id);
    done
}

#[test]
fn spec_decode_never_changes_tokens_across_schedules() {
    // ISSUE 10 acceptance sweep: speculative decoding is a pure
    // throughput optimization — K ∈ {0, 2, 4, 8} × chunk {∞, 1, 16} ×
    // threads {1, 3} × cache off/on must serve token streams
    // bit-identical to the plain W8A8 engine, greedy AND temperature
    // requests alike (workload() mixes both).
    let seed = 2u64;
    let baseline = run(NativeEngineConfig::default(), true, seed);
    for k in [0usize, 2, 4, 8] {
        for chunk in [0usize, 1, 16] {
            for threads in [1usize, 3] {
                for cache_bytes in [0usize, 1 << 20] {
                    let cfg = NativeEngineConfig {
                        prefill_chunk: chunk,
                        threads,
                        cache_bytes,
                        snapshot_stride: if cache_bytes > 0 { 3 } else { 0 },
                        ..Default::default()
                    };
                    let got = run_spec(cfg, k, false, seed);
                    assert_eq!(
                        baseline, got,
                        "spec decode moved tokens (K={k} chunk={chunk} \
                         threads={threads} cache={cache_bytes})"
                    );
                }
            }
        }
    }
    // second seed, spot-checked at the matrix corners
    let seed = 19u64;
    let baseline = run(NativeEngineConfig::default(), true, seed);
    for (k, chunk, threads) in [(2usize, 0usize, 1usize), (8, 1, 3), (4, 16, 3)] {
        let cfg = NativeEngineConfig { prefill_chunk: chunk, threads, ..Default::default() };
        assert_eq!(
            baseline,
            run_spec(cfg, k, false, seed),
            "spec decode moved tokens (seed={seed} K={k} chunk={chunk} threads={threads})"
        );
    }
}

#[test]
fn spec_decode_with_fp32_draft_never_changes_tokens() {
    // the draft tier is a free choice: an fp32 draft proposes
    // different tokens than the W4A8 twin (different acceptance
    // rates), but the verify pass pins the output stream regardless
    for seed in [2u64, 19] {
        let baseline = run(NativeEngineConfig::default(), true, seed);
        for k in [2usize, 8] {
            assert_eq!(
                baseline,
                run_spec(NativeEngineConfig::default(), k, true, seed),
                "fp32-draft spec decode moved tokens (seed={seed} K={k})"
            );
        }
    }
}

#[test]
fn spec_decode_identical_across_kernel_backends() {
    let base = NativeEngineConfig {
        prefill_chunk: 5,
        cache_bytes: 1 << 20,
        snapshot_stride: 4,
        kernel_backend: Some(KernelBackend::Scalar),
        ..Default::default()
    };
    let want = run_spec(base.clone(), 4, false, 11);
    assert_eq!(want, run(base.clone(), true, 11), "spec scalar run diverged from plain");
    for backend in Kernels::available() {
        let cfg = NativeEngineConfig { kernel_backend: Some(backend), ..base.clone() };
        let got = run_spec(cfg, 4, false, 11);
        assert_eq!(want, got, "spec backend {} changed tokens", backend.label());
    }
}

#[test]
fn verify_rows_bit_identical_to_step_decode() {
    // the mechanism spec_tick relies on: feeding already-emitted
    // tokens through prefill_batch_into must produce, row for row,
    // the same logits step_into would have produced one token at a
    // time — for the fp32 reference AND both quantized tiers.
    use quamba::ssm::verify_row;
    let t = tier();
    let v = t.vocab;
    let fp = fp32_model(7);
    let q8 = w8a8_model(7);
    let q4 = w4a8_model(7);
    for model in [&fp as &dyn StepModel, &q8, &q4] {
        let quantized = model.quantized_conv_state();
        let mut r = Pcg32::new(0x5bec);
        let prompt: Vec<u16> = (0..6).map(|_| r.below(v as u32) as u16).collect();
        let pending: Vec<u16> = (0..9).map(|_| r.below(v as u32) as u16).collect();
        let mut scratch = StepScratch::new(1);

        // oracle: stepwise decode from the prefilled state
        let mut st_step = MambaState::new_for(&t, 1, quantized);
        let mut lg = Vec::new();
        model.prefill_into(&prompt, &mut st_step, &mut scratch, &mut lg);
        let mut step_rows: Vec<Vec<f32>> = Vec::new();
        for &tok in &pending {
            let mut row = vec![0.0f32; v];
            model.step_into(&[tok], &mut st_step, &mut scratch, &mut row);
            step_rows.push(row);
        }

        // spec path: the same tokens as ONE batched verify chunk
        let mut st_batch = MambaState::new_for(&t, 1, quantized);
        let mut lg2 = Vec::new();
        model.prefill_into(&prompt, &mut st_batch, &mut scratch, &mut lg2);
        let mut logits = Vec::new();
        model.prefill_batch_into(&[&pending], &mut st_batch, &mut scratch, &mut logits);
        for (ti, want) in step_rows.iter().enumerate() {
            assert_bits_eq(
                want,
                verify_row(&logits, 0, pending.len(), ti, v),
                &format!("verify row {ti}"),
            );
        }
        // and the rolled-forward state matches the stepwise one
        assert_eq!(st_step.conv_q, st_batch.conv_q, "conv codes");
        assert_bits_eq(&st_step.conv, &st_batch.conv, "conv");
        assert_bits_eq(&st_step.ssm, &st_batch.ssm, "ssm");
    }
}

#[test]
fn token_budget_never_changes_tokens() {
    // tight budgets reorder work across ticks (incl. the
    // minimum-progress 1-token path) but must not touch the streams
    let baseline = run(NativeEngineConfig::default(), true, 23);
    for budget in [4usize, 9, 64] {
        let cfg = NativeEngineConfig {
            prefill_chunk: 16,
            max_tokens_per_tick: budget,
            ..Default::default()
        };
        assert_eq!(baseline, run(cfg, true, 23), "budget {budget} changed tokens");
    }
}

#[test]
fn chunked_cache_still_hits_and_saves_prefill() {
    // chunk ends snap to the stride grid, so a chunked engine must
    // produce the same nested-prefix snapshot reuse the whole-prompt
    // path did: resubmitting the workload yields full-prompt hits
    let cfg = NativeEngineConfig {
        prefill_chunk: 4,
        cache_bytes: 1 << 20,
        snapshot_stride: 3,
        ..Default::default()
    };
    let mut eng = NativeEngine::new(Box::new(w8a8_model(31)), cfg);
    for req in workload(31) {
        eng.submit(req);
    }
    eng.run_to_completion().unwrap();
    let warmup = eng.cache_stats().unwrap();
    assert!(warmup.insertions > 0, "{warmup:?}");
    for mut req in workload(31) {
        req.id += 100;
        eng.submit(req);
    }
    eng.run_to_completion().unwrap();
    let s = eng.cache_stats().unwrap();
    assert!(
        s.hits >= warmup.hits + 12,
        "every resubmitted prompt must hit (12 requests): {s:?}"
    );
    assert!(s.prefill_tokens_saved > warmup.prefill_tokens_saved, "{s:?}");
}
