//! Prefix-sharing SSM state cache: the cache may never change tokens,
//! only TTFT. These tests pin that contract:
//!
//! * **replay bit-parity** (model level): segmented
//!   `prefill_resume_into` reproduces the one-shot `prefill_into`
//!   logits AND final state bit-for-bit, for the fp32 reference and
//!   the W8A8 model, under every available kernel backend — the
//!   property that makes restore-and-prefill-the-suffix exact;
//! * **engine equivalence** (property over seeds): greedy and
//!   temperature-sampled token streams are identical with the cache
//!   on and off across random shared-prefix workloads, both native
//!   engines (fp32 and W8A8 `NativeEngine`), forced scalar and SIMD
//!   backends, with hit/eviction/opt-out accounting checked along the
//!   way.
//!
//! Trie longest-prefix match, LRU eviction under a byte budget and
//! hit accounting also have unit tests in `src/cache/`. The XLA
//! `Engine`'s exact-hit path shares that unit-tested `lookup_exact` /
//! `restore` machinery but cannot be integration-tested here — it
//! needs AOT artifacts (JAX) that no CI configuration of this repo
//! can build; its hit path falls back to a cold prefill (rather than
//! panicking) if the cache invariant ever drifts.

use quamba::cache::CacheStats;
use quamba::coordinator::{NativeEngine, NativeEngineConfig, Request, SamplingParams};
use quamba::quant::{KernelBackend, Kernels};
use quamba::ssm::{
    MambaModel, MambaState, MambaTier, QuantConfig, QuantizedMambaModel, StepModel, StepScratch,
};
use quamba::util::rng::Pcg32;

fn tier() -> MambaTier {
    MambaTier {
        name: "cache".into(),
        d_model: 16,
        n_layer: 2,
        d_state: 4,
        d_conv: 4,
        d_inner: 32,
        dt_rank: 4,
        vocab: 32,
    }
}

fn fp32_model(seed: u64) -> MambaModel {
    MambaModel::synthetic(tier(), seed)
}

fn w8a8_model(seed: u64) -> QuantizedMambaModel {
    let t = tier();
    let model = MambaModel::synthetic(t.clone(), seed);
    let mut r = Pcg32::new(seed ^ 0x1234);
    let calib: Vec<u16> = (0..256).map(|_| r.below(t.vocab as u32) as u16).collect();
    QuantizedMambaModel::from_model(&model, &calib, &QuantConfig::default())
}

fn assert_bits_eq(a: &[f32], b: &[f32], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length mismatch");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{what}[{i}]: {x} vs {y}");
    }
}

/// One-shot prefill vs the same prompt run as resume segments split at
/// `cuts`: logits rows and final state must be bit-identical.
fn assert_segmented_prefill_bit_identical(
    model: &dyn StepModel,
    kers: Kernels,
    prompt: &[u16],
    cuts: &[usize],
) {
    let t = model.tier().clone();
    let quantized = model.quantized_conv_state();
    let mut scratch = StepScratch::with_kernels(1, kers);

    let mut st_full = MambaState::new_for(&t, 1, quantized);
    let mut full = Vec::new();
    model.prefill_into(prompt, &mut st_full, &mut scratch, &mut full);

    let mut st_seg = MambaState::new_for(&t, 1, quantized);
    let mut seg = Vec::new();
    let mut got: Vec<f32> = Vec::new();
    let mut start = 0usize;
    for &c in cuts.iter().chain(std::iter::once(&prompt.len())) {
        assert!(c > start && c <= prompt.len(), "test bug: bad cut {c}");
        model.prefill_resume_into(&prompt[start..c], &mut st_seg, &mut scratch, &mut seg);
        got.extend_from_slice(&seg);
        start = c;
    }
    assert_bits_eq(&full, &got, "segmented prefill logits");
    assert_eq!(st_full.conv_q, st_seg.conv_q, "conv window codes diverged");
    assert_bits_eq(&st_full.conv, &st_seg.conv, "f32 conv window");
    assert_bits_eq(&st_full.ssm, &st_seg.ssm, "ssm state");
}

#[test]
fn prop_segmented_resume_prefill_bit_identical() {
    // the cache's core oracle, for both models and (for the int8
    // paths) every kernel backend this machine can run
    let fp = fp32_model(7);
    let qm = w8a8_model(7);
    let t = tier();
    for seed in 0..20u64 {
        let mut r = Pcg32::new(0xCAC4E ^ seed);
        let tl = 8 + r.below(32) as usize;
        let prompt: Vec<u16> = (0..tl).map(|_| r.below(t.vocab as u32) as u16).collect();
        // random strictly-increasing interior cut set (possibly empty)
        let mut cuts: Vec<usize> = (1..tl).filter(|_| r.f32() < 0.2).collect();
        if cuts.is_empty() && tl > 2 {
            cuts.push(1 + r.below(tl as u32 - 1) as usize);
        }
        cuts.sort_unstable();
        cuts.dedup();
        assert_segmented_prefill_bit_identical(&fp, Kernels::scalar(), &prompt, &cuts);
        for backend in Kernels::available() {
            assert_segmented_prefill_bit_identical(
                &qm,
                Kernels::for_backend(backend),
                &prompt,
                &cuts,
            );
        }
    }
}

/// Deterministic shared-prefix workload: 4 base prompts × 4 variants
/// (base | base+a | base again | base+a+b) — by construction later
/// variants find earlier end-of-prompt snapshots as proper prefixes
/// (or exact matches), so a warmed cache must produce hits.
fn shared_prefix_workload(seed: u64, temperature: f32) -> Vec<Request> {
    let t = tier();
    let v = t.vocab as u32;
    let mut r = Pcg32::new(seed ^ 0xAB);
    let bases: Vec<Vec<u16>> = (0..4)
        .map(|_| {
            let len = 4 + r.below(12) as usize;
            (0..len).map(|_| r.below(v) as u16).collect()
        })
        .collect();
    let exts: Vec<(Vec<u16>, Vec<u16>)> = (0..4)
        .map(|_| {
            let la = 1 + r.below(5) as usize;
            let lb = 1 + r.below(5) as usize;
            (
                (0..la).map(|_| r.below(v) as u16).collect(),
                (0..lb).map(|_| r.below(v) as u16).collect(),
            )
        })
        .collect();
    let mut reqs = Vec::new();
    for i in 0..16u64 {
        let bi = (i % 4) as usize;
        let variant = (i / 4) as usize;
        let mut prompt = bases[bi].clone();
        if variant == 1 || variant == 3 {
            prompt.extend_from_slice(&exts[bi].0);
        }
        if variant == 3 {
            prompt.extend_from_slice(&exts[bi].1);
        }
        reqs.push(Request {
            id: i,
            prompt,
            max_new_tokens: 3 + (i as usize) % 4,
            params: SamplingParams {
                temperature,
                top_k: if temperature > 0.0 { 8 } else { 0 },
                ..Default::default()
            },
            stop_at_eos: false,
        });
    }
    reqs
}

fn run_workload(
    cfg: NativeEngineConfig,
    quantized: bool,
    seed: u64,
    temperature: f32,
    no_cache: bool,
) -> (Vec<(u64, Vec<u16>)>, Option<CacheStats>) {
    let mut eng = if quantized {
        NativeEngine::new(Box::new(w8a8_model(seed)), cfg)
    } else {
        NativeEngine::new(Box::new(fp32_model(seed)), cfg)
    };
    for mut req in shared_prefix_workload(seed, temperature) {
        req.params.no_cache = no_cache;
        eng.submit(req);
    }
    let mut done: Vec<(u64, Vec<u16>)> = eng
        .run_to_completion()
        .unwrap()
        .into_iter()
        .map(|r| (r.id, r.tokens))
        .collect();
    done.sort_by_key(|(id, _)| *id);
    (done, eng.cache_stats())
}

#[test]
fn prop_cache_on_off_tokens_identical_both_engines() {
    // ISSUE 4 acceptance: greedy AND temperature-sampled streams are
    // identical with the cache on/off, fp32 and W8A8, with and without
    // interior stride snapshots — and the cache actually got exercised
    for quantized in [false, true] {
        for temperature in [0.0f32, 0.8] {
            for seed in [3u64, 11, 42] {
                let (cold, no_stats) =
                    run_workload(NativeEngineConfig::default(), quantized, seed, temperature, false);
                assert!(no_stats.is_none(), "cache off must report no stats");
                for stride in [0usize, 3] {
                    let cfg = NativeEngineConfig {
                        cache_bytes: 1 << 20,
                        snapshot_stride: stride,
                        ..Default::default()
                    };
                    let (warm, stats) = run_workload(cfg, quantized, seed, temperature, false);
                    assert_eq!(
                        cold, warm,
                        "cache changed tokens (quantized={quantized} temp={temperature} \
                         seed={seed} stride={stride})"
                    );
                    let s = stats.expect("cache on must report stats");
                    assert!(s.hits > 0, "workload must produce hits (stride={stride}): {s:?}");
                    assert!(s.prefill_tokens_saved > 0, "{s:?}");
                    assert!(s.bytes_in_use <= s.capacity_bytes, "{s:?}");
                }
            }
        }
    }
}

#[test]
fn cache_on_off_identical_under_forced_kernel_backends() {
    // warm paths must stay bit-replayable under every SIMD dispatch
    let base_cfg = NativeEngineConfig {
        cache_bytes: 1 << 20,
        snapshot_stride: 4,
        kernel_backend: Some(KernelBackend::Scalar),
        ..Default::default()
    };
    let (want, _) = run_workload(base_cfg, true, 5, 0.8, false);
    for backend in Kernels::available() {
        let cfg = NativeEngineConfig {
            cache_bytes: 1 << 20,
            snapshot_stride: 4,
            kernel_backend: Some(backend),
            ..Default::default()
        };
        let (got, stats) = run_workload(cfg, true, 5, 0.8, false);
        assert_eq!(want, got, "cached serving diverged on backend {}", backend.label());
        assert!(stats.unwrap().hits > 0);
    }
}

#[test]
fn exact_resubmission_skips_prefill_and_matches_greedy() {
    let t = tier();
    let cfg = NativeEngineConfig { cache_bytes: 1 << 20, ..Default::default() };
    let mut eng = NativeEngine::new(Box::new(w8a8_model(9)), cfg);
    let prompt: Vec<u16> = (0..24).map(|i| (i * 7 % t.vocab) as u16).collect();
    let req = |id| Request {
        id,
        prompt: prompt.clone(),
        max_new_tokens: 5,
        params: SamplingParams::default(),
        stop_at_eos: false,
    };
    eng.submit(req(1));
    let first = eng.run_to_completion().unwrap();
    let s1 = eng.cache_stats().unwrap();
    assert!(s1.insertions >= 1);
    assert_eq!(s1.hits, 0);
    eng.submit(req(2));
    let second = eng.run_to_completion().unwrap();
    let s2 = eng.cache_stats().unwrap();
    assert_eq!(s2.hits, 1, "resubmission must be a full-prompt hit");
    assert_eq!(
        s2.prefill_tokens_saved,
        prompt.len() as u64,
        "a full hit skips the whole prompt"
    );
    assert_eq!(first[0].tokens, second[0].tokens, "warm greedy tokens must match cold");
}

#[test]
fn per_request_opt_out_bypasses_cache_without_changing_tokens() {
    let (cold, _) = run_workload(NativeEngineConfig::default(), true, 13, 0.0, false);
    let cfg = NativeEngineConfig {
        cache_bytes: 1 << 20,
        snapshot_stride: 3,
        ..Default::default()
    };
    let (opted, stats) = run_workload(cfg, true, 13, 0.0, true);
    assert_eq!(cold, opted, "no_cache requests must decode identically");
    let s = stats.expect("engine still owns a (cold) cache");
    assert_eq!((s.hits, s.misses, s.insertions), (0, 0, 0), "opt-out must not touch it: {s:?}");
}

#[test]
fn tight_budget_evicts_but_serves_identically() {
    // budget ≈ 2 quantized end-of-prompt snapshots (slab + logits row
    // + entry overhead + the per-key-token trie charge at the
    // workload's max prompt length of 25): eviction churn must not
    // change tokens, and the budget must hold throughout
    use quamba::cache::{ENTRY_OVERHEAD_BYTES, KEY_TOKEN_OVERHEAD_BYTES};
    let t = tier();
    let slab_bytes = t.n_layer * ((t.d_conv - 1) * t.d_inner + 4 * t.d_inner * t.d_state);
    let per = slab_bytes + 4 * t.vocab + ENTRY_OVERHEAD_BYTES + 25 * KEY_TOKEN_OVERHEAD_BYTES;
    let cfg = NativeEngineConfig {
        cache_bytes: 2 * per,
        snapshot_stride: 3,
        ..Default::default()
    };
    let (cold, _) = run_workload(NativeEngineConfig::default(), true, 21, 0.0, false);
    let (warm, stats) = run_workload(cfg, true, 21, 0.0, false);
    assert_eq!(cold, warm, "eviction churn changed tokens");
    let s = stats.unwrap();
    assert!(s.evictions > 0, "budget for ~2 snapshots must evict: {s:?}");
    assert!(s.evicted_bytes > 0 && s.bytes_in_use <= s.capacity_bytes, "{s:?}");
}
