//! Native decode correctness: prefill+step vs full-sequence forward,
//! W8A8 greedy token parity, and end-to-end NativeEngine serving — all
//! artifact-free (synthetic weights).
//!
//! The quantized-parity tier/seeds were validated numerically against
//! an independent float32 simulation of the whole pipeline: with this
//! tier the fp32 greedy trajectory's smallest top-2 logit margin is
//! ~6.8 (seed 7) / ~8.9 (seed 8) while the W8A8 logit error stays
//! ≤ ~0.4, so token equality holds with a wide safety factor.

use quamba::coordinator::sampler::argmax;
use quamba::coordinator::{NativeEngine, NativeEngineConfig, Request, SamplingParams};
use quamba::ssm::mamba::QuantSites;
use quamba::ssm::{MambaModel, MambaState, MambaTier, QuantConfig, QuantizedMambaModel, StepModel};
use quamba::util::rng::Pcg32;

fn parity_tier() -> MambaTier {
    MambaTier {
        name: "parity".into(),
        d_model: 16,
        n_layer: 2,
        d_state: 4,
        d_conv: 4,
        d_inner: 32,
        dt_rank: 4,
        vocab: 32,
    }
}

/// Greedy decode through the StepModel surface: prefill the prompt,
/// then feed back the argmax token `steps` times in total.
fn greedy(model: &dyn StepModel, prompt: &[u16], steps: usize) -> Vec<u16> {
    let tier = model.tier();
    let v = tier.vocab;
    let mut st = MambaState::new(tier, 1);
    let logits = model.prefill(prompt, &mut st);
    let last = &logits[(prompt.len() - 1) * v..prompt.len() * v];
    let mut toks = vec![argmax(last) as u16];
    for _ in 1..steps {
        let lg = model.step(&toks[toks.len() - 1..], &mut st);
        toks.push(argmax(&lg[..v]) as u16);
    }
    toks
}

#[test]
fn prefill_plus_step_reproduces_full_forward() {
    // ISSUE 1 acceptance: MambaState::prefill + step over T tokens must
    // reproduce the full-sequence forward logits (≤ 1e-4)
    let tier = parity_tier();
    let model = MambaModel::synthetic(tier.clone(), 7);
    let mut r = Pcg32::new(0xF00D);
    let tokens: Vec<u16> = (0..24).map(|_| r.below(tier.vocab as u32) as u16).collect();
    let full = model.forward(&tokens, &QuantSites::none(), None);

    let split = 8usize;
    let v = tier.vocab;
    let mut st = MambaState::new(&tier, 1);
    let mut stepwise = model.prefill(&tokens[..split], &mut st);
    for ti in split..tokens.len() {
        stepwise.extend(model.step(&tokens[ti..ti + 1], &mut st));
    }
    assert_eq!(stepwise.len(), full.len());
    for (i, (a, b)) in full.iter().zip(&stepwise).enumerate() {
        assert!(
            (a - b).abs() <= 1e-4,
            "logit mismatch at row {} col {}: {a} vs {b}",
            i / v,
            i % v
        );
    }
}

#[test]
fn prefill_in_chunks_matches_single_prefill() {
    // state composition: prefill(a) then step over b == prefill(a ++ b)
    let tier = parity_tier();
    let model = MambaModel::synthetic(tier.clone(), 3);
    let mut r = Pcg32::new(0xBEAD);
    let tokens: Vec<u16> = (0..12).map(|_| r.below(tier.vocab as u32) as u16).collect();
    let mut st_full = MambaState::new(&tier, 1);
    model.prefill(&tokens, &mut st_full);
    let mut st_chunk = MambaState::new(&tier, 1);
    model.prefill(&tokens[..5], &mut st_chunk);
    for ti in 5..tokens.len() {
        model.step(&tokens[ti..ti + 1], &mut st_chunk);
    }
    let (cf, sf) = st_full.into_raw();
    let (cc, sc) = st_chunk.into_raw();
    for (a, b) in cf.iter().zip(&cc) {
        assert!((a - b).abs() < 1e-5, "conv state: {a} vs {b}");
    }
    for (a, b) in sf.iter().zip(&sc) {
        assert!((a - b).abs() < 1e-5, "ssm state: {a} vs {b}");
    }
}

#[test]
fn quantized_greedy_matches_fp32_reference() {
    // ISSUE 1 acceptance: W8A8 greedy tokens == fp32 greedy tokens on
    // the synthetic tier for ≥ 64 steps (margin-validated seeds)
    let tier = parity_tier();
    for seed in [7u64, 8] {
        let model = MambaModel::synthetic(tier.clone(), seed);
        let mut r = Pcg32::new(seed ^ 0x1234);
        let calib: Vec<u16> = (0..256).map(|_| r.below(tier.vocab as u32) as u16).collect();
        let qmodel = QuantizedMambaModel::from_model(&model, &calib, &QuantConfig::default());
        let prompt: Vec<u16> = (0..8).map(|_| r.below(tier.vocab as u32) as u16).collect();
        let steps = 72; // ≥ 64 required
        let fp = greedy(&model, &prompt, steps);
        let q = greedy(&qmodel, &prompt, steps);
        assert_eq!(
            fp, q,
            "seed {seed}: W8A8 greedy decode diverged from the fp32 reference"
        );
    }
}

#[test]
fn native_engine_serves_fp32_and_w8a8_without_artifacts() {
    // ISSUE 1 acceptance: NativeEngine serves a multi-request workload
    // end-to-end with no XLA artifacts present
    let tier = parity_tier();
    let model = MambaModel::synthetic(tier.clone(), 7);
    let mut r = Pcg32::new(99);
    let calib: Vec<u16> = (0..256).map(|_| r.below(tier.vocab as u32) as u16).collect();
    let qmodel = QuantizedMambaModel::from_model(&model, &calib, &QuantConfig::default());
    let models: Vec<Box<dyn StepModel + Send + Sync>> = vec![Box::new(model), Box::new(qmodel)];
    for m in models {
        let mut eng = NativeEngine::new(m, NativeEngineConfig::default());
        for i in 0..12u64 {
            let plen = 3 + (i as usize % 6);
            let prompt: Vec<u16> =
                (0..plen).map(|_| r.below(tier.vocab as u32) as u16).collect();
            eng.submit(Request {
                id: i,
                prompt,
                max_new_tokens: 4 + i as usize % 5,
                params: SamplingParams::default(),
                stop_at_eos: false,
            });
        }
        let done = eng.run_to_completion().unwrap();
        assert_eq!(done.len(), 12);
        for resp in &done {
            assert_eq!(resp.tokens.len(), 4 + resp.id as usize % 5);
            assert!(resp.tokens.iter().all(|&t| (t as usize) < tier.vocab));
        }
        assert_eq!(eng.metrics.requests_done, 12);
        assert!(eng.metrics.tokens_out >= 12 * 4);
        // continuous batching actually batched something
        assert!(eng.metrics.total_lanes > 0);
    }
}

#[test]
fn engine_batching_does_not_change_tokens() {
    // a request decoded alongside 7 others must produce exactly the
    // tokens it produces alone (greedy): lane math is independent and
    // the planner/pool roundtrip is lossless
    let tier = parity_tier();
    let prompt: Vec<u16> = vec![3, 1, 4, 1, 5, 9, 2, 6];
    let solo_tokens = {
        let model = MambaModel::synthetic(tier.clone(), 7);
        let mut eng = NativeEngine::new(Box::new(model), NativeEngineConfig::default());
        eng.submit(Request {
            id: 0,
            prompt: prompt.clone(),
            max_new_tokens: 12,
            params: SamplingParams::default(),
            stop_at_eos: false,
        });
        eng.run_to_completion().unwrap().remove(0).tokens
    };
    let model = MambaModel::synthetic(tier.clone(), 7);
    let mut eng = NativeEngine::new(Box::new(model), NativeEngineConfig::default());
    for i in 0..8u64 {
        let p = if i == 3 {
            prompt.clone()
        } else {
            vec![(i as u16) % 16, 7, 11, (i as u16 + 5) % 16]
        };
        eng.submit(Request {
            id: i,
            prompt: p,
            max_new_tokens: 12,
            params: SamplingParams::default(),
            stop_at_eos: false,
        });
    }
    let done = eng.run_to_completion().unwrap();
    let in_batch = done.iter().find(|r| r.id == 3).unwrap();
    assert_eq!(solo_tokens, in_batch.tokens, "batched decode changed a request's tokens");
}
