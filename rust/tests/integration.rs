//! Integration tests over the real artifact tree: HLO → PJRT → engine.
//!
//! These need `make artifacts` (or at least a `--quick` build). They
//! look for $QUAMBA_ARTIFACTS, then ./artifacts, then the pytest quick
//! tree; if none exists they SKIP (print + pass) so `cargo test` stays
//! green on a fresh checkout.

use std::path::PathBuf;

use quamba::coordinator::engine::{Engine, EngineConfig};
use quamba::coordinator::request::{Request, SamplingParams};
use quamba::data;
use quamba::eval;
use quamba::runtime::Runtime;
use quamba::ssm::mamba::{MambaModel, MambaTier, QuantSites};
use quamba::tensor::{DType, Tensor};

fn artifacts() -> Option<PathBuf> {
    let candidates = [
        std::env::var("QUAMBA_ARTIFACTS").ok().map(PathBuf::from),
        Some(PathBuf::from("artifacts")),
        Some(PathBuf::from("/tmp/quamba_pytest_artifacts")),
        Some(PathBuf::from("/tmp/artq")),
    ];
    candidates
        .into_iter()
        .flatten()
        .find(|p| p.join("manifest.json").exists())
}

macro_rules! need_artifacts {
    () => {
        match artifacts() {
            Some(p) => p,
            None => {
                eprintln!("[skip] no artifacts tree — run `make artifacts`");
                return;
            }
        }
    };
}

fn first_tier(rt: &Runtime) -> String {
    rt.manifest()
        .tiers
        .keys()
        .find(|t| *t != "jamba")
        .cloned()
        .expect("no tiers")
}

#[test]
fn runtime_executes_prefill_and_shapes_match() {
    let root = need_artifacts!();
    let mut rt = Runtime::new(&root).expect("runtime");
    let tier = first_tier(&rt);
    let t = rt.manifest().tiers[&tier].clone();
    let g = rt
        .manifest()
        .find_graph(&tier, "fp16", "prefill", 1, None)
        .expect("prefill graph")
        .name
        .clone();
    let seq = rt.manifest().graphs[&g].seq;
    let toks: Vec<i32> = (0..seq as i32).map(|i| (i % 200) + 4).collect();
    let out = rt
        .execute(
            &g,
            &[
                Tensor::from_i32(&[1, seq], &toks),
                Tensor::zeros(DType::F32, &[t.n_layer, 1, t.d_conv - 1, t.d_inner]),
                Tensor::zeros(DType::F32, &[t.n_layer, 1, t.d_inner, t.d_state]),
            ],
        )
        .expect("execute");
    assert_eq!(out.len(), 3);
    assert_eq!(out[0].shape, vec![1, seq, t.vocab]);
    assert_eq!(out[1].shape, vec![t.n_layer, 1, t.d_conv - 1, t.d_inner]);
    assert_eq!(out[2].shape, vec![t.n_layer, 1, t.d_inner, t.d_state]);
    assert!(out[0].to_f32().iter().all(|v| v.is_finite()));
}

#[test]
fn hlo_fp_graph_matches_rust_reference_model() {
    // The same weights through two entirely different stacks: the
    // jax→HLO→PJRT graph and the pure-rust simulator. Logits must
    // agree to fp tolerance — this validates BOTH implementations.
    let root = need_artifacts!();
    let mut rt = Runtime::new(&root).expect("runtime");
    let tier = first_tier(&rt);
    let t = rt.manifest().tiers[&tier].clone();
    let g = rt
        .manifest()
        .find_graph(&tier, "fp16", "prefill", 1, None)
        .expect("graph")
        .name
        .clone();
    let seq = rt.manifest().graphs[&g].seq.min(48);
    let gseq = rt.manifest().graphs[&g].seq;
    let stream = data::load_stream(&rt.manifest().data["pile_eval"]).unwrap();
    let toks_u16: Vec<u16> = stream[..gseq].to_vec();
    let toks: Vec<i32> = toks_u16.iter().map(|&x| x as i32).collect();
    let out = rt
        .execute(
            &g,
            &[
                Tensor::from_i32(&[1, gseq], &toks),
                Tensor::zeros(DType::F32, &[t.n_layer, 1, t.d_conv - 1, t.d_inner]),
                Tensor::zeros(DType::F32, &[t.n_layer, 1, t.d_inner, t.d_state]),
            ],
        )
        .expect("execute");
    let hlo_logits = out[0].to_f32();

    let q = rt.weight_qtz(&format!("{tier}_fp16")).expect("weights");
    let model = MambaModel::from_qtz(
        MambaTier {
            name: t.name.clone(),
            d_model: t.d_model,
            n_layer: t.n_layer,
            d_state: t.d_state,
            d_conv: t.d_conv,
            d_inner: t.d_inner,
            dt_rank: t.dt_rank,
            vocab: t.vocab,
        },
        &q,
    )
    .expect("model");
    let ref_logits = model.forward(&toks_u16, &QuantSites::none(), None);
    // compare a prefix of positions (tolerances accumulate over T)
    let v = t.vocab;
    let mut max_rel = 0.0f32;
    for i in 0..seq * v {
        let (a, b) = (hlo_logits[i], ref_logits[i]);
        let rel = (a - b).abs() / (1.0 + a.abs().max(b.abs()));
        max_rel = max_rel.max(rel);
    }
    assert!(max_rel < 2e-2, "HLO vs rust reference diverged: {max_rel}");
}

#[test]
fn engine_generates_and_batches() {
    let root = need_artifacts!();
    let rt = Runtime::new(&root).expect("runtime");
    let tier = first_tier(&rt);
    let methods = rt.manifest().methods_for_tier(&tier, "decode");
    let method = if methods.iter().any(|m| m == "quamba") { "quamba" } else { &methods[0] };
    let mut engine = Engine::new(rt, EngineConfig::new(&tier, method)).expect("engine");
    engine.warmup().expect("warmup");
    let stream = data::load_stream(&engine.manifest().data["pile_eval"]).unwrap();
    for i in 0..5 {
        engine.submit(Request {
            id: i,
            prompt: stream[i as usize * 10..i as usize * 10 + 12].to_vec(),
            max_new_tokens: 6 + i as usize,
            params: SamplingParams::default(),
            stop_at_eos: false,
        });
    }
    let responses = engine.run_to_completion().expect("run");
    assert_eq!(responses.len(), 5);
    for r in &responses {
        let want = 6 + r.id as usize;
        assert_eq!(r.tokens.len(), want, "request {} length", r.id);
        assert!(r.ttft_ms.is_finite() && r.ttft_ms > 0.0);
        assert!(r.tokens.iter().all(|&t| (t as usize) < 256));
    }
    // deterministic greedy sampling: same prompt → same tokens
    let m = engine.metrics.report();
    assert!(m.contains("requests=5"));
}

#[test]
fn engine_deterministic_greedy() {
    let root = need_artifacts!();
    let run = |root: &PathBuf| {
        let rt = Runtime::new(root).expect("runtime");
        let tier = first_tier(&rt);
        let methods = rt.manifest().methods_for_tier(&tier, "decode");
        let method = if methods.iter().any(|m| m == "fp16") { "fp16" } else { &methods[0] };
        let mut engine = Engine::new(rt, EngineConfig::new(&tier, method)).expect("engine");
        let stream = data::load_stream(&engine.manifest().data["pile_eval"]).unwrap();
        engine.submit(Request {
            id: 1,
            prompt: stream[..16].to_vec(),
            max_new_tokens: 8,
            params: SamplingParams::default(),
            stop_at_eos: false,
        });
        engine.run_to_completion().expect("run")[0].tokens.clone()
    };
    assert_eq!(run(&root), run(&root));
}

#[test]
fn quantized_ppl_close_to_fp() {
    let root = need_artifacts!();
    let mut rt = Runtime::new(&root).expect("runtime");
    let tier = first_tier(&rt);
    if rt.manifest().find_graph(&tier, "quamba", "prefill", 4, None).is_none() {
        eprintln!("[skip] no quamba eval graph");
        return;
    }
    let stream = data::load_stream(&rt.manifest().data["pile_eval"]).unwrap();
    let fp = eval::perplexity(&mut rt, &tier, "fp16", &stream, 4).expect("fp ppl");
    let q = eval::perplexity(&mut rt, &tier, "quamba", &stream, 4).expect("q ppl");
    assert!(fp.ppl.is_finite() && q.ppl.is_finite());
    assert!(
        q.ppl < fp.ppl * 1.5,
        "quamba ppl {} vs fp {} — recipe should stay near FP",
        q.ppl,
        fp.ppl
    );
}

#[test]
fn task_harness_scores_all_six() {
    let root = need_artifacts!();
    let mut rt = Runtime::new(&root).expect("runtime");
    let tier = first_tier(&rt);
    let tasks = data::load_tasks(&rt.manifest().data["tasks"]).unwrap();
    assert_eq!(tasks.len(), 6);
    let res = eval::run_tasks(&mut rt, &tier, "fp16", &tasks, 8).expect("tasks");
    assert_eq!(res.len(), 6);
    for (name, acc) in &res {
        assert!((0.0..=1.0).contains(acc), "{name}: {acc}");
    }
}

#[test]
fn weight_bundle_size_reduction() {
    let root = need_artifacts!();
    let rt = Runtime::new(&root).expect("runtime");
    let tier = first_tier(&rt);
    let fp = rt.model_bytes(&format!("{tier}_fp16"));
    let q = rt.model_bytes(&format!("{tier}_quamba"));
    if let (Some(fp), Some(q)) = (fp, q) {
        let ratio = fp as f64 / q as f64;
        assert!(ratio > 1.8, "size reduction {ratio:.2}x < paper's ~1.9x shape");
    }
}

#[test]
fn transformer_engine_serves_with_backpressure() {
    let root = need_artifacts!();
    let rt = Runtime::new(&root).expect("runtime");
    let Some(tier) = rt.manifest().transformer_tiers.keys().next().cloned() else {
        eprintln!("[skip] no transformer tier built");
        return;
    };
    use quamba::coordinator::engine_tr::TransformerEngine;
    let mut engine = TransformerEngine::new(rt, &tier, "fp16", usize::MAX).expect("tr engine");
    let stream = data::load_stream(&engine.rt.manifest().data["pile_eval"]).unwrap();
    for i in 0..2 {
        engine.submit(Request {
            id: i,
            prompt: stream[i as usize * 16..i as usize * 16 + 12].to_vec(),
            max_new_tokens: 4,
            params: SamplingParams::default(),
            stop_at_eos: false,
        });
    }
    let responses = engine.run_to_completion().expect("run");
    assert_eq!(responses.len(), 2);
    for r in &responses {
        assert_eq!(r.tokens.len(), 4);
        assert!(r.tokens.iter().all(|&t| (t as usize) < 256));
    }
    // constant-vs-growing memory check against the mamba engine
    assert!(engine.bytes_at(2048) > 10 * engine.bytes_at(128));
}

#[test]
fn jamba_combos_scoreable() {
    let root = need_artifacts!();
    let mut rt = Runtime::new(&root).expect("runtime");
    if !rt.manifest().tiers.contains_key("jamba") {
        eprintln!("[skip] jamba tier not built");
        return;
    }
    let tasks = data::load_tasks(&rt.manifest().data["tasks"]).unwrap();
    let lambada: Vec<_> = tasks.into_iter().filter(|t| t.name == "lambada_synth").collect();
    let fp = eval::run_tasks(&mut rt, "jamba", "fp_fp_fp", &lambada, 8).expect("fp combo");
    assert!((0.0..=1.0).contains(&fp[0].1));
}

#[test]
fn runtime_rejects_unknown_graph() {
    let root = need_artifacts!();
    let mut rt = Runtime::new(&root).expect("runtime");
    assert!(rt.execute("no_such_graph", &[]).is_err());
}

#[test]
fn runtime_compile_is_cached() {
    let root = need_artifacts!();
    let mut rt = Runtime::new(&root).expect("runtime");
    let tier = first_tier(&rt);
    let g = rt
        .manifest()
        .find_graph(&tier, "fp16", "decode", 1, None)
        .expect("decode")
        .name
        .clone();
    rt.load(&g).unwrap();
    let c1 = rt.stats.compiles;
    rt.load(&g).unwrap();
    assert_eq!(rt.stats.compiles, c1, "second load must hit the cache");
}
