//! Deterministic chaos suite for the native engine's failure model
//! (ISSUE 7).
//!
//! Hundreds of seeded schedules drive random mixes of arrivals,
//! bounded-queue overflow, deadlines, client cancellations and
//! injected faults (decode/prefill panics, admission alloc failures,
//! snapshot corruption, tick latency) against a real
//! [`NativeEngine`], asserting at EVERY tick boundary:
//!
//! * **slot conservation** — pool free-list accounting intact, one
//!   slot per live request, no duplicates
//!   ([`NativeEngine::check_slot_conservation`]);
//! * **request conservation** — submitted == collected + live +
//!   queued: nothing leaks, nothing is double-harvested, nothing gets
//!   stuck;
//!
//! and at the end of each schedule:
//!
//! * **metrics conservation** — every submission lands in exactly one
//!   outcome counter ([`Metrics::total_outcomes`]);
//! * **survivor bit-parity** — every response's tokens are a prefix
//!   of (and for clean finishes, equal to) the tokens the same
//!   request produces on a fault-free engine. Chaos may shorten a
//!   stream; it must never *change* it.
//!
//! Everything is replayable: `Clock::Manual` removes wall time,
//! [`FaultPlan`] decisions are stateless hashes of
//! (seed, site, request, step), and the schedule itself is generated
//! from the seed. A failing seed reproduces with
//! `QUAMBA_CHAOS_SEED_BASE=<seed> QUAMBA_CHAOS_SEEDS=1`.

use std::collections::BTreeMap;

use quamba::coordinator::faults::{silence_injected_panics, TargetedFault};
use quamba::coordinator::native::{NativeEngine, NativeEngineConfig};
use quamba::coordinator::server::ServerHandle;
use quamba::coordinator::{
    Clock, FaultPlan, FaultSite, FinishReason, Request, RequestId, Response, SamplingParams,
};
use quamba::ssm::{MambaModel, MambaTier};
use quamba::util::rng::Pcg32;

fn tier() -> MambaTier {
    MambaTier {
        name: "chaos".into(),
        d_model: 8,
        n_layer: 2,
        d_state: 4,
        d_conv: 4,
        d_inner: 16,
        dt_rank: 2,
        vocab: 16,
    }
}

/// Target model (and, for spec-enabled configs, a *different* seed-14
/// draft — imperfect proposals exercise the rollback path constantly;
/// the seeded Draft/Verify fault sites fire on top of that).
fn engine(cfg: NativeEngineConfig) -> NativeEngine {
    let model = Box::new(MambaModel::synthetic(tier(), 13));
    if cfg.spec_tokens > 0 {
        NativeEngine::with_draft(model, Box::new(MambaModel::synthetic(tier(), 14)), cfg)
    } else {
        NativeEngine::new(model, cfg)
    }
}

fn env_u64(name: &str, default: u64) -> u64 {
    std::env::var(name).ok().and_then(|s| s.parse().ok()).unwrap_or(default)
}

/// One seeded schedule: request set, arrival ticks, cancel points.
struct Schedule {
    cfg: NativeEngineConfig,
    /// (arrival tick, request)
    arrivals: Vec<(u64, Request)>,
    /// (cancel tick, request id)
    cancels: Vec<(u64, RequestId)>,
}

fn schedule(seed: u64) -> Schedule {
    let mut r = Pcg32::new(seed.wrapping_mul(0x9E3779B97F4A7C15).wrapping_add(seed) | 1);
    let with_cache = r.below(2) == 0;
    let cfg = NativeEngineConfig {
        capacity: 2 + r.below(3) as usize,
        max_queue: r.below(4) as usize, // 0 = unbounded
        prefill_chunk: [0usize, 2, 3][r.below(3) as usize],
        max_prefills_per_tick: 1 + r.below(2) as usize,
        cache_bytes: if with_cache { 1 << 16 } else { 0 },
        snapshot_stride: if with_cache { 2 } else { 0 },
        default_deadline_ms: if r.below(3) == 0 { 40.0 } else { 0.0 },
        clock: Clock::Manual { ms_per_tick: 1.0 },
        faults: FaultPlan::seeded(seed, 0.02 + 0.03 * r.f64()),
        // a third of the schedules run speculative decoding (draft +
        // verify + rollback under fire: the seeded plan injects at the
        // Draft/Verify sites too); the clean reference stays spec-off —
        // valid because speculation never moves tokens
        spec_tokens: [0, 0, 2, 4][r.below(4) as usize],
        ..Default::default()
    };
    let n_req = 4 + r.below(4) as u64;
    let mut arrivals = Vec::new();
    let mut cancels = Vec::new();
    for i in 0..n_req {
        let id = i + 1;
        let prompt: Vec<u16> = (0..1 + r.below(6)).map(|_| r.below(16) as u16).collect();
        let params = SamplingParams {
            temperature: 0.8,
            top_k: 8,
            seed: id * 31 + 7,
            deadline_ms: (r.below(4) == 0).then(|| 6.0 + 20.0 * r.f64()),
            ttft_deadline_ms: (r.below(5) == 0).then(|| 3.0 + 8.0 * r.f64()),
            ..Default::default()
        };
        let arrival = 1 + r.below(6) as u64;
        arrivals.push((
            arrival,
            Request {
                id,
                prompt,
                max_new_tokens: 2 + r.below(5) as usize,
                params,
                stop_at_eos: false,
            },
        ));
        if r.below(3) == 0 {
            cancels.push((arrival + r.below(10) as u64, id));
        }
    }
    Schedule { cfg, arrivals, cancels }
}

/// Canonical per-request token streams: the same requests (deadlines
/// stripped, same ids / prompts / sampler params) on a fault-free,
/// admission-unbounded engine. Batch composition never changes tokens
/// (per-request RNG streams + per-lane state), so this is THE
/// reference stream for every request regardless of what chaos did to
/// its neighbours.
fn clean_streams(arrivals: &[(u64, Request)]) -> BTreeMap<RequestId, Vec<u16>> {
    let mut eng = engine(NativeEngineConfig {
        capacity: 16,
        clock: Clock::Manual { ms_per_tick: 1.0 },
        ..Default::default()
    });
    for (_, req) in arrivals {
        let mut req = req.clone();
        req.params.deadline_ms = None;
        req.params.ttft_deadline_ms = None;
        eng.submit(req);
    }
    eng.run_to_completion()
        .expect("clean run cannot fail")
        .into_iter()
        .map(|r| (r.id, r.tokens))
        .collect()
}

fn run_seed(seed: u64) {
    let sched = schedule(seed);
    let clean = clean_streams(&sched.arrivals);
    let mut eng = engine(sched.cfg.clone());
    let n_req = sched.arrivals.len();
    let mut collected: Vec<Response> = Vec::new();
    let mut submitted = 0usize;
    for tick in 1..=1000u64 {
        for (at, req) in &sched.arrivals {
            if *at == tick {
                submitted += 1;
                if let Some(reject) = eng.try_submit(req.clone()) {
                    collected.push(reject);
                }
            }
        }
        for (at, id) in &sched.cancels {
            if *at == tick {
                if let Some(resp) = eng.cancel(*id) {
                    collected.push(resp);
                }
            }
        }
        collected.extend(eng.step().unwrap_or_else(|e| panic!("seed {seed}: step: {e}")));
        // per-tick invariants: nothing leaks, nothing double-books
        eng.check_slot_conservation()
            .unwrap_or_else(|e| panic!("seed {seed} tick {tick}: {e}"));
        assert_eq!(
            collected.len() + eng.n_live() + eng.n_queued(),
            submitted,
            "seed {seed} tick {tick}: request conservation broken"
        );
        if submitted == n_req && eng.n_live() == 0 && eng.n_queued() == 0 {
            break;
        }
    }
    // every submission reached exactly one terminal outcome
    assert_eq!(collected.len(), n_req, "seed {seed}: stuck requests");
    let mut ids: Vec<u64> = collected.iter().map(|r| r.id).collect();
    ids.sort_unstable();
    ids.dedup();
    assert_eq!(ids.len(), n_req, "seed {seed}: duplicate response ids");
    assert_eq!(eng.pool_in_use(), 0, "seed {seed}: leaked slots after drain");
    assert_eq!(eng.draft_pool_in_use(), 0, "seed {seed}: leaked draft slots after drain");
    assert_eq!(
        eng.metrics.total_outcomes(),
        n_req as u64,
        "seed {seed}: metrics outcome conservation broken"
    );
    // survivor bit-parity: chaos may truncate a stream, never mutate it
    for resp in &collected {
        let reference = &clean[&resp.id];
        assert!(
            resp.tokens.len() <= reference.len()
                && resp.tokens[..] == reference[..resp.tokens.len()],
            "seed {seed} req {}: tokens diverge from fault-free stream",
            resp.id
        );
        if resp.finish.is_ok() {
            assert_eq!(
                &resp.tokens, reference,
                "seed {seed} req {}: clean finish must be bit-identical",
                resp.id
            );
            assert!(resp.error.is_none());
        } else {
            assert!(
                resp.error.is_some(),
                "seed {seed} req {}: failure without a typed error ({:?})",
                resp.id,
                resp.finish
            );
        }
    }
}

/// The main matrix: `QUAMBA_CHAOS_SEEDS` seeded schedules starting at
/// `QUAMBA_CHAOS_SEED_BASE` (CI shards the base across jobs).
#[test]
fn chaos_seeded_schedules_conserve_slots_requests_and_tokens() {
    silence_injected_panics();
    let base = env_u64("QUAMBA_CHAOS_SEED_BASE", 0);
    let n = env_u64("QUAMBA_CHAOS_SEEDS", 200);
    for seed in base..base + n {
        run_seed(seed);
    }
}

/// ISSUE 7 acceptance demo at the serving-layer level: a worker panic
/// mid-decode fails exactly one request; its co-batched neighbours
/// finish bit-identically to a fault-free run, and the engine accepts
/// and serves new work afterwards.
#[test]
fn worker_panic_fails_one_request_while_server_keeps_serving() {
    silence_injected_panics();
    let clean = clean_streams(&[
        (1, req(1)),
        (1, req(2)),
        (1, req(3)),
    ]);
    let faults = FaultPlan {
        targeted: vec![TargetedFault { site: FaultSite::Decode, req_id: 2, step: 2 }],
        ..FaultPlan::none()
    };
    let cfg = NativeEngineConfig { capacity: 8, faults, ..Default::default() };
    let mut handle =
        ServerHandle::spawn_native(Box::new(MambaModel::synthetic(tier(), 13)), cfg).unwrap();
    let rxs: Vec<_> = (0..3)
        .map(|_| handle.submit(vec![1, 2, 3], 6, SamplingParams { temperature: 0.8, top_k: 8, ..Default::default() }))
        .collect();
    let resps: Vec<Response> = rxs.into_iter().map(|rx| rx.recv().unwrap()).collect();
    let victim = resps.iter().find(|r| r.id == 2).unwrap();
    assert_eq!(victim.finish, FinishReason::Failed);
    assert!(victim.error.as_deref().unwrap_or("").contains("injected"), "{:?}", victim.error);
    assert_eq!(victim.tokens.len(), 2, "tokens before the failing round survive");
    for r in resps.iter().filter(|r| r.id != 2) {
        assert_eq!(r.finish, FinishReason::Length, "survivor {} must finish clean", r.id);
        assert_eq!(&r.tokens, &clean[&r.id], "survivor {} diverged", r.id);
    }
    // the engine is still alive and serving after the panic
    let rx = handle.submit(vec![4, 5], 4, SamplingParams::default());
    let resp = rx.recv().unwrap();
    assert_eq!(resp.finish, FinishReason::Length);
    assert_eq!(resp.tokens.len(), 4);
    handle.shutdown();
}

/// ISSUE 10 targeted chaos: a panic mid-verify (the speculative
/// target pass) retires exactly the named victim with its pre-verify
/// tokens intact — the O(1) pre-draft snapshot restore means nothing
/// half-committed survives — while co-batched spec lanes finish
/// bit-identical to a fault-free, spec-OFF engine.
#[test]
fn verify_panic_restores_snapshot_and_survivors_stay_bit_identical() {
    silence_injected_panics();
    let arrivals: Vec<(u64, Request)> = (1..=3).map(|id| (1, req(id))).collect();
    let clean = clean_streams(&arrivals);
    // every lane enters speculation holding exactly the one token its
    // prefill emitted, so (Verify, req 2, step 1) fires on the
    // victim's FIRST verify round regardless of draft acceptance
    let faults = FaultPlan {
        targeted: vec![TargetedFault { site: FaultSite::Verify, req_id: 2, step: 1 }],
        ..FaultPlan::none()
    };
    let cfg = NativeEngineConfig { capacity: 8, spec_tokens: 4, faults, ..Default::default() };
    let mut eng = engine(cfg);
    for (_, r) in &arrivals {
        eng.submit(r.clone());
    }
    let mut done: Vec<Response> = Vec::new();
    for _ in 0..1000 {
        done.extend(eng.step().unwrap());
        eng.check_slot_conservation().unwrap();
        if eng.n_live() == 0 && eng.n_queued() == 0 {
            break;
        }
    }
    assert_eq!(done.len(), 3, "all requests must reach a terminal outcome");
    let victim = done.iter().find(|r| r.id == 2).unwrap();
    assert_eq!(victim.finish, FinishReason::Failed);
    assert!(victim.error.as_deref().unwrap_or("").contains("injected"), "{:?}", victim.error);
    assert_eq!(
        victim.tokens,
        clean[&2][..1],
        "the pre-verify token survives; nothing half-verified leaks"
    );
    for r in done.iter().filter(|r| r.id != 2) {
        assert_eq!(r.finish, FinishReason::Length, "survivor {} must finish clean", r.id);
        assert_eq!(&r.tokens, &clean[&r.id], "survivor {} diverged", r.id);
    }
    assert_eq!(eng.pool_in_use(), 0, "target slots leaked");
    assert_eq!(eng.draft_pool_in_use(), 0, "draft slots leaked");
}

/// Draft panics are never fatal: the draft runs on scratch copies, so
/// an injected panic in catch-up or proposal steps only costs that
/// tick's speculation — every request still finishes clean with
/// tokens bit-identical to the spec-off reference.
#[test]
fn draft_panic_never_fails_requests_and_tokens_stay_bit_identical() {
    silence_injected_panics();
    let arrivals: Vec<(u64, Request)> = (1..=3).map(|id| (1, req(id))).collect();
    let clean = clean_streams(&arrivals);
    let faults = FaultPlan {
        targeted: vec![
            // proposal-step key (generated + 1 + step_index) on the
            // first round, and a catch-up key later in the stream
            TargetedFault { site: FaultSite::Draft, req_id: 2, step: 2 },
            TargetedFault { site: FaultSite::Draft, req_id: 3, step: 4 },
        ],
        ..FaultPlan::none()
    };
    let cfg = NativeEngineConfig { capacity: 8, spec_tokens: 4, faults, ..Default::default() };
    let mut eng = engine(cfg);
    for (_, r) in &arrivals {
        eng.submit(r.clone());
    }
    let mut done: Vec<Response> = Vec::new();
    for _ in 0..1000 {
        done.extend(eng.step().unwrap());
        eng.check_slot_conservation().unwrap();
        if eng.n_live() == 0 && eng.n_queued() == 0 {
            break;
        }
    }
    assert_eq!(done.len(), 3);
    for r in &done {
        assert_eq!(r.finish, FinishReason::Length, "req {} must survive draft panics", r.id);
        assert_eq!(&r.tokens, &clean[&r.id], "req {} diverged", r.id);
    }
    assert_eq!(eng.draft_pool_in_use(), 0, "draft slots leaked");
}

/// Helper for the serving-layer tests: the server assigns ids 1..;
/// mirror that numbering for the clean reference run.
fn req(id: u64) -> Request {
    Request {
        id,
        prompt: vec![1, 2, 3],
        max_new_tokens: 6,
        params: SamplingParams { temperature: 0.8, top_k: 8, ..Default::default() },
        stop_at_eos: false,
    }
}

/// Client-side cancellation through the server mailbox: the waiter
/// gets a typed `Cancelled` response and the engine keeps running.
#[test]
fn server_cancel_frees_request_and_answers_waiter() {
    let cfg = NativeEngineConfig { capacity: 4, ..Default::default() };
    let mut handle =
        ServerHandle::spawn_native(Box::new(MambaModel::synthetic(tier(), 13)), cfg).unwrap();
    // effectively-unbounded generation so the cancel always lands
    // first (the mailbox is drained every tick; `generated` grows
    // lazily, so a huge bound costs nothing)
    let (id, rx) = handle.submit_with_id(vec![1, 2, 3], 1 << 40, SamplingParams::default());
    handle.cancel(id);
    let resp = rx.recv_timeout(std::time::Duration::from_secs(60)).unwrap();
    assert_eq!(resp.finish, FinishReason::Cancelled);
    assert!(resp.error.is_some());
    // server still serves after the cancellation
    let rx2 = handle.submit(vec![7], 3, SamplingParams::default());
    assert_eq!(rx2.recv().unwrap().finish, FinishReason::Length);
    handle.shutdown();
}

/// Deadline shedding through the public metrics report: the failure
/// counters and shed rate surface in `metrics_report`.
#[test]
fn rejections_surface_in_metrics_report() {
    let cfg = NativeEngineConfig { capacity: 1, max_queue: 1, ..Default::default() };
    let mut handle =
        ServerHandle::spawn_native(Box::new(MambaModel::synthetic(tier(), 13)), cfg).unwrap();
    // a long-running request pins the single slot, so the burst below
    // deterministically overflows the 1-deep queue: one submission
    // queues, the other four shed. The mailbox is FIFO from this
    // thread, so the cancel is guaranteed to arrive after the burst.
    let (long_id, long_rx) =
        handle.submit_with_id(vec![1, 2, 3], 1 << 40, SamplingParams::default());
    let rxs: Vec<_> =
        (0..5).map(|_| handle.submit(vec![1, 2], 4, SamplingParams::default())).collect();
    handle.cancel(long_id);
    assert_eq!(long_rx.recv().unwrap().finish, FinishReason::Cancelled);
    let resps: Vec<Response> = rxs.into_iter().map(|rx| rx.recv().unwrap()).collect();
    let rejected = resps.iter().filter(|r| r.finish == FinishReason::Rejected).count();
    let served = resps.iter().filter(|r| r.finish == FinishReason::Length).count();
    assert_eq!((rejected, served), (4, 1), "exactly one queues, four shed");
    let report = handle.metrics_report().unwrap();
    assert!(report.contains("failures"), "report must carry failure counters:\n{report}");
    assert!(report.contains("shed-rate"), "report must carry shed rate:\n{report}");
    handle.shutdown();
}
