//! Property-based tests (proptest is not in the offline vendor set —
//! this file carries a micro property-harness: seeded generators, N
//! cases, first-failure reporting with its seed for reproduction).

use quamba::coordinator::batcher;
use quamba::coordinator::state::SsmStatePool;
use quamba::config::TierInfo;
use quamba::quant;
use quamba::quant::hadamard;
use quamba::ssm::scan::{selective_scan, ScanParams};
use quamba::tensor::{qtz, DType, Tensor};
use quamba::util::json::{self, Json};
use quamba::util::rng::Pcg32;

/// Run `prop` over `n` seeded cases; panic with the failing seed.
fn forall<T: std::fmt::Debug>(
    name: &str,
    n: usize,
    gen: impl Fn(&mut Pcg32) -> T,
    prop: impl Fn(&T) -> bool,
) {
    for seed in 0..n as u64 {
        let mut rng = Pcg32::new(0xBEEF ^ seed);
        let case = gen(&mut rng);
        assert!(
            prop(&case),
            "property `{name}` failed at seed {seed}: {case:?}"
        );
    }
}

// ---------------------------------------------------------------------------
// batcher invariants (routing/batching state — the L3 contribution)
// ---------------------------------------------------------------------------

#[test]
fn prop_batcher_plan_covers_and_fits() {
    forall(
        "plan covers all requests with valid buckets",
        300,
        |r| {
            let n = 1 + r.below(40) as usize;
            // random sorted bucket subset of {1,2,4,8,16}
            let all = [1usize, 2, 4, 8, 16];
            let mut buckets: Vec<usize> =
                all.iter().filter(|_| r.f32() < 0.6).cloned().collect();
            if buckets.is_empty() {
                buckets.push(1);
            }
            (n, buckets)
        },
        |(n, buckets)| {
            let plan = batcher::plan_rounds(*n, buckets);
            let lanes: usize = plan.iter().sum();
            let groups = batcher::assign(*n, &plan);
            let covered: usize = groups.iter().map(|g| g.len()).sum();
            lanes >= *n
                && covered == *n
                && plan.iter().all(|b| buckets.contains(b))
                // waste bounded: padding < the largest bucket
                && lanes - *n < *buckets.last().unwrap()
        },
    );
}

#[test]
fn prop_batcher_exact_bucket_single_round() {
    forall(
        "n equal to a bucket size ⇒ exactly that one round",
        100,
        |r| [1usize, 2, 4, 8][r.below(4) as usize],
        |n| batcher::plan_rounds(*n, &[1, 2, 4, 8]) == vec![*n],
    );
}

#[test]
fn prop_batcher_zero_waste_with_unit_bucket() {
    // with a 1-bucket available every count is exactly composable, so
    // the minimum-padding planner must never pad at all
    forall(
        "bucket set containing 1 ⇒ zero padded lanes",
        200,
        |r| 1 + r.below(40) as usize,
        |n| {
            let plan = batcher::plan_rounds(*n, &[1, 2, 4, 8]);
            plan.iter().sum::<usize>() == *n
        },
    );
}

// ---------------------------------------------------------------------------
// state-pool invariants
// ---------------------------------------------------------------------------

fn tier(d_inner: usize, n_layer: usize) -> TierInfo {
    TierInfo {
        name: "t".into(),
        paper_name: "T".into(),
        d_model: d_inner / 2,
        n_layer,
        d_state: 4,
        d_conv: 4,
        d_inner,
        dt_rank: 1,
        vocab: 256,
        n_params: 0,
    }
}

#[test]
fn prop_state_pool_alloc_release_sequences() {
    forall(
        "random alloc/release keeps pool consistent",
        100,
        |r| {
            let ops: Vec<bool> = (0..60).map(|_| r.f32() < 0.6).collect();
            ops
        },
        |ops| {
            let t = tier(8, 2);
            let mut pool = SsmStatePool::new(&t, 8);
            let mut held: Vec<usize> = Vec::new();
            for &alloc in ops {
                if alloc {
                    if let Some(s) = pool.alloc() {
                        if held.contains(&s) {
                            return false; // double-grant
                        }
                        held.push(s);
                    } else if held.len() != 8 {
                        return false; // refused while capacity free
                    }
                } else if let Some(s) = held.pop() {
                    pool.release(s);
                }
                if pool.in_use() != held.len() {
                    return false;
                }
            }
            true
        },
    );
}

#[test]
fn prop_state_pool_conservation_holds_under_churn() {
    // the chaos suite calls `check_conservation()` after every engine
    // tick (ARCHITECTURE §7.4); this property pins the checker itself:
    // any interleaving of grants and releases keeps
    // free + in_use == capacity with a duplicate-free free list.
    forall(
        "check_conservation holds after every alloc/release",
        150,
        |r| {
            let cap = 1 + r.below(8) as usize;
            let ops: Vec<u32> = (0..48).map(|_| r.next_u32()).collect();
            (cap, ops)
        },
        |(cap, ops)| {
            let t = tier(8, 2);
            let mut pool = SsmStatePool::new(&t, *cap);
            let mut held: Vec<usize> = Vec::new();
            for &op in ops {
                if op % 2 == 0 {
                    if let Some(s) = pool.alloc() {
                        held.push(s);
                    }
                } else if !held.is_empty() {
                    let i = (op / 2) as usize % held.len();
                    pool.release(held.swap_remove(i));
                }
                if pool.check_conservation().is_err() || pool.in_use() != held.len() {
                    return false;
                }
            }
            for s in held.drain(..) {
                pool.release(s);
            }
            pool.check_conservation().is_ok() && pool.in_use() == 0
        },
    );
}

#[test]
fn prop_state_gather_scatter_roundtrip() {
    forall(
        "gather∘scatter is identity on live slots",
        60,
        |r| {
            let k = 1 + r.below(4) as usize;
            let b = [1usize, 2, 4, 8][r.below(4) as usize].max(k);
            let seed = r.next_u64();
            (k, b, seed)
        },
        |&(k, b, seed)| {
            let mut r = Pcg32::new(seed);
            let t = tier(16, 2);
            let mut pool = SsmStatePool::new(&t, 6);
            let mut slots = Vec::new();
            for _ in 0..k {
                let s = pool.alloc().unwrap();
                let mut slab = pool.get(s).clone();
                for v in slab.conv.iter_mut() {
                    *v = r.normal();
                }
                for v in slab.ssm.iter_mut() {
                    *v = r.normal();
                }
                pool.write(s, slab);
                slots.push(s);
            }
            let (conv, ssm) = pool.gather(&slots, b);
            let mut p2 = SsmStatePool::new(&t, 6);
            let d: Vec<usize> = slots.iter().map(|_| p2.alloc().unwrap()).collect();
            p2.scatter(&d, &conv, &ssm);
            slots
                .iter()
                .zip(&d)
                .all(|(s, dd)| p2.get(*dd).conv == pool.get(*s).conv
                    && p2.get(*dd).ssm == pool.get(*s).ssm)
        },
    );
}

// ---------------------------------------------------------------------------
// quantization properties
// ---------------------------------------------------------------------------

#[test]
fn prop_fake_quant_idempotent_and_bounded() {
    forall(
        "fake-quant is idempotent; error ≤ s/2",
        200,
        |r| {
            let n = 16 + r.below(256) as usize;
            let scale_mag = 10f32.powf(r.range_f32(-3.0, 3.0));
            let xs: Vec<f32> = (0..n).map(|_| r.normal() * scale_mag).collect();
            xs
        },
        |xs| {
            let s = quant::scale_sym(quant::amax(xs), 8);
            let mut once = xs.clone();
            quant::fake_quant_sym(&mut once, s, 8);
            let mut twice = once.clone();
            quant::fake_quant_sym(&mut twice, s, 8);
            once == twice
                && xs
                    .iter()
                    .zip(&once)
                    .all(|(a, b)| (a - b).abs() <= s * 0.5 + s * 1e-3)
        },
    );
}

#[test]
fn prop_percentile_monotone_and_below_amax() {
    forall(
        "percentile_amax monotone in p, ≤ amax",
        100,
        |r| (0..500).map(|_| r.normal() * 3.0).collect::<Vec<f32>>(),
        |xs| {
            let a = quant::amax(xs);
            let ps = [90.0, 99.0, 99.9, 100.0];
            let vals: Vec<f32> = ps.iter().map(|&p| quant::percentile_amax(xs, p)).collect();
            vals.windows(2).all(|w| w[0] <= w[1] + 1e-6) && vals[3] <= a + 1e-6
        },
    );
}

// ---------------------------------------------------------------------------
// Hadamard properties
// ---------------------------------------------------------------------------

#[test]
fn prop_fwht_roundtrip_all_model_dims() {
    forall(
        "ifwht(fwht(x)) == x for every tier dim",
        60,
        |r| {
            let dims = [64usize, 96, 128, 160, 192, 256, 320];
            let n = dims[r.below(dims.len() as u32) as usize];
            let xs: Vec<f32> = (0..n).map(|_| r.normal()).collect();
            xs
        },
        |xs| {
            let y = hadamard::fwht(xs);
            let back = hadamard::ifwht(&y);
            xs.iter().zip(&back).all(|(a, b)| (a - b).abs() < 1e-3)
        },
    );
}

// ---------------------------------------------------------------------------
// scan properties
// ---------------------------------------------------------------------------

#[test]
fn prop_scan_chunked_equals_full() {
    forall(
        "scan composability (prefill→decode chain)",
        40,
        |r| {
            let di = [2usize, 4, 8][r.below(3) as usize];
            let n = [2usize, 4][r.below(2) as usize];
            let t = 4 + r.below(20) as usize;
            let cut = 1 + r.below(t as u32 - 1) as usize;
            let a: Vec<f32> = (0..di * n).map(|_| -(r.f32() + 0.3)).collect();
            let d: Vec<f32> = (0..di).map(|_| r.normal()).collect();
            let x: Vec<f32> = (0..t * di).map(|_| r.normal()).collect();
            let dt: Vec<f32> = (0..t * di).map(|_| 0.01 + 0.3 * r.f32()).collect();
            let b: Vec<f32> = (0..t * n).map(|_| r.normal()).collect();
            let c: Vec<f32> = (0..t * n).map(|_| r.normal()).collect();
            (di, n, t, cut, a, d, x, dt, b, c)
        },
        |(di, n, t, cut, a, d, x, dt, b, c)| {
            let p = ScanParams { a, d, d_inner: *di, n_state: *n };
            let mut hf = vec![0.0; di * n];
            let yf = selective_scan(&p, x, dt, b, c, &mut hf);
            let mut hc = vec![0.0; di * n];
            let (xd, bd) = (cut * di, cut * n);
            let mut yc = selective_scan(&p, &x[..xd], &dt[..xd], &b[..bd], &c[..bd], &mut hc);
            yc.extend(selective_scan(&p, &x[xd..], &dt[xd..], &b[bd..], &c[bd..], &mut hc));
            let _ = t;
            yf.iter().zip(&yc).all(|(u, v)| (u - v).abs() < 1e-4)
                && hf.iter().zip(&hc).all(|(u, v)| (u - v).abs() < 1e-4)
        },
    );
}

#[test]
fn prop_scan_homogeneous_in_x() {
    forall(
        "y(αx) = α y(x) given fixed (Δ,B,C)",
        40,
        |r| {
            let alpha = r.range_f32(0.1, 5.0);
            let x: Vec<f32> = (0..8 * 4).map(|_| r.normal()).collect();
            let seed = r.next_u64();
            (alpha, x, seed)
        },
        |(alpha, x, seed)| {
            let mut r = Pcg32::new(*seed);
            let (di, n, t) = (4usize, 4usize, 8usize);
            let a: Vec<f32> = (0..di * n).map(|_| -(r.f32() + 0.3)).collect();
            let d: Vec<f32> = (0..di).map(|_| r.normal()).collect();
            let dt: Vec<f32> = (0..t * di).map(|_| 0.01 + 0.3 * r.f32()).collect();
            let b: Vec<f32> = (0..t * n).map(|_| r.normal()).collect();
            let c: Vec<f32> = (0..t * n).map(|_| r.normal()).collect();
            let p = ScanParams { a: &a, d: &d, d_inner: di, n_state: n };
            let mut h1 = vec![0.0; di * n];
            let y1 = selective_scan(&p, x, &dt, &b, &c, &mut h1);
            let xs: Vec<f32> = x.iter().map(|v| v * alpha).collect();
            let mut h2 = vec![0.0; di * n];
            let y2 = selective_scan(&p, &xs, &dt, &b, &c, &mut h2);
            y1.iter()
                .zip(&y2)
                .all(|(u, v)| (alpha * u - v).abs() < 1e-3 * (1.0 + v.abs()))
        },
    );
}

// ---------------------------------------------------------------------------
// container / JSON round-trips
// ---------------------------------------------------------------------------

#[test]
fn prop_qtz_roundtrip_random_tensors() {
    let dir = std::env::temp_dir().join("quamba_prop_qtz");
    std::fs::create_dir_all(&dir).unwrap();
    forall(
        "qtz save/load identity",
        30,
        |r| {
            let k = 1 + r.below(5) as usize;
            let mut entries = Vec::new();
            for i in 0..k {
                let dims: Vec<usize> = (0..1 + r.below(3)).map(|_| 1 + r.below(6) as usize).collect();
                let n: usize = dims.iter().product();
                let t = match r.below(3) {
                    0 => Tensor::from_f32(&dims, &(0..n).map(|_| r.normal()).collect::<Vec<_>>()),
                    1 => Tensor::from_i8(&dims, &(0..n).map(|_| (r.below(255) as i32 - 128) as i8).collect::<Vec<_>>()),
                    _ => Tensor::from_u16(&dims, &(0..n).map(|_| r.below(65535) as u16).collect::<Vec<_>>()),
                };
                entries.push((format!("tensor.{i}"), t));
            }
            (entries, r.next_u64())
        },
        |(entries, tag)| {
            let p = dir.join(format!("t{tag}.qtz"));
            qtz::save(&p, entries).unwrap();
            let f = qtz::load(&p).unwrap();
            let _ = std::fs::remove_file(&p);
            entries.iter().all(|(name, t)| f.get(name) == Some(t))
        },
    );
}

#[test]
fn prop_json_roundtrip_random_values() {
    fn gen_json(r: &mut Pcg32, depth: usize) -> Json {
        match if depth > 2 { r.below(4) } else { r.below(6) } {
            0 => Json::Null,
            1 => Json::Bool(r.f32() < 0.5),
            2 => Json::Num((r.normal() * 100.0).round() as f64 / 4.0),
            3 => Json::Str(format!("s{}-\"quote\\n{}", r.below(100), r.below(10))),
            4 => Json::Arr((0..r.below(4)).map(|_| gen_json(r, depth + 1)).collect()),
            _ => Json::Obj(
                (0..r.below(4))
                    .map(|i| (format!("k{i}"), gen_json(r, depth + 1)))
                    .collect(),
            ),
        }
    }
    forall(
        "json write∘parse identity",
        200,
        |r| gen_json(r, 0),
        |v| json::parse(&json::write(v)).as_ref() == Ok(v),
    );
}

// ---------------------------------------------------------------------------
// Tensor invariants used by the runtime bridge
// ---------------------------------------------------------------------------

#[test]
fn prop_tensor_f32_bytes_roundtrip() {
    forall(
        "tensor to_f32 inverts from_f32",
        100,
        |r| (0..1 + r.below(64) as usize).map(|_| r.normal() * 1e3).collect::<Vec<f32>>(),
        |v| Tensor::from_f32(&[v.len()], v).to_f32() == *v,
    );
}

#[test]
fn prop_zeros_are_zero() {
    forall(
        "Tensor::zeros yields all-zero views",
        20,
        |r| 1 + r.below(100) as usize,
        |n| {
            Tensor::zeros(DType::F32, &[*n]).to_f32().iter().all(|v| *v == 0.0)
                && Tensor::zeros(DType::I8, &[*n]).to_i8().iter().all(|v| *v == 0)
        },
    );
}
