//! ISSUE 2 acceptance: `QuantizedMambaModel::step_into` performs ZERO
//! heap allocations per call once the [`StepScratch`] has warmed up —
//! and (ISSUE 3) not just for power-of-two `d_inner`: the Paley-base
//! 12·2^k tier is held to the same standard now that each layer caches
//! its `FwhtPlan` (base matrix + stack temp instead of per-call Vecs).
//! ISSUE 8 widens the contract to the W4A8 packed-nibble tier: its
//! grouped GEMM accumulates into stack tiles, so 4-bit step AND
//! chunked batched prefill are held to the same zero-alloc standard.
//!
//! Measured with a counting `#[global_allocator]` wrapper around the
//! system allocator. The counter is thread-local (const-initialized,
//! so reading it never allocates or recurses) — the test harness's
//! other threads cannot perturb the measurement, and the model runs
//! single-threaded (`threads = 1`), so every allocation it would make
//! lands on this thread's counter.

use std::alloc::{GlobalAlloc, Layout, System};
use std::cell::Cell;

use quamba::ssm::{
    MambaModel, MambaState, MambaTier, QuantConfig, QuantizedMambaModel, StepModel, StepScratch,
};

std::thread_local! {
    static ALLOC_COUNT: Cell<u64> = const { Cell::new(0) };
}

struct CountingAlloc;

// explicit `unsafe` blocks keep this valid under editions where
// unsafe-op-in-unsafe-fn is denied; the allow covers older editions
// where the blocks are redundant
#[allow(unused_unsafe)]
unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, l: Layout) -> *mut u8 {
        ALLOC_COUNT.with(|c| c.set(c.get() + 1));
        unsafe { System.alloc(l) }
    }
    unsafe fn alloc_zeroed(&self, l: Layout) -> *mut u8 {
        ALLOC_COUNT.with(|c| c.set(c.get() + 1));
        unsafe { System.alloc_zeroed(l) }
    }
    unsafe fn realloc(&self, p: *mut u8, l: Layout, new_size: usize) -> *mut u8 {
        ALLOC_COUNT.with(|c| c.set(c.get() + 1));
        unsafe { System.realloc(p, l, new_size) }
    }
    unsafe fn dealloc(&self, p: *mut u8, l: Layout) {
        unsafe { System.dealloc(p, l) }
    }
}

#[global_allocator]
static ALLOCATOR: CountingAlloc = CountingAlloc;

fn allocs_on_this_thread() -> u64 {
    ALLOC_COUNT.with(|c| c.get())
}

fn tier() -> MambaTier {
    MambaTier {
        name: "alloc".into(),
        d_model: 16,
        n_layer: 2,
        d_state: 4,
        d_conv: 4,
        // power of two: the butterfly-only FWHT path
        d_inner: 32,
        dt_rank: 4,
        vocab: 32,
    }
}

fn paley_tier() -> MambaTier {
    MambaTier {
        name: "alloc12".into(),
        d_model: 16,
        n_layer: 2,
        d_state: 4,
        d_conv: 4,
        // 48 = 12·2^2: the Paley-base FWHT path — the per-layer
        // FwhtPlan (cached base matrix, stack temp) keeps it zero-alloc
        d_inner: 48,
        dt_rank: 4,
        vocab: 32,
    }
}

fn quantized_model(t: &MambaTier, weight_bits: u8) -> QuantizedMambaModel {
    let model = MambaModel::synthetic(t.clone(), 7);
    let calib: Vec<u16> = (0..256u16).map(|i| i % t.vocab as u16).collect();
    let cfg = QuantConfig { weight_bits, ..QuantConfig::default() };
    QuantizedMambaModel::from_model(&model, &calib, &cfg)
}

fn assert_quantized_step_zero_alloc(t: &MambaTier, weight_bits: u8) {
    let qm = quantized_model(t, weight_bits);
    let b = 4usize;
    let mut st = MambaState::new_quantized(t, b);
    let mut scratch = StepScratch::new(1);
    let mut logits = Vec::new();
    let toks: Vec<u16> = (0..b as u16).collect();
    // warmup: scratch + logits grow to their steady-state capacity
    for _ in 0..3 {
        qm.step_into(&toks, &mut st, &mut scratch, &mut logits);
    }
    let before = allocs_on_this_thread();
    for _ in 0..16 {
        qm.step_into(&toks, &mut st, &mut scratch, &mut logits);
    }
    let after = allocs_on_this_thread();
    assert_eq!(
        after - before,
        0,
        "tier {}: W{}A8 step_into heap-allocated {} time(s) across 16 post-warmup calls",
        t.name,
        weight_bits,
        after - before
    );
}

#[test]
fn w8a8_step_is_allocation_free_after_warmup() {
    assert_quantized_step_zero_alloc(&tier(), 8);
}

#[test]
fn w8a8_step_is_allocation_free_for_paley_base_d_inner() {
    // ISSUE 3 satellite (ROADMAP item): the 12·2^k tier used to
    // allocate its Hadamard base matrix + temp inside fwht_rows every
    // step; the cached per-layer FwhtPlan removes that
    assert_quantized_step_zero_alloc(&paley_tier(), 8);
}

#[test]
fn w4a8_step_is_allocation_free_after_warmup() {
    // ISSUE 8 satellite: the packed-nibble tier accumulates into stack
    // tiles inside `matmul_w4a8_with` — no i32 scratch Vec at all, so
    // the decode step stays zero-alloc on both FWHT paths
    assert_quantized_step_zero_alloc(&tier(), 4);
    assert_quantized_step_zero_alloc(&paley_tier(), 4);
}

fn assert_quantized_batched_prefill_zero_alloc(weight_bits: u8) {
    // ISSUE 5 acceptance (and the ISSUE 8 W4A8 twin): the unified
    // scheduler's (B, T) batched chunk prefill executes out of the
    // caller's scratch — once buffers have peaked at B·T_max rows,
    // advancing in-flight prompts chunk by chunk costs zero heap
    // allocations (ragged pads included)
    let t = tier();
    let qm = quantized_model(&t, weight_bits);
    let b = 3usize;
    let mut st = MambaState::new_quantized(&t, b);
    let mut scratch = StepScratch::new(1);
    let mut logits = Vec::new();
    // ragged chunk shapes held fixed across rounds (the scheduler pads
    // lanes to the chunk grid)
    let c0: Vec<u16> = (0..7u16).map(|i| i % t.vocab as u16).collect();
    let c1: Vec<u16> = (0..4u16).collect();
    let c2: Vec<u16> = (0..7u16).rev().collect();
    let chunks: Vec<&[u16]> = vec![&c0, &c1, &c2];
    for _ in 0..3 {
        qm.prefill_batch_into(&chunks, &mut st, &mut scratch, &mut logits);
    }
    let before = allocs_on_this_thread();
    for _ in 0..8 {
        qm.prefill_batch_into(&chunks, &mut st, &mut scratch, &mut logits);
    }
    let after = allocs_on_this_thread();
    assert_eq!(
        after - before,
        0,
        "W{}A8 chunked (B,T) prefill heap-allocated {} time(s) across 8 post-warmup rounds",
        weight_bits,
        after - before
    );
}

#[test]
fn w8a8_chunked_batched_prefill_is_allocation_free_after_warmup() {
    assert_quantized_batched_prefill_zero_alloc(8);
}

#[test]
fn w4a8_chunked_batched_prefill_is_allocation_free_after_warmup() {
    assert_quantized_batched_prefill_zero_alloc(4);
}

#[test]
fn trace_ring_recording_is_allocation_free_after_warmup() {
    // ISSUE 9 overhead contract: the flight recorder preallocates its
    // whole ring at construction, so recording a span — including
    // wrapping around and overwriting the oldest records — never
    // touches the heap
    use quamba::obs::{SpanKind, SpanRecord, TraceRing, NO_REQ};
    let mut ring = TraceRing::new(256);
    let span = |i: u64| SpanRecord {
        kind: SpanKind::DecodeRound,
        tick: i,
        start_ms: i as f64,
        end_ms: i as f64 + 0.5,
        req_id: NO_REQ,
        tokens: 4,
        lanes: 4,
    };
    // warmup (the ring is prefilled at new(), but hold the same
    // measurement shape as the other tests)
    for i in 0..8 {
        ring.record(span(i));
    }
    let before = allocs_on_this_thread();
    // 1024 records through a 256-slot ring: crosses the wrap point
    // many times over
    for i in 0..1024 {
        ring.record(span(i));
    }
    let after = allocs_on_this_thread();
    assert_eq!(
        after - before,
        0,
        "TraceRing::record heap-allocated {} time(s) across 1024 post-warmup records",
        after - before
    );
    assert_eq!(ring.iter().count(), 256, "ring retains exactly its capacity");
}

#[test]
fn fp32_step_is_allocation_free_after_warmup() {
    // the fp32 reference shares the scratch design; hold it to the
    // same standard so regressions can't hide behind the quantized test
    let t = tier();
    let model = MambaModel::synthetic(t.clone(), 9);
    let b = 3usize;
    let mut st = MambaState::new(&t, b);
    let mut scratch = StepScratch::new(1);
    let mut logits = Vec::new();
    let toks: Vec<u16> = (0..b as u16).collect();
    for _ in 0..3 {
        model.step_into(&toks, &mut st, &mut scratch, &mut logits);
    }
    let before = allocs_on_this_thread();
    for _ in 0..16 {
        model.step_into(&toks, &mut st, &mut scratch, &mut logits);
    }
    let after = allocs_on_this_thread();
    assert_eq!(
        after - before,
        0,
        "fp32 step_into heap-allocated {} time(s) across 16 post-warmup calls",
        after - before
    );
}
