//! Exhaustive interleaving models of the threaded serving core
//! (driven by `quamba::util::interleave` — see its module docs for
//! what this does and does not prove; the CI TSan job covers the
//! memory-model side on the real `std::thread` code).
//!
//! Three models, each paired with a deliberately broken variant that
//! the explorer must catch — proving the model actually constrains
//! the property, not just happens to pass:
//!
//! * **A — lane-split decode** (`ssm/qmamba.rs::par_lane_chunks`):
//!   workers sweep disjoint lane chunks, the main thread commits only
//!   after all workers finish; result must be bit-identical to the
//!   sequential sweep and each lane written exactly once. Broken
//!   variant: overlapping chunk bounds.
//! * **B — engine mailbox** (`coordinator/engine.rs`): clients submit,
//!   the engine tick runs admit → decode → harvest; every submitted id
//!   is harvested exactly once, whatever the submit/tick interleaving.
//!   Broken variant: harvest runs before decode inside a tick, so a
//!   late admit is never decoded.
//! * **C — snapshot consistency** (`coordinator/state.rs::snapshot`):
//!   a decode step writes its conv window and ssm state as two
//!   sub-steps; snapshots are only legal on the even boundary. Broken
//!   variant: snapshot enabled mid-step captures a torn state.
//! * **D — cancel vs harvest** (`coordinator/server.rs::Msg::Cancel` +
//!   `native.rs::cancel`): a client cancel races the engine's own
//!   admit → decode → harvest progression through the mailbox. The
//!   waiter must receive exactly one response, and the state-pool slot
//!   must be released exactly as many times as it was allocated,
//!   whenever the cancel lands — before admission, mid-flight, or
//!   after the natural finish (where it must degrade to a no-op, the
//!   `cancel` returns-`None` path). Broken variant: a phase-blind
//!   cancel that always frees + responds, double-answering a finished
//!   request and freeing a slot that was never allocated.

use std::panic::{catch_unwind, AssertUnwindSafe};

use quamba::util::interleave::{explore, Model};

fn panic_msg(err: Box<dyn std::any::Any + Send>) -> String {
    err.downcast_ref::<String>()
        .cloned()
        .or_else(|| err.downcast_ref::<&str>().map(|s| s.to_string()))
        .unwrap_or_default()
}

// ==== model A: lane-split decode ====================================

const LANES: usize = 4;

/// The per-lane "decode" the workers and the sequential reference both
/// apply — any injective-enough function works; the check is
/// bit-identity, not numerics.
fn lane_decode(lane: usize, v: i32) -> i32 {
    v * 31 + lane as i32 + 1
}

#[derive(Clone)]
struct LaneState {
    lanes: [i32; LANES],
    writes: [u32; LANES],
    worker_done: [bool; 2],
    committed: bool,
}

/// Two workers over chunk bounds + a main commit thread gated on both.
struct LaneSplit {
    /// half-open chunk [start, end) per worker
    chunks: [(usize, usize); 2],
}

impl Model for LaneSplit {
    type State = LaneState;

    fn init(&self) -> LaneState {
        LaneState {
            lanes: [10, 20, 30, 40],
            writes: [0; LANES],
            worker_done: [false; 2],
            committed: false,
        }
    }

    /// threads 0,1 = workers (one step: sweep own chunk); thread 2 =
    /// main (one step: commit)
    fn thread_steps(&self) -> Vec<usize> {
        vec![1, 1, 1]
    }

    fn enabled(&self, st: &LaneState, t: usize, _step: usize) -> bool {
        // main blocks on the scoped-join: both workers done
        t < 2 || (st.worker_done[0] && st.worker_done[1])
    }

    fn step(&self, st: &mut LaneState, t: usize, _step: usize) {
        if t < 2 {
            let (lo, hi) = self.chunks[t];
            for lane in lo..hi {
                st.lanes[lane] = lane_decode(lane, st.lanes[lane]);
                st.writes[lane] += 1;
            }
            st.worker_done[t] = true;
        } else {
            st.committed = true;
        }
    }

    fn check_final(&self, st: &LaneState) {
        assert!(st.committed);
        // bit-identity to the sequential sweep
        let mut want = [10, 20, 30, 40];
        for (lane, w) in want.iter_mut().enumerate() {
            *w = lane_decode(lane, *w);
        }
        assert_eq!(st.lanes, want, "lane-split result differs from sequential sweep");
        assert_eq!(st.writes, [1; LANES], "each lane must be written exactly once");
    }
}

#[test]
fn lane_split_decode_is_bit_identical_under_all_schedules() {
    let ex = explore(&LaneSplit { chunks: [(0, 2), (2, 4)] });
    // workers in either order, commit always last: 2 schedules
    assert_eq!(ex.executions, 2);
}

#[test]
fn overlapping_lane_chunks_are_caught() {
    let err = catch_unwind(AssertUnwindSafe(|| {
        explore(&LaneSplit { chunks: [(0, 3), (1, 4)] })
    }))
    .expect_err("overlapping chunks double-write lanes 1..3");
    let msg = panic_msg(err);
    assert!(msg.contains("exactly once") || msg.contains("sequential sweep"), "got: {msg}");
}

// ==== model B: engine mailbox =======================================

const CLIENTS: usize = 2;

#[derive(Clone, Default)]
struct EngineState {
    queue: Vec<usize>,   // submitted, not yet admitted
    active: Vec<usize>,  // admitted, not yet decoded
    outputs: Vec<usize>, // decoded, not yet harvested
    harvested: Vec<usize>,
}

/// Clients are one-step submitters; the engine runs `ticks` ticks.
/// `harvest_before_decode` seeds the broken variant.
struct Mailbox {
    ticks: usize,
    harvest_before_decode: bool,
}

impl Mailbox {
    fn all_harvested(st: &EngineState) -> bool {
        st.harvested.len() == CLIENTS && st.queue.is_empty() && st.active.is_empty() && st.outputs.is_empty()
    }
}

impl Model for Mailbox {
    type State = EngineState;

    fn init(&self) -> EngineState {
        EngineState::default()
    }

    /// threads 0..CLIENTS = clients (one submit each); last = engine
    fn thread_steps(&self) -> Vec<usize> {
        let mut v = vec![1; CLIENTS];
        v.push(self.ticks);
        v
    }

    fn enabled(&self, st: &EngineState, t: usize, _step: usize) -> bool {
        // the engine's recv blocks until work is pending — this gate
        // is what makes "tick before any submit" unschedulable, like
        // the real channel recv
        t < CLIENTS
            || !(st.queue.is_empty() && st.active.is_empty() && st.outputs.is_empty())
    }

    fn step(&self, st: &mut EngineState, t: usize, _step: usize) {
        if t < CLIENTS {
            st.queue.push(t);
            return;
        }
        if self.harvest_before_decode {
            // BROKEN: harvest precedes decode, so work admitted this
            // tick reaches `outputs` only on a *later* tick — the last
            // tick strands it there
            st.harvested.append(&mut st.outputs);
            st.active.append(&mut st.queue);
            st.outputs.append(&mut st.active);
        } else {
            // admit → decode → harvest, the real engine's tick order
            st.active.append(&mut st.queue);
            st.outputs.append(&mut st.active);
            st.harvested.append(&mut st.outputs);
        }
    }

    fn check_step(&self, st: &EngineState) {
        let mut seen = [false; CLIENTS];
        for &id in &st.harvested {
            assert!(!seen[id], "request {id} harvested twice");
            seen[id] = true;
        }
    }

    fn check_final(&self, st: &EngineState) {
        assert!(Self::all_harvested(st), "request stranded: {:?}", st.harvested);
    }

    fn quiescent_ok(&self, st: &EngineState, done: &[usize]) -> bool {
        // engine with spare ticks and an empty mailbox is legitimate
        // quiescence — but only once every submit has been harvested
        let clients_done = done[..CLIENTS].iter().all(|&d| d == 1);
        if !clients_done {
            return false;
        }
        assert!(
            Self::all_harvested(st),
            "engine went quiescent with work stranded: harvested {:?}, queue {:?}, \
             active {:?}, outputs {:?}",
            st.harvested,
            st.queue,
            st.active,
            st.outputs
        );
        true
    }
}

#[test]
fn every_submit_is_harvested_exactly_once() {
    let ex = explore(&Mailbox { ticks: CLIENTS, harvest_before_decode: false });
    assert!(ex.executions > 1, "gating collapsed the schedule space");
}

#[test]
fn harvest_before_decode_strands_requests() {
    let err = catch_unwind(AssertUnwindSafe(|| {
        explore(&Mailbox { ticks: CLIENTS, harvest_before_decode: true })
    }))
    .expect_err("mis-ordered tick must strand a request in some schedule");
    let msg = panic_msg(err);
    assert!(msg.contains("stranded") || msg.contains("deadlock"), "got: {msg}");
}

// ==== model C: snapshot consistency =================================

#[derive(Clone, Default)]
struct SnapState {
    conv: u32, // conv-window writes completed
    ssm: u32,  // ssm-state writes completed
    snapshots: Vec<(u32, u32)>,
}

/// One decode thread advancing `tokens` tokens, each as two sub-steps
/// (write conv window, then ssm state); one snapshot thread taking
/// `snaps` snapshots. `allow_torn` seeds the broken variant where the
/// snapshot does not wait for the token boundary.
struct Snapshotter {
    tokens: usize,
    snaps: usize,
    allow_torn: bool,
}

impl Model for Snapshotter {
    type State = SnapState;

    fn init(&self) -> SnapState {
        SnapState::default()
    }

    /// thread 0 = decode (2 sub-steps per token); thread 1 = snapshots
    fn thread_steps(&self) -> Vec<usize> {
        vec![2 * self.tokens, self.snaps]
    }

    fn enabled(&self, st: &SnapState, t: usize, _step: usize) -> bool {
        // the real pool snapshots only between step_into calls — model
        // that as "conv and ssm counts agree"; the broken variant
        // drops the gate
        t == 0 || self.allow_torn || st.conv == st.ssm
    }

    fn step(&self, st: &mut SnapState, t: usize, step: usize) {
        if t == 0 {
            if step % 2 == 0 {
                st.conv += 1;
            } else {
                st.ssm += 1;
            }
        } else {
            st.snapshots.push((st.conv, st.ssm));
        }
    }

    fn check_step(&self, st: &SnapState) {
        for &(c, s) in &st.snapshots {
            assert_eq!(c, s, "torn snapshot: conv window at token {c}, ssm state at {s}");
        }
    }

    fn check_final(&self, st: &SnapState) {
        assert_eq!(st.conv, self.tokens as u32);
        assert_eq!(st.ssm, self.tokens as u32);
        assert_eq!(st.snapshots.len(), self.snaps);
    }
}

#[test]
fn snapshots_on_token_boundaries_are_never_torn() {
    let ex = explore(&Snapshotter { tokens: 2, snaps: 2, allow_torn: false });
    assert!(ex.executions > 1);
}

#[test]
fn unguarded_snapshot_captures_torn_state() {
    let err = catch_unwind(AssertUnwindSafe(|| {
        explore(&Snapshotter { tokens: 2, snaps: 1, allow_torn: true })
    }))
    .expect_err("an ungated snapshot must land mid-token in some schedule");
    let msg = panic_msg(err);
    assert!(msg.contains("torn snapshot"), "got: {msg}");
}

// ==== model D: cancel vs harvest ====================================

/// Request lifecycle phases as the engine sees them.
const QUEUED: u8 = 0;
const LIVE: u8 = 1;
const RETIRED: u8 = 2; // harvested naturally or cancelled

#[derive(Clone, Default)]
struct CancelState {
    phase: u8,
    /// slot currently held by the request
    slot_held: bool,
    allocated: u32,
    released: u32,
    /// responses delivered to the waiter (harvest or cancel)
    responses: u32,
    cancel_pending: bool,
}

/// One client thread sends one cancel at an arbitrary point; the
/// engine drains the mailbox then advances the request one lifecycle
/// stage per tick (admit, then decode+harvest). `blind` seeds the
/// broken variant: a cancel handler that skips the phase check.
struct CancelRace {
    ticks: usize,
    blind: bool,
}

impl Model for CancelRace {
    type State = CancelState;

    fn init(&self) -> CancelState {
        CancelState::default()
    }

    /// thread 0 = client (one cancel); thread 1 = engine ticks
    fn thread_steps(&self) -> Vec<usize> {
        vec![1, self.ticks]
    }

    fn enabled(&self, st: &CancelState, t: usize, _step: usize) -> bool {
        // the engine's recv blocks when there is neither work nor mail
        t == 0 || st.phase != RETIRED || st.cancel_pending
    }

    fn step(&self, st: &mut CancelState, t: usize, _step: usize) {
        if t == 0 {
            st.cancel_pending = true;
            return;
        }
        // tick: mailbox first (mirrors the server loop), then progress
        if st.cancel_pending {
            st.cancel_pending = false;
            if self.blind {
                // BROKEN: phase-blind — frees and answers regardless
                // of whether the request was ever admitted or already
                // finished
                st.slot_held = false;
                st.released += 1;
                st.responses += 1;
                st.phase = RETIRED;
            } else {
                match st.phase {
                    QUEUED => {
                        // cancelled while queued: no slot to release
                        st.phase = RETIRED;
                        st.responses += 1;
                    }
                    LIVE => {
                        // the finish_live path: release + respond
                        st.slot_held = false;
                        st.released += 1;
                        st.phase = RETIRED;
                        st.responses += 1;
                    }
                    _ => {} // already finished: cancel is a no-op (None)
                }
            }
            return;
        }
        match st.phase {
            QUEUED => {
                st.phase = LIVE;
                st.slot_held = true;
                st.allocated += 1;
            }
            LIVE => {
                // natural finish through finish_live
                st.slot_held = false;
                st.released += 1;
                st.phase = RETIRED;
                st.responses += 1;
            }
            _ => {}
        }
    }

    fn check_step(&self, st: &CancelState) {
        assert!(st.responses <= 1, "waiter answered twice");
        assert!(st.released <= st.allocated, "released a slot that was never allocated");
        assert!(!(st.slot_held && st.phase == RETIRED), "retired request still holds its slot");
    }

    fn check_final(&self, st: &CancelState) {
        assert_eq!(st.phase, RETIRED);
        assert_eq!(st.responses, 1, "waiter must get exactly one response");
        assert!(!st.slot_held, "slot leaked");
        assert_eq!(st.released, st.allocated, "alloc/release imbalance");
    }

    fn quiescent_ok(&self, st: &CancelState, done: &[usize]) -> bool {
        // spare engine ticks once the request retired and the mailbox
        // drained are legitimate (the real loop blocks in recv) — but
        // only with the full final invariant already satisfied
        if done[0] != 1 {
            return false;
        }
        self.check_final(st);
        true
    }
}

#[test]
fn cancel_vs_harvest_delivers_exactly_one_response_in_all_schedules() {
    // 3 ticks cover: cancel-before-admit, cancel-mid-flight, and
    // cancel-after-finish (the no-op race from native.rs::cancel)
    let ex = explore(&CancelRace { ticks: 3, blind: false });
    assert!(ex.executions > 1, "gating collapsed the schedule space");
}

#[test]
fn phase_blind_cancel_double_frees_or_double_answers() {
    let err = catch_unwind(AssertUnwindSafe(|| explore(&CancelRace { ticks: 3, blind: true })))
        .expect_err("a blind cancel must double-answer or double-free in some schedule");
    let msg = panic_msg(err);
    assert!(
        msg.contains("answered twice") || msg.contains("never allocated"),
        "got: {msg}"
    );
}
