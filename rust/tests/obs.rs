//! Observability integration tests (ISSUE 9 / docs/ARCHITECTURE.md §8):
//! flight-recorder determinism under `Clock::Manual`, Chrome
//! trace-event dump shape, span/tick duration accounting under
//! `Clock::Wall`, trace on/off token parity, per-request timelines,
//! and the typed-metrics + Prometheus exporter path through the
//! server mailbox.

use std::io::{Read as _, Write as _};

use quamba::coordinator::server::ServerHandle;
use quamba::coordinator::{Clock, NativeEngine, NativeEngineConfig, Request, SamplingParams};
use quamba::obs::{MetricsExporter, SpanKind};
use quamba::ssm::{MambaModel, MambaTier, StepModel};
use quamba::util::rng::Pcg32;

fn obs_tier() -> MambaTier {
    MambaTier {
        name: "obs16".into(),
        d_model: 16,
        n_layer: 2,
        d_state: 4,
        d_conv: 4,
        d_inner: 32,
        dt_rank: 4,
        vocab: 32,
    }
}

fn model() -> Box<dyn StepModel + Send + Sync> {
    Box::new(MambaModel::synthetic(obs_tier(), 7))
}

/// Deterministic mixed workload: shortish prompts (so chunked
/// prefill emits several PrefillChunk spans) plus varying max_new.
fn workload(n: usize) -> Vec<(Vec<u16>, usize)> {
    let mut r = Pcg32::new(0x0B5);
    (0..n)
        .map(|i| {
            let len = 6 + (i % 3) * 5;
            let prompt = (0..len).map(|_| r.below(32) as u16).collect();
            (prompt, 4 + i % 4)
        })
        .collect()
}

fn manual_cfg() -> NativeEngineConfig {
    NativeEngineConfig {
        clock: Clock::Manual { ms_per_tick: 2.0 },
        trace: true,
        prefill_chunk: 4,
        cache_bytes: 1 << 20,
        snapshot_stride: 8,
        ..Default::default()
    }
}

/// Run the canonical workload to completion on a fresh engine.
fn run_manual(cfg: NativeEngineConfig) -> (NativeEngine, Vec<quamba::coordinator::Response>) {
    let mut eng = NativeEngine::new(model(), cfg);
    for (i, (prompt, max_new)) in workload(6).into_iter().enumerate() {
        eng.submit(Request {
            id: (i + 1) as u64,
            prompt,
            max_new_tokens: max_new,
            params: SamplingParams::default(),
            stop_at_eos: false,
        });
    }
    let mut resp = eng.run_to_completion().expect("run");
    resp.sort_by_key(|r| r.id);
    (eng, resp)
}

#[test]
fn manual_clock_traces_and_snapshots_are_deterministic() {
    // ISSUE 9 acceptance: two identically-seeded Clock::Manual runs
    // produce BYTE-identical trace dumps and equal typed snapshots
    let (a, ra) = run_manual(manual_cfg());
    let (b, rb) = run_manual(manual_cfg());
    let (da, db) = (a.dump_trace().expect("trace on"), b.dump_trace().expect("trace on"));
    assert!(!da.is_empty());
    assert_eq!(da, db, "trace dumps differ between identical Manual-clock runs");
    assert_eq!(a.metrics_snapshot(), b.metrics_snapshot());
    // and the workload itself was deterministic
    let toks = |rs: &[quamba::coordinator::Response]| {
        rs.iter().map(|r| r.tokens.clone()).collect::<Vec<_>>()
    };
    assert_eq!(toks(&ra), toks(&rb));
}

#[test]
fn chrome_trace_dump_has_the_documented_shape() {
    let (eng, _) = run_manual(manual_cfg());
    let dump = eng.dump_trace().expect("trace on");
    assert!(dump.starts_with("{\"displayTimeUnit\":\"ms\",\"traceEvents\":["), "{dump:.120}");
    assert!(dump.ends_with("]}\n"), "dump must be a newline-terminated JSON object");
    // complete events + per-kind thread metadata
    assert!(dump.contains("\"ph\":\"X\""));
    assert!(dump.contains("\"ph\":\"M\""));
    for kind in SpanKind::all() {
        assert!(dump.contains(kind.name()), "missing {} events/metadata", kind.name());
    }
    // ts/dur are microseconds — a 2 ms Manual tick must show up as 2000
    assert!(dump.contains("\"ts\":"));
    assert!(dump.contains("\"dur\":"));
}

#[test]
fn span_rows_nest_inside_their_tick_and_sum_within_it() {
    // duration accounting under the REAL clock: every phase span lies
    // inside its tick's [start, end], and per tick the phase durations
    // sum to no more than the measured tick wall time (the phases are
    // disjoint sequential sections of step())
    let cfg = NativeEngineConfig { clock: Clock::Wall, ..manual_cfg() };
    let (eng, _) = run_manual(cfg);
    let ring = eng.trace_ring().expect("trace on");
    let spans: Vec<_> = ring.iter().copied().collect();
    assert!(!spans.is_empty());
    let ticks: Vec<_> = spans.iter().filter(|s| s.kind == SpanKind::Tick).collect();
    assert!(!ticks.is_empty(), "no tick spans recorded");
    let mut phases_seen = 0usize;
    for t in &ticks {
        let children: Vec<_> =
            spans.iter().filter(|s| s.tick == t.tick && s.kind != SpanKind::Tick).collect();
        let mut sum = 0.0;
        for c in &children {
            assert!(
                c.start_ms >= t.start_ms - 1e-6 && c.end_ms <= t.end_ms + 1e-6,
                "{:?} span [{:.4}, {:.4}] escapes tick {} [{:.4}, {:.4}]",
                c.kind,
                c.start_ms,
                c.end_ms,
                t.tick,
                t.start_ms,
                t.end_ms
            );
            sum += c.duration_ms();
        }
        phases_seen += children.len();
        // bookkeeping slack: the tick also spends (unspanned) time in
        // scheduling glue, so children can only undershoot — allow a
        // hair of float noise on top
        assert!(
            sum <= t.duration_ms() + 0.5,
            "phase spans sum to {sum:.4} ms > tick {} duration {:.4} ms",
            t.tick,
            t.duration_ms()
        );
    }
    assert!(phases_seen > 0, "ticks recorded but no phase spans at all");
}

#[test]
fn tokens_are_identical_with_tracing_on_and_off() {
    let on = manual_cfg();
    let off = NativeEngineConfig { trace: false, ..manual_cfg() };
    let (eng_off, r_off) = run_manual(off);
    let (_, r_on) = run_manual(on);
    assert!(eng_off.dump_trace().is_none(), "trace off must dump None");
    assert_eq!(
        r_on.iter().map(|r| r.tokens.clone()).collect::<Vec<_>>(),
        r_off.iter().map(|r| r.tokens.clone()).collect::<Vec<_>>(),
        "tracing must never move tokens"
    );
}

#[test]
fn per_request_timelines_are_ordered() {
    let (_, responses) = run_manual(manual_cfg());
    assert!(!responses.is_empty());
    for r in &responses {
        assert!(r.finish.is_ok(), "{:?}", r.finish);
        assert!(r.queued_ms <= r.admitted_ms, "{}", r.timeline());
        assert!(r.admitted_ms <= r.first_token_ms, "{}", r.timeline());
        assert!(r.first_token_ms <= r.finished_ms, "{}", r.timeline());
        // the printable line carries all four stamps
        let line = r.timeline();
        for key in ["queued=", "admitted=", "first-token=", "finished="] {
            assert!(line.contains(key), "{line}");
        }
    }
}

/// End-to-end mailbox + exporter path: a native server behind
/// `ServerHandle`, typed snapshots over the channel, a live HTTP
/// scrape of `/metrics`, and the trace dump through `Msg::DumpTrace`.
#[test]
fn server_snapshot_trace_and_live_scrape() {
    let cfg = NativeEngineConfig { trace: true, prefill_chunk: 4, ..Default::default() };
    let mut server = ServerHandle::spawn_native(model(), cfg).expect("spawn");
    let rxs: Vec<_> = workload(4)
        .into_iter()
        .map(|(prompt, max_new)| server.submit(prompt, max_new, SamplingParams::default()))
        .collect();
    let responses: Vec<_> = rxs.into_iter().map(|rx| rx.recv().expect("response")).collect();
    assert!(responses.iter().all(|r| r.finish.is_ok()));

    // typed snapshot over the mailbox
    let snap = server.metrics_snapshot().expect("native engine snapshots");
    assert!(snap.tokens_out > 0);
    assert_eq!(snap.requests_done, responses.len() as u64);
    assert!(snap.tick_ms.count > 0, "tick histogram empty");

    // trace dump over the mailbox
    let dump = server.dump_trace().expect("trace was enabled");
    assert!(dump.contains("\"traceEvents\""));

    // live scrape through a real TCP socket on an ephemeral port
    let labels = quamba::obs::ExporterLabels {
        backend: "native".into(),
        kernels: "test".into(),
        weight_bits: "32".into(),
    };
    let mut exp = MetricsExporter::spawn(0, labels, server.snapshot_fetch()).expect("bind");
    let mut conn =
        std::net::TcpStream::connect(("127.0.0.1", exp.port())).expect("connect exporter");
    conn.write_all(b"GET /metrics HTTP/1.1\r\nHost: localhost\r\n\r\n").expect("send");
    let mut body = String::new();
    let _ = conn.read_to_string(&mut body);
    assert!(body.starts_with("HTTP/1.1 200 OK"), "{body:.200}");
    assert!(body.contains("quamba_tokens_generated_total"), "{body}");
    let tokens: f64 = body
        .lines()
        .find(|l| l.starts_with("quamba_tokens_generated_total{"))
        .and_then(|l| l.rsplit(' ').next())
        .and_then(|v| v.parse().ok())
        .expect("token counter line");
    assert!(tokens > 0.0, "scrape shows zero generated tokens:\n{body}");
    assert!(body.contains("quamba_ttft_ms_bucket"), "{body}");
    assert!(body.contains("le=\"+Inf\""), "{body}");
    exp.stop();
    server.shutdown();
}
