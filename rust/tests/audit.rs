//! `quamba-audit` integration tests: the real tree must come back
//! clean, and each seeded-violation fixture must make the auditor
//! fail — both through the rule functions directly and through the
//! end-to-end `audit_repo` path on a synthesized crate tree. A
//! scanner that rots into accepting everything fails these the same
//! way a rotted tree fails the clean check.

use std::path::{Path, PathBuf};

use quamba::audit::{self, rules, scales, shapes};

/// Walk up from the test binary's cwd to the first dir that holds a
/// crate source root (handles `cargo test` from rust/ or the repo).
fn repo_root() -> PathBuf {
    let mut dir = std::env::current_dir().expect("cwd");
    loop {
        if audit::find_src_root(&dir).is_some() {
            return dir;
        }
        assert!(dir.pop(), "no crate source root above the test cwd");
    }
}

#[test]
fn tree_is_clean() {
    let report = audit::audit_repo(&repo_root()).expect("audit runs");
    for f in &report.findings {
        eprintln!("{f}");
    }
    assert!(report.ok(), "{} finding(s) in the tree", report.findings.len());
    // coverage floors: if the walker breaks and scans nothing, "clean"
    // would be vacuous
    assert!(report.files_scanned >= 40, "only {} files scanned", report.files_scanned);
    assert!(report.tiers_checked >= 10, "only {} tier literals", report.tiers_checked);
    assert_eq!(report.scales_checked, 11, "QLayer has 10 s_* scales + model s_head_in");
}

// ---- seeded violations, rule-level ---------------------------------

#[test]
fn fixture_missing_safety_comment_fails() {
    let txt = include_str!("fixtures/audit/missing_safety.rs.txt");
    let fs = rules::scan_kernels(rules::KERNELS_FILE, txt);
    assert!(
        fs.iter().any(|f| f.rule == "safety-comment"),
        "missing SAFETY comment not flagged: {fs:?}"
    );
}

#[test]
fn fixture_bad_target_feature_fails() {
    let txt = include_str!("fixtures/audit/bad_target_feature.rs.txt");
    let fs = rules::scan_kernels(rules::KERNELS_FILE, txt);
    assert!(
        fs.iter().any(|f| f.rule == "target-feature"),
        "sse2-in-avx2-module not flagged: {fs:?}"
    );
}

#[test]
fn fixture_unsafe_outside_kernels_fails() {
    let txt = include_str!("fixtures/audit/unsafe_outside_kernels.rs.txt");
    let fs = rules::scan_source_file("ssm/evil.rs", txt);
    assert!(
        fs.iter().any(|f| f.rule == "unsafe-confinement"),
        "escaped unsafe not flagged: {fs:?}"
    );
}

#[test]
fn fixture_bad_k_shape_fails() {
    let txt = include_str!("fixtures/audit/bad_k_shape.rs.txt");
    let tiers = shapes::collect_tier_literals("ssm/evil.rs", txt);
    assert_eq!(tiers.len(), 1, "fixture tier literal not collected");
    let fs = shapes::check_tier(&tiers[0]);
    assert!(
        fs.iter().any(|f| f.rule == "k-bound" && f.message.contains("d_model")),
        "out-of-bound d_model not flagged: {fs:?}"
    );
}

#[test]
fn fixture_unbalanced_scale_fails() {
    let txt = include_str!("fixtures/audit/unbalanced_scale.rs.txt");
    let (fs, n) = scales::audit_scales("ssm/qmamba.rs", txt);
    assert_eq!(n, 3, "fixture declares s_xin, s_x, s_head_in");
    assert!(
        fs.iter()
            .any(|f| f.rule == "scale-flow" && f.message.contains("s_x") && f.message.contains("step_into")),
        "unconsumed s_x not flagged: {fs:?}"
    );
}

#[test]
fn fixture_bare_cast_fails() {
    let txt = include_str!("fixtures/audit/bare_cast.rs.txt");
    let fs = rules::scan_source_file("quant/evil.rs", txt);
    let casts = fs.iter().filter(|f| f.rule == "bare-cast").count();
    assert_eq!(casts, 2, "both the `as i8` and the `as f32 *` must flag: {fs:?}");
}

#[test]
fn fixture_w4a8_guard_with_wrong_bound_fails() {
    // ISSUE 8: the qlinear guard map now carries (fn, bound) pairs —
    // a w4a8 entry point guarded with the i8 bound must flag, while
    // the correctly guarded i8 entry point in the same file stays clean
    let txt = include_str!("fixtures/audit/w4a8_wrong_bound.rs.txt");
    let entries = rules::guarded_entry_points("quant/qlinear.rs");
    assert_eq!(entries.len(), 2, "qlinear carries both tier entry points");
    for (fn_name, bound) in entries {
        let fs = rules::check_guard_present("quant/qlinear.rs", txt, fn_name, bound);
        if *fn_name == "matmul_w4a8_with" {
            assert_eq!(fs.len(), 1, "wrong-bound w4a8 guard not flagged: {fs:?}");
            assert_eq!(fs[0].rule, "accumulator-bound");
            assert!(fs[0].message.contains("MAX_SAFE_K_I4"), "{}", fs[0].message);
        } else {
            assert!(fs.is_empty(), "i8 path wrongly flagged: {fs:?}");
        }
    }
}

#[test]
fn missing_i4_const_proof_fails() {
    // a kernels module that only proves the i8 tier must flag both
    // missing i4 constants
    let txt = "pub const MAX_ABS_PROD_I8: i64 = 1 << 14;\n\
               pub const MAX_SAFE_K: usize = 131071;\n\
               const _: () = assert!(true);\n";
    let fs = rules::check_const_proof("quant/kernels.rs", txt);
    assert_eq!(fs.len(), 2, "{fs:?}");
    assert!(fs.iter().all(|f| f.rule == "const-proof"));
    assert!(fs.iter().any(|f| f.message.contains("MAX_ABS_PROD_I4I8")), "{fs:?}");
    assert!(fs.iter().any(|f| f.message.contains("MAX_SAFE_K_I4")), "{fs:?}");
}

#[test]
fn fixture_native_leaky_release_fails() {
    let txt = include_str!("fixtures/audit/native_leaky_release.rs.txt");
    let fs = rules::scan_native_engine(rules::NATIVE_FILE, txt);
    assert!(
        fs.iter().any(|f| f.rule == "engine-no-unwrap"),
        "admission-path .expect() not flagged: {fs:?}"
    );
    assert!(
        fs.iter().any(|f| f.rule == "slot-reclaim" && f.line > 0),
        "release outside finish_live not flagged: {fs:?}"
    );
    // exactly the two step() sites fire — the confined swap_remove +
    // release inside finish_live itself must stay clean
    assert_eq!(
        fs.iter().filter(|f| f.rule == "slot-reclaim").count(),
        2,
        "confined reclaim inside finish_live wrongly flagged: {fs:?}"
    );
}

#[test]
fn fixture_clock_discipline_fails() {
    let txt = include_str!("fixtures/audit/clock_discipline.rs.txt");
    let fs = rules::scan_clock_discipline("coordinator/evil_clock.rs", txt);
    // exactly the two raw reads — the string mention and the
    // test-region read must stay exempt
    assert_eq!(fs.len(), 2, "{fs:?}");
    assert!(fs.iter().all(|f| f.rule == "clock-discipline"));
    assert!(fs.iter().any(|f| f.message.contains("Instant::now")), "{fs:?}");
    assert!(fs.iter().any(|f| f.message.contains("SystemTime::now")), "{fs:?}");
    // the sanctioned implementation file itself is exempt by
    // registration (audit_repo skips CLOCK_FILE), not by content —
    // prove the registration guard matters
    assert_eq!(rules::CLOCK_FILE, "coordinator/faults.rs");
}

#[test]
fn native_engine_without_reclaim_point_is_whole_file_violation() {
    let fs = rules::scan_native_engine(
        rules::NATIVE_FILE,
        "pub fn harvest(pool: &mut Pool) {\n    pool.release(0);\n}\n",
    );
    assert!(
        fs.iter().any(|f| f.rule == "slot-reclaim" && f.line == 0),
        "missing finish_live not reported as whole-file finding: {fs:?}"
    );
    assert!(
        fs.iter().any(|f| f.rule == "slot-reclaim" && f.line == 2),
        "stray release not flagged when finish_live is absent: {fs:?}"
    );
}

// ---- seeded violations, end-to-end ---------------------------------

/// Synthesize a minimal crate tree under CARGO_TARGET_TMPDIR with one
/// fixture planted at `rel`, run the full `audit_repo`, and return the
/// report. The skeleton lib.rs carries the required lint table so the
/// only findings are the seeded ones.
fn audit_planted(case: &str, rel: &str, fixture: &str) -> audit::Report {
    let root = Path::new(env!("CARGO_TARGET_TMPDIR")).join(format!("audit_fixture_{case}"));
    let src = root.join("src");
    let _ = std::fs::remove_dir_all(&root);
    std::fs::create_dir_all(src.join("ssm")).expect("mk ssm");
    std::fs::create_dir_all(src.join("quant")).expect("mk quant");
    std::fs::create_dir_all(src.join("coordinator")).expect("mk coordinator");
    std::fs::write(
        src.join("lib.rs"),
        "#![deny(unsafe_code)]\n\
         #![deny(unsafe_op_in_unsafe_fn)]\n\
         #![warn(clippy::undocumented_unsafe_blocks)]\n\
         pub mod quant;\npub mod ssm;\n",
    )
    .expect("write lib.rs");
    std::fs::write(src.join(rel), fixture).expect("write fixture");
    let report = audit::audit_repo(&root).expect("audit runs");
    let _ = std::fs::remove_dir_all(&root);
    report
}

#[test]
fn planted_unsafe_fails_end_to_end() {
    let report = audit_planted(
        "unsafe",
        "ssm/evil.rs",
        include_str!("fixtures/audit/unsafe_outside_kernels.rs.txt"),
    );
    assert!(!report.ok(), "planted unsafe came back clean");
    assert!(report.findings.iter().any(|f| f.rule == "unsafe-confinement"));
}

#[test]
fn planted_bad_tier_fails_end_to_end() {
    let report = audit_planted(
        "tier",
        "ssm/evil.rs",
        include_str!("fixtures/audit/bad_k_shape.rs.txt"),
    );
    assert!(!report.ok(), "planted 200k-wide tier came back clean");
    assert!(report.findings.iter().any(|f| f.rule == "k-bound"));
}

#[test]
fn planted_leaky_native_engine_fails_end_to_end() {
    let report = audit_planted(
        "native",
        "coordinator/native.rs",
        include_str!("fixtures/audit/native_leaky_release.rs.txt"),
    );
    assert!(!report.ok(), "planted leaky engine came back clean");
    assert!(report.findings.iter().any(|f| f.rule == "engine-no-unwrap"));
    assert!(report.findings.iter().any(|f| f.rule == "slot-reclaim"));
}

#[test]
fn planted_w4a8_wrong_bound_fails_end_to_end() {
    let report = audit_planted(
        "w4a8_guard",
        "quant/qlinear.rs",
        include_str!("fixtures/audit/w4a8_wrong_bound.rs.txt"),
    );
    assert!(!report.ok(), "planted wrong-bound w4a8 guard came back clean");
    assert!(
        report
            .findings
            .iter()
            .any(|f| f.rule == "accumulator-bound" && f.message.contains("MAX_SAFE_K_I4")),
        "{:?}",
        report.findings
    );
}

#[test]
fn planted_raw_clock_read_fails_end_to_end() {
    let report = audit_planted(
        "clock",
        "coordinator/evil_clock.rs",
        include_str!("fixtures/audit/clock_discipline.rs.txt"),
    );
    assert!(!report.ok(), "planted raw clock read came back clean");
    assert_eq!(
        report.findings.iter().filter(|f| f.rule == "clock-discipline").count(),
        2,
        "{:?}",
        report.findings
    );
}

#[test]
fn clean_skeleton_passes_end_to_end() {
    // control: the same synthesized skeleton with an innocuous file is
    // clean — proves the planted findings above come from the fixture,
    // not the harness
    let report = audit_planted("control", "ssm/fine.rs", "pub fn fine() -> u32 { 7 }\n");
    assert!(report.ok(), "control skeleton not clean: {:?}", report.findings);
}
