//! Quamba: a Rust + JAX + Pallas reproduction of
//! *"Quamba: A Post-Training Quantization Recipe for Selective State
//! Space Models"* (ICLR 2025).
//!
//! Architecture (DESIGN.md):
//! * **L3 (this crate)** — the serving coordinator: request router,
//!   bucketed continuous batcher, SSM-state / KV-cache pools, sampler,
//!   metrics, plus the evaluation + benchmark harnesses that regenerate
//!   every table and figure of the paper.
//! * **L2/L1 (python/, build-time only)** — the JAX Mamba /
//!   Transformer / hybrid models and the Pallas kernels, AOT-lowered to
//!   HLO text which [`runtime`] loads through the PJRT CPU client.
//!
//! The offline vendor set has no tokio/serde/clap/criterion/proptest;
//! [`util`] provides the std-only substrates (JSON, CLI, PRNG, stats;
//! a micro property-testing harness lives in `tests/`).

// ==== correctness lint table ========================================
// The build manifest is supplied by the environment, so the curated
// lint set lives here as crate attributes instead of a Cargo.toml
// `[lints]` table. `quamba_audit` (src/audit + tests/audit.rs + the CI
// audit job) checks that this block stays in place.
//
// unsafe hygiene: all `unsafe` is confined to `quant::kernels` (the
// explicit SIMD backends), which carries the crate's one
// `#[allow(unsafe_code)]`; every unsafe block there carries a
// `// SAFETY:` comment and every intrinsic fn a `#[target_feature]`
// consistent with its dispatch arm.
#![deny(unsafe_code)]
#![deny(unsafe_op_in_unsafe_fn)]
#![warn(clippy::undocumented_unsafe_blocks)]
// narrowing-cast hygiene: quant/ssm hot paths use the documented
// conversions in `quant::{code_to_i8, dq_i8, dq_i32}` instead of bare
// `as` truncations (machine-checked by the auditor's cast rule).
#![warn(clippy::char_lit_as_u8)]
#![warn(clippy::fn_to_numeric_cast_any)]
#![warn(clippy::as_underscore)]

pub mod attn;
pub mod audit;
pub mod bench_support;
pub mod cache;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod eval;
pub mod obs;
pub mod quant;
pub mod runtime;
pub mod ssm;
pub mod tensor;
pub mod util;

/// Crate version string used by the CLI.
pub const VERSION: &str = env!("CARGO_PKG_VERSION");
