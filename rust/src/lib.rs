//! Quamba: a Rust + JAX + Pallas reproduction of
//! *"Quamba: A Post-Training Quantization Recipe for Selective State
//! Space Models"* (ICLR 2025).
//!
//! Architecture (DESIGN.md):
//! * **L3 (this crate)** — the serving coordinator: request router,
//!   bucketed continuous batcher, SSM-state / KV-cache pools, sampler,
//!   metrics, plus the evaluation + benchmark harnesses that regenerate
//!   every table and figure of the paper.
//! * **L2/L1 (python/, build-time only)** — the JAX Mamba /
//!   Transformer / hybrid models and the Pallas kernels, AOT-lowered to
//!   HLO text which [`runtime`] loads through the PJRT CPU client.
//!
//! The offline vendor set has no tokio/serde/clap/criterion/proptest;
//! [`util`] provides the std-only substrates (JSON, CLI, PRNG, stats;
//! a micro property-testing harness lives in `tests/`).

pub mod attn;
pub mod bench_support;
pub mod cache;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod eval;
pub mod quant;
pub mod runtime;
pub mod ssm;
pub mod tensor;
pub mod util;

/// Crate version string used by the CLI.
pub const VERSION: &str = env!("CARGO_PKG_VERSION");
