//! Seeded PRNG (rand-crate substitute): PCG-XSH-RR 64/32.
//! Deterministic across platforms; used by workload generators,
//! samplers, and the property-test harness.

#[derive(Clone, Debug)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
}

impl Pcg32 {
    pub fn new(seed: u64) -> Self {
        let mut r = Pcg32 {
            state: 0,
            inc: (seed << 1) | 1,
        };
        r.next_u32();
        r.state = r.state.wrapping_add(0x853c_49e6_748f_ea9b ^ seed);
        r.next_u32();
        r
    }

    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old
            .wrapping_mul(6_364_136_223_846_793_005)
            .wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in [0, 1).
    pub fn f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 / (1u32 << 24) as f32
    }

    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Uniform integer in [0, n) without modulo bias (Lemire).
    pub fn below(&mut self, n: u32) -> u32 {
        debug_assert!(n > 0);
        loop {
            let x = self.next_u32();
            let m = (x as u64).wrapping_mul(n as u64);
            let l = m as u32;
            if l >= n.wrapping_neg() % n {
                return (m >> 32) as u32;
            }
            // retry in the rejected zone
            let _ = x;
        }
    }

    pub fn range_f32(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.f32()
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f32 {
        let u1 = self.f32().max(1e-9);
        let u2 = self.f32();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f32::consts::PI * u2).cos()
    }

    /// Exponential with rate `lambda` (inter-arrival times for Poisson
    /// request workloads).
    pub fn exp(&mut self, lambda: f64) -> f64 {
        -(1.0 - self.f64()).ln() / lambda
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u32 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Sample an index from unnormalized non-negative weights.
    pub fn weighted(&mut self, w: &[f32]) -> usize {
        let total: f32 = w.iter().sum();
        let mut t = self.f32() * total;
        for (i, &wi) in w.iter().enumerate() {
            t -= wi;
            if t <= 0.0 {
                return i;
            }
        }
        w.len() - 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Pcg32::new(42);
        let mut b = Pcg32::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u32(), b.next_u32());
        }
    }

    #[test]
    fn below_bounds() {
        let mut r = Pcg32::new(1);
        for _ in 0..10_000 {
            assert!(r.below(7) < 7);
        }
    }

    #[test]
    fn f32_range_and_mean() {
        let mut r = Pcg32::new(3);
        let mut sum = 0.0;
        for _ in 0..10_000 {
            let x = r.f32();
            assert!((0.0..1.0).contains(&x));
            sum += x as f64;
        }
        assert!((sum / 10_000.0 - 0.5).abs() < 0.02);
    }

    #[test]
    fn normal_moments() {
        let mut r = Pcg32::new(5);
        let n = 20_000;
        let (mut m, mut v) = (0.0f64, 0.0f64);
        for _ in 0..n {
            let x = r.normal() as f64;
            m += x;
            v += x * x;
        }
        m /= n as f64;
        v = v / n as f64 - m * m;
        assert!(m.abs() < 0.03, "mean {m}");
        assert!((v - 1.0).abs() < 0.05, "var {v}");
    }
}
