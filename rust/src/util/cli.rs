//! Tiny CLI argument parser (clap substitute).
//!
//! Supports `command [--flag] [--key value] [positional...]` with
//! typed getters and an auto-generated usage string.

use std::collections::BTreeMap;

#[derive(Debug, Default, Clone)]
pub struct Args {
    pub command: Option<String>,
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    /// Parse `argv[1..]`. The first non-dash token becomes the command;
    /// `--key value` pairs become options unless `key` is declared in
    /// `bool_flags` (then it is a flag and consumes no value).
    pub fn parse(argv: &[String], bool_flags: &[&str]) -> Args {
        let mut out = Args::default();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(name) = a.strip_prefix("--") {
                if bool_flags.contains(&name) {
                    out.flags.push(name.to_string());
                } else if i + 1 < argv.len() {
                    out.options.insert(name.to_string(), argv[i + 1].clone());
                    i += 1;
                } else {
                    out.flags.push(name.to_string());
                }
            } else if out.command.is_none() {
                out.command = Some(a.clone());
            } else {
                out.positional.push(a.clone());
            }
            i += 1;
        }
        out
    }

    pub fn from_env(bool_flags: &[&str]) -> Args {
        let argv: Vec<String> = std::env::args().skip(1).collect();
        Args::parse(&argv, bool_flags)
    }

    pub fn has(&self, flag: &str) -> bool {
        self.flags.iter().any(|f| f == flag)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn get_usize(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn get_u64(&self, key: &str, default: u64) -> u64 {
        self.get(key).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn get_f64(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    /// Comma-separated list option.
    pub fn get_list(&self, key: &str) -> Option<Vec<String>> {
        self.get(key)
            .map(|s| s.split(',').map(|x| x.trim().to_string()).collect())
    }

    /// Megabyte-denominated option returned in **bytes** (`--cache-mb
    /// 8` → 8_000_000); fractional values work (`--cache-mb 0.5`).
    /// Used for the prefix-cache budget flags.
    pub fn get_mb(&self, key: &str, default_mb: f64) -> usize {
        (self.get_f64(key, default_mb) * 1e6) as usize
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn v(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parse_mixed() {
        let a = Args::parse(
            &v(&["serve", "--tier", "m2p8", "--verbose", "extra", "--n", "4"]),
            &["verbose"],
        );
        assert_eq!(a.command.as_deref(), Some("serve"));
        assert_eq!(a.get("tier"), Some("m2p8"));
        assert!(a.has("verbose"));
        assert_eq!(a.positional, vec!["extra".to_string()]);
        assert_eq!(a.get_usize("n", 0), 4);
    }

    #[test]
    fn trailing_flag() {
        let a = Args::parse(&v(&["x", "--last"]), &[]);
        assert!(a.has("last"));
    }

    #[test]
    fn mb_option_converts_to_bytes() {
        let a = Args::parse(&v(&["x", "--cache-mb", "0.5"]), &[]);
        assert_eq!(a.get_mb("cache-mb", 8.0), 500_000);
        assert_eq!(a.get_mb("other-mb", 8.0), 8_000_000);
    }

    #[test]
    fn list_option() {
        let a = Args::parse(&v(&["x", "--methods", "fp16, quamba"]), &[]);
        assert_eq!(
            a.get_list("methods").unwrap(),
            vec!["fp16".to_string(), "quamba".to_string()]
        );
    }
}
