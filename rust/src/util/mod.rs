//! std-only substrates for crates missing from the offline vendor set
//! (serde/serde_json, clap, rand, parts of criterion). Each submodule
//! is deliberately small, fully tested, and used across the crate.

pub mod cli;
pub mod interleave;
pub mod json;
pub mod rng;
pub mod stats;

/// Wall-clock helper: seconds elapsed since `t0`.
pub fn secs_since(t0: std::time::Instant) -> f64 {
    t0.elapsed().as_secs_f64()
}
