//! Minimal JSON parser + writer (serde_json substitute, DESIGN.md §2).
//!
//! Supports the full JSON data model the artifact manifest and task
//! suite use: objects, arrays, strings (with escapes), numbers, bools,
//! null. Numbers are stored as f64 (adequate: the manifest's largest
//! integers are byte counts < 2^53).

use std::collections::BTreeMap;
use std::fmt::Write as _;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }
    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|f| f as i64)
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|f| f as usize)
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }
    /// `obj["a"]["b"]`-style access; returns Null for missing keys.
    pub fn get(&self, key: &str) -> &Json {
        static NULL: Json = Json::Null;
        match self {
            Json::Obj(o) => o.get(key).unwrap_or(&NULL),
            _ => &NULL,
        }
    }
    pub fn idx(&self, i: usize) -> &Json {
        static NULL: Json = Json::Null;
        match self {
            Json::Arr(a) => a.get(i).unwrap_or(&NULL),
            _ => &NULL,
        }
    }
}

// ---------------------------------------------------------------------------
// Parser
// ---------------------------------------------------------------------------

pub fn parse(s: &str) -> Result<Json, String> {
    let b = s.as_bytes();
    let mut p = Parser { b, i: 0 };
    p.ws();
    let v = p.value()?;
    p.ws();
    if p.i != b.len() {
        return Err(format!("trailing garbage at byte {}", p.i));
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }
    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }
    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", c as char, self.i))
        }
    }
    fn value(&mut self) -> Result<Json, String> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(format!("unexpected byte at {}", self.i)),
        }
    }
    fn lit(&mut self, s: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }
    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.i += 1;
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| format!("bad number at byte {start}"))
    }
    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'n') => out.push('\n'),
                        Some(b'r') => out.push('\r'),
                        Some(b't') => out.push('\t'),
                        Some(b'u') => {
                            let hex = self
                                .b
                                .get(self.i + 1..self.i + 5)
                                .ok_or("bad \\u escape")?;
                            let cp = u32::from_str_radix(
                                std::str::from_utf8(hex).map_err(|_| "bad \\u")?,
                                16,
                            )
                            .map_err(|_| "bad \\u")?;
                            out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err("bad escape".into()),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // consume one UTF-8 scalar
                    let s = std::str::from_utf8(&self.b[self.i..]).map_err(|_| "bad utf8")?;
                    let ch = s.chars().next().unwrap();
                    out.push(ch);
                    self.i += ch.len_utf8();
                }
            }
        }
    }
    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(format!("expected ',' or ']' at byte {}", self.i)),
            }
        }
    }
    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(format!("expected ',' or '}}' at byte {}", self.i)),
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Writer
// ---------------------------------------------------------------------------

pub fn write(v: &Json) -> String {
    let mut s = String::new();
    write_into(v, &mut s);
    s
}

fn write_into(v: &Json, out: &mut String) {
    match v {
        Json::Null => out.push_str("null"),
        Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
        Json::Num(n) => {
            if n.fract() == 0.0 && n.abs() < 9e15 {
                let _ = write!(out, "{}", *n as i64);
            } else {
                let _ = write!(out, "{n}");
            }
        }
        Json::Str(s) => write_escaped(s, out),
        Json::Arr(a) => {
            out.push('[');
            for (i, x) in a.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_into(x, out);
            }
            out.push(']');
        }
        Json::Obj(m) => {
            out.push('{');
            for (i, (k, x)) in m.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_escaped(k, out);
                out.push(':');
                write_into(x, out);
            }
            out.push('}');
        }
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Convenience constructors for building manifests/reports.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}
pub fn num(n: f64) -> Json {
    Json::Num(n)
}
pub fn s(v: &str) -> Json {
    Json::Str(v.to_string())
}
pub fn arr(v: Vec<Json>) -> Json {
    Json::Arr(v)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let src = r#"{"a": [1, 2.5, -3e2], "b": {"c": "x\ny", "d": true, "e": null}}"#;
        let v = parse(src).unwrap();
        assert_eq!(v.get("a").idx(1).as_f64(), Some(2.5));
        assert_eq!(v.get("b").get("c").as_str(), Some("x\ny"));
        assert_eq!(v.get("b").get("d").as_bool(), Some(true));
        let re = parse(&write(&v)).unwrap();
        assert_eq!(v, re);
    }

    #[test]
    fn unicode_and_escapes() {
        let v = parse(r#""Aéü""#).unwrap();
        assert_eq!(v.as_str(), Some("Aéü"));
    }

    #[test]
    fn errors() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("12 34").is_err());
    }
}
