//! Summary statistics: percentiles, histograms, latency summaries.
//! Shared by the metrics pipeline and the bench harness.

/// Percentile of a sample set (linear interpolation, p in [0, 100]).
/// Sorts a copy; fine for the ≤1e6-sample uses in this crate.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    let mut v: Vec<f64> = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    percentile_sorted(&v, p)
}

pub fn percentile_sorted(v: &[f64], p: f64) -> f64 {
    if v.is_empty() {
        return f64::NAN;
    }
    if v.len() == 1 {
        return v[0];
    }
    let rank = (p / 100.0).clamp(0.0, 1.0) * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    let frac = rank - lo as f64;
    v[lo] * (1.0 - frac) + v[hi] * frac
}

pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

pub fn std_dev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64).sqrt()
}

/// Latency summary used by metrics and the bench printer.
#[derive(Debug, Clone, Default)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub p50: f64,
    pub p90: f64,
    pub p95: f64,
    pub p99: f64,
    pub max: f64,
}

impl Summary {
    pub fn of(xs: &[f64]) -> Summary {
        if xs.is_empty() {
            return Summary::default();
        }
        let mut v = xs.to_vec();
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Summary {
            n: v.len(),
            mean: mean(&v),
            std: std_dev(&v),
            min: v[0],
            p50: percentile_sorted(&v, 50.0),
            p90: percentile_sorted(&v, 90.0),
            p95: percentile_sorted(&v, 95.0),
            p99: percentile_sorted(&v, 99.0),
            max: *v.last().unwrap(),
        }
    }
}

/// Streaming histogram with fixed log-spaced buckets (for TPOT/TTFT
/// distributions without retaining every sample).
#[derive(Debug, Clone)]
pub struct LogHistogram {
    /// bucket i covers [lo * ratio^i, lo * ratio^(i+1))
    lo: f64,
    ratio: f64,
    counts: Vec<u64>,
    pub n: u64,
    pub sum: f64,
    pub min: f64,
    pub max: f64,
}

impl LogHistogram {
    pub fn new(lo: f64, hi: f64, buckets: usize) -> Self {
        let ratio = (hi / lo).powf(1.0 / buckets as f64);
        LogHistogram {
            lo,
            ratio,
            counts: vec![0; buckets + 2], // +under/overflow
            n: 0,
            sum: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    pub fn record(&mut self, x: f64) {
        self.n += 1;
        self.sum += x;
        self.min = self.min.min(x);
        self.max = self.max.max(x);
        let idx = if x < self.lo {
            0
        } else {
            let i = ((x / self.lo).ln() / self.ratio.ln()).floor() as isize + 1;
            (i.max(0) as usize).min(self.counts.len() - 1)
        };
        self.counts[idx] += 1;
    }

    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            f64::NAN
        } else {
            self.sum / self.n as f64
        }
    }

    /// Approximate quantile from bucket boundaries.
    pub fn quantile(&self, q: f64) -> f64 {
        if self.n == 0 {
            return f64::NAN;
        }
        let target = (q.clamp(0.0, 1.0) * self.n as f64).ceil() as u64;
        let mut acc = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            acc += c;
            if acc >= target {
                if i == 0 {
                    return self.min;
                }
                return self.lo * self.ratio.powi(i as i32 - 1);
            }
        }
        self.max
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentile_basics() {
        let xs = [1.0, 2.0, 3.0, 4.0, 5.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 50.0), 3.0);
        assert_eq!(percentile(&xs, 100.0), 5.0);
        assert!((percentile(&xs, 25.0) - 2.0).abs() < 1e-9);
    }

    #[test]
    fn summary() {
        let xs: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        let s = Summary::of(&xs);
        assert_eq!(s.n, 100);
        assert!((s.mean - 50.5).abs() < 1e-9);
        assert!((s.p50 - 50.5).abs() < 1.0);
        assert!((s.p95 - 95.0).abs() < 1.0, "p95={}", s.p95);
        assert!(s.p90 <= s.p95 && s.p95 <= s.p99);
    }

    #[test]
    fn log_histogram_quantiles() {
        let mut h = LogHistogram::new(1e-6, 10.0, 64);
        for i in 1..=1000 {
            h.record(i as f64 / 1000.0);
        }
        let q50 = h.quantile(0.5);
        assert!(q50 > 0.3 && q50 < 0.7, "q50={q50}");
        assert_eq!(h.n, 1000);
    }
}
