//! A loom-style exhaustive interleaving explorer for the threaded
//! serving core (std-only — the offline vendor set has no `loom`).
//!
//! A [`Model`] describes a small concurrent algorithm as N logical
//! threads, each a fixed sequence of *atomic* steps over one shared
//! [`Model::State`]. [`explore`] then runs **every** schedule: at each
//! point it branches on all enabled threads (cloning the state), so an
//! invariant that can be broken by *some* interleaving of the modeled
//! steps is broken deterministically, with the offending schedule in
//! the panic message — no stress loops, no flaky 1-in-10⁶ repros.
//!
//! This checks the *algorithm* (orderings, gating, exactly-once
//! effects), not the memory model: steps here are sequentially
//! consistent, so it complements — never replaces — the TSan job in
//! CI, which watches the real `std::thread` code for data races the
//! model abstracts away. `tests/loom_model.rs` models the lane-split
//! decode path, the EngineCore submit→admit→decode→harvest handoff,
//! and prefix-cache snapshot consistency, each alongside a
//! deliberately broken variant proving the explorer catches the bug
//! class.

/// A concurrent algorithm modeled as fixed per-thread step sequences
/// over a cloneable shared state.
pub trait Model {
    /// Shared state; cloned at every branch point of the exploration.
    type State: Clone;

    /// Initial shared state.
    fn init(&self) -> Self::State;

    /// Number of atomic steps each logical thread executes.
    fn thread_steps(&self) -> Vec<usize>;

    /// May thread `t` execute its `step`-th step now? Gating on the
    /// state models blocking (a worker waiting on a channel recv is
    /// "not enabled" until the message is there).
    fn enabled(&self, _st: &Self::State, _t: usize, _step: usize) -> bool {
        true
    }

    /// Execute thread `t`'s `step`-th step. Must be deterministic.
    fn step(&self, st: &mut Self::State, t: usize, step: usize);

    /// Invariant checked after every step of every schedule.
    fn check_step(&self, _st: &Self::State) {}

    /// Invariant checked when every thread has run to completion.
    fn check_final(&self, st: &Self::State);

    /// Called when no thread is enabled but some still have steps
    /// left. Return `true` if this quiescence is legitimate (e.g. an
    /// engine with spare ticks and an empty mailbox); the explorer
    /// then treats the schedule as complete and calls nothing further.
    /// Default `false` = this is a deadlock, panic with the schedule.
    fn quiescent_ok(&self, _st: &Self::State, _done: &[usize]) -> bool {
        false
    }
}

/// Exploration statistics returned by [`explore`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Explored {
    /// Complete schedules executed (including legitimate quiescences).
    pub executions: u64,
    /// Total steps executed across all schedules.
    pub steps: u64,
}

/// Hard budget on total executed steps — an exhaustive explorer on an
/// oversized model should fail loudly, not hang CI.
const STEP_BUDGET: u64 = 5_000_000;

/// Exhaustively run every interleaving of `m`'s threads. Panics (with
/// the schedule, as a list of thread ids in execution order) on a
/// deadlock, on budget exhaustion, or whenever a `check_*` panics.
pub fn explore<M: Model>(m: &M) -> Explored {
    let steps = m.thread_steps();
    let mut stats = Explored { executions: 0, steps: 0 };
    let mut sched: Vec<usize> = Vec::new();
    dfs(m, m.init(), &steps, &mut vec![0; steps.len()], &mut sched, &mut stats);
    stats
}

fn dfs<M: Model>(
    m: &M,
    st: M::State,
    steps: &[usize],
    done: &mut Vec<usize>,
    sched: &mut Vec<usize>,
    stats: &mut Explored,
) {
    let mut ran_any = false;
    for t in 0..steps.len() {
        if done[t] >= steps[t] || !m.enabled(&st, t, done[t]) {
            continue;
        }
        ran_any = true;
        stats.steps += 1;
        assert!(
            stats.steps <= STEP_BUDGET,
            "interleaving model exceeds the {STEP_BUDGET}-step exploration budget \
             (schedule prefix: {sched:?}) — shrink the model"
        );
        let mut next = st.clone();
        m.step(&mut next, t, done[t]);
        m.check_step(&next);
        done[t] += 1;
        sched.push(t);
        dfs(m, next, steps, done, sched, stats);
        sched.pop();
        done[t] -= 1;
    }
    if ran_any {
        return;
    }
    if done.iter().zip(steps).all(|(d, s)| d >= s) {
        m.check_final(&st);
        stats.executions += 1;
    } else if m.quiescent_ok(&st, done) {
        stats.executions += 1;
    } else {
        panic!(
            "deadlock: no thread enabled with steps remaining \
             (progress {done:?} of {steps:?}, schedule {sched:?})"
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::panic::{catch_unwind, AssertUnwindSafe};

    /// Two threads, two independent atomic increments each.
    struct Independent;
    impl Model for Independent {
        type State = [u32; 2];
        fn init(&self) -> Self::State {
            [0, 0]
        }
        fn thread_steps(&self) -> Vec<usize> {
            vec![2, 2]
        }
        fn step(&self, st: &mut Self::State, t: usize, _step: usize) {
            st[t] += 1;
        }
        fn check_final(&self, st: &Self::State) {
            assert_eq!(*st, [2, 2]);
        }
    }

    #[test]
    fn counts_all_interleavings() {
        // 2 threads × 2 steps: C(4,2) = 6 distinct schedules
        let ex = explore(&Independent);
        assert_eq!(ex.executions, 6);
        assert!(ex.steps > 6);
    }

    /// Classic torn read-modify-write: each thread loads the shared
    /// counter into a register step, then stores register+1.
    struct RacyCounter;
    #[derive(Clone, Default)]
    struct RacyState {
        shared: u32,
        reg: [u32; 2],
    }
    impl Model for RacyCounter {
        type State = RacyState;
        fn init(&self) -> Self::State {
            RacyState::default()
        }
        fn thread_steps(&self) -> Vec<usize> {
            vec![2, 2]
        }
        fn step(&self, st: &mut Self::State, t: usize, step: usize) {
            match step {
                0 => st.reg[t] = st.shared,
                _ => st.shared = st.reg[t] + 1,
            }
        }
        fn check_final(&self, st: &Self::State) {
            assert_eq!(st.shared, 2, "lost update");
        }
    }

    #[test]
    fn catches_lost_update_deterministically() {
        let err = catch_unwind(AssertUnwindSafe(|| explore(&RacyCounter)))
            .expect_err("the unsynchronized counter must lose an update in some schedule");
        let msg = err.downcast_ref::<String>().cloned().unwrap_or_default();
        assert!(msg.contains("lost update"), "unexpected panic: {msg}");
    }

    /// Thread 1's only step is gated on thread 0 finishing; thread 0's
    /// second step is gated on thread 1 finishing — a circular wait.
    struct Circular;
    impl Model for Circular {
        type State = [usize; 2]; // steps completed per thread
        fn init(&self) -> Self::State {
            [0, 0]
        }
        fn thread_steps(&self) -> Vec<usize> {
            vec![2, 1]
        }
        fn enabled(&self, st: &Self::State, t: usize, step: usize) -> bool {
            match (t, step) {
                (0, 1) => st[1] == 1, // t0's 2nd step needs t1 done
                (1, 0) => st[0] == 2, // t1's step needs t0 done
                _ => true,
            }
        }
        fn step(&self, st: &mut Self::State, t: usize, _step: usize) {
            st[t] += 1;
        }
        fn check_final(&self, _st: &Self::State) {}
    }

    #[test]
    fn reports_deadlock_with_schedule() {
        let err = catch_unwind(AssertUnwindSafe(|| explore(&Circular)))
            .expect_err("circular wait must deadlock");
        let msg = err.downcast_ref::<String>().cloned().unwrap_or_default();
        assert!(msg.contains("deadlock"), "unexpected panic: {msg}");
        assert!(msg.contains("schedule"), "schedule trace missing: {msg}");
    }

    /// Same circular model, but the model declares the stuck point a
    /// legitimate quiescence — explore() then completes normally.
    struct CircularQuiesce;
    impl Model for CircularQuiesce {
        type State = [usize; 2];
        fn init(&self) -> Self::State {
            [0, 0]
        }
        fn thread_steps(&self) -> Vec<usize> {
            vec![2, 1]
        }
        fn enabled(&self, st: &Self::State, t: usize, step: usize) -> bool {
            Circular.enabled(st, t, step)
        }
        fn step(&self, st: &mut Self::State, t: usize, _step: usize) {
            st[t] += 1;
        }
        fn check_final(&self, _st: &Self::State) {}
        fn quiescent_ok(&self, _st: &Self::State, done: &[usize]) -> bool {
            done == [1, 0] // only the known benign stuck point
        }
    }

    #[test]
    fn quiescence_hook_accepts_benign_stalls() {
        assert_eq!(explore(&CircularQuiesce).executions, 1);
    }
}
