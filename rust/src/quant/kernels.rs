//! Explicit-SIMD int8 kernel suite with one-time runtime dispatch —
//! the paper's "8-bit ... benefits from hardware acceleration" claim
//! (§1, Table 1) made concrete on CPU: every int8 hot path executes
//! `i8 × i8 → i16 → i32` widening multiply-adds through one [`Kernels`]
//! dispatch struct instead of hoping the auto-vectorizer finds them.
//!
//! Backends ([`KernelBackend`]):
//!
//! * **`Scalar`** — portable Rust, structured for auto-vectorization
//!   (the PR-2 blocked kernel). Always available; the other backends
//!   are property-tested bit-identical to it (`tests/kernel_parity.rs`).
//! * **`Avx2`** — x86-64 AVX2: the `pmaddwd`-style path. Weights are
//!   sign-extended `i8 → i16` and interleaved in K-pairs so one
//!   `_mm256_madd_epi16` performs 16 widening multiplies + 8 pairwise
//!   i32 adds; a 4-row register tile reuses each extended weight block
//!   across four activation rows (SSSE3 `maddubs` needs an unsigned
//!   operand + correction term; `pmaddwd` on extended i16 is the same
//!   throughput idea without the fixup).
//! * **`Neon`** — aarch64: `vmull_s8` widening multiplies folded into
//!   i32 accumulators with `vaddw_s16`.
//!
//! Selection happens **once** per process ([`Kernels::auto`], a
//! `OnceLock`): `is_x86_feature_detected!("avx2")` /
//! `cfg(target_arch = "aarch64")`, overridable with the
//! `QUAMBA_KERNELS` env var (`auto` | `scalar` | `avx2` | `neon`) for
//! testing and benchmarking. Forced construction for tests goes
//! through [`Kernels::for_backend`]; [`Kernels::available`] lists every
//! backend runnable on this machine so parity suites can sweep them.
//!
//! Exactness contract: all three primitives are **bit-identical**
//! across backends —
//!
//! * [`Kernels::gemm_rows`] and [`Kernels::mac_i8`] are exact integer
//!   arithmetic (an i8·i8 product fits i16, a K-sum of them fits i32),
//!   so any accumulation grouping matches the naive oracle bit-for-bit;
//! * [`Kernels::dequant_i8`] is element-wise (`q as f32 * s`, one IEEE
//!   multiply per element), so vector lanes round exactly like the
//!   scalar loop.
//!
//! That contract is what lets the W8A8 serving path switch backends
//! without changing a single sampled token (asserted per backend in
//! `tests/kernel_parity.rs` and the engine-level
//! `forced_kernel_backend_serves_identical_tokens` test in
//! [`crate::coordinator::native`]).

use std::sync::OnceLock;

/// Column-block width of the packed weight layout ([`crate::quant::qlinear::PackedWeightI8`]):
/// 16 i8 weights = one 128-bit lane load; 16 i32 accumulators fit in
/// two 256-bit registers (or four 128-bit ones).
pub const GEMM_NB: usize = 16;

/// Register-tile height of the blocked GEMM: rows of activations
/// processed together so each widened weight block is reused `MR`
/// times from registers.
pub const GEMM_MR: usize = 4;

/// Largest magnitude of a single i8·i8 product:
/// `(-128) · (-128) = 2¹⁴ = 16384`. Every kernel in this module folds
/// such products into an `i32` accumulator, so this constant is the
/// per-term headroom bound of the whole int8 suite.
pub const MAX_ABS_PROD_I8: i64 = 1 << 14;

/// Largest dot-product length K for which a worst-case i8·i8 sum is
/// guaranteed to fit an `i32` accumulator:
/// `K · 2¹⁴ ≤ i32::MAX  ⇔  K ≤ ⌊(2³¹ − 1) / 2¹⁴⌋ = 2¹⁷ − 1 = 131071`.
///
/// Checked three ways: the const assertions below prove the bound at
/// compile time, `debug_assert!` guards in `matmul_i8_blocked`,
/// `fused_conv_silu_i8`, and `selective_scan_q_into` enforce it on
/// every runtime shape, and `quamba_audit` cross-checks every
/// `MambaTier` literal and bench shape in the tree against it.
pub const MAX_SAFE_K: usize = (i32::MAX as i64 / MAX_ABS_PROD_I8) as usize;

// Compile-time overflow proof: K = MAX_SAFE_K worth of worst-case
// products fits i32; K = MAX_SAFE_K + 1 does not. If either inequality
// breaks (e.g. someone widens the quantizer grid past 8 bits without
// re-deriving the bound), the build fails here instead of wrapping an
// accumulator at runtime.
const _: () = assert!(MAX_SAFE_K as i64 * MAX_ABS_PROD_I8 <= i32::MAX as i64);
const _: () = assert!((MAX_SAFE_K as i64 + 1) * MAX_ABS_PROD_I8 > i32::MAX as i64);
const _: () = assert!(MAX_SAFE_K == (1 << 17) - 1);

/// Largest magnitude of a single i4·i8 product (the W4A8 tier:
/// packed-nibble weights in −8..=7 against int8 activations):
/// `(-8) · (-128) = 2¹⁰ = 1024` — 16× smaller per term than the
/// i8·i8 worst case, so the same i32 accumulator admits a 16× longer
/// dot product before it can wrap.
pub const MAX_ABS_PROD_I4I8: i64 = 1 << 10;

/// Largest dot-product length K for which a worst-case i4·i8 sum is
/// guaranteed to fit an `i32` accumulator:
/// `K · 2¹⁰ ≤ i32::MAX  ⇔  K ≤ ⌊(2³¹ − 1) / 2¹⁰⌋ = 2²¹ − 1 = 2097151`.
///
/// The looser bound matters because the W4A8 GEMM
/// ([`crate::quant::qlinear::matmul_w4a8_with`]) accumulates one
/// K-*group* per integer tile, but the guard is stated against the
/// full K so the proof holds even if grouping is ever widened to the
/// whole axis. `quamba_audit` checks W4A8 bench shapes against this
/// bound (and i8 shapes against the tighter [`MAX_SAFE_K`]).
pub const MAX_SAFE_K_I4: usize = (i32::MAX as i64 / MAX_ABS_PROD_I4I8) as usize;

// Compile-time overflow proof for the i4×i8 tier, mirroring the i8
// proof above: the bound fits, one more worst-case product does not,
// and the derived value is pinned in closed form.
const _: () = assert!(MAX_SAFE_K_I4 as i64 * MAX_ABS_PROD_I4I8 <= i32::MAX as i64);
const _: () = assert!((MAX_SAFE_K_I4 as i64 + 1) * MAX_ABS_PROD_I4I8 > i32::MAX as i64);
const _: () = assert!(MAX_SAFE_K_I4 == (1 << 21) - 1);

/// One int8 execution backend. `Scalar` exists everywhere; the SIMD
/// variants are constructible only where the hardware supports them
/// (checked at runtime, see [`KernelBackend::is_available`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum KernelBackend {
    /// Portable Rust loops (auto-vectorized at whatever ISA the build
    /// targets). The bit-exactness oracle for the SIMD paths.
    Scalar,
    /// x86-64 AVX2 widening multiply-add (`_mm256_madd_epi16`).
    Avx2,
    /// aarch64 NEON widening multiply-add (`vmull_s8` + `vaddw_s16`).
    Neon,
}

#[cfg(target_arch = "x86_64")]
fn avx2_available() -> bool {
    is_x86_feature_detected!("avx2")
}

#[cfg(not(target_arch = "x86_64"))]
fn avx2_available() -> bool {
    false
}

fn neon_available() -> bool {
    // NEON is a mandatory feature of every aarch64 target rustc ships
    cfg!(target_arch = "aarch64")
}

impl KernelBackend {
    /// Stable lowercase name (the `QUAMBA_KERNELS` vocabulary).
    pub fn label(self) -> &'static str {
        match self {
            KernelBackend::Scalar => "scalar",
            KernelBackend::Avx2 => "avx2",
            KernelBackend::Neon => "neon",
        }
    }

    /// Parse a [`Self::label`] string (used by `QUAMBA_KERNELS` and the
    /// serving CLI). `None` for unknown names.
    pub fn parse(s: &str) -> Option<KernelBackend> {
        match s {
            "scalar" => Some(KernelBackend::Scalar),
            "avx2" => Some(KernelBackend::Avx2),
            "neon" => Some(KernelBackend::Neon),
            _ => None,
        }
    }

    /// Can this backend execute on the current machine?
    pub fn is_available(self) -> bool {
        match self {
            KernelBackend::Scalar => true,
            KernelBackend::Avx2 => avx2_available(),
            KernelBackend::Neon => neon_available(),
        }
    }
}

/// The dispatch handle threaded through every int8 hot path: the
/// blocked GEMM ([`Self::gemm_rows`]), the fused conv's element-wise
/// MAC ([`Self::mac_i8`]), and the scan's code dequantization
/// ([`Self::dequant_i8`]). `Copy` so it rides along in
/// [`crate::ssm::StepScratch`] and closures without lifetime plumbing;
/// dispatch is a single enum match per kernel call (amortized over a
/// whole block/row of work).
#[derive(Debug, Clone, Copy)]
pub struct Kernels {
    backend: KernelBackend,
}

impl Kernels {
    /// The portable baseline (always works; the parity oracle).
    pub fn scalar() -> Kernels {
        Kernels { backend: KernelBackend::Scalar }
    }

    /// A specific backend, `None` if this machine cannot run it.
    pub fn try_new(backend: KernelBackend) -> Option<Kernels> {
        if backend.is_available() {
            Some(Kernels { backend })
        } else {
            None
        }
    }

    /// A specific backend; panics (with the available set) if the
    /// machine cannot run it — forcing a path that would silently fall
    /// back elsewhere would invalidate parity tests and benchmarks.
    pub fn for_backend(backend: KernelBackend) -> Kernels {
        Self::try_new(backend).unwrap_or_else(|| {
            panic!(
                "kernel backend '{}' not available on this machine (available: {})",
                backend.label(),
                Self::available().iter().map(|b| b.label()).collect::<Vec<_>>().join(", ")
            )
        })
    }

    /// Every backend runnable here, `Scalar` first (parity suites sweep
    /// this list).
    pub fn available() -> Vec<KernelBackend> {
        [KernelBackend::Scalar, KernelBackend::Avx2, KernelBackend::Neon]
            .into_iter()
            .filter(|b| b.is_available())
            .collect()
    }

    /// Best backend the hardware offers (no env override).
    pub fn detect() -> Kernels {
        if avx2_available() {
            Kernels { backend: KernelBackend::Avx2 }
        } else if neon_available() {
            Kernels { backend: KernelBackend::Neon }
        } else {
            Kernels::scalar()
        }
    }

    /// The process-wide selection, made exactly once: `QUAMBA_KERNELS`
    /// (`auto`/`scalar`/`avx2`/`neon`) if set, else [`Self::detect`].
    /// An unknown or unavailable forced value panics loudly rather than
    /// benchmarking the wrong path.
    pub fn auto() -> Kernels {
        static SELECTED: OnceLock<Kernels> = OnceLock::new();
        *SELECTED.get_or_init(|| match std::env::var("QUAMBA_KERNELS") {
            Ok(v) if v.is_empty() || v == "auto" => Self::detect(),
            Ok(v) => {
                let b = KernelBackend::parse(&v).unwrap_or_else(|| {
                    panic!("QUAMBA_KERNELS={v}: unknown backend (auto|scalar|avx2|neon)")
                });
                Self::for_backend(b)
            }
            Err(_) => Self::detect(),
        })
    }

    pub fn backend(self) -> KernelBackend {
        self.backend
    }

    /// Stable name of the selected backend (logging / bench JSON).
    pub fn label(self) -> &'static str {
        self.backend.label()
    }

    /// Blocked-GEMM register tile: `acc` (rows × [`GEMM_NB`], fully
    /// overwritten) = `x` (rows × K, row stride `k`) · `blk` (K-major
    /// [`GEMM_NB`]-wide weight block). `rows` ≤ [`GEMM_MR`]. All
    /// accumulation is exact i32, so every backend is bit-identical to
    /// the naive triple loop.
    pub fn gemm_rows(self, x: &[i8], k: usize, rows: usize, blk: &[i8], acc: &mut [i32]) {
        assert!(rows >= 1 && rows <= GEMM_MR, "rows {rows} outside 1..={GEMM_MR}");
        assert!(x.len() >= rows * k, "x tile too short");
        assert!(blk.len() >= k * GEMM_NB, "weight block too short");
        assert!(acc.len() >= rows * GEMM_NB, "acc tile too short");
        match self.backend {
            KernelBackend::Scalar => scalar::gemm_rows(x, k, rows, blk, acc),
            KernelBackend::Avx2 => {
                #[cfg(target_arch = "x86_64")]
                // SAFETY: Avx2 is only constructible when runtime
                // detection succeeded (try_new/for_backend/detect).
                unsafe {
                    if rows == GEMM_MR {
                        avx2::gemm_x4(x, k, blk, acc);
                    } else {
                        for r in 0..rows {
                            avx2::gemm_x1(&x[r * k..], k, blk, &mut acc[r * GEMM_NB..]);
                        }
                    }
                }
                #[cfg(not(target_arch = "x86_64"))]
                unreachable!("AVX2 backend constructed on non-x86_64");
            }
            KernelBackend::Neon => {
                #[cfg(target_arch = "aarch64")]
                // SAFETY: Neon is only constructible on aarch64, where
                // NEON is a mandatory target feature.
                unsafe {
                    for r in 0..rows {
                        neon::gemm_x1(&x[r * k..], k, blk, &mut acc[r * GEMM_NB..]);
                    }
                }
                #[cfg(not(target_arch = "aarch64"))]
                unreachable!("NEON backend constructed on non-aarch64");
            }
        }
    }

    /// Blocked W4A8 GEMM register tile over one K-*group*: `acc`
    /// (rows × [`GEMM_NB`], fully overwritten) = `x` (rows of `kg`
    /// activations at row stride `stride`) · `blk` (a packed-nibble
    /// K-major block, two i4 codes per byte: low nibble = even K row,
    /// high nibble = odd K row, sign4-decoded `(nib ^ 8) − 8`).
    ///
    /// `blk` must start at an even K row of the packed layout (the
    /// group offset in bytes is `(g·G/2)·NB` — per-group packing keeps
    /// groups even-sized so nibble pairs never straddle a group).
    /// All accumulation is exact i32 (|i4·i8| ≤ 2¹⁰, see
    /// [`MAX_SAFE_K_I4`]), so every backend is bit-identical to the
    /// naive decode-then-multiply loop.
    pub fn gemm_rows_i4(
        self,
        x: &[i8],
        kg: usize,
        stride: usize,
        rows: usize,
        blk: &[u8],
        acc: &mut [i32],
    ) {
        assert!(rows >= 1 && rows <= GEMM_MR, "rows {rows} outside 1..={GEMM_MR}");
        assert!(stride >= kg, "row stride {stride} shorter than group width {kg}");
        assert!(x.len() >= (rows - 1) * stride + kg, "x tile too short");
        assert!(blk.len() >= kg.div_ceil(2) * GEMM_NB, "nibble block too short");
        assert!(acc.len() >= rows * GEMM_NB, "acc tile too short");
        match self.backend {
            KernelBackend::Scalar => scalar::gemm_rows_i4(x, kg, stride, rows, blk, acc),
            KernelBackend::Avx2 => {
                #[cfg(target_arch = "x86_64")]
                // SAFETY: Avx2 is only constructible when runtime
                // detection succeeded (try_new/for_backend/detect).
                unsafe {
                    if rows == GEMM_MR {
                        avx2::gemm_i4_x4(x, kg, stride, blk, acc);
                    } else {
                        for r in 0..rows {
                            avx2::gemm_i4_x1(&x[r * stride..], kg, blk, &mut acc[r * GEMM_NB..]);
                        }
                    }
                }
                #[cfg(not(target_arch = "x86_64"))]
                unreachable!("AVX2 backend constructed on non-x86_64");
            }
            KernelBackend::Neon => {
                #[cfg(target_arch = "aarch64")]
                // SAFETY: Neon is only constructible on aarch64, where
                // NEON is a mandatory target feature.
                unsafe {
                    for r in 0..rows {
                        neon::gemm_i4_x1(&x[r * stride..], kg, blk, &mut acc[r * GEMM_NB..]);
                    }
                }
                #[cfg(not(target_arch = "aarch64"))]
                unreachable!("NEON backend constructed on non-aarch64");
            }
        }
    }

    /// Element-wise widening multiply-accumulate:
    /// `acc[i] += a[i] as i32 * b[i] as i32` — the fused integer conv's
    /// per-tap channel sweep. Exact integers, bit-identical everywhere.
    pub fn mac_i8(self, a: &[i8], b: &[i8], acc: &mut [i32]) {
        assert_eq!(a.len(), acc.len(), "mac_i8 operand length mismatch");
        assert_eq!(b.len(), acc.len(), "mac_i8 operand length mismatch");
        match self.backend {
            KernelBackend::Scalar => scalar::mac_i8(a, b, acc),
            KernelBackend::Avx2 => {
                #[cfg(target_arch = "x86_64")]
                // SAFETY: see gemm_rows — backend implies detection.
                unsafe {
                    avx2::mac_i8(a, b, acc);
                }
                #[cfg(not(target_arch = "x86_64"))]
                unreachable!("AVX2 backend constructed on non-x86_64");
            }
            KernelBackend::Neon => {
                #[cfg(target_arch = "aarch64")]
                // SAFETY: see gemm_rows.
                unsafe {
                    neon::mac_i8(a, b, acc);
                }
                #[cfg(not(target_arch = "aarch64"))]
                unreachable!("NEON backend constructed on non-aarch64");
            }
        }
    }

    /// Scaled dequantization: `out[i] = q[i] as f32 * s` — the int8
    /// scan's per-step B/C row expansion. Per-element IEEE multiply,
    /// so SIMD lanes round exactly like the scalar loop.
    pub fn dequant_i8(self, q: &[i8], s: f32, out: &mut [f32]) {
        assert_eq!(q.len(), out.len(), "dequant_i8 length mismatch");
        match self.backend {
            KernelBackend::Scalar => scalar::dequant_i8(q, s, out),
            KernelBackend::Avx2 => {
                #[cfg(target_arch = "x86_64")]
                // SAFETY: see gemm_rows — backend implies detection.
                unsafe {
                    avx2::dequant_i8(q, s, out);
                }
                #[cfg(not(target_arch = "x86_64"))]
                unreachable!("AVX2 backend constructed on non-x86_64");
            }
            KernelBackend::Neon => {
                #[cfg(target_arch = "aarch64")]
                // SAFETY: see gemm_rows.
                unsafe {
                    neon::dequant_i8(q, s, out);
                }
                #[cfg(not(target_arch = "aarch64"))]
                unreachable!("NEON backend constructed on non-aarch64");
            }
        }
    }
}

/// Portable baseline: plain loops shaped so the compiler's
/// auto-vectorizer can work at the build's target ISA. This is the
/// semantics oracle — integer ops are exact, so the SIMD modules must
/// match it bit-for-bit.
mod scalar {
    use super::{GEMM_MR, GEMM_NB};

    pub fn gemm_rows(x: &[i8], k: usize, rows: usize, blk: &[i8], acc: &mut [i32]) {
        debug_assert!(rows <= GEMM_MR);
        for r in 0..rows {
            let xrow = &x[r * k..(r + 1) * k];
            let mut tile = [0i32; GEMM_NB];
            // K unrolled ×4 (i32 products of i8 values are exact, so
            // any grouping is bit-identical to the naive oracle)
            let kt = k & !3;
            let mut p = 0;
            while p < kt {
                let x0 = xrow[p] as i32;
                let x1 = xrow[p + 1] as i32;
                let x2 = xrow[p + 2] as i32;
                let x3 = xrow[p + 3] as i32;
                let w0 = &blk[p * GEMM_NB..p * GEMM_NB + GEMM_NB];
                let w1 = &blk[(p + 1) * GEMM_NB..(p + 1) * GEMM_NB + GEMM_NB];
                let w2 = &blk[(p + 2) * GEMM_NB..(p + 2) * GEMM_NB + GEMM_NB];
                let w3 = &blk[(p + 3) * GEMM_NB..(p + 3) * GEMM_NB + GEMM_NB];
                for jj in 0..GEMM_NB {
                    tile[jj] += x0 * w0[jj] as i32
                        + x1 * w1[jj] as i32
                        + x2 * w2[jj] as i32
                        + x3 * w3[jj] as i32;
                }
                p += 4;
            }
            while p < k {
                let xv = xrow[p] as i32;
                let wrow = &blk[p * GEMM_NB..p * GEMM_NB + GEMM_NB];
                for jj in 0..GEMM_NB {
                    tile[jj] += xv * wrow[jj] as i32;
                }
                p += 1;
            }
            acc[r * GEMM_NB..r * GEMM_NB + GEMM_NB].copy_from_slice(&tile);
        }
    }

    /// Sign-4 decode of a nibble: 0..=15 → −8..=7 via `(n ^ 8) − 8`.
    #[inline(always)]
    fn sign4(nib: u8) -> i32 {
        ((nib & 0x0F) as i32 ^ 8) - 8
    }

    pub fn gemm_rows_i4(x: &[i8], kg: usize, stride: usize, rows: usize, blk: &[u8], acc: &mut [i32]) {
        debug_assert!(rows <= GEMM_MR);
        for r in 0..rows {
            let xrow = &x[r * stride..r * stride + kg];
            let mut tile = [0i32; GEMM_NB];
            // one byte row = two K rows (low nibble first)
            let kpb = kg / 2;
            for pb in 0..kpb {
                let x0 = xrow[2 * pb] as i32;
                let x1 = xrow[2 * pb + 1] as i32;
                let brow = &blk[pb * GEMM_NB..pb * GEMM_NB + GEMM_NB];
                for jj in 0..GEMM_NB {
                    let b = brow[jj];
                    tile[jj] += x0 * sign4(b) + x1 * sign4(b >> 4);
                }
            }
            if kg & 1 == 1 {
                // odd group tail: the byte's high nibble is pack-time
                // zero padding; multiply it by 0 anyway so the op
                // sequence matches the SIMD odd-tail path exactly
                let x0 = xrow[kg - 1] as i32;
                let brow = &blk[kpb * GEMM_NB..kpb * GEMM_NB + GEMM_NB];
                for jj in 0..GEMM_NB {
                    tile[jj] += x0 * sign4(brow[jj]);
                }
            }
            acc[r * GEMM_NB..r * GEMM_NB + GEMM_NB].copy_from_slice(&tile);
        }
    }

    pub fn mac_i8(a: &[i8], b: &[i8], acc: &mut [i32]) {
        for ((av, bv), c) in a.iter().zip(b).zip(acc.iter_mut()) {
            *c += *av as i32 * *bv as i32;
        }
    }

    pub fn dequant_i8(q: &[i8], s: f32, out: &mut [f32]) {
        for (o, &v) in out.iter_mut().zip(q) {
            *o = v as f32 * s;
        }
    }
}

/// AVX2: weights are widened `i8 → i16` once per K-pair and reused
/// across the whole register tile; `_mm256_madd_epi16` then does the
/// widening multiply + pairwise i32 add in one instruction. Everything
/// stays exact integer, so outputs are bit-identical to [`scalar`].
#[cfg(target_arch = "x86_64")]
#[allow(unused_unsafe)] // explicit unsafe blocks for newer editions
mod avx2 {
    use super::GEMM_NB;
    use core::arch::x86_64::*;

    /// Two consecutive K activations packed as (lo: x0, hi: x1) i16s in
    /// one i32 — the `b` operand of `pmaddwd`.
    #[inline(always)]
    fn pair(x0: i8, x1: i8) -> i32 {
        ((x0 as i16 as u16 as u32) | ((x1 as i16 as u16 as u32) << 16)) as i32
    }

    /// One activation row × one K-major weight block → 16 i32 sums.
    ///
    /// # Safety
    /// Caller guarantees AVX2 is available, `x.len() >= k`,
    /// `blk.len() >= k * 16`, `acc.len() >= 16`.
    #[target_feature(enable = "avx2")]
    pub unsafe fn gemm_x1(x: &[i8], k: usize, blk: &[i8], acc: &mut [i32]) {
        // SAFETY: per the fn contract, AVX2 is enabled and the slice
        // bounds hold; all pointer loads/stores below stay inside the
        // caller-guaranteed `k * GEMM_NB` / `GEMM_NB` extents.
        unsafe {
            let bp = blk.as_ptr();
            let mut acc_lo = _mm256_setzero_si256();
            let mut acc_hi = _mm256_setzero_si256();
            let kt = k & !1;
            let mut p = 0;
            while p < kt {
                let w0 = _mm_loadu_si128(bp.add(p * GEMM_NB) as *const __m128i);
                let w1 = _mm_loadu_si128(bp.add((p + 1) * GEMM_NB) as *const __m128i);
                // interleave → (w_p[j], w_{p+1}[j]) i16 pairs per lane
                let wlo = _mm256_cvtepi8_epi16(_mm_unpacklo_epi8(w0, w1));
                let whi = _mm256_cvtepi8_epi16(_mm_unpackhi_epi8(w0, w1));
                let xv = _mm256_set1_epi32(pair(x[p], x[p + 1]));
                acc_lo = _mm256_add_epi32(acc_lo, _mm256_madd_epi16(wlo, xv));
                acc_hi = _mm256_add_epi32(acc_hi, _mm256_madd_epi16(whi, xv));
                p += 2;
            }
            if p < k {
                // odd K tail: pair the last row with a zero row
                let w0 = _mm_loadu_si128(bp.add(p * GEMM_NB) as *const __m128i);
                let z = _mm_setzero_si128();
                let wlo = _mm256_cvtepi8_epi16(_mm_unpacklo_epi8(w0, z));
                let whi = _mm256_cvtepi8_epi16(_mm_unpackhi_epi8(w0, z));
                let xv = _mm256_set1_epi32(pair(x[p], 0));
                acc_lo = _mm256_add_epi32(acc_lo, _mm256_madd_epi16(wlo, xv));
                acc_hi = _mm256_add_epi32(acc_hi, _mm256_madd_epi16(whi, xv));
            }
            _mm256_storeu_si256(acc.as_mut_ptr() as *mut __m256i, acc_lo);
            _mm256_storeu_si256(acc.as_mut_ptr().add(8) as *mut __m256i, acc_hi);
        }
    }

    /// Four activation rows × one weight block: the widened weight
    /// pair is loaded once and reused by all four rows' accumulators
    /// (10 live ymm registers: 8 accumulators + 2 weights).
    ///
    /// # Safety
    /// Caller guarantees AVX2 is available, `x.len() >= 4 * k` (row
    /// stride `k`), `blk.len() >= k * 16`, `acc.len() >= 64`.
    #[target_feature(enable = "avx2")]
    pub unsafe fn gemm_x4(x: &[i8], k: usize, blk: &[i8], acc: &mut [i32]) {
        // SAFETY: per the fn contract, AVX2 is enabled, the four rows
        // are stride-`k` within `x`, and every pointer access stays
        // inside the caller-guaranteed `k * GEMM_NB` / `4 * GEMM_NB`
        // extents.
        unsafe {
            let bp = blk.as_ptr();
            let mut a0l = _mm256_setzero_si256();
            let mut a0h = _mm256_setzero_si256();
            let mut a1l = _mm256_setzero_si256();
            let mut a1h = _mm256_setzero_si256();
            let mut a2l = _mm256_setzero_si256();
            let mut a2h = _mm256_setzero_si256();
            let mut a3l = _mm256_setzero_si256();
            let mut a3h = _mm256_setzero_si256();
            let kt = k & !1;
            let mut p = 0;
            while p < kt {
                let w0 = _mm_loadu_si128(bp.add(p * GEMM_NB) as *const __m128i);
                let w1 = _mm_loadu_si128(bp.add((p + 1) * GEMM_NB) as *const __m128i);
                let wlo = _mm256_cvtepi8_epi16(_mm_unpacklo_epi8(w0, w1));
                let whi = _mm256_cvtepi8_epi16(_mm_unpackhi_epi8(w0, w1));
                let x0 = _mm256_set1_epi32(pair(x[p], x[p + 1]));
                a0l = _mm256_add_epi32(a0l, _mm256_madd_epi16(wlo, x0));
                a0h = _mm256_add_epi32(a0h, _mm256_madd_epi16(whi, x0));
                let x1 = _mm256_set1_epi32(pair(x[k + p], x[k + p + 1]));
                a1l = _mm256_add_epi32(a1l, _mm256_madd_epi16(wlo, x1));
                a1h = _mm256_add_epi32(a1h, _mm256_madd_epi16(whi, x1));
                let x2 = _mm256_set1_epi32(pair(x[2 * k + p], x[2 * k + p + 1]));
                a2l = _mm256_add_epi32(a2l, _mm256_madd_epi16(wlo, x2));
                a2h = _mm256_add_epi32(a2h, _mm256_madd_epi16(whi, x2));
                let x3 = _mm256_set1_epi32(pair(x[3 * k + p], x[3 * k + p + 1]));
                a3l = _mm256_add_epi32(a3l, _mm256_madd_epi16(wlo, x3));
                a3h = _mm256_add_epi32(a3h, _mm256_madd_epi16(whi, x3));
                p += 2;
            }
            if p < k {
                let w0 = _mm_loadu_si128(bp.add(p * GEMM_NB) as *const __m128i);
                let z = _mm_setzero_si128();
                let wlo = _mm256_cvtepi8_epi16(_mm_unpacklo_epi8(w0, z));
                let whi = _mm256_cvtepi8_epi16(_mm_unpackhi_epi8(w0, z));
                let x0 = _mm256_set1_epi32(pair(x[p], 0));
                a0l = _mm256_add_epi32(a0l, _mm256_madd_epi16(wlo, x0));
                a0h = _mm256_add_epi32(a0h, _mm256_madd_epi16(whi, x0));
                let x1 = _mm256_set1_epi32(pair(x[k + p], 0));
                a1l = _mm256_add_epi32(a1l, _mm256_madd_epi16(wlo, x1));
                a1h = _mm256_add_epi32(a1h, _mm256_madd_epi16(whi, x1));
                let x2 = _mm256_set1_epi32(pair(x[2 * k + p], 0));
                a2l = _mm256_add_epi32(a2l, _mm256_madd_epi16(wlo, x2));
                a2h = _mm256_add_epi32(a2h, _mm256_madd_epi16(whi, x2));
                let x3 = _mm256_set1_epi32(pair(x[3 * k + p], 0));
                a3l = _mm256_add_epi32(a3l, _mm256_madd_epi16(wlo, x3));
                a3h = _mm256_add_epi32(a3h, _mm256_madd_epi16(whi, x3));
            }
            let ap = acc.as_mut_ptr();
            _mm256_storeu_si256(ap as *mut __m256i, a0l);
            _mm256_storeu_si256(ap.add(8) as *mut __m256i, a0h);
            _mm256_storeu_si256(ap.add(16) as *mut __m256i, a1l);
            _mm256_storeu_si256(ap.add(24) as *mut __m256i, a1h);
            _mm256_storeu_si256(ap.add(32) as *mut __m256i, a2l);
            _mm256_storeu_si256(ap.add(40) as *mut __m256i, a2h);
            _mm256_storeu_si256(ap.add(48) as *mut __m256i, a3l);
            _mm256_storeu_si256(ap.add(56) as *mut __m256i, a3h);
        }
    }

    /// Decode a 16-byte packed-nibble row into its two i8 weight rows
    /// ((even K, odd K)): mask / shift out each nibble, then the sign4
    /// fix `(n ^ 8) − 8` applied lane-wise.
    ///
    /// # Safety
    /// Caller guarantees AVX2 is available.
    #[target_feature(enable = "avx2")]
    #[inline]
    unsafe fn nib_rows(b: __m128i) -> (__m128i, __m128i) {
        // SAFETY: pure register arithmetic; AVX2 enabled per contract.
        unsafe {
            let m = _mm_set1_epi8(0x0F);
            let eight = _mm_set1_epi8(8);
            let lo = _mm_and_si128(b, m);
            let hi = _mm_and_si128(_mm_srli_epi16::<4>(b), m);
            (
                _mm_sub_epi8(_mm_xor_si128(lo, eight), eight),
                _mm_sub_epi8(_mm_xor_si128(hi, eight), eight),
            )
        }
    }

    /// One activation row × one packed-nibble K-group block → 16 i32
    /// sums. One 128-bit load yields TWO K rows (the nibble payoff:
    /// half the weight traffic of the i8 kernel), which are exactly the
    /// K-pair `pmaddwd` wants.
    ///
    /// # Safety
    /// Caller guarantees AVX2 is available, `x.len() >= kg`,
    /// `blk.len() >= ceil(kg/2) * 16`, `acc.len() >= 16`.
    #[target_feature(enable = "avx2")]
    pub unsafe fn gemm_i4_x1(x: &[i8], kg: usize, blk: &[u8], acc: &mut [i32]) {
        // SAFETY: per the fn contract, AVX2 is enabled and every
        // pointer access stays inside the caller-guaranteed
        // `ceil(kg/2) * GEMM_NB` / GEMM_NB extents.
        unsafe {
            let bp = blk.as_ptr();
            let mut acc_lo = _mm256_setzero_si256();
            let mut acc_hi = _mm256_setzero_si256();
            let kpb = kg / 2;
            for pb in 0..kpb {
                let (w0, w1) = nib_rows(_mm_loadu_si128(bp.add(pb * GEMM_NB) as *const __m128i));
                let wlo = _mm256_cvtepi8_epi16(_mm_unpacklo_epi8(w0, w1));
                let whi = _mm256_cvtepi8_epi16(_mm_unpackhi_epi8(w0, w1));
                let xv = _mm256_set1_epi32(pair(x[2 * pb], x[2 * pb + 1]));
                acc_lo = _mm256_add_epi32(acc_lo, _mm256_madd_epi16(wlo, xv));
                acc_hi = _mm256_add_epi32(acc_hi, _mm256_madd_epi16(whi, xv));
            }
            if kg & 1 == 1 {
                // odd tail: the high nibble is pack-time zero padding
                // and the second activation is forced to 0 — exact
                // either way
                let (w0, w1) = nib_rows(_mm_loadu_si128(bp.add(kpb * GEMM_NB) as *const __m128i));
                let wlo = _mm256_cvtepi8_epi16(_mm_unpacklo_epi8(w0, w1));
                let whi = _mm256_cvtepi8_epi16(_mm_unpackhi_epi8(w0, w1));
                let xv = _mm256_set1_epi32(pair(x[kg - 1], 0));
                acc_lo = _mm256_add_epi32(acc_lo, _mm256_madd_epi16(wlo, xv));
                acc_hi = _mm256_add_epi32(acc_hi, _mm256_madd_epi16(whi, xv));
            }
            _mm256_storeu_si256(acc.as_mut_ptr() as *mut __m256i, acc_lo);
            _mm256_storeu_si256(acc.as_mut_ptr().add(8) as *mut __m256i, acc_hi);
        }
    }

    /// Four activation rows × one packed-nibble block: each decoded
    /// nibble pair is widened once and reused by all four rows'
    /// accumulators — the W4A8 decode-path workhorse.
    ///
    /// # Safety
    /// Caller guarantees AVX2 is available, `x.len() >= 3 * stride +
    /// kg` (row stride `stride >= kg`), `blk.len() >= ceil(kg/2) * 16`,
    /// `acc.len() >= 64`.
    #[target_feature(enable = "avx2")]
    pub unsafe fn gemm_i4_x4(x: &[i8], kg: usize, stride: usize, blk: &[u8], acc: &mut [i32]) {
        // SAFETY: per the fn contract, AVX2 is enabled, the four rows
        // are stride-`stride` within `x`, and every pointer access
        // stays inside the caller-guaranteed extents.
        unsafe {
            let bp = blk.as_ptr();
            let mut a0l = _mm256_setzero_si256();
            let mut a0h = _mm256_setzero_si256();
            let mut a1l = _mm256_setzero_si256();
            let mut a1h = _mm256_setzero_si256();
            let mut a2l = _mm256_setzero_si256();
            let mut a2h = _mm256_setzero_si256();
            let mut a3l = _mm256_setzero_si256();
            let mut a3h = _mm256_setzero_si256();
            let kpb = kg / 2;
            for pb in 0..kpb {
                let (w0, w1) = nib_rows(_mm_loadu_si128(bp.add(pb * GEMM_NB) as *const __m128i));
                let wlo = _mm256_cvtepi8_epi16(_mm_unpacklo_epi8(w0, w1));
                let whi = _mm256_cvtepi8_epi16(_mm_unpackhi_epi8(w0, w1));
                let p = 2 * pb;
                let x0 = _mm256_set1_epi32(pair(x[p], x[p + 1]));
                a0l = _mm256_add_epi32(a0l, _mm256_madd_epi16(wlo, x0));
                a0h = _mm256_add_epi32(a0h, _mm256_madd_epi16(whi, x0));
                let x1 = _mm256_set1_epi32(pair(x[stride + p], x[stride + p + 1]));
                a1l = _mm256_add_epi32(a1l, _mm256_madd_epi16(wlo, x1));
                a1h = _mm256_add_epi32(a1h, _mm256_madd_epi16(whi, x1));
                let x2 = _mm256_set1_epi32(pair(x[2 * stride + p], x[2 * stride + p + 1]));
                a2l = _mm256_add_epi32(a2l, _mm256_madd_epi16(wlo, x2));
                a2h = _mm256_add_epi32(a2h, _mm256_madd_epi16(whi, x2));
                let x3 = _mm256_set1_epi32(pair(x[3 * stride + p], x[3 * stride + p + 1]));
                a3l = _mm256_add_epi32(a3l, _mm256_madd_epi16(wlo, x3));
                a3h = _mm256_add_epi32(a3h, _mm256_madd_epi16(whi, x3));
            }
            if kg & 1 == 1 {
                let (w0, w1) = nib_rows(_mm_loadu_si128(bp.add(kpb * GEMM_NB) as *const __m128i));
                let wlo = _mm256_cvtepi8_epi16(_mm_unpacklo_epi8(w0, w1));
                let whi = _mm256_cvtepi8_epi16(_mm_unpackhi_epi8(w0, w1));
                let p = kg - 1;
                let x0 = _mm256_set1_epi32(pair(x[p], 0));
                a0l = _mm256_add_epi32(a0l, _mm256_madd_epi16(wlo, x0));
                a0h = _mm256_add_epi32(a0h, _mm256_madd_epi16(whi, x0));
                let x1 = _mm256_set1_epi32(pair(x[stride + p], 0));
                a1l = _mm256_add_epi32(a1l, _mm256_madd_epi16(wlo, x1));
                a1h = _mm256_add_epi32(a1h, _mm256_madd_epi16(whi, x1));
                let x2 = _mm256_set1_epi32(pair(x[2 * stride + p], 0));
                a2l = _mm256_add_epi32(a2l, _mm256_madd_epi16(wlo, x2));
                a2h = _mm256_add_epi32(a2h, _mm256_madd_epi16(whi, x2));
                let x3 = _mm256_set1_epi32(pair(x[3 * stride + p], 0));
                a3l = _mm256_add_epi32(a3l, _mm256_madd_epi16(wlo, x3));
                a3h = _mm256_add_epi32(a3h, _mm256_madd_epi16(whi, x3));
            }
            let ap = acc.as_mut_ptr();
            _mm256_storeu_si256(ap as *mut __m256i, a0l);
            _mm256_storeu_si256(ap.add(8) as *mut __m256i, a0h);
            _mm256_storeu_si256(ap.add(16) as *mut __m256i, a1l);
            _mm256_storeu_si256(ap.add(24) as *mut __m256i, a1h);
            _mm256_storeu_si256(ap.add(32) as *mut __m256i, a2l);
            _mm256_storeu_si256(ap.add(40) as *mut __m256i, a2h);
            _mm256_storeu_si256(ap.add(48) as *mut __m256i, a3l);
            _mm256_storeu_si256(ap.add(56) as *mut __m256i, a3h);
        }
    }

    /// # Safety
    /// Caller guarantees AVX2 is available and the three slices have
    /// equal length.
    #[target_feature(enable = "avx2")]
    pub unsafe fn mac_i8(a: &[i8], b: &[i8], acc: &mut [i32]) {
        // SAFETY: per the fn contract, AVX2 is enabled and all three
        // slices share `acc.len()`; the vector loop touches `i..i+16`
        // only while `i + 16 <= n`.
        unsafe {
            let n = acc.len();
            let mut i = 0;
            while i + 16 <= n {
                let pa = a.as_ptr().add(i) as *const __m128i;
                let pb = b.as_ptr().add(i) as *const __m128i;
                let va = _mm256_cvtepi8_epi16(_mm_loadu_si128(pa));
                let vb = _mm256_cvtepi8_epi16(_mm_loadu_si128(pb));
                // |i8·i8| ≤ 16384 < 2^15, so the low-16 product is exact
                let prod = _mm256_mullo_epi16(va, vb);
                let lo = _mm256_cvtepi16_epi32(_mm256_castsi256_si128(prod));
                let hi = _mm256_cvtepi16_epi32(_mm256_extracti128_si256::<1>(prod));
                let p0 = acc.as_mut_ptr().add(i);
                let p1 = p0.add(8);
                _mm256_storeu_si256(
                    p0 as *mut __m256i,
                    _mm256_add_epi32(_mm256_loadu_si256(p0 as *const __m256i), lo),
                );
                _mm256_storeu_si256(
                    p1 as *mut __m256i,
                    _mm256_add_epi32(_mm256_loadu_si256(p1 as *const __m256i), hi),
                );
                i += 16;
            }
            while i < n {
                acc[i] += a[i] as i32 * b[i] as i32;
                i += 1;
            }
        }
    }

    /// # Safety
    /// Caller guarantees AVX2 is available and `q.len() == out.len()`.
    #[target_feature(enable = "avx2")]
    pub unsafe fn dequant_i8(q: &[i8], s: f32, out: &mut [f32]) {
        // SAFETY: per the fn contract, AVX2 is enabled and
        // `q.len() == out.len()`; the vector loop touches `i..i+8`
        // only while `i + 8 <= n`.
        unsafe {
            let n = out.len();
            let vs = _mm256_set1_ps(s);
            let mut i = 0;
            while i + 8 <= n {
                let v = _mm256_cvtepi8_epi32(_mm_loadl_epi64(q.as_ptr().add(i) as *const __m128i));
                let f = _mm256_mul_ps(_mm256_cvtepi32_ps(v), vs);
                _mm256_storeu_ps(out.as_mut_ptr().add(i), f);
                i += 8;
            }
            while i < n {
                out[i] = q[i] as f32 * s;
                i += 1;
            }
        }
    }
}

/// aarch64 NEON: `vmull_s8` widens i8×i8 → i16 exactly (|product| ≤
/// 16384), `vaddw_s16` folds into i32 accumulators. Bit-identical to
/// [`scalar`] for the same reason as AVX2 — everything is exact
/// integer arithmetic.
#[cfg(target_arch = "aarch64")]
#[allow(unused_unsafe)] // explicit unsafe blocks for newer editions
mod neon {
    use super::GEMM_NB;
    use core::arch::aarch64::*;

    /// # Safety
    /// Caller guarantees NEON is available (mandatory on aarch64, but
    /// declared explicitly so the dispatch contract matches AVX2) and
    /// `x.len() >= k`, `blk.len() >= k * 16`, `acc.len() >= 16`.
    #[target_feature(enable = "neon")]
    pub unsafe fn gemm_x1(x: &[i8], k: usize, blk: &[i8], acc: &mut [i32]) {
        // SAFETY: per the fn contract, NEON is enabled and every
        // pointer access stays inside the caller-guaranteed
        // `k * GEMM_NB` / 16 extents.
        unsafe {
            let bp = blk.as_ptr();
            let mut a0 = vdupq_n_s32(0);
            let mut a1 = vdupq_n_s32(0);
            let mut a2 = vdupq_n_s32(0);
            let mut a3 = vdupq_n_s32(0);
            for p in 0..k {
                let w = vld1q_s8(bp.add(p * GEMM_NB));
                let xv = vdup_n_s8(x[p]);
                let lo = vmull_s8(vget_low_s8(w), xv);
                let hi = vmull_s8(vget_high_s8(w), xv);
                a0 = vaddw_s16(a0, vget_low_s16(lo));
                a1 = vaddw_s16(a1, vget_high_s16(lo));
                a2 = vaddw_s16(a2, vget_low_s16(hi));
                a3 = vaddw_s16(a3, vget_high_s16(hi));
            }
            let ap = acc.as_mut_ptr();
            vst1q_s32(ap, a0);
            vst1q_s32(ap.add(4), a1);
            vst1q_s32(ap.add(8), a2);
            vst1q_s32(ap.add(12), a3);
        }
    }

    /// Decode a 16-byte packed-nibble row into its two i8 weight rows
    /// (even K, odd K): mask / shift out each nibble, then the sign4
    /// fix `(n ^ 8) − 8` applied lane-wise.
    ///
    /// # Safety
    /// Caller guarantees NEON is available.
    #[target_feature(enable = "neon")]
    #[inline]
    unsafe fn nib_rows(b: uint8x16_t) -> (int8x16_t, int8x16_t) {
        // SAFETY: pure register arithmetic; NEON enabled per contract.
        unsafe {
            let m = vdupq_n_u8(0x0F);
            let eight = vdupq_n_s8(8);
            let lo = vreinterpretq_s8_u8(vandq_u8(b, m));
            let hi = vreinterpretq_s8_u8(vandq_u8(vshrq_n_u8::<4>(b), m));
            (
                vsubq_s8(veorq_s8(lo, eight), eight),
                vsubq_s8(veorq_s8(hi, eight), eight),
            )
        }
    }

    /// One activation row × one packed-nibble K-group block → 16 i32
    /// sums. One 128-bit load yields TWO K rows (half the weight
    /// traffic of the i8 kernel); each decoded row goes through the
    /// same exact `vmull_s8`/`vaddw_s16` ladder as [`gemm_x1`], so the
    /// result is bit-identical to [`super::scalar::gemm_rows_i4`].
    ///
    /// # Safety
    /// Caller guarantees NEON is available, `x.len() >= kg`,
    /// `blk.len() >= ceil(kg/2) * 16`, `acc.len() >= 16`.
    #[target_feature(enable = "neon")]
    pub unsafe fn gemm_i4_x1(x: &[i8], kg: usize, blk: &[u8], acc: &mut [i32]) {
        // SAFETY: per the fn contract, NEON is enabled and every
        // pointer access stays inside the caller-guaranteed
        // `ceil(kg/2) * GEMM_NB` / 16 extents.
        unsafe {
            let bp = blk.as_ptr();
            let mut a0 = vdupq_n_s32(0);
            let mut a1 = vdupq_n_s32(0);
            let mut a2 = vdupq_n_s32(0);
            let mut a3 = vdupq_n_s32(0);
            let kpb = kg / 2;
            for pb in 0..kpb {
                let (w0, w1) = nib_rows(vld1q_u8(bp.add(pb * GEMM_NB)));
                let xv0 = vdup_n_s8(x[2 * pb]);
                let lo = vmull_s8(vget_low_s8(w0), xv0);
                let hi = vmull_s8(vget_high_s8(w0), xv0);
                a0 = vaddw_s16(a0, vget_low_s16(lo));
                a1 = vaddw_s16(a1, vget_high_s16(lo));
                a2 = vaddw_s16(a2, vget_low_s16(hi));
                a3 = vaddw_s16(a3, vget_high_s16(hi));
                let xv1 = vdup_n_s8(x[2 * pb + 1]);
                let lo = vmull_s8(vget_low_s8(w1), xv1);
                let hi = vmull_s8(vget_high_s8(w1), xv1);
                a0 = vaddw_s16(a0, vget_low_s16(lo));
                a1 = vaddw_s16(a1, vget_high_s16(lo));
                a2 = vaddw_s16(a2, vget_low_s16(hi));
                a3 = vaddw_s16(a3, vget_high_s16(hi));
            }
            if kg & 1 == 1 {
                // odd tail: the byte's high nibble is pack-time zero
                // padding — only the low-nibble K row is live
                let (w0, _) = nib_rows(vld1q_u8(bp.add(kpb * GEMM_NB)));
                let xv0 = vdup_n_s8(x[kg - 1]);
                let lo = vmull_s8(vget_low_s8(w0), xv0);
                let hi = vmull_s8(vget_high_s8(w0), xv0);
                a0 = vaddw_s16(a0, vget_low_s16(lo));
                a1 = vaddw_s16(a1, vget_high_s16(lo));
                a2 = vaddw_s16(a2, vget_low_s16(hi));
                a3 = vaddw_s16(a3, vget_high_s16(hi));
            }
            let ap = acc.as_mut_ptr();
            vst1q_s32(ap, a0);
            vst1q_s32(ap.add(4), a1);
            vst1q_s32(ap.add(8), a2);
            vst1q_s32(ap.add(12), a3);
        }
    }

    /// # Safety
    /// Caller guarantees NEON is available and the three slices have
    /// equal length.
    #[target_feature(enable = "neon")]
    pub unsafe fn mac_i8(a: &[i8], b: &[i8], acc: &mut [i32]) {
        // SAFETY: per the fn contract, NEON is enabled and all three
        // slices share `acc.len()`; the vector loop touches `i..i+8`
        // only while `i + 8 <= n`.
        unsafe {
            let n = acc.len();
            let mut i = 0;
            while i + 8 <= n {
                let prod = vmull_s8(vld1_s8(a.as_ptr().add(i)), vld1_s8(b.as_ptr().add(i)));
                let p0 = acc.as_mut_ptr().add(i);
                let p1 = p0.add(4);
                vst1q_s32(p0, vaddw_s16(vld1q_s32(p0), vget_low_s16(prod)));
                vst1q_s32(p1, vaddw_s16(vld1q_s32(p1), vget_high_s16(prod)));
                i += 8;
            }
            while i < n {
                acc[i] += a[i] as i32 * b[i] as i32;
                i += 1;
            }
        }
    }

    /// # Safety
    /// Caller guarantees NEON is available and `q.len() == out.len()`.
    #[target_feature(enable = "neon")]
    pub unsafe fn dequant_i8(q: &[i8], s: f32, out: &mut [f32]) {
        // SAFETY: per the fn contract, NEON is enabled and
        // `q.len() == out.len()`; the vector loop touches `i..i+8`
        // only while `i + 8 <= n`.
        unsafe {
            let n = out.len();
            let mut i = 0;
            while i + 8 <= n {
                let w = vmovl_s8(vld1_s8(q.as_ptr().add(i)));
                let lo = vcvtq_f32_s32(vmovl_s16(vget_low_s16(w)));
                let hi = vcvtq_f32_s32(vmovl_s16(vget_high_s16(w)));
                vst1q_f32(out.as_mut_ptr().add(i), vmulq_n_f32(lo, s));
                vst1q_f32(out.as_mut_ptr().add(i + 4), vmulq_n_f32(hi, s));
                i += 8;
            }
            while i < n {
                out[i] = q[i] as f32 * s;
                i += 1;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    fn rand_i8(r: &mut Pcg32, n: usize) -> Vec<i8> {
        (0..n).map(|_| (r.below(256) as i32 - 128) as i8).collect()
    }

    #[test]
    fn scalar_always_available_and_auto_resolves() {
        assert!(KernelBackend::Scalar.is_available());
        let avail = Kernels::available();
        assert!(avail.contains(&KernelBackend::Scalar));
        // auto must select something this machine can actually run
        assert!(avail.contains(&Kernels::auto().backend()));
        assert!(avail.contains(&Kernels::detect().backend()));
    }

    #[test]
    fn k_bound_is_tight() {
        // the proven accumulator bound, spelled out in decimal so the
        // margin to i32::MAX (= 2_147_483_647) is visible: one more
        // worst-case product (16384) would not fit.
        assert_eq!(MAX_SAFE_K, 131071);
        assert_eq!(MAX_SAFE_K as i64 * MAX_ABS_PROD_I8, 2_147_467_264);
        assert!(MAX_SAFE_K as i64 * MAX_ABS_PROD_I8 + MAX_ABS_PROD_I8 > i32::MAX as i64);
    }

    #[test]
    fn backend_labels_roundtrip() {
        for b in [KernelBackend::Scalar, KernelBackend::Avx2, KernelBackend::Neon] {
            assert_eq!(KernelBackend::parse(b.label()), Some(b));
        }
        assert_eq!(KernelBackend::parse("sse9"), None);
    }

    #[test]
    fn gemm_rows_matches_reference_every_backend() {
        // full-range i8 inputs (incl. -128·-128 edge products) across
        // odd K and every tile height
        let mut r = Pcg32::new(0x51D);
        for backend in Kernels::available() {
            let kers = Kernels::for_backend(backend);
            for k in [0usize, 1, 2, 3, 7, 16, 33, 64, 129] {
                for rows in 1..=GEMM_MR {
                    let x = rand_i8(&mut r, rows * k.max(1));
                    let blk = rand_i8(&mut r, k * GEMM_NB);
                    let mut want = vec![0i32; rows * GEMM_NB];
                    for (ri, w) in want.chunks_mut(GEMM_NB).enumerate() {
                        for (p, wrow) in blk.chunks(GEMM_NB).enumerate() {
                            let xv = x[ri * k + p] as i32;
                            for (jj, wv) in wrow.iter().enumerate() {
                                w[jj] += xv * *wv as i32;
                            }
                        }
                    }
                    let mut got = vec![7i32; rows * GEMM_NB]; // poison
                    kers.gemm_rows(&x, k, rows, &blk, &mut got);
                    assert_eq!(want, got, "{}: k={k} rows={rows}", backend.label());
                }
            }
        }
    }

    #[test]
    fn i4_k_bound_is_tight() {
        // the i4×i8 accumulator bound in decimal: 2²¹ − 1 worst-case
        // 2¹⁰ products still fit an i32, one more would not.
        assert_eq!(MAX_SAFE_K_I4, 2_097_151);
        assert_eq!(MAX_SAFE_K_I4 as i64 * MAX_ABS_PROD_I4I8, 2_147_482_624);
        assert!(MAX_SAFE_K_I4 as i64 * MAX_ABS_PROD_I4I8 + MAX_ABS_PROD_I4I8 > i32::MAX as i64);
        // 16× looser than the i8 tier, exactly
        assert_eq!(MAX_SAFE_K_I4 + 1, 16 * (MAX_SAFE_K + 1));
    }

    /// Nibble-decode reference: the dispatch contract in one loop.
    fn ref_i4(x: &[i8], kg: usize, stride: usize, rows: usize, blk: &[u8]) -> Vec<i32> {
        let sign4 = |n: u8| ((n & 0x0F) as i32 ^ 8) - 8;
        let mut want = vec![0i32; rows * GEMM_NB];
        for (ri, w) in want.chunks_mut(GEMM_NB).enumerate() {
            for p in 0..kg {
                let byte_row = &blk[(p / 2) * GEMM_NB..(p / 2) * GEMM_NB + GEMM_NB];
                let xv = x[ri * stride + p] as i32;
                for (jj, b) in byte_row.iter().enumerate() {
                    let code = if p & 1 == 0 { sign4(*b) } else { sign4(*b >> 4) };
                    w[jj] += xv * code;
                }
            }
        }
        want
    }

    #[test]
    fn gemm_rows_i4_matches_reference_every_backend() {
        // full-range i8 activations against every nibble byte value,
        // across odd group widths (pack-padding tail), strides wider
        // than the group, and every tile height
        let mut r = Pcg32::new(0x1D4);
        for backend in Kernels::available() {
            let kers = Kernels::for_backend(backend);
            for kg in [0usize, 1, 2, 3, 7, 16, 33, 64, 129] {
                for rows in 1..=GEMM_MR {
                    for extra in [0usize, 5] {
                        let stride = kg + extra;
                        let x = rand_i8(&mut r, ((rows - 1) * stride + kg).max(1));
                        let mut blk: Vec<u8> =
                            (0..kg.div_ceil(2) * GEMM_NB).map(|_| r.below(256) as u8).collect();
                        if kg & 1 == 1 {
                            // pack-time contract: odd-K tail bytes carry
                            // zero high nibbles
                            for b in &mut blk[(kg / 2) * GEMM_NB..] {
                                *b &= 0x0F;
                            }
                        }
                        let want = ref_i4(&x, kg, stride, rows, &blk);
                        let mut got = vec![7i32; rows * GEMM_NB]; // poison
                        kers.gemm_rows_i4(&x, kg, stride, rows, &blk, &mut got);
                        assert_eq!(
                            want,
                            got,
                            "{}: kg={kg} rows={rows} stride={stride}",
                            backend.label()
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn mac_and_dequant_match_scalar_every_backend() {
        let mut r = Pcg32::new(0xACC);
        let scalar = Kernels::scalar();
        for backend in Kernels::available() {
            let kers = Kernels::for_backend(backend);
            for n in [0usize, 1, 5, 8, 15, 16, 17, 64, 100] {
                let a = rand_i8(&mut r, n);
                let b = rand_i8(&mut r, n);
                let mut want: Vec<i32> = (0..n as i32).collect();
                let mut got = want.clone();
                scalar.mac_i8(&a, &b, &mut want);
                kers.mac_i8(&a, &b, &mut got);
                assert_eq!(want, got, "mac {}: n={n}", backend.label());
                let s = 0.037f32;
                let mut fw = vec![0.0f32; n];
                let mut fg = vec![1.0f32; n];
                scalar.dequant_i8(&a, s, &mut fw);
                kers.dequant_i8(&a, s, &mut fg);
                for (x, y) in fw.iter().zip(&fg) {
                    assert_eq!(x.to_bits(), y.to_bits(), "dequant {}: n={n}", backend.label());
                }
            }
        }
    }
}
