//! Rust-side quantization library: the same primitives as
//! `python/compile/quant/`, used by the pure-rust SSM/attention
//! reference simulators, the Figure 2/5/6/8/10 analyses, and the
//! coordinator's size accounting. Numerics match the python
//! implementations (cross-checked via the `.qtz` artifacts in
//! integration tests).

pub mod hadamard;
// The one sanctioned home for `unsafe` in this crate: the explicit
// SIMD backends. `quamba_audit` (and `tests/audit.rs`) enforce that
// this allow — and the crate-level `#![deny(unsafe_code)]` it opts out
// of — stay exactly here.
#[allow(unsafe_code)]
pub mod kernels;
pub mod qlinear;

pub use kernels::{
    KernelBackend, Kernels, MAX_ABS_PROD_I4I8, MAX_ABS_PROD_I8, MAX_SAFE_K, MAX_SAFE_K_I4,
};

/// Narrow a quantizer code to its i8 storage type. [`quantize_one`]
/// clamps to `[qmin, qmax] ⊆ [-128, 127]` for every nbits ≤ 8, so the
/// conversion is lossless by construction; the `debug_assert!` checks
/// that contract instead of letting a bare `as` truncate silently.
#[inline(always)]
pub fn code_to_i8(code: i32) -> i8 {
    debug_assert!(
        (i8::MIN as i32..=i8::MAX as i32).contains(&code),
        "quantizer code {code} outside i8 — nbits > 8 reached an i8 storage path"
    );
    code as i8 // audit:allow(cast) — range proven by the assert above
}

/// Pack two i4 codes (each in `−8..=7`) into one byte: low nibble =
/// `lo` (the even K row), high nibble = `hi` (the odd K row). The
/// storage dual of [`sign4`]; odd-K tails pass `hi = 0`, which decodes
/// back to 0.
#[inline(always)]
pub fn pack_nibble_pair(lo: i32, hi: i32) -> u8 {
    debug_assert!(
        (-8..=7).contains(&lo) && (-8..=7).contains(&hi),
        "i4 code pair ({lo}, {hi}) outside −8..=7 — a wider quantizer reached the nibble packer"
    );
    ((lo & 0x0F) | ((hi & 0x0F) << 4)) as u8 // audit:allow(cast) — both nibbles masked to 4 bits above
}

/// Sign-4 decode of one nibble: `0..=15 → −8..=7` via `(n ^ 8) − 8`,
/// the exact inverse of [`pack_nibble_pair`] per nibble and the same
/// lane-wise op sequence the i4 GEMM kernels use.
#[inline(always)]
pub fn sign4(nib: u8) -> i8 {
    code_to_i8((i32::from(nib & 0x0F) ^ 8) - 8)
}

/// Dequantize one i8 code: exact `i8 → f32` widening (every i8 is
/// representable) followed by a single IEEE multiply — the same op
/// sequence as the SIMD `dequant_i8` lanes, so scalar call sites stay
/// bit-identical to the kernels.
#[inline(always)]
pub fn dq_i8(code: i8, s: f32) -> f32 {
    f32::from(code) * s
}

/// Dequantize an i32 accumulator (or wide quantizer code) at scale `s`.
/// The `i32 → f32` conversion is exact for |v| ≤ 2²⁴ and correctly
/// rounded (≤ 0.5 ulp) beyond; [`MAX_SAFE_K`] bounds every accumulator
/// below 2³¹, so the conversion is always well-defined. This is the
/// documented home of the one deliberate i32→f32 `as` in quant/ssm.
#[inline(always)]
pub fn dq_i32(v: i32, s: f32) -> f32 {
    v as f32 * s // audit:allow(cast) — rounding contract documented above
}

/// Largest representable magnitude at bit-width `n` (signed symmetric).
pub fn qmax(nbits: u32) -> f32 {
    ((1i32 << (nbits - 1)) - 1) as f32
}

pub fn qmin(nbits: u32) -> f32 {
    -((1i32 << (nbits - 1)) as f32)
}

/// Symmetric scale from an absolute max (Eq. 2 of the paper).
pub fn scale_sym(amax: f32, nbits: u32) -> f32 {
    amax.max(1e-8) / qmax(nbits)
}

/// Quantize one value to the signed grid.
pub fn quantize_one(x: f32, s: f32, nbits: u32) -> i32 {
    (x / s).round().clamp(qmin(nbits), qmax(nbits)) as i32
}

/// Quantize a slice; returns i8 codes (nbits ≤ 8).
pub fn quantize_sym(xs: &[f32], s: f32, nbits: u32) -> Vec<i8> {
    debug_assert!(nbits <= 8);
    xs.iter().map(|&x| code_to_i8(quantize_one(x, s, nbits))).collect()
}

/// Quantize a slice into a caller-owned buffer (cleared + refilled).
/// Allocation-free once `out` has warmed up to capacity — the decode
/// hot path requantizes several tensors per layer per step.
pub fn quantize_sym_into(xs: &[f32], s: f32, nbits: u32, out: &mut Vec<i8>) {
    debug_assert!(nbits <= 8);
    out.clear();
    out.extend(xs.iter().map(|&x| code_to_i8(quantize_one(x, s, nbits))));
}

pub fn dequantize_sym(q: &[i8], s: f32) -> Vec<f32> {
    q.iter().map(|&v| dq_i8(v, s)).collect()
}

/// Fake-quant round trip (quantize-dequantize) in place.
pub fn fake_quant_sym(xs: &mut [f32], s: f32, nbits: u32) {
    for x in xs.iter_mut() {
        *x = dq_i32(quantize_one(*x, s, nbits), s);
    }
}

/// Absolute maximum of a slice.
pub fn amax(xs: &[f32]) -> f32 {
    xs.iter().fold(0.0f32, |m, &x| m.max(x.abs()))
}

/// The paper's percentile max (§4.2): the p-th percentile of |x|,
/// p in percent (99.999 keeps all but the top 0.001%). Linear
/// interpolation between order statistics (numpy's default), found by
/// selection rather than a full sort — this runs per-layer per-forward
/// during calibration, so it is O(n) instead of O(n log n).
pub fn percentile_amax(xs: &[f32], p: f64) -> f32 {
    if xs.is_empty() {
        return 0.0;
    }
    if p >= 100.0 {
        return amax(xs);
    }
    let mut v: Vec<f32> = xs.iter().map(|x| x.abs()).collect();
    let rank = (p / 100.0) * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let frac = (rank - lo as f64) as f32;
    let (_, lo_v, upper) = v.select_nth_unstable_by(lo, |a, b| a.partial_cmp(b).unwrap());
    let lo_v = *lo_v;
    if frac == 0.0 || upper.is_empty() {
        return lo_v;
    }
    // the (lo+1)-th order statistic is the minimum of the upper partition
    let hi_v = upper.iter().fold(f32::INFINITY, |m, &x| m.min(x));
    lo_v * (1.0 - frac) + hi_v * frac
}

/// Bounded, seeded reservoir sample (Algorithm R) feeding
/// [`percentile_amax`]: calibration over long streams keeps O(cap)
/// memory instead of retaining every T×d_inner activation. Fully
/// deterministic — the replacement draws come from a [`Pcg32`] seeded
/// at construction, so a given (seed, stream) always yields the same
/// sample. While `seen ≤ cap` the reservoir holds the stream exactly,
/// so short calibrations are bit-identical to unbounded collection.
#[derive(Debug, Clone)]
pub struct Reservoir {
    cap: usize,
    seen: u64,
    rng: crate::util::rng::Pcg32,
    vals: Vec<f32>,
}

impl Reservoir {
    pub fn new(cap: usize, seed: u64) -> Reservoir {
        assert!(cap > 0, "reservoir needs capacity");
        Reservoir { cap, seen: 0, rng: crate::util::rng::Pcg32::new(seed), vals: Vec::new() }
    }

    pub fn push(&mut self, v: f32) {
        self.seen += 1;
        if self.vals.len() < self.cap {
            self.vals.push(v);
        } else {
            // draw j uniform in [0, seen); streams beyond 2^32 elements
            // saturate the draw range (negligible bias at that scale)
            let j = self.rng.below(self.seen.min(u32::MAX as u64) as u32) as usize;
            if j < self.cap {
                self.vals[j] = v;
            }
        }
    }

    pub fn extend_from_slice(&mut self, xs: &[f32]) {
        for &v in xs {
            self.push(v);
        }
    }

    /// The retained sample (== the full stream while `seen ≤ cap`).
    pub fn values(&self) -> &[f32] {
        &self.vals
    }

    /// Total elements offered to the reservoir.
    pub fn seen(&self) -> u64 {
        self.seen
    }
}

/// Asymmetric parameters from observed (min, max).
pub fn asym_params(xmin: f32, xmax: f32, nbits: u32) -> (f32, i32) {
    let lo = xmin.min(0.0);
    let hi = xmax.max(0.0);
    let s = ((hi - lo) as f64 / ((1u32 << nbits) - 1) as f64).max(1e-8) as f32;
    let z = (-lo / s).round() as i32;
    (s, z)
}

pub fn fake_quant_asym(xs: &mut [f32], s: f32, z: i32, nbits: u32) {
    let hi = ((1u32 << nbits) - 1) as f32;
    for x in xs.iter_mut() {
        let q = ((*x / s).round() + z as f32).clamp(0.0, hi);
        *x = (q - z as f32) * s;
    }
}

/// FP8 fake-quantization (paper §F "other alternatives": E4M3/E5M2 on
/// NVIDIA Hopper as a possible SSM-input format — probed here as the
/// `ext_fp8` extension experiment). Rounds to the nearest representable
/// value of an (exp_bits, man_bits) minifloat with IEEE-style bias,
/// subnormals, and saturation to the max finite value.
pub fn fake_quant_fp8_one(x: f32, exp_bits: i32, man_bits: i32) -> f32 {
    if x.is_nan() {
        // deterministic saturation: the int8 path maps NaN to code 0
        // (`NaN as i32 == 0` after the clamp); mirror that here rather
        // than letting NaN propagate through calibrated scales
        return 0.0;
    }
    if x.is_infinite() {
        return x.signum() * fp8_max(exp_bits, man_bits);
    }
    if x == 0.0 {
        return 0.0;
    }
    let bias = (1 << (exp_bits - 1)) - 1;
    let e_min = 1 - bias; // smallest normal exponent
    let sign = x.signum();
    let a = x.abs();
    let e = a.log2().floor() as i32;
    let e_clamped = e.max(e_min);
    // quantize the significand on a 2^man_bits grid at exponent e
    let scale = 2f32.powi(e_clamped - man_bits);
    let mut q = (a / scale).round() * scale;
    // rounding can carry the significand up to 2.0 (e.g. 1.99 → 16/8 at
    // E4M3): renormalize onto the next exponent's (coarser) grid so the
    // result is a representable mantissa, not an off-grid 2.0·2^e
    if q >= 2f32.powi(e_clamped + 1) {
        let scale2 = 2f32.powi(e_clamped + 1 - man_bits);
        q = (a / scale2).round() * scale2;
    }
    let max = fp8_max(exp_bits, man_bits);
    sign * q.min(max)
}

/// Largest finite value of the minifloat format. E4M3 follows the OCP
/// convention (top exponent kept for normals, all-ones mantissa is the
/// NaN code): max = 1.75·2^8 = 448. Everything else is IEEE-style (top
/// exponent reserved for inf/NaN): E5M2 max = 1.75·2^15 = 57344.
fn fp8_max(exp_bits: i32, man_bits: i32) -> f32 {
    let bias = (1 << (exp_bits - 1)) - 1;
    if (exp_bits, man_bits) == (4, 3) {
        let e_max = (1 << exp_bits) - 2 - bias + 1;
        (2.0 - 2.0 * 2f32.powi(-man_bits)) * 2f32.powi(e_max)
    } else {
        let e_max = (1 << exp_bits) - 2 - bias;
        (2.0 - 2f32.powi(-man_bits)) * 2f32.powi(e_max)
    }
}

/// In-place FP8 round trip with a per-tensor scale into the format's
/// dynamic range (like the int8 path's amax scaling).
pub fn fake_quant_fp8(xs: &mut [f32], exp_bits: i32, man_bits: i32) {
    let am = amax(xs).max(1e-8);
    let s = fp8_max(exp_bits, man_bits) / am;
    for x in xs.iter_mut() {
        *x = fake_quant_fp8_one(*x * s, exp_bits, man_bits) / s;
    }
}

/// Mean-squared quantization error of a fake-quant round trip.
pub fn mse_of_quant(xs: &[f32], s: f32, nbits: u32) -> f64 {
    let mut acc = 0.0f64;
    for &x in xs {
        let xq = dq_i32(quantize_one(x, s, nbits), s);
        let d = (x - xq) as f64;
        acc += d * d;
    }
    acc / xs.len().max(1) as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quantize_roundtrip_small_error() {
        let xs: Vec<f32> = (0..1000).map(|i| ((i as f32) / 100.0).sin()).collect();
        let s = scale_sym(amax(&xs), 8);
        let q = quantize_sym(&xs, s, 8);
        let d = dequantize_sym(&q, s);
        for (a, b) in xs.iter().zip(&d) {
            assert!((a - b).abs() <= s * 0.5 + 1e-7);
        }
    }

    #[test]
    fn percentile_clips_outliers() {
        let mut xs = vec![0.5f32; 10_000];
        xs[0] = 100.0; // one massive outlier
        let naive = scale_sym(amax(&xs), 8);
        let clipped = scale_sym(percentile_amax(&xs, 99.9), 8);
        assert!(clipped < naive / 50.0, "clipped={clipped} naive={naive}");
    }

    #[test]
    fn reservoir_exact_under_cap_and_deterministic() {
        let mut r = crate::util::rng::Pcg32::new(5);
        let xs: Vec<f32> = (0..100).map(|_| r.normal()).collect();
        let mut a = Reservoir::new(128, 1);
        a.extend_from_slice(&xs);
        assert_eq!(a.values(), &xs[..], "under cap the reservoir is the stream");
        assert_eq!(a.seen(), 100);
        let mut b = Reservoir::new(16, 9);
        let mut c = Reservoir::new(16, 9);
        b.extend_from_slice(&xs);
        c.extend_from_slice(&xs);
        assert_eq!(b.values(), c.values(), "same seed + stream => same sample");
        assert_eq!(b.values().len(), 16);
    }

    #[test]
    fn reservoir_percentile_close_to_exact() {
        // satellite acceptance: the scale produced from a bounded
        // reservoir stays within tolerance of the exact percentile.
        // margins validated against an independent numpy simulation of
        // this exact Pcg32 stream: rel err 6.1e-4 (p=99) / 2.0e-4
        // (p=99.9) vs the 5% budget.
        let mut g = crate::util::rng::Pcg32::new(42);
        let n = 100_000;
        let stream: Vec<f32> = (0..n).map(|_| g.f32()).collect();
        let mut res = Reservoir::new(8192, 0x5EED);
        res.extend_from_slice(&stream);
        assert_eq!(res.values().len(), 8192);
        assert_eq!(res.seen(), n as u64);
        for p in [99.0f64, 99.9] {
            let exact = scale_sym(percentile_amax(&stream, p), 8);
            let approx = scale_sym(percentile_amax(res.values(), p), 8);
            let rel = (exact - approx).abs() / exact;
            assert!(rel < 0.05, "p={p}: reservoir scale off by {rel}");
        }
    }

    #[test]
    fn asym_covers_range() {
        let (s, z) = asym_params(-1.0, 3.0, 8);
        let mut xs = vec![-1.0f32, 0.0, 3.0];
        fake_quant_asym(&mut xs, s, z, 8);
        assert!((xs[0] + 1.0).abs() < 0.05);
        assert!(xs[1].abs() < 0.02);
        assert!((xs[2] - 3.0).abs() < 0.05);
    }

    #[test]
    fn fp8_exact_on_representable_values() {
        // powers of two and small integers are exactly representable
        for v in [1.0f32, 2.0, 0.5, 0.25, 3.0, -6.0] {
            assert_eq!(fake_quant_fp8_one(v, 4, 3), v, "E4M3 {v}");
            assert_eq!(fake_quant_fp8_one(v, 5, 2), v, "E5M2 {v}");
        }
    }

    #[test]
    fn fp8_relative_error_bounded() {
        let mut r = crate::util::rng::Pcg32::new(9);
        for _ in 0..2000 {
            let x = r.normal() * 10f32.powf(r.range_f32(-2.0, 2.0));
            let q = fake_quant_fp8_one(x, 4, 3);
            if x.abs() < fp8_max(4, 3) && x.abs() > 2f32.powi(-6) {
                let rel = (x - q).abs() / x.abs();
                assert!(rel <= 2f32.powi(-3) / 2.0 + 1e-6, "x={x} q={q} rel={rel}");
            }
        }
    }

    #[test]
    fn fp8_better_than_int8_on_outlier_skewed_data() {
        // the paper's §F motivation: exponent formats keep small values
        // when the range is skewed by outliers
        let mut r = crate::util::rng::Pcg32::new(4);
        let mut xs: Vec<f32> = (0..4096).map(|_| 0.01 * r.normal()).collect();
        xs[0] = 50.0;
        let mut int8 = xs.clone();
        let s = scale_sym(amax(&xs), 8);
        fake_quant_sym(&mut int8, s, 8);
        let mut fp8 = xs.clone();
        fake_quant_fp8(&mut fp8, 4, 3);
        let err = |ys: &[f32]| -> f64 {
            xs.iter().zip(ys).skip(1).map(|(a, b)| ((a - b) as f64).powi(2)).sum()
        };
        assert!(err(&fp8) < err(&int8) / 10.0);
    }

    #[test]
    fn fp8_nonfinite_saturates_deterministically() {
        // NaN maps to 0 (the int8 path's `NaN as i32 == 0` semantics);
        // infinities saturate to the signed finite max
        assert_eq!(fake_quant_fp8_one(f32::NAN, 4, 3), 0.0);
        assert_eq!(fake_quant_fp8_one(f32::NAN, 5, 2), 0.0);
        assert_eq!(fake_quant_fp8_one(f32::INFINITY, 4, 3), 448.0);
        assert_eq!(fake_quant_fp8_one(f32::NEG_INFINITY, 4, 3), -448.0);
        assert_eq!(fake_quant_fp8_one(f32::INFINITY, 5, 2), 57344.0);
    }

    #[test]
    fn fp8_renormalizes_significand_carry() {
        // values just under a power of two round up across the exponent
        // boundary; the result must sit on the next exponent's grid
        assert_eq!(fake_quant_fp8_one(1.99, 4, 3), 2.0);
        assert_eq!(fake_quant_fp8_one(-1.99, 4, 3), -2.0);
        assert_eq!(fake_quant_fp8_one(1.99, 5, 2), 2.0);
        assert_eq!(fake_quant_fp8_one(3.98, 4, 3), 4.0);
        // and mid-grid values still round to the fine grid
        assert_eq!(fake_quant_fp8_one(1.90, 4, 3), 1.875);
    }

    #[test]
    fn fp8_standard_maxima() {
        // OCP E4M3 max = 448, IEEE E5M2 max = 57344
        assert_eq!(fp8_max(4, 3), 448.0);
        assert_eq!(fp8_max(5, 2), 57344.0);
        assert_eq!(fake_quant_fp8_one(448.0, 4, 3), 448.0);
        assert_eq!(fake_quant_fp8_one(1.0e4, 4, 3), 448.0);
        assert_eq!(fake_quant_fp8_one(57344.0, 5, 2), 57344.0);
        assert_eq!(fake_quant_fp8_one(1.0e9, 5, 2), 57344.0);
    }

    /// Reference percentile (full sort + interpolation) the selection
    /// implementation must match exactly.
    fn percentile_amax_sorted(xs: &[f32], p: f64) -> f32 {
        let mut v: Vec<f32> = xs.iter().map(|x| x.abs()).collect();
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let rank = (p / 100.0) * (v.len() - 1) as f64;
        let lo = rank.floor() as usize;
        let hi = rank.ceil() as usize;
        let frac = (rank - lo as f64) as f32;
        v[lo] * (1.0 - frac) + v[hi] * frac
    }

    #[test]
    fn percentile_selection_matches_sorted() {
        let mut r = crate::util::rng::Pcg32::new(17);
        for n in [1usize, 2, 3, 10, 100, 1000, 4097] {
            let xs: Vec<f32> = (0..n).map(|_| r.normal() * 4.0).collect();
            for p in [0.0, 10.0, 50.0, 90.0, 99.0, 99.9, 99.999, 100.0] {
                let fast = percentile_amax(&xs, p);
                let slow = if p >= 100.0 { amax(&xs) } else { percentile_amax_sorted(&xs, p) };
                assert_eq!(fast, slow, "n={n} p={p}");
            }
        }
    }

    #[test]
    fn four_bit_coarser_than_eight() {
        let xs: Vec<f32> = (0..512).map(|i| (i as f32 / 37.0).cos()).collect();
        let s8 = scale_sym(amax(&xs), 8);
        let s4 = scale_sym(amax(&xs), 4);
        assert!(mse_of_quant(&xs, s4, 4) > 10.0 * mse_of_quant(&xs, s8, 8));
    }

    #[test]
    fn nibble_pack_roundtrips_every_code_pair() {
        for lo in -8..=7i32 {
            for hi in -8..=7i32 {
                let b = pack_nibble_pair(lo, hi);
                assert_eq!(sign4(b) as i32, lo, "low nibble of ({lo}, {hi})");
                assert_eq!(sign4(b >> 4) as i32, hi, "high nibble of ({lo}, {hi})");
            }
        }
        // the odd-K pad convention: a zero high nibble decodes to 0
        assert_eq!(sign4(pack_nibble_pair(-8, 0) >> 4), 0);
    }
}
