//! W8A8 linear execution: i8 × i8 → i32 accumulate, dequantized by
//! `s_x · s_w` (+ f32 bias) — the rust-native mirror of
//! `python/compile/kernels/matmul_i8.py` (the CUTLASS-INT8 stand-in on
//! the deployment path). Unlike the fake-quant instrumentation in
//! [`crate::quant`], this path really executes in the integer domain,
//! so the native serving backend carries int8 weights end-to-end.
//!
//! Two kernels share the same integer semantics:
//!
//! * [`matmul_i8`] — the naive triple loop, kept as the *test oracle*;
//! * [`matmul_i8_blocked`] — the hot-path kernel over a
//!   [`PackedWeightI8`] column-blocked, K-major layout (packed once at
//!   [`QLinear`] construction), executed through the
//!   [`Kernels`] dispatch layer ([`crate::quant::kernels`]): explicit
//!   AVX2/NEON widening multiply-adds with a [`GEMM_MR`]-row register
//!   tile, or the portable scalar fallback. All accumulation is exact
//!   i32, so every backend is **bit-identical** to the oracle for
//!   every shape (property-tested in `rust/tests/kernel_parity.rs`).
//!
//! The `*_into` methods take caller-owned scratch so the decode hot
//! path performs no heap allocation per call (see
//! [`crate::ssm::step::StepScratch`]).
//!
//! A second weight tier halves the bytes again: [`PackedWeightI4`] /
//! [`QLinearI4`] store two i4 codes per byte in the same column-blocked
//! K-major layout, with per-group scales along K ([`I4_GROUP_K`]) to
//! hold accuracy at 4 bits (Q-S5 / QS4D recipe). Activations stay int8
//! (§4.2 percentile clipping is tuned for 8-bit activations); only the
//! weight side narrows. [`matmul_w4a8`] executes group-by-group with
//! exact i32 accumulation per group (|i4·i8| ≤ 2¹⁰, see
//! [`crate::quant::MAX_SAFE_K_I4`]) and a fixed per-element f32
//! epilogue order, so every backend is bit-identical to the retained
//! naive oracle [`matmul_w4a8_ref`].

use crate::quant;
use crate::quant::kernels::Kernels;

pub use crate::quant::kernels::{GEMM_MR, GEMM_NB};

/// out (M×N) i32 = x_q (M×K) i8 · w_q (K×N) i8, i32 accumulation.
/// Naive triple loop — retained as the bit-exactness oracle for
/// [`matmul_i8_blocked`].
pub fn matmul_i8(x_q: &[i8], w_q: &[i8], m: usize, k: usize, n: usize, out: &mut [i32]) {
    assert_eq!(x_q.len(), m * k);
    assert_eq!(w_q.len(), k * n);
    assert_eq!(out.len(), m * n);
    out.fill(0);
    for i in 0..m {
        for p in 0..k {
            let xv = x_q[i * k + p] as i32;
            if xv == 0 {
                continue;
            }
            let wrow = &w_q[p * n..(p + 1) * n];
            let orow = &mut out[i * n..(i + 1) * n];
            for j in 0..n {
                orow[j] += xv * wrow[j] as i32;
            }
        }
    }
}

/// Int8 weight repacked for the blocked kernel: the (K×N) matrix is
/// split into ⌈N/NB⌉ column blocks of width [`GEMM_NB`]; each block is
/// stored K-major (`block[p·NB + jj] = w[p·N + jb·NB + jj]`), zero-
/// padded in the tail block. A row of activations then streams each
/// block with unit stride while NB running sums stay in registers.
pub struct PackedWeightI8 {
    pub k: usize,
    pub n: usize,
    data: Vec<i8>,
}

impl PackedWeightI8 {
    pub fn pack(w_q: &[i8], k: usize, n: usize) -> PackedWeightI8 {
        assert_eq!(w_q.len(), k * n);
        let nb = GEMM_NB;
        let nblk = n.div_ceil(nb);
        let mut data = vec![0i8; nblk * k * nb];
        for jb in 0..nblk {
            let jlo = jb * nb;
            let jw = nb.min(n - jlo);
            let base = jb * k * nb;
            for p in 0..k {
                data[base + p * nb..base + p * nb + jw]
                    .copy_from_slice(&w_q[p * n + jlo..p * n + jlo + jw]);
            }
        }
        PackedWeightI8 { k, n, data }
    }

    /// Packed bytes (≥ k·n due to tail-block padding).
    pub fn packed_bytes(&self) -> usize {
        self.data.len()
    }
}

/// Blocked int8 GEMM: out (M×N) i32 = x_q (M×K) i8 · packed (K×N) i8,
/// executed on the process-wide auto-selected backend
/// ([`Kernels::auto`]). See [`matmul_i8_blocked_with`].
pub fn matmul_i8_blocked(x_q: &[i8], w: &PackedWeightI8, m: usize, out: &mut [i32]) {
    matmul_i8_blocked_with(Kernels::auto(), x_q, w, m, out)
}

/// Blocked int8 GEMM on an explicit kernel backend.
///
/// Loop order (block, row-tile, K): each K-major column block is
/// streamed once per [`GEMM_MR`]-row activation tile with the
/// rows × [`GEMM_NB`] i32 accumulators held in registers
/// ([`Kernels::gemm_rows`]), so `out` is written exactly once per
/// element (the naive kernel re-reads and re-writes each output row K
/// times). Integer accumulation is exact, therefore every backend is
/// bit-identical to [`matmul_i8`].
pub fn matmul_i8_blocked_with(
    kers: Kernels,
    x_q: &[i8],
    w: &PackedWeightI8,
    m: usize,
    out: &mut [i32],
) {
    let (k, n) = (w.k, w.n);
    assert_eq!(x_q.len(), m * k);
    assert_eq!(out.len(), m * n);
    // accumulator-overflow guard: a length-K dot product of worst-case
    // i8 values sums K · 2¹⁴; beyond MAX_SAFE_K it can wrap the i32
    // accumulator silently (see the const proof in quant::kernels)
    debug_assert!(
        k <= quant::MAX_SAFE_K,
        "GEMM K = {k} exceeds MAX_SAFE_K = {}: a worst-case i8·i8 dot product \
         of this length overflows the i32 accumulator",
        quant::MAX_SAFE_K
    );
    let nb = GEMM_NB;
    let nblk = n.div_ceil(nb);
    let mut tile = [0i32; GEMM_MR * GEMM_NB];
    for jb in 0..nblk {
        let blk = &w.data[jb * k * nb..(jb + 1) * k * nb];
        let jlo = jb * nb;
        let jw = nb.min(n - jlo);
        let mut i = 0;
        while i < m {
            let rows = GEMM_MR.min(m - i);
            kers.gemm_rows(&x_q[i * k..(i + rows) * k], k, rows, blk, &mut tile);
            for r in 0..rows {
                let orow = &mut out[(i + r) * n + jlo..(i + r) * n + jlo + jw];
                orow.copy_from_slice(&tile[r * nb..r * nb + jw]);
            }
            i += rows;
        }
    }
}

/// Default K-group size for per-group i4 weight scales: long enough to
/// amortize the f32 epilogue per group, short enough that one outlier
/// row cannot flatten a whole column's resolution (QS4D uses the same
/// order of magnitude).
pub const I4_GROUP_K: usize = 128;

/// Int4 weight repacked for the blocked kernel: same ⌈N/NB⌉ column
/// blocks as [`PackedWeightI8`], but each block stores **byte rows** of
/// K-row *pairs* — `data[jb·kp·NB + pb·NB + jj]` holds K rows `2·pb`
/// (low nibble) and `2·pb + 1` (high nibble) of column `jb·NB + jj`,
/// where `kp = ⌈K/2⌉`. Odd-K tails pack a zero high nibble, which
/// sign4-decodes to 0, so the kernels never need a scalar remainder
/// for the K axis. Codes are sign4 (`−8..=7`); decode is
/// [`quant::sign4`].
pub struct PackedWeightI4 {
    pub k: usize,
    pub n: usize,
    data: Vec<u8>,
}

impl PackedWeightI4 {
    /// Pack row-major i4 codes (stored in i8, each in `−8..=7`).
    pub fn pack(w_q4: &[i8], k: usize, n: usize) -> PackedWeightI4 {
        assert_eq!(w_q4.len(), k * n);
        let nb = GEMM_NB;
        let nblk = n.div_ceil(nb);
        let kp = k.div_ceil(2);
        let mut data = vec![0u8; nblk * kp * nb];
        for jb in 0..nblk {
            let jlo = jb * nb;
            let jw = nb.min(n - jlo);
            let base = jb * kp * nb;
            for pb in 0..kp {
                for jj in 0..jw {
                    let lo = i32::from(w_q4[2 * pb * n + jlo + jj]);
                    let hi = if 2 * pb + 1 < k {
                        i32::from(w_q4[(2 * pb + 1) * n + jlo + jj])
                    } else {
                        0 // odd-K pad: decodes to 0
                    };
                    data[base + pb * nb + jj] = quant::pack_nibble_pair(lo, hi);
                }
            }
        }
        PackedWeightI4 { k, n, data }
    }

    /// Unpack one code (row `p`, column `j`) — the test/oracle
    /// accessor; the hot path never goes through this.
    pub fn code(&self, p: usize, j: usize) -> i8 {
        assert!(p < self.k && j < self.n);
        let nb = GEMM_NB;
        let kp = self.k.div_ceil(2);
        let byte = self.data[(j / nb) * kp * nb + (p / 2) * nb + (j % nb)];
        if p & 1 == 0 {
            quant::sign4(byte)
        } else {
            quant::sign4(byte >> 4)
        }
    }

    /// Packed bytes (≥ ⌈k/2⌉·n due to tail-block padding) — exactly
    /// half the [`PackedWeightI8`] footprint for even K.
    pub fn packed_bytes(&self) -> usize {
        self.data.len()
    }
}

/// Naive W4A8 oracle: out (M×N) f32 = x_q (M×K) i8 · w_q4 (K×N) i4,
/// dequantized per K-group — for group `g` covering rows
/// `[g·group_k, min(K, (g+1)·group_k))`, the group's exact i32 dot
/// product is scaled by `s_x · scales[g·N + j]` and f32-accumulated in
/// ascending group order. [`matmul_w4a8`] commits to the *same*
/// per-element IEEE op sequence, so the two are bit-identical.
#[allow(clippy::too_many_arguments)]
pub fn matmul_w4a8_ref(
    x_q: &[i8],
    w_q4: &[i8],
    scales: &[f32],
    group_k: usize,
    s_x: f32,
    m: usize,
    k: usize,
    n: usize,
    out: &mut [f32],
) {
    assert_eq!(x_q.len(), m * k);
    assert_eq!(w_q4.len(), k * n);
    let n_groups = k.div_ceil(group_k);
    assert_eq!(scales.len(), n_groups * n);
    assert_eq!(out.len(), m * n);
    for i in 0..m {
        for j in 0..n {
            let mut f = 0.0f32;
            for g in 0..n_groups {
                let k0 = g * group_k;
                let k1 = k.min(k0 + group_k);
                let mut acc = 0i32;
                for p in k0..k1 {
                    acc += i32::from(x_q[i * k + p]) * i32::from(w_q4[p * n + j]);
                }
                f += quant::dq_i32(acc, s_x * scales[g * n + j]);
            }
            out[i * n + j] = f;
        }
    }
}

/// Blocked W4A8 GEMM on the process-wide auto-selected backend. See
/// [`matmul_w4a8_with`].
pub fn matmul_w4a8(
    x_q: &[i8],
    w: &PackedWeightI4,
    scales: &[f32],
    group_k: usize,
    s_x: f32,
    m: usize,
    out: &mut [f32],
) {
    matmul_w4a8_with(Kernels::auto(), x_q, w, scales, group_k, s_x, m, out)
}

/// Blocked W4A8 GEMM on an explicit kernel backend: out (M×N) f32 =
/// x_q (M×K) i8 · packed (K×N) i4, per-group dequant.
///
/// Loop order (block, row-tile, group): each K-group of a column block
/// is reduced to exact i32 sums in registers ([`Kernels::gemm_rows_i4`])
/// and immediately folded into an f32 tile at that group's scale, in
/// ascending group order — element-for-element the op sequence of
/// [`matmul_w4a8_ref`], so every backend is bit-identical to the
/// oracle. `group_k` must be even (≥ 2) so groups start on whole bytes
/// of the nibble layout; only the final group may be odd-length (K
/// odd), which the kernels handle via the zero-padded high nibble.
#[allow(clippy::too_many_arguments)]
pub fn matmul_w4a8_with(
    kers: Kernels,
    x_q: &[i8],
    w: &PackedWeightI4,
    scales: &[f32],
    group_k: usize,
    s_x: f32,
    m: usize,
    out: &mut [f32],
) {
    let (k, n) = (w.k, w.n);
    assert_eq!(x_q.len(), m * k);
    assert_eq!(out.len(), m * n);
    assert!(group_k >= 2 && group_k & 1 == 0, "i4 group_k {group_k} must be even (whole bytes)");
    let n_groups = k.div_ceil(group_k);
    assert_eq!(scales.len(), n_groups * n);
    // accumulator-overflow guard, stated against the FULL K even though
    // accumulation is per group (≤ group_k ≤ k terms), so the proof
    // stays valid if grouping is ever widened to the whole axis: a
    // worst-case i4·i8 dot product sums K · 2¹⁰ (see the const proof in
    // quant::kernels)
    debug_assert!(
        k <= quant::MAX_SAFE_K_I4,
        "GEMM K = {k} exceeds MAX_SAFE_K_I4 = {}: a worst-case i4·i8 dot product \
         of this length overflows the i32 accumulator",
        quant::MAX_SAFE_K_I4
    );
    let nb = GEMM_NB;
    let nblk = n.div_ceil(nb);
    let kp = k.div_ceil(2);
    let mut tile = [0i32; GEMM_MR * GEMM_NB];
    let mut ftile = [0.0f32; GEMM_MR * GEMM_NB];
    for jb in 0..nblk {
        let blk = &w.data[jb * kp * nb..(jb + 1) * kp * nb];
        let jlo = jb * nb;
        let jw = nb.min(n - jlo);
        let mut i = 0;
        while i < m {
            let rows = GEMM_MR.min(m - i);
            ftile[..rows * nb].fill(0.0);
            for g in 0..n_groups {
                let k0 = g * group_k;
                let kg = k.min(k0 + group_k) - k0;
                // group_k is even, so k0/2 lands on a whole byte row
                kers.gemm_rows_i4(
                    &x_q[i * k + k0..],
                    kg,
                    k,
                    rows,
                    &blk[(k0 / 2) * nb..],
                    &mut tile,
                );
                for r in 0..rows {
                    for jj in 0..jw {
                        ftile[r * nb + jj] +=
                            quant::dq_i32(tile[r * nb + jj], s_x * scales[g * n + jlo + jj]);
                    }
                }
            }
            for r in 0..rows {
                out[(i + r) * n + jlo..(i + r) * n + jlo + jw]
                    .copy_from_slice(&ftile[r * nb..r * nb + jw]);
            }
            i += rows;
        }
    }
}

/// A linear layer with per-tensor symmetric int8 weights and a static
/// input scale supplied per call (baked at calibration time, Eq. 2).
/// The weight lives ONLY in the [`PackedWeightI8`] layout the hot
/// path executes from (the row-major codes are transient at
/// construction), so resident weight memory is exactly the int8
/// matrix plus tail-block padding.
pub struct QLinear {
    pub k: usize,
    pub n: usize,
    /// blocked K-major layout, packed once at construction
    packed: PackedWeightI8,
    /// weight scale; offline folds (e.g. the Hadamard 1/d_inner) are
    /// absorbed here, exactly like `wscales[...] / d_inner` in
    /// `python/compile/quant/calibrate.py`
    pub s_w: f32,
    pub bias: Option<Vec<f32>>,
}

impl QLinear {
    /// Quantize an fp32 (K×N) row-major weight with a per-tensor scale.
    pub fn from_f32(w: &[f32], k: usize, n: usize, bias: Option<Vec<f32>>) -> QLinear {
        assert_eq!(w.len(), k * n);
        if let Some(b) = &bias {
            assert_eq!(b.len(), n);
        }
        let s_w = quant::scale_sym(quant::amax(w), 8);
        let w_q = quant::quantize_sym(w, s_w, 8);
        let packed = PackedWeightI8::pack(&w_q, k, n);
        QLinear { k, n, packed, s_w, bias }
    }

    /// Fold an extra factor into the weight scale (compute-invariant
    /// offline transform, paper §3.3).
    pub fn fold_scale(mut self, f: f32) -> QLinear {
        self.s_w *= f;
        self
    }

    /// Logical int8 weight bytes (k·n — what shipping the matrix
    /// costs; excludes the packed layout's tail padding).
    pub fn weight_bytes(&self) -> usize {
        self.k * self.n
    }

    /// x_q (M×K) i8 at static scale `s_x` → f32 (M×N) into `out`, with
    /// the i32 accumulator supplied by the caller (no allocation once
    /// `acc` has warmed up to capacity). `kers` picks the GEMM backend
    /// — the serving path passes its [`crate::ssm::StepScratch`]'s
    /// handle; outputs are bit-identical across backends.
    pub fn forward_q_into(
        &self,
        kers: Kernels,
        x_q: &[i8],
        s_x: f32,
        m: usize,
        acc: &mut Vec<i32>,
        out: &mut [f32],
    ) {
        assert_eq!(x_q.len(), m * self.k);
        assert_eq!(out.len(), m * self.n);
        // grow-only resize: the blocked kernel overwrites every element
        // (poison-tested), so zero-filling would be a wasted memset
        acc.resize(m * self.n, 0);
        matmul_i8_blocked_with(kers, x_q, &self.packed, m, acc);
        let s = s_x * self.s_w;
        for (o, &a) in out.iter_mut().zip(acc.iter()) {
            *o = quant::dq_i32(a, s);
        }
        if let Some(b) = &self.bias {
            for row in out.chunks_exact_mut(self.n) {
                for (o, &bv) in row.iter_mut().zip(b) {
                    *o += bv;
                }
            }
        }
    }

    /// Quantize fp32 input rows at `s_x` into caller-owned `x_q`, then
    /// run the blocked int8 matmul. Allocation-free after warmup; the
    /// i8 codes stay in `x_q` for reuse (e.g. the scan consumes the
    /// same quantized x as `x_proj`, paper §4.3).
    #[allow(clippy::too_many_arguments)]
    pub fn forward_into(
        &self,
        kers: Kernels,
        x: &[f32],
        s_x: f32,
        m: usize,
        x_q: &mut Vec<i8>,
        acc: &mut Vec<i32>,
        out: &mut [f32],
    ) {
        assert_eq!(x.len(), m * self.k);
        quant::quantize_sym_into(x, s_x, 8, x_q);
        self.forward_q_into(kers, x_q, s_x, m, acc, out);
    }

    /// x_q (M×K) i8 at static scale `s_x` → f32 (M×N) into `out`
    /// (auto-selected backend; allocating convenience).
    pub fn forward_q(&self, x_q: &[i8], s_x: f32, m: usize, out: &mut [f32]) {
        let mut acc = Vec::new();
        self.forward_q_into(Kernels::auto(), x_q, s_x, m, &mut acc, out);
    }

    /// Quantize fp32 input rows at `s_x`, then run the int8 matmul
    /// (auto-selected backend). Returns the i8 codes so callers can
    /// reuse them.
    pub fn forward(&self, x: &[f32], s_x: f32, m: usize, out: &mut [f32]) -> Vec<i8> {
        let mut x_q = Vec::new();
        let mut acc = Vec::new();
        self.forward_into(Kernels::auto(), x, s_x, m, &mut x_q, &mut acc, out);
        x_q
    }
}

/// The W4A8 sibling of [`QLinear`]: packed-nibble symmetric i4 weights
/// with **per-group** scales along K (one `f32` per (group, column)
/// pair), activations still int8 at a static per-tensor scale. Resident
/// weight memory is half the int8 tier; the scale table adds
/// `⌈K/group_k⌉·N` f32s (≈ 3% at `group_k = 128`).
pub struct QLinearI4 {
    pub k: usize,
    pub n: usize,
    /// blocked K-major nibble layout, packed once at construction
    packed: PackedWeightI4,
    /// `scales[g·n + j]` dequantizes K-group `g` of column `j`; offline
    /// folds (e.g. the Hadamard 1/d_inner) multiply into every entry
    scales: Vec<f32>,
    /// K-group length; even so groups start on whole nibble bytes
    pub group_k: usize,
    pub bias: Option<Vec<f32>>,
}

impl QLinearI4 {
    /// Quantize an fp32 (K×N) row-major weight at the default group
    /// size [`I4_GROUP_K`].
    pub fn from_f32(w: &[f32], k: usize, n: usize, bias: Option<Vec<f32>>) -> QLinearI4 {
        QLinearI4::from_f32_grouped(w, k, n, bias, I4_GROUP_K)
    }

    /// Quantize with an explicit K-group size (`group_k` even, ≥ 2):
    /// each (group, column) gets its own symmetric 4-bit scale from the
    /// group's amax, so one heavy row only costs resolution within its
    /// own group.
    pub fn from_f32_grouped(
        w: &[f32],
        k: usize,
        n: usize,
        bias: Option<Vec<f32>>,
        group_k: usize,
    ) -> QLinearI4 {
        assert_eq!(w.len(), k * n);
        if let Some(b) = &bias {
            assert_eq!(b.len(), n);
        }
        assert!(group_k >= 2 && group_k & 1 == 0, "i4 group_k {group_k} must be even");
        let n_groups = k.div_ceil(group_k);
        let mut scales = vec![0.0f32; n_groups * n];
        let mut w_q4 = vec![0i8; k * n];
        for g in 0..n_groups {
            let k0 = g * group_k;
            let k1 = k.min(k0 + group_k);
            for j in 0..n {
                let mut amax = 0.0f32;
                for p in k0..k1 {
                    amax = amax.max(w[p * n + j].abs());
                }
                let s = quant::scale_sym(amax, 4);
                scales[g * n + j] = s;
                for p in k0..k1 {
                    w_q4[p * n + j] = quant::code_to_i8(quant::quantize_one(w[p * n + j], s, 4));
                }
            }
        }
        let packed = PackedWeightI4::pack(&w_q4, k, n);
        QLinearI4 { k, n, packed, scales, group_k, bias }
    }

    /// Fold an extra factor into every group scale (compute-invariant
    /// offline transform, paper §3.3) — the i4 analogue of
    /// [`QLinear::fold_scale`].
    pub fn fold_scale(mut self, f: f32) -> QLinearI4 {
        for s in &mut self.scales {
            *s *= f;
        }
        self
    }

    /// Logical packed weight bytes (⌈k·n/2⌉ — two codes per byte;
    /// excludes the layout's tail padding and the f32 scale table).
    pub fn weight_bytes(&self) -> usize {
        (self.k * self.n).div_ceil(2)
    }

    /// x_q (M×K) i8 at static scale `s_x` → f32 (M×N) into `out`.
    /// Allocation-free: the group accumulators live in stack tiles
    /// inside [`matmul_w4a8_with`], so no i32 scratch vector is needed
    /// (the structural difference from [`QLinear::forward_q_into`]).
    pub fn forward_q_into(&self, kers: Kernels, x_q: &[i8], s_x: f32, m: usize, out: &mut [f32]) {
        assert_eq!(x_q.len(), m * self.k);
        assert_eq!(out.len(), m * self.n);
        matmul_w4a8_with(kers, x_q, &self.packed, &self.scales, self.group_k, s_x, m, out);
        if let Some(b) = &self.bias {
            for row in out.chunks_exact_mut(self.n) {
                for (o, &bv) in row.iter_mut().zip(b) {
                    *o += bv;
                }
            }
        }
    }

    /// Quantize fp32 input rows at `s_x` into caller-owned `x_q` (int8
    /// — activations stay 8-bit in W4A8), then run the blocked nibble
    /// matmul. Allocation-free after warmup.
    pub fn forward_into(
        &self,
        kers: Kernels,
        x: &[f32],
        s_x: f32,
        m: usize,
        x_q: &mut Vec<i8>,
        out: &mut [f32],
    ) {
        assert_eq!(x.len(), m * self.k);
        quant::quantize_sym_into(x, s_x, 8, x_q);
        self.forward_q_into(kers, x_q, s_x, m, out);
    }

    /// Allocating convenience (auto-selected backend).
    pub fn forward_q(&self, x_q: &[i8], s_x: f32, m: usize, out: &mut [f32]) {
        self.forward_q_into(Kernels::auto(), x_q, s_x, m, out);
    }

    /// Quantize then multiply (auto-selected backend); returns the i8
    /// codes so callers can reuse them.
    pub fn forward(&self, x: &[f32], s_x: f32, m: usize, out: &mut [f32]) -> Vec<i8> {
        let mut x_q = Vec::new();
        self.forward_into(Kernels::auto(), x, s_x, m, &mut x_q, out);
        x_q
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    #[test]
    fn i8_matmul_matches_f32_on_grid() {
        // inputs already on the int8 grid: integer and f32 paths agree
        let mut r = Pcg32::new(3);
        let (m, k, n) = (4usize, 8usize, 6usize);
        let s_x = 0.02f32;
        let s_w = 0.01f32;
        let x_q: Vec<i8> = (0..m * k).map(|_| (r.below(255) as i32 - 127) as i8).collect();
        let w_q: Vec<i8> = (0..k * n).map(|_| (r.below(255) as i32 - 127) as i8).collect();
        let mut acc = vec![0i32; m * n];
        matmul_i8(&x_q, &w_q, m, k, n, &mut acc);
        for i in 0..m {
            for j in 0..n {
                let mut f = 0.0f64;
                for p in 0..k {
                    f += (x_q[i * k + p] as f64 * s_x as f64) * (w_q[p * n + j] as f64 * s_w as f64);
                }
                let got = acc[i * n + j] as f64 * (s_x as f64 * s_w as f64);
                assert!((f - got).abs() < 1e-6, "({i},{j}): {f} vs {got}");
            }
        }
    }

    #[test]
    fn blocked_matches_naive_oracle() {
        // bit-exact across shapes where K and N are NOT multiples of
        // the block/unroll widths, on EVERY available dispatch backend
        // (the broader sweep lives in rust/tests/kernel_parity.rs)
        let mut r = Pcg32::new(77);
        let shapes = [(1usize, 7usize, 5usize), (3, 17, 33), (8, 64, 48), (2, 5, 16), (4, 1, 1)];
        for (m, k, n) in shapes {
            let x_q: Vec<i8> = (0..m * k).map(|_| (r.below(255) as i32 - 127) as i8).collect();
            let w_q: Vec<i8> = (0..k * n).map(|_| (r.below(255) as i32 - 127) as i8).collect();
            let mut want = vec![0i32; m * n];
            matmul_i8(&x_q, &w_q, m, k, n, &mut want);
            let packed = PackedWeightI8::pack(&w_q, k, n);
            let mut got = vec![0i32; m * n];
            matmul_i8_blocked(&x_q, &packed, m, &mut got);
            assert_eq!(want, got, "auto backend, shape ({m},{k},{n})");
            for backend in Kernels::available() {
                got.fill(7); // poison: kernel must overwrite fully
                matmul_i8_blocked_with(Kernels::for_backend(backend), &x_q, &packed, m, &mut got);
                assert_eq!(want, got, "{} backend, shape ({m},{k},{n})", backend.label());
            }
        }
    }

    #[test]
    fn gemm_exact_at_proven_k_bound() {
        // worst-case dot product at K = MAX_SAFE_K: every term is
        // (-128)·(-128) = 2¹⁴, so the i32 accumulator lands at
        // 131071 · 16384 = 2_147_467_264, a hair under i32::MAX — the
        // exact sum the const proof in quant::kernels promises fits.
        let k = quant::MAX_SAFE_K;
        let x_q = vec![-128i8; k];
        let w_q = vec![-128i8; k]; // K×1 matrix
        let packed = PackedWeightI8::pack(&w_q, k, 1);
        let want = (k as i64 * quant::MAX_ABS_PROD_I8) as i32;
        assert_eq!(want, 2_147_467_264);
        for backend in Kernels::available() {
            let mut out = vec![0i32; 1];
            matmul_i8_blocked_with(Kernels::for_backend(backend), &x_q, &packed, 1, &mut out);
            assert_eq!(out[0], want, "{} backend wrapped at the K bound", backend.label());
        }
    }

    #[test]
    #[cfg(debug_assertions)]
    #[should_panic(expected = "MAX_SAFE_K")]
    fn gemm_rejects_k_one_past_bound() {
        // one past the proven bound must trip the debug guard before
        // the kernel gets a chance to wrap silently
        let k = quant::MAX_SAFE_K + 1;
        let x_q = vec![-128i8; k];
        let w_q = vec![-128i8; k];
        let packed = PackedWeightI8::pack(&w_q, k, 1);
        let mut out = vec![0i32; 1];
        matmul_i8_blocked_with(Kernels::scalar(), &x_q, &packed, 1, &mut out);
    }

    #[test]
    fn qlinear_close_to_f32_linear() {
        let mut r = Pcg32::new(9);
        let (m, k, n) = (3usize, 32usize, 16usize);
        let w: Vec<f32> = (0..k * n).map(|_| r.normal() * 0.2).collect();
        let bias: Vec<f32> = (0..n).map(|_| r.normal() * 0.1).collect();
        let x: Vec<f32> = (0..m * k).map(|_| r.normal()).collect();
        let ql = QLinear::from_f32(&w, k, n, Some(bias.clone()));
        let s_x = crate::quant::scale_sym(crate::quant::amax(&x), 8);
        let mut got = vec![0.0f32; m * n];
        ql.forward(&x, s_x, m, &mut got);
        // reference: f32 matmul + bias
        let mut want = vec![0.0f32; m * n];
        for i in 0..m {
            for j in 0..n {
                let mut acc = bias[j];
                for p in 0..k {
                    acc += x[i * k + p] * w[p * n + j];
                }
                want[i * n + j] = acc;
            }
        }
        // error budget: k accumulations of (s_x/2 · |w| + s_w/2 · |x|)
        let tol = k as f32 * (s_x * 0.2 + ql.s_w * 3.0);
        for (a, b) in want.iter().zip(&got) {
            assert!((a - b).abs() < tol, "{a} vs {b} (tol {tol})");
        }
    }

    #[test]
    fn forward_into_reuses_scratch_capacity() {
        let mut r = Pcg32::new(12);
        let (m, k, n) = (2usize, 24usize, 20usize);
        let w: Vec<f32> = (0..k * n).map(|_| r.normal() * 0.2).collect();
        let ql = QLinear::from_f32(&w, k, n, None);
        let x: Vec<f32> = (0..m * k).map(|_| r.normal()).collect();
        let kers = Kernels::auto();
        let mut x_q = Vec::new();
        let mut acc = Vec::new();
        let mut out = vec![0.0f32; m * n];
        ql.forward_into(kers, &x, 0.05, m, &mut x_q, &mut acc, &mut out);
        let (cq, ca) = (x_q.capacity(), acc.capacity());
        let (pq, pa) = (x_q.as_ptr(), acc.as_ptr());
        for _ in 0..5 {
            ql.forward_into(kers, &x, 0.05, m, &mut x_q, &mut acc, &mut out);
        }
        assert_eq!(x_q.capacity(), cq);
        assert_eq!(acc.capacity(), ca);
        assert_eq!(x_q.as_ptr(), pq, "x_q scratch reallocated");
        assert_eq!(acc.as_ptr(), pa, "acc scratch reallocated");
    }

    #[test]
    fn fold_scale_scales_output() {
        let w = vec![1.0f32, -1.0, 0.5, 0.25];
        let ql = QLinear::from_f32(&w, 2, 2, None);
        let folded = QLinear::from_f32(&w, 2, 2, None).fold_scale(0.5);
        let x_q: Vec<i8> = vec![10, -20];
        let (mut a, mut b) = (vec![0.0f32; 2], vec![0.0f32; 2]);
        ql.forward_q(&x_q, 0.1, 1, &mut a);
        folded.forward_q(&x_q, 0.1, 1, &mut b);
        for (u, v) in a.iter().zip(&b) {
            assert!((u * 0.5 - v).abs() < 1e-6);
        }
    }

    fn rand_i4(r: &mut Pcg32, n: usize) -> Vec<i8> {
        (0..n).map(|_| (r.below(16) as i32 - 8) as i8).collect()
    }

    #[test]
    fn packed_i4_roundtrips_on_awkward_shapes() {
        // odd K (pad nibble), K not a multiple of any group, N off the
        // block width — every code must come back exactly
        let mut r = Pcg32::new(0x44);
        for (k, n) in [(1usize, 1usize), (5, 3), (7, 16), (8, 17), (129, 33), (2, 48)] {
            let w_q4 = rand_i4(&mut r, k * n);
            let packed = PackedWeightI4::pack(&w_q4, k, n);
            for p in 0..k {
                for j in 0..n {
                    assert_eq!(packed.code(p, j), w_q4[p * n + j], "({k},{n}) code ({p},{j})");
                }
            }
            assert_eq!(packed.packed_bytes(), n.div_ceil(GEMM_NB) * GEMM_NB * k.div_ceil(2));
        }
    }

    #[test]
    fn w4a8_blocked_bit_identical_to_naive_oracle() {
        // sweep shapes where K is odd / not a multiple of the group and
        // N straddles block boundaries, on EVERY available backend
        let mut r = Pcg32::new(0x4A8);
        let cases = [
            // (m, k, n, group_k)
            (1usize, 7usize, 5usize, 4usize),
            (3, 17, 33, 8),
            (8, 64, 48, 16),
            (2, 5, 16, 128), // single short group
            (4, 1, 1, 2),
            (5, 130, 20, 64), // last group length 2
            (4, 129, 16, 64), // last group odd
        ];
        for (m, k, n, group_k) in cases {
            let x_q: Vec<i8> = (0..m * k).map(|_| (r.below(255) as i32 - 127) as i8).collect();
            let w_q4 = rand_i4(&mut r, k * n);
            let n_groups = k.div_ceil(group_k);
            let scales: Vec<f32> =
                (0..n_groups * n).map(|_| 0.003 + 0.001 * r.below(32) as f32).collect();
            let s_x = 0.021f32;
            let mut want = vec![0.0f32; m * n];
            matmul_w4a8_ref(&x_q, &w_q4, &scales, group_k, s_x, m, k, n, &mut want);
            let packed = PackedWeightI4::pack(&w_q4, k, n);
            for backend in Kernels::available() {
                let mut got = vec![7.0f32; m * n]; // poison
                matmul_w4a8_with(
                    Kernels::for_backend(backend),
                    &x_q,
                    &packed,
                    &scales,
                    group_k,
                    s_x,
                    m,
                    &mut got,
                );
                for (jj, (a, b)) in want.iter().zip(&got).enumerate() {
                    assert_eq!(
                        a.to_bits(),
                        b.to_bits(),
                        "{} backend, shape ({m},{k},{n}) g{group_k} elem {jj}: {a} vs {b}",
                        backend.label()
                    );
                }
            }
        }
    }

    #[test]
    fn w4a8_exact_at_proven_i4_k_bound() {
        // worst-case dot product at K = MAX_SAFE_K_I4: every term is
        // (-8)·(-128) = 2¹⁰, so the i32 accumulator lands at
        // 2097151 · 1024 = 2_147_482_624, a hair under i32::MAX. K is
        // odd here, so this also exercises the pad-nibble tail at the
        // extreme. One group spanning all of K makes the accumulation
        // truly length-K.
        let k = quant::MAX_SAFE_K_I4;
        let group_k = k + 1; // even; single group of length k
        let x_q = vec![-128i8; k];
        let w_q4 = vec![-8i8; k]; // K×1 matrix
        let packed = PackedWeightI4::pack(&w_q4, k, 1);
        let want = (k as i64 * quant::MAX_ABS_PROD_I4I8) as f32;
        for backend in Kernels::available() {
            let mut out = vec![0.0f32; 1];
            matmul_w4a8_with(
                Kernels::for_backend(backend),
                &x_q,
                &packed,
                &[1.0],
                group_k,
                1.0,
                1,
                &mut out,
            );
            assert_eq!(
                out[0].to_bits(),
                want.to_bits(),
                "{} backend wrapped at the i4 K bound",
                backend.label()
            );
        }
    }

    #[test]
    #[cfg(debug_assertions)]
    #[should_panic(expected = "MAX_SAFE_K_I4")]
    fn w4a8_rejects_k_one_past_bound() {
        let k = quant::MAX_SAFE_K_I4 + 1;
        let x_q = vec![-128i8; k];
        let w_q4 = vec![-8i8; k];
        let packed = PackedWeightI4::pack(&w_q4, k, 1);
        let mut out = vec![0.0f32; 1];
        matmul_w4a8_with(Kernels::scalar(), &x_q, &packed, &[1.0], k, 1.0, 1, &mut out);
    }

    #[test]
    fn qlinear_i4_close_to_f32_linear() {
        // per-group scales must hold 4-bit error to the coarse-grid
        // budget even with a bias and a non-trivial group count
        let mut r = Pcg32::new(0x14);
        let (m, k, n) = (3usize, 64usize, 16usize);
        let w: Vec<f32> = (0..k * n).map(|_| r.normal() * 0.2).collect();
        let bias: Vec<f32> = (0..n).map(|_| r.normal() * 0.1).collect();
        let x: Vec<f32> = (0..m * k).map(|_| r.normal()).collect();
        let ql = QLinearI4::from_f32_grouped(&w, k, n, Some(bias.clone()), 16);
        let s_x = crate::quant::scale_sym(crate::quant::amax(&x), 8);
        let mut got = vec![0.0f32; m * n];
        ql.forward(&x, s_x, m, &mut got);
        let mut want = vec![0.0f32; m * n];
        for i in 0..m {
            for j in 0..n {
                let mut acc = bias[j];
                for p in 0..k {
                    acc += x[i * k + p] * w[p * n + j];
                }
                want[i * n + j] = acc;
            }
        }
        // error budget: k accumulations of (s_x/2 · |w| + s4/2 · |x|)
        // with the 4-bit weight step ≈ amax/7 per group
        let tol = k as f32 * (s_x * 0.2 + (0.8 / 7.0) * 3.0);
        for (a, b) in want.iter().zip(&got) {
            assert!((a - b).abs() < tol, "{a} vs {b} (tol {tol})");
        }
    }

    #[test]
    fn qlinear_i4_halves_weight_bytes() {
        let mut r = Pcg32::new(0x48);
        let (k, n) = (64usize, 48usize);
        let w: Vec<f32> = (0..k * n).map(|_| r.normal() * 0.2).collect();
        let q8 = QLinear::from_f32(&w, k, n, None);
        let q4 = QLinearI4::from_f32(&w, k, n, None);
        assert_eq!(2 * q4.weight_bytes(), q8.weight_bytes());
        // odd k·n rounds the half byte up
        let w_odd: Vec<f32> = (0..3 * 3).map(|_| r.normal()).collect();
        assert_eq!(QLinearI4::from_f32(&w_odd, 3, 3, None).weight_bytes(), 5);
    }

    #[test]
    fn i4_fold_scale_scales_output() {
        let mut r = Pcg32::new(0x4F);
        let (k, n) = (8usize, 4usize);
        let w: Vec<f32> = (0..k * n).map(|_| r.normal()).collect();
        let ql = QLinearI4::from_f32_grouped(&w, k, n, None, 4);
        let folded = QLinearI4::from_f32_grouped(&w, k, n, None, 4).fold_scale(0.5);
        let x_q: Vec<i8> = (0..k).map(|_| (r.below(255) as i32 - 127) as i8).collect();
        let (mut a, mut b) = (vec![0.0f32; n], vec![0.0f32; n]);
        ql.forward_q(&x_q, 0.1, 1, &mut a);
        folded.forward_q(&x_q, 0.1, 1, &mut b);
        for (u, v) in a.iter().zip(&b) {
            assert!((u * 0.5 - v).abs() < 1e-6);
        }
    }

    #[test]
    fn i4_forward_into_reuses_scratch_capacity() {
        let mut r = Pcg32::new(0x4C);
        let (m, k, n) = (2usize, 24usize, 20usize);
        let w: Vec<f32> = (0..k * n).map(|_| r.normal() * 0.2).collect();
        let ql = QLinearI4::from_f32_grouped(&w, k, n, None, 8);
        let x: Vec<f32> = (0..m * k).map(|_| r.normal()).collect();
        let kers = Kernels::auto();
        let mut x_q = Vec::new();
        let mut out = vec![0.0f32; m * n];
        ql.forward_into(kers, &x, 0.05, m, &mut x_q, &mut out);
        let cq = x_q.capacity();
        let pq = x_q.as_ptr();
        for _ in 0..5 {
            ql.forward_into(kers, &x, 0.05, m, &mut x_q, &mut out);
        }
        assert_eq!(x_q.capacity(), cq);
        assert_eq!(x_q.as_ptr(), pq, "x_q scratch reallocated");
    }
}
