//! W8A8 linear execution: i8 × i8 → i32 accumulate, dequantized by
//! `s_x · s_w` (+ f32 bias) — the rust-native mirror of
//! `python/compile/kernels/matmul_i8.py` (the CUTLASS-INT8 stand-in on
//! the deployment path). Unlike the fake-quant instrumentation in
//! [`crate::quant`], this path really executes in the integer domain,
//! so the native serving backend carries int8 weights end-to-end.

use crate::quant;

/// out (M×N) i32 = x_q (M×K) i8 · w_q (K×N) i8, i32 accumulation.
pub fn matmul_i8(x_q: &[i8], w_q: &[i8], m: usize, k: usize, n: usize, out: &mut [i32]) {
    assert_eq!(x_q.len(), m * k);
    assert_eq!(w_q.len(), k * n);
    assert_eq!(out.len(), m * n);
    out.fill(0);
    for i in 0..m {
        for p in 0..k {
            let xv = x_q[i * k + p] as i32;
            if xv == 0 {
                continue;
            }
            let wrow = &w_q[p * n..(p + 1) * n];
            let orow = &mut out[i * n..(i + 1) * n];
            for j in 0..n {
                orow[j] += xv * wrow[j] as i32;
            }
        }
    }
}

/// A linear layer with per-tensor symmetric int8 weights and a static
/// input scale supplied per call (baked at calibration time, Eq. 2).
pub struct QLinear {
    pub k: usize,
    pub n: usize,
    pub w_q: Vec<i8>,
    /// weight scale; offline folds (e.g. the Hadamard 1/d_inner) are
    /// absorbed here, exactly like `wscales[...] / d_inner` in
    /// `python/compile/quant/calibrate.py`
    pub s_w: f32,
    pub bias: Option<Vec<f32>>,
}

impl QLinear {
    /// Quantize an fp32 (K×N) row-major weight with a per-tensor scale.
    pub fn from_f32(w: &[f32], k: usize, n: usize, bias: Option<Vec<f32>>) -> QLinear {
        assert_eq!(w.len(), k * n);
        if let Some(b) = &bias {
            assert_eq!(b.len(), n);
        }
        let s_w = quant::scale_sym(quant::amax(w), 8);
        QLinear { k, n, w_q: quant::quantize_sym(w, s_w, 8), s_w, bias }
    }

    /// Fold an extra factor into the weight scale (compute-invariant
    /// offline transform, paper §3.3).
    pub fn fold_scale(mut self, f: f32) -> QLinear {
        self.s_w *= f;
        self
    }

    pub fn weight_bytes(&self) -> usize {
        self.w_q.len()
    }

    /// x_q (M×K) i8 at static scale `s_x` → f32 (M×N) into `out`.
    pub fn forward_q(&self, x_q: &[i8], s_x: f32, m: usize, out: &mut [f32]) {
        assert_eq!(out.len(), m * self.n);
        let mut acc = vec![0i32; m * self.n];
        matmul_i8(x_q, &self.w_q, m, self.k, self.n, &mut acc);
        let s = s_x * self.s_w;
        for (o, &a) in out.iter_mut().zip(&acc) {
            *o = a as f32 * s;
        }
        if let Some(b) = &self.bias {
            for row in out.chunks_exact_mut(self.n) {
                for (o, &bv) in row.iter_mut().zip(b) {
                    *o += bv;
                }
            }
        }
    }

    /// Quantize fp32 input rows at `s_x`, then run the int8 matmul.
    /// Returns the i8 codes so callers can reuse them (e.g. the scan
    /// consumes the same quantized x as `x_proj`, paper §4.3).
    pub fn forward(&self, x: &[f32], s_x: f32, m: usize, out: &mut [f32]) -> Vec<i8> {
        assert_eq!(x.len(), m * self.k);
        let x_q = quant::quantize_sym(x, s_x, 8);
        self.forward_q(&x_q, s_x, m, out);
        x_q
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    #[test]
    fn i8_matmul_matches_f32_on_grid() {
        // inputs already on the int8 grid: integer and f32 paths agree
        let mut r = Pcg32::new(3);
        let (m, k, n) = (4usize, 8usize, 6usize);
        let s_x = 0.02f32;
        let s_w = 0.01f32;
        let x_q: Vec<i8> = (0..m * k).map(|_| (r.below(255) as i32 - 127) as i8).collect();
        let w_q: Vec<i8> = (0..k * n).map(|_| (r.below(255) as i32 - 127) as i8).collect();
        let mut acc = vec![0i32; m * n];
        matmul_i8(&x_q, &w_q, m, k, n, &mut acc);
        for i in 0..m {
            for j in 0..n {
                let mut f = 0.0f64;
                for p in 0..k {
                    f += (x_q[i * k + p] as f64 * s_x as f64) * (w_q[p * n + j] as f64 * s_w as f64);
                }
                let got = acc[i * n + j] as f64 * (s_x as f64 * s_w as f64);
                assert!((f - got).abs() < 1e-6, "({i},{j}): {f} vs {got}");
            }
        }
    }

    #[test]
    fn qlinear_close_to_f32_linear() {
        let mut r = Pcg32::new(9);
        let (m, k, n) = (3usize, 32usize, 16usize);
        let w: Vec<f32> = (0..k * n).map(|_| r.normal() * 0.2).collect();
        let bias: Vec<f32> = (0..n).map(|_| r.normal() * 0.1).collect();
        let x: Vec<f32> = (0..m * k).map(|_| r.normal()).collect();
        let ql = QLinear::from_f32(&w, k, n, Some(bias.clone()));
        let s_x = crate::quant::scale_sym(crate::quant::amax(&x), 8);
        let mut got = vec![0.0f32; m * n];
        ql.forward(&x, s_x, m, &mut got);
        // reference: f32 matmul + bias
        let mut want = vec![0.0f32; m * n];
        for i in 0..m {
            for j in 0..n {
                let mut acc = bias[j];
                for p in 0..k {
                    acc += x[i * k + p] * w[p * n + j];
                }
                want[i * n + j] = acc;
            }
        }
        // error budget: k accumulations of (s_x/2 · |w| + s_w/2 · |x|)
        let tol = k as f32 * (s_x * 0.2 + ql.s_w * 3.0);
        for (a, b) in want.iter().zip(&got) {
            assert!((a - b).abs() < tol, "{a} vs {b} (tol {tol})");
        }
    }

    #[test]
    fn fold_scale_scales_output() {
        let w = vec![1.0f32, -1.0, 0.5, 0.25];
        let ql = QLinear::from_f32(&w, 2, 2, None);
        let folded = QLinear::from_f32(&w, 2, 2, None).fold_scale(0.5);
        let x_q: Vec<i8> = vec![10, -20];
        let (mut a, mut b) = (vec![0.0f32; 2], vec![0.0f32; 2]);
        ql.forward_q(&x_q, 0.1, 1, &mut a);
        folded.forward_q(&x_q, 0.1, 1, &mut b);
        for (u, v) in a.iter().zip(&b) {
            assert!((u * 0.5 - v).abs() < 1e-6);
        }
    }
}
