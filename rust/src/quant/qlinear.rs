//! W8A8 linear execution: i8 × i8 → i32 accumulate, dequantized by
//! `s_x · s_w` (+ f32 bias) — the rust-native mirror of
//! `python/compile/kernels/matmul_i8.py` (the CUTLASS-INT8 stand-in on
//! the deployment path). Unlike the fake-quant instrumentation in
//! [`crate::quant`], this path really executes in the integer domain,
//! so the native serving backend carries int8 weights end-to-end.
//!
//! Two kernels share the same integer semantics:
//!
//! * [`matmul_i8`] — the naive triple loop, kept as the *test oracle*;
//! * [`matmul_i8_blocked`] — the hot-path kernel over a
//!   [`PackedWeightI8`] column-blocked, K-major layout (packed once at
//!   [`QLinear`] construction), executed through the
//!   [`Kernels`] dispatch layer ([`crate::quant::kernels`]): explicit
//!   AVX2/NEON widening multiply-adds with a [`GEMM_MR`]-row register
//!   tile, or the portable scalar fallback. All accumulation is exact
//!   i32, so every backend is **bit-identical** to the oracle for
//!   every shape (property-tested in `rust/tests/kernel_parity.rs`).
//!
//! The `*_into` methods take caller-owned scratch so the decode hot
//! path performs no heap allocation per call (see
//! [`crate::ssm::step::StepScratch`]).

use crate::quant;
use crate::quant::kernels::Kernels;

pub use crate::quant::kernels::{GEMM_MR, GEMM_NB};

/// out (M×N) i32 = x_q (M×K) i8 · w_q (K×N) i8, i32 accumulation.
/// Naive triple loop — retained as the bit-exactness oracle for
/// [`matmul_i8_blocked`].
pub fn matmul_i8(x_q: &[i8], w_q: &[i8], m: usize, k: usize, n: usize, out: &mut [i32]) {
    assert_eq!(x_q.len(), m * k);
    assert_eq!(w_q.len(), k * n);
    assert_eq!(out.len(), m * n);
    out.fill(0);
    for i in 0..m {
        for p in 0..k {
            let xv = x_q[i * k + p] as i32;
            if xv == 0 {
                continue;
            }
            let wrow = &w_q[p * n..(p + 1) * n];
            let orow = &mut out[i * n..(i + 1) * n];
            for j in 0..n {
                orow[j] += xv * wrow[j] as i32;
            }
        }
    }
}

/// Int8 weight repacked for the blocked kernel: the (K×N) matrix is
/// split into ⌈N/NB⌉ column blocks of width [`GEMM_NB`]; each block is
/// stored K-major (`block[p·NB + jj] = w[p·N + jb·NB + jj]`), zero-
/// padded in the tail block. A row of activations then streams each
/// block with unit stride while NB running sums stay in registers.
pub struct PackedWeightI8 {
    pub k: usize,
    pub n: usize,
    data: Vec<i8>,
}

impl PackedWeightI8 {
    pub fn pack(w_q: &[i8], k: usize, n: usize) -> PackedWeightI8 {
        assert_eq!(w_q.len(), k * n);
        let nb = GEMM_NB;
        let nblk = n.div_ceil(nb);
        let mut data = vec![0i8; nblk * k * nb];
        for jb in 0..nblk {
            let jlo = jb * nb;
            let jw = nb.min(n - jlo);
            let base = jb * k * nb;
            for p in 0..k {
                data[base + p * nb..base + p * nb + jw]
                    .copy_from_slice(&w_q[p * n + jlo..p * n + jlo + jw]);
            }
        }
        PackedWeightI8 { k, n, data }
    }

    /// Packed bytes (≥ k·n due to tail-block padding).
    pub fn packed_bytes(&self) -> usize {
        self.data.len()
    }
}

/// Blocked int8 GEMM: out (M×N) i32 = x_q (M×K) i8 · packed (K×N) i8,
/// executed on the process-wide auto-selected backend
/// ([`Kernels::auto`]). See [`matmul_i8_blocked_with`].
pub fn matmul_i8_blocked(x_q: &[i8], w: &PackedWeightI8, m: usize, out: &mut [i32]) {
    matmul_i8_blocked_with(Kernels::auto(), x_q, w, m, out)
}

/// Blocked int8 GEMM on an explicit kernel backend.
///
/// Loop order (block, row-tile, K): each K-major column block is
/// streamed once per [`GEMM_MR`]-row activation tile with the
/// rows × [`GEMM_NB`] i32 accumulators held in registers
/// ([`Kernels::gemm_rows`]), so `out` is written exactly once per
/// element (the naive kernel re-reads and re-writes each output row K
/// times). Integer accumulation is exact, therefore every backend is
/// bit-identical to [`matmul_i8`].
pub fn matmul_i8_blocked_with(
    kers: Kernels,
    x_q: &[i8],
    w: &PackedWeightI8,
    m: usize,
    out: &mut [i32],
) {
    let (k, n) = (w.k, w.n);
    assert_eq!(x_q.len(), m * k);
    assert_eq!(out.len(), m * n);
    // accumulator-overflow guard: a length-K dot product of worst-case
    // i8 values sums K · 2¹⁴; beyond MAX_SAFE_K it can wrap the i32
    // accumulator silently (see the const proof in quant::kernels)
    debug_assert!(
        k <= quant::MAX_SAFE_K,
        "GEMM K = {k} exceeds MAX_SAFE_K = {}: a worst-case i8·i8 dot product \
         of this length overflows the i32 accumulator",
        quant::MAX_SAFE_K
    );
    let nb = GEMM_NB;
    let nblk = n.div_ceil(nb);
    let mut tile = [0i32; GEMM_MR * GEMM_NB];
    for jb in 0..nblk {
        let blk = &w.data[jb * k * nb..(jb + 1) * k * nb];
        let jlo = jb * nb;
        let jw = nb.min(n - jlo);
        let mut i = 0;
        while i < m {
            let rows = GEMM_MR.min(m - i);
            kers.gemm_rows(&x_q[i * k..(i + rows) * k], k, rows, blk, &mut tile);
            for r in 0..rows {
                let orow = &mut out[(i + r) * n + jlo..(i + r) * n + jlo + jw];
                orow.copy_from_slice(&tile[r * nb..r * nb + jw]);
            }
            i += rows;
        }
    }
}

/// A linear layer with per-tensor symmetric int8 weights and a static
/// input scale supplied per call (baked at calibration time, Eq. 2).
/// The weight lives ONLY in the [`PackedWeightI8`] layout the hot
/// path executes from (the row-major codes are transient at
/// construction), so resident weight memory is exactly the int8
/// matrix plus tail-block padding.
pub struct QLinear {
    pub k: usize,
    pub n: usize,
    /// blocked K-major layout, packed once at construction
    packed: PackedWeightI8,
    /// weight scale; offline folds (e.g. the Hadamard 1/d_inner) are
    /// absorbed here, exactly like `wscales[...] / d_inner` in
    /// `python/compile/quant/calibrate.py`
    pub s_w: f32,
    pub bias: Option<Vec<f32>>,
}

impl QLinear {
    /// Quantize an fp32 (K×N) row-major weight with a per-tensor scale.
    pub fn from_f32(w: &[f32], k: usize, n: usize, bias: Option<Vec<f32>>) -> QLinear {
        assert_eq!(w.len(), k * n);
        if let Some(b) = &bias {
            assert_eq!(b.len(), n);
        }
        let s_w = quant::scale_sym(quant::amax(w), 8);
        let w_q = quant::quantize_sym(w, s_w, 8);
        let packed = PackedWeightI8::pack(&w_q, k, n);
        QLinear { k, n, packed, s_w, bias }
    }

    /// Fold an extra factor into the weight scale (compute-invariant
    /// offline transform, paper §3.3).
    pub fn fold_scale(mut self, f: f32) -> QLinear {
        self.s_w *= f;
        self
    }

    /// Logical int8 weight bytes (k·n — what shipping the matrix
    /// costs; excludes the packed layout's tail padding).
    pub fn weight_bytes(&self) -> usize {
        self.k * self.n
    }

    /// x_q (M×K) i8 at static scale `s_x` → f32 (M×N) into `out`, with
    /// the i32 accumulator supplied by the caller (no allocation once
    /// `acc` has warmed up to capacity). `kers` picks the GEMM backend
    /// — the serving path passes its [`crate::ssm::StepScratch`]'s
    /// handle; outputs are bit-identical across backends.
    pub fn forward_q_into(
        &self,
        kers: Kernels,
        x_q: &[i8],
        s_x: f32,
        m: usize,
        acc: &mut Vec<i32>,
        out: &mut [f32],
    ) {
        assert_eq!(x_q.len(), m * self.k);
        assert_eq!(out.len(), m * self.n);
        // grow-only resize: the blocked kernel overwrites every element
        // (poison-tested), so zero-filling would be a wasted memset
        acc.resize(m * self.n, 0);
        matmul_i8_blocked_with(kers, x_q, &self.packed, m, acc);
        let s = s_x * self.s_w;
        for (o, &a) in out.iter_mut().zip(acc.iter()) {
            *o = quant::dq_i32(a, s);
        }
        if let Some(b) = &self.bias {
            for row in out.chunks_exact_mut(self.n) {
                for (o, &bv) in row.iter_mut().zip(b) {
                    *o += bv;
                }
            }
        }
    }

    /// Quantize fp32 input rows at `s_x` into caller-owned `x_q`, then
    /// run the blocked int8 matmul. Allocation-free after warmup; the
    /// i8 codes stay in `x_q` for reuse (e.g. the scan consumes the
    /// same quantized x as `x_proj`, paper §4.3).
    #[allow(clippy::too_many_arguments)]
    pub fn forward_into(
        &self,
        kers: Kernels,
        x: &[f32],
        s_x: f32,
        m: usize,
        x_q: &mut Vec<i8>,
        acc: &mut Vec<i32>,
        out: &mut [f32],
    ) {
        assert_eq!(x.len(), m * self.k);
        quant::quantize_sym_into(x, s_x, 8, x_q);
        self.forward_q_into(kers, x_q, s_x, m, acc, out);
    }

    /// x_q (M×K) i8 at static scale `s_x` → f32 (M×N) into `out`
    /// (auto-selected backend; allocating convenience).
    pub fn forward_q(&self, x_q: &[i8], s_x: f32, m: usize, out: &mut [f32]) {
        let mut acc = Vec::new();
        self.forward_q_into(Kernels::auto(), x_q, s_x, m, &mut acc, out);
    }

    /// Quantize fp32 input rows at `s_x`, then run the int8 matmul
    /// (auto-selected backend). Returns the i8 codes so callers can
    /// reuse them.
    pub fn forward(&self, x: &[f32], s_x: f32, m: usize, out: &mut [f32]) -> Vec<i8> {
        let mut x_q = Vec::new();
        let mut acc = Vec::new();
        self.forward_into(Kernels::auto(), x, s_x, m, &mut x_q, &mut acc, out);
        x_q
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    #[test]
    fn i8_matmul_matches_f32_on_grid() {
        // inputs already on the int8 grid: integer and f32 paths agree
        let mut r = Pcg32::new(3);
        let (m, k, n) = (4usize, 8usize, 6usize);
        let s_x = 0.02f32;
        let s_w = 0.01f32;
        let x_q: Vec<i8> = (0..m * k).map(|_| (r.below(255) as i32 - 127) as i8).collect();
        let w_q: Vec<i8> = (0..k * n).map(|_| (r.below(255) as i32 - 127) as i8).collect();
        let mut acc = vec![0i32; m * n];
        matmul_i8(&x_q, &w_q, m, k, n, &mut acc);
        for i in 0..m {
            for j in 0..n {
                let mut f = 0.0f64;
                for p in 0..k {
                    f += (x_q[i * k + p] as f64 * s_x as f64) * (w_q[p * n + j] as f64 * s_w as f64);
                }
                let got = acc[i * n + j] as f64 * (s_x as f64 * s_w as f64);
                assert!((f - got).abs() < 1e-6, "({i},{j}): {f} vs {got}");
            }
        }
    }

    #[test]
    fn blocked_matches_naive_oracle() {
        // bit-exact across shapes where K and N are NOT multiples of
        // the block/unroll widths, on EVERY available dispatch backend
        // (the broader sweep lives in rust/tests/kernel_parity.rs)
        let mut r = Pcg32::new(77);
        let shapes = [(1usize, 7usize, 5usize), (3, 17, 33), (8, 64, 48), (2, 5, 16), (4, 1, 1)];
        for (m, k, n) in shapes {
            let x_q: Vec<i8> = (0..m * k).map(|_| (r.below(255) as i32 - 127) as i8).collect();
            let w_q: Vec<i8> = (0..k * n).map(|_| (r.below(255) as i32 - 127) as i8).collect();
            let mut want = vec![0i32; m * n];
            matmul_i8(&x_q, &w_q, m, k, n, &mut want);
            let packed = PackedWeightI8::pack(&w_q, k, n);
            let mut got = vec![0i32; m * n];
            matmul_i8_blocked(&x_q, &packed, m, &mut got);
            assert_eq!(want, got, "auto backend, shape ({m},{k},{n})");
            for backend in Kernels::available() {
                got.fill(7); // poison: kernel must overwrite fully
                matmul_i8_blocked_with(Kernels::for_backend(backend), &x_q, &packed, m, &mut got);
                assert_eq!(want, got, "{} backend, shape ({m},{k},{n})", backend.label());
            }
        }
    }

    #[test]
    fn gemm_exact_at_proven_k_bound() {
        // worst-case dot product at K = MAX_SAFE_K: every term is
        // (-128)·(-128) = 2¹⁴, so the i32 accumulator lands at
        // 131071 · 16384 = 2_147_467_264, a hair under i32::MAX — the
        // exact sum the const proof in quant::kernels promises fits.
        let k = quant::MAX_SAFE_K;
        let x_q = vec![-128i8; k];
        let w_q = vec![-128i8; k]; // K×1 matrix
        let packed = PackedWeightI8::pack(&w_q, k, 1);
        let want = (k as i64 * quant::MAX_ABS_PROD_I8) as i32;
        assert_eq!(want, 2_147_467_264);
        for backend in Kernels::available() {
            let mut out = vec![0i32; 1];
            matmul_i8_blocked_with(Kernels::for_backend(backend), &x_q, &packed, 1, &mut out);
            assert_eq!(out[0], want, "{} backend wrapped at the K bound", backend.label());
        }
    }

    #[test]
    #[cfg(debug_assertions)]
    #[should_panic(expected = "MAX_SAFE_K")]
    fn gemm_rejects_k_one_past_bound() {
        // one past the proven bound must trip the debug guard before
        // the kernel gets a chance to wrap silently
        let k = quant::MAX_SAFE_K + 1;
        let x_q = vec![-128i8; k];
        let w_q = vec![-128i8; k];
        let packed = PackedWeightI8::pack(&w_q, k, 1);
        let mut out = vec![0i32; 1];
        matmul_i8_blocked_with(Kernels::scalar(), &x_q, &packed, 1, &mut out);
    }

    #[test]
    fn qlinear_close_to_f32_linear() {
        let mut r = Pcg32::new(9);
        let (m, k, n) = (3usize, 32usize, 16usize);
        let w: Vec<f32> = (0..k * n).map(|_| r.normal() * 0.2).collect();
        let bias: Vec<f32> = (0..n).map(|_| r.normal() * 0.1).collect();
        let x: Vec<f32> = (0..m * k).map(|_| r.normal()).collect();
        let ql = QLinear::from_f32(&w, k, n, Some(bias.clone()));
        let s_x = crate::quant::scale_sym(crate::quant::amax(&x), 8);
        let mut got = vec![0.0f32; m * n];
        ql.forward(&x, s_x, m, &mut got);
        // reference: f32 matmul + bias
        let mut want = vec![0.0f32; m * n];
        for i in 0..m {
            for j in 0..n {
                let mut acc = bias[j];
                for p in 0..k {
                    acc += x[i * k + p] * w[p * n + j];
                }
                want[i * n + j] = acc;
            }
        }
        // error budget: k accumulations of (s_x/2 · |w| + s_w/2 · |x|)
        let tol = k as f32 * (s_x * 0.2 + ql.s_w * 3.0);
        for (a, b) in want.iter().zip(&got) {
            assert!((a - b).abs() < tol, "{a} vs {b} (tol {tol})");
        }
    }

    #[test]
    fn forward_into_reuses_scratch_capacity() {
        let mut r = Pcg32::new(12);
        let (m, k, n) = (2usize, 24usize, 20usize);
        let w: Vec<f32> = (0..k * n).map(|_| r.normal() * 0.2).collect();
        let ql = QLinear::from_f32(&w, k, n, None);
        let x: Vec<f32> = (0..m * k).map(|_| r.normal()).collect();
        let kers = Kernels::auto();
        let mut x_q = Vec::new();
        let mut acc = Vec::new();
        let mut out = vec![0.0f32; m * n];
        ql.forward_into(kers, &x, 0.05, m, &mut x_q, &mut acc, &mut out);
        let (cq, ca) = (x_q.capacity(), acc.capacity());
        let (pq, pa) = (x_q.as_ptr(), acc.as_ptr());
        for _ in 0..5 {
            ql.forward_into(kers, &x, 0.05, m, &mut x_q, &mut acc, &mut out);
        }
        assert_eq!(x_q.capacity(), cq);
        assert_eq!(acc.capacity(), ca);
        assert_eq!(x_q.as_ptr(), pq, "x_q scratch reallocated");
        assert_eq!(acc.as_ptr(), pa, "acc scratch reallocated");
    }

    #[test]
    fn fold_scale_scales_output() {
        let w = vec![1.0f32, -1.0, 0.5, 0.25];
        let ql = QLinear::from_f32(&w, 2, 2, None);
        let folded = QLinear::from_f32(&w, 2, 2, None).fold_scale(0.5);
        let x_q: Vec<i8> = vec![10, -20];
        let (mut a, mut b) = (vec![0.0f32; 2], vec![0.0f32; 2]);
        ql.forward_q(&x_q, 0.1, 1, &mut a);
        folded.forward_q(&x_q, 0.1, 1, &mut b);
        for (u, v) in a.iter().zip(&b) {
            assert!((u * 0.5 - v).abs() < 1e-6);
        }
    }
}
