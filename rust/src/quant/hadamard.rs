//! Walsh–Hadamard transform + Paley constructions (paper §3.3).
//! Mirrors `python/compile/quant/hadamard_util.py`: n = 2^p · m with
//! m ∈ {1, 12, 20}; the FWHT is the in-place O(n log n) butterfly the
//! fused Pallas kernel implements, reproduced here for the rust-side
//! analyses and cross-checks.

/// Legendre symbol (a/q) for odd prime q.
fn legendre(a: i64, q: i64) -> i64 {
    let a = a.rem_euclid(q);
    if a == 0 {
        return 0;
    }
    // a^((q-1)/2) mod q by fast exponentiation
    let mut base = a % q;
    let mut e = (q - 1) / 2;
    let mut acc = 1i64;
    while e > 0 {
        if e & 1 == 1 {
            acc = acc * base % q;
        }
        base = base * base % q;
        e >>= 1;
    }
    if acc == 1 {
        1
    } else {
        -1
    }
}

/// Paley type-I Hadamard matrix H_{q+1} for prime q ≡ 3 (mod 4).
pub fn paley(q: i64) -> Vec<Vec<f32>> {
    assert_eq!(q % 4, 3, "Paley-I needs q ≡ 3 (mod 4)");
    let n = (q + 1) as usize;
    let mut h = vec![vec![1.0f32; n]; n];
    for i in 1..n {
        h[i][0] = -1.0;
        for j in 1..n {
            let chi = legendre(j as i64 - i as i64, q);
            h[i][j] = if i == j { 1.0 } else { chi as f32 };
        }
    }
    h
}

/// Factor n = 2^p · m with m ∈ {1, 12, 20}. Returns (p, m).
pub fn decompose(n: usize) -> Option<(u32, usize)> {
    let mut odd = n;
    let mut p = 0u32;
    while odd % 2 == 0 {
        odd /= 2;
        p += 1;
    }
    match odd {
        1 => Some((p, 1)),
        3 | 5 if p >= 2 => Some((p - 2, odd * 4)),
        _ => None,
    }
}

/// Base matrix for m ∈ {1, 12, 20}.
pub fn base_matrix(m: usize) -> Vec<Vec<f32>> {
    match m {
        1 => vec![vec![1.0]],
        12 => paley(11),
        20 => paley(19),
        _ => panic!("no Hadamard base of size {m}"),
    }
}

/// Largest Paley base the constructions produce (m ∈ {1, 12, 20}) —
/// bounds [`FwhtPlan::apply_rows`]'s stack scratch.
pub const MAX_BASE: usize = 20;

/// A prepared transform for one size n = 2^p · m: the m×m base matrix
/// is built **once** (flattened, row-major) so every
/// [`FwhtPlan::apply_rows`] call is allocation-free — the per-block
/// temp lives on the stack ([`MAX_BASE`] floats). This is what keeps
/// the W8A8 decode step zero-alloc for Paley-base `d_inner`
/// (12·2^k / 20·2^k tiers), not just powers of two; each
/// `ssm::qmamba` layer caches one plan for its `d_inner`.
#[derive(Debug, Clone)]
pub struct FwhtPlan {
    n: usize,
    m: usize,
    /// flattened m×m base (empty when m == 1)
    base: Vec<f32>,
}

impl FwhtPlan {
    /// Prepare the transform for size `n`. Panics if n has no
    /// Hadamard construction (see [`decompose`]).
    pub fn new(n: usize) -> FwhtPlan {
        let (_, m) =
            decompose(n).unwrap_or_else(|| panic!("no Hadamard factorization for n={n}"));
        let base = if m > 1 {
            let hm = base_matrix(m);
            let mut flat = vec![0.0f32; m * m];
            for (i, row) in hm.iter().enumerate() {
                flat[i * m..(i + 1) * m].copy_from_slice(row);
            }
            flat
        } else {
            Vec::new()
        };
        FwhtPlan { n, m, base }
    }

    /// Transform size this plan was built for.
    pub fn n(&self) -> usize {
        self.n
    }

    /// In-place FWHT over the last axis of a row-major (rows × n)
    /// buffer: y = H_n x (unnormalized), **zero heap allocations**.
    /// Bit-identical to [`fwht_rows`] (same base contraction order,
    /// same butterfly schedule).
    pub fn apply_rows(&self, x: &mut [f32]) {
        let (n, m) = (self.n, self.m);
        assert_eq!(x.len() % n, 0, "buffer must be rows × n");
        let rows = x.len() / n;
        // base m×m contraction first (on contiguous m-blocks)
        if m > 1 {
            let mut tmp = [0.0f32; MAX_BASE];
            let tmp = &mut tmp[..m];
            for r in 0..rows {
                let row = &mut x[r * n..(r + 1) * n];
                for blk in row.chunks_exact_mut(m) {
                    for (i, t) in tmp.iter_mut().enumerate() {
                        let hrow = &self.base[i * m..(i + 1) * m];
                        *t = hrow.iter().zip(blk.iter()).map(|(h, b)| h * b).sum();
                    }
                    blk.copy_from_slice(tmp);
                }
            }
        }
        // 2^p butterfly stages over stride = h*m blocks
        let mut h = m;
        while h < n {
            for r in 0..rows {
                let row = &mut x[r * n..(r + 1) * n];
                let mut start = 0;
                while start < n {
                    for i in start..start + h {
                        let a = row[i];
                        let b = row[i + h];
                        row[i] = a + b;
                        row[i + h] = a - b;
                    }
                    start += 2 * h;
                }
            }
            h *= 2;
        }
    }
}

/// In-place FWHT over the last axis of a row-major (rows × n) buffer.
/// Computes y = H_n x (unnormalized). Panics if n has no construction.
/// Convenience wrapper that builds a [`FwhtPlan`] per call — hot paths
/// (the W8A8 step) hold a plan instead so the base matrix is not
/// rebuilt every invocation.
pub fn fwht_rows(x: &mut [f32], n: usize) {
    FwhtPlan::new(n).apply_rows(x);
}

/// Convenience: transform a single vector, returning a new Vec.
pub fn fwht(x: &[f32]) -> Vec<f32> {
    let mut v = x.to_vec();
    let n = x.len();
    fwht_rows(&mut v, n);
    v
}

/// Inverse transform: x = (1/n) H_nᵀ y. For the 2^p part H = Hᵀ; for
/// the Paley base Hᵀ ≠ H, so we apply the transpose base explicitly.
pub fn ifwht(y: &[f32]) -> Vec<f32> {
    let n = y.len();
    let (_, m) = decompose(n).unwrap();
    let mut v = y.to_vec();
    // butterflies are involutive up to scale; undo them first
    let mut h = n / 2;
    while h >= m {
        let mut start = 0;
        while start < n {
            for i in start..start + h {
                let a = v[i];
                let b = v[i + h];
                v[i] = a + b;
                v[i + h] = a - b;
            }
            start += 2 * h;
        }
        if h == m {
            break;
        }
        h /= 2;
    }
    if m > 1 {
        let hm = base_matrix(m);
        let mut tmp = vec![0.0f32; m];
        for blk in v.chunks_exact_mut(m) {
            for (i, t) in tmp.iter_mut().enumerate() {
                *t = (0..m).map(|j| hm[j][i] * blk[j]).sum(); // Hᵀ
            }
            blk.copy_from_slice(&tmp);
        }
    }
    for x in v.iter_mut() {
        *x /= n as f32;
    }
    v
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    #[test]
    fn paley_orthogonal() {
        for q in [11i64, 19] {
            let h = paley(q);
            let n = (q + 1) as usize;
            for i in 0..n {
                for j in 0..n {
                    let dot: f32 = (0..n).map(|k| h[i][k] * h[j][k]).sum();
                    let expect = if i == j { n as f32 } else { 0.0 };
                    assert!((dot - expect).abs() < 1e-3, "q={q} i={i} j={j} dot={dot}");
                }
            }
        }
    }

    #[test]
    fn fwht_inverse_roundtrip() {
        let mut rng = Pcg32::new(7);
        for n in [8usize, 64, 96, 128, 160, 192, 256, 320] {
            let x: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
            let y = fwht(&x);
            let back = ifwht(&y);
            for (a, b) in x.iter().zip(&back) {
                assert!((a - b).abs() < 1e-3, "n={n}");
            }
        }
    }

    #[test]
    fn fwht_preserves_energy() {
        // Parseval: ||Hx||² = n ||x||²
        let mut rng = Pcg32::new(3);
        for n in [64usize, 192, 320] {
            let x: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
            let y = fwht(&x);
            let ex: f32 = x.iter().map(|v| v * v).sum();
            let ey: f32 = y.iter().map(|v| v * v).sum();
            assert!((ey / (n as f32 * ex) - 1.0).abs() < 1e-3, "n={n}");
        }
    }

    #[test]
    fn fwht_smooths_outliers() {
        // The paper's use-case (§4.2): quantizing in the rotated space
        // preserves the small values that a direct outlier-skewed scale
        // crushes. Compare end-to-end reconstruction error.
        let n = 256;
        let mut rng = Pcg32::new(11);
        let mut x: Vec<f32> = (0..n).map(|_| 0.1 * rng.normal()).collect();
        x[17] = 100.0; // one massive outlier channel

        // direct: quantize x with its own abs-max scale
        let s_d = crate::quant::scale_sym(crate::quant::amax(&x), 8);
        let mut direct = x.clone();
        crate::quant::fake_quant_sym(&mut direct, s_d, 8);
        let err_direct: f32 = x.iter().zip(&direct).map(|(a, b)| (a - b) * (a - b)).sum();

        // rotated: quantize Hx, reconstruct via (1/n)Hᵀ
        let mut y = fwht(&x);
        let s_r = crate::quant::scale_sym(crate::quant::amax(&y), 8);
        crate::quant::fake_quant_sym(&mut y, s_r, 8);
        let back = ifwht(&y);
        let err_rot: f32 = x.iter().zip(&back).map(|(a, b)| (a - b) * (a - b)).sum();

        assert!(
            err_rot * 4.0 < err_direct,
            "rotated err {err_rot} should be ≪ direct err {err_direct}"
        );
    }

    #[test]
    fn plan_matches_per_call_transform_bit_exactly() {
        // the cached-base plan must be indistinguishable from the
        // build-per-call path, including multi-row buffers
        let mut rng = Pcg32::new(21);
        for n in [8usize, 48, 96, 128, 160, 192, 320] {
            let plan = FwhtPlan::new(n);
            assert_eq!(plan.n(), n);
            for rows in [1usize, 3] {
                let x: Vec<f32> = (0..rows * n).map(|_| rng.normal()).collect();
                let mut a = x.clone();
                let mut b = x;
                fwht_rows(&mut a, n);
                plan.apply_rows(&mut b);
                for (i, (u, v)) in a.iter().zip(&b).enumerate() {
                    assert_eq!(u.to_bits(), v.to_bits(), "n={n} rows={rows} i={i}");
                }
            }
        }
    }

    #[test]
    fn decompose_all_tiers() {
        assert_eq!(decompose(128), Some((7, 1)));
        assert_eq!(decompose(192), Some((4, 12)));
        assert_eq!(decompose(256), Some((8, 1)));
        assert_eq!(decompose(320), Some((4, 20)));
        assert_eq!(decompose(96), Some((3, 12)));
        assert_eq!(decompose(7), None);
    }
}
