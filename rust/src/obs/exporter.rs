//! Prometheus-style `/metrics` exporter (ISSUE 9): a tiny std-only
//! HTTP responder on one background thread — `TcpListener`, GET-only,
//! no routing beyond `/metrics`, no dependencies — rendering the
//! engine's typed [`MetricsSnapshot`] in the Prometheus text
//! exposition format (version 0.0.4).
//!
//! The exporter owns a *fetch closure* rather than the metrics
//! themselves: each scrape calls it to pull a fresh snapshot across
//! the engine mailbox, so the engine thread remains the only metrics
//! writer and the exporter never touches engine state. A fetch that
//! returns `None` (engine gone, mailbox closed) answers `503` so the
//! scraper sees the difference between "engine down" and "no traffic".
//!
//! Every series carries the `backend` / `kernels` / `weight_bits`
//! labels, so dashboards can overlay the fp32 arm against the W8A8 and
//! W4A8 tiers — the serving-side view of the paper's accuracy/latency
//! trade-off. Histograms use the log₂ bucket bounds from
//! [`crate::obs::hist::LogHistogram`] verbatim: `_bucket{le=...}`
//! cumulative counts, exact `_sum` / `_count`, plus a bucket-quantized
//! ITL quantile gauge for the p50/p95/p99 SLO lines.

use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

use crate::coordinator::metrics::MetricsSnapshot;
use crate::obs::hist::LogHistogram;

/// Labels attached to every exported series.
#[derive(Debug, Clone)]
pub struct ExporterLabels {
    /// engine backend (`native`, `threaded`, ...)
    pub backend: String,
    /// kernel backend reported by the runtime (`scalar`, `pallas`, ...)
    pub kernels: String,
    /// weight tier (`fp32`, `w8`, `w4`)
    pub weight_bits: String,
}

impl ExporterLabels {
    /// `backend="...",kernels="...",weight_bits="..."` — the shared
    /// label body (values are escaped per the exposition format).
    fn body(&self) -> String {
        format!(
            "backend=\"{}\",kernels=\"{}\",weight_bits=\"{}\"",
            escape_label(&self.backend),
            escape_label(&self.kernels),
            escape_label(&self.weight_bits),
        )
    }
}

/// Escape a label value per the text exposition format: backslash,
/// double quote, and newline must be escaped.
fn escape_label(v: &str) -> String {
    let mut out = String::with_capacity(v.len());
    for c in v.chars() {
        match c {
            '\\' => out.push_str("\\\\"),
            '"' => out.push_str("\\\""),
            '\n' => out.push_str("\\n"),
            _ => out.push(c),
        }
    }
    out
}

/// Format a sample value. Prometheus accepts integer, decimal, and
/// scientific notation; Rust's shortest-roundtrip `{}` emits exactly
/// those (and `NaN` for NaN, which the format also allows).
fn fmt_val(v: f64) -> String {
    if v == f64::INFINITY {
        "+Inf".to_owned()
    } else if v == f64::NEG_INFINITY {
        "-Inf".to_owned()
    } else {
        format!("{v}")
    }
}

fn push_gauge(out: &mut String, name: &str, help: &str, labels: &str, v: f64) {
    out.push_str(&format!("# HELP {name} {help}\n# TYPE {name} gauge\n"));
    out.push_str(&format!("{name}{{{labels}}} {}\n", fmt_val(v)));
}

fn push_counter(out: &mut String, name: &str, help: &str, labels: &str, v: u64) {
    out.push_str(&format!("# HELP {name} {help}\n# TYPE {name} counter\n"));
    out.push_str(&format!("{name}{{{labels}}} {v}\n"));
}

fn push_histogram(out: &mut String, name: &str, help: &str, labels: &str, h: &LogHistogram) {
    out.push_str(&format!("# HELP {name} {help}\n# TYPE {name} histogram\n"));
    for (ub, c) in h.cumulative_buckets() {
        out.push_str(&format!(
            "{name}_bucket{{{labels},le=\"{}\"}} {c}\n",
            fmt_val(ub)
        ));
    }
    out.push_str(&format!("{name}_bucket{{{labels},le=\"+Inf\"}} {}\n", h.count));
    out.push_str(&format!("{name}_sum{{{labels}}} {}\n", fmt_val(h.sum)));
    out.push_str(&format!("{name}_count{{{labels}}} {}\n", h.count));
}

/// Render a snapshot as the Prometheus text exposition (deterministic:
/// fixed series order, label order, and bucket order).
pub fn render_prometheus(snap: &MetricsSnapshot, labels: &ExporterLabels) -> String {
    let lb = labels.body();
    let mut out = String::with_capacity(4096);

    // request outcomes: one labeled counter per terminal FinishReason class
    out.push_str(
        "# HELP quamba_requests_total Requests that reached a terminal outcome.\n\
         # TYPE quamba_requests_total counter\n",
    );
    for (outcome, v) in [
        ("done", snap.requests_done),
        ("rejected", snap.rejected),
        ("deadline", snap.deadline_missed),
        ("cancelled", snap.cancelled),
        ("failed", snap.failed),
    ] {
        out.push_str(&format!(
            "quamba_requests_total{{{lb},outcome=\"{outcome}\"}} {v}\n"
        ));
    }

    push_counter(
        &mut out,
        "quamba_tokens_generated_total",
        "Decoded tokens emitted.",
        &lb,
        snap.tokens_out,
    );
    push_gauge(
        &mut out,
        "quamba_tokens_per_second",
        "Decode throughput over the engine-clock lifetime.",
        &lb,
        snap.tok_per_s,
    );
    push_gauge(
        &mut out,
        "quamba_shed_rate",
        "Fraction of outcomes shed by overload policy (rejected + deadline).",
        &lb,
        snap.shed_rate,
    );
    push_counter(
        &mut out,
        "quamba_snapshot_drops_total",
        "Prefix-cache snapshot inserts dropped by validation or cache panic.",
        &lb,
        snap.snapshot_drops,
    );
    push_counter(
        &mut out,
        "quamba_lanes_total",
        "Batch lanes scheduled across all decode rounds.",
        &lb,
        snap.total_lanes,
    );
    push_counter(
        &mut out,
        "quamba_padded_lanes_total",
        "Scheduled lanes that carried padding, not a live request.",
        &lb,
        snap.padded_lanes,
    );

    // speculative decoding (ISSUE 10): emitted even at zero so
    // dashboards can tell "spec off" from "scrape missing"
    push_counter(
        &mut out,
        "quamba_spec_rounds_total",
        "Speculative draft-verify rounds completed.",
        &lb,
        snap.spec_rounds,
    );
    push_counter(
        &mut out,
        "quamba_spec_drafted_tokens_total",
        "Draft tokens proposed by the speculative draft model.",
        &lb,
        snap.spec_drafted_tokens,
    );
    push_counter(
        &mut out,
        "quamba_spec_accepted_tokens",
        "Draft tokens accepted by target verification.",
        &lb,
        snap.spec_accepted_tokens,
    );
    push_histogram(
        &mut out,
        "quamba_spec_accept_len",
        "Accepted draft tokens per verify round (log2 buckets).",
        &lb,
        &snap.spec_accept_len,
    );

    if let Some(c) = &snap.cache {
        push_counter(&mut out, "quamba_cache_hits_total", "Prefix-cache hits.", &lb, c.hits);
        push_counter(&mut out, "quamba_cache_misses_total", "Prefix-cache misses.", &lb, c.misses);
        push_counter(
            &mut out,
            "quamba_cache_evictions_total",
            "Prefix-cache entries evicted.",
            &lb,
            c.evictions,
        );
        push_counter(
            &mut out,
            "quamba_cache_evicted_bytes_total",
            "Bytes reclaimed by prefix-cache eviction.",
            &lb,
            c.evicted_bytes,
        );
        push_counter(
            &mut out,
            "quamba_cache_prefill_tokens_saved_total",
            "Prompt tokens the prefix cache kept out of prefill.",
            &lb,
            c.prefill_tokens_saved,
        );
        push_gauge(
            &mut out,
            "quamba_cache_entries",
            "Live prefix-cache entries.",
            &lb,
            c.entries as f64,
        );
        push_gauge(
            &mut out,
            "quamba_cache_bytes_in_use",
            "Bytes held by live prefix-cache entries.",
            &lb,
            c.bytes_in_use as f64,
        );
    }

    push_histogram(
        &mut out,
        "quamba_ttft_ms",
        "Time to first token, ms (log2 buckets).",
        &lb,
        &snap.ttft_ms,
    );
    push_histogram(
        &mut out,
        "quamba_itl_ms",
        "Inter-token latency per emitted token, ms (log2 buckets).",
        &lb,
        &snap.itl_ms,
    );
    push_histogram(
        &mut out,
        "quamba_tick_ms",
        "Engine tick duration, ms (log2 buckets).",
        &lb,
        &snap.tick_ms,
    );
    push_histogram(
        &mut out,
        "quamba_queue_depth",
        "Submit-queue depth sampled each tick.",
        &lb,
        &snap.queue_depth,
    );

    // the SLO tail as ready-to-read gauges (bucket-quantized, clamped
    // to the exact min/max envelope)
    out.push_str(
        "# HELP quamba_itl_ms_quantile Bucket-quantized ITL quantiles, ms.\n\
         # TYPE quamba_itl_ms_quantile gauge\n",
    );
    for (q, qs) in [(0.5, "0.5"), (0.95, "0.95"), (0.99, "0.99")] {
        out.push_str(&format!(
            "quamba_itl_ms_quantile{{{lb},quantile=\"{qs}\"}} {}\n",
            fmt_val(snap.itl_ms.quantile(q))
        ));
    }
    out
}

/// The background scrape endpoint. One thread, blocking accept loop;
/// [`MetricsExporter::stop`] (also run on drop) flips a flag and
/// self-connects to unblock the accept.
pub struct MetricsExporter {
    port: u16,
    stop: Arc<AtomicBool>,
    thread: Option<std::thread::JoinHandle<()>>,
}

/// Pulls a fresh snapshot per scrape; `None` means the engine is gone.
pub type SnapshotFetch = Box<dyn Fn() -> Option<MetricsSnapshot> + Send>;

impl MetricsExporter {
    /// Bind `127.0.0.1:port` (`port` 0 picks an ephemeral port — read it
    /// back with [`MetricsExporter::port`]) and start serving scrapes.
    pub fn spawn(port: u16, labels: ExporterLabels, fetch: SnapshotFetch) -> std::io::Result<Self> {
        let listener = TcpListener::bind(("127.0.0.1", port))?;
        let port = listener.local_addr()?.port();
        let stop = Arc::new(AtomicBool::new(false));
        let stop_in = Arc::clone(&stop);
        let thread = std::thread::Builder::new()
            .name("quamba-metrics".into())
            .spawn(move || serve_loop(listener, labels, fetch, stop_in))?;
        Ok(MetricsExporter { port, stop, thread: Some(thread) })
    }

    /// The bound port (resolved when `spawn` was given port 0).
    pub fn port(&self) -> u16 {
        self.port
    }

    /// Stop the serve loop and join the thread. Idempotent.
    pub fn stop(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        // unblock the accept; a failed connect means the listener is
        // already gone, which is fine
        let _ = TcpStream::connect(("127.0.0.1", self.port));
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
    }
}

impl Drop for MetricsExporter {
    fn drop(&mut self) {
        self.stop();
    }
}

fn serve_loop(
    listener: TcpListener,
    labels: ExporterLabels,
    fetch: SnapshotFetch,
    stop: Arc<AtomicBool>,
) {
    for stream in listener.incoming() {
        if stop.load(Ordering::SeqCst) {
            return;
        }
        let mut stream = match stream {
            Ok(s) => s,
            Err(_) => continue,
        };
        // a stuck client must not wedge the exporter
        let _ = stream.set_read_timeout(Some(Duration::from_millis(500)));
        let _ = stream.set_write_timeout(Some(Duration::from_millis(500)));
        let _ = handle_conn(&mut stream, &labels, &fetch);
    }
}

fn handle_conn(
    stream: &mut TcpStream,
    labels: &ExporterLabels,
    fetch: &SnapshotFetch,
) -> std::io::Result<()> {
    // the request line is all we route on; drain up to 4 KiB of headers
    let mut buf = [0u8; 4096];
    let n = stream.read(&mut buf)?;
    let req = String::from_utf8_lossy(&buf[..n]);
    let line = req.lines().next().unwrap_or("");
    let mut parts = line.split_whitespace();
    let (method, path) = (parts.next().unwrap_or(""), parts.next().unwrap_or(""));

    let (status, ctype, body) = if method != "GET" {
        ("405 Method Not Allowed", "text/plain", "method not allowed\n".to_owned())
    } else if path != "/metrics" {
        ("404 Not Found", "text/plain", "try /metrics\n".to_owned())
    } else {
        match fetch() {
            Some(snap) => (
                "200 OK",
                "text/plain; version=0.0.4; charset=utf-8",
                render_prometheus(&snap, labels),
            ),
            None => ("503 Service Unavailable", "text/plain", "engine unavailable\n".to_owned()),
        }
    };
    let resp = format!(
        "HTTP/1.1 {status}\r\nContent-Type: {ctype}\r\nContent-Length: {}\r\nConnection: close\r\n\r\n{body}",
        body.len(),
    );
    stream.write_all(resp.as_bytes())?;
    stream.flush()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_snapshot() -> MetricsSnapshot {
        let mut itl = LogHistogram::new();
        for g in [1.0, 1.5, 2.0, 9.0] {
            itl.record(g);
        }
        let mut ttft = LogHistogram::new();
        ttft.record(12.0);
        let mut tick = LogHistogram::new();
        tick.record(0.25);
        let mut depth = LogHistogram::new();
        depth.record(3.0);
        MetricsSnapshot {
            requests_done: 2,
            rejected: 1,
            deadline_missed: 0,
            cancelled: 0,
            failed: 0,
            tokens_out: 70,
            snapshot_drops: 0,
            padded_lanes: 3,
            total_lanes: 8,
            spec_accept_len: {
                let mut h = LogHistogram::new();
                h.record(3.0);
                h.record(1.0);
                h
            },
            spec_rounds: 2,
            spec_drafted_tokens: 8,
            spec_accepted_tokens: 4,
            elapsed_ms: 100.0,
            tok_per_s: 700.0,
            shed_rate: 1.0 / 3.0,
            ttft_ms: ttft,
            tpot_ms: LogHistogram::new(),
            ttlt_ms: LogHistogram::new(),
            itl_ms: itl,
            tick_ms: tick,
            queue_depth: depth,
            cache: None,
        }
    }

    fn labels() -> ExporterLabels {
        ExporterLabels {
            backend: "native".into(),
            kernels: "scalar".into(),
            weight_bits: "w8".into(),
        }
    }

    #[test]
    fn exposition_has_counters_histograms_and_quantiles() {
        let text = render_prometheus(&sample_snapshot(), &labels());
        assert!(text.contains(
            "quamba_requests_total{backend=\"native\",kernels=\"scalar\",weight_bits=\"w8\",outcome=\"done\"} 2"
        ), "{text}");
        assert!(text.contains("outcome=\"rejected\"} 1"), "{text}");
        assert!(text.contains("quamba_tokens_generated_total{"), "{text}");
        assert!(text.contains("} 70\n"), "{text}");
        assert!(text.contains("# TYPE quamba_itl_ms histogram"), "{text}");
        assert!(text.contains("quamba_itl_ms_bucket{"), "{text}");
        assert!(text.contains("le=\"+Inf\"} 4"), "{text}");
        assert!(text.contains("quamba_itl_ms_count{"), "{text}");
        assert!(text.contains("quamba_itl_ms_quantile{"), "{text}");
        assert!(text.contains("quantile=\"0.99\""), "{text}");
        assert!(text.contains("quamba_spec_accepted_tokens{"), "{text}");
        assert!(text.contains("quamba_spec_rounds_total{"), "{text}");
        assert!(text.contains("# TYPE quamba_spec_accept_len histogram"), "{text}");
        // no cache stats synced → no cache series
        assert!(!text.contains("quamba_cache_"), "{text}");
        // deterministic rendering
        assert_eq!(text, render_prometheus(&sample_snapshot(), &labels()));
    }

    #[test]
    fn histogram_bucket_counts_are_cumulative_and_sum_exact() {
        let text = render_prometheus(&sample_snapshot(), &labels());
        let mut prev = 0u64;
        let mut n_buckets = 0;
        for line in text.lines().filter(|l| l.starts_with("quamba_itl_ms_bucket{")) {
            let c: u64 = line.rsplit(' ').next().and_then(|v| v.parse().ok()).expect("count");
            assert!(c >= prev, "bucket counts must be cumulative: {line}");
            prev = c;
            n_buckets += 1;
        }
        assert!(n_buckets >= 2, "expected multiple le buckets:\n{text}");
        assert_eq!(prev, 4, "+Inf bucket must equal the total count");
        assert!(text.contains("quamba_itl_ms_sum{") && text.contains("} 13.5\n"), "{text}");
    }

    #[test]
    fn label_values_are_escaped() {
        assert_eq!(escape_label("a\"b\\c\nd"), "a\\\"b\\\\c\\nd");
        assert_eq!(fmt_val(f64::INFINITY), "+Inf");
        assert_eq!(fmt_val(0.5), "0.5");
    }

    #[test]
    fn exporter_serves_scrapes_and_404s_other_paths() {
        let mut ex = MetricsExporter::spawn(
            0,
            labels(),
            Box::new(|| Some(sample_snapshot())),
        )
        .expect("bind ephemeral port");
        let port = ex.port();
        assert_ne!(port, 0);

        let body = http_get(port, "/metrics");
        assert!(body.starts_with("HTTP/1.1 200 OK"), "{body}");
        assert!(body.contains("quamba_tokens_generated_total"), "{body}");

        let miss = http_get(port, "/nope");
        assert!(miss.starts_with("HTTP/1.1 404"), "{miss}");

        ex.stop();
        ex.stop(); // idempotent
    }

    #[test]
    fn exporter_answers_503_when_engine_is_gone() {
        let mut ex = MetricsExporter::spawn(0, labels(), Box::new(|| None)).expect("bind");
        let body = http_get(ex.port(), "/metrics");
        assert!(body.starts_with("HTTP/1.1 503"), "{body}");
        ex.stop();
    }

    fn http_get(port: u16, path: &str) -> String {
        let mut s = TcpStream::connect(("127.0.0.1", port)).expect("connect");
        s.write_all(format!("GET {path} HTTP/1.1\r\nHost: localhost\r\n\r\n").as_bytes())
            .expect("send");
        let mut out = String::new();
        s.read_to_string(&mut out).expect("read");
        out
    }
}
