//! Flight-recorder tick tracing for the native engine (ISSUE 9).
//!
//! [`TraceRing`] is a preallocated fixed-capacity ring of fixed-size
//! [`SpanRecord`]s. The engine records one span per tick phase
//! (admission, plan, decode round, prefill chunk, snapshot insert,
//! harvest) plus an enclosing per-tick span; when the ring fills, the
//! **oldest records are overwritten** — the recorder always holds the
//! last `capacity` spans, which is exactly the "what just happened
//! before things went wrong" question a flight recorder answers.
//!
//! Contracts:
//! * `record` is zero-allocation after construction ([`SpanRecord`] is
//!   `Copy`, the buffer is pre-filled at `new`) — held to the counting
//!   allocator in `tests/zero_alloc.rs`;
//! * timestamps come from the engine's injectable clock
//!   ([`crate::coordinator::faults::Clock`]): wall-clock ms under
//!   `Clock::Wall`, deterministic tick-derived ms under
//!   `Clock::Manual` — so a seeded manual-clock run dumps a
//!   byte-identical trace every time;
//! * [`TraceRing::to_chrome_json`] renders the Chrome trace-event
//!   format (`chrome://tracing` / `ui.perfetto.dev`): one complete
//!   (`"ph":"X"`) event per span, phases on per-kind tracks via `tid`,
//!   timestamps in microseconds. Rendering allocates — it is a dump
//!   path, not a hot path.

use crate::util::json::{self, Json};

/// Sentinel `req_id` for spans not tied to one request.
pub const NO_REQ: u64 = u64::MAX;

/// Which tick phase a span covers.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SpanKind {
    /// the whole `NativeEngine::step` call
    Tick,
    /// deadline sweep + queue admission
    Admission,
    /// `batcher::plan_tick`
    Plan,
    /// one decode round (all decode lanes, one token each)
    DecodeRound,
    /// one batched (B, T) prefill sub-round
    PrefillChunk,
    /// one prefix-cache snapshot insert
    SnapshotInsert,
    /// the finished-lane harvest loop
    Harvest,
    /// one speculative draft round: catch-up prefill + K proposal
    /// steps on the draft model (ISSUE 10)
    DraftRound,
    /// one batched target verification of the speculating lanes'
    /// pending + drafted tokens (ISSUE 10)
    VerifyChunk,
}

impl SpanKind {
    /// Stable event name in the dumped trace.
    pub fn name(self) -> &'static str {
        match self {
            SpanKind::Tick => "tick",
            SpanKind::Admission => "admission",
            SpanKind::Plan => "plan",
            SpanKind::DecodeRound => "decode_round",
            SpanKind::PrefillChunk => "prefill_chunk",
            SpanKind::SnapshotInsert => "snapshot_insert",
            SpanKind::Harvest => "harvest",
            SpanKind::DraftRound => "draft_round",
            SpanKind::VerifyChunk => "verify_chunk",
        }
    }

    /// Track id in the dumped trace (one lane per phase kind).
    fn tid(self) -> u64 {
        match self {
            SpanKind::Tick => 0,
            SpanKind::Admission => 1,
            SpanKind::Plan => 2,
            SpanKind::DecodeRound => 3,
            SpanKind::PrefillChunk => 4,
            SpanKind::SnapshotInsert => 5,
            SpanKind::Harvest => 6,
            SpanKind::DraftRound => 7,
            SpanKind::VerifyChunk => 8,
        }
    }

    /// Every kind, in tid order (tests/tooling iterate this).
    pub fn all() -> [SpanKind; 9] {
        [
            SpanKind::Tick,
            SpanKind::Admission,
            SpanKind::Plan,
            SpanKind::DecodeRound,
            SpanKind::PrefillChunk,
            SpanKind::SnapshotInsert,
            SpanKind::Harvest,
            SpanKind::DraftRound,
            SpanKind::VerifyChunk,
        ]
    }
}

/// One fixed-size phase record. All fields are plain scalars so the
/// ring buffer is a flat `Copy` slab.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SpanRecord {
    pub kind: SpanKind,
    /// engine tick counter when the span closed
    pub tick: u64,
    /// clock-relative start, ms (see module docs for the clock rules)
    pub start_ms: f64,
    /// clock-relative end, ms; `end_ms >= start_ms`
    pub end_ms: f64,
    /// owning request, or [`NO_REQ`] for batch-level spans
    pub req_id: u64,
    /// tokens processed inside the span (admitted requests for
    /// `Admission`, harvested responses for `Harvest`)
    pub tokens: u32,
    /// lanes participating in the span
    pub lanes: u32,
}

impl Default for SpanRecord {
    fn default() -> Self {
        SpanRecord {
            kind: SpanKind::Tick,
            tick: 0,
            start_ms: 0.0,
            end_ms: 0.0,
            req_id: NO_REQ,
            tokens: 0,
            lanes: 0,
        }
    }
}

impl SpanRecord {
    pub fn duration_ms(&self) -> f64 {
        self.end_ms - self.start_ms
    }
}

/// Fixed-capacity overwrite-oldest span ring (see module docs).
#[derive(Debug, Clone)]
pub struct TraceRing {
    buf: Vec<SpanRecord>,
    /// next write slot
    head: usize,
    /// total spans ever recorded (≥ `buf.len()` once the ring wraps)
    written: u64,
}

impl TraceRing {
    /// Preallocate a ring of `capacity` span slots (min 1). All
    /// allocation happens here; [`TraceRing::record`] never allocates.
    pub fn new(capacity: usize) -> Self {
        let cap = capacity.max(1);
        TraceRing { buf: vec![SpanRecord::default(); cap], head: 0, written: 0 }
    }

    pub fn capacity(&self) -> usize {
        self.buf.len()
    }

    /// Spans currently held (saturates at capacity).
    pub fn len(&self) -> usize {
        (self.written as usize).min(self.buf.len())
    }

    pub fn is_empty(&self) -> bool {
        self.written == 0
    }

    /// Total spans ever recorded, including overwritten ones.
    pub fn total_recorded(&self) -> u64 {
        self.written
    }

    /// Record one span, overwriting the oldest slot when full. O(1),
    /// zero allocation.
    #[inline]
    pub fn record(&mut self, rec: SpanRecord) {
        self.buf[self.head] = rec;
        self.head += 1;
        if self.head == self.buf.len() {
            self.head = 0;
        }
        self.written += 1;
    }

    /// Retained spans, oldest first.
    pub fn iter(&self) -> impl Iterator<Item = &SpanRecord> {
        let n = self.len();
        let start = if self.written as usize > self.buf.len() { self.head } else { 0 };
        self.buf.iter().cycle().skip(start).take(n)
    }

    /// Render the retained spans as Chrome trace-event JSON
    /// (deterministic: object keys are sorted by the std-only JSON
    /// writer, span order is ring order).
    pub fn to_chrome_json(&self) -> String {
        let mut events: Vec<Json> = Vec::with_capacity(self.len() + 8);
        // metadata: name the process and one track per phase kind
        events.push(json::obj(vec![
            ("name", json::s("process_name")),
            ("ph", json::s("M")),
            ("pid", json::num(1.0)),
            ("tid", json::num(0.0)),
            ("args", json::obj(vec![("name", json::s("quamba-native-engine"))])),
        ]));
        for kind in SpanKind::all() {
            events.push(json::obj(vec![
                ("name", json::s("thread_name")),
                ("ph", json::s("M")),
                ("pid", json::num(1.0)),
                ("tid", json::num(kind.tid() as f64)),
                ("args", json::obj(vec![("name", json::s(kind.name()))])),
            ]));
        }
        for r in self.iter() {
            let mut args = vec![
                ("tick", json::num(r.tick as f64)),
                ("tokens", json::num(r.tokens as f64)),
                ("lanes", json::num(r.lanes as f64)),
            ];
            if r.req_id != NO_REQ {
                args.push(("req", json::num(r.req_id as f64)));
            }
            events.push(json::obj(vec![
                ("name", json::s(r.kind.name())),
                ("ph", json::s("X")),
                // chrome traces are in microseconds
                ("ts", json::num(r.start_ms * 1e3)),
                ("dur", json::num(r.duration_ms().max(0.0) * 1e3)),
                ("pid", json::num(1.0)),
                ("tid", json::num(r.kind.tid() as f64)),
                ("args", json::obj(args)),
            ]));
        }
        let doc = json::obj(vec![
            ("displayTimeUnit", json::s("ms")),
            ("traceEvents", Json::Arr(events)),
        ]);
        json::write(&doc) + "\n"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn span(tick: u64, start: f64) -> SpanRecord {
        SpanRecord {
            kind: SpanKind::DecodeRound,
            tick,
            start_ms: start,
            end_ms: start + 1.0,
            req_id: NO_REQ,
            tokens: 4,
            lanes: 4,
        }
    }

    #[test]
    fn ring_overwrites_oldest_first() {
        let mut r = TraceRing::new(4);
        assert!(r.is_empty());
        for i in 0..6u64 {
            r.record(span(i, i as f64));
        }
        assert_eq!(r.len(), 4);
        assert_eq!(r.total_recorded(), 6);
        let ticks: Vec<u64> = r.iter().map(|s| s.tick).collect();
        assert_eq!(ticks, vec![2, 3, 4, 5], "the two oldest spans are gone");
    }

    #[test]
    fn iter_before_wrap_is_in_recording_order() {
        let mut r = TraceRing::new(8);
        for i in 0..3u64 {
            r.record(span(i, i as f64));
        }
        let ticks: Vec<u64> = r.iter().map(|s| s.tick).collect();
        assert_eq!(ticks, vec![0, 1, 2]);
    }

    #[test]
    fn chrome_dump_parses_and_keeps_all_spans() {
        let mut r = TraceRing::new(16);
        for i in 0..5u64 {
            r.record(SpanRecord { req_id: i, ..span(i, i as f64 * 2.0) });
        }
        let txt = r.to_chrome_json();
        let doc = crate::util::json::parse(&txt).expect("dump must be valid JSON");
        let events = doc.get("traceEvents").as_arr().expect("traceEvents array");
        let xs: Vec<_> =
            events.iter().filter(|e| e.get("ph").as_str() == Some("X")).collect();
        assert_eq!(xs.len(), 5);
        for e in &xs {
            assert!(e.get("ts").as_f64().is_some());
            assert!(e.get("dur").as_f64().unwrap_or(-1.0) >= 0.0);
            assert!(e.get("args").get("tick").as_f64().is_some());
        }
        // deterministic: rendering twice gives the same bytes
        assert_eq!(txt, r.to_chrome_json());
    }

    #[test]
    fn capacity_is_clamped_to_one() {
        let mut r = TraceRing::new(0);
        r.record(span(1, 0.0));
        r.record(span(2, 1.0));
        assert_eq!(r.len(), 1);
        assert_eq!(r.iter().next().map(|s| s.tick), Some(2));
    }
}
