//! Observability for the native serving engine (ISSUE 9): flight-recorder
//! tick tracing, mergeable constant-memory histograms, and a
//! Prometheus-style `/metrics` exporter. Std-only — no new dependencies.
//!
//! * [`trace`] — [`trace::TraceRing`]: a preallocated overwrite-oldest
//!   ring of fixed-size per-phase span records, recorded from
//!   `NativeEngine::step` with zero allocation after construction and
//!   dumpable as Chrome trace-event JSON (`chrome://tracing`).
//! * [`hist`] — [`hist::LogHistogram`]: 64 log₂ buckets + exact
//!   moments; constant memory, bucket-wise mergeable, deterministic
//!   bucket indexing via the f64 exponent field.
//! * [`exporter`] — [`exporter::MetricsExporter`]: a one-thread
//!   GET-only `TcpListener` responder rendering the engine's typed
//!   `MetricsSnapshot` in the Prometheus text exposition format.
//!
//! Clock discipline (audited by the `clock-discipline` rule of
//! `quamba-audit`): nothing in this module reads wall time directly —
//! all timestamps arrive from the engine's injectable
//! [`crate::coordinator::faults::Clock`], so under `Clock::Manual` a
//! seeded run produces byte-identical traces and snapshots.

pub mod exporter;
pub mod hist;
pub mod trace;

pub use exporter::{render_prometheus, ExporterLabels, MetricsExporter, SnapshotFetch};
pub use hist::LogHistogram;
pub use trace::{SpanKind, SpanRecord, TraceRing, NO_REQ};
