//! Mergeable constant-memory log₂-bucket histograms (ISSUE 9).
//!
//! The metrics path used to keep raw sample vectors (capped by an
//! Algorithm-R reservoir for ITL) and sort them at report time. That
//! shape cannot cross the engine mailbox as numbers, cannot be merged
//! across engines/replicas, and its memory scales with traffic. This
//! histogram replaces it with a fixed 64-bucket power-of-two layout:
//!
//! * bucket `b` counts values `v` with `floor(log2(v)) + OFFSET == b`
//!   (clamped into `0..64`), i.e. bucket `b` covers
//!   `[2^(b-OFFSET), 2^(b+1-OFFSET))` milliseconds — ~58% worst-case
//!   relative quantile error, constant 600-ish bytes, no allocation
//!   after construction;
//! * exact first moments ride alongside (`count`, `sum`, `sum_sq`,
//!   `min`, `max`), so mean/std/min/max in summaries are *exact* and
//!   only the interior percentiles are bucket-quantized;
//! * `merge` is bucket-wise addition plus moment addition — two
//!   histograms recorded on different engines combine into exactly the
//!   histogram a single engine would have recorded (the property the
//!   ROADMAP's replica-routing item needs);
//! * bucket indexing reads the f64 exponent field directly
//!   ([`bucket_of`]), so identical inputs give identical histograms on
//!   every platform — no libm `log2` ULP drift.
//!
//! This is intentionally a *different* type from
//! [`crate::util::stats::LogHistogram`] (lo/ratio-parameterized, not
//! mergeable), which the bench harness keeps using.

use crate::util::stats::Summary;

/// Number of log₂ buckets (fixed; the struct is `Copy`-sized).
pub const N_BUCKETS: usize = 64;

/// Bucket shift: bucket 0's upper bound is `2^(1-OFFSET)` ms (≈ 1.9 ns),
/// bucket 62's is `2^43` ms; bucket 63 is the +∞ clamp. Wide enough for
/// nanosecond phase durations and day-long uptimes alike.
const OFFSET: i32 = 20;

/// Bucket index for a value (total order, clamped at both ends).
/// Non-finite inputs are the caller's job to filter ([`LogHistogram::record`]
/// drops them); zero and negatives land in bucket 0.
#[inline]
fn bucket_of(v: f64) -> usize {
    if v <= 0.0 {
        return 0;
    }
    // IEEE-754 exponent = floor(log2(v)) for normal v; subnormals give
    // -1023 which clamps to bucket 0 anyway.
    let e = ((v.to_bits() >> 52) & 0x7ff) as i32 - 1023;
    (e + OFFSET).clamp(0, N_BUCKETS as i32 - 1) as usize
}

/// Upper bound (exclusive) of bucket `b`, in ms; bucket 63 reports +∞.
#[inline]
pub fn bucket_upper_bound(b: usize) -> f64 {
    if b >= N_BUCKETS - 1 {
        f64::INFINITY
    } else {
        // 2^(b+1-OFFSET), exactly representable
        (2.0f64).powi(b as i32 + 1 - OFFSET)
    }
}

/// A mergeable fixed-memory log₂ histogram (see module docs).
#[derive(Debug, Clone, PartialEq)]
pub struct LogHistogram {
    counts: [u64; N_BUCKETS],
    /// exact number of recorded samples
    pub count: u64,
    /// exact sum of recorded samples
    pub sum: f64,
    /// exact sum of squares (for std)
    pub sum_sq: f64,
    /// exact minimum (+∞ when empty)
    pub min: f64,
    /// exact maximum (-∞ when empty)
    pub max: f64,
}

impl Default for LogHistogram {
    fn default() -> Self {
        LogHistogram {
            counts: [0; N_BUCKETS],
            count: 0,
            sum: 0.0,
            sum_sq: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }
}

impl LogHistogram {
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one sample. Non-finite values are dropped (the ITL path
    /// feeds NaN for the first token of a request, where no gap
    /// exists); zero-allocation, O(1).
    #[inline]
    pub fn record(&mut self, x: f64) {
        if !x.is_finite() {
            return;
        }
        self.count += 1;
        self.sum += x;
        self.sum_sq += x * x;
        if x < self.min {
            self.min = x;
        }
        if x > self.max {
            self.max = x;
        }
        self.counts[bucket_of(x)] += 1;
    }

    /// Bucket-wise merge: `self` becomes the histogram a single
    /// recorder observing both sample streams would hold.
    pub fn merge(&mut self, other: &LogHistogram) {
        for (a, b) in self.counts.iter_mut().zip(other.counts.iter()) {
            *a += b;
        }
        self.count += other.count;
        self.sum += other.sum;
        self.sum_sq += other.sum_sq;
        if other.min < self.min {
            self.min = other.min;
        }
        if other.max > self.max {
            self.max = other.max;
        }
    }

    /// Exact mean (NaN when empty).
    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            f64::NAN
        } else {
            self.sum / self.count as f64
        }
    }

    /// Sample standard deviation from the exact moments (0 for n < 2).
    pub fn std_dev(&self) -> f64 {
        if self.count < 2 {
            return 0.0;
        }
        let n = self.count as f64;
        let var = (self.sum_sq - self.sum * self.sum / n) / (n - 1.0);
        var.max(0.0).sqrt()
    }

    /// Bucket-quantized quantile, `q` in [0, 1]: the upper bound of the
    /// bucket holding the ⌈q·n⌉-th sample, clamped to the exact
    /// `[min, max]` envelope (NaN when empty).
    pub fn quantile(&self, q: f64) -> f64 {
        if self.count == 0 {
            return f64::NAN;
        }
        let target = ((q.clamp(0.0, 1.0) * self.count as f64).ceil() as u64).max(1);
        let mut acc = 0u64;
        for (b, &c) in self.counts.iter().enumerate() {
            acc += c;
            if acc >= target {
                return bucket_upper_bound(b).clamp(self.min, self.max);
            }
        }
        self.max
    }

    /// Latency [`Summary`] view: `n`/`mean`/`std`/`min`/`max` are exact,
    /// the interior percentiles are bucket-quantized.
    pub fn summary(&self) -> Summary {
        if self.count == 0 {
            return Summary::default();
        }
        Summary {
            n: self.count as usize,
            mean: self.mean(),
            std: self.std_dev(),
            min: self.min,
            p50: self.quantile(0.50),
            p90: self.quantile(0.90),
            p95: self.quantile(0.95),
            p99: self.quantile(0.99),
            max: self.max,
        }
    }

    /// Cumulative `(upper_bound_ms, cumulative_count)` pairs for
    /// Prometheus `_bucket` series: one pair per bucket up to the last
    /// non-empty bucket (the exporter appends the `+Inf` bucket, whose
    /// count is [`LogHistogram::count`]). Empty histogram → no pairs.
    pub fn cumulative_buckets(&self) -> Vec<(f64, u64)> {
        let last = match self.counts.iter().rposition(|&c| c > 0) {
            Some(i) => i,
            None => return Vec::new(),
        };
        let mut acc = 0u64;
        self.counts[..=last]
            .iter()
            .enumerate()
            .map(|(b, &c)| {
                acc += c;
                (bucket_upper_bound(b), acc)
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_moments_and_bucketed_quantiles() {
        let mut h = LogHistogram::new();
        for x in [10.0, 20.0, 10.0, 9.0] {
            h.record(x);
        }
        assert_eq!(h.count, 4);
        assert_eq!(h.sum, 49.0);
        assert_eq!(h.min, 9.0);
        assert_eq!(h.max, 20.0);
        assert!((h.mean() - 12.25).abs() < 1e-12);
        // quantiles are bucket bounds clamped into [min, max]
        let p50 = h.quantile(0.5);
        assert!((9.0..=20.0).contains(&p50), "p50={p50}");
        assert_eq!(h.quantile(1.0), 20.0);
        let s = h.summary();
        assert_eq!(s.n, 4);
        assert_eq!(s.max, 20.0);
        assert!(s.p50 <= s.p95 && s.p95 <= s.p99);
    }

    #[test]
    fn nan_and_infinite_are_dropped() {
        let mut h = LogHistogram::new();
        h.record(f64::NAN);
        h.record(f64::INFINITY);
        h.record(1.0);
        assert_eq!(h.count, 1);
        assert_eq!(h.sum, 1.0);
    }

    #[test]
    fn merge_equals_single_recorder() {
        let xs: Vec<f64> = (1..=100).map(|i| i as f64 * 0.37).collect();
        let mut whole = LogHistogram::new();
        let mut a = LogHistogram::new();
        let mut b = LogHistogram::new();
        for (i, &x) in xs.iter().enumerate() {
            whole.record(x);
            if i % 2 == 0 {
                a.record(x);
            } else {
                b.record(x);
            }
        }
        a.merge(&b);
        assert_eq!(a, whole, "merge must be exactly bucket-wise + moment-wise addition");
    }

    #[test]
    fn bucket_bounds_are_monotone_powers_of_two() {
        let mut prev = 0.0;
        for b in 0..N_BUCKETS - 1 {
            let ub = bucket_upper_bound(b);
            assert!(ub > prev, "bucket {b}: {ub} <= {prev}");
            assert_eq!(ub.log2().fract(), 0.0, "bound must be a power of two");
            prev = ub;
        }
        assert!(bucket_upper_bound(N_BUCKETS - 1).is_infinite());
    }

    #[test]
    fn extremes_clamp_into_end_buckets() {
        let mut h = LogHistogram::new();
        h.record(0.0);
        h.record(-3.0);
        h.record(1e300);
        assert_eq!(h.count, 3);
        let cb = h.cumulative_buckets();
        assert_eq!(cb.first().map(|&(_, c)| c), Some(2), "0 and -3 land in bucket 0");
        assert_eq!(cb.last().map(|&(_, c)| c), Some(3));
    }

    #[test]
    fn cumulative_buckets_are_nondecreasing_and_end_at_count() {
        let mut h = LogHistogram::new();
        for i in 0..50 {
            h.record(0.5 + i as f64);
        }
        let cb = h.cumulative_buckets();
        let mut prev = 0;
        for &(ub, c) in &cb {
            assert!(c >= prev);
            assert!(ub.is_finite());
            prev = c;
        }
        assert_eq!(prev, h.count);
    }
}
