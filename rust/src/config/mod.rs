//! Artifact manifest + runtime configuration.
//!
//! `artifacts/manifest.json` (written by `python/compile/aot.py`) is
//! the single source of truth: which graphs exist, their parameter
//! order, tier dimensions, data files. This module parses it into
//! typed structs the runtime and coordinator consume.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::util::json;

#[derive(Debug, Clone)]
pub struct TierInfo {
    pub name: String,
    pub paper_name: String,
    pub d_model: usize,
    pub n_layer: usize,
    pub d_state: usize,
    pub d_conv: usize,
    pub d_inner: usize,
    pub dt_rank: usize,
    pub vocab: usize,
    pub n_params: usize,
}

#[derive(Debug, Clone)]
pub struct TransformerTierInfo {
    pub name: String,
    pub paper_name: String,
    pub d_model: usize,
    pub n_layer: usize,
    pub n_head: usize,
    pub max_ctx: usize,
    pub vocab: usize,
    pub n_params: usize,
}

#[derive(Debug, Clone)]
pub struct GraphInfo {
    pub name: String,
    pub file: PathBuf,
    pub family: String, // "mamba" | "transformer"
    pub tier: String,
    pub method: String,
    pub kind: String, // "prefill" | "decode"
    pub batch: usize,
    pub seq: usize,
    pub weights_key: String,
}

#[derive(Debug, Clone)]
pub struct WeightsInfo {
    pub file: PathBuf,
    pub params: Vec<String>,
    pub bytes: usize,
}

#[derive(Debug)]
pub struct Manifest {
    pub root: PathBuf,
    pub vocab_size: usize,
    pub quick: bool,
    pub graphs: BTreeMap<String, GraphInfo>,
    pub weights: BTreeMap<String, WeightsInfo>,
    pub tiers: BTreeMap<String, TierInfo>,
    pub transformer_tiers: BTreeMap<String, TransformerTierInfo>,
    pub data: BTreeMap<String, PathBuf>,
}

impl Manifest {
    pub fn load(root: &Path) -> Result<Manifest, String> {
        let path = root.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .map_err(|e| format!("cannot read {path:?}: {e}. Run `make artifacts` first."))?;
        let j = json::parse(&text)?;
        let mut m = Manifest {
            root: root.to_path_buf(),
            vocab_size: j.get("vocab_size").as_usize().unwrap_or(256),
            quick: j.get("quick").as_bool().unwrap_or(false),
            graphs: BTreeMap::new(),
            weights: BTreeMap::new(),
            tiers: BTreeMap::new(),
            transformer_tiers: BTreeMap::new(),
            data: BTreeMap::new(),
        };
        if let Some(obj) = j.get("graphs").as_obj() {
            for (name, g) in obj {
                m.graphs.insert(
                    name.clone(),
                    GraphInfo {
                        name: name.clone(),
                        file: root.join(g.get("file").as_str().unwrap_or_default()),
                        family: g.get("family").as_str().unwrap_or("mamba").to_string(),
                        tier: g.get("tier").as_str().unwrap_or_default().to_string(),
                        method: g.get("method").as_str().unwrap_or_default().to_string(),
                        kind: g.get("kind").as_str().unwrap_or_default().to_string(),
                        batch: g.get("batch").as_usize().unwrap_or(1),
                        seq: g.get("seq").as_usize().unwrap_or(1),
                        weights_key: g.get("weights").as_str().unwrap_or_default().to_string(),
                    },
                );
            }
        }
        if let Some(obj) = j.get("weights").as_obj() {
            for (name, w) in obj {
                let params = w
                    .get("params")
                    .as_arr()
                    .map(|a| a.iter().filter_map(|x| x.as_str().map(String::from)).collect())
                    .unwrap_or_default();
                m.weights.insert(
                    name.clone(),
                    WeightsInfo {
                        file: root.join(w.get("file").as_str().unwrap_or_default()),
                        params,
                        bytes: w.get("bytes").as_usize().unwrap_or(0),
                    },
                );
            }
        }
        if let Some(obj) = j.get("tiers").as_obj() {
            for (name, t) in obj {
                m.tiers.insert(
                    name.clone(),
                    TierInfo {
                        name: name.clone(),
                        paper_name: t.get("paper_name").as_str().unwrap_or_default().to_string(),
                        d_model: t.get("d_model").as_usize().unwrap_or(0),
                        n_layer: t.get("n_layer").as_usize().unwrap_or(0),
                        d_state: t.get("d_state").as_usize().unwrap_or(16),
                        d_conv: t.get("d_conv").as_usize().unwrap_or(4),
                        d_inner: t.get("d_inner").as_usize().unwrap_or(0),
                        dt_rank: t.get("dt_rank").as_usize().unwrap_or(1),
                        vocab: t.get("vocab").as_usize().unwrap_or(256),
                        n_params: t.get("n_params").as_usize().unwrap_or(0),
                    },
                );
            }
        }
        if let Some(obj) = j.get("transformer_tiers").as_obj() {
            for (name, t) in obj {
                m.transformer_tiers.insert(
                    name.clone(),
                    TransformerTierInfo {
                        name: name.clone(),
                        paper_name: t.get("paper_name").as_str().unwrap_or_default().to_string(),
                        d_model: t.get("d_model").as_usize().unwrap_or(0),
                        n_layer: t.get("n_layer").as_usize().unwrap_or(0),
                        n_head: t.get("n_head").as_usize().unwrap_or(1),
                        max_ctx: t.get("max_ctx").as_usize().unwrap_or(2048),
                        vocab: t.get("vocab").as_usize().unwrap_or(256),
                        n_params: t.get("n_params").as_usize().unwrap_or(0),
                    },
                );
            }
        }
        if let Some(obj) = j.get("data").as_obj() {
            for (k, v) in obj {
                if let Some(s) = v.as_str() {
                    m.data.insert(k.clone(), root.join(s));
                }
            }
        }
        Ok(m)
    }

    /// Default artifacts root: $QUAMBA_ARTIFACTS or ./artifacts.
    pub fn default_root() -> PathBuf {
        std::env::var("QUAMBA_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|_| PathBuf::from("artifacts"))
    }

    /// Find a graph by (tier, method, kind, batch) with the largest
    /// seq ≤ `seq_at_most` (prefill) or exact batch (decode).
    pub fn find_graph(
        &self,
        tier: &str,
        method: &str,
        kind: &str,
        batch: usize,
        seq: Option<usize>,
    ) -> Option<&GraphInfo> {
        let mut best: Option<&GraphInfo> = None;
        for g in self.graphs.values() {
            if g.tier == tier && g.method == method && g.kind == kind && g.batch == batch {
                match seq {
                    None => return Some(g),
                    Some(s) => {
                        if g.seq == s {
                            return Some(g);
                        }
                        if best.map(|b| g.seq > b.seq).unwrap_or(true) {
                            best = Some(g);
                        }
                    }
                }
            }
        }
        best
    }

    pub fn methods_for_tier(&self, tier: &str, kind: &str) -> Vec<String> {
        let mut v: Vec<String> = self
            .graphs
            .values()
            .filter(|g| g.tier == tier && g.kind == kind)
            .map(|g| g.method.clone())
            .collect();
        v.sort();
        v.dedup();
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_minimal_manifest() {
        let dir = std::env::temp_dir().join("quamba_manifest_test");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.json"),
            r#"{"vocab_size": 256, "quick": true,
                "graphs": {"m130_fp16_decode_b1": {"file": "g.hlo.txt", "family": "mamba",
                  "tier": "m130", "method": "fp16", "kind": "decode", "batch": 1, "seq": 1,
                  "weights": "m130_fp16", "inputs": [], "outputs": []}},
                "weights": {"m130_fp16": {"file": "w.qtz", "params": ["a", "b"], "bytes": 10}},
                "tiers": {"m130": {"paper_name": "Mamba-130M", "d_model": 64, "n_layer": 2,
                  "d_state": 16, "d_conv": 4, "d_inner": 128, "dt_rank": 4,
                  "vocab": 256, "n_params": 1000}},
                "data": {"tasks": "data/tasks.json"}}"#,
        )
        .unwrap();
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.vocab_size, 256);
        assert!(m.quick);
        let g = m.find_graph("m130", "fp16", "decode", 1, None).unwrap();
        assert_eq!(g.weights_key, "m130_fp16");
        assert_eq!(m.weights["m130_fp16"].params, vec!["a", "b"]);
        assert_eq!(m.tiers["m130"].d_inner, 128);
    }
}
