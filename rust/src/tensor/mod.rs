//! Shape-checked host tensors + the `.qtz` container (shared with
//! `python/compile/qtz.py`). These are the host-side carriers between
//! the artifact files, the coordinator's state manager, and the PJRT
//! literals.

pub mod qtz;

use std::fmt;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DType {
    F32,
    I8,
    I32,
    U16,
    I64,
    U8,
}

impl DType {
    pub fn itemsize(self) -> usize {
        match self {
            DType::F32 | DType::I32 => 4,
            DType::I8 | DType::U8 => 1,
            DType::U16 => 2,
            DType::I64 => 8,
        }
    }
    pub fn code(self) -> u8 {
        match self {
            DType::F32 => 0,
            DType::I8 => 1,
            DType::I32 => 2,
            DType::U16 => 3,
            DType::I64 => 4,
            DType::U8 => 5,
        }
    }
    pub fn from_code(c: u8) -> Option<DType> {
        Some(match c {
            0 => DType::F32,
            1 => DType::I8,
            2 => DType::I32,
            3 => DType::U16,
            4 => DType::I64,
            5 => DType::U8,
            _ => return None,
        })
    }
}

/// A dense host tensor: raw little-endian bytes + shape + dtype.
/// Conversions to typed slices are zero-copy views where alignment
/// allows (always, for our Vec<u8>-backed buffers, via `bytemuck`-less
/// manual reads on the safe path).
#[derive(Clone, PartialEq)]
pub struct Tensor {
    pub dtype: DType,
    pub shape: Vec<usize>,
    pub data: Vec<u8>,
}

impl fmt::Debug for Tensor {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Tensor({:?}, {:?}, {} bytes)", self.dtype, self.shape, self.data.len())
    }
}

impl Tensor {
    pub fn new(dtype: DType, shape: Vec<usize>, data: Vec<u8>) -> Tensor {
        let n: usize = shape.iter().product();
        assert_eq!(n * dtype.itemsize(), data.len(), "shape/bytes mismatch");
        Tensor { dtype, shape, data }
    }

    pub fn zeros(dtype: DType, shape: &[usize]) -> Tensor {
        let n: usize = shape.iter().product();
        Tensor {
            dtype,
            shape: shape.to_vec(),
            data: vec![0u8; n * dtype.itemsize()],
        }
    }

    pub fn from_f32(shape: &[usize], v: &[f32]) -> Tensor {
        assert_eq!(shape.iter().product::<usize>(), v.len());
        let mut data = Vec::with_capacity(v.len() * 4);
        for x in v {
            data.extend_from_slice(&x.to_le_bytes());
        }
        Tensor {
            dtype: DType::F32,
            shape: shape.to_vec(),
            data,
        }
    }

    pub fn from_i8(shape: &[usize], v: &[i8]) -> Tensor {
        assert_eq!(shape.iter().product::<usize>(), v.len());
        Tensor {
            dtype: DType::I8,
            shape: shape.to_vec(),
            data: v.iter().map(|&x| x as u8).collect(),
        }
    }

    pub fn from_i32(shape: &[usize], v: &[i32]) -> Tensor {
        assert_eq!(shape.iter().product::<usize>(), v.len());
        let mut data = Vec::with_capacity(v.len() * 4);
        for x in v {
            data.extend_from_slice(&x.to_le_bytes());
        }
        Tensor {
            dtype: DType::I32,
            shape: shape.to_vec(),
            data,
        }
    }

    pub fn from_u16(shape: &[usize], v: &[u16]) -> Tensor {
        assert_eq!(shape.iter().product::<usize>(), v.len());
        let mut data = Vec::with_capacity(v.len() * 2);
        for x in v {
            data.extend_from_slice(&x.to_le_bytes());
        }
        Tensor {
            dtype: DType::U16,
            shape: shape.to_vec(),
            data,
        }
    }

    pub fn len(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn nbytes(&self) -> usize {
        self.data.len()
    }

    pub fn to_f32(&self) -> Vec<f32> {
        assert_eq!(self.dtype, DType::F32, "dtype {:?} != F32", self.dtype);
        self.data
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect()
    }

    pub fn to_i8(&self) -> Vec<i8> {
        assert_eq!(self.dtype, DType::I8);
        self.data.iter().map(|&b| b as i8).collect()
    }

    pub fn to_i32(&self) -> Vec<i32> {
        assert_eq!(self.dtype, DType::I32);
        self.data
            .chunks_exact(4)
            .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect()
    }

    pub fn to_u16(&self) -> Vec<u16> {
        assert_eq!(self.dtype, DType::U16);
        self.data
            .chunks_exact(2)
            .map(|c| u16::from_le_bytes([c[0], c[1]]))
            .collect()
    }

    /// Reshape (element count must match).
    pub fn reshape(mut self, shape: &[usize]) -> Tensor {
        assert_eq!(self.len(), shape.iter().product::<usize>());
        self.shape = shape.to_vec();
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_f32() {
        let t = Tensor::from_f32(&[2, 3], &[1.0, -2.0, 3.5, 0.0, 1e-8, -1e8]);
        assert_eq!(t.to_f32(), vec![1.0, -2.0, 3.5, 0.0, 1e-8, -1e8]);
        assert_eq!(t.nbytes(), 24);
    }

    #[test]
    fn roundtrip_i8() {
        let t = Tensor::from_i8(&[4], &[-128, -1, 0, 127]);
        assert_eq!(t.to_i8(), vec![-128, -1, 0, 127]);
    }

    #[test]
    #[should_panic]
    fn shape_mismatch_panics() {
        Tensor::from_f32(&[3], &[1.0, 2.0]);
    }
}
