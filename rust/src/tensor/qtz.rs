//! `.qtz` container reader/writer — byte-compatible with
//! `python/compile/qtz.py` (see that file for the format spec).

use std::collections::BTreeMap;
use std::fs::File;
use std::io::{self, Read, Write};
use std::path::Path;

use super::{DType, Tensor};

pub const MAGIC: &[u8; 4] = b"QTZ1";

/// Ordered tensor map (insertion order preserved — the manifest refers
/// to weights positionally by name list, but order keeps files stable).
pub struct QtzFile {
    pub names: Vec<String>,
    pub tensors: BTreeMap<String, Tensor>,
}

impl QtzFile {
    pub fn get(&self, name: &str) -> Option<&Tensor> {
        self.tensors.get(name)
    }

    pub fn total_bytes(&self) -> usize {
        self.tensors.values().map(|t| t.nbytes()).sum()
    }
}

pub fn load(path: &Path) -> io::Result<QtzFile> {
    let mut f = File::open(path)?;
    let mut buf = Vec::new();
    f.read_to_end(&mut buf)?;
    load_bytes(&buf).map_err(|e| io::Error::new(io::ErrorKind::InvalidData, format!("{path:?}: {e}")))
}

pub fn load_bytes(buf: &[u8]) -> Result<QtzFile, String> {
    let mut p = 0usize;
    let take = |p: &mut usize, n: usize| -> Result<&[u8], String> {
        if *p + n > buf.len() {
            return Err(format!("truncated at byte {p}"));
        }
        let s = &buf[*p..*p + n];
        *p += n;
        Ok(s)
    };
    if take(&mut p, 4)? != MAGIC {
        return Err("bad magic (not a QTZ1 file)".into());
    }
    let count = u32::from_le_bytes(take(&mut p, 4)?.try_into().unwrap()) as usize;
    // every tensor needs ≥ 4 header bytes: reject absurd counts before
    // any allocation (corrupted headers must error, not OOM-abort)
    if count > buf.len() / 4 {
        return Err(format!("implausible tensor count {count} for {} bytes", buf.len()));
    }
    let mut names = Vec::with_capacity(count);
    let mut tensors = BTreeMap::new();
    for _ in 0..count {
        let nlen = u16::from_le_bytes(take(&mut p, 2)?.try_into().unwrap()) as usize;
        let name = String::from_utf8(take(&mut p, nlen)?.to_vec())
            .map_err(|_| "non-utf8 tensor name")?;
        let hdr = take(&mut p, 2)?;
        let dtype = DType::from_code(hdr[0]).ok_or(format!("bad dtype code {}", hdr[0]))?;
        let ndim = hdr[1] as usize;
        let mut shape = Vec::with_capacity(ndim);
        for _ in 0..ndim {
            shape.push(u32::from_le_bytes(take(&mut p, 4)?.try_into().unwrap()) as usize);
        }
        let n: usize = shape.iter().product();
        let data = take(&mut p, n * dtype.itemsize())?.to_vec();
        names.push(name.clone());
        tensors.insert(name, Tensor::new(dtype, shape, data));
    }
    if p != buf.len() {
        return Err(format!("trailing bytes: {} of {}", buf.len() - p, buf.len()));
    }
    Ok(QtzFile { names, tensors })
}

pub fn save(path: &Path, entries: &[(String, Tensor)]) -> io::Result<()> {
    let mut out: Vec<u8> = Vec::new();
    out.extend_from_slice(MAGIC);
    out.extend_from_slice(&(entries.len() as u32).to_le_bytes());
    for (name, t) in entries {
        let nb = name.as_bytes();
        out.extend_from_slice(&(nb.len() as u16).to_le_bytes());
        out.extend_from_slice(nb);
        out.push(t.dtype.code());
        out.push(t.shape.len() as u8);
        for &d in &t.shape {
            out.extend_from_slice(&(d as u32).to_le_bytes());
        }
        out.extend_from_slice(&t.data);
    }
    let mut f = File::create(path)?;
    f.write_all(&out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn save_load_roundtrip() {
        let dir = std::env::temp_dir().join("qtz_test_rs");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("t.qtz");
        let entries = vec![
            ("a".to_string(), Tensor::from_f32(&[2, 2], &[1.0, 2.0, 3.0, 4.0])),
            ("b.weight".to_string(), Tensor::from_i8(&[3], &[-1, 0, 1])),
            ("c".to_string(), Tensor::from_u16(&[4], &[0, 1, 65535, 7])),
        ];
        save(&p, &entries).unwrap();
        let f = load(&p).unwrap();
        assert_eq!(f.names, vec!["a", "b.weight", "c"]);
        assert_eq!(f.get("a").unwrap().to_f32(), vec![1.0, 2.0, 3.0, 4.0]);
        assert_eq!(f.get("b.weight").unwrap().to_i8(), vec![-1, 0, 1]);
        assert_eq!(f.get("c").unwrap().to_u16(), vec![0, 1, 65535, 7]);
    }

    #[test]
    fn reject_garbage() {
        assert!(load_bytes(b"NOPE").is_err());
        assert!(load_bytes(b"QTZ1\x01\x00\x00\x00").is_err());
    }
}
