//! `quamba` — the leader binary: serving, generation, evaluation and
//! profiling over the AOT artifacts.
//!
//! Usage:
//!   quamba info        [--artifacts DIR]
//!   quamba generate    [--tier m2p8] [--method quamba] [--prompt-len 32]
//!                      [--max-new 64] [--temperature 0.8] [--top-k 20]
//!   quamba serve       [--tier m2p8] [--method quamba] [--requests 16]
//!                      [--rate 4.0] [--max-new 32]
//!                      [--backend auto|xla|native] [--weights x.qtz]
//!                      [--calib-file tokens.txt]
//!                      [--cache-mb 8] [--snapshot-stride 64]
//!                      [--prefill-chunk 64] [--max-tokens-per-tick 0]
//!                      [--threads N] [--kernels auto|scalar|avx2|neon]
//!                      [--bits 8|4]
//!                      [--spec-tokens K] [--spec-draft w4a8|fp32]
//!                      [--metrics-port P] [--trace-out FILE]
//!                      [--metrics-linger-ms MS]
//!   quamba eval-ppl    [--tier m130] [--methods fp16,quamba] [--windows 16]
//!   quamba eval-tasks  [--tier m130] [--methods fp16,quamba] [--examples 40]
//!   quamba profile     [--tier m2p8] [--methods fp16,quamba] [--seqs 256,512]
//!   quamba analyze     [--tier m2p8]   # activation distributions (Fig 8)

use std::path::{Path, PathBuf};

use anyhow::{anyhow, Result};
use quamba::bench_support::{f2, ms, Table, Workload};
use quamba::config::Manifest;
use quamba::coordinator::server::ServerHandle;
use quamba::coordinator::{EngineConfig, NativeEngineConfig, SamplingParams, SpecDraft};
use quamba::data;
use quamba::eval;
use quamba::obs::{ExporterLabels, MetricsExporter};
use quamba::quant::{KernelBackend, Kernels};
use quamba::runtime::Runtime;
use quamba::ssm::{MambaModel, MambaTier, QuantConfig, QuantizedMambaModel, StepModel};
use quamba::tensor::qtz;
use quamba::util::cli::Args;
use quamba::util::rng::Pcg32;

fn artifacts_root(args: &Args) -> PathBuf {
    args.get("artifacts")
        .map(PathBuf::from)
        .unwrap_or_else(Manifest::default_root)
}

fn main() {
    let args = Args::from_env(&["verbose", "help"]);
    let cmd = args.command.clone().unwrap_or_else(|| "help".to_string());
    let result = match cmd.as_str() {
        "info" => cmd_info(&args),
        "generate" => cmd_generate(&args),
        "serve" => cmd_serve(&args),
        "compare" => cmd_compare(&args),
        "eval-ppl" => cmd_eval_ppl(&args),
        "eval-tasks" => cmd_eval_tasks(&args),
        "profile" => cmd_profile(&args),
        "analyze" => cmd_analyze(&args),
        "help" | _ => {
            print_help();
            Ok(())
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn print_help() {
    println!(
        "quamba {} — W8A8 selective-SSM serving (Quamba reproduction)\n\n\
         commands:\n\
         \x20 info         artifact inventory\n\
         \x20 generate     generate text from a corpus prompt\n\
         \x20 compare      side-by-side FP vs quantized generation (paper Fig. 9)\n\
         \x20 serve        threaded serving demo with Poisson arrivals\n\
         \x20              (--backend native [--weights x.qtz] serves\n\
         \x20              artifact-free with the prefix cache and the\n\
         \x20              unified chunked-prefill scheduler:\n\
         \x20              --cache-mb / --snapshot-stride /\n\
         \x20              --prefill-chunk / --max-tokens-per-tick;\n\
         \x20              --max-queue bounds admission (overflow is\n\
         \x20              shed with typed Rejected responses) and\n\
         \x20              --default-deadline-ms applies a total-latency\n\
         \x20              deadline to every request (0 = off, both);\n\
         \x20              --calib-file feeds a real W8A8 calibration\n\
         \x20              token stream instead of synthetic tokens;\n\
         \x20              --bits 4 serves the packed-nibble W4A8 tier\n\
         \x20              — half the weight bytes, per-group scales;\n\
         \x20              --spec-tokens K enables self-speculative\n\
         \x20              decoding: a cheap draft twin (--spec-draft\n\
         \x20              w4a8|fp32) proposes K tokens/lane that the\n\
         \x20              target verifies in one batched prefill —\n\
         \x20              token streams stay bit-identical to plain\n\
         \x20              decode (0 = off);\n\
         \x20              --metrics-port P exposes Prometheus text at\n\
         \x20              http://127.0.0.1:P/metrics (0 = ephemeral,\n\
         \x20              the bound port is printed), --trace-out FILE\n\
         \x20              dumps the flight recorder as Chrome\n\
         \x20              trace-event JSON on drain, and\n\
         \x20              --metrics-linger-ms MS keeps the exporter up\n\
         \x20              after the workload for external scrapers)\n\
         \x20 eval-ppl     perplexity on wiki-synth / pile-synth (Table 2)\n\
         \x20 eval-tasks   six zero-shot tasks (Table 3)\n\
         \x20 profile      TTFT/TPOT latency profile (Table 1)\n\
         \x20 analyze      activation distribution dump (Fig. 8)\n\n\
         common options: --artifacts DIR --tier m130|m370|m1p4|m2p8 --method NAME",
        quamba::VERSION
    );
}

fn cmd_info(args: &Args) -> Result<()> {
    let mani = Manifest::load(&artifacts_root(args)).map_err(|e| anyhow!(e))?;
    println!("artifacts: {:?} (quick={})", mani.root, mani.quick);
    let mut t = Table::new("Model tiers", &["tier", "paper analog", "d_model", "layers", "params"]);
    for tier in mani.tiers.values() {
        t.row(vec![
            tier.name.clone(),
            tier.paper_name.clone(),
            tier.d_model.to_string(),
            tier.n_layer.to_string(),
            format!("{:.2}M", tier.n_params as f64 / 1e6),
        ]);
    }
    t.print();
    let mut t = Table::new("Weight bundles (resident bytes)", &["bundle", "MB"]);
    for (k, w) in &mani.weights {
        t.row(vec![k.clone(), format!("{:.2}", w.bytes as f64 / 1e6)]);
    }
    t.print();
    println!("\ngraphs: {}", mani.graphs.len());
    Ok(())
}

fn cmd_generate(args: &Args) -> Result<()> {
    let root = artifacts_root(args);
    let mani = Manifest::load(&root).map_err(|e| anyhow!(e))?;
    let tier = args.get_or("tier", mani.tiers.keys().next().map(|s| s.as_str()).unwrap_or("m130"));
    let method = args.get_or("method", "quamba");
    let prompt_len = args.get_usize("prompt-len", 32);
    let max_new = args.get_usize("max-new", 64);
    let temp = args.get_f64("temperature", 0.8) as f32;
    let top_k = args.get_usize("top-k", 20);

    let stream = data::load_stream(&mani.data["pile_eval"])?;
    let vocab = data::Vocab::load(&mani.data["vocab"])?;
    let prompt = stream[..prompt_len.min(stream.len())].to_vec();
    println!("prompt: {}", vocab.decode(&prompt));

    let mut server = ServerHandle::spawn(root, EngineConfig::new(tier, method))?;
    let rx = server.submit(
        prompt,
        max_new,
        SamplingParams { temperature: temp, top_k, seed: 7, ..Default::default() },
    );
    let resp = rx.recv().map_err(|_| anyhow!("engine dropped the request"))?;
    println!("\n[{tier}/{method}] generated: {}", vocab.decode(&resp.tokens));
    println!(
        "\nTTFT {:.1} ms · TPOT {:.2} ms/token · TTLT {:.1} ms · {} tokens",
        resp.ttft_ms,
        resp.tpot_ms,
        resp.ttlt_ms,
        resp.tokens.len()
    );
    if let Some(r) = server.metrics_report() {
        println!("\n{r}");
    }
    server.shutdown();
    Ok(())
}

/// Paper Figure 9: the same prompt through the FP and the quantized
/// model, reporting how far each got after a fixed wall-clock budget.
fn cmd_compare(args: &Args) -> Result<()> {
    let root = artifacts_root(args);
    let mani = Manifest::load(&root).map_err(|e| anyhow!(e))?;
    let tier = args.get_or("tier", "m2p8").to_string();
    let budget_s = args.get_f64("budget", 3.0);
    let stream = data::load_stream(&mani.data["pile_eval"])?;
    let vocab = data::Vocab::load(&mani.data["vocab"])?;
    let prompt = stream[..32.min(stream.len())].to_vec();
    println!("prompt: {}\n(budget: {budget_s}s per model)\n", vocab.decode(&prompt));
    for method in ["fp16", "quamba"] {
        use quamba::coordinator::engine::Engine;
        use quamba::coordinator::request::Request;
        let rt = Runtime::new(&root)?;
        let mut engine = match Engine::new(rt, EngineConfig::new(&tier, method)) {
            Ok(e) => e,
            Err(e) => {
                println!("[{method}] unavailable: {e}");
                continue;
            }
        };
        engine.warmup()?;
        engine.submit(Request {
            id: 1,
            prompt: prompt.clone(),
            max_new_tokens: 100_000,
            params: SamplingParams { temperature: 0.8, top_k: 20, seed: 9, ..Default::default() },
            stop_at_eos: false,
        });
        let t0 = std::time::Instant::now();
        let mut produced = 0usize;
        while t0.elapsed().as_secs_f64() < budget_s && engine.n_live() + engine.n_queued() > 0 {
            engine.step()?;
            produced = engine.tokens_generated();
        }
        println!(
            "[{method:>7}] {} tokens in {budget_s}s ({:.1} tok/s) — the paper's\n\
             \"T=20 snapshot\" analog: more content per wall-clock second.",
            produced,
            produced as f64 / budget_s
        );
    }
    Ok(())
}

/// `--metrics-port P`: start the std-only Prometheus exporter
/// ([`quamba::obs::exporter`]) against the server mailbox. Port 0
/// binds an ephemeral port; the bound port is always printed so
/// scrapers (and the CI metrics-smoke test) can find it. Returns the
/// guard — keep it alive for the serving window.
fn maybe_spawn_exporter(
    args: &Args,
    server: &ServerHandle,
    labels: ExporterLabels,
) -> Result<Option<MetricsExporter>> {
    let Some(raw) = args.get("metrics-port") else { return Ok(None) };
    let port: u16 =
        raw.parse().map_err(|_| anyhow!("--metrics-port {raw}: not a port number"))?;
    let exp = MetricsExporter::spawn(port, labels, server.snapshot_fetch())
        .map_err(|e| anyhow!("metrics exporter: {e}"))?;
    println!("metrics: listening on http://127.0.0.1:{}/metrics", exp.port());
    Ok(Some(exp))
}

/// `--metrics-linger-ms MS`: hold the process (and the exporter) open
/// after the workload drains so an external scraper can read a final
/// `/metrics` — the CI smoke test relies on this window.
fn metrics_linger(args: &Args) {
    let ms = args.get_f64("metrics-linger-ms", 0.0);
    if ms > 0.0 {
        println!("metrics: lingering {ms} ms for scrapers");
        std::thread::sleep(std::time::Duration::from_secs_f64(ms / 1e3));
    }
}

/// `--trace-out FILE`: write the engine's flight-recorder dump
/// (Chrome trace-event JSON) before shutdown.
fn maybe_write_trace(args: &Args, server: &ServerHandle) -> Result<()> {
    let Some(path) = args.get("trace-out") else { return Ok(()) };
    match server.dump_trace() {
        Some(json) => {
            std::fs::write(path, &json).map_err(|e| anyhow!("{path}: {e}"))?;
            println!("trace: wrote {} bytes of Chrome trace JSON to {path}", json.len());
        }
        None => println!(
            "trace: this backend has no flight recorder (--trace-out is a native-backend flag)"
        ),
    }
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    // backend dispatch: `native` serves artifact-free (from --weights
    // x.qtz or a synthetic tier); `xla` needs the AOT artifact tree;
    // `auto` picks xla when artifacts exist — unless --weights forces
    // the native import path
    let backend = args.get_or("backend", "auto");
    let use_xla = match backend {
        "xla" => true,
        "native" => false,
        _ => args.get("weights").is_none() && Manifest::load(&artifacts_root(args)).is_ok(),
    };
    if !use_xla {
        return cmd_serve_native(args);
    }
    let root = artifacts_root(args);
    let mani = Manifest::load(&root).map_err(|e| anyhow!(e))?;
    let tier = args.get_or("tier", "m2p8");
    let method = args.get_or("method", "quamba");
    let n = args.get_usize("requests", 16);
    let rate = args.get_f64("rate", 4.0);
    let max_new = args.get_usize("max-new", 32);

    let stream = data::load_stream(&mani.data["pile_eval"])?;
    let wl = Workload::poisson(&stream, n, rate, 8, 48, max_new, 42);

    let mut cfg = EngineConfig::new(tier, method);
    cfg.cache_bytes = args.get_mb("cache-mb", 0.0);
    let mut server = ServerHandle::spawn(root, cfg)?;
    let _exporter = maybe_spawn_exporter(
        args,
        &server,
        ExporterLabels {
            backend: "xla".into(),
            kernels: "xla".into(),
            weight_bits: if method == "fp16" { "16".into() } else { "8".into() },
        },
    )?;
    println!("serving {n} requests at ~{rate}/s on {tier}/{method} ...");
    let t0 = std::time::Instant::now();
    let mut rxs = Vec::new();
    for (i, prompt) in wl.prompts.iter().enumerate() {
        // honor arrival times
        let target = wl.arrival_s[i];
        let now = t0.elapsed().as_secs_f64();
        if target > now {
            std::thread::sleep(std::time::Duration::from_secs_f64(target - now));
        }
        rxs.push(server.submit(prompt.clone(), max_new, SamplingParams::default()));
    }
    let mut done = 0;
    for rx in rxs {
        if rx.recv().is_ok() {
            done += 1;
        }
    }
    println!("completed {done}/{n} in {:.2}s", t0.elapsed().as_secs_f64());
    if let Some(r) = server.metrics_report() {
        println!("\n{r}");
    }
    maybe_write_trace(args, &server)?;
    metrics_linger(args);
    server.shutdown();
    Ok(())
}

/// Parse a `--calib-file` token stream: decimal u16 token ids
/// separated by any whitespace (spaces/newlines). Ids must be < vocab
/// — calibrating on out-of-range ids would index past the embedding
/// table. This closes the ROADMAP "real calibration stream" leftover:
/// `CalibRecord::calibrate` consumes the user's corpus instead of the
/// deterministic synthetic tokens.
fn load_calib_tokens(path: &Path, vocab: usize) -> Result<Vec<u16>> {
    let text =
        std::fs::read_to_string(path).map_err(|e| anyhow!("{}: {e}", path.display()))?;
    let mut toks = Vec::new();
    for (i, w) in text.split_whitespace().enumerate() {
        let t: u16 = w
            .parse()
            .map_err(|_| anyhow!("{}: token #{i} ({w:?}) is not a u16 token id", path.display()))?;
        if (t as usize) >= vocab {
            return Err(anyhow!(
                "{}: token #{i} = {t} out of range for vocab {vocab}",
                path.display()
            ));
        }
        toks.push(t);
    }
    if toks.is_empty() {
        return Err(anyhow!("{}: empty calibration stream", path.display()));
    }
    Ok(toks)
}

/// `quamba serve --backend native [--weights x.qtz]`: real checkpoints
/// (or a synthetic tier) served artifact-free, with the prefix cache
/// and the unified chunked-prefill scheduler — the ROADMAP "weight
/// import for the native backend" item. The tier is inferred from the
/// bundle's tensor shapes; `--method quamba` (default) calibrates a
/// W8A8 model on `--calib-file` (falling back to a deterministic
/// synthetic stream), `--method fp32` serves the fp32 reference
/// directly.
fn cmd_serve_native(args: &Args) -> Result<()> {
    let n = args.get_usize("requests", 16);
    let rate = args.get_f64("rate", 4.0);
    let max_new = args.get_usize("max-new", 32);
    let method = args.get_or("method", "quamba").to_string();
    let seed = args.get_u64("seed", 7);
    let bits = args.get_usize("bits", 8);
    if bits != 8 && bits != 4 {
        return Err(anyhow!("--bits {bits}: supported weight widths are 8 (W8A8) and 4 (W4A8)"));
    }
    let spec_tokens = args.get_usize("spec-tokens", 0);
    let spec_draft = {
        let raw = args.get_or("spec-draft", "w4a8");
        SpecDraft::parse(raw).ok_or_else(|| anyhow!("--spec-draft {raw}: expected w4a8 or fp32"))?
    };

    let model = match args.get("weights") {
        Some(path) => {
            let q = qtz::load(Path::new(path))?;
            let tier = MambaTier::infer_from_qtz(
                Path::new(path).file_stem().and_then(|s| s.to_str()).unwrap_or("imported"),
                &q,
            )
            .map_err(|e| anyhow!("{path}: {e}"))?;
            println!(
                "imported {path}: d_model={} n_layer={} d_inner={} d_state={} vocab={}",
                tier.d_model, tier.n_layer, tier.d_inner, tier.d_state, tier.vocab
            );
            MambaModel::from_qtz(tier, &q).map_err(|e| anyhow!("{path}: {e}"))?
        }
        None => {
            let tier = MambaTier {
                name: "edge64".into(),
                d_model: 64,
                n_layer: 4,
                d_state: 8,
                d_conv: 4,
                d_inner: 128,
                dt_rank: 8,
                vocab: 256,
            };
            println!("no --weights given: serving the synthetic {} tier", tier.name);
            MambaModel::synthetic(tier, seed)
        }
    };
    let tier = model.tier.clone();
    let mut rng = Pcg32::new(seed ^ 0x5EED);
    // calibration stream: a real token stream via --calib-file, or
    // deterministic synthetic tokens as the artifact-free fallback.
    // Shared by the quantized target and the W4A8 draft twin so they
    // calibrate identically.
    let need_calib = method != "fp32" || (spec_tokens > 0 && spec_draft == SpecDraft::W4A8);
    let calib: Vec<u16> = if !need_calib {
        Vec::new()
    } else {
        match args.get("calib-file") {
            Some(path) => {
                let toks = load_calib_tokens(Path::new(path), tier.vocab)?;
                println!("calibration stream: {} tokens from {path}", toks.len());
                toks
            }
            None => {
                println!(
                    "calibration stream: 512 synthetic tokens \
                     (pass --calib-file FILE for a real corpus)"
                );
                (0..512).map(|_| rng.below(tier.vocab as u32) as u16).collect()
            }
        }
    };
    // speculative draft: a cheap twin built from the same weights —
    // packed-nibble W4A8 (default) or the fp32 reference rebuilt from
    // its source. Correctness never depends on the draft: the target's
    // verify pass keeps token streams bit-identical to plain decode.
    let draft: Option<Box<dyn StepModel + Send + Sync>> = if spec_tokens == 0 {
        None
    } else {
        Some(match spec_draft {
            SpecDraft::W4A8 => {
                let qcfg = QuantConfig { weight_bits: 4, ..QuantConfig::default() };
                let dm = QuantizedMambaModel::from_model(&model, &calib, &qcfg);
                println!(
                    "spec draft: W4A8 twin ({} KiB GEMM weights), K={spec_tokens}",
                    dm.gemm_weight_bytes() as f64 / 1024.0,
                );
                Box::new(dm) as Box<dyn StepModel + Send + Sync>
            }
            SpecDraft::Fp32 => {
                let dm = match args.get("weights") {
                    Some(path) => {
                        let q = qtz::load(Path::new(path))?;
                        MambaModel::from_qtz(tier.clone(), &q).map_err(|e| anyhow!("{path}: {e}"))?
                    }
                    None => MambaModel::synthetic(tier.clone(), seed),
                };
                println!("spec draft: fp32 reference, K={spec_tokens}");
                Box::new(dm) as Box<dyn StepModel + Send + Sync>
            }
        })
    };
    let boxed: Box<dyn StepModel + Send + Sync> = if method == "fp32" {
        Box::new(model)
    } else {
        let qcfg = QuantConfig { weight_bits: bits as u8, ..QuantConfig::default() };
        let qm = QuantizedMambaModel::from_model(&model, &calib, &qcfg);
        println!(
            "quantized tier: W{bits}A8 ({} KiB GEMM weights{})",
            qm.gemm_weight_bytes() as f64 / 1024.0,
            if bits == 4 { ", packed nibble + per-group scales" } else { "" },
        );
        Box::new(qm)
    };
    let cfg = NativeEngineConfig {
        weight_bits: if method == "fp32" { 32 } else { bits as u8 },
        threads: args.get_usize("threads", 1),
        kernel_backend: args
            .get("kernels")
            .filter(|v| *v != "auto")
            .map(|v| KernelBackend::parse(v).ok_or_else(|| anyhow!("--kernels {v}: unknown backend")))
            .transpose()?,
        cache_bytes: args.get_mb("cache-mb", 8.0),
        snapshot_stride: args.get_usize("snapshot-stride", 64),
        // serving entry points default to chunked prefill: long
        // prompts advance 64 tokens/tick so live lanes keep bounded
        // inter-token latency (tokens are identical at any chunk size;
        // --prefill-chunk 0 restores whole-prompt-per-tick behavior)
        prefill_chunk: args.get_usize("prefill-chunk", 64),
        max_tokens_per_tick: args.get_usize("max-tokens-per-tick", 0),
        // failure model (docs/ARCHITECTURE.md §7): bounded admission
        // queue (0 = unbounded) and an engine-wide total-latency
        // deadline (0 = none) for requests that don't set their own
        max_queue: args.get_usize("max-queue", 0),
        default_deadline_ms: args.get_f64("default-deadline-ms", 0.0),
        // flight recorder: on iff the dump is going somewhere
        trace: args.get("trace-out").is_some(),
        // speculative decoding: K draft tokens per lane per round
        spec_tokens,
        spec_draft,
        ..Default::default()
    };
    println!(
        "prefix cache: {} ({} MB budget, stride {}) | scheduler: prefill_chunk={} \
         max_tokens_per_tick={}",
        if cfg.cache_bytes > 0 { "on" } else { "off" },
        cfg.cache_bytes as f64 / 1e6,
        cfg.snapshot_stride,
        cfg.prefill_chunk,
        cfg.max_tokens_per_tick,
    );
    if cfg.max_queue > 0 || cfg.default_deadline_ms > 0.0 {
        println!(
            "admission control: max_queue={} default_deadline_ms={} \
             (overload sheds typed Rejected/DeadlineExceeded responses)",
            cfg.max_queue, cfg.default_deadline_ms,
        );
    }
    if cfg.spec_tokens > 0 {
        println!(
            "speculative decoding: K={} draft={} (token streams bit-identical to plain decode)",
            cfg.spec_tokens,
            cfg.spec_draft.label(),
        );
    }
    let stream: Vec<u16> =
        (0..4096).map(|_| rng.below(tier.vocab as u32) as u16).collect();
    let wl = Workload::poisson(&stream, n, rate, 8, 48, max_new, 42);
    let labels = ExporterLabels {
        backend: "native".into(),
        kernels: cfg
            .kernel_backend
            .map(|k| k.label().to_string())
            .unwrap_or_else(|| Kernels::detect().backend.label().to_string()),
        weight_bits: cfg.weight_bits.to_string(),
    };
    let mut server = match draft {
        Some(d) => ServerHandle::spawn_native_with_draft(boxed, d, cfg)?,
        None => ServerHandle::spawn_native(boxed, cfg)?,
    };
    let _exporter = maybe_spawn_exporter(args, &server, labels)?;
    println!("serving {n} requests at ~{rate}/s on {}/{method} (native) ...", tier.name);
    let t0 = std::time::Instant::now();
    let mut rxs = Vec::new();
    for (i, prompt) in wl.prompts.iter().enumerate() {
        let target = wl.arrival_s[i];
        let now = t0.elapsed().as_secs_f64();
        if target > now {
            std::thread::sleep(std::time::Duration::from_secs_f64(target - now));
        }
        rxs.push(server.submit(prompt.clone(), max_new, SamplingParams::default()));
    }
    // clean finishes only — shed/deadline-exceeded requests still get
    // typed responses and land on the report's failures line
    let done = rxs
        .into_iter()
        .filter(|rx| rx.recv().map(|r| r.finish.is_ok()).unwrap_or(false))
        .count();
    println!("completed {done}/{n} in {:.2}s", t0.elapsed().as_secs_f64());
    if let Some(r) = server.metrics_report() {
        println!("\n{r}");
    }
    maybe_write_trace(args, &server)?;
    metrics_linger(args);
    server.shutdown();
    Ok(())
}

fn cmd_eval_ppl(args: &Args) -> Result<()> {
    let root = artifacts_root(args);
    let mut rt = Runtime::new(&root)?;
    let tier = args.get_or("tier", "m130").to_string();
    let methods = args
        .get_list("methods")
        .unwrap_or_else(|| rt.manifest().methods_for_tier(&tier, "prefill"));
    let windows = args.get_usize("windows", 16);
    let wiki = data::load_stream(&rt.manifest().data["wiki_eval"])?;
    let pile = data::load_stream(&rt.manifest().data["pile_eval"])?;
    let mut t = Table::new(
        &format!("Perplexity — tier {tier} (paper Table 2 analog)"),
        &["method", "wiki-synth ppl", "pile-synth ppl", "tokens"],
    );
    for m in &methods {
        let w = eval::perplexity(&mut rt, &tier, m, &wiki, windows)?;
        let p = eval::perplexity(&mut rt, &tier, m, &pile, windows)?;
        t.row(vec![m.clone(), f2(w.ppl), f2(p.ppl), w.n_tokens.to_string()]);
    }
    t.print();
    Ok(())
}

fn cmd_eval_tasks(args: &Args) -> Result<()> {
    let root = artifacts_root(args);
    let mut rt = Runtime::new(&root)?;
    let tier = args.get_or("tier", "m130").to_string();
    let methods = args
        .get_list("methods")
        .unwrap_or_else(|| rt.manifest().methods_for_tier(&tier, "prefill"));
    let max_ex = args.get_usize("examples", 60);
    let tasks = data::load_tasks(&rt.manifest().data["tasks"])?;
    let mut header: Vec<String> = vec!["method".into()];
    header.extend(tasks.iter().map(|t| t.name.clone()));
    header.push("avg".into());
    let hdr_refs: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
    let mut t = Table::new(
        &format!("Zero-shot accuracy — tier {tier} (paper Table 3 analog)"),
        &hdr_refs,
    );
    for m in &methods {
        let res = eval::run_tasks(&mut rt, &tier, m, &tasks, max_ex)?;
        let mut row = vec![m.clone()];
        row.extend(res.iter().map(|(_, a)| quamba::bench_support::pct(*a)));
        row.push(quamba::bench_support::pct(eval::average_accuracy(&res)));
        t.row(row);
    }
    t.print();
    Ok(())
}

fn cmd_profile(args: &Args) -> Result<()> {
    let root = artifacts_root(args);
    let mut rt = Runtime::new(&root)?;
    let tier = args.get_or("tier", "m2p8").to_string();
    let methods = args
        .get_list("methods")
        .unwrap_or_else(|| vec!["fp16".into(), "quamba".into()]);
    let iters = args.get_usize("iters", 20);
    let mut t = Table::new(
        &format!("Latency profile — tier {tier} (paper Table 1 analog)"),
        &["method", "size (MB)", "L=1 (ms)", "prefill graphs (ms)"],
    );
    for m in &methods {
        // decode (TPOT)
        let l1 = if let Some(g) = rt.manifest().find_graph(&tier, m, "decode", 1, None) {
            let gname = g.name.clone();
            let tinfo = rt.manifest().tiers[&tier].clone();
            let tok = quamba::tensor::Tensor::from_i32(&[1, 1], &[5]);
            let conv = quamba::tensor::Tensor::zeros(
                quamba::tensor::DType::F32,
                &[tinfo.n_layer, 1, tinfo.d_conv - 1, tinfo.d_inner],
            );
            let ssm = quamba::tensor::Tensor::zeros(
                quamba::tensor::DType::F32,
                &[tinfo.n_layer, 1, tinfo.d_inner, tinfo.d_state],
            );
            rt.load(&gname)?;
            let s = quamba::bench_support::bench_ms(3, iters, || {
                rt.execute(&gname, &[tok.clone(), conv.clone(), ssm.clone()]).unwrap();
            });
            ms(s.mean)
        } else {
            "-".into()
        };
        // prefill latencies over available (B=1) graphs
        let mut pf_parts = Vec::new();
        let graphs: Vec<(String, usize)> = rt
            .manifest()
            .graphs
            .values()
            .filter(|g| g.tier == tier && &g.method == m && g.kind == "prefill" && g.batch == 1)
            .map(|g| (g.name.clone(), g.seq))
            .collect();
        let mut graphs = graphs;
        graphs.sort_by_key(|(_, s)| *s);
        for (gname, seq) in graphs {
            let toks: Vec<i32> = (0..seq as i32).map(|i| (i % 200) + 4).collect();
            let s = {
                let tinfo = rt.manifest().tiers[&tier].clone();
                let tok = quamba::tensor::Tensor::from_i32(&[1, seq], &toks);
                let conv = quamba::tensor::Tensor::zeros(
                    quamba::tensor::DType::F32,
                    &[tinfo.n_layer, 1, tinfo.d_conv - 1, tinfo.d_inner],
                );
                let ssm = quamba::tensor::Tensor::zeros(
                    quamba::tensor::DType::F32,
                    &[tinfo.n_layer, 1, tinfo.d_inner, tinfo.d_state],
                );
                rt.load(&gname)?;
                quamba::bench_support::bench_ms(1, iters.min(10), || {
                    rt.execute(&gname, &[tok.clone(), conv.clone(), ssm.clone()]).unwrap();
                })
            };
            pf_parts.push(format!("L={seq}:{}", ms(s.mean)));
        }
        let size = rt
            .model_bytes(&format!("{tier}_{m}"))
            .map(|b| format!("{:.2}", b as f64 / 1e6))
            .unwrap_or_else(|| "-".into());
        t.row(vec![m.clone(), size, l1, pf_parts.join(" ")]);
    }
    t.print();
    Ok(())
}

fn cmd_analyze(args: &Args) -> Result<()> {
    let root = artifacts_root(args);
    let rt = Runtime::new(&root)?;
    let tier_name = args.get_or("tier", "m130").to_string();
    let mani = rt.manifest();
    let tinfo = mani
        .tiers
        .get(&tier_name)
        .ok_or_else(|| anyhow!("unknown tier"))?;
    let q = rt.weight_qtz(&format!("{tier_name}_fp16"))?;
    let model = quamba::ssm::MambaModel::from_qtz(
        quamba::ssm::MambaTier {
            name: tinfo.name.clone(),
            d_model: tinfo.d_model,
            n_layer: tinfo.n_layer,
            d_state: tinfo.d_state,
            d_conv: tinfo.d_conv,
            d_inner: tinfo.d_inner,
            dt_rank: tinfo.dt_rank,
            vocab: tinfo.vocab,
        },
        &q,
    )
    .map_err(|e| anyhow!(e))?;
    let stream = data::load_stream(&mani.data["pile_eval"])?;
    let toks = &stream[..256.min(stream.len())];
    let mut taps = Vec::new();
    let _ = model.forward(toks, &quamba::ssm::mamba::QuantSites::none(), Some(&mut taps));
    let mut t = Table::new(
        &format!("SSM activation ranges — tier {tier_name} (paper Fig. 8/12 analog)"),
        &["layer", "|x| max", "|x| p99", "|y| max", "|gated| max", "|H·gated| max"],
    );
    for (i, tap) in taps.iter().enumerate() {
        t.row(vec![
            i.to_string(),
            f2(tap.x_ssm_absmax as f64),
            f2(tap.x_ssm_p99 as f64),
            f2(tap.y_absmax as f64),
            f2(tap.gated_absmax as f64),
            f2(tap.gated_h_absmax as f64),
        ]);
    }
    t.print();
    println!(
        "\nNote: outliers concentrate in |gated| (paper: y tensor) and are\n\
         suppressed by the Hadamard transform (|H·gated| spread over ~√n)."
    );
    Ok(())
}
