//! Pure-rust fp32 Mamba model over `.qtz` weights — the instrumentable
//! reference simulator (Fig. 2/8/10/12 analyses + runtime cross-check).
//!
//! Matches `python/compile/model.py::forward_fp` (including the
//! outlier-injection gain diagonals shipped as `__gains.*` in the
//! weight bundle). The full-sequence [`MambaModel::forward`] drives
//! the analyses; the layer math lives in shared `pub(crate)` helpers
//! so the stateful decode path ([`super::step`]) and the W8A8 native
//! model ([`super::qmamba`]) execute the identical arithmetic.

use crate::quant;
use crate::tensor::qtz::QtzFile;
use crate::util::rng::Pcg32;

#[derive(Debug, Clone)]
pub struct MambaTier {
    pub name: String,
    pub d_model: usize,
    pub n_layer: usize,
    pub d_state: usize,
    pub d_conv: usize,
    pub d_inner: usize,
    pub dt_rank: usize,
    pub vocab: usize,
}

impl MambaTier {
    /// Infer every tier dimension from a `.qtz` weight bundle's tensor
    /// shapes — `embedding.weight` (V, d), `layers.0.conv1d.weight`
    /// (W, d_inner), `layers.0.A_log` (d_inner, N),
    /// `layers.0.dt_proj.weight` (r, d_inner); the layer count is the
    /// run of `layers.N.norm.weight` tensors. This is what lets
    /// `quamba serve --backend native --weights x.qtz` come up with no
    /// artifact manifest at all: the checkpoint is self-describing.
    pub fn infer_from_qtz(name: &str, q: &QtzFile) -> Result<MambaTier, String> {
        let shape = |t: &str, ndim: usize| -> Result<Vec<usize>, String> {
            let s = q
                .get(t)
                .map(|x| x.shape.clone())
                .ok_or_else(|| format!("missing tensor {t}"))?;
            if s.len() != ndim {
                return Err(format!("tensor {t}: expected {ndim}-d shape, got {s:?}"));
            }
            Ok(s)
        };
        let emb = shape("embedding.weight", 2)?;
        let conv = shape("layers.0.conv1d.weight", 2)?;
        let a = shape("layers.0.A_log", 2)?;
        let dt = shape("layers.0.dt_proj.weight", 2)?;
        if conv[1] != a[0] || dt[1] != a[0] {
            return Err(format!(
                "inconsistent d_inner: conv1d {conv:?} vs A_log {a:?} vs dt_proj {dt:?}"
            ));
        }
        let mut n_layer = 0usize;
        while q.get(&format!("layers.{n_layer}.norm.weight")).is_some() {
            n_layer += 1;
        }
        if n_layer == 0 {
            return Err("no layers.N.norm.weight tensors — not a Mamba bundle".into());
        }
        Ok(MambaTier {
            name: name.to_string(),
            d_model: emb[1],
            n_layer,
            d_state: a[1],
            d_conv: conv[0],
            d_inner: conv[1],
            dt_rank: dt[0],
            vocab: emb[0],
        })
    }
}

/// Which tensor sites to fake-quantize during a forward pass — the
/// instrument behind the Figure 2/6/10 sensitivity analyses.
#[derive(Debug, Clone, Default)]
pub struct QuantSites {
    pub bits: u32,
    pub x_ssm: bool,
    pub y_out: bool,
    pub b: bool,
    pub c: bool,
    pub dt: bool,
    pub conv_in: bool,
    pub gated: bool,
    /// clip percentile for the x site (100 = abs-max)
    pub x_percentile: f64,
    /// rotate the gated tensor with H before quantizing (Quamba out)
    pub y_hadamard: bool,
    /// restrict quantization to these layers (None = all) — the paper
    /// §D future-work probe: "layers closer to the model output have
    /// larger outlier values, suggesting different quantization
    /// schemes can be applied to the earlier layers"
    pub layer_mask: Option<Vec<bool>>,
    /// quantize the x site with an FP8 minifloat instead of int8 —
    /// (exp_bits, man_bits), e.g. (4,3)=E4M3, (5,2)=E5M2 (paper §F)
    pub x_fp8: Option<(i32, i32)>,
}

impl QuantSites {
    pub fn none() -> Self {
        QuantSites { bits: 8, x_percentile: 100.0, ..Default::default() }
    }

    fn layer_on(&self, li: usize) -> bool {
        self.layer_mask.as_ref().map(|m| m.get(li).copied().unwrap_or(true)).unwrap_or(true)
    }
}

/// Per-layer activation statistics collected during a forward pass
/// (drives the Fig. 3/8/12 distribution dumps).
#[derive(Debug, Clone, Default)]
pub struct LayerTaps {
    pub x_ssm_absmax: f32,
    pub x_ssm_p99: f32,
    pub y_absmax: f32,
    pub gated_absmax: f32,
    pub gated_h_absmax: f32,
    pub conv_in_absmax: f32,
}

pub struct MambaModel {
    pub tier: MambaTier,
    // weights, all fp32 row-major
    pub(crate) embedding: Vec<f32>,            // (V, d)
    pub(crate) norm_f: Vec<f32>,               // (d,)
    pub(crate) layers: Vec<Layer>,
    pub(crate) g_x: Vec<f32>,                  // (L, di)
    pub(crate) g_y: Vec<f32>,                  // (L, di)
}

pub(crate) struct Layer {
    pub(crate) norm: Vec<f32>,       // (d,)
    pub(crate) in_proj: Vec<f32>,    // (d, 2di)
    pub(crate) conv_w: Vec<f32>,     // (W, di)
    pub(crate) conv_b: Vec<f32>,     // (di,)
    pub(crate) x_proj: Vec<f32>,     // (di, r+2n)
    pub(crate) dt_proj: Vec<f32>,    // (r, di)
    pub(crate) dt_bias: Vec<f32>,    // (di,)
    pub(crate) a: Vec<f32>,          // (di, n) = -exp(A_log)
    pub(crate) d: Vec<f32>,          // (di,)
    pub(crate) out_proj: Vec<f32>,   // (di, d)
}

pub(crate) fn silu(x: f32) -> f32 {
    x / (1.0 + (-x).exp())
}

pub(crate) fn softplus(x: f32) -> f32 {
    if x > 20.0 {
        x
    } else {
        (1.0 + x.exp()).ln()
    }
}

/// y (M×N) = x (M×K) @ w (K×N)
pub(crate) fn matmul(x: &[f32], w: &[f32], m: usize, k: usize, n: usize, out: &mut [f32]) {
    assert_eq!(x.len(), m * k);
    assert_eq!(w.len(), k * n);
    assert_eq!(out.len(), m * n);
    out.fill(0.0);
    for i in 0..m {
        for p in 0..k {
            let xv = x[i * k + p];
            if xv == 0.0 {
                continue;
            }
            let wrow = &w[p * n..(p + 1) * n];
            let orow = &mut out[i * n..(i + 1) * n];
            for j in 0..n {
                orow[j] += xv * wrow[j];
            }
        }
    }
}

pub(crate) fn rmsnorm(x: &[f32], w: &[f32], d: usize, eps: f32, out: &mut [f32]) {
    for (row_in, row_out) in x.chunks_exact(d).zip(out.chunks_exact_mut(d)) {
        let ms: f32 = row_in.iter().map(|v| v * v).sum::<f32>() / d as f32;
        let r = 1.0 / (ms + eps).sqrt();
        for j in 0..d {
            row_out[j] = row_in[j] * r * w[j];
        }
    }
}

/// Copy columns [lo, hi) of a (rows × row_w) matrix into a new buffer.
pub(crate) fn take_cols(src: &[f32], rows: usize, row_w: usize, lo: usize, hi: usize) -> Vec<f32> {
    debug_assert_eq!(src.len(), rows * row_w);
    let w = hi - lo;
    let mut out = Vec::with_capacity(rows * w);
    for r in 0..rows {
        out.extend_from_slice(&src[r * row_w + lo..r * row_w + hi]);
    }
    out
}

/// [`take_cols`] into a caller-owned slice (the zero-alloc hot path).
pub(crate) fn take_cols_into(
    src: &[f32],
    rows: usize,
    row_w: usize,
    lo: usize,
    hi: usize,
    out: &mut [f32],
) {
    debug_assert_eq!(src.len(), rows * row_w);
    let w = hi - lo;
    assert_eq!(out.len(), rows * w);
    for r in 0..rows {
        out[r * w..(r + 1) * w].copy_from_slice(&src[r * row_w + lo..r * row_w + hi]);
    }
}

/// Causal depthwise conv + SiLU + per-channel gain over a (tl × di)
/// time-major block — the one conv implementation shared by the
/// full-sequence forward, the stateful prefill, and the decode step.
///
/// `hist` is the carried window of the last (W−1) conv *inputs*
/// (oldest row first); `None` means zero history (a fresh sequence).
/// When given, it is advanced in place to the last (W−1) inputs of
/// [hist ; x], so chunked calls compose exactly with one full call.
#[allow(clippy::too_many_arguments)]
pub(crate) fn causal_conv_silu(
    x: &[f32],
    mut hist: Option<&mut [f32]>,
    conv_w: &[f32],
    conv_b: &[f32],
    gx: &[f32],
    tl: usize,
    di: usize,
    w: usize,
    out: &mut [f32],
) {
    assert_eq!(x.len(), tl * di);
    assert_eq!(out.len(), tl * di);
    assert_eq!(conv_w.len(), w * di);
    if let Some(h) = hist.as_deref() {
        assert_eq!(h.len(), (w - 1) * di);
    }
    for ti in 0..tl {
        for ch in 0..di {
            let mut acc = conv_b[ch];
            for j in 0..w {
                let src = ti as isize - (w as isize - 1) + j as isize;
                let v = if src >= 0 {
                    x[src as usize * di + ch]
                } else if let Some(h) = hist.as_deref() {
                    h[(src + w as isize - 1) as usize * di + ch]
                } else {
                    continue;
                };
                acc += v * conv_w[j * di + ch];
            }
            out[ti * di + ch] = silu(acc) * gx[ch];
        }
    }
    if let Some(h) = hist.as_deref_mut() {
        // slide the window: new history = last (w−1) rows of [hist ; x]
        let hw = w - 1;
        for s in 0..hw {
            let src_row = tl + s; // index into the (hw + tl)-row concat
            if src_row < hw {
                h.copy_within(src_row * di..(src_row + 1) * di, s * di);
            } else {
                let xr = src_row - hw;
                h[s * di..(s + 1) * di].copy_from_slice(&x[xr * di..(xr + 1) * di]);
            }
        }
    }
}

fn maybe_quant(site_on: bool, xs: &mut [f32], bits: u32, pctl: f64) {
    if !site_on {
        return;
    }
    let am = if pctl >= 100.0 {
        quant::amax(xs)
    } else {
        quant::percentile_amax(xs, pctl)
    };
    let s = quant::scale_sym(am, bits);
    quant::fake_quant_sym(xs, s, bits);
}

impl MambaModel {
    /// Load the fp16-method weight bundle for a tier.
    ///
    /// Every tensor is shape-checked against the tier's dimensions and
    /// scanned for non-finite values before it reaches the kernels — a
    /// truncated or corrupted `.qtz` fails here with a typed message
    /// naming the tensor, not later as a silent slice panic or a NaN
    /// stream mid-decode (ISSUE 7 failure model).
    pub fn from_qtz(tier: MambaTier, q: &QtzFile) -> Result<MambaModel, String> {
        let f32s = |name: &str, want: usize| -> Result<Vec<f32>, String> {
            let t = q.get(name).ok_or_else(|| format!("missing tensor {name}"))?;
            let xs = t.to_f32();
            if xs.len() != want {
                return Err(format!(
                    "tensor {name}: {} values, expected {want} for tier dims",
                    xs.len()
                ));
            }
            if let Some(i) = xs.iter().position(|v| !v.is_finite()) {
                return Err(format!("tensor {name}: non-finite value at index {i}"));
            }
            Ok(xs)
        };
        let (d, di, n, rk, w, v) =
            (tier.d_model, tier.d_inner, tier.d_state, tier.dt_rank, tier.d_conv, tier.vocab);
        let mut layers = Vec::with_capacity(tier.n_layer);
        for i in 0..tier.n_layer {
            let p = format!("layers.{i}.");
            layers.push(Layer {
                norm: f32s(&format!("{p}norm.weight"), d)?,
                in_proj: f32s(&format!("{p}in_proj.weight"), d * 2 * di)?,
                conv_w: f32s(&format!("{p}conv1d.weight"), w * di)?,
                conv_b: f32s(&format!("{p}conv1d.bias"), di)?,
                x_proj: f32s(&format!("{p}x_proj.weight"), di * (rk + 2 * n))?,
                dt_proj: f32s(&format!("{p}dt_proj.weight"), rk * di)?,
                dt_bias: f32s(&format!("{p}dt_proj.bias"), di)?,
                a: f32s(&format!("{p}A_log"), di * n)?
                    .iter()
                    .map(|v| -v.exp())
                    .collect(),
                d: f32s(&format!("{p}D"), di)?,
                out_proj: f32s(&format!("{p}out_proj.weight"), di * d)?,
            });
        }
        let gains = |name: &str| -> Result<Vec<f32>, String> {
            // Optional calibration gains: absent → identity; present
            // with the wrong shape → a hard error (half-written file).
            match q.get(name) {
                None => Ok(vec![1.0f32; tier.n_layer * di]),
                Some(_) => f32s(name, tier.n_layer * di),
            }
        };
        Ok(MambaModel {
            embedding: f32s("embedding.weight", v * d)?,
            norm_f: f32s("norm_f.weight", d)?,
            layers,
            g_x: gains("__gains.g_x")?,
            g_y: gains("__gains.g_y")?,
            tier,
        })
    }

    /// Deterministic synthetic weights for a tier — powers the
    /// artifact-free ("edge") serving scenario, the native-decode
    /// parity tests, and the native benches. Initialization follows
    /// standard Mamba practice: unit norms, fan-in-scaled projections,
    /// Δ-bias in softplus⁻¹([~0.02, ~0.3]), A in (−2, −0.5).
    pub fn synthetic(tier: MambaTier, seed: u64) -> MambaModel {
        fn nrm(r: &mut Pcg32, count: usize, scale: f32) -> Vec<f32> {
            (0..count).map(|_| r.normal() * scale).collect()
        }
        let mut r = Pcg32::new(seed);
        let (d, di, n, rk, w, v, l) = (
            tier.d_model,
            tier.d_inner,
            tier.d_state,
            tier.dt_rank,
            tier.d_conv,
            tier.vocab,
            tier.n_layer,
        );
        let embedding = nrm(&mut r, v * d, 1.0);
        let norm_f = vec![1.0f32; d];
        let mut layers = Vec::with_capacity(l);
        for _ in 0..l {
            let norm = vec![1.0f32; d];
            let in_proj = nrm(&mut r, d * 2 * di, 1.0 / (d as f32).sqrt());
            let conv_w = nrm(&mut r, w * di, 0.5);
            let conv_b = nrm(&mut r, di, 0.1);
            let x_proj = nrm(&mut r, di * (rk + 2 * n), 1.0 / (di as f32).sqrt());
            let dt_proj = nrm(&mut r, rk * di, 1.0 / (rk as f32).sqrt());
            let dt_bias: Vec<f32> = (0..di).map(|_| r.range_f32(-4.0, -1.0)).collect();
            let a: Vec<f32> = (0..di * n).map(|_| -(0.5 + 1.5 * r.f32())).collect();
            let dvec = nrm(&mut r, di, 1.0);
            let out_proj = nrm(&mut r, di * d, 1.0 / (di as f32).sqrt());
            layers.push(Layer {
                norm,
                in_proj,
                conv_w,
                conv_b,
                x_proj,
                dt_proj,
                dt_bias,
                a,
                d: dvec,
                out_proj,
            });
        }
        let ones = vec![1.0f32; l * di];
        MambaModel { embedding, norm_f, layers, g_x: ones.clone(), g_y: ones, tier }
    }

    /// Final rmsnorm over `rows` residual rows.
    pub(crate) fn final_hidden(&self, resid: &[f32], rows: usize) -> Vec<f32> {
        let d = self.tier.d_model;
        let mut fin = vec![0.0f32; rows * d];
        rmsnorm(resid, &self.norm_f, d, 1e-5, &mut fin);
        fin
    }

    /// Tied-embedding logits: fin (rows × d) @ embeddingᵀ → (rows × V).
    pub(crate) fn tied_logits(&self, fin: &[f32], rows: usize) -> Vec<f32> {
        let mut logits = Vec::new();
        self.tied_logits_into(fin, rows, &mut logits);
        logits
    }

    /// [`Self::tied_logits`] into a caller-owned buffer (cleared and
    /// refilled; allocation-free once warmed up to capacity).
    pub(crate) fn tied_logits_into(&self, fin: &[f32], rows: usize, logits: &mut Vec<f32>) {
        let d = self.tier.d_model;
        let v = self.tier.vocab;
        // grow-only resize: every element is assigned below
        logits.resize(rows * v, 0.0);
        for ti in 0..rows {
            let frow = &fin[ti * d..(ti + 1) * d];
            for tok in 0..v {
                let erow = &self.embedding[tok * d..(tok + 1) * d];
                logits[ti * v + tok] = erow.iter().zip(frow).map(|(a, b)| a * b).sum();
            }
        }
    }

    /// Forward over a token sequence (B=1). Returns logits (T × V).
    /// `sites` selects fake-quantized tensors; `taps` (if given)
    /// collects per-layer activation stats.
    pub fn forward(
        &self,
        tokens: &[u16],
        sites: &QuantSites,
        mut taps: Option<&mut Vec<LayerTaps>>,
    ) -> Vec<f32> {
        let t = self.tier.clone();
        let (d, di, n, r, w, tl) = (t.d_model, t.d_inner, t.d_state, t.dt_rank, t.d_conv, tokens.len());
        let mut resid = vec![0.0f32; tl * d];
        for (i, &tok) in tokens.iter().enumerate() {
            resid[i * d..(i + 1) * d]
                .copy_from_slice(&self.embedding[tok as usize * d..(tok as usize + 1) * d]);
        }
        let mut x_in = vec![0.0f32; tl * d];
        let mut xz = vec![0.0f32; tl * 2 * di];
        let mut bcdt = vec![0.0f32; tl * (r + 2 * n)];
        let mut out = vec![0.0f32; tl * d];
        for (li, layer) in self.layers.iter().enumerate() {
            rmsnorm(&resid, &layer.norm, d, 1e-5, &mut x_in);
            matmul(&x_in, &layer.in_proj, tl, d, 2 * di, &mut xz);
            // split x / z
            let mut x = take_cols(&xz, tl, 2 * di, 0, di);
            let z = take_cols(&xz, tl, 2 * di, di, 2 * di);
            let conv_in_absmax = quant::amax(&x);
            maybe_quant(sites.conv_in && sites.layer_on(li), &mut x, sites.bits, 100.0);
            // causal depthwise conv + SiLU + x-gain
            let gx = &self.g_x[li * di..(li + 1) * di];
            let mut xs = vec![0.0f32; tl * di];
            causal_conv_silu(&x, None, &layer.conv_w, &layer.conv_b, gx, tl, di, w, &mut xs);
            let x_ssm_absmax = quant::amax(&xs);
            let x_ssm_p99 = quant::percentile_amax(&xs, 99.0);
            if sites.layer_on(li) {
                if let Some((e, m)) = sites.x_fp8 {
                    quant::fake_quant_fp8(&mut xs, e, m);
                } else {
                    maybe_quant(sites.x_ssm, &mut xs, sites.bits, sites.x_percentile);
                }
            }
            // selection projections
            matmul(&xs, &layer.x_proj, tl, di, r + 2 * n, &mut bcdt);
            let mut dt_low = take_cols(&bcdt, tl, r + 2 * n, 0, r);
            let mut bmat = take_cols(&bcdt, tl, r + 2 * n, r, r + n);
            let mut cmat = take_cols(&bcdt, tl, r + 2 * n, r + n, r + 2 * n);
            maybe_quant(sites.dt && sites.layer_on(li), &mut dt_low, sites.bits, 100.0);
            maybe_quant(sites.b && sites.layer_on(li), &mut bmat, sites.bits, 100.0);
            maybe_quant(sites.c && sites.layer_on(li), &mut cmat, sites.bits, 100.0);
            let mut dt = vec![0.0f32; tl * di];
            matmul(&dt_low, &layer.dt_proj, tl, r, di, &mut dt);
            for ti in 0..tl {
                for ch in 0..di {
                    dt[ti * di + ch] = softplus(dt[ti * di + ch] + layer.dt_bias[ch]);
                }
            }
            // scan
            let p = super::scan::ScanParams { a: &layer.a, d: &layer.d, d_inner: di, n_state: n };
            let mut h = vec![0.0f32; di * n];
            let mut y = super::scan::selective_scan(&p, &xs, &dt, &bmat, &cmat, &mut h);
            let y_absmax = quant::amax(&y);
            maybe_quant(sites.y_out && sites.layer_on(li), &mut y, sites.bits, 100.0);
            // gate + y-gain
            let gy = &self.g_y[li * di..(li + 1) * di];
            let mut gated = vec![0.0f32; tl * di];
            for ti in 0..tl {
                for ch in 0..di {
                    gated[ti * di + ch] = y[ti * di + ch] * silu(z[ti * di + ch]) * gy[ch];
                }
            }
            let gated_absmax = quant::amax(&gated);
            let mut gated_h_absmax = 0.0f32;
            if sites.gated && sites.layer_on(li) {
                if sites.y_hadamard {
                    // rotate → quantize → rotate back (compute-invariant
                    // analog of the fused-Hadamard deployment path)
                    crate::quant::hadamard::fwht_rows(&mut gated, di);
                    gated_h_absmax = quant::amax(&gated);
                    let s = quant::scale_sym(gated_h_absmax, sites.bits);
                    quant::fake_quant_sym(&mut gated, s, sites.bits);
                    let mut und = Vec::with_capacity(gated.len());
                    for row in gated.chunks_exact(di) {
                        und.extend(crate::quant::hadamard::ifwht(row));
                    }
                    gated = und;
                } else {
                    maybe_quant(true, &mut gated, sites.bits, 100.0);
                }
            }
            if taps.is_some() && gated_h_absmax == 0.0 {
                let mut gh = gated.clone();
                crate::quant::hadamard::fwht_rows(&mut gh, di);
                gated_h_absmax = quant::amax(&gh);
            }
            matmul(&gated, &layer.out_proj, tl, di, d, &mut out);
            for i in 0..resid.len() {
                resid[i] += out[i];
            }
            if let Some(tv) = taps.as_deref_mut() {
                tv.push(LayerTaps {
                    x_ssm_absmax,
                    x_ssm_p99,
                    y_absmax,
                    gated_absmax,
                    gated_h_absmax,
                    conv_in_absmax,
                });
            }
        }
        let fin = self.final_hidden(&resid, tl);
        self.tied_logits(&fin, tl)
    }
}

#[cfg(test)]
mod tests {
    // end-to-end checks live in rust/tests/ (they need artifacts);
    // here only pure-math units.
    use super::*;

    #[test]
    fn silu_softplus_sane() {
        assert!((silu(0.0)).abs() < 1e-7);
        assert!((softplus(0.0) - std::f32::consts::LN_2).abs() < 1e-6);
        assert!(softplus(30.0) - 30.0 < 1e-3);
    }

    #[test]
    fn matmul_identity() {
        let x = vec![1.0, 2.0, 3.0, 4.0];
        let eye = vec![1.0, 0.0, 0.0, 1.0];
        let mut out = vec![0.0; 4];
        matmul(&x, &eye, 2, 2, 2, &mut out);
        assert_eq!(out, x);
    }

    #[test]
    fn rmsnorm_unit_rows() {
        let x = vec![3.0f32, 4.0];
        let w = vec![1.0f32, 1.0];
        let mut out = vec![0.0f32; 2];
        rmsnorm(&x, &w, 2, 0.0, &mut out);
        let ms: f32 = out.iter().map(|v| v * v).sum::<f32>() / 2.0;
        assert!((ms - 1.0).abs() < 1e-5);
    }

    #[test]
    fn take_cols_splits() {
        // 2×4 matrix, take columns [1,3)
        let m = vec![0.0f32, 1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0];
        assert_eq!(take_cols(&m, 2, 4, 1, 3), vec![1.0, 2.0, 5.0, 6.0]);
    }

    #[test]
    fn conv_history_composes_with_full_block() {
        // conv over [a;b] in one call == conv(a) carrying history into conv(b)
        let mut r = crate::util::rng::Pcg32::new(5);
        let (di, w, tl, cut) = (3usize, 4usize, 9usize, 4usize);
        let x: Vec<f32> = (0..tl * di).map(|_| r.normal()).collect();
        let conv_w: Vec<f32> = (0..w * di).map(|_| r.normal()).collect();
        let conv_b: Vec<f32> = (0..di).map(|_| r.normal()).collect();
        let gx = vec![1.0f32; di];
        let mut full = vec![0.0f32; tl * di];
        causal_conv_silu(&x, None, &conv_w, &conv_b, &gx, tl, di, w, &mut full);
        let mut hist = vec![0.0f32; (w - 1) * di];
        let mut p1 = vec![0.0f32; cut * di];
        causal_conv_silu(&x[..cut * di], Some(&mut hist), &conv_w, &conv_b, &gx, cut, di, w, &mut p1);
        let mut p2 = vec![0.0f32; (tl - cut) * di];
        causal_conv_silu(&x[cut * di..], Some(&mut hist), &conv_w, &conv_b, &gx, tl - cut, di, w, &mut p2);
        for (i, (u, v)) in full.iter().zip(p1.iter().chain(p2.iter())).enumerate() {
            assert!((u - v).abs() < 1e-6, "t={} {u} vs {v}", i / di);
        }
        // final history = last (w-1) raw inputs
        for s in 0..w - 1 {
            let src = tl - (w - 1) + s;
            for ch in 0..di {
                assert_eq!(hist[s * di + ch], x[src * di + ch]);
            }
        }
    }

    #[test]
    fn conv_short_chunks_compose() {
        // chunks shorter than the window (tl < W-1) must still compose
        let mut r = crate::util::rng::Pcg32::new(8);
        let (di, w, tl) = (2usize, 4usize, 6usize);
        let x: Vec<f32> = (0..tl * di).map(|_| r.normal()).collect();
        let conv_w: Vec<f32> = (0..w * di).map(|_| r.normal()).collect();
        let conv_b = vec![0.1f32; di];
        let gx = vec![1.0f32; di];
        let mut full = vec![0.0f32; tl * di];
        causal_conv_silu(&x, None, &conv_w, &conv_b, &gx, tl, di, w, &mut full);
        let mut hist = vec![0.0f32; (w - 1) * di];
        let mut got = Vec::new();
        for ti in 0..tl {
            let mut one = vec![0.0f32; di];
            causal_conv_silu(&x[ti * di..(ti + 1) * di], Some(&mut hist), &conv_w, &conv_b, &gx, 1, di, w, &mut one);
            got.extend(one);
        }
        for (u, v) in full.iter().zip(&got) {
            assert!((u - v).abs() < 1e-6);
        }
    }

    #[test]
    fn tier_inferred_from_qtz_shapes() {
        use crate::tensor::{qtz::QtzFile, Tensor};
        use std::collections::BTreeMap;
        let mut tensors: BTreeMap<String, Tensor> = BTreeMap::new();
        let mut put = |name: String, shape: &[usize]| {
            let n: usize = shape.iter().product();
            tensors.insert(name, Tensor::from_f32(shape, &vec![0.0; n]));
        };
        put("embedding.weight".into(), &[16, 8]);
        for li in 0..3 {
            put(format!("layers.{li}.norm.weight"), &[8]);
            put(format!("layers.{li}.conv1d.weight"), &[4, 16]);
            put(format!("layers.{li}.A_log"), &[16, 4]);
            put(format!("layers.{li}.dt_proj.weight"), &[2, 16]);
        }
        let q = QtzFile { names: tensors.keys().cloned().collect(), tensors };
        let t = MambaTier::infer_from_qtz("imported", &q).unwrap();
        assert_eq!(
            (t.d_model, t.n_layer, t.d_state, t.d_conv, t.d_inner, t.dt_rank, t.vocab),
            (8, 3, 4, 4, 16, 2, 16)
        );
        // a bundle missing the embedding must error, not panic
        let empty = QtzFile { names: vec![], tensors: BTreeMap::new() };
        assert!(MambaTier::infer_from_qtz("x", &empty).is_err());
    }

    #[test]
    fn from_qtz_validates_shapes_and_finiteness() {
        use crate::tensor::{qtz::QtzFile, Tensor};
        use std::collections::BTreeMap;
        let tier = MambaTier {
            name: "tiny".into(),
            d_model: 8,
            n_layer: 2,
            d_state: 4,
            d_conv: 4,
            d_inner: 16,
            dt_rank: 2,
            vocab: 16,
        };
        let build = |mutate: &dyn Fn(&mut BTreeMap<String, Tensor>)| -> QtzFile {
            let mut tensors: BTreeMap<String, Tensor> = BTreeMap::new();
            let mut put = |name: String, shape: &[usize]| {
                let n: usize = shape.iter().product();
                tensors.insert(name, Tensor::from_f32(shape, &vec![0.25; n]));
            };
            put("embedding.weight".into(), &[16, 8]);
            put("norm_f.weight".into(), &[8]);
            for li in 0..2 {
                put(format!("layers.{li}.norm.weight"), &[8]);
                put(format!("layers.{li}.in_proj.weight"), &[32, 8]);
                put(format!("layers.{li}.conv1d.weight"), &[4, 16]);
                put(format!("layers.{li}.conv1d.bias"), &[16]);
                put(format!("layers.{li}.x_proj.weight"), &[10, 16]);
                put(format!("layers.{li}.dt_proj.weight"), &[2, 16]);
                put(format!("layers.{li}.dt_proj.bias"), &[16]);
                put(format!("layers.{li}.A_log"), &[16, 4]);
                put(format!("layers.{li}.D"), &[16]);
                put(format!("layers.{li}.out_proj.weight"), &[16, 8]);
            }
            mutate(&mut tensors);
            QtzFile { names: tensors.keys().cloned().collect(), tensors }
        };

        // a complete bundle loads, with absent gains defaulting to ones
        let ok = MambaModel::from_qtz(tier.clone(), &build(&|_| {})).unwrap();
        assert!(ok.g_x.iter().all(|v| *v == 1.0));

        // truncated tensor → typed error naming the tensor, not a panic
        let short = build(&|t| {
            t.insert("layers.1.D".into(), Tensor::from_f32(&[3], &[0.1, 0.2, 0.3]));
        });
        let err = MambaModel::from_qtz(tier.clone(), &short).unwrap_err();
        assert!(err.contains("layers.1.D") && err.contains("expected 16"), "{err}");

        // non-finite weight → typed error with the offending index
        let nan = build(&|t| {
            let mut xs = vec![0.25f32; 16];
            xs[7] = f32::NAN;
            t.insert("layers.0.conv1d.bias".into(), Tensor::from_f32(&[16], &xs));
        });
        let err = MambaModel::from_qtz(tier.clone(), &nan).unwrap_err();
        assert!(err.contains("layers.0.conv1d.bias") && err.contains("non-finite"), "{err}");

        // present-but-wrong-shape gains are a hard error (half-written
        // file), unlike absent gains which fall back to identity
        let bad_gains = build(&|t| {
            t.insert("__gains.g_x".into(), Tensor::from_f32(&[4], &[1.0; 4]));
        });
        let err = MambaModel::from_qtz(tier, &bad_gains).unwrap_err();
        assert!(err.contains("__gains.g_x"), "{err}");
    }

    #[test]
    fn synthetic_model_is_deterministic() {
        let tier = MambaTier {
            name: "syn".into(),
            d_model: 8,
            n_layer: 2,
            d_state: 4,
            d_conv: 4,
            d_inner: 16,
            dt_rank: 2,
            vocab: 16,
        };
        let a = MambaModel::synthetic(tier.clone(), 11);
        let b = MambaModel::synthetic(tier, 11);
        assert_eq!(a.embedding, b.embedding);
        assert_eq!(a.layers[1].out_proj, b.layers[1].out_proj);
        // A must be negative (stable decay)
        assert!(a.layers[0].a.iter().all(|v| *v < 0.0));
    }
}
