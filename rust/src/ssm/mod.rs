//! Pure-rust selective-SSM substrate: the CPU reference simulator and
//! the native (artifact-free) inference backend.
//!
//! The request path can execute AOT-compiled HLO ([`crate::runtime`])
//! or serve natively from this module. It exists because the paper's
//! analyses need a model we can instrument arbitrarily: per-tensor
//! quantization-error propagation (Fig. 2/10), activation
//! distributions (Fig. 3/8/12), the LTI error bound (Thm 4.1 / Fig. 5
//! via [`hippo`]), and property tests of scan invariants that would be
//! awkward through PJRT. It also cross-checks the runtime's outputs
//! bit-for-bit-ish (fp tolerance) in integration tests, loading the
//! same `.qtz` weights.
//!
//! * [`mamba`]  — the fp32 reference model + shared layer math
//! * [`step`]   — stateful decode: [`step::MambaState`] prefill/step
//! * [`qmamba`] — the calibrated W8A8 model (real int8 execution)
//! * [`scan`]   — fp32 and int8 selective scans
//! * [`hippo`]  — LTI/HiPPO error-bound machinery

pub mod hippo;
pub mod mamba;
pub mod qmamba;
pub mod scan;
pub mod step;

pub use mamba::{MambaModel, MambaTier};
pub use qmamba::{
    fused_conv_silu_i8, fused_conv_silu_i8_with, verify_row, QuantConfig, QuantizedMambaModel,
};
pub use scan::{
    selective_scan, selective_scan_into, selective_scan_q, selective_scan_q_into,
    selective_scan_q_into_with, ScanParams,
};
pub use step::{CalibRecord, LayerCalib, MambaState, StepModel, StepScratch, X_CALIB_SAMPLES};
