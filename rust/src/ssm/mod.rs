//! Pure-rust selective-SSM substrate: the CPU reference simulator.
//!
//! The request path executes AOT-compiled HLO ([`crate::runtime`]);
//! this module exists because the paper's analyses need a model we can
//! instrument arbitrarily: per-tensor quantization-error propagation
//! (Fig. 2/10), activation distributions (Fig. 3/8/12), the LTI error
//! bound (Thm 4.1 / Fig. 5 via [`hippo`]), and property tests of scan
//! invariants that would be awkward through PJRT. It also cross-checks
//! the runtime's outputs bit-for-bit-ish (fp tolerance) in integration
//! tests, loading the same `.qtz` weights.

pub mod hippo;
pub mod mamba;
pub mod scan;

pub use mamba::{MambaModel, MambaTier};
pub use scan::{selective_scan, selective_scan_q, ScanParams};
