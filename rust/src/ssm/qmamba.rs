//! `QuantizedMambaModel`: a real W8A8 Mamba built from the fp32
//! reference by calibration — int8 weights, static per-tensor
//! activation scales, integer matmuls ([`crate::quant::qlinear`]) and
//! the int8 selective scan. This is the paper's deployment recipe
//! (§3.3/§4.2/§4.3) executed natively in rust, mirroring
//! `python/compile/model.py::forward_q`:
//!
//! * every projection (in/x/dt/out and the tied head) runs i8×i8→i32
//!   with scales baked at calibration time (Eq. 2);
//! * the SSM input x is clipped at a calibration percentile (§4.2);
//! * out_proj executes in the Hadamard-rotated space: W_out is folded
//!   offline to H·W_out (the 1/d_inner lands in its weight scale), so
//!   the runtime only rotates the activation and quantizes (§3.3);
//! * the conv uses int8 weights with f32 accumulation on exactly
//!   representable dequantized values (the `_conv_live_q` semantics; a
//!   fully fused integer conv kernel is a ROADMAP follow-on);
//! * the recurrence itself stays f32 ([`super::scan::selective_scan_q`]).

use super::mamba::{rmsnorm, silu, softplus, take_cols, MambaModel, MambaTier};
use super::scan::selective_scan_q;
use super::step::{CalibRecord, MambaState, StepModel};
use crate::quant;
use crate::quant::qlinear::QLinear;

/// Quantizer configuration (the paper's "quamba" method point).
#[derive(Debug, Clone)]
pub struct QuantConfig {
    /// percentile clip for the SSM-input scale (§4.2; 100 = abs-max)
    pub x_percentile: f64,
}

impl Default for QuantConfig {
    fn default() -> Self {
        QuantConfig { x_percentile: 99.999 }
    }
}

struct QLayer {
    norm: Vec<f32>,
    in_proj: QLinear, // (d, 2di)
    s_xin: f32,
    /// int8 conv weights, stored dequantized (exactly on-grid)
    conv_w_deq: Vec<f32>, // (W, di)
    conv_b: Vec<f32>,
    s_cin: f32,
    x_proj: QLinear, // (di, r+2n)
    s_x: f32,
    dt_proj: QLinear, // (r, di), bias folded in
    s_dt: f32,
    a_q: Vec<i8>,
    s_a: f32,
    d_q: Vec<i8>,
    s_d: f32,
    s_b: f32,
    s_c: f32,
    out_proj: QLinear, // folded H·W_out (di, d); scale absorbs 1/di
    s_gh: f32,
}

pub struct QuantizedMambaModel {
    pub tier: MambaTier,
    embedding: Vec<f32>, // f32 rows for the residual spine
    norm_f: Vec<f32>,
    head: QLinear, // tied head: embeddingᵀ quantized (d, V)
    s_head_in: f32,
    layers: Vec<QLayer>,
    g_x: Vec<f32>,
    g_y: Vec<f32>,
}

impl QuantizedMambaModel {
    /// Build by calibrating the fp32 model over `calib_tokens` (one
    /// pass is enough for the static per-tensor scales; concatenate
    /// streams for more coverage).
    pub fn from_model(model: &MambaModel, calib_tokens: &[u16], cfg: &QuantConfig) -> Self {
        let rec = model.calibrate(calib_tokens);
        Self::from_calibration(model, &rec, cfg)
    }

    /// Build from an existing calibration record.
    pub fn from_calibration(model: &MambaModel, rec: &CalibRecord, cfg: &QuantConfig) -> Self {
        let t = model.tier.clone();
        let (d, di, n, r) = (t.d_model, t.d_inner, t.d_state, t.dt_rank);
        assert_eq!(rec.layers.len(), t.n_layer, "calibration record layer count");
        let mut layers = Vec::with_capacity(t.n_layer);
        for (layer, lc) in model.layers.iter().zip(&rec.layers) {
            // fold H into out_proj: W' = H·W_out applied per column,
            // i.e. FWHT over the rows of W_outᵀ; 1/di goes into s_w
            let mut wt = vec![0.0f32; d * di]; // (d, di) = W_outᵀ
            for row in 0..di {
                for col in 0..d {
                    wt[col * di + row] = layer.out_proj[row * d + col];
                }
            }
            crate::quant::hadamard::fwht_rows(&mut wt, di);
            let mut w_fold = vec![0.0f32; di * d];
            for col in 0..d {
                for row in 0..di {
                    w_fold[row * d + col] = wt[col * di + row];
                }
            }
            let conv_sw = quant::scale_sym(quant::amax(&layer.conv_w), 8);
            let conv_q = quant::quantize_sym(&layer.conv_w, conv_sw, 8);
            let (a_sw, d_sw) = (
                quant::scale_sym(quant::amax(&layer.a), 8),
                quant::scale_sym(quant::amax(&layer.d), 8),
            );
            layers.push(QLayer {
                norm: layer.norm.clone(),
                in_proj: QLinear::from_f32(&layer.in_proj, d, 2 * di, None),
                s_xin: quant::scale_sym(lc.x_in_amax, 8),
                conv_w_deq: quant::dequantize_sym(&conv_q, conv_sw),
                conv_b: layer.conv_b.clone(),
                s_cin: quant::scale_sym(lc.conv_in_amax, 8),
                x_proj: QLinear::from_f32(&layer.x_proj, di, r + 2 * n, None),
                s_x: quant::scale_sym(
                    quant::percentile_amax(&lc.x_ssm_vals, cfg.x_percentile),
                    8,
                ),
                dt_proj: QLinear::from_f32(&layer.dt_proj, r, di, Some(layer.dt_bias.clone())),
                s_dt: quant::scale_sym(lc.dt_low_amax, 8),
                a_q: quant::quantize_sym(&layer.a, a_sw, 8),
                s_a: a_sw,
                d_q: quant::quantize_sym(&layer.d, d_sw, 8),
                s_d: d_sw,
                s_b: quant::scale_sym(lc.b_amax, 8),
                s_c: quant::scale_sym(lc.c_amax, 8),
                out_proj: QLinear::from_f32(&w_fold, di, d, None).fold_scale(1.0 / di as f32),
                s_gh: quant::scale_sym(lc.gated_h_amax, 8),
            });
        }
        // tied head: quantize embeddingᵀ (d, V)
        let v = t.vocab;
        let mut head_w = vec![0.0f32; d * v];
        for tok in 0..v {
            for j in 0..d {
                head_w[j * v + tok] = model.embedding[tok * d + j];
            }
        }
        QuantizedMambaModel {
            embedding: model.embedding.clone(),
            norm_f: model.norm_f.clone(),
            head: QLinear::from_f32(&head_w, d, v, None),
            s_head_in: quant::scale_sym(rec.head_in_amax, 8),
            layers,
            g_x: model.g_x.clone(),
            g_y: model.g_y.clone(),
            tier: t,
        }
    }

    /// 8-bit weight count = bytes when shipped as int8 (conv/A/D are
    /// held dequantized in RAM for the f32 recurrence but live exactly
    /// on the int8 grid) — the Fig. 1(c)-style memory story for the
    /// native backend.
    pub fn weight_bytes_i8(&self) -> usize {
        let per_layer: usize = self
            .layers
            .iter()
            .map(|l| {
                l.in_proj.weight_bytes()
                    + l.x_proj.weight_bytes()
                    + l.dt_proj.weight_bytes()
                    + l.out_proj.weight_bytes()
                    + l.conv_w_deq.len()
                    + l.a_q.len()
                    + l.d_q.len()
            })
            .sum();
        per_layer + self.head.weight_bytes()
    }
}

impl StepModel for QuantizedMambaModel {
    fn tier(&self) -> &MambaTier {
        &self.tier
    }

    /// Quantized prefill = repeated single-token steps: every scale is
    /// static, so the stepwise path is numerically identical to a
    /// full-sequence quantized forward, and the state composition is
    /// exact by construction.
    fn prefill(&self, tokens: &[u16], state: &mut MambaState) -> Vec<f32> {
        assert_eq!(state.b, 1, "prefill is single-sequence");
        assert!(!tokens.is_empty(), "prefill needs at least one token");
        state.reset();
        let v = self.tier.vocab;
        let mut logits = Vec::with_capacity(tokens.len() * v);
        for &tok in tokens {
            logits.extend(self.step(&[tok], state));
        }
        debug_assert_eq!(logits.len(), tokens.len() * v);
        logits
    }

    /// The W8A8 batched decode step — the native serving hot path.
    fn step(&self, tokens: &[u16], state: &mut MambaState) -> Vec<f32> {
        let t = &self.tier;
        let (d, di, n, r, w) = (t.d_model, t.d_inner, t.d_state, t.dt_rank, t.d_conv);
        let b = state.b;
        assert_eq!(tokens.len(), b, "one input token per state lane");
        let mut resid = vec![0.0f32; b * d];
        for (bi, &tok) in tokens.iter().enumerate() {
            resid[bi * d..(bi + 1) * d]
                .copy_from_slice(&self.embedding[tok as usize * d..(tok as usize + 1) * d]);
        }
        let mut x_in = vec![0.0f32; b * d];
        let mut xz = vec![0.0f32; b * 2 * di];
        let mut bcdt = vec![0.0f32; b * (r + 2 * n)];
        let mut out = vec![0.0f32; b * d];
        let hw = w - 1;
        for (li, ql) in self.layers.iter().enumerate() {
            // fused norm + requant into the int8 in_proj
            rmsnorm(&resid, &ql.norm, d, 1e-5, &mut x_in);
            ql.in_proj.forward(&x_in, ql.s_xin, b, &mut xz);
            let x = take_cols(&xz, b, 2 * di, 0, di);
            let z = take_cols(&xz, b, 2 * di, di, 2 * di);
            // int8-semantics conv: requant the input, accumulate in f32
            // over exactly-representable dequantized values
            let x_deq = {
                let q = quant::quantize_sym(&x, ql.s_cin, 8);
                quant::dequantize_sym(&q, ql.s_cin)
            };
            let gx = &self.g_x[li * di..(li + 1) * di];
            let mut act = vec![0.0f32; b * di];
            for bi in 0..b {
                let hist = state.conv_lane(li, bi);
                for ch in 0..di {
                    let mut acc = ql.conv_b[ch];
                    for j in 0..hw {
                        acc += hist[j * di + ch] * ql.conv_w_deq[j * di + ch];
                    }
                    acc += x_deq[bi * di + ch] * ql.conv_w_deq[hw * di + ch];
                    act[bi * di + ch] = silu(acc) * gx[ch];
                }
                // slide the window with the dequantized input (what the
                // int8 conv would see next step)
                if hw > 0 {
                    hist.copy_within(di.., 0);
                    hist[(hw - 1) * di..].copy_from_slice(&x_deq[bi * di..(bi + 1) * di]);
                }
            }
            // percentile-clipped static x-scale; the scan reuses the codes
            let x8s = quant::quantize_sym(&act, ql.s_x, 8);
            ql.x_proj.forward_q(&x8s, ql.s_x, b, &mut bcdt);
            let dt_low = take_cols(&bcdt, b, r + 2 * n, 0, r);
            let bmat = take_cols(&bcdt, b, r + 2 * n, r, r + n);
            let cmat = take_cols(&bcdt, b, r + 2 * n, r + n, r + 2 * n);
            let mut dt = vec![0.0f32; b * di];
            ql.dt_proj.forward(&dt_low, ql.s_dt, b, &mut dt);
            for v in dt.iter_mut() {
                *v = softplus(*v);
            }
            let b8 = quant::quantize_sym(&bmat, ql.s_b, 8);
            let c8 = quant::quantize_sym(&cmat, ql.s_c, 8);
            let gy = &self.g_y[li * di..(li + 1) * di];
            let mut gated = vec![0.0f32; b * di];
            for bi in 0..b {
                let y = selective_scan_q(
                    di,
                    n,
                    &x8s[bi * di..(bi + 1) * di],
                    ql.s_x,
                    &dt[bi * di..(bi + 1) * di],
                    &ql.a_q,
                    ql.s_a,
                    &b8[bi * n..(bi + 1) * n],
                    ql.s_b,
                    &c8[bi * n..(bi + 1) * n],
                    ql.s_c,
                    &ql.d_q,
                    ql.s_d,
                    state.ssm_lane(li, bi),
                );
                for ch in 0..di {
                    gated[bi * di + ch] = y[ch] * silu(z[bi * di + ch]) * gy[ch];
                }
            }
            // out_proj in the rotated space: rotate, quantize, int8 matmul
            // against the folded H·W_out (its scale carries the 1/di)
            crate::quant::hadamard::fwht_rows(&mut gated, di);
            ql.out_proj.forward(&gated, ql.s_gh, b, &mut out);
            for i in 0..resid.len() {
                resid[i] += out[i];
            }
        }
        let mut fin = vec![0.0f32; b * d];
        rmsnorm(&resid, &self.norm_f, d, 1e-5, &mut fin);
        let mut logits = vec![0.0f32; b * self.tier.vocab];
        self.head.forward(&fin, self.s_head_in, b, &mut logits);
        logits
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tier() -> MambaTier {
        MambaTier {
            name: "tiny".into(),
            d_model: 16,
            n_layer: 2,
            d_state: 4,
            d_conv: 4,
            d_inner: 32,
            dt_rank: 4,
            vocab: 32,
        }
    }

    #[test]
    fn quantized_logits_close_to_fp32() {
        let t = tier();
        let model = MambaModel::synthetic(t.clone(), 7);
        let mut r = crate::util::rng::Pcg32::new(0xCAFE);
        let calib: Vec<u16> = (0..256).map(|_| r.below(t.vocab as u32) as u16).collect();
        let qm = QuantizedMambaModel::from_model(&model, &calib, &QuantConfig::default());
        let prompt: Vec<u16> = (0..12).map(|_| r.below(t.vocab as u32) as u16).collect();
        let lf = model.forward(&prompt, &crate::ssm::mamba::QuantSites::none(), None);
        let mut st = MambaState::new(&t, 1);
        let lq = qm.prefill(&prompt, &mut st);
        assert_eq!(lf.len(), lq.len());
        let amax = lf.iter().fold(0.0f32, |m, v| m.max(v.abs()));
        let err = lf.iter().zip(&lq).fold(0.0f32, |m, (a, b)| m.max((a - b).abs()));
        // W8A8 with static scales: a few percent of the logit range
        assert!(err < 0.06 * amax, "W8A8 err {err} vs logit amax {amax}");
        assert!(err > 0.0, "suspiciously exact — quantization not applied?");
    }

    #[test]
    fn hadamard_fold_matches_unrotated_projection() {
        // without quantization the fold is compute-invariant:
        // (1/di)·(H g)·(H W_out) == g·W_out. Verify on the dequantized
        // folded weight to isolate the algebra from int8 rounding.
        let t = tier();
        let model = MambaModel::synthetic(t.clone(), 3);
        let (d, di) = (t.d_model, t.d_inner);
        let layer = &model.layers[0];
        let mut r = crate::util::rng::Pcg32::new(2);
        let g: Vec<f32> = (0..di).map(|_| r.normal()).collect();
        // reference: g @ W_out
        let mut want = vec![0.0f32; d];
        for (ch, gv) in g.iter().enumerate() {
            for j in 0..d {
                want[j] += gv * layer.out_proj[ch * d + j];
            }
        }
        // folded: (1/di) · fwht(g) @ (H·W_out)
        let mut wt = vec![0.0f32; d * di];
        for row in 0..di {
            for col in 0..d {
                wt[col * di + row] = layer.out_proj[row * d + col];
            }
        }
        crate::quant::hadamard::fwht_rows(&mut wt, di);
        let gh = crate::quant::hadamard::fwht(&g);
        let mut got = vec![0.0f32; d];
        for j in 0..d {
            let wcol = &wt[j * di..(j + 1) * di];
            got[j] = gh.iter().zip(wcol).map(|(a, b)| a * b).sum::<f32>() / di as f32;
        }
        for (a, b) in want.iter().zip(&got) {
            assert!((a - b).abs() < 1e-3, "{a} vs {b}");
        }
    }

    #[test]
    fn int8_weights_are_quarter_size() {
        let t = tier();
        let model = MambaModel::synthetic(t.clone(), 1);
        let qm = QuantizedMambaModel::from_model(&model, &[1, 2, 3, 4, 5, 6, 7, 8], &QuantConfig::default());
        // f32 projection weights for the same tier
        let (d, di, n, r) = (t.d_model, t.d_inner, t.d_state, t.dt_rank);
        let f32_proj_bytes = 4
            * t.n_layer
            * (d * 2 * di + di * (r + 2 * n) + r * di + di * d + t.d_conv * di + di * n + di)
            + 4 * d * t.vocab;
        let i8_bytes = qm.weight_bytes_i8();
        assert!(
            i8_bytes * 3 < f32_proj_bytes,
            "int8 {i8_bytes} should be ~4x below f32 {f32_proj_bytes}"
        );
    }
}
