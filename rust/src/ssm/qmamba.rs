//! `QuantizedMambaModel`: a real W8A8 Mamba built from the fp32
//! reference by calibration — int8 weights, static per-tensor
//! activation scales, blocked integer matmuls
//! ([`crate::quant::qlinear`]), a fused integer depthwise conv, and
//! the int8 selective scan. This is the paper's deployment recipe
//! (§3.3/§4.2/§4.3) executed natively in rust, mirroring
//! `python/compile/model.py::forward_q`:
//!
//! * every projection (in/x/dt/out and the tied head) runs i8×i8→i32
//!   through the cache-blocked packed-weight kernel with scales baked
//!   at calibration time (Eq. 2);
//! * the SSM input x is clipped at a calibration percentile (§4.2);
//! * out_proj executes in the Hadamard-rotated space: W_out is folded
//!   offline to H·W_out (the 1/d_inner lands in its weight scale), so
//!   the runtime only rotates the activation and quantizes (§3.3);
//! * the depthwise conv is **fully fused integer**: the window lives
//!   as i8 codes in the state ([`MambaState::new_quantized`]), the
//!   accumulation is i32, and one folded `s_cin·s_w` dequant lands at
//!   the end — completing the §4.3 end-to-end integer pipeline and
//!   shrinking per-request conv state to 1 byte/entry;
//! * the recurrence itself stays f32 ([`super::scan::selective_scan_q`]).
//!
//! All int8 arithmetic dispatches through the
//! [`crate::quant::Kernels`] backend carried in the caller's
//! [`StepScratch`] (`scratch.kernels`): the blocked GEMMs, the fused
//! conv's widening MACs, and the scan's code dequantization run
//! explicit AVX2/NEON or the scalar fallback — bit-identically, so a
//! backend switch never changes a sampled token.
//!
//! `step_into` executes entirely out of the caller's [`StepScratch`]:
//! **zero heap allocations** per call after warmup (asserted in
//! `rust/tests/zero_alloc.rs`) — for power-of-two *and* Paley-base
//! `d_inner` (12·2^k / 20·2^k), since each layer caches its
//! [`FwhtPlan`] (base matrix built once at calibration).
//! `prefill_into` runs the whole prompt
//! as (T×K) batched int8 GEMMs; `prefill_batch_into` (the unified
//! chunked-prefill scheduler's workhorse) generalizes that to
//! (B·T_max×K) GEMMs over several in-flight prompts at once, each
//! lane's conv window / scan state advancing independently. Static
//! scales make every variant bit-identical to the stepwise path
//! ([`QuantizedMambaModel::prefill_stepwise`], kept as the test
//! oracle) — chunking and batching move latency, never bits.

use super::mamba::{rmsnorm, silu, softplus, take_cols_into, MambaModel, MambaTier};
use super::scan::selective_scan_q_into_with;
use super::step::{
    par_lane_chunks, rf32, zero_pad_rows, CalibRecord, MambaState, StepModel, StepScratch,
};
use crate::quant;
use crate::quant::hadamard::FwhtPlan;
use crate::quant::qlinear::{QLinear, QLinearI4};
use crate::quant::Kernels;

/// Quantizer configuration (the paper's "quamba" method point).
#[derive(Debug, Clone)]
pub struct QuantConfig {
    /// percentile clip for the SSM-input scale (§4.2; 100 = abs-max)
    pub x_percentile: f64,
    /// projection/head weight width: 8 (per-tensor int8, the paper's
    /// W8A8 recipe) or 4 (packed-nibble W4A8 with per-group scales —
    /// activations stay int8 either way, §4.2's clipping is tuned for
    /// 8-bit activation grids)
    pub weight_bits: u8,
}

impl Default for QuantConfig {
    fn default() -> Self {
        QuantConfig { x_percentile: 99.999, weight_bits: 8 }
    }
}

/// A projection at the configured weight width: per-tensor int8
/// ([`QLinear`]) or packed-nibble int4 with per-group scales
/// ([`QLinearI4`]). Both arms expose the same `forward*_into` shape —
/// quantized-i8 activations in, f32 out, caller-owned scratch — so the
/// step/prefill bodies are width-agnostic; the i4 arm simply never
/// touches the i32 `acc` vector (its group accumulators are stack
/// tiles).
enum QProj {
    I8(QLinear),
    I4(QLinearI4),
}

impl QProj {
    fn from_f32(w: &[f32], k: usize, n: usize, bias: Option<Vec<f32>>, bits: u8) -> QProj {
        match bits {
            8 => QProj::I8(QLinear::from_f32(w, k, n, bias)),
            4 => QProj::I4(QLinearI4::from_f32(w, k, n, bias)),
            _ => panic!("unsupported weight_bits {bits}: native tiers are 8 (int8) or 4 (nibble)"),
        }
    }

    fn fold_scale(self, f: f32) -> QProj {
        match self {
            QProj::I8(q) => QProj::I8(q.fold_scale(f)),
            QProj::I4(q) => QProj::I4(q.fold_scale(f)),
        }
    }

    /// Logical packed weight bytes at the configured width (k·n for
    /// int8, ⌈k·n/2⌉ for the nibble tier; scale tables excluded).
    fn weight_bytes(&self) -> usize {
        match self {
            QProj::I8(q) => q.weight_bytes(),
            QProj::I4(q) => q.weight_bytes(),
        }
    }

    fn forward_q_into(
        &self,
        kers: Kernels,
        x_q: &[i8],
        s_x: f32,
        m: usize,
        acc: &mut Vec<i32>,
        out: &mut [f32],
    ) {
        match self {
            QProj::I8(q) => q.forward_q_into(kers, x_q, s_x, m, acc, out),
            QProj::I4(q) => q.forward_q_into(kers, x_q, s_x, m, out),
        }
    }

    #[allow(clippy::too_many_arguments)]
    fn forward_into(
        &self,
        kers: Kernels,
        x: &[f32],
        s_x: f32,
        m: usize,
        x_q: &mut Vec<i8>,
        acc: &mut Vec<i32>,
        out: &mut [f32],
    ) {
        match self {
            QProj::I8(q) => q.forward_into(kers, x, s_x, m, x_q, acc, out),
            QProj::I4(q) => q.forward_into(kers, x, s_x, m, x_q, out),
        }
    }
}

struct QLayer {
    norm: Vec<f32>,
    in_proj: QProj, // (d, 2di)
    s_xin: f32,
    /// int8 depthwise conv weights (W, di) — integer-domain execution
    conv_w_q: Vec<i8>,
    conv_b: Vec<f32>,
    /// conv input scale (window codes are at this scale)
    s_cin: f32,
    /// folded dequant for the i32 conv accumulator: s_cin · s_convw
    s_conv: f32,
    x_proj: QProj, // (di, r+2n)
    s_x: f32,
    dt_proj: QProj, // (r, di), bias folded in
    s_dt: f32,
    a_q: Vec<i8>,
    s_a: f32,
    d_q: Vec<i8>,
    s_d: f32,
    s_b: f32,
    s_c: f32,
    out_proj: QProj, // folded H·W_out (di, d); scale absorbs 1/di
    s_gh: f32,
    /// cached H_{d_inner} transform: base matrix built once, so the
    /// rotated out_proj stays allocation-free for Paley-base d_inner
    /// (12·2^k / 20·2^k), not just powers of two
    fwht: FwhtPlan,
}

pub struct QuantizedMambaModel {
    pub tier: MambaTier,
    /// projection/head weight width this model was built at (8 or 4)
    pub weight_bits: u8,
    embedding: Vec<f32>, // f32 rows for the residual spine
    norm_f: Vec<f32>,
    head: QProj, // tied head: embeddingᵀ quantized (d, V)
    s_head_in: f32,
    layers: Vec<QLayer>,
    g_x: Vec<f32>,
    g_y: Vec<f32>,
}

/// Channel-chunk width of the fused conv's integer accumulator: each
/// (chunk × tap) sweep runs through [`Kernels::mac_i8`] with the i32
/// accumulator on the stack, so the conv is SIMD-dispatched *and*
/// allocation-free for any `d_inner`.
const CONV_CHUNK: usize = 128;

/// Fused integer depthwise causal conv + SiLU + per-channel gain on
/// the auto-selected kernel backend. See [`fused_conv_silu_i8_with`].
#[allow(clippy::too_many_arguments)]
pub fn fused_conv_silu_i8(
    x_q: &[i8],
    hist: &mut [i8],
    w_q: &[i8],
    bias: &[f32],
    gx: &[f32],
    s: f32,
    tl: usize,
    di: usize,
    w: usize,
    out: &mut [f32],
) {
    fused_conv_silu_i8_with(Kernels::auto(), x_q, hist, w_q, bias, gx, s, tl, di, w, out)
}

/// Fused integer depthwise causal conv + SiLU + per-channel gain over
/// a (tl × di) time-major block of int8 *codes*: i8 window × i8
/// weights, i32 accumulate, one folded `s = s_cin·s_w` dequant (+ f32
/// bias) at the end. Each conv tap is an element-wise widening MAC
/// across a channel chunk ([`Kernels::mac_i8`]) — exact integers, so
/// every backend is bit-identical. `hist` is the carried (W−1, di)
/// window of input codes (oldest row first), advanced in place —
/// chunked calls compose **bit-exactly** with one full call because
/// the accumulator is integer. Parity with the dequantized-f32 conv
/// is property-tested in `rust/tests/kernel_parity.rs`.
#[allow(clippy::too_many_arguments)]
pub fn fused_conv_silu_i8_with(
    kers: Kernels,
    x_q: &[i8],
    hist: &mut [i8],
    w_q: &[i8],
    bias: &[f32],
    gx: &[f32],
    s: f32,
    tl: usize,
    di: usize,
    w: usize,
    out: &mut [f32],
) {
    assert_eq!(x_q.len(), tl * di);
    assert_eq!(out.len(), tl * di);
    assert_eq!(w_q.len(), w * di);
    assert_eq!(hist.len(), (w - 1) * di);
    // accumulator-overflow guard: each output element sums one i8·i8
    // product per tap into the same i32 lane, so the tap count plays
    // the GEMM's K role (see the const proof in quant::kernels)
    debug_assert!(
        w <= quant::MAX_SAFE_K,
        "conv taps w = {w} exceed MAX_SAFE_K = {}: a worst-case per-channel \
         tap sum overflows the i32 accumulator",
        quant::MAX_SAFE_K
    );
    let hw = w - 1;
    let mut acc = [0i32; CONV_CHUNK];
    for ti in 0..tl {
        let mut c0 = 0;
        while c0 < di {
            let cl = CONV_CHUNK.min(di - c0);
            let a = &mut acc[..cl];
            a.fill(0);
            for j in 0..w {
                let src = ti as isize - hw as isize + j as isize;
                let row = if src >= 0 {
                    let r0 = src as usize * di;
                    &x_q[r0 + c0..r0 + c0 + cl]
                } else {
                    let r0 = (src + hw as isize) as usize * di;
                    &hist[r0 + c0..r0 + c0 + cl]
                };
                kers.mac_i8(row, &w_q[j * di + c0..j * di + c0 + cl], a);
            }
            for (ci, &av) in a.iter().enumerate() {
                let ch = c0 + ci;
                out[ti * di + ch] = silu(quant::dq_i32(av, s) + bias[ch]) * gx[ch];
            }
            c0 += cl;
        }
    }
    // slide the window: new history = last (w−1) rows of [hist ; x_q]
    for row in 0..hw {
        let src_row = tl + row; // index into the (hw + tl)-row concat
        if src_row < hw {
            hist.copy_within(src_row * di..(src_row + 1) * di, row * di);
        } else {
            let xr = src_row - hw;
            hist[row * di..(row + 1) * di].copy_from_slice(&x_q[xr * di..(xr + 1) * di]);
        }
    }
}

/// Index one logits row out of a [`StepModel::prefill_batch_into`]
/// output (ISSUE 10's speculative verify path reads draft/verify rows
/// through this, so the lane-major `(bi·t_max + t)·vocab` layout is
/// spelled in exactly one place).
///
/// `bi` is the lane, `t_max` the padded time grid (the longest chunk
/// in the batch), `t` the 0-based row within lane `bi`'s real chunk,
/// `vocab` the row width. Row `t` holds the next-token distribution
/// after the lane has consumed `chunk[..=t]` — verification walks rows
/// `c-1 ..= c-1+k` for a chunk of `c` catch-up tokens plus `k` drafts.
pub fn verify_row(logits: &[f32], bi: usize, t_max: usize, t: usize, vocab: usize) -> &[f32] {
    debug_assert!(t < t_max, "row {t} outside the padded grid {t_max}");
    let off = (bi * t_max + t) * vocab;
    &logits[off..off + vocab]
}

impl QuantizedMambaModel {
    /// Build by calibrating the fp32 model over `calib_tokens` (one
    /// pass is enough for the static per-tensor scales; concatenate
    /// streams for more coverage).
    pub fn from_model(model: &MambaModel, calib_tokens: &[u16], cfg: &QuantConfig) -> Self {
        let rec = model.calibrate(calib_tokens);
        Self::from_calibration(model, &rec, cfg)
    }

    /// Build from an existing calibration record.
    pub fn from_calibration(model: &MambaModel, rec: &CalibRecord, cfg: &QuantConfig) -> Self {
        let t = model.tier.clone();
        let (d, di, n, r) = (t.d_model, t.d_inner, t.d_state, t.dt_rank);
        assert_eq!(rec.layers.len(), t.n_layer, "calibration record layer count");
        let bits = cfg.weight_bits;
        let mut layers = Vec::with_capacity(t.n_layer);
        // one prepared H_{d_inner} per model, cloned into each layer:
        // the Paley base matrix (m ∈ {12, 20}) is built once here and
        // never again on the hot path
        let fwht = FwhtPlan::new(di);
        for (layer, lc) in model.layers.iter().zip(&rec.layers) {
            // fold H into out_proj: W' = H·W_out applied per column,
            // i.e. FWHT over the rows of W_outᵀ; 1/di goes into s_w
            let mut wt = vec![0.0f32; d * di]; // (d, di) = W_outᵀ
            for row in 0..di {
                for col in 0..d {
                    wt[col * di + row] = layer.out_proj[row * d + col];
                }
            }
            fwht.apply_rows(&mut wt);
            let mut w_fold = vec![0.0f32; di * d];
            for col in 0..d {
                for row in 0..di {
                    w_fold[row * d + col] = wt[col * di + row];
                }
            }
            let conv_sw = quant::scale_sym(quant::amax(&layer.conv_w), 8);
            let conv_w_q = quant::quantize_sym(&layer.conv_w, conv_sw, 8);
            let s_cin = quant::scale_sym(lc.conv_in_amax, 8);
            let (a_sw, d_sw) = (
                quant::scale_sym(quant::amax(&layer.a), 8),
                quant::scale_sym(quant::amax(&layer.d), 8),
            );
            layers.push(QLayer {
                norm: layer.norm.clone(),
                in_proj: QProj::from_f32(&layer.in_proj, d, 2 * di, None, bits),
                s_xin: quant::scale_sym(lc.x_in_amax, 8),
                conv_w_q,
                conv_b: layer.conv_b.clone(),
                s_cin,
                s_conv: s_cin * conv_sw,
                x_proj: QProj::from_f32(&layer.x_proj, di, r + 2 * n, None, bits),
                s_x: quant::scale_sym(
                    quant::percentile_amax(lc.x_ssm.values(), cfg.x_percentile),
                    8,
                ),
                dt_proj: QProj::from_f32(&layer.dt_proj, r, di, Some(layer.dt_bias.clone()), bits),
                s_dt: quant::scale_sym(lc.dt_low_amax, 8),
                a_q: quant::quantize_sym(&layer.a, a_sw, 8),
                s_a: a_sw,
                d_q: quant::quantize_sym(&layer.d, d_sw, 8),
                s_d: d_sw,
                s_b: quant::scale_sym(lc.b_amax, 8),
                s_c: quant::scale_sym(lc.c_amax, 8),
                out_proj: QProj::from_f32(&w_fold, di, d, None, bits).fold_scale(1.0 / di as f32),
                s_gh: quant::scale_sym(lc.gated_h_amax, 8),
                fwht: fwht.clone(),
            });
        }
        // tied head: quantize embeddingᵀ (d, V)
        let v = t.vocab;
        let mut head_w = vec![0.0f32; d * v];
        for tok in 0..v {
            for j in 0..d {
                head_w[j * v + tok] = model.embedding[tok * d + j];
            }
        }
        QuantizedMambaModel {
            embedding: model.embedding.clone(),
            norm_f: model.norm_f.clone(),
            head: QProj::from_f32(&head_w, d, v, None, bits),
            s_head_in: quant::scale_sym(rec.head_in_amax, 8),
            layers,
            g_x: model.g_x.clone(),
            g_y: model.g_y.clone(),
            tier: t,
            weight_bits: bits,
        }
    }

    /// Weight bytes at the configured width: GEMM weights at
    /// `weight_bits` (int8, or ⌈k·n/2⌉ packed nibbles) plus the int8
    /// conv/A/D codes (those stay 8-bit at every tier) — the
    /// Fig. 1(c)-style memory story for the native backend.
    pub fn weight_bytes_i8(&self) -> usize {
        let per_layer: usize = self
            .layers
            .iter()
            .map(|l| {
                l.in_proj.weight_bytes()
                    + l.x_proj.weight_bytes()
                    + l.dt_proj.weight_bytes()
                    + l.out_proj.weight_bytes()
                    + l.conv_w_q.len()
                    + l.a_q.len()
                    + l.d_q.len()
            })
            .sum();
        per_layer + self.head.weight_bytes()
    }

    /// Packed bytes of the GEMM weights alone (projections + head,
    /// excluding the always-int8 conv/A/D codes): the quantity the
    /// `--bits 4` tier halves exactly, asserted in
    /// `benches/perf_native_decode.rs`.
    pub fn gemm_weight_bytes(&self) -> usize {
        let per_layer: usize = self
            .layers
            .iter()
            .map(|l| {
                l.in_proj.weight_bytes()
                    + l.x_proj.weight_bytes()
                    + l.dt_proj.weight_bytes()
                    + l.out_proj.weight_bytes()
            })
            .sum();
        per_layer + self.head.weight_bytes()
    }

    /// The pre-PR-2 prefill: repeated single-token steps. Static
    /// scales make the full-sequence [`StepModel::prefill_into`]
    /// numerically identical; this stays as the bit-exactness oracle
    /// (and the "before" side of the prefill speedup bench).
    pub fn prefill_stepwise(&self, tokens: &[u16], state: &mut MambaState) -> Vec<f32> {
        assert_eq!(state.b, 1, "prefill is single-sequence");
        assert!(!tokens.is_empty(), "prefill needs at least one token");
        state.ensure_quantized_conv();
        state.reset();
        let v = self.tier.vocab;
        let mut scratch = StepScratch::new(1);
        let mut step_logits = Vec::new();
        let mut logits = Vec::with_capacity(tokens.len() * v);
        for &tok in tokens {
            self.step_into(&[tok], state, &mut scratch, &mut step_logits);
            logits.extend_from_slice(&step_logits);
        }
        debug_assert_eq!(logits.len(), tokens.len() * v);
        logits
    }

    /// The shared (B, T) prefill body: advance `state.b` independent
    /// in-flight prompts by one chunk each, lane-major ragged rows
    /// padded to `t_max` (pad rows are zeroed before each GEMM so
    /// every buffer stays deterministic; their outputs are discarded).
    /// With B = 1 this **is** the old single-sequence prefill segment
    /// — `prefill_into` / `prefill_resume_into` route through here, so
    /// the batched and per-request paths cannot drift. Static scales +
    /// exact integer accumulation + per-row f32 epilogues make both
    /// chunk composition *and* lane batching bit-exact — the same
    /// property that makes [`Self::prefill_stepwise`] an exact oracle.
    fn prefill_batch_impl(
        &self,
        chunks: &[&[u16]],
        state: &mut MambaState,
        scratch: &mut StepScratch,
        logits: &mut Vec<f32>,
    ) {
        let t = &self.tier;
        let (d, di, n, r, w) = (t.d_model, t.d_inner, t.d_state, t.dt_rank, t.d_conv);
        let b = state.b;
        assert_eq!(chunks.len(), b, "one chunk per state lane");
        assert!(chunks.iter().all(|c| !c.is_empty()), "prefill chunks must be non-empty");
        assert!(
            state.is_quantized_conv(),
            "W8A8 prefill needs an i8 conv-window state"
        );
        let t_max = chunks.iter().map(|c| c.len()).max().unwrap();
        let rows = b * t_max;
        scratch.prep(rows, t);
        let kers = scratch.kernels;
        let StepScratch {
            resid,
            x_in,
            xz,
            x,
            z,
            act,
            bcdt,
            dt_low,
            bmat,
            cmat,
            dt,
            gated,
            out,
            fin,
            q_xin,
            q_conv,
            q_x,
            q_dt,
            q_b,
            q_c,
            q_gh,
            q_head,
            acc,
            ..
        } = scratch;
        for (bi, chunk) in chunks.iter().enumerate() {
            for ti in 0..t_max {
                let tok = if ti < chunk.len() {
                    chunk[ti] as usize
                } else {
                    crate::data::BOS as usize
                };
                resid[(bi * t_max + ti) * d..(bi * t_max + ti + 1) * d]
                    .copy_from_slice(&self.embedding[tok * d..(tok + 1) * d]);
            }
        }
        for (li, ql) in self.layers.iter().enumerate() {
            rmsnorm(resid, &ql.norm, d, 1e-5, x_in);
            ql.in_proj.forward_into(kers, x_in, ql.s_xin, rows, q_xin, acc, xz);
            take_cols_into(xz, rows, 2 * di, 0, di, x);
            take_cols_into(xz, rows, 2 * di, di, 2 * di, z);
            // requant the conv input to the static conv-in scale; the
            // window codes carry the same scale
            quant::quantize_sym_into(x, ql.s_cin, 8, q_conv);
            let gx = &self.g_x[li * di..(li + 1) * di];
            // conv + scan are the sequential-per-lane sections: each
            // lane sweeps its own real rows with its own carried window
            for (bi, chunk) in chunks.iter().enumerate() {
                let tl = chunk.len();
                let off = bi * t_max * di;
                fused_conv_silu_i8_with(
                    kers,
                    &q_conv[off..off + tl * di],
                    state.conv_lane_q(li, bi),
                    &ql.conv_w_q,
                    &ql.conv_b,
                    gx,
                    ql.s_conv,
                    tl,
                    di,
                    w,
                    &mut act[off..off + tl * di],
                );
            }
            zero_pad_rows(act, chunks, t_max, di);
            // percentile-clipped static x-scale; the scan reuses the codes
            quant::quantize_sym_into(act, ql.s_x, 8, q_x);
            ql.x_proj.forward_q_into(kers, q_x, ql.s_x, rows, acc, bcdt);
            take_cols_into(bcdt, rows, r + 2 * n, 0, r, dt_low);
            take_cols_into(bcdt, rows, r + 2 * n, r, r + n, bmat);
            take_cols_into(bcdt, rows, r + 2 * n, r + n, r + 2 * n, cmat);
            ql.dt_proj.forward_into(kers, dt_low, ql.s_dt, rows, q_dt, acc, dt);
            for v in dt.iter_mut() {
                *v = softplus(*v);
            }
            quant::quantize_sym_into(bmat, ql.s_b, 8, q_b);
            quant::quantize_sym_into(cmat, ql.s_c, 8, q_c);
            let gy = &self.g_y[li * di..(li + 1) * di];
            for (bi, chunk) in chunks.iter().enumerate() {
                let tl = chunk.len();
                let off = bi * t_max * di;
                let boff = bi * t_max * n;
                selective_scan_q_into_with(
                    kers,
                    di,
                    n,
                    &q_x[off..off + tl * di],
                    ql.s_x,
                    &dt[off..off + tl * di],
                    &ql.a_q,
                    ql.s_a,
                    &q_b[boff..boff + tl * n],
                    ql.s_b,
                    &q_c[boff..boff + tl * n],
                    ql.s_c,
                    &ql.d_q,
                    ql.s_d,
                    state.ssm_lane(li, bi),
                    &mut gated[off..off + tl * di],
                );
                for (ti, row) in gated[off..off + tl * di].chunks_exact_mut(di).enumerate() {
                    let zrow = &z[off + ti * di..off + (ti + 1) * di];
                    for ch in 0..di {
                        row[ch] = row[ch] * silu(zrow[ch]) * gy[ch];
                    }
                }
            }
            zero_pad_rows(gated, chunks, t_max, di);
            // out_proj in the rotated space: rotate, quantize, int8
            // matmul against the folded H·W_out (scale carries 1/di)
            ql.fwht.apply_rows(gated);
            ql.out_proj.forward_into(kers, gated, ql.s_gh, rows, q_gh, acc, out);
            for i in 0..resid.len() {
                resid[i] += out[i];
            }
        }
        rmsnorm(resid, &self.norm_f, d, 1e-5, fin);
        rf32(logits, rows * self.tier.vocab);
        self.head.forward_into(kers, fin, self.s_head_in, rows, q_head, acc, logits);
    }
}

impl StepModel for QuantizedMambaModel {
    fn tier(&self) -> &MambaTier {
        &self.tier
    }

    fn quantized_conv_state(&self) -> bool {
        true
    }

    /// Full-sequence quantized prefill: the whole prompt runs as
    /// (T×K) batched int8 GEMMs, one fused-conv sweep and one scan per
    /// layer. Every scale is static, integer accumulation is exact,
    /// and the f32 epilogues are per-element — so logits *and* final
    /// state are bit-identical to [`Self::prefill_stepwise`]
    /// (asserted in tests) at a fraction of the dispatch cost.
    fn prefill_into(
        &self,
        tokens: &[u16],
        state: &mut MambaState,
        scratch: &mut StepScratch,
        logits: &mut Vec<f32>,
    ) {
        assert_eq!(state.b, 1, "prefill is single-sequence; prefill_batch_into handles B > 1");
        state.ensure_quantized_conv();
        state.reset();
        self.prefill_batch_impl(&[tokens], state, scratch, logits);
    }

    /// Warm-path prefill continuation: `state` already holds a prefix's
    /// conv codes + h-state (e.g. restored from the prefix cache) and
    /// `tokens` is the remaining suffix. Bit-exact composition with
    /// `prefill_into` — both run the same segment body; static scales
    /// plus exact integer accumulation make cutting invisible.
    fn prefill_resume_into(
        &self,
        tokens: &[u16],
        state: &mut MambaState,
        scratch: &mut StepScratch,
        logits: &mut Vec<f32>,
    ) {
        assert_eq!(state.b, 1, "resume is single-sequence; prefill_batch_into handles B > 1");
        assert!(
            state.is_quantized_conv(),
            "resume needs a quantized-conv state (produced by a prior W8A8 prefill)"
        );
        self.prefill_batch_impl(&[tokens], state, scratch, logits);
    }

    /// The unified scheduler's (B, T) batched chunk prefill: every
    /// projection runs as one (B·T_max × K) blocked int8 GEMM across
    /// all lanes, the conv/scan sweep each lane's carried state over
    /// its real rows. Bit-identical per lane to the per-request
    /// `prefill_into` oracle (see [`Self::prefill_batch_impl`]).
    fn prefill_batch_into(
        &self,
        chunks: &[&[u16]],
        state: &mut MambaState,
        scratch: &mut StepScratch,
        logits: &mut Vec<f32>,
    ) {
        self.prefill_batch_impl(chunks, state, scratch, logits);
    }

    /// The W8A8 batched decode step — the native serving hot path.
    /// Executes entirely out of `scratch` (zero allocations after
    /// warmup); `scratch.threads > 1` splits the per-lane conv and
    /// scan across scoped threads, bit-identically.
    fn step_into(
        &self,
        tokens: &[u16],
        state: &mut MambaState,
        scratch: &mut StepScratch,
        logits: &mut Vec<f32>,
    ) {
        let t = &self.tier;
        let (d, di, n, r, w) = (t.d_model, t.d_inner, t.d_state, t.dt_rank, t.d_conv);
        let b = state.b;
        assert_eq!(tokens.len(), b, "one input token per state lane");
        assert!(
            state.is_quantized_conv(),
            "W8A8 step needs an i8 conv-window state (MambaState::new_quantized / prefill first)"
        );
        scratch.prep(b, t);
        let nt = scratch.threads.max(1).min(b);
        let kers = scratch.kernels;
        let cpl = (w - 1) * di;
        let spl = di * n;
        let StepScratch {
            resid,
            x_in,
            xz,
            x,
            z,
            act,
            bcdt,
            dt_low,
            bmat,
            cmat,
            dt,
            gated,
            out,
            fin,
            q_xin,
            q_conv,
            q_x,
            q_dt,
            q_b,
            q_c,
            q_gh,
            q_head,
            acc,
            ..
        } = scratch;
        for (bi, &tok) in tokens.iter().enumerate() {
            resid[bi * d..(bi + 1) * d]
                .copy_from_slice(&self.embedding[tok as usize * d..(tok as usize + 1) * d]);
        }
        for (li, ql) in self.layers.iter().enumerate() {
            // fused norm + requant into the int8 in_proj
            rmsnorm(resid, &ql.norm, d, 1e-5, x_in);
            ql.in_proj.forward_into(kers, x_in, ql.s_xin, b, q_xin, acc, xz);
            take_cols_into(xz, b, 2 * di, 0, di, x);
            take_cols_into(xz, b, 2 * di, di, 2 * di, z);
            quant::quantize_sym_into(x, ql.s_cin, 8, q_conv);
            let gx = &self.g_x[li * di..(li + 1) * di];
            let layer_conv = state.conv_q_layer_mut(li);
            if nt > 1 && cpl > 0 {
                let xq_r: &[i8] = &q_conv[..];
                let (w_q, bias, s_conv) = (&ql.conv_w_q, &ql.conv_b, ql.s_conv);
                par_lane_chunks(nt, b, &mut act[..], di, layer_conv, cpl, |lane0, act_c, hist_c| {
                    for (l, (a_l, h_l)) in
                        act_c.chunks_mut(di).zip(hist_c.chunks_mut(cpl)).enumerate()
                    {
                        let bi = lane0 + l;
                        fused_conv_silu_i8_with(
                            kers,
                            &xq_r[bi * di..(bi + 1) * di],
                            h_l,
                            w_q,
                            bias,
                            gx,
                            s_conv,
                            1,
                            di,
                            w,
                            a_l,
                        );
                    }
                });
            } else {
                for bi in 0..b {
                    fused_conv_silu_i8_with(
                        kers,
                        &q_conv[bi * di..(bi + 1) * di],
                        &mut layer_conv[bi * cpl..(bi + 1) * cpl],
                        &ql.conv_w_q,
                        &ql.conv_b,
                        gx,
                        ql.s_conv,
                        1,
                        di,
                        w,
                        &mut act[bi * di..(bi + 1) * di],
                    );
                }
            }
            // percentile-clipped static x-scale; the scan reuses the codes
            quant::quantize_sym_into(act, ql.s_x, 8, q_x);
            ql.x_proj.forward_q_into(kers, q_x, ql.s_x, b, acc, bcdt);
            take_cols_into(bcdt, b, r + 2 * n, 0, r, dt_low);
            take_cols_into(bcdt, b, r + 2 * n, r, r + n, bmat);
            take_cols_into(bcdt, b, r + 2 * n, r + n, r + 2 * n, cmat);
            ql.dt_proj.forward_into(kers, dt_low, ql.s_dt, b, q_dt, acc, dt);
            for v in dt.iter_mut() {
                *v = softplus(*v);
            }
            quant::quantize_sym_into(bmat, ql.s_b, 8, q_b);
            quant::quantize_sym_into(cmat, ql.s_c, 8, q_c);
            let gy = &self.g_y[li * di..(li + 1) * di];
            let layer_ssm = state.ssm_layer_mut(li);
            if nt > 1 {
                let (xq_r, dt_r, bq_r, cq_r, z_r) =
                    (&q_x[..], &dt[..], &q_b[..], &q_c[..], &z[..]);
                let (a_q, d_q) = (&ql.a_q, &ql.d_q);
                let (s_x, s_a, s_b, s_c, s_d) = (ql.s_x, ql.s_a, ql.s_b, ql.s_c, ql.s_d);
                par_lane_chunks(nt, b, &mut gated[..], di, layer_ssm, spl, |lane0, gated_c, ssm_c| {
                    for (l, (y, h)) in
                        gated_c.chunks_mut(di).zip(ssm_c.chunks_mut(spl)).enumerate()
                    {
                        let bi = lane0 + l;
                        selective_scan_q_into_with(
                            kers,
                            di,
                            n,
                            &xq_r[bi * di..(bi + 1) * di],
                            s_x,
                            &dt_r[bi * di..(bi + 1) * di],
                            a_q,
                            s_a,
                            &bq_r[bi * n..(bi + 1) * n],
                            s_b,
                            &cq_r[bi * n..(bi + 1) * n],
                            s_c,
                            d_q,
                            s_d,
                            h,
                            y,
                        );
                        for ch in 0..di {
                            y[ch] = y[ch] * silu(z_r[bi * di + ch]) * gy[ch];
                        }
                    }
                });
            } else {
                for bi in 0..b {
                    let y = &mut gated[bi * di..(bi + 1) * di];
                    selective_scan_q_into_with(
                        kers,
                        di,
                        n,
                        &q_x[bi * di..(bi + 1) * di],
                        ql.s_x,
                        &dt[bi * di..(bi + 1) * di],
                        &ql.a_q,
                        ql.s_a,
                        &q_b[bi * n..(bi + 1) * n],
                        ql.s_b,
                        &q_c[bi * n..(bi + 1) * n],
                        ql.s_c,
                        &ql.d_q,
                        ql.s_d,
                        &mut layer_ssm[bi * spl..(bi + 1) * spl],
                        y,
                    );
                    for ch in 0..di {
                        y[ch] = y[ch] * silu(z[bi * di + ch]) * gy[ch];
                    }
                }
            }
            // out_proj in the rotated space: rotate, quantize, int8 matmul
            // against the folded H·W_out (its scale carries the 1/di)
            ql.fwht.apply_rows(gated);
            ql.out_proj.forward_into(kers, gated, ql.s_gh, b, q_gh, acc, out);
            for i in 0..resid.len() {
                resid[i] += out[i];
            }
        }
        rmsnorm(resid, &self.norm_f, d, 1e-5, fin);
        rf32(logits, b * self.tier.vocab);
        self.head.forward_into(kers, fin, self.s_head_in, b, q_head, acc, logits);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tier() -> MambaTier {
        MambaTier {
            name: "tiny".into(),
            d_model: 16,
            n_layer: 2,
            d_state: 4,
            d_conv: 4,
            d_inner: 32,
            dt_rank: 4,
            vocab: 32,
        }
    }

    #[test]
    fn quantized_logits_close_to_fp32() {
        let t = tier();
        let model = MambaModel::synthetic(t.clone(), 7);
        let mut r = crate::util::rng::Pcg32::new(0xCAFE);
        let calib: Vec<u16> = (0..256).map(|_| r.below(t.vocab as u32) as u16).collect();
        let qm = QuantizedMambaModel::from_model(&model, &calib, &QuantConfig::default());
        let prompt: Vec<u16> = (0..12).map(|_| r.below(t.vocab as u32) as u16).collect();
        let lf = model.forward(&prompt, &crate::ssm::mamba::QuantSites::none(), None);
        let mut st = MambaState::new(&t, 1);
        let lq = qm.prefill(&prompt, &mut st);
        assert_eq!(lf.len(), lq.len());
        let amax = lf.iter().fold(0.0f32, |m, v| m.max(v.abs()));
        let err = lf.iter().zip(&lq).fold(0.0f32, |m, (a, b)| m.max((a - b).abs()));
        // W8A8 with static scales: a few percent of the logit range
        assert!(err < 0.06 * amax, "W8A8 err {err} vs logit amax {amax}");
        assert!(err > 0.0, "suspiciously exact — quantization not applied?");
    }

    #[test]
    fn batched_prefill_bit_identical_to_stepwise() {
        // ISSUE 2 acceptance: the (T×K) full-sequence quantized prefill
        // produces bit-identical logits AND state vs per-token stepping
        let t = tier();
        let model = MambaModel::synthetic(t.clone(), 7);
        let mut r = crate::util::rng::Pcg32::new(0xFEED);
        let calib: Vec<u16> = (0..256).map(|_| r.below(t.vocab as u32) as u16).collect();
        let qm = QuantizedMambaModel::from_model(&model, &calib, &QuantConfig::default());
        let prompt: Vec<u16> = (0..23).map(|_| r.below(t.vocab as u32) as u16).collect();
        let mut st_batched = MambaState::new_quantized(&t, 1);
        let lg_batched = qm.prefill(&prompt, &mut st_batched);
        let mut st_step = MambaState::new_quantized(&t, 1);
        let lg_step = qm.prefill_stepwise(&prompt, &mut st_step);
        assert_eq!(lg_batched.len(), lg_step.len());
        for (i, (a, b)) in lg_batched.iter().zip(&lg_step).enumerate() {
            assert_eq!(
                a.to_bits(),
                b.to_bits(),
                "logit {i}: batched {a} != stepwise {b}"
            );
        }
        assert_eq!(st_batched.conv_q, st_step.conv_q, "conv window codes diverged");
        for (i, (a, b)) in st_batched.ssm.iter().zip(&st_step.ssm).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "ssm state {i}: {a} != {b}");
        }
    }

    #[test]
    fn verify_row_addresses_the_batched_logits_grid() {
        // two ragged chunks (lengths 3 and 1) through the batched
        // prefill: every row verify_row returns must equal the
        // single-lane oracle's row at the same token position
        let t = tier();
        let model = MambaModel::synthetic(t.clone(), 7);
        let mut r = crate::util::rng::Pcg32::new(0xB00);
        let calib: Vec<u16> = (0..256).map(|_| r.below(t.vocab as u32) as u16).collect();
        let qm = QuantizedMambaModel::from_model(&model, &calib, &QuantConfig::default());
        let chunks: Vec<Vec<u16>> = vec![vec![1, 2, 3], vec![4]];
        let slices: Vec<&[u16]> = chunks.iter().map(|c| c.as_slice()).collect();
        let mut st = MambaState::new_quantized(&t, 2);
        let mut scratch = StepScratch::new(1);
        let mut logits = Vec::new();
        qm.prefill_batch_into(&slices, &mut st, &mut scratch, &mut logits);
        let t_max = 3;
        assert_eq!(logits.len(), 2 * t_max * t.vocab);
        for (bi, chunk) in chunks.iter().enumerate() {
            let mut st1 = MambaState::new_quantized(&t, 1);
            let mut l1 = Vec::new();
            qm.prefill_batch_into(&[chunk.as_slice()], &mut st1, &mut scratch, &mut l1);
            for ti in 0..chunk.len() {
                let got = verify_row(&logits, bi, t_max, ti, t.vocab);
                let want = verify_row(&l1, 0, chunk.len(), ti, t.vocab);
                for (i, (a, b)) in got.iter().zip(want).enumerate() {
                    assert_eq!(
                        a.to_bits(),
                        b.to_bits(),
                        "lane {bi} row {ti} logit {i}: batched {a} != oracle {b}"
                    );
                }
            }
        }
    }

    #[test]
    fn prefill_upgrades_f32_state_to_quantized_conv() {
        // serving code may hand the W8A8 model a plain MambaState::new
        // state; prefill converts it to the i8 conv-window layout
        let t = tier();
        let model = MambaModel::synthetic(t.clone(), 3);
        let qm = QuantizedMambaModel::from_model(&model, &[1, 2, 3, 4], &QuantConfig::default());
        let mut st = MambaState::new(&t, 1);
        assert!(!st.is_quantized_conv());
        qm.prefill(&[5, 6, 7], &mut st);
        assert!(st.is_quantized_conv());
        assert!(st.conv.is_empty());
    }

    #[test]
    fn hadamard_fold_matches_unrotated_projection() {
        // without quantization the fold is compute-invariant:
        // (1/di)·(H g)·(H W_out) == g·W_out. Verify on the dequantized
        // folded weight to isolate the algebra from int8 rounding.
        let t = tier();
        let model = MambaModel::synthetic(t.clone(), 3);
        let (d, di) = (t.d_model, t.d_inner);
        let layer = &model.layers[0];
        let mut r = crate::util::rng::Pcg32::new(2);
        let g: Vec<f32> = (0..di).map(|_| r.normal()).collect();
        // reference: g @ W_out
        let mut want = vec![0.0f32; d];
        for (ch, gv) in g.iter().enumerate() {
            for j in 0..d {
                want[j] += gv * layer.out_proj[ch * d + j];
            }
        }
        // folded: (1/di) · fwht(g) @ (H·W_out)
        let mut wt = vec![0.0f32; d * di];
        for row in 0..di {
            for col in 0..d {
                wt[col * di + row] = layer.out_proj[row * d + col];
            }
        }
        crate::quant::hadamard::fwht_rows(&mut wt, di);
        let gh = crate::quant::hadamard::fwht(&g);
        let mut got = vec![0.0f32; d];
        for j in 0..d {
            let wcol = &wt[j * di..(j + 1) * di];
            got[j] = gh.iter().zip(wcol).map(|(a, b)| a * b).sum::<f32>() / di as f32;
        }
        for (a, b) in want.iter().zip(&got) {
            assert!((a - b).abs() < 1e-3, "{a} vs {b}");
        }
    }

    #[test]
    fn fused_conv_exact_at_tap_bound() {
        // worst-case tap sum at w = MAX_SAFE_K: every product is 2¹⁴,
        // so the i32 accumulator lands exactly at 131071 · 16384 —
        // check via the dequantized output (s chosen so the value maps
        // back to the accumulator exactly at f32 precision ~2^31·2^-31)
        let w = quant::MAX_SAFE_K;
        let di = 1usize;
        let x_q = vec![-128i8; di]; // tl = 1
        let mut hist = vec![-128i8; (w - 1) * di];
        let w_q = vec![-128i8; w * di];
        let bias = vec![0.0f32];
        let gx = vec![1.0f32];
        // s = 2^-31 keeps silu's argument ~1.0 (well away from any
        // saturation) while remaining a power of two: the dequant of
        // the exact accumulator is then itself exact in f32
        let s = (2.0f32).powi(-31);
        let mut out = vec![0.0f32; di];
        fused_conv_silu_i8_with(
            Kernels::scalar(), &x_q, &mut hist, &w_q, &bias, &gx, s, 1, di, w, &mut out,
        );
        let acc = (w as i64) * quant::MAX_ABS_PROD_I8; // 2_147_467_264
        let want = silu(acc as f32 * s);
        assert_eq!(out[0].to_bits(), want.to_bits());
        assert!(out[0] > 0.7, "accumulator wrapped: silu output {}", out[0]);
    }

    #[test]
    #[cfg(debug_assertions)]
    #[should_panic(expected = "MAX_SAFE_K")]
    fn fused_conv_rejects_taps_past_bound() {
        let w = quant::MAX_SAFE_K + 1;
        let di = 1usize;
        let x_q = vec![-128i8; di];
        let mut hist = vec![-128i8; (w - 1) * di];
        let w_q = vec![-128i8; w * di];
        let mut out = vec![0.0f32; di];
        fused_conv_silu_i8_with(
            Kernels::scalar(), &x_q, &mut hist, &w_q, &[0.0], &[1.0], 0.01, 1, di, w, &mut out,
        );
    }

    fn w4_cfg() -> QuantConfig {
        QuantConfig { weight_bits: 4, ..QuantConfig::default() }
    }

    #[test]
    fn w4a8_logits_close_to_fp32() {
        // the nibble tier trades precision for bytes; per-group scales
        // must keep the logits within a (looser) budget of fp32
        let t = tier();
        let model = MambaModel::synthetic(t.clone(), 7);
        let mut r = crate::util::rng::Pcg32::new(0xCAFE);
        let calib: Vec<u16> = (0..256).map(|_| r.below(t.vocab as u32) as u16).collect();
        let qm = QuantizedMambaModel::from_model(&model, &calib, &w4_cfg());
        assert_eq!(qm.weight_bits, 4);
        let prompt: Vec<u16> = (0..12).map(|_| r.below(t.vocab as u32) as u16).collect();
        let lf = model.forward(&prompt, &crate::ssm::mamba::QuantSites::none(), None);
        let mut st = MambaState::new(&t, 1);
        let lq = qm.prefill(&prompt, &mut st);
        assert_eq!(lf.len(), lq.len());
        let amax = lf.iter().fold(0.0f32, |m, v| m.max(v.abs()));
        let err = lf.iter().zip(&lq).fold(0.0f32, |m, (a, b)| m.max((a - b).abs()));
        assert!(err < 0.25 * amax, "W4A8 err {err} vs logit amax {amax}");
        assert!(err > 0.0, "suspiciously exact — quantization not applied?");
    }

    #[test]
    fn w4a8_batched_prefill_bit_identical_to_stepwise() {
        // the bit-exactness contract holds at 4-bit weights too: exact
        // per-group i32 accumulation + fixed f32 epilogue order
        let t = tier();
        let model = MambaModel::synthetic(t.clone(), 7);
        let mut r = crate::util::rng::Pcg32::new(0xFEED);
        let calib: Vec<u16> = (0..256).map(|_| r.below(t.vocab as u32) as u16).collect();
        let qm = QuantizedMambaModel::from_model(&model, &calib, &w4_cfg());
        let prompt: Vec<u16> = (0..23).map(|_| r.below(t.vocab as u32) as u16).collect();
        let mut st_batched = MambaState::new_quantized(&t, 1);
        let lg_batched = qm.prefill(&prompt, &mut st_batched);
        let mut st_step = MambaState::new_quantized(&t, 1);
        let lg_step = qm.prefill_stepwise(&prompt, &mut st_step);
        assert_eq!(lg_batched.len(), lg_step.len());
        for (i, (a, b)) in lg_batched.iter().zip(&lg_step).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "logit {i}: batched {a} != stepwise {b}");
        }
        assert_eq!(st_batched.conv_q, st_step.conv_q, "conv window codes diverged");
        for (i, (a, b)) in st_batched.ssm.iter().zip(&st_step.ssm).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "ssm state {i}: {a} != {b}");
        }
    }

    #[test]
    fn w4a8_halves_gemm_weight_bytes() {
        let t = tier();
        let model = MambaModel::synthetic(t.clone(), 1);
        let calib: Vec<u16> = vec![1, 2, 3, 4, 5, 6, 7, 8];
        let q8 = QuantizedMambaModel::from_model(&model, &calib, &QuantConfig::default());
        let q4 = QuantizedMambaModel::from_model(&model, &calib, &w4_cfg());
        assert_eq!(q8.weight_bits, 8);
        assert_eq!(2 * q4.gemm_weight_bytes(), q8.gemm_weight_bytes());
        // conv/A/D codes stay int8, so total bytes shrink by less than 2×
        assert!(q4.weight_bytes_i8() < q8.weight_bytes_i8());
        assert!(2 * q4.weight_bytes_i8() > q8.weight_bytes_i8());
    }

    #[test]
    #[should_panic(expected = "unsupported weight_bits")]
    fn rejects_unsupported_weight_bits() {
        let t = tier();
        let model = MambaModel::synthetic(t.clone(), 1);
        let cfg = QuantConfig { weight_bits: 2, ..QuantConfig::default() };
        let _ = QuantizedMambaModel::from_model(&model, &[1, 2, 3], &cfg);
    }

    #[test]
    fn int8_weights_are_quarter_size() {
        let t = tier();
        let model = MambaModel::synthetic(t.clone(), 1);
        let qm = QuantizedMambaModel::from_model(&model, &[1, 2, 3, 4, 5, 6, 7, 8], &QuantConfig::default());
        // f32 projection weights for the same tier
        let (d, di, n, r) = (t.d_model, t.d_inner, t.d_state, t.dt_rank);
        let f32_proj_bytes = 4
            * t.n_layer
            * (d * 2 * di + di * (r + 2 * n) + r * di + di * d + t.d_conv * di + di * n + di)
            + 4 * d * t.vocab;
        let i8_bytes = qm.weight_bytes_i8();
        assert!(
            i8_bytes * 3 < f32_proj_bytes,
            "int8 {i8_bytes} should be ~4x below f32 {f32_proj_bytes}"
        );
    }
}
