//! Stateful decode for the native (artifact-free) serving backend:
//! a batched per-request recurrent state ([`MambaState`]), the
//! [`StepModel`] trait the coordinator serves from, the reusable
//! [`StepScratch`] workspace that makes the decode hot path
//! allocation-free, and the fp32 implementation for [`MambaModel`].
//!
//! The state layout is exactly the coordinator pool's raw batched
//! layout — conv (L, B, W−1, d_inner) and ssm (L, B, d_inner, N), both
//! flattened row-major — so `SsmStatePool::gather_raw` output can be
//! stepped directly and scattered back without reshaping. Quantized
//! models keep the conv window as **i8 codes** instead
//! ([`MambaState::new_quantized`]; 1 byte/entry, the §4.3 integer
//! pipeline), same layout, parallel `conv_q` buffer. The layer math is
//! the shared `pub(crate)` helper set in [`super::mamba`] plus
//! [`super::scan::selective_scan`], so a prefill followed by steps
//! reproduces the full-sequence `forward` exactly (see
//! `rust/tests/native_decode.rs`).
//!
//! ## Threading
//!
//! `StepScratch::threads > 1` splits the per-lane conv and scan loops
//! of a batched step across `std::thread::scope` workers. Lane math is
//! independent and every lane runs the identical instruction sequence,
//! so threaded output is **bit-identical** to single-threaded
//! (property-tested in `rust/tests/kernel_parity.rs`).

use super::mamba::{
    causal_conv_silu, matmul, rmsnorm, silu, softplus, take_cols, take_cols_into, MambaModel,
    MambaTier,
};
use super::scan::{selective_scan, selective_scan_into, ScanParams};
use crate::quant;
use crate::quant::Reservoir;

/// Per-layer cap on retained SSM-input calibration samples. Streams at
/// or below the cap are kept exactly (bit-identical to unbounded
/// collection — the parity-test calibrations fit); longer streams are
/// reservoir-sampled deterministically.
pub const X_CALIB_SAMPLES: usize = 8192;

/// Recurrent decode state for `b` sequences advancing in lockstep.
///
/// The conv window lives in exactly one of two parallel buffers:
/// `conv` (f32 values, the fp32 reference model) or `conv_q` (i8
/// codes at the layer's static conv-input scale, the W8A8 model) —
/// the other stays empty. Both use the (L, B, W−1, d_inner) layout.
pub struct MambaState {
    pub b: usize,
    n_layer: usize,
    conv_per_layer: usize, // (W-1) * d_inner
    ssm_per_layer: usize,  // d_inner * N
    /// which conv representation this state carries (the other buffer
    /// stays empty)
    quantized_conv: bool,
    /// (L, B, W−1, d_inner) flattened: the last W−1 conv inputs per
    /// layer per lane, oldest row first (fp32 models)
    pub conv: Vec<f32>,
    /// same layout as `conv`, but int8 *codes* (quantized models);
    /// empty unless the state was built for a quantized-conv model
    pub conv_q: Vec<i8>,
    /// (L, B, d_inner, N) flattened recurrent state
    pub ssm: Vec<f32>,
}

impl MambaState {
    pub fn new(tier: &MambaTier, b: usize) -> MambaState {
        Self::new_for(tier, b, false)
    }

    /// A state whose conv window is int8 codes (W8A8 models): quarter
    /// the conv bytes of the f32 layout.
    pub fn new_quantized(tier: &MambaTier, b: usize) -> MambaState {
        Self::new_for(tier, b, true)
    }

    /// Dispatch on [`StepModel::quantized_conv_state`].
    pub fn new_for(tier: &MambaTier, b: usize, quantized_conv: bool) -> MambaState {
        assert!(b > 0, "state needs at least one lane");
        let cpl = (tier.d_conv - 1) * tier.d_inner;
        let spl = tier.d_inner * tier.d_state;
        MambaState {
            b,
            n_layer: tier.n_layer,
            conv_per_layer: cpl,
            ssm_per_layer: spl,
            quantized_conv,
            conv: if quantized_conv { Vec::new() } else { vec![0.0; tier.n_layer * b * cpl] },
            conv_q: if quantized_conv { vec![0; tier.n_layer * b * cpl] } else { Vec::new() },
            ssm: vec![0.0; tier.n_layer * b * spl],
        }
    }

    /// Wrap raw batched buffers (the `SsmStatePool::gather_raw` layout).
    pub fn from_raw(tier: &MambaTier, b: usize, conv: Vec<f32>, ssm: Vec<f32>) -> MambaState {
        let cpl = (tier.d_conv - 1) * tier.d_inner;
        let spl = tier.d_inner * tier.d_state;
        assert_eq!(conv.len(), tier.n_layer * b * cpl, "conv buffer shape mismatch");
        assert_eq!(ssm.len(), tier.n_layer * b * spl, "ssm buffer shape mismatch");
        MambaState {
            b,
            n_layer: tier.n_layer,
            conv_per_layer: cpl,
            ssm_per_layer: spl,
            quantized_conv: false,
            conv,
            conv_q: Vec::new(),
            ssm,
        }
    }

    /// Wrap raw batched buffers with an i8 conv window
    /// (`SsmStatePool::gather_raw_q` layout).
    pub fn from_raw_q(tier: &MambaTier, b: usize, conv_q: Vec<i8>, ssm: Vec<f32>) -> MambaState {
        let cpl = (tier.d_conv - 1) * tier.d_inner;
        let spl = tier.d_inner * tier.d_state;
        assert_eq!(conv_q.len(), tier.n_layer * b * cpl, "conv_q buffer shape mismatch");
        assert_eq!(ssm.len(), tier.n_layer * b * spl, "ssm buffer shape mismatch");
        MambaState {
            b,
            n_layer: tier.n_layer,
            conv_per_layer: cpl,
            ssm_per_layer: spl,
            quantized_conv: true,
            conv: Vec::new(),
            conv_q,
            ssm,
        }
    }

    /// Back to the raw buffers for `SsmStatePool::scatter_raw`.
    pub fn into_raw(self) -> (Vec<f32>, Vec<f32>) {
        assert!(!self.quantized_conv, "state carries an i8 conv window: use into_raw_q");
        (self.conv, self.ssm)
    }

    /// Back to the raw buffers for `SsmStatePool::scatter_raw_q`.
    pub fn into_raw_q(self) -> (Vec<i8>, Vec<f32>) {
        assert!(self.quantized_conv, "state carries an f32 conv window: use into_raw");
        (self.conv_q, self.ssm)
    }

    /// True when the conv window is stored as i8 codes.
    pub fn is_quantized_conv(&self) -> bool {
        self.quantized_conv
    }

    /// Switch this state to the i8 conv-window representation (used by
    /// quantized prefill on a state built with [`Self::new`]); resets
    /// nothing else.
    pub(crate) fn ensure_quantized_conv(&mut self) {
        if !self.quantized_conv {
            self.quantized_conv = true;
            self.conv_q = vec![0; self.n_layer * self.b * self.conv_per_layer];
            self.conv = Vec::new();
        }
    }

    pub fn reset(&mut self) {
        self.conv.fill(0.0);
        self.conv_q.fill(0);
        self.ssm.fill(0.0);
    }

    /// Per-request state bytes (constant in context length; the i8
    /// conv window of quantized models is a quarter of the f32 one).
    pub fn bytes_per_lane(&self) -> usize {
        let conv_bytes =
            if self.is_quantized_conv() { self.conv_per_layer } else { 4 * self.conv_per_layer };
        self.n_layer * (conv_bytes + 4 * self.ssm_per_layer)
    }

    pub(crate) fn conv_lane(&mut self, li: usize, bi: usize) -> &mut [f32] {
        let cpl = self.conv_per_layer;
        let off = (li * self.b + bi) * cpl;
        &mut self.conv[off..off + cpl]
    }

    pub(crate) fn conv_lane_q(&mut self, li: usize, bi: usize) -> &mut [i8] {
        let cpl = self.conv_per_layer;
        let off = (li * self.b + bi) * cpl;
        &mut self.conv_q[off..off + cpl]
    }

    /// All lanes of one layer's f32 conv window, contiguous (B × cpl).
    pub(crate) fn conv_layer_mut(&mut self, li: usize) -> &mut [f32] {
        let stride = self.b * self.conv_per_layer;
        &mut self.conv[li * stride..(li + 1) * stride]
    }

    /// All lanes of one layer's i8 conv window, contiguous (B × cpl).
    pub(crate) fn conv_q_layer_mut(&mut self, li: usize) -> &mut [i8] {
        let stride = self.b * self.conv_per_layer;
        &mut self.conv_q[li * stride..(li + 1) * stride]
    }

    pub(crate) fn ssm_lane(&mut self, li: usize, bi: usize) -> &mut [f32] {
        let spl = self.ssm_per_layer;
        let off = (li * self.b + bi) * spl;
        &mut self.ssm[off..off + spl]
    }

    /// All lanes of one layer's recurrent state, contiguous (B × spl).
    pub(crate) fn ssm_layer_mut(&mut self, li: usize) -> &mut [f32] {
        let stride = self.b * self.ssm_per_layer;
        &mut self.ssm[li * stride..(li + 1) * stride]
    }
}

/// Zero the pad rows of a lane-major (B × t_max × width) batched
/// prefill buffer: lane bi's rows at t ≥ |chunks[bi]| are padding.
/// Shared by both `prefill_batch_into` impls — zeroed pads keep every
/// downstream row-local op deterministic (stale scratch values could
/// otherwise produce NaN/Inf in rows that are discarded anyway, which
/// would make reruns non-reproducible at the buffer level).
pub(crate) fn zero_pad_rows(buf: &mut [f32], chunks: &[&[u16]], t_max: usize, width: usize) {
    for (bi, c) in chunks.iter().enumerate() {
        let tl = c.len();
        if tl < t_max {
            buf[(bi * t_max + tl) * width..(bi + 1) * t_max * width].fill(0.0);
        }
    }
}

/// Resize a scratch buffer to exactly `n` elements WITHOUT clearing:
/// every consumer fully overwrites its buffer before reading (matmul /
/// rmsnorm / take_cols_into / conv / scan all write each element), so
/// zero-filling the whole length each call would be a wasted memset on
/// the hot path — only growth is zero-initialized.
pub(crate) fn rf32(v: &mut Vec<f32>, n: usize) {
    v.resize(n, 0.0);
}

/// Split `b` lanes into up to `nt` contiguous chunks across
/// `std::thread::scope` workers. `a` / `bb` are two per-lane-strided
/// mutable buffers (strides `sa`, `sb`, both > 0); `f` runs once per
/// chunk with the chunk's first global lane index and the two matching
/// sub-slices. Lane math is independent per lane, so any chunking is
/// bit-identical to a sequential loop — this is the one place the
/// batched-step conv/scan sections (fp32 and W8A8) get their
/// parity-tested chunk arithmetic from.
pub(crate) fn par_lane_chunks<T: Send, U: Send>(
    nt: usize,
    b: usize,
    a: &mut [T],
    sa: usize,
    bb: &mut [U],
    sb: usize,
    f: impl Fn(usize, &mut [T], &mut [U]) + Sync,
) {
    debug_assert!(sa > 0 && sb > 0, "strides must be positive");
    debug_assert_eq!(a.len(), b * sa);
    debug_assert_eq!(bb.len(), b * sb);
    let lanes_per = b.div_ceil(nt.max(1));
    let fr = &f;
    std::thread::scope(|sc| {
        for (ci, (ac, bc)) in
            a.chunks_mut(lanes_per * sa).zip(bb.chunks_mut(lanes_per * sb)).enumerate()
        {
            sc.spawn(move || fr(ci * lanes_per, ac, bc));
        }
    });
}

/// Reusable per-engine workspace for [`StepModel::step_into`] /
/// [`StepModel::prefill_into`]: every intermediate buffer of a layer
/// step lives here, so after one warmup call the hot path performs
/// **zero heap allocations** (asserted by `rust/tests/zero_alloc.rs`).
/// Buffers are sized by `rows = B` (batched decode) or `rows = T`
/// (full-sequence quantized prefill) on each call; `clear + resize`
/// never reallocates once capacity has peaked.
pub struct StepScratch {
    /// worker threads for the lane-parallel conv/scan sections of a
    /// batched step (1 = sequential; >1 is bit-identical, see module
    /// docs). Set from `NativeEngineConfig::threads` by the engine.
    pub threads: usize,
    /// int8 kernel backend for the GEMM/conv/scan hot paths
    /// ([`crate::quant::Kernels`]): auto-detected by default,
    /// forceable per scratch (engine config / parity tests). Every
    /// backend is bit-identical, so this only changes wall-clock.
    pub kernels: crate::quant::Kernels,
    pub(crate) resid: Vec<f32>,
    pub(crate) x_in: Vec<f32>,
    pub(crate) xz: Vec<f32>,
    pub(crate) x: Vec<f32>,
    pub(crate) z: Vec<f32>,
    pub(crate) act: Vec<f32>,
    pub(crate) bcdt: Vec<f32>,
    pub(crate) dt_low: Vec<f32>,
    pub(crate) bmat: Vec<f32>,
    pub(crate) cmat: Vec<f32>,
    pub(crate) dt: Vec<f32>,
    pub(crate) gated: Vec<f32>,
    pub(crate) out: Vec<f32>,
    pub(crate) fin: Vec<f32>,
    // int8 code buffers (the W8A8 path)
    pub(crate) q_xin: Vec<i8>,
    pub(crate) q_conv: Vec<i8>,
    pub(crate) q_x: Vec<i8>,
    pub(crate) q_dt: Vec<i8>,
    pub(crate) q_b: Vec<i8>,
    pub(crate) q_c: Vec<i8>,
    pub(crate) q_gh: Vec<i8>,
    pub(crate) q_head: Vec<i8>,
    /// shared i32 accumulator for the blocked int8 GEMMs
    pub(crate) acc: Vec<i32>,
}

impl StepScratch {
    pub fn new(threads: usize) -> StepScratch {
        Self::with_kernels(threads, crate::quant::Kernels::auto())
    }

    /// A scratch pinned to a specific kernel backend (testing /
    /// benchmarking; [`Self::new`] auto-selects).
    pub fn with_kernels(threads: usize, kernels: crate::quant::Kernels) -> StepScratch {
        StepScratch {
            threads: threads.max(1),
            kernels,
            resid: Vec::new(),
            x_in: Vec::new(),
            xz: Vec::new(),
            x: Vec::new(),
            z: Vec::new(),
            act: Vec::new(),
            bcdt: Vec::new(),
            dt_low: Vec::new(),
            bmat: Vec::new(),
            cmat: Vec::new(),
            dt: Vec::new(),
            gated: Vec::new(),
            out: Vec::new(),
            fin: Vec::new(),
            q_xin: Vec::new(),
            q_conv: Vec::new(),
            q_x: Vec::new(),
            q_dt: Vec::new(),
            q_b: Vec::new(),
            q_c: Vec::new(),
            q_gh: Vec::new(),
            q_head: Vec::new(),
            acc: Vec::new(),
        }
    }

    /// Size the f32 buffers for `rows` rows of tier `t`.
    pub(crate) fn prep(&mut self, rows: usize, t: &MambaTier) {
        let (d, di, n, r) = (t.d_model, t.d_inner, t.d_state, t.dt_rank);
        rf32(&mut self.resid, rows * d);
        rf32(&mut self.x_in, rows * d);
        rf32(&mut self.xz, rows * 2 * di);
        rf32(&mut self.x, rows * di);
        rf32(&mut self.z, rows * di);
        rf32(&mut self.act, rows * di);
        rf32(&mut self.bcdt, rows * (r + 2 * n));
        rf32(&mut self.dt_low, rows * r);
        rf32(&mut self.bmat, rows * n);
        rf32(&mut self.cmat, rows * n);
        rf32(&mut self.dt, rows * di);
        rf32(&mut self.gated, rows * di);
        rf32(&mut self.out, rows * d);
        rf32(&mut self.fin, rows * d);
    }
}

impl Default for StepScratch {
    fn default() -> Self {
        StepScratch::new(1)
    }
}

/// A model the native engine can serve: full-sequence prompt ingestion
/// plus a batched single-token step. Implemented by the fp32
/// [`MambaModel`] and the W8A8 [`super::qmamba::QuantizedMambaModel`].
/// The `*_into` methods are the hot-path surface (caller-owned scratch
/// and logits buffer); `prefill`/`step` are allocating conveniences.
pub trait StepModel {
    fn tier(&self) -> &MambaTier;

    /// True when the model keeps its conv window as i8 codes — the
    /// engine builds its state pool (and [`MambaState`]s) to match.
    fn quantized_conv_state(&self) -> bool {
        false
    }

    /// Consume a prompt into a fresh B=1 `state`. (T × V) logits land
    /// in `logits` (row t conditions on tokens[..=t]).
    fn prefill_into(
        &self,
        tokens: &[u16],
        state: &mut MambaState,
        scratch: &mut StepScratch,
        logits: &mut Vec<f32>,
    );

    /// Continue a prefill from an existing (non-fresh) B=1 `state`:
    /// like [`Self::prefill_into`] but without the state reset —
    /// `tokens` is the *suffix* of a prompt whose prefix already
    /// produced `state`. Composition is **bit-exact**:
    /// `prefill(p)` then `prefill_resume(s)` yields the same final
    /// state as `prefill(p ++ s)`, and the emitted logits rows equal
    /// the corresponding suffix rows of the one-shot run (per-row f32
    /// ops plus the carried conv window / scan state replay the
    /// identical instruction sequence — the same property that makes
    /// the stepwise prefill oracle exact). This is the prefix-cache
    /// warm path; property-tested in `rust/tests/prefix_cache.rs`.
    fn prefill_resume_into(
        &self,
        tokens: &[u16],
        state: &mut MambaState,
        scratch: &mut StepScratch,
        logits: &mut Vec<f32>,
    );

    /// Advance all `state.b` lanes by one token each (`tokens[bi]` is
    /// lane bi's input); (B × V) next-token logits land in `logits`.
    /// Allocation-free after warmup for the W8A8 model.
    fn step_into(
        &self,
        tokens: &[u16],
        state: &mut MambaState,
        scratch: &mut StepScratch,
        logits: &mut Vec<f32>,
    );

    /// Advance `state.b` **independent in-flight prefills** by one
    /// chunk each — the unified scheduler's (B, T) batched prefill.
    /// `chunks[bi]` is lane bi's next (non-empty) slice of prompt
    /// tokens; the lane's carried conv window / scan state advances in
    /// place, exactly as a per-lane [`Self::prefill_resume_into`]
    /// would. Ragged chunks are padded to `t_max = max_i |chunks[i]|`
    /// on a lane-major grid: `logits` comes back as
    /// (B × t_max × V) with lane bi's row t at `(bi·t_max + t)·V`;
    /// rows at t ≥ |chunks[bi]| are deterministic filler (a BOS pad
    /// row pushed through the row-local ops) and must be ignored.
    ///
    /// **Bit-parity contract** (property-tested in
    /// `rust/tests/chunked_prefill.rs`): every op in the prefill body
    /// is either per-row (rmsnorm, projections, gates, head) or
    /// sequential-per-lane with carried state (conv window, scan h),
    /// so batching lanes together — whatever the padding — replays
    /// each lane's per-request `prefill_into`/`prefill_resume_into`
    /// instruction sequence exactly: valid logits rows and final
    /// states are bit-identical to the B=1 oracle.
    fn prefill_batch_into(
        &self,
        chunks: &[&[u16]],
        state: &mut MambaState,
        scratch: &mut StepScratch,
        logits: &mut Vec<f32>,
    );

    /// Allocating convenience wrapper over [`Self::prefill_into`].
    fn prefill(&self, tokens: &[u16], state: &mut MambaState) -> Vec<f32> {
        let mut scratch = StepScratch::new(1);
        let mut logits = Vec::new();
        self.prefill_into(tokens, state, &mut scratch, &mut logits);
        logits
    }

    /// Allocating convenience wrapper over [`Self::step_into`].
    fn step(&self, tokens: &[u16], state: &mut MambaState) -> Vec<f32> {
        let mut scratch = StepScratch::new(1);
        let mut logits = Vec::new();
        self.step_into(tokens, state, &mut scratch, &mut logits);
        logits
    }
}

/// Per-layer activation ranges recorded by a calibration prefill —
/// everything the W8A8 quantizer needs (paper §4.2 / §5.1).
#[derive(Debug, Clone)]
pub struct LayerCalib {
    /// |rmsnorm output| max — the in_proj input scale
    pub x_in_amax: f32,
    /// |conv input| max
    pub conv_in_amax: f32,
    /// bounded reservoir of SSM-input samples (percentile clip applied
    /// by the quantizer); O([`X_CALIB_SAMPLES`]) memory however long
    /// the calibration stream runs
    pub x_ssm: Reservoir,
    pub dt_low_amax: f32,
    pub b_amax: f32,
    pub c_amax: f32,
    /// |H·gated| max — the rotated-space out_proj input scale (§3.3)
    pub gated_h_amax: f32,
}

impl Default for LayerCalib {
    fn default() -> Self {
        LayerCalib {
            x_in_amax: 0.0,
            conv_in_amax: 0.0,
            x_ssm: Reservoir::new(X_CALIB_SAMPLES, 0xCA11B),
            dt_low_amax: 0.0,
            b_amax: 0.0,
            c_amax: 0.0,
            gated_h_amax: 0.0,
        }
    }
}

/// Whole-model calibration record.
#[derive(Debug, Clone, Default)]
pub struct CalibRecord {
    pub layers: Vec<LayerCalib>,
    /// |final rmsnorm output| max — the tied-head input scale
    pub head_in_amax: f32,
}

impl MambaModel {
    /// fp32 calibration pass: one prefill over `tokens` recording the
    /// activation ranges for [`super::qmamba::QuantizedMambaModel`].
    /// SSM-input samples go into per-layer seeded reservoirs, so
    /// calibration memory is bounded regardless of stream length.
    pub fn calibrate(&self, tokens: &[u16]) -> CalibRecord {
        let mut rec = CalibRecord {
            layers: (0..self.tier.n_layer)
                .map(|li| LayerCalib {
                    x_ssm: Reservoir::new(X_CALIB_SAMPLES, 0xCA11B ^ li as u64),
                    ..Default::default()
                })
                .collect(),
            head_in_amax: 0.0,
        };
        let mut state = MambaState::new(&self.tier, 1);
        let _ = self.prefill_impl(tokens, &mut state, Some(&mut rec), false);
        rec
    }

    /// Full-sequence prefill with carried state; optionally records
    /// calibration statistics. Shared by `StepModel::prefill`,
    /// `StepModel::prefill_resume_into` (`resume = true` keeps the
    /// incoming state — the prefix-cache warm path) and
    /// [`Self::calibrate`].
    fn prefill_impl(
        &self,
        tokens: &[u16],
        state: &mut MambaState,
        mut calib: Option<&mut CalibRecord>,
        resume: bool,
    ) -> Vec<f32> {
        assert_eq!(state.b, 1, "prefill is single-sequence; step() handles batched decode");
        assert!(!tokens.is_empty(), "prefill needs at least one token");
        assert!(!state.is_quantized_conv(), "fp32 prefill needs an f32 conv state");
        if !resume {
            state.reset();
        }
        let t = &self.tier;
        let (d, di, n, r, w, tl) =
            (t.d_model, t.d_inner, t.d_state, t.dt_rank, t.d_conv, tokens.len());
        let mut resid = vec![0.0f32; tl * d];
        for (i, &tok) in tokens.iter().enumerate() {
            resid[i * d..(i + 1) * d]
                .copy_from_slice(&self.embedding[tok as usize * d..(tok as usize + 1) * d]);
        }
        let mut x_in = vec![0.0f32; tl * d];
        let mut xz = vec![0.0f32; tl * 2 * di];
        let mut bcdt = vec![0.0f32; tl * (r + 2 * n)];
        let mut out = vec![0.0f32; tl * d];
        for (li, layer) in self.layers.iter().enumerate() {
            rmsnorm(&resid, &layer.norm, d, 1e-5, &mut x_in);
            matmul(&x_in, &layer.in_proj, tl, d, 2 * di, &mut xz);
            let x = take_cols(&xz, tl, 2 * di, 0, di);
            let z = take_cols(&xz, tl, 2 * di, di, 2 * di);
            let gx = &self.g_x[li * di..(li + 1) * di];
            let mut xs = vec![0.0f32; tl * di];
            causal_conv_silu(
                &x,
                Some(state.conv_lane(li, 0)),
                &layer.conv_w,
                &layer.conv_b,
                gx,
                tl,
                di,
                w,
                &mut xs,
            );
            matmul(&xs, &layer.x_proj, tl, di, r + 2 * n, &mut bcdt);
            let dt_low = take_cols(&bcdt, tl, r + 2 * n, 0, r);
            let bmat = take_cols(&bcdt, tl, r + 2 * n, r, r + n);
            let cmat = take_cols(&bcdt, tl, r + 2 * n, r + n, r + 2 * n);
            let mut dt = vec![0.0f32; tl * di];
            matmul(&dt_low, &layer.dt_proj, tl, r, di, &mut dt);
            for ti in 0..tl {
                for ch in 0..di {
                    dt[ti * di + ch] = softplus(dt[ti * di + ch] + layer.dt_bias[ch]);
                }
            }
            let p = ScanParams { a: &layer.a, d: &layer.d, d_inner: di, n_state: n };
            let y = selective_scan(&p, &xs, &dt, &bmat, &cmat, state.ssm_lane(li, 0));
            let gy = &self.g_y[li * di..(li + 1) * di];
            let mut gated = vec![0.0f32; tl * di];
            for ti in 0..tl {
                for ch in 0..di {
                    gated[ti * di + ch] = y[ti * di + ch] * silu(z[ti * di + ch]) * gy[ch];
                }
            }
            if let Some(rec) = calib.as_deref_mut() {
                let lc = &mut rec.layers[li];
                lc.x_in_amax = lc.x_in_amax.max(quant::amax(&x_in));
                lc.conv_in_amax = lc.conv_in_amax.max(quant::amax(&x));
                lc.x_ssm.extend_from_slice(&xs);
                lc.dt_low_amax = lc.dt_low_amax.max(quant::amax(&dt_low));
                lc.b_amax = lc.b_amax.max(quant::amax(&bmat));
                lc.c_amax = lc.c_amax.max(quant::amax(&cmat));
                let mut gh = gated.clone();
                crate::quant::hadamard::fwht_rows(&mut gh, di);
                lc.gated_h_amax = lc.gated_h_amax.max(quant::amax(&gh));
            }
            matmul(&gated, &layer.out_proj, tl, di, d, &mut out);
            for i in 0..resid.len() {
                resid[i] += out[i];
            }
        }
        let fin = self.final_hidden(&resid, tl);
        if let Some(rec) = calib.as_deref_mut() {
            rec.head_in_amax = rec.head_in_amax.max(quant::amax(&fin));
        }
        self.tied_logits(&fin, tl)
    }
}

impl StepModel for MambaModel {
    fn tier(&self) -> &MambaTier {
        &self.tier
    }

    fn prefill_into(
        &self,
        tokens: &[u16],
        state: &mut MambaState,
        _scratch: &mut StepScratch,
        logits: &mut Vec<f32>,
    ) {
        *logits = self.prefill_impl(tokens, state, None, false);
    }

    fn prefill_resume_into(
        &self,
        tokens: &[u16],
        state: &mut MambaState,
        _scratch: &mut StepScratch,
        logits: &mut Vec<f32>,
    ) {
        *logits = self.prefill_impl(tokens, state, None, true);
    }

    /// (B, T) batched multi-prompt prefill, fp32. Row-parallel ops run
    /// over the whole lane-major grid out of the scratch (zero-alloc
    /// after warmup, like `step_into`); the conv window and scan state
    /// advance per lane over that lane's real rows only — so each
    /// lane's valid logits rows and final state are **bit-identical**
    /// to running `prefill_resume_into` on it alone (see trait docs).
    fn prefill_batch_into(
        &self,
        chunks: &[&[u16]],
        state: &mut MambaState,
        scratch: &mut StepScratch,
        logits: &mut Vec<f32>,
    ) {
        let t = &self.tier;
        let (d, di, n, r, w) = (t.d_model, t.d_inner, t.d_state, t.dt_rank, t.d_conv);
        let b = state.b;
        assert_eq!(chunks.len(), b, "one chunk per state lane");
        assert!(chunks.iter().all(|c| !c.is_empty()), "prefill chunks must be non-empty");
        assert!(!state.is_quantized_conv(), "fp32 prefill needs an f32 conv state");
        let t_max = chunks.iter().map(|c| c.len()).max().unwrap();
        let rows = b * t_max;
        scratch.prep(rows, t);
        let StepScratch {
            resid, x_in, xz, x, z, act, bcdt, dt_low, bmat, cmat, dt, gated, out, fin, ..
        } = scratch;
        for (bi, chunk) in chunks.iter().enumerate() {
            for ti in 0..t_max {
                let tok = if ti < chunk.len() {
                    chunk[ti] as usize
                } else {
                    crate::data::BOS as usize
                };
                resid[(bi * t_max + ti) * d..(bi * t_max + ti + 1) * d]
                    .copy_from_slice(&self.embedding[tok * d..(tok + 1) * d]);
            }
        }
        for (li, layer) in self.layers.iter().enumerate() {
            rmsnorm(resid, &layer.norm, d, 1e-5, x_in);
            matmul(x_in, &layer.in_proj, rows, d, 2 * di, xz);
            take_cols_into(xz, rows, 2 * di, 0, di, x);
            take_cols_into(xz, rows, 2 * di, di, 2 * di, z);
            let gx = &self.g_x[li * di..(li + 1) * di];
            for (bi, chunk) in chunks.iter().enumerate() {
                let tl = chunk.len();
                let off = bi * t_max * di;
                causal_conv_silu(
                    &x[off..off + tl * di],
                    Some(state.conv_lane(li, bi)),
                    &layer.conv_w,
                    &layer.conv_b,
                    gx,
                    tl,
                    di,
                    w,
                    &mut act[off..off + tl * di],
                );
            }
            zero_pad_rows(act, chunks, t_max, di);
            matmul(act, &layer.x_proj, rows, di, r + 2 * n, bcdt);
            take_cols_into(bcdt, rows, r + 2 * n, 0, r, dt_low);
            take_cols_into(bcdt, rows, r + 2 * n, r, r + n, bmat);
            take_cols_into(bcdt, rows, r + 2 * n, r + n, r + 2 * n, cmat);
            matmul(dt_low, &layer.dt_proj, rows, r, di, dt);
            for row in 0..rows {
                for ch in 0..di {
                    dt[row * di + ch] = softplus(dt[row * di + ch] + layer.dt_bias[ch]);
                }
            }
            let p = ScanParams { a: &layer.a, d: &layer.d, d_inner: di, n_state: n };
            let gy = &self.g_y[li * di..(li + 1) * di];
            for (bi, chunk) in chunks.iter().enumerate() {
                let tl = chunk.len();
                let off = bi * t_max * di;
                let boff = bi * t_max * n;
                selective_scan_into(
                    &p,
                    &act[off..off + tl * di],
                    &dt[off..off + tl * di],
                    &bmat[boff..boff + tl * n],
                    &cmat[boff..boff + tl * n],
                    state.ssm_lane(li, bi),
                    &mut gated[off..off + tl * di],
                );
                for (ti, row) in gated[off..off + tl * di].chunks_exact_mut(di).enumerate() {
                    let zrow = &z[off + ti * di..off + (ti + 1) * di];
                    for ch in 0..di {
                        row[ch] = row[ch] * silu(zrow[ch]) * gy[ch];
                    }
                }
            }
            zero_pad_rows(gated, chunks, t_max, di);
            matmul(gated, &layer.out_proj, rows, di, d, out);
            for i in 0..resid.len() {
                resid[i] += out[i];
            }
        }
        rmsnorm(resid, &self.norm_f, d, 1e-5, fin);
        self.tied_logits_into(fin, rows, logits);
    }

    fn step_into(
        &self,
        tokens: &[u16],
        state: &mut MambaState,
        scratch: &mut StepScratch,
        logits: &mut Vec<f32>,
    ) {
        let t = &self.tier;
        let (d, di, n, r, w) = (t.d_model, t.d_inner, t.d_state, t.dt_rank, t.d_conv);
        let b = state.b;
        assert_eq!(tokens.len(), b, "one input token per state lane");
        assert!(!state.is_quantized_conv(), "fp32 step needs an f32 conv state");
        scratch.prep(b, t);
        let nt = scratch.threads.max(1).min(b);
        let cpl = (w - 1) * di;
        let spl = di * n;
        let StepScratch {
            resid, x_in, xz, x, z, act, bcdt, dt_low, bmat, cmat, dt, gated, out, fin, ..
        } = scratch;
        for (bi, &tok) in tokens.iter().enumerate() {
            resid[bi * d..(bi + 1) * d]
                .copy_from_slice(&self.embedding[tok as usize * d..(tok as usize + 1) * d]);
        }
        for (li, layer) in self.layers.iter().enumerate() {
            rmsnorm(resid, &layer.norm, d, 1e-5, x_in);
            matmul(x_in, &layer.in_proj, b, d, 2 * di, xz);
            take_cols_into(xz, b, 2 * di, 0, di, x);
            take_cols_into(xz, b, 2 * di, di, 2 * di, z);
            let gx = &self.g_x[li * di..(li + 1) * di];
            let layer_conv = state.conv_layer_mut(li);
            if nt > 1 && cpl > 0 {
                let xr: &[f32] = &x[..];
                let (conv_w, conv_b) = (&layer.conv_w, &layer.conv_b);
                par_lane_chunks(nt, b, &mut act[..], di, layer_conv, cpl, |lane0, act_c, hist_c| {
                    for (l, (a_l, h_l)) in
                        act_c.chunks_mut(di).zip(hist_c.chunks_mut(cpl)).enumerate()
                    {
                        let bi = lane0 + l;
                        causal_conv_silu(
                            &xr[bi * di..(bi + 1) * di],
                            Some(h_l),
                            conv_w,
                            conv_b,
                            gx,
                            1,
                            di,
                            w,
                            a_l,
                        );
                    }
                });
            } else {
                for bi in 0..b {
                    causal_conv_silu(
                        &x[bi * di..(bi + 1) * di],
                        Some(&mut layer_conv[bi * cpl..(bi + 1) * cpl]),
                        &layer.conv_w,
                        &layer.conv_b,
                        gx,
                        1,
                        di,
                        w,
                        &mut act[bi * di..(bi + 1) * di],
                    );
                }
            }
            matmul(act, &layer.x_proj, b, di, r + 2 * n, bcdt);
            take_cols_into(bcdt, b, r + 2 * n, 0, r, dt_low);
            take_cols_into(bcdt, b, r + 2 * n, r, r + n, bmat);
            take_cols_into(bcdt, b, r + 2 * n, r + n, r + 2 * n, cmat);
            matmul(dt_low, &layer.dt_proj, b, r, di, dt);
            for bi in 0..b {
                for ch in 0..di {
                    dt[bi * di + ch] = softplus(dt[bi * di + ch] + layer.dt_bias[ch]);
                }
            }
            let p = ScanParams { a: &layer.a, d: &layer.d, d_inner: di, n_state: n };
            let gy = &self.g_y[li * di..(li + 1) * di];
            let layer_ssm = state.ssm_layer_mut(li);
            if nt > 1 {
                let (xs_r, dt_r, b_r, c_r, z_r) =
                    (&act[..], &dt[..], &bmat[..], &cmat[..], &z[..]);
                let pp = &p;
                par_lane_chunks(nt, b, &mut gated[..], di, layer_ssm, spl, |lane0, gated_c, ssm_c| {
                    for (l, (y, h)) in
                        gated_c.chunks_mut(di).zip(ssm_c.chunks_mut(spl)).enumerate()
                    {
                        let bi = lane0 + l;
                        selective_scan_into(
                            pp,
                            &xs_r[bi * di..(bi + 1) * di],
                            &dt_r[bi * di..(bi + 1) * di],
                            &b_r[bi * n..(bi + 1) * n],
                            &c_r[bi * n..(bi + 1) * n],
                            h,
                            y,
                        );
                        for ch in 0..di {
                            y[ch] = y[ch] * silu(z_r[bi * di + ch]) * gy[ch];
                        }
                    }
                });
            } else {
                for bi in 0..b {
                    let y = &mut gated[bi * di..(bi + 1) * di];
                    selective_scan_into(
                        &p,
                        &act[bi * di..(bi + 1) * di],
                        &dt[bi * di..(bi + 1) * di],
                        &bmat[bi * n..(bi + 1) * n],
                        &cmat[bi * n..(bi + 1) * n],
                        &mut layer_ssm[bi * spl..(bi + 1) * spl],
                        y,
                    );
                    for ch in 0..di {
                        y[ch] = y[ch] * silu(z[bi * di + ch]) * gy[ch];
                    }
                }
            }
            matmul(gated, &layer.out_proj, b, di, d, out);
            for i in 0..resid.len() {
                resid[i] += out[i];
            }
        }
        rmsnorm(resid, &self.norm_f, d, 1e-5, fin);
        self.tied_logits_into(fin, b, logits);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_tier() -> MambaTier {
        MambaTier {
            name: "tiny".into(),
            d_model: 8,
            n_layer: 2,
            d_state: 4,
            d_conv: 4,
            d_inner: 16,
            dt_rank: 2,
            vocab: 16,
        }
    }

    #[test]
    fn state_layout_roundtrips_raw() {
        let tier = tiny_tier();
        let mut st = MambaState::new(&tier, 3);
        st.conv.iter_mut().enumerate().for_each(|(i, v)| *v = i as f32);
        st.ssm.iter_mut().enumerate().for_each(|(i, v)| *v = -(i as f32));
        let (c, s) = (st.conv.clone(), st.ssm.clone());
        let st2 = MambaState::from_raw(&tier, 3, c, s);
        let (c2, s2) = st2.into_raw();
        assert_eq!(c2, st.conv);
        assert_eq!(s2, st.ssm);
    }

    #[test]
    fn quantized_state_layout_roundtrips_raw() {
        let tier = tiny_tier();
        let mut st = MambaState::new_quantized(&tier, 2);
        assert!(st.conv.is_empty());
        st.conv_q.iter_mut().enumerate().for_each(|(i, v)| *v = (i % 127) as i8);
        st.ssm.iter_mut().enumerate().for_each(|(i, v)| *v = i as f32);
        let (cq, s) = (st.conv_q.clone(), st.ssm.clone());
        let st2 = MambaState::from_raw_q(&tier, 2, cq, s);
        assert!(st2.is_quantized_conv());
        let (cq2, s2) = st2.into_raw_q();
        assert_eq!(cq2, st.conv_q);
        assert_eq!(s2, st.ssm);
    }

    #[test]
    fn quantized_state_shrinks_conv_bytes() {
        let tier = tiny_tier();
        let f = MambaState::new(&tier, 1);
        let q = MambaState::new_quantized(&tier, 1);
        let cpl = (tier.d_conv - 1) * tier.d_inner;
        assert_eq!(f.bytes_per_lane() - q.bytes_per_lane(), tier.n_layer * 3 * cpl);
    }

    #[test]
    fn batched_step_matches_individual_lanes() {
        // stepping B lanes at once == stepping each alone (lane math is
        // independent; batching only amortizes the weight traversal)
        let tier = tiny_tier();
        let model = MambaModel::synthetic(tier.clone(), 21);
        let prompts: [&[u16]; 3] = [&[1, 2, 3], &[4, 5], &[6, 7, 8, 9]];
        let mut singles = Vec::new();
        for p in prompts {
            let mut st = MambaState::new(&tier, 1);
            model.prefill(p, &mut st);
            singles.push(st);
        }
        // pack into one B=3 state
        let mut packed = MambaState::new(&tier, 3);
        for (bi, st) in singles.iter_mut().enumerate() {
            for li in 0..tier.n_layer {
                packed.conv_lane(li, bi).copy_from_slice(st.conv_lane(li, 0));
                packed.ssm_lane(li, bi).copy_from_slice(st.ssm_lane(li, 0));
            }
        }
        let toks = [3u16, 5, 9];
        let batched = model.step(&toks, &mut packed);
        let v = tier.vocab;
        for (bi, st) in singles.iter_mut().enumerate() {
            let alone = model.step(&toks[bi..bi + 1], st);
            for (a, b) in alone.iter().zip(&batched[bi * v..(bi + 1) * v]) {
                assert!((a - b).abs() < 1e-6, "lane {bi}: {a} vs {b}");
            }
            for li in 0..tier.n_layer {
                let (pl, sl) = (packed.conv_lane(li, bi).to_vec(), st.conv_lane(li, 0).to_vec());
                assert_eq!(pl, sl, "conv state diverged lane {bi} layer {li}");
            }
        }
    }

    #[test]
    fn calibration_records_every_site() {
        let tier = tiny_tier();
        let model = MambaModel::synthetic(tier.clone(), 4);
        let tokens: Vec<u16> = (0..32u16).map(|i| i % tier.vocab as u16).collect();
        let rec = model.calibrate(&tokens);
        assert_eq!(rec.layers.len(), tier.n_layer);
        assert!(rec.head_in_amax > 0.0);
        for lc in &rec.layers {
            assert!(lc.x_in_amax > 0.0);
            assert!(lc.conv_in_amax > 0.0);
            // under the reservoir cap the sample IS the full stream
            assert_eq!(lc.x_ssm.seen(), (tokens.len() * tier.d_inner) as u64);
            assert_eq!(lc.x_ssm.values().len(), tokens.len() * tier.d_inner);
            assert!(lc.b_amax > 0.0 && lc.c_amax > 0.0 && lc.dt_low_amax > 0.0);
            assert!(lc.gated_h_amax > 0.0);
        }
    }
}
