//! Stateful decode for the native (artifact-free) serving backend:
//! a batched per-request recurrent state ([`MambaState`]), the
//! [`StepModel`] trait the coordinator serves from, and the fp32
//! implementation for [`MambaModel`].
//!
//! The state layout is exactly the coordinator pool's raw batched
//! layout — conv (L, B, W−1, d_inner) and ssm (L, B, d_inner, N), both
//! flattened row-major — so `SsmStatePool::gather_raw` output can be
//! stepped directly and scattered back without reshaping. The layer
//! math is the shared `pub(crate)` helper set in [`super::mamba`] plus
//! [`super::scan::selective_scan`] with T = 1, so a prefill followed
//! by steps reproduces the full-sequence `forward` exactly (see
//! `rust/tests/native_decode.rs`).

use super::mamba::{
    causal_conv_silu, matmul, rmsnorm, silu, softplus, take_cols, MambaModel, MambaTier,
};
use super::scan::{selective_scan, ScanParams};
use crate::quant;

/// Recurrent decode state for `b` sequences advancing in lockstep.
pub struct MambaState {
    pub b: usize,
    n_layer: usize,
    conv_per_layer: usize, // (W-1) * d_inner
    ssm_per_layer: usize,  // d_inner * N
    /// (L, B, W−1, d_inner) flattened: the last W−1 conv inputs per
    /// layer per lane, oldest row first
    pub conv: Vec<f32>,
    /// (L, B, d_inner, N) flattened recurrent state
    pub ssm: Vec<f32>,
}

impl MambaState {
    pub fn new(tier: &MambaTier, b: usize) -> MambaState {
        assert!(b > 0, "state needs at least one lane");
        let cpl = (tier.d_conv - 1) * tier.d_inner;
        let spl = tier.d_inner * tier.d_state;
        MambaState {
            b,
            n_layer: tier.n_layer,
            conv_per_layer: cpl,
            ssm_per_layer: spl,
            conv: vec![0.0; tier.n_layer * b * cpl],
            ssm: vec![0.0; tier.n_layer * b * spl],
        }
    }

    /// Wrap raw batched buffers (the `SsmStatePool::gather_raw` layout).
    pub fn from_raw(tier: &MambaTier, b: usize, conv: Vec<f32>, ssm: Vec<f32>) -> MambaState {
        let cpl = (tier.d_conv - 1) * tier.d_inner;
        let spl = tier.d_inner * tier.d_state;
        assert_eq!(conv.len(), tier.n_layer * b * cpl, "conv buffer shape mismatch");
        assert_eq!(ssm.len(), tier.n_layer * b * spl, "ssm buffer shape mismatch");
        MambaState { b, n_layer: tier.n_layer, conv_per_layer: cpl, ssm_per_layer: spl, conv, ssm }
    }

    /// Back to the raw buffers for `SsmStatePool::scatter_raw`.
    pub fn into_raw(self) -> (Vec<f32>, Vec<f32>) {
        (self.conv, self.ssm)
    }

    pub fn reset(&mut self) {
        self.conv.fill(0.0);
        self.ssm.fill(0.0);
    }

    /// Per-request state bytes (constant in context length).
    pub fn bytes_per_lane(&self) -> usize {
        4 * self.n_layer * (self.conv_per_layer + self.ssm_per_layer)
    }

    pub(crate) fn conv_lane(&mut self, li: usize, bi: usize) -> &mut [f32] {
        let cpl = self.conv_per_layer;
        let off = (li * self.b + bi) * cpl;
        &mut self.conv[off..off + cpl]
    }

    pub(crate) fn ssm_lane(&mut self, li: usize, bi: usize) -> &mut [f32] {
        let spl = self.ssm_per_layer;
        let off = (li * self.b + bi) * spl;
        &mut self.ssm[off..off + spl]
    }
}

/// A model the native engine can serve: full-sequence prompt ingestion
/// plus a batched single-token step. Implemented by the fp32
/// [`MambaModel`] and the W8A8 [`super::qmamba::QuantizedMambaModel`].
pub trait StepModel {
    fn tier(&self) -> &MambaTier;

    /// Consume a prompt into a fresh B=1 `state`. Returns (T × V)
    /// logits (row t conditions on tokens[..=t]).
    fn prefill(&self, tokens: &[u16], state: &mut MambaState) -> Vec<f32>;

    /// Advance all `state.b` lanes by one token each (`tokens[bi]` is
    /// lane bi's input). Returns (B × V) next-token logits.
    fn step(&self, tokens: &[u16], state: &mut MambaState) -> Vec<f32>;
}

/// Per-layer activation ranges recorded by a calibration prefill —
/// everything the W8A8 quantizer needs (paper §4.2 / §5.1).
#[derive(Debug, Clone, Default)]
pub struct LayerCalib {
    /// |rmsnorm output| max — the in_proj input scale
    pub x_in_amax: f32,
    /// |conv input| max
    pub conv_in_amax: f32,
    /// raw SSM-input samples (percentile clip applied by the quantizer)
    pub x_ssm_vals: Vec<f32>,
    pub dt_low_amax: f32,
    pub b_amax: f32,
    pub c_amax: f32,
    /// |H·gated| max — the rotated-space out_proj input scale (§3.3)
    pub gated_h_amax: f32,
}

/// Whole-model calibration record.
#[derive(Debug, Clone, Default)]
pub struct CalibRecord {
    pub layers: Vec<LayerCalib>,
    /// |final rmsnorm output| max — the tied-head input scale
    pub head_in_amax: f32,
}

impl MambaModel {
    /// fp32 calibration pass: one prefill over `tokens` recording the
    /// activation ranges for [`super::qmamba::QuantizedMambaModel`].
    pub fn calibrate(&self, tokens: &[u16]) -> CalibRecord {
        let mut rec = CalibRecord {
            layers: vec![LayerCalib::default(); self.tier.n_layer],
            head_in_amax: 0.0,
        };
        let mut state = MambaState::new(&self.tier, 1);
        let _ = self.prefill_impl(tokens, &mut state, Some(&mut rec));
        rec
    }

    /// Full-sequence prefill with carried state; optionally records
    /// calibration statistics. Shared by `StepModel::prefill` and
    /// [`Self::calibrate`].
    fn prefill_impl(
        &self,
        tokens: &[u16],
        state: &mut MambaState,
        mut calib: Option<&mut CalibRecord>,
    ) -> Vec<f32> {
        assert_eq!(state.b, 1, "prefill is single-sequence; step() handles batched decode");
        assert!(!tokens.is_empty(), "prefill needs at least one token");
        state.reset();
        let t = &self.tier;
        let (d, di, n, r, w, tl) =
            (t.d_model, t.d_inner, t.d_state, t.dt_rank, t.d_conv, tokens.len());
        let mut resid = vec![0.0f32; tl * d];
        for (i, &tok) in tokens.iter().enumerate() {
            resid[i * d..(i + 1) * d]
                .copy_from_slice(&self.embedding[tok as usize * d..(tok as usize + 1) * d]);
        }
        let mut x_in = vec![0.0f32; tl * d];
        let mut xz = vec![0.0f32; tl * 2 * di];
        let mut bcdt = vec![0.0f32; tl * (r + 2 * n)];
        let mut out = vec![0.0f32; tl * d];
        for (li, layer) in self.layers.iter().enumerate() {
            rmsnorm(&resid, &layer.norm, d, 1e-5, &mut x_in);
            matmul(&x_in, &layer.in_proj, tl, d, 2 * di, &mut xz);
            let x = take_cols(&xz, tl, 2 * di, 0, di);
            let z = take_cols(&xz, tl, 2 * di, di, 2 * di);
            let gx = &self.g_x[li * di..(li + 1) * di];
            let mut xs = vec![0.0f32; tl * di];
            causal_conv_silu(
                &x,
                Some(state.conv_lane(li, 0)),
                &layer.conv_w,
                &layer.conv_b,
                gx,
                tl,
                di,
                w,
                &mut xs,
            );
            matmul(&xs, &layer.x_proj, tl, di, r + 2 * n, &mut bcdt);
            let dt_low = take_cols(&bcdt, tl, r + 2 * n, 0, r);
            let bmat = take_cols(&bcdt, tl, r + 2 * n, r, r + n);
            let cmat = take_cols(&bcdt, tl, r + 2 * n, r + n, r + 2 * n);
            let mut dt = vec![0.0f32; tl * di];
            matmul(&dt_low, &layer.dt_proj, tl, r, di, &mut dt);
            for ti in 0..tl {
                for ch in 0..di {
                    dt[ti * di + ch] = softplus(dt[ti * di + ch] + layer.dt_bias[ch]);
                }
            }
            let p = ScanParams { a: &layer.a, d: &layer.d, d_inner: di, n_state: n };
            let y = selective_scan(&p, &xs, &dt, &bmat, &cmat, state.ssm_lane(li, 0));
            let gy = &self.g_y[li * di..(li + 1) * di];
            let mut gated = vec![0.0f32; tl * di];
            for ti in 0..tl {
                for ch in 0..di {
                    gated[ti * di + ch] = y[ti * di + ch] * silu(z[ti * di + ch]) * gy[ch];
                }
            }
            if let Some(rec) = calib.as_deref_mut() {
                let lc = &mut rec.layers[li];
                lc.x_in_amax = lc.x_in_amax.max(quant::amax(&x_in));
                lc.conv_in_amax = lc.conv_in_amax.max(quant::amax(&x));
                lc.x_ssm_vals.extend_from_slice(&xs);
                lc.dt_low_amax = lc.dt_low_amax.max(quant::amax(&dt_low));
                lc.b_amax = lc.b_amax.max(quant::amax(&bmat));
                lc.c_amax = lc.c_amax.max(quant::amax(&cmat));
                let mut gh = gated.clone();
                crate::quant::hadamard::fwht_rows(&mut gh, di);
                lc.gated_h_amax = lc.gated_h_amax.max(quant::amax(&gh));
            }
            matmul(&gated, &layer.out_proj, tl, di, d, &mut out);
            for i in 0..resid.len() {
                resid[i] += out[i];
            }
        }
        let fin = self.final_hidden(&resid, tl);
        if let Some(rec) = calib.as_deref_mut() {
            rec.head_in_amax = rec.head_in_amax.max(quant::amax(&fin));
        }
        self.tied_logits(&fin, tl)
    }
}

impl StepModel for MambaModel {
    fn tier(&self) -> &MambaTier {
        &self.tier
    }

    fn prefill(&self, tokens: &[u16], state: &mut MambaState) -> Vec<f32> {
        self.prefill_impl(tokens, state, None)
    }

    fn step(&self, tokens: &[u16], state: &mut MambaState) -> Vec<f32> {
        let t = &self.tier;
        let (d, di, n, r, w) = (t.d_model, t.d_inner, t.d_state, t.dt_rank, t.d_conv);
        let b = state.b;
        assert_eq!(tokens.len(), b, "one input token per state lane");
        let mut resid = vec![0.0f32; b * d];
        for (bi, &tok) in tokens.iter().enumerate() {
            resid[bi * d..(bi + 1) * d]
                .copy_from_slice(&self.embedding[tok as usize * d..(tok as usize + 1) * d]);
        }
        let mut x_in = vec![0.0f32; b * d];
        let mut xz = vec![0.0f32; b * 2 * di];
        let mut bcdt = vec![0.0f32; b * (r + 2 * n)];
        let mut out = vec![0.0f32; b * d];
        for (li, layer) in self.layers.iter().enumerate() {
            rmsnorm(&resid, &layer.norm, d, 1e-5, &mut x_in);
            matmul(&x_in, &layer.in_proj, b, d, 2 * di, &mut xz);
            let x = take_cols(&xz, b, 2 * di, 0, di);
            let z = take_cols(&xz, b, 2 * di, di, 2 * di);
            let gx = &self.g_x[li * di..(li + 1) * di];
            let mut xs = vec![0.0f32; b * di];
            for bi in 0..b {
                causal_conv_silu(
                    &x[bi * di..(bi + 1) * di],
                    Some(state.conv_lane(li, bi)),
                    &layer.conv_w,
                    &layer.conv_b,
                    gx,
                    1,
                    di,
                    w,
                    &mut xs[bi * di..(bi + 1) * di],
                );
            }
            matmul(&xs, &layer.x_proj, b, di, r + 2 * n, &mut bcdt);
            let dt_low = take_cols(&bcdt, b, r + 2 * n, 0, r);
            let bmat = take_cols(&bcdt, b, r + 2 * n, r, r + n);
            let cmat = take_cols(&bcdt, b, r + 2 * n, r + n, r + 2 * n);
            let mut dt = vec![0.0f32; b * di];
            matmul(&dt_low, &layer.dt_proj, b, r, di, &mut dt);
            for bi in 0..b {
                for ch in 0..di {
                    dt[bi * di + ch] = softplus(dt[bi * di + ch] + layer.dt_bias[ch]);
                }
            }
            let p = ScanParams { a: &layer.a, d: &layer.d, d_inner: di, n_state: n };
            let gy = &self.g_y[li * di..(li + 1) * di];
            let mut gated = vec![0.0f32; b * di];
            for bi in 0..b {
                let y = selective_scan(
                    &p,
                    &xs[bi * di..(bi + 1) * di],
                    &dt[bi * di..(bi + 1) * di],
                    &bmat[bi * n..(bi + 1) * n],
                    &cmat[bi * n..(bi + 1) * n],
                    state.ssm_lane(li, bi),
                );
                for ch in 0..di {
                    gated[bi * di + ch] = y[ch] * silu(z[bi * di + ch]) * gy[ch];
                }
            }
            matmul(&gated, &layer.out_proj, b, di, d, &mut out);
            for i in 0..resid.len() {
                resid[i] += out[i];
            }
        }
        let fin = self.final_hidden(&resid, b);
        self.tied_logits(&fin, b)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_tier() -> MambaTier {
        MambaTier {
            name: "tiny".into(),
            d_model: 8,
            n_layer: 2,
            d_state: 4,
            d_conv: 4,
            d_inner: 16,
            dt_rank: 2,
            vocab: 16,
        }
    }

    #[test]
    fn state_layout_roundtrips_raw() {
        let tier = tiny_tier();
        let mut st = MambaState::new(&tier, 3);
        st.conv.iter_mut().enumerate().for_each(|(i, v)| *v = i as f32);
        st.ssm.iter_mut().enumerate().for_each(|(i, v)| *v = -(i as f32));
        let (c, s) = (st.conv.clone(), st.ssm.clone());
        let st2 = MambaState::from_raw(&tier, 3, c, s);
        let (c2, s2) = st2.into_raw();
        assert_eq!(c2, st.conv);
        assert_eq!(s2, st.ssm);
    }

    #[test]
    fn batched_step_matches_individual_lanes() {
        // stepping B lanes at once == stepping each alone (lane math is
        // independent; batching only amortizes the weight traversal)
        let tier = tiny_tier();
        let model = MambaModel::synthetic(tier.clone(), 21);
        let prompts: [&[u16]; 3] = [&[1, 2, 3], &[4, 5], &[6, 7, 8, 9]];
        let mut singles = Vec::new();
        for p in prompts {
            let mut st = MambaState::new(&tier, 1);
            model.prefill(p, &mut st);
            singles.push(st);
        }
        // pack into one B=3 state
        let mut packed = MambaState::new(&tier, 3);
        for (bi, st) in singles.iter_mut().enumerate() {
            for li in 0..tier.n_layer {
                packed.conv_lane(li, bi).copy_from_slice(st.conv_lane(li, 0));
                packed.ssm_lane(li, bi).copy_from_slice(st.ssm_lane(li, 0));
            }
        }
        let toks = [3u16, 5, 9];
        let batched = model.step(&toks, &mut packed);
        let v = tier.vocab;
        for (bi, st) in singles.iter_mut().enumerate() {
            let alone = model.step(&toks[bi..bi + 1], st);
            for (a, b) in alone.iter().zip(&batched[bi * v..(bi + 1) * v]) {
                assert!((a - b).abs() < 1e-6, "lane {bi}: {a} vs {b}");
            }
            for li in 0..tier.n_layer {
                let (pl, sl) = (packed.conv_lane(li, bi).to_vec(), st.conv_lane(li, 0).to_vec());
                assert_eq!(pl, sl, "conv state diverged lane {bi} layer {li}");
            }
        }
    }

    #[test]
    fn calibration_records_every_site() {
        let tier = tiny_tier();
        let model = MambaModel::synthetic(tier.clone(), 4);
        let tokens: Vec<u16> = (0..32u16).map(|i| i % tier.vocab as u16).collect();
        let rec = model.calibrate(&tokens);
        assert_eq!(rec.layers.len(), tier.n_layer);
        assert!(rec.head_in_amax > 0.0);
        for lc in &rec.layers {
            assert!(lc.x_in_amax > 0.0);
            assert!(lc.conv_in_amax > 0.0);
            assert_eq!(lc.x_ssm_vals.len(), tokens.len() * tier.d_inner);
            assert!(lc.b_amax > 0.0 && lc.c_amax > 0.0 && lc.dt_low_amax > 0.0);
            assert!(lc.gated_h_amax > 0.0);
        }
    }
}
