//! Reference selective scan (paper Eq. 1, discretized), fp32 and
//! quantized — semantics identical to the Pallas kernels
//! (`python/compile/kernels/selective_scan.py`) and to
//! `kernels/ref.py::selective_scan`.
//!
//! The quantized scan's per-step int8 work goes through the
//! [`Kernels`] dispatch layer ([`selective_scan_q_into_with`]): each
//! time-step's B/C code rows are dequantized **once** into stack
//! buffers via [`Kernels::dequant_i8`] (SIMD lanes, exact per-element
//! multiply) instead of `d_inner × n` times inside the channel loop.
//! The f32 recurrence itself stays in fixed scalar order so every
//! backend produces bit-identical states and outputs.

use crate::quant::{dq_i8, Kernels};

/// Dimensions + parameters of one scan invocation (single sequence).
/// Layout: time-major slices over `d_inner` channels and `n` states.
pub struct ScanParams<'a> {
    /// A (d_inner × n), negative reals (state decay)
    pub a: &'a [f32],
    /// D (d_inner), skip gain
    pub d: &'a [f32],
    pub d_inner: usize,
    pub n_state: usize,
}

/// fp32 selective scan for one sequence.
///
/// x, dt: (T × d_inner) time-major; b, c: (T × n); h0: (d_inner × n),
/// updated in place to the final state. Returns y (T × d_inner):
/// y[t] = C_t · h_t + D ⊙ x_t with h_t = exp(Δ_t A) ⊙ h_{t-1} + Δ_t B_t x_t.
pub fn selective_scan(
    p: &ScanParams,
    x: &[f32],
    dt: &[f32],
    b: &[f32],
    c: &[f32],
    h: &mut [f32],
) -> Vec<f32> {
    let mut y = vec![0.0f32; x.len()];
    selective_scan_into(p, x, dt, b, c, h, &mut y);
    y
}

/// [`selective_scan`] writing y into a caller-owned (T × d_inner)
/// slice — the zero-alloc decode hot path.
pub fn selective_scan_into(
    p: &ScanParams,
    x: &[f32],
    dt: &[f32],
    b: &[f32],
    c: &[f32],
    h: &mut [f32],
    y: &mut [f32],
) {
    let (di, n) = (p.d_inner, p.n_state);
    let t_len = x.len() / di;
    assert_eq!(x.len(), t_len * di, "x length must be a multiple of d_inner");
    assert_eq!(dt.len(), t_len * di, "dt must match x (T × d_inner)");
    assert_eq!(b.len(), t_len * n, "B must be T × n_state");
    assert_eq!(c.len(), t_len * n, "C must be T × n_state");
    assert_eq!(p.a.len(), di * n, "A must be d_inner × n_state");
    assert_eq!(p.d.len(), di, "D must be d_inner");
    assert_eq!(h.len(), di * n, "h must be d_inner × n_state");
    assert_eq!(y.len(), t_len * di, "y must match x (T × d_inner)");
    for t in 0..t_len {
        let xt = &x[t * di..(t + 1) * di];
        let dtt = &dt[t * di..(t + 1) * di];
        let bt = &b[t * n..(t + 1) * n];
        let ct = &c[t * n..(t + 1) * n];
        for ch in 0..di {
            let hrow = &mut h[ch * n..(ch + 1) * n];
            let arow = &p.a[ch * n..(ch + 1) * n];
            let dtx = dtt[ch] * xt[ch];
            let mut acc = 0.0f32;
            for s in 0..n {
                let da = (dtt[ch] * arow[s]).exp();
                hrow[s] = da * hrow[s] + dtx * bt[s];
                acc += hrow[s] * ct[s];
            }
            y[t * di + ch] = acc + p.d[ch] * xt[ch];
        }
    }
}

/// Quantized selective scan (paper §4.2): int8 activations (x, B, C)
/// and weights (A, D) with static scales; recurrence in f32; f32 out.
/// Matches `ref.selective_scan_q`.
#[allow(clippy::too_many_arguments)]
pub fn selective_scan_q(
    d_inner: usize,
    n_state: usize,
    x_q: &[i8],
    s_x: f32,
    dt: &[f32],
    a_q: &[i8],
    s_a: f32,
    b_q: &[i8],
    s_b: f32,
    c_q: &[i8],
    s_c: f32,
    d_q: &[i8],
    s_d: f32,
    h: &mut [f32],
) -> Vec<f32> {
    let mut y = vec![0.0f32; x_q.len()];
    selective_scan_q_into(
        d_inner, n_state, x_q, s_x, dt, a_q, s_a, b_q, s_b, c_q, s_c, d_q, s_d, h, &mut y,
    );
    y
}

/// [`selective_scan_q`] writing y into a caller-owned (T × d_inner)
/// slice on the auto-selected kernel backend — the zero-alloc W8A8
/// decode hot path. See [`selective_scan_q_into_with`].
#[allow(clippy::too_many_arguments)]
pub fn selective_scan_q_into(
    d_inner: usize,
    n_state: usize,
    x_q: &[i8],
    s_x: f32,
    dt: &[f32],
    a_q: &[i8],
    s_a: f32,
    b_q: &[i8],
    s_b: f32,
    c_q: &[i8],
    s_c: f32,
    d_q: &[i8],
    s_d: f32,
    h: &mut [f32],
    y: &mut [f32],
) {
    selective_scan_q_into_with(
        Kernels::auto(),
        d_inner,
        n_state,
        x_q,
        s_x,
        dt,
        a_q,
        s_a,
        b_q,
        s_b,
        c_q,
        s_c,
        d_q,
        s_d,
        h,
        y,
    )
}

/// Stack-buffer bound for the per-step dequantized B/C rows: states
/// up to this size take the kernel-dispatched fast path (the paper's
/// models use n = 16); larger n falls back to in-loop dequantization
/// with identical numerics.
pub const SCAN_N_MAX: usize = 128;

/// [`selective_scan_q_into`] on an explicit kernel backend: per
/// time-step, B_t and C_t are dequantized once through
/// [`Kernels::dequant_i8`] (instead of per channel), then the f32
/// recurrence runs in fixed scalar order — outputs and final state
/// are **bit-identical** across backends and to the pre-dispatch
/// implementation.
#[allow(clippy::too_many_arguments)]
pub fn selective_scan_q_into_with(
    kers: Kernels,
    d_inner: usize,
    n_state: usize,
    x_q: &[i8],
    s_x: f32,
    dt: &[f32],
    a_q: &[i8],
    s_a: f32,
    b_q: &[i8],
    s_b: f32,
    c_q: &[i8],
    s_c: f32,
    d_q: &[i8],
    s_d: f32,
    h: &mut [f32],
    y: &mut [f32],
) {
    let (di, n) = (d_inner, n_state);
    let t_len = x_q.len() / di;
    // the same shape guards as `selective_scan`: malformed inputs must
    // panic, not silently truncate the scan
    assert_eq!(x_q.len(), t_len * di, "x_q length must be a multiple of d_inner");
    assert_eq!(dt.len(), t_len * di, "dt must match x_q (T × d_inner)");
    assert_eq!(b_q.len(), t_len * n, "B_q must be T × n_state");
    assert_eq!(c_q.len(), t_len * n, "C_q must be T × n_state");
    assert_eq!(a_q.len(), di * n, "A_q must be d_inner × n_state");
    assert_eq!(d_q.len(), di, "D_q must be d_inner");
    assert_eq!(h.len(), di * n, "h must be d_inner × n_state");
    assert_eq!(y.len(), t_len * di, "y must match x_q (T × d_inner)");
    // Accumulator-headroom guard: today's recurrence is f32 (no i32
    // accumulator to wrap), but the planned low-bit integer scan will
    // fold one i8·i8 product per state into i32 — hold n_state to the
    // same proven bound as the GEMM/conv K dims now, so every int8
    // kernel entry point shares one shape contract (quamba_audit
    // cross-checks MambaTier/bench shapes against the same constant).
    debug_assert!(
        n <= crate::quant::MAX_SAFE_K,
        "n_state = {n} exceeds MAX_SAFE_K = {}",
        crate::quant::MAX_SAFE_K
    );
    if n <= SCAN_N_MAX {
        // fast path: per-step kernel dequant of the B/C code rows into
        // stack buffers (zero heap traffic), shared by all di channels
        let mut bf = [0.0f32; SCAN_N_MAX];
        let mut cf = [0.0f32; SCAN_N_MAX];
        for t in 0..t_len {
            kers.dequant_i8(&b_q[t * n..(t + 1) * n], s_b, &mut bf[..n]);
            kers.dequant_i8(&c_q[t * n..(t + 1) * n], s_c, &mut cf[..n]);
            for ch in 0..di {
                let x = dq_i8(x_q[t * di + ch], s_x);
                let dtv = dt[t * di + ch];
                let dtx = dtv * x;
                let hrow = &mut h[ch * n..(ch + 1) * n];
                let arow = &a_q[ch * n..(ch + 1) * n];
                let mut acc = 0.0f32;
                for s in 0..n {
                    let a = dq_i8(arow[s], s_a);
                    let da = (dtv * a).exp();
                    hrow[s] = da * hrow[s] + dtx * bf[s];
                    acc += hrow[s] * cf[s];
                }
                y[t * di + ch] = acc + dq_i8(d_q[ch], s_d) * x;
            }
        }
    } else {
        // oversize-state fallback: dequantize inline (same values,
        // same op order — bit-identical to the fast path)
        for t in 0..t_len {
            for ch in 0..di {
                let x = dq_i8(x_q[t * di + ch], s_x);
                let dtv = dt[t * di + ch];
                let dtx = dtv * x;
                let hrow = &mut h[ch * n..(ch + 1) * n];
                let arow = &a_q[ch * n..(ch + 1) * n];
                let mut acc = 0.0f32;
                for s in 0..n {
                    let a = dq_i8(arow[s], s_a);
                    let bq = dq_i8(b_q[t * n + s], s_b);
                    let cq = dq_i8(c_q[t * n + s], s_c);
                    let da = (dtv * a).exp();
                    hrow[s] = da * hrow[s] + dtx * bq;
                    acc += hrow[s] * cq;
                }
                y[t * di + ch] = acc + dq_i8(d_q[ch], s_d) * x;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    fn setup(di: usize, n: usize, t: usize, seed: u64) -> (Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>) {
        let mut r = Pcg32::new(seed);
        let a: Vec<f32> = (0..di * n).map(|_| -(r.f32() + 0.5)).collect();
        let d: Vec<f32> = (0..di).map(|_| r.normal()).collect();
        let x: Vec<f32> = (0..t * di).map(|_| r.normal()).collect();
        let dt: Vec<f32> = (0..t * di).map(|_| 0.01 + 0.1 * r.f32()).collect();
        let b: Vec<f32> = (0..t * n).map(|_| r.normal()).collect();
        let c: Vec<f32> = (0..t * n).map(|_| r.normal()).collect();
        (a, d, x, dt, b, c)
    }

    #[test]
    fn zero_input_zero_output() {
        let (a, d, _, dt, b, c) = setup(4, 3, 8, 1);
        let p = ScanParams { a: &a, d: &d, d_inner: 4, n_state: 3 };
        let x = vec![0.0; 8 * 4];
        let mut h = vec![0.0; 4 * 3];
        let y = selective_scan(&p, &x, &dt, &b, &c, &mut h);
        assert!(y.iter().all(|v| v.abs() < 1e-7));
        assert!(h.iter().all(|v| v.abs() < 1e-7));
    }

    #[test]
    fn chunked_equals_full() {
        // scanning T then continuing == scanning 2T in one call
        let (a, d, x, dt, b, c) = setup(6, 4, 16, 2);
        let p = ScanParams { a: &a, d: &d, d_inner: 6, n_state: 4 };
        let mut h_full = vec![0.0; 6 * 4];
        let y_full = selective_scan(&p, &x, &dt, &b, &c, &mut h_full);
        let mut h_chunk = vec![0.0; 6 * 4];
        let half_x = 8 * 6;
        let half_bn = 8 * 4;
        let mut y_chunk = selective_scan(&p, &x[..half_x], &dt[..half_x], &b[..half_bn], &c[..half_bn], &mut h_chunk);
        let y2 = selective_scan(&p, &x[half_x..], &dt[half_x..], &b[half_bn..], &c[half_bn..], &mut h_chunk);
        y_chunk.extend(y2);
        for (u, v) in y_full.iter().zip(&y_chunk) {
            assert!((u - v).abs() < 1e-5);
        }
        for (u, v) in h_full.iter().zip(&h_chunk) {
            assert!((u - v).abs() < 1e-5);
        }
    }

    #[test]
    fn linearity_in_x() {
        // given fixed (Δ, B, C), y is linear in x: y(αx) = α y(x)
        let (a, d, x, dt, b, c) = setup(4, 4, 12, 3);
        let p = ScanParams { a: &a, d: &d, d_inner: 4, n_state: 4 };
        let mut h1 = vec![0.0; 16];
        let y1 = selective_scan(&p, &x, &dt, &b, &c, &mut h1);
        let x2: Vec<f32> = x.iter().map(|v| 3.0 * v).collect();
        let mut h2 = vec![0.0; 16];
        let y2 = selective_scan(&p, &x2, &dt, &b, &c, &mut h2);
        for (u, v) in y1.iter().zip(&y2) {
            assert!((3.0 * u - v).abs() < 1e-4 * (1.0 + v.abs()));
        }
    }

    #[test]
    fn quantized_matches_fp_on_grid_values() {
        // if all inputs already sit on the int8 grid, q-scan == fp-scan
        let (mut a, mut d, mut x, dt, mut b, mut c) = setup(4, 4, 10, 4);
        let s = 0.05f32;
        let snap = |v: &mut Vec<f32>| {
            for e in v.iter_mut() {
                *e = (*e / s).round().clamp(-127.0, 127.0) * s;
            }
        };
        snap(&mut a);
        snap(&mut d);
        snap(&mut x);
        snap(&mut b);
        snap(&mut c);
        let q = |v: &[f32]| -> Vec<i8> { v.iter().map(|e| (e / s).round() as i8).collect() };
        let p = ScanParams { a: &a, d: &d, d_inner: 4, n_state: 4 };
        let mut h1 = vec![0.0; 16];
        let y_fp = selective_scan(&p, &x, &dt, &b, &c, &mut h1);
        let mut h2 = vec![0.0; 16];
        let y_q = selective_scan_q(4, 4, &q(&x), s, &dt, &q(&a), s, &q(&b), s, &q(&c), s, &q(&d), s, &mut h2);
        for (u, v) in y_fp.iter().zip(&y_q) {
            assert!((u - v).abs() < 1e-4, "{u} vs {v}");
        }
    }

    #[test]
    fn quantized_scan_bit_identical_across_backends_and_paths() {
        // every dispatch backend, and the oversize-state fallback path,
        // must produce bit-identical y and h
        let mut r = Pcg32::new(0x5CA7);
        for (di, n, t) in [(6usize, 4usize, 9usize), (3, 130, 4)] {
            let x_q: Vec<i8> = (0..t * di).map(|_| (r.below(255) as i32 - 127) as i8).collect();
            let dt: Vec<f32> = (0..t * di).map(|_| 0.01 + 0.1 * r.f32()).collect();
            let a_q: Vec<i8> = (0..di * n).map(|_| -(1 + r.below(100) as i32) as i8).collect();
            let b_q: Vec<i8> = (0..t * n).map(|_| (r.below(255) as i32 - 127) as i8).collect();
            let c_q: Vec<i8> = (0..t * n).map(|_| (r.below(255) as i32 - 127) as i8).collect();
            let d_q: Vec<i8> = (0..di).map(|_| (r.below(255) as i32 - 127) as i8).collect();
            let run = |kers: Kernels| {
                let mut h = vec![0.0f32; di * n];
                let mut y = vec![0.0f32; t * di];
                selective_scan_q_into_with(
                    kers, di, n, &x_q, 0.04, &dt, &a_q, 0.02, &b_q, 0.03, &c_q, 0.05, &d_q,
                    0.06, &mut h, &mut y,
                );
                (h, y)
            };
            let (h0, y0) = run(Kernels::scalar());
            for backend in Kernels::available() {
                let (h1, y1) = run(Kernels::for_backend(backend));
                for (a, b) in h0.iter().zip(&h1) {
                    assert_eq!(a.to_bits(), b.to_bits(), "{} h (di={di},n={n})", backend.label());
                }
                for (a, b) in y0.iter().zip(&y1) {
                    assert_eq!(a.to_bits(), b.to_bits(), "{} y (di={di},n={n})", backend.label());
                }
            }
        }
    }

    fn q_args(t: usize) -> (Vec<i8>, Vec<f32>, Vec<i8>, Vec<i8>, Vec<i8>, Vec<i8>) {
        // well-formed int8 inputs for a (di=4, n=4, T=t) scan
        let (di, n) = (4usize, 4usize);
        let x_q = vec![1i8; t * di];
        let dt = vec![0.1f32; t * di];
        let a_q = vec![-50i8; di * n];
        let b_q = vec![2i8; t * n];
        let c_q = vec![3i8; t * n];
        let d_q = vec![1i8; di];
        (x_q, dt, a_q, b_q, c_q, d_q)
    }

    #[test]
    #[should_panic(expected = "B_q must be T × n_state")]
    fn quantized_scan_rejects_short_b() {
        let (x_q, dt, a_q, b_q, c_q, d_q) = q_args(6);
        let mut h = vec![0.0; 16];
        let _ = selective_scan_q(
            4, 4, &x_q, 0.1, &dt, &a_q, 0.02, &b_q[..5 * 4], 0.1, &c_q, 0.1, &d_q, 0.5, &mut h,
        );
    }

    #[test]
    #[should_panic(expected = "C_q must be T × n_state")]
    fn quantized_scan_rejects_short_c() {
        let (x_q, dt, a_q, b_q, c_q, d_q) = q_args(6);
        let mut h = vec![0.0; 16];
        let _ = selective_scan_q(
            4, 4, &x_q, 0.1, &dt, &a_q, 0.02, &b_q, 0.1, &c_q[..3], 0.1, &d_q, 0.5, &mut h,
        );
    }

    #[test]
    #[should_panic(expected = "multiple of d_inner")]
    fn quantized_scan_rejects_ragged_x() {
        let (x_q, dt, a_q, b_q, c_q, d_q) = q_args(6);
        let mut h = vec![0.0; 16];
        let _ = selective_scan_q(
            4, 4, &x_q[..x_q.len() - 1], 0.1, &dt, &a_q, 0.02, &b_q, 0.1, &c_q, 0.1, &d_q, 0.5,
            &mut h,
        );
    }

    #[test]
    #[cfg(debug_assertions)]
    #[should_panic(expected = "MAX_SAFE_K")]
    fn quantized_scan_rejects_n_state_past_bound() {
        // the shared int8 shape contract: n_state past the proven
        // accumulator bound trips the debug guard (see the guard's
        // rationale in selective_scan_q_into_with)
        let n = crate::quant::MAX_SAFE_K + 1;
        let (di, t) = (1usize, 1usize);
        let x_q = vec![1i8; t * di];
        let dt = vec![0.1f32; t * di];
        let a_q = vec![-50i8; di * n];
        let b_q = vec![2i8; t * n];
        let c_q = vec![3i8; t * n];
        let d_q = vec![1i8; di];
        let mut h = vec![0.0f32; di * n];
        let mut y = vec![0.0f32; t * di];
        selective_scan_q_into_with(
            Kernels::scalar(), di, n, &x_q, 0.1, &dt, &a_q, 0.02, &b_q, 0.1, &c_q, 0.1, &d_q,
            0.5, &mut h, &mut y,
        );
    }

    #[test]
    fn state_decays_with_negative_a() {
        // with x = 0 after t0, the state decays monotonically
        let (a, d, _, _, _, _) = setup(2, 2, 1, 5);
        let p = ScanParams { a: &a, d: &d, d_inner: 2, n_state: 2 };
        let mut h = vec![1.0f32; 4];
        let t = 20;
        let x = vec![0.0f32; t * 2];
        let dt = vec![0.5f32; t * 2];
        let b = vec![0.0f32; t * 2];
        let c = vec![1.0f32; t * 2];
        let _ = selective_scan(&p, &x, &dt, &b, &c, &mut h);
        assert!(h.iter().all(|v| v.abs() < 1.0));
    }
}
