//! HiPPO materializations + the Theorem 4.1 error-bound experiment
//! (paper §A / Figure 5).
//!
//! Implements HiPPO-LegT and HiPPO-LegS (Gu et al. 2020) A/B matrices,
//! bilinear discretization, and the empirical quantization-error
//! propagation study: drive a discrete LTI SSM with N(0,1) inputs,
//! quantize the inputs to 8 bits, and measure mean |y - ȳ| per step —
//! the paper shows the error stays bounded; `benches/fig5_error_bound`
//! regenerates the curve, and tests here check the bound analytically.

use crate::util::rng::Pcg32;

/// HiPPO-LegT (translated Legendre / LMU matrices, Gu et al. 2020
/// App. B): ċ = −A c + B f with
///   A_{nk} = (2n+1) · ( 1 if n ≥ k, (−1)^{n−k} if n < k ),
///   B_n    = (2n+1) · (−1)^n.
/// Returned here pre-negated (our convention: ḣ = A h + B x).
pub fn legt(n: usize) -> (Vec<f32>, Vec<f32>) {
    let mut a = vec![0.0f32; n * n];
    let mut b = vec![0.0f32; n];
    for i in 0..n {
        let li = (2 * i + 1) as f32;
        b[i] = li * if i % 2 == 0 { 1.0 } else { -1.0 };
        for j in 0..n {
            let v = if i >= j {
                li
            } else {
                li * if (i + j) % 2 == 0 { 1.0 } else { -1.0 }
            };
            a[i * n + j] = -v;
        }
    }
    (a, b)
}

/// HiPPO-LegS (scaled Legendre): the N×N A and B (Gu et al. 2020 Eq. 2).
pub fn legs(n: usize) -> (Vec<f32>, Vec<f32>) {
    let mut a = vec![0.0f32; n * n];
    let mut b = vec![0.0f32; n];
    for i in 0..n {
        b[i] = ((2 * i + 1) as f32).sqrt();
        for j in 0..n {
            a[i * n + j] = -if i > j {
                (((2 * i + 1) as f32) * ((2 * j + 1) as f32)).sqrt()
            } else if i == j {
                (i + 1) as f32
            } else {
                0.0
            };
        }
    }
    (a, b)
}

/// Bilinear (Tustin) discretization: Ȧ = (I − Δ/2 A)⁻¹(I + Δ/2 A),
/// Ḃ = (I − Δ/2 A)⁻¹ Δ B. Uses Gauss-Jordan (n ≤ 16 here).
pub fn bilinear(a: &[f32], b: &[f32], n: usize, dt: f32) -> (Vec<f32>, Vec<f32>) {
    // M = I - dt/2 A
    let mut m = vec![0.0f64; n * n];
    for i in 0..n {
        for j in 0..n {
            m[i * n + j] = -(dt as f64) / 2.0 * a[i * n + j] as f64 + if i == j { 1.0 } else { 0.0 };
        }
    }
    let minv = invert(&m, n);
    // P = I + dt/2 A
    let mut p = vec![0.0f64; n * n];
    for i in 0..n {
        for j in 0..n {
            p[i * n + j] = (dt as f64) / 2.0 * a[i * n + j] as f64 + if i == j { 1.0 } else { 0.0 };
        }
    }
    let mut ad = vec![0.0f32; n * n];
    let mut bd = vec![0.0f32; n];
    for i in 0..n {
        for j in 0..n {
            let mut acc = 0.0f64;
            for k in 0..n {
                acc += minv[i * n + k] * p[k * n + j];
            }
            ad[i * n + j] = acc as f32;
        }
        let mut acc = 0.0f64;
        for k in 0..n {
            acc += minv[i * n + k] * (dt as f64) * b[k] as f64;
        }
        bd[i] = acc as f32;
    }
    (ad, bd)
}

fn invert(m: &[f64], n: usize) -> Vec<f64> {
    let mut a = m.to_vec();
    let mut inv = vec![0.0f64; n * n];
    for i in 0..n {
        inv[i * n + i] = 1.0;
    }
    for col in 0..n {
        // partial pivot
        let mut piv = col;
        for r in col + 1..n {
            if a[r * n + col].abs() > a[piv * n + col].abs() {
                piv = r;
            }
        }
        if piv != col {
            for j in 0..n {
                a.swap(col * n + j, piv * n + j);
                inv.swap(col * n + j, piv * n + j);
            }
        }
        let d = a[col * n + col];
        assert!(d.abs() > 1e-12, "singular matrix in bilinear discretization");
        for j in 0..n {
            a[col * n + j] /= d;
            inv[col * n + j] /= d;
        }
        for r in 0..n {
            if r != col {
                let f = a[r * n + col];
                if f != 0.0 {
                    for j in 0..n {
                        a[r * n + j] -= f * a[col * n + j];
                        inv[r * n + j] -= f * inv[col * n + j];
                    }
                }
            }
        }
    }
    inv
}

/// The Figure 5 experiment: run the discretized LTI system with clean
/// and 8-bit-quantized inputs; return mean |y[t] − ȳ[t]| per step.
///
/// n = p = q dims (paper uses 4), T total steps (paper uses 100),
/// C ~ N(0,1), x[t] ~ N(0,1).
pub struct ErrorBoundRun {
    pub per_step_err: Vec<f64>,
    pub bound: Vec<f64>,
}

pub fn error_bound_experiment(
    materialize: fn(usize) -> (Vec<f32>, Vec<f32>),
    n: usize,
    t_total: usize,
    dt: f32,
    seed: u64,
) -> ErrorBoundRun {
    let (a, b) = materialize(n);
    let (ad, bd) = bilinear(&a, &b, n, dt);
    let mut rng = Pcg32::new(seed);
    let c: Vec<f32> = (0..n * n).map(|_| rng.normal()).collect(); // q = n outputs
    let xs: Vec<f32> = (0..t_total * n).map(|_| rng.normal()).collect();
    // quantize inputs to int8 over the empirical range
    let s = crate::quant::scale_sym(crate::quant::amax(&xs), 8);
    let eps = s * 0.5;
    let mut xq = xs.clone();
    crate::quant::fake_quant_sym(&mut xq, s, 8);

    let step = |h: &mut [f32], x: &[f32]| {
        let mut nh = vec![0.0f32; n];
        for i in 0..n {
            let mut acc = 0.0f32;
            for j in 0..n {
                acc += ad[i * n + j] * h[j];
            }
            for (j, xv) in x.iter().enumerate().take(n) {
                // p = n inputs share bd per input dim (diagonal drive)
                if j == i {
                    acc += bd[i] * xv;
                }
            }
            nh[i] = acc;
        }
        h.copy_from_slice(&nh);
    };

    let mut h = vec![0.0f32; n];
    let mut hq = vec![0.0f32; n];
    let mut per_step = Vec::with_capacity(t_total);
    let b_norm = bd.iter().map(|v| v.abs() as f64).fold(0.0, f64::max);
    let mut bound = Vec::with_capacity(t_total);
    for t in 0..t_total {
        step(&mut h, &xs[t * n..(t + 1) * n]);
        step(&mut hq, &xq[t * n..(t + 1) * n]);
        let mut err = 0.0f64;
        for i in 0..n {
            // y = C h
            let mut y = 0.0f32;
            let mut yq = 0.0f32;
            for j in 0..n {
                y += c[i * n + j] * h[j];
                yq += c[i * n + j] * hq[j];
            }
            err += (y - yq).abs() as f64;
        }
        per_step.push(err / n as f64);
        // Thm 4.1-style bound: bε e^{t−T}/(e−1) (scaled to our C norm)
        let c_norm = crate::quant::amax(&c) as f64;
        let th = b_norm * eps as f64 * ((t as f64 - t_total as f64).exp()) / (std::f64::consts::E - 1.0);
        bound.push(th * c_norm * n as f64 + eps as f64 * b_norm * c_norm * n as f64);
    }
    ErrorBoundRun { per_step_err: per_step, bound }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn legs_shapes_and_signs() {
        let (a, b) = legs(4);
        assert_eq!(a.len(), 16);
        // lower-triangular negative, diagonal -(i+1)
        assert_eq!(a[0], -1.0);
        assert_eq!(a[5], -2.0);
        assert_eq!(a[1], 0.0); // upper triangle zero
        assert!(b.iter().all(|v| *v > 0.0));
    }

    #[test]
    fn bilinear_stable_legs() {
        // discretized LegS must have spectral radius < 1 (stable)
        let (a, b) = legs(4);
        let (ad, _) = bilinear(&a, &b, 4, 0.1);
        // power-iterate a few times; norms must not blow up
        let mut v = vec![1.0f32; 4];
        for _ in 0..200 {
            let mut nv = vec![0.0f32; 4];
            for i in 0..4 {
                for j in 0..4 {
                    nv[i] += ad[i * 4 + j] * v[j];
                }
            }
            v = nv;
        }
        assert!(v.iter().all(|x| x.abs() < 10.0), "unstable: {v:?}");
    }

    #[test]
    fn error_stays_bounded() {
        for mat in [legs as fn(usize) -> _, legt as fn(usize) -> _] {
            let run = error_bound_experiment(mat, 4, 100, 0.1, 42);
            let max_err = run.per_step_err.iter().cloned().fold(0.0, f64::max);
            // errors must neither be zero (quantization is real) nor
            // diverge (paper's claim: bounded for stable LTI)
            assert!(max_err > 0.0);
            let tail = &run.per_step_err[50..];
            let head = &run.per_step_err[..50];
            let tail_max = tail.iter().cloned().fold(0.0, f64::max);
            let head_max = head.iter().cloned().fold(0.0, f64::max);
            assert!(
                tail_max < head_max * 10.0 + 1e-6,
                "error grows unboundedly: head {head_max} tail {tail_max}"
            );
        }
    }
}
