//! Data loading: token streams, vocab decode, and the six-task
//! zero-shot suite (all emitted by `python/compile/aot.py`).

use std::path::Path;

use anyhow::{anyhow, Context, Result};

use crate::tensor::qtz;
use crate::util::json::{self, Json};

pub const PAD: u16 = 0;
pub const BOS: u16 = 1;
pub const EOS: u16 = 2;
pub const SEP: u16 = 3;

/// Load a token stream from a `.qtz` (tensor "tokens", u16).
pub fn load_stream(path: &Path) -> Result<Vec<u16>> {
    let f = qtz::load(path).with_context(|| format!("loading {path:?}"))?;
    let t = f
        .get("tokens")
        .ok_or_else(|| anyhow!("{path:?}: no 'tokens' tensor"))?;
    Ok(t.to_u16())
}

/// Word-level vocab for decoding generations.
pub struct Vocab {
    pub words: Vec<String>,
}

impl Vocab {
    pub fn load(path: &Path) -> Result<Vocab> {
        let text = std::fs::read_to_string(path)?;
        let j = json::parse(&text).map_err(|e| anyhow!(e))?;
        let words = j
            .get("words")
            .as_arr()
            .ok_or_else(|| anyhow!("vocab.json: no words"))?
            .iter()
            .filter_map(|w| w.as_str().map(String::from))
            .collect();
        Ok(Vocab { words })
    }

    pub fn decode(&self, ids: &[u16]) -> String {
        let mut out = Vec::new();
        for &t in ids {
            match t {
                BOS | PAD => {}
                EOS => break,
                SEP => out.push("<sep>".to_string()),
                t => {
                    let i = t as usize - 4;
                    out.push(
                        self.words
                            .get(i)
                            .cloned()
                            .unwrap_or_else(|| format!("<{t}>")),
                    );
                }
            }
        }
        out.join(" ")
    }
}

/// One zero-shot example.
#[derive(Debug, Clone)]
pub enum Example {
    /// exact-match last-token prediction (lambada-style)
    ExactLast { prompt: Vec<u16>, target: Vec<u16> },
    /// choose among continuations by (optionally length-normalized)
    /// likelihood
    Choice {
        prompt: Vec<u16>,
        choices: Vec<Vec<u16>>,
        gold: usize,
    },
}

#[derive(Debug, Clone)]
pub struct Task {
    pub name: String,
    /// "exact_last" | "choice" | "choice_norm"
    pub kind: String,
    pub examples: Vec<Example>,
}

pub fn load_tasks(path: &Path) -> Result<Vec<Task>> {
    let text = std::fs::read_to_string(path).with_context(|| format!("reading {path:?}"))?;
    let j = json::parse(&text).map_err(|e| anyhow!(e))?;
    let obj = j.as_obj().ok_or_else(|| anyhow!("tasks.json: not an object"))?;
    let toks = |v: &Json| -> Vec<u16> {
        v.as_arr()
            .map(|a| a.iter().filter_map(|x| x.as_i64().map(|n| n as u16)).collect())
            .unwrap_or_default()
    };
    let mut tasks = Vec::new();
    for (name, t) in obj {
        let kind = t.get("kind").as_str().unwrap_or("choice").to_string();
        let mut examples = Vec::new();
        if let Some(exs) = t.get("examples").as_arr() {
            for e in exs {
                if kind == "exact_last" {
                    examples.push(Example::ExactLast {
                        prompt: toks(e.get("prompt")),
                        target: toks(e.get("target")),
                    });
                } else {
                    let choices = e
                        .get("choices")
                        .as_arr()
                        .map(|a| a.iter().map(toks).collect())
                        .unwrap_or_default();
                    examples.push(Example::Choice {
                        prompt: toks(e.get("prompt")),
                        choices,
                        gold: e.get("gold").as_usize().unwrap_or(0),
                    });
                }
            }
        }
        tasks.push(Task {
            name: name.clone(),
            kind,
            examples,
        });
    }
    Ok(tasks)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_tasks_json() {
        let dir = std::env::temp_dir().join("quamba_tasks_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("tasks.json");
        std::fs::write(
            &p,
            r#"{"lambada_synth": {"kind": "exact_last",
                 "examples": [{"prompt": [1,2,3], "target": [9]}]},
                "piqa_synth": {"kind": "choice",
                 "examples": [{"prompt": [4], "choices": [[5],[6]], "gold": 1}]}}"#,
        )
        .unwrap();
        let tasks = load_tasks(&p).unwrap();
        assert_eq!(tasks.len(), 2);
        let lam = tasks.iter().find(|t| t.name == "lambada_synth").unwrap();
        match &lam.examples[0] {
            Example::ExactLast { prompt, target } => {
                assert_eq!(prompt, &vec![1, 2, 3]);
                assert_eq!(target, &vec![9]);
            }
            _ => panic!("wrong kind"),
        }
    }
}
