//! `quamba-audit` — the quantization-soundness static analysis pass.
//!
//! Checks the project invariants rustc can't (see [`quamba::audit`]):
//! unsafe confinement to the SIMD kernel module, `// SAFETY:` /
//! `#[target_feature]` discipline, accumulator-overflow K bounds on
//! every `MambaTier` literal and bench-baseline shape, scale
//! produce/consume/fold consistency, and cast hygiene.
//!
//! ```text
//! cargo run --release --bin quamba_audit            # audit this tree
//! cargo run --release --bin quamba_audit -- --root some/checkout
//! ```
//!
//! Exit status: 0 = clean, 1 = findings (printed one per line as
//! `file:line: [rule] message`), 2 = usage/environment error. CI runs
//! this as a required job (`audit` in .github/workflows/ci.yml).

use std::path::PathBuf;
use std::process::ExitCode;

use quamba::audit;

fn main() -> ExitCode {
    let mut root: Option<PathBuf> = None;
    let mut args = std::env::args().skip(1);
    while let Some(a) = args.next() {
        match a.as_str() {
            "--root" => match args.next() {
                Some(p) => root = Some(PathBuf::from(p)),
                None => {
                    eprintln!("quamba-audit: --root needs a path");
                    return ExitCode::from(2);
                }
            },
            "-h" | "--help" => {
                println!(
                    "quamba-audit: quantization-soundness static analysis\n\
                     usage: quamba_audit [--root PATH]\n\
                     PATH may be the repo root, the crate dir, or src/ itself;\n\
                     default: the first of ., .., $CARGO_MANIFEST_DIR that holds a crate."
                );
                return ExitCode::SUCCESS;
            }
            other => {
                eprintln!("quamba-audit: unknown argument `{other}` (try --help)");
                return ExitCode::from(2);
            }
        }
    }
    let root = root.or_else(default_root);
    let Some(root) = root else {
        eprintln!("quamba-audit: no crate source root found (run from the repo or pass --root)");
        return ExitCode::from(2);
    };

    match audit::audit_repo(&root) {
        Err(e) => {
            eprintln!("quamba-audit: {e}");
            ExitCode::from(2)
        }
        Ok(report) => {
            for f in &report.findings {
                println!("{f}");
            }
            println!(
                "quamba-audit: {} file(s), {} tier literal(s), {} scale(s) checked — {}",
                report.files_scanned,
                report.tiers_checked,
                report.scales_checked,
                if report.ok() {
                    "clean".to_string()
                } else {
                    format!("{} finding(s)", report.findings.len())
                }
            );
            if report.ok() {
                ExitCode::SUCCESS
            } else {
                ExitCode::FAILURE
            }
        }
    }
}

/// First of `.`, `..`, `$CARGO_MANIFEST_DIR` that resolves to a crate
/// source root — covers `cargo run` from the crate dir, from the repo
/// root, and direct binary invocation from CI.
fn default_root() -> Option<PathBuf> {
    let mut cands = vec![PathBuf::from("."), PathBuf::from("..")];
    if let Ok(md) = std::env::var("CARGO_MANIFEST_DIR") {
        cands.push(PathBuf::from(md));
    }
    cands.into_iter().find(|c| audit::find_src_root(c).is_some())
}
