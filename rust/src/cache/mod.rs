//! Prefix-sharing SSM state cache — warm-TTFT serving from O(1)
//! prompt snapshots.
//!
//! The serving argument (paper §1): a selective SSM's prompt context
//! is a **constant-size** recurrent state, so caching "everything this
//! prompt did" costs the same bytes at any prompt length — prefix
//! caching is uniquely cheap for SSMs. This module provides:
//!
//! * [`trie::TokenTrie`] — token-prefix trie with longest-prefix match
//! * [`prefix::PrefixCache`] — the byte-budgeted, LRU-evicting
//!   snapshot store both engines admit requests through
//!
//! Integration lives in `coordinator/native.rs` (true prefix reuse:
//! restore + suffix-only prefill) and `coordinator/engine.rs` (the
//! fixed-length XLA prefill can only replay exact whole-prompt hits);
//! the per-request opt-out is `SamplingParams::no_cache`. Cached-path
//! decode is **bit-identical** to cold-path decode — the cache may
//! never change tokens, only TTFT (`rust/tests/prefix_cache.rs`).

pub mod prefix;
pub mod trie;

pub use prefix::{
    CacheHit, CacheStats, PrefixCache, PrefixCacheConfig, Snapshot, ENTRY_OVERHEAD_BYTES,
    KEY_TOKEN_OVERHEAD_BYTES,
};
pub use trie::TokenTrie;
