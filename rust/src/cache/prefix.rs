//! The byte-budgeted, LRU-evicting prefix cache of SSM state
//! snapshots.
//!
//! ## Why this is cheap for SSMs (paper §1 / §2)
//!
//! A selective-SSM layer's entire prompt context after prefill is one
//! **constant-size** state: the `(d_inner × d_state)` f32 recurrent
//! h-state plus a `(d_conv−1 × d_inner)` conv window (held as i8 codes
//! for the W8A8 model). A full-prompt snapshot therefore costs the
//! same bytes whether the shared prefix is 10 or 10,000 tokens —
//! unlike a KV cache, whose snapshots grow O(T). Snapshot cost:
//!
//! ```text
//! bytes = n_layer · (conv_bytes · (d_conv−1) · d_inner  +  4 · d_inner · d_state)
//!         (+ 4 · vocab for end-of-prompt snapshots, which carry the
//!          last logits row so an exact-prompt hit skips prefill
//!          entirely)            conv_bytes = 1 (i8 codes) or 4 (f32)
//! ```
//!
//! The byte budget additionally charges every entry a fixed overhead
//! plus a per-key-token trie-path cost ([`ENTRY_OVERHEAD_BYTES`],
//! [`KEY_TOKEN_OVERHEAD_BYTES`]), so `capacity_bytes` conservatively
//! bounds real memory including the trie, not just the slabs.
//!
//! ## Replay guarantee
//!
//! The cache may never change tokens — only TTFT. That holds because
//! (a) prefill is split-anywhere bit-exact: running a prompt in
//! segments through `StepModel::prefill_resume_into` reproduces the
//! one-shot logits and final state bit-for-bit (the same property that
//! makes the stepwise prefill oracle exact), and (b) a snapshot keyed
//! by a token prefix is the deterministic state of that prefix, so
//! restoring it and prefilling only the suffix replays the cold
//! computation exactly. Both are property-tested in
//! `rust/tests/prefix_cache.rs`.

use crate::coordinator::state::SsmSlab;

use super::trie::TokenTrie;

/// Linked-list sentinel for the LRU chain.
const NIL: u32 = u32::MAX;

/// Approximate per-entry bookkeeping bytes (LRU links + slab headers)
/// charged against the budget on top of the payload.
pub const ENTRY_OVERHEAD_BYTES: usize = 96;

/// Per-key-token bytes charged for the trie path: each token of a
/// cached key may create one arena node (parent/token/entry fields +
/// child-map heap). Shared prefixes share nodes, so charging every
/// entry for its full key length makes the budget a conservative
/// *upper* bound on real trie memory — long-prompt keys cannot blow
/// past `capacity_bytes` through unbudgeted path nodes.
pub const KEY_TOKEN_OVERHEAD_BYTES: usize = 48;

#[derive(Debug, Clone)]
pub struct PrefixCacheConfig {
    /// total snapshot-byte budget; admission evicts LRU entries to fit
    pub capacity_bytes: usize,
    /// also snapshot every `stride` prompt tokens (nested-prefix
    /// reuse); 0 = end-of-prompt snapshots only
    pub snapshot_stride: usize,
}

/// One cached state: the constant-size slab, plus — for end-of-prompt
/// snapshots — the prompt's last logits row, which lets an
/// exact-prompt hit skip prefill (and the fixed-length XLA engine,
/// which cannot replay a suffix, reuse whole prompts).
pub struct Snapshot {
    pub slab: SsmSlab,
    pub logits_row: Option<Vec<f32>>,
}

impl Snapshot {
    /// Budgeted payload bytes: slab + logits row +
    /// [`ENTRY_OVERHEAD_BYTES`]. Admission additionally charges
    /// [`KEY_TOKEN_OVERHEAD_BYTES`] per key token for the trie path.
    pub fn bytes(&self) -> usize {
        self.slab.bytes()
            + self.logits_row.as_ref().map_or(0, |l| 4 * l.len())
            + ENTRY_OVERHEAD_BYTES
    }
}

/// A successful probe: the matched prefix length and owned clones of
/// the cached payload (the caller feeds them straight into a
/// `MambaState` / pool slot).
pub struct CacheHit {
    pub len: usize,
    pub slab: SsmSlab,
    /// present iff `len` covered the whole probed prompt
    pub logits_row: Option<Vec<f32>>,
}

/// Counters the serving metrics mirror (`coordinator/metrics.rs`).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct CacheStats {
    pub hits: u64,
    pub misses: u64,
    pub insertions: u64,
    pub evictions: u64,
    pub evicted_bytes: u64,
    /// prompt tokens NOT prefilled thanks to hits (the TTFT win)
    pub prefill_tokens_saved: u64,
    pub bytes_in_use: usize,
    pub entries: usize,
    pub capacity_bytes: usize,
}

impl CacheStats {
    pub fn hit_rate(&self) -> f64 {
        let n = self.hits + self.misses;
        if n == 0 {
            0.0
        } else {
            self.hits as f64 / n as f64
        }
    }
}

struct Entry {
    /// trie node this entry is parked at
    node: usize,
    bytes: usize,
    prev: u32,
    next: u32,
    slab: SsmSlab,
    logits_row: Option<Vec<f32>>,
}

pub struct PrefixCache {
    cfg: PrefixCacheConfig,
    trie: TokenTrie,
    entries: Vec<Option<Entry>>,
    free: Vec<u32>,
    /// most-recently-used entry
    head: u32,
    /// least-recently-used entry (eviction victim)
    tail: u32,
    stats: CacheStats,
}

impl PrefixCache {
    pub fn new(cfg: PrefixCacheConfig) -> PrefixCache {
        assert!(cfg.capacity_bytes > 0, "a zero-byte cache cannot admit anything");
        let stats = CacheStats { capacity_bytes: cfg.capacity_bytes, ..Default::default() };
        PrefixCache {
            cfg,
            trie: TokenTrie::new(),
            entries: Vec::new(),
            free: Vec::new(),
            head: NIL,
            tail: NIL,
            stats,
        }
    }

    pub fn config(&self) -> &PrefixCacheConfig {
        &self.cfg
    }

    pub fn stats(&self) -> CacheStats {
        self.stats
    }

    /// Longest usable cached prefix of `tokens`. A match shorter than
    /// the prompt is always usable (the engine prefills the suffix); a
    /// full-length match is usable only if the snapshot carries the
    /// last logits row (nothing is left to prefill, so the first
    /// sample must come from the cache). Hits are cloned out and
    /// refresh recency; probes count toward hit/miss stats.
    pub fn lookup(&mut self, tokens: &[u16]) -> Option<CacheHit> {
        let mut best: Option<(usize, u32)> = None;
        for (len, id) in self.trie.matches(tokens) {
            let e = self.entries[id as usize].as_ref().expect("trie points at a live entry");
            if len < tokens.len() || e.logits_row.is_some() {
                best = Some((len, id)); // matches come shallow→deep
            }
        }
        self.finish_probe(tokens.len(), best)
    }

    /// Whole-prompt probe: hit only when the full `tokens` sequence is
    /// cached **with** its logits row. This is the only reuse the
    /// fixed-length left-padded XLA prefill can replay bit-exactly —
    /// a partial prefix would need a suffix-shaped graph.
    pub fn lookup_exact(&mut self, tokens: &[u16]) -> Option<CacheHit> {
        let mut best: Option<(usize, u32)> = None;
        for (len, id) in self.trie.matches(tokens) {
            let e = self.entries[id as usize].as_ref().expect("trie points at a live entry");
            if len == tokens.len() && e.logits_row.is_some() {
                best = Some((len, id));
            }
        }
        self.finish_probe(tokens.len(), best)
    }

    fn finish_probe(&mut self, prompt_len: usize, best: Option<(usize, u32)>) -> Option<CacheHit> {
        match best {
            Some((len, id)) => {
                self.stats.hits += 1;
                self.stats.prefill_tokens_saved += len as u64;
                self.touch(id);
                let e = self.entries[id as usize].as_ref().unwrap();
                // the logits row travels ONLY on whole-prompt hits: a
                // partial match may land on some shorter prompt's
                // end-of-prompt snapshot, whose row belongs to THAT
                // prompt — surfacing it here would let a caller sample
                // a stale row instead of prefilling the suffix
                let logits_row =
                    if len == prompt_len { e.logits_row.clone() } else { None };
                Some(CacheHit { len, slab: e.slab.clone(), logits_row })
            }
            None => {
                self.stats.misses += 1;
                None
            }
        }
    }

    /// Admit a snapshot keyed by `tokens`. If the key is already
    /// cached, the existing entry is refreshed (and upgraded with the
    /// logits row if the new snapshot carries one and it didn't) —
    /// deterministic models make re-stored bytes identical, so there
    /// is nothing to overwrite. Admission evicts LRU entries until the
    /// budget fits; a snapshot larger than the whole budget is
    /// rejected outright.
    pub fn insert(&mut self, tokens: &[u16], snap: Snapshot) {
        if tokens.is_empty() {
            return;
        }
        if let Some(id) = self.trie.find(tokens).and_then(|n| self.trie.entry(n)) {
            // refresh path: recency + optional logits upgrade
            let e = self.entries[id as usize].as_mut().expect("trie points at a live entry");
            if e.logits_row.is_none() {
                if let Some(row) = snap.logits_row {
                    let extra = 4 * row.len();
                    e.logits_row = Some(row);
                    e.bytes += extra;
                    self.stats.bytes_in_use += extra;
                }
            }
            self.touch(id);
            // the upgrade may have pushed us over budget; never evict
            // the entry we just refreshed (its node holds an entry, so
            // eviction pruning can never detach it)
            while self.stats.bytes_in_use > self.cfg.capacity_bytes && self.tail != id {
                self.evict_lru();
            }
            // touch() made `id` the head, so `tail == id` means it is
            // now the only entry; if it alone exceeds the budget, give
            // the just-added row back rather than carrying a permanent
            // budget violation (the slab fit when first admitted)
            if self.stats.bytes_in_use > self.cfg.capacity_bytes {
                let e = self.entries[id as usize].as_mut().unwrap();
                if let Some(row) = e.logits_row.take() {
                    let extra = 4 * row.len();
                    e.bytes -= extra;
                    self.stats.bytes_in_use -= extra;
                }
            }
            return;
        }
        // budget charge = payload + per-entry overhead + a conservative
        // per-key-token trie-path charge (see KEY_TOKEN_OVERHEAD_BYTES)
        let bytes = snap.bytes() + tokens.len() * KEY_TOKEN_OVERHEAD_BYTES;
        if bytes > self.cfg.capacity_bytes {
            // un-admittable; nothing has been created yet
            return;
        }
        // evict BEFORE creating the key's trie path: evicting an entry
        // that shares this key's path would prune the just-created
        // (still entry-less) node out of the trie, and the new entry
        // would land on a detached, recycled node
        while self.stats.bytes_in_use + bytes > self.cfg.capacity_bytes {
            self.evict_lru();
        }
        let node = self.trie.insert_path(tokens);
        debug_assert!(self.trie.entry(node).is_none(), "refresh branch must have caught this key");
        let id = match self.free.pop() {
            Some(id) => id,
            None => {
                self.entries.push(None);
                (self.entries.len() - 1) as u32
            }
        };
        self.entries[id as usize] = Some(Entry {
            node,
            bytes,
            prev: NIL,
            next: NIL,
            slab: snap.slab,
            logits_row: snap.logits_row,
        });
        self.trie.set_entry(node, id);
        self.push_front(id);
        self.stats.bytes_in_use += bytes;
        self.stats.entries += 1;
        self.stats.insertions += 1;
    }

    fn evict_lru(&mut self) {
        let victim = self.tail;
        assert_ne!(victim, NIL, "evict called on an empty cache");
        self.detach(victim);
        let e = self.entries[victim as usize].take().expect("LRU chain points at a live entry");
        self.trie.remove_entry(e.node);
        self.free.push(victim);
        self.stats.bytes_in_use -= e.bytes;
        self.stats.entries -= 1;
        self.stats.evictions += 1;
        self.stats.evicted_bytes += e.bytes as u64;
    }

    fn touch(&mut self, id: u32) {
        if self.head == id {
            return;
        }
        self.detach(id);
        self.push_front(id);
    }

    fn detach(&mut self, id: u32) {
        let (prev, next) = {
            let e = self.entries[id as usize].as_ref().unwrap();
            (e.prev, e.next)
        };
        if prev != NIL {
            self.entries[prev as usize].as_mut().unwrap().next = next;
        } else {
            self.head = next;
        }
        if next != NIL {
            self.entries[next as usize].as_mut().unwrap().prev = prev;
        } else {
            self.tail = prev;
        }
        let e = self.entries[id as usize].as_mut().unwrap();
        e.prev = NIL;
        e.next = NIL;
    }

    fn push_front(&mut self, id: u32) {
        let old = self.head;
        {
            let e = self.entries[id as usize].as_mut().unwrap();
            e.prev = NIL;
            e.next = old;
        }
        if old != NIL {
            self.entries[old as usize].as_mut().unwrap().prev = id;
        } else {
            self.tail = id;
        }
        self.head = id;
    }

    /// Live trie node count (tests: eviction must prune paths).
    pub fn trie_nodes(&self) -> usize {
        self.trie.node_count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn slab(n: usize, fill: f32) -> SsmSlab {
        SsmSlab { conv: vec![fill; n], conv_q: Vec::new(), ssm: vec![-fill; n] }
    }

    fn snap(n: usize, fill: f32) -> Snapshot {
        Snapshot { slab: slab(n, fill), logits_row: None }
    }

    #[test]
    fn longest_prefix_match_and_full_match_rules() {
        let mut c = PrefixCache::new(PrefixCacheConfig {
            capacity_bytes: 1 << 20,
            snapshot_stride: 0,
        });
        c.insert(&[1, 2, 3], snap(4, 1.0));
        c.insert(&[1, 2, 3, 4, 5], snap(4, 2.0));
        // partial: deepest snapshot wins
        let h = c.lookup(&[1, 2, 3, 4, 5, 6]).expect("prefix hit");
        assert_eq!(h.len, 5);
        assert_eq!(h.slab.conv, vec![2.0; 4]);
        assert!(h.logits_row.is_none());
        // full-length without a logits row is unusable — the probe
        // falls back to the shallower snapshot
        assert_eq!(c.lookup(&[1, 2, 3, 4, 5]).map(|h| h.len), Some(3));
        // … but becomes usable once upgraded with one
        c.insert(
            &[1, 2, 3, 4, 5],
            Snapshot { slab: slab(4, 2.0), logits_row: Some(vec![9.0; 8]) },
        );
        let h = c.lookup(&[1, 2, 3, 4, 5]).expect("full hit after upgrade");
        assert_eq!(h.len, 5);
        assert_eq!(h.logits_row.as_deref(), Some(&[9.0f32; 8][..]));
        // a PARTIAL hit landing on that same logits-bearing key must
        // strip the row — it belongs to the shorter prompt, and the
        // caller has a suffix left to prefill
        let h = c.lookup(&[1, 2, 3, 4, 5, 6]).expect("partial hit");
        assert_eq!(h.len, 5);
        assert!(h.logits_row.is_none(), "stale logits row leaked through a partial hit");
        // no shared prefix at all
        assert!(c.lookup(&[7, 7, 7]).is_none());
        let s = c.stats();
        assert_eq!(s.hits, 4);
        assert_eq!(s.misses, 1);
        assert_eq!(s.prefill_tokens_saved, (5 + 3 + 5 + 5) as u64);
    }

    #[test]
    fn lru_eviction_respects_byte_budget_and_recency() {
        // single-token keys: one trie-path token charge per entry
        let per = snap(8, 0.0).bytes() + KEY_TOKEN_OVERHEAD_BYTES;
        let mut c = PrefixCache::new(PrefixCacheConfig {
            capacity_bytes: 2 * per,
            snapshot_stride: 0,
        });
        c.insert(&[1], snap(8, 1.0));
        c.insert(&[2], snap(8, 2.0));
        assert_eq!(c.stats().entries, 2);
        // touch [1] so [2] becomes the LRU victim
        assert!(c.lookup(&[1, 9]).is_some());
        c.insert(&[3], snap(8, 3.0));
        let s = c.stats();
        assert_eq!(s.entries, 2);
        assert_eq!(s.evictions, 1);
        assert_eq!(s.evicted_bytes, per as u64);
        assert!(s.bytes_in_use <= s.capacity_bytes);
        assert!(c.lookup(&[2, 9]).is_none(), "LRU entry [2] must be gone");
        assert!(c.lookup(&[1, 9]).is_some());
        assert!(c.lookup(&[3, 9]).is_some());
        // eviction pruned [2]'s trie path
        assert_eq!(c.trie_nodes(), 2);
    }

    #[test]
    fn oversized_snapshot_rejected() {
        let mut c = PrefixCache::new(PrefixCacheConfig {
            capacity_bytes: 64,
            snapshot_stride: 0,
        });
        c.insert(&[1, 2], snap(1024, 1.0));
        assert_eq!(c.stats().entries, 0);
        assert_eq!(c.stats().bytes_in_use, 0);
        assert_eq!(c.trie_nodes(), 0, "rejected insert must not leak trie nodes");
    }

    #[test]
    fn long_keys_charge_trie_path_bytes() {
        // a prompt whose slab fits but whose key path would dominate
        // memory must be rejected — the budget bounds the trie too
        let key: Vec<u16> = (0..1000u16).collect();
        let mut c = PrefixCache::new(PrefixCacheConfig {
            capacity_bytes: snap(8, 0.0).bytes() + 100, // << 1000 token charges
            snapshot_stride: 0,
        });
        c.insert(&key, snap(8, 1.0));
        assert_eq!(c.stats().entries, 0);
        assert_eq!(c.trie_nodes(), 0);
    }

    #[test]
    fn logits_upgrade_cannot_wedge_budget_above_capacity() {
        // entry admitted without a row; upgrading with a huge row on a
        // budget that cannot absorb it must strip the row back rather
        // than leave bytes_in_use permanently above capacity
        let base = snap(8, 0.0).bytes() + 2 * KEY_TOKEN_OVERHEAD_BYTES;
        let mut c = PrefixCache::new(PrefixCacheConfig {
            capacity_bytes: base + 16,
            snapshot_stride: 0,
        });
        c.insert(&[1, 2], snap(8, 1.0));
        assert_eq!(c.stats().entries, 1);
        c.insert(&[1, 2], Snapshot { slab: slab(8, 1.0), logits_row: Some(vec![0.0; 64]) });
        let s = c.stats();
        assert!(s.bytes_in_use <= s.capacity_bytes, "{s:?}");
        assert_eq!(s.entries, 1, "the refreshed entry itself must survive");
        // without a retained row, a full-length probe cannot hit …
        assert!(c.lookup(&[1, 2]).is_none());
        // … but the state is still there for longer prompts
        assert_eq!(c.lookup(&[1, 2, 3]).map(|h| h.len), Some(2));
    }

    #[test]
    fn exact_lookup_ignores_partial_matches() {
        let mut c = PrefixCache::new(PrefixCacheConfig {
            capacity_bytes: 1 << 20,
            snapshot_stride: 0,
        });
        c.insert(&[1, 2], Snapshot { slab: slab(4, 1.0), logits_row: Some(vec![1.0]) });
        assert!(c.lookup_exact(&[1, 2, 3]).is_none(), "prefix-only is not exact");
        assert_eq!(c.lookup_exact(&[1, 2]).map(|h| h.len), Some(2));
        let s = c.stats();
        assert_eq!((s.hits, s.misses), (1, 1));
    }
}
