//! Token-prefix trie: maps token sequences to cache-entry ids with
//! longest-prefix lookup.
//!
//! Nodes live in an arena (`Vec<Node>` + free list) with parent links,
//! so removing an entry can prune the now-useless tail of its path in
//! O(depth). Children are a `BTreeMap` — prompt branching factors are
//! tiny next to snapshot bytes, and deterministic iteration keeps the
//! whole cache replayable.

use std::collections::BTreeMap;

/// Sentinel for "no entry at this node".
const NO_ENTRY: u32 = u32::MAX;

struct Node {
    parent: usize,
    /// edge label from `parent` to this node (unused for the root)
    token: u16,
    children: BTreeMap<u16, usize>,
    /// cache-entry id parked at this node, or [`NO_ENTRY`]
    entry: u32,
}

impl Node {
    fn new(parent: usize, token: u16) -> Node {
        Node { parent, token, children: BTreeMap::new(), entry: NO_ENTRY }
    }
}

pub struct TokenTrie {
    nodes: Vec<Node>,
    free: Vec<usize>,
    /// live nodes excluding the root
    live: usize,
}

impl Default for TokenTrie {
    fn default() -> Self {
        Self::new()
    }
}

impl TokenTrie {
    pub fn new() -> TokenTrie {
        TokenTrie { nodes: vec![Node::new(0, 0)], free: Vec::new(), live: 0 }
    }

    /// Live node count (root excluded) — eviction must prune paths, so
    /// this cannot grow monotonically.
    pub fn node_count(&self) -> usize {
        self.live
    }

    /// Every `(prefix_len, entry_id)` stored along the path of
    /// `tokens`, shallowest first. The last element is the
    /// longest-prefix match.
    pub fn matches(&self, tokens: &[u16]) -> Vec<(usize, u32)> {
        let mut out = Vec::new();
        let mut cur = 0usize;
        for (i, &tok) in tokens.iter().enumerate() {
            match self.nodes[cur].children.get(&tok) {
                Some(&next) => {
                    cur = next;
                    if self.nodes[cur].entry != NO_ENTRY {
                        out.push((i + 1, self.nodes[cur].entry));
                    }
                }
                None => break,
            }
        }
        out
    }

    /// Node id spelling exactly `tokens`, if that path already exists
    /// (read-only twin of [`Self::insert_path`]).
    pub fn find(&self, tokens: &[u16]) -> Option<usize> {
        let mut cur = 0usize;
        for &tok in tokens {
            cur = *self.nodes[cur].children.get(&tok)?;
        }
        Some(cur)
    }

    /// Walk (creating as needed) the node spelling `tokens`; returns
    /// its id. `tokens` must be non-empty — the root holds no entry.
    pub fn insert_path(&mut self, tokens: &[u16]) -> usize {
        assert!(!tokens.is_empty(), "cannot key a cache entry by the empty prefix");
        let mut cur = 0usize;
        for &tok in tokens {
            cur = match self.nodes[cur].children.get(&tok) {
                Some(&next) => next,
                None => {
                    let id = match self.free.pop() {
                        Some(id) => {
                            self.nodes[id] = Node::new(cur, tok);
                            id
                        }
                        None => {
                            self.nodes.push(Node::new(cur, tok));
                            self.nodes.len() - 1
                        }
                    };
                    self.nodes[cur].children.insert(tok, id);
                    self.live += 1;
                    id
                }
            };
        }
        cur
    }

    /// Entry id at `node`, if any.
    pub fn entry(&self, node: usize) -> Option<u32> {
        let e = self.nodes[node].entry;
        if e == NO_ENTRY {
            None
        } else {
            Some(e)
        }
    }

    pub fn set_entry(&mut self, node: usize, id: u32) {
        debug_assert_ne!(id, NO_ENTRY);
        self.nodes[node].entry = id;
    }

    /// Drop the entry at `node` and prune any ancestors left with no
    /// entry and no children (the orphaned tail of this key's path).
    pub fn remove_entry(&mut self, node: usize) {
        self.nodes[node].entry = NO_ENTRY;
        let mut cur = node;
        while cur != 0
            && self.nodes[cur].entry == NO_ENTRY
            && self.nodes[cur].children.is_empty()
        {
            let parent = self.nodes[cur].parent;
            let token = self.nodes[cur].token;
            self.nodes[parent].children.remove(&token);
            self.free.push(cur);
            self.live -= 1;
            cur = parent;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn longest_prefix_and_nesting() {
        let mut t = TokenTrie::new();
        let a = t.insert_path(&[1, 2, 3]);
        let b = t.insert_path(&[1, 2, 3, 4, 5]);
        t.set_entry(a, 10);
        t.set_entry(b, 11);
        assert_eq!(t.matches(&[1, 2, 3, 4, 5, 6]), vec![(3, 10), (5, 11)]);
        assert_eq!(t.matches(&[1, 2, 3, 9]), vec![(3, 10)]);
        assert_eq!(t.matches(&[1, 2]), vec![]);
        assert_eq!(t.matches(&[7, 7]), vec![]);
        assert_eq!(t.node_count(), 5);
    }

    #[test]
    fn shared_prefix_paths_share_nodes() {
        let mut t = TokenTrie::new();
        t.insert_path(&[5, 6, 7]);
        t.insert_path(&[5, 6, 8]);
        // 5,6 shared; 7 and 8 split
        assert_eq!(t.node_count(), 4);
        // re-inserting an existing path allocates nothing
        t.insert_path(&[5, 6, 7]);
        assert_eq!(t.node_count(), 4);
    }

    #[test]
    fn remove_prunes_orphaned_tail_only() {
        let mut t = TokenTrie::new();
        let shallow = t.insert_path(&[1, 2]);
        let deep = t.insert_path(&[1, 2, 3, 4]);
        t.set_entry(shallow, 0);
        t.set_entry(deep, 1);
        t.remove_entry(deep);
        // nodes 3,4 pruned; [1,2] survives (has an entry)
        assert_eq!(t.node_count(), 2);
        assert_eq!(t.matches(&[1, 2, 3, 4]), vec![(2, 0)]);
        t.remove_entry(shallow);
        assert_eq!(t.node_count(), 0);
        // arena slots are reused
        let n = t.insert_path(&[9]);
        t.set_entry(n, 2);
        assert_eq!(t.matches(&[9]), vec![(1, 2)]);
    }
}
