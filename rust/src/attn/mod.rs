//! Pure-rust Transformer reference (the Pythia-like comparator) — the
//! self-attention half of the Figure 2/10/13 sensitivity analyses:
//! quantize one tensor site at a time (h, qkv, attention output, the
//! feed-forward hidden h_d) and measure the damage; the paper's finding
//! is that attention tensors are robust where the SSM's x/y are not.
//!
//! Mirrors `python/compile/transformer.py::forward_fp` (ALiBi-biased
//! causal attention, pre-norm, GELU MLP) over the same `.qtz` weights.

use crate::quant;
use crate::tensor::qtz::QtzFile;

#[derive(Debug, Clone)]
pub struct AttnTier {
    pub name: String,
    pub d_model: usize,
    pub n_layer: usize,
    pub n_head: usize,
    pub vocab: usize,
}

#[derive(Debug, Clone, Default)]
pub struct AttnQuantSites {
    pub bits: u32,
    pub h_in: bool,    // attention input (post-norm)
    pub qkv: bool,     // fused qkv projections output
    pub attn_y: bool,  // attention output (token mixing result)
    pub mlp_in: bool,
    pub h_d: bool,     // MLP hidden — the transformer's outlier tensor
}

impl AttnQuantSites {
    pub fn none() -> Self {
        AttnQuantSites { bits: 8, ..Default::default() }
    }
}

pub struct AttnModel {
    pub tier: AttnTier,
    embedding: Vec<f32>,
    norm_f: Vec<f32>,
    layers: Vec<Layer>,
}

struct Layer {
    norm1: Vec<f32>,
    wqkv: Vec<f32>, // (d, 3d)
    wo: Vec<f32>,   // (d, d)
    norm2: Vec<f32>,
    w1: Vec<f32>,   // (d, ff)
    b1: Vec<f32>,
    w2: Vec<f32>,   // (ff, d)
}

fn gelu(x: f32) -> f32 {
    0.5 * x * (1.0 + ((2.0 / std::f32::consts::PI).sqrt() * (x + 0.044715 * x * x * x)).tanh())
}

fn rmsnorm_rows(x: &[f32], w: &[f32], d: usize, out: &mut [f32]) {
    for (ri, ro) in x.chunks_exact(d).zip(out.chunks_exact_mut(d)) {
        let ms: f32 = ri.iter().map(|v| v * v).sum::<f32>() / d as f32;
        let r = 1.0 / (ms + 1e-5).sqrt();
        for j in 0..d {
            ro[j] = ri[j] * r * w[j];
        }
    }
}

fn matmul(x: &[f32], w: &[f32], m: usize, k: usize, n: usize, out: &mut [f32]) {
    out.fill(0.0);
    for i in 0..m {
        for p in 0..k {
            let xv = x[i * k + p];
            let wrow = &w[p * n..(p + 1) * n];
            let orow = &mut out[i * n..(i + 1) * n];
            for j in 0..n {
                orow[j] += xv * wrow[j];
            }
        }
    }
}

fn fq(on: bool, xs: &mut [f32], bits: u32) {
    if on {
        let s = quant::scale_sym(quant::amax(xs), bits);
        quant::fake_quant_sym(xs, s, bits);
    }
}

impl AttnModel {
    pub fn from_qtz(tier: AttnTier, q: &QtzFile) -> Result<AttnModel, String> {
        let g = |n: &str| q.get(n).map(|t| t.to_f32()).ok_or_else(|| format!("missing {n}"));
        let mut layers = Vec::new();
        for i in 0..tier.n_layer {
            let p = format!("layers.{i}.");
            layers.push(Layer {
                norm1: g(&format!("{p}norm1.weight"))?,
                wqkv: g(&format!("{p}wqkv"))?,
                wo: g(&format!("{p}wo"))?,
                norm2: g(&format!("{p}norm2.weight"))?,
                w1: g(&format!("{p}w1"))?,
                b1: g(&format!("{p}b1"))?,
                w2: g(&format!("{p}w2"))?,
            });
        }
        Ok(AttnModel {
            embedding: g("embedding.weight")?,
            norm_f: g("norm_f.weight")?,
            layers,
            tier,
        })
    }

    /// Forward (B=1). Returns logits (T × V).
    pub fn forward(&self, tokens: &[u16], sites: &AttnQuantSites) -> Vec<f32> {
        let t = &self.tier;
        let (d, hn, tl) = (t.d_model, t.n_head, tokens.len());
        let dh = d / hn;
        let ff = 4 * d;
        let slopes: Vec<f32> = (0..hn).map(|i| 2f32.powf(-((i + 1) as f32) * 8.0 / hn as f32)).collect();
        let mut resid = vec![0.0f32; tl * d];
        for (i, &tok) in tokens.iter().enumerate() {
            resid[i * d..(i + 1) * d]
                .copy_from_slice(&self.embedding[tok as usize * d..(tok as usize + 1) * d]);
        }
        let mut h = vec![0.0f32; tl * d];
        let mut qkv = vec![0.0f32; tl * 3 * d];
        let mut attn_out = vec![0.0f32; tl * d];
        let mut proj = vec![0.0f32; tl * d];
        let mut hid = vec![0.0f32; tl * ff];
        for layer in &self.layers {
            rmsnorm_rows(&resid, &layer.norm1, d, &mut h);
            fq(sites.h_in, &mut h, sites.bits);
            matmul(&h, &layer.wqkv, tl, d, 3 * d, &mut qkv);
            fq(sites.qkv, &mut qkv, sites.bits);
            // attention per head, causal with ALiBi
            attn_out.fill(0.0);
            for head in 0..hn {
                for qi in 0..tl {
                    let qv = &qkv[qi * 3 * d + head * dh..qi * 3 * d + head * dh + dh];
                    // logits over keys 0..=qi
                    let mut w = Vec::with_capacity(qi + 1);
                    let mut wmax = f32::NEG_INFINITY;
                    for ki in 0..=qi {
                        let kv = &qkv[ki * 3 * d + d + head * dh..ki * 3 * d + d + head * dh + dh];
                        let mut dot = 0.0f32;
                        for j in 0..dh {
                            dot += qv[j] * kv[j];
                        }
                        let logit = dot / (dh as f32).sqrt() - slopes[head] * (qi - ki) as f32;
                        wmax = wmax.max(logit);
                        w.push(logit);
                    }
                    let mut z = 0.0f32;
                    for wv in w.iter_mut() {
                        *wv = (*wv - wmax).exp();
                        z += *wv;
                    }
                    let orow = &mut attn_out[qi * d + head * dh..qi * d + head * dh + dh];
                    for (ki, wv) in w.iter().enumerate() {
                        let vv = &qkv[ki * 3 * d + 2 * d + head * dh..ki * 3 * d + 2 * d + head * dh + dh];
                        let p = wv / z;
                        for j in 0..dh {
                            orow[j] += p * vv[j];
                        }
                    }
                }
            }
            fq(sites.attn_y, &mut attn_out, sites.bits);
            matmul(&attn_out, &layer.wo, tl, d, d, &mut proj);
            for i in 0..resid.len() {
                resid[i] += proj[i];
            }
            rmsnorm_rows(&resid, &layer.norm2, d, &mut h);
            fq(sites.mlp_in, &mut h, sites.bits);
            matmul(&h, &layer.w1, tl, d, ff, &mut hid);
            for ti in 0..tl {
                for j in 0..ff {
                    hid[ti * ff + j] = gelu(hid[ti * ff + j] + layer.b1[j]);
                }
            }
            fq(sites.h_d, &mut hid, sites.bits);
            matmul(&hid, &layer.w2, tl, ff, d, &mut proj);
            for i in 0..resid.len() {
                resid[i] += proj[i];
            }
        }
        let mut fin = vec![0.0f32; tl * d];
        rmsnorm_rows(&resid, &self.norm_f, d, &mut fin);
        let v = t.vocab;
        let mut logits = vec![0.0f32; tl * v];
        for ti in 0..tl {
            for tok in 0..v {
                let erow = &self.embedding[tok * d..(tok + 1) * d];
                logits[ti * v + tok] = erow
                    .iter()
                    .zip(&fin[ti * d..(ti + 1) * d])
                    .map(|(a, b)| a * b)
                    .sum();
            }
        }
        logits
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gelu_fixed_points() {
        assert!(gelu(0.0).abs() < 1e-7);
        assert!((gelu(10.0) - 10.0).abs() < 1e-3);
        assert!(gelu(-10.0).abs() < 1e-3);
    }
}
