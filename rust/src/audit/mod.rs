//! `quamba-audit`: the repo-specific quantization-soundness static
//! analysis pass (run as `cargo run --bin quamba_audit`, gated in CI).
//!
//! The compiler proves memory safety inside each `unsafe` block and
//! the type system proves shapes line up — but nothing in rustc knows
//! that (a) `unsafe` belongs only in the SIMD kernel module with a
//! written safety argument, (b) an i32 accumulator fed |i8·i8| ≤ 2¹⁴
//! products survives at most K = [`crate::quant::MAX_SAFE_K`] of them,
//! or (c) every activation scale baked at calibration is consumed by
//! the execution paths exactly as it was folded. Those are *project*
//! invariants, and the paper's failure mode for getting them wrong is
//! silent accuracy loss, not a crash — so this module makes them
//! machine-checkable:
//!
//! * **unsafe confinement** ([`rules`]) — every `unsafe` token in
//!   `src/` lives in `quant/kernels.rs`; every unsafe block there has
//!   a `// SAFETY:` comment; every intrinsic fn inside an arch module
//!   carries a `#[target_feature]` consistent with that module; the
//!   crate lint table (`#![deny(unsafe_code)]` + friends in `lib.rs`)
//!   and the kernels module's lone `#[allow(unsafe_code)]` stay put.
//! * **accumulator-overflow proofs** ([`shapes`]) — every `MambaTier`
//!   literal in src/tests/benches and every gemm/conv shape in the
//!   committed bench baseline keeps its K-role dims within the proven
//!   bound for its tier (|i8·i8| ≤ 2¹⁴ ⇒ `MAX_SAFE_K`; the packed
//!   W4A8 GEMM's |i4·i8| ≤ 2¹⁰ ⇒ the 16× looser `MAX_SAFE_K_I4`);
//!   the runtime `debug_assert!` guards exist in the int8 + int4
//!   kernel entry points, each naming its own bound constant.
//! * **scale-propagation audit** ([`scales`]) — each `QLayer` /
//!   `QuantizedMambaModel` scale field is produced exactly once in
//!   `from_calibration`, consumed by both execution bodies
//!   (`prefill_batch_impl` and `step_into`), and the Hadamard out_proj
//!   fold keeps its invariants (`s_conv = s_cin·conv_sw`, the `1/di`
//!   folded into the out_proj weight scale, rotate-before-project).
//! * **cast hygiene** ([`rules`]) — no bare `as` narrowing or
//!   dequantizing casts in non-test `quant/`/`ssm/` code outside the
//!   kernels module; the sanctioned conversions are
//!   `quant::{code_to_i8, dq_i8, dq_i32}` and sites marked
//!   `// audit:allow(cast)` with a written rationale.
//! * **failure-model discipline** ([`rules::scan_native_engine`]) —
//!   the native serving engine (`coordinator/native.rs`) carries no
//!   `.unwrap()` / `.expect()` in non-test code (failures must become
//!   typed responses, not aborts), and `live.swap_remove` /
//!   `pool.release` stay confined to `fn finish_live`, the single
//!   documented slot-reclaim point every retirement path funnels
//!   through (ISSUE 7).
//! * **clock discipline** ([`rules::scan_clock_discipline`]) —
//!   non-test `coordinator/` and `obs/` code never calls
//!   `Instant::now()` / `SystemTime::now()` directly; the one
//!   sanctioned wall-clock reader is `coordinator/faults.rs`
//!   (`WallAnchor` / `Clock`), so `Clock::Manual` serving stays
//!   deterministic — byte-identical flight-recorder dumps and equal
//!   metrics snapshots run-to-run (ISSUE 9).
//!
//! The scanner is a deliberate line-level pass (the offline vendor set
//! has no `syn`): strings and comments are stripped per line, module
//! and test-region context is tracked, and every rule is exercised by
//! seeded-violation fixtures in `tests/audit.rs` — the auditor must
//! fail on each of them, so a regression in the scanner itself is
//! caught the same way as a regression in the tree.

pub mod rules;
pub mod scales;
pub mod shapes;

use std::fmt;
use std::path::{Path, PathBuf};

/// One rule violation at a file:line.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Finding {
    /// stable rule id (kebab-case), e.g. `unsafe-confinement`
    pub rule: &'static str,
    /// path relative to the scanned root, forward slashes
    pub file: String,
    /// 1-based line; 0 = whole-file finding
    pub line: usize,
    pub message: String,
}

impl fmt::Display for Finding {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}:{}: [{}] {}", self.file, self.line, self.rule, self.message)
    }
}

/// Outcome of one [`audit_repo`] run.
#[derive(Debug, Default)]
pub struct Report {
    pub findings: Vec<Finding>,
    /// `.rs` files scanned under src/ + tests/ + benches/
    pub files_scanned: usize,
    /// complete `MambaTier { .. }` literals shape-checked
    pub tiers_checked: usize,
    /// scale fields traced through produce/consume
    pub scales_checked: usize,
}

impl Report {
    pub fn ok(&self) -> bool {
        self.findings.is_empty()
    }
}

/// Locate the crate source root under `root`: accepts the repo root
/// (`<root>/rust/src`), the crate dir (`<root>/src`), or the src dir
/// itself (`<root>/lib.rs`).
pub fn find_src_root(root: &Path) -> Option<PathBuf> {
    for cand in [root.join("rust/src"), root.join("src"), root.to_path_buf()] {
        if cand.join("lib.rs").is_file() {
            return Some(cand);
        }
    }
    None
}

fn walk_rs(dir: &Path, out: &mut Vec<PathBuf>) {
    let Ok(rd) = std::fs::read_dir(dir) else {
        return;
    };
    let mut names: Vec<PathBuf> = rd.flatten().map(|e| e.path()).collect();
    names.sort();
    for p in names {
        if p.is_dir() {
            walk_rs(&p, out);
        } else if p.extension().is_some_and(|e| e == "rs") {
            out.push(p);
        }
    }
}

fn rel_str(base: &Path, p: &Path) -> String {
    p.strip_prefix(base)
        .unwrap_or(p)
        .to_string_lossy()
        .replace(std::path::MAIN_SEPARATOR, "/")
}

/// Run every audit rule over the tree rooted at `root` (the repo root,
/// the crate dir, or the src dir — see [`find_src_root`]).
pub fn audit_repo(root: &Path) -> Result<Report, String> {
    let src = find_src_root(root)
        .ok_or_else(|| format!("no crate source root under {}", root.display()))?;
    let crate_dir = src.parent().map(Path::to_path_buf).unwrap_or_else(|| src.clone());
    let mut report = Report::default();

    // --- src/: unsafe confinement, casts, lint table, guards, scales
    let mut files = Vec::new();
    walk_rs(&src, &mut files);
    if files.is_empty() {
        return Err(format!("no .rs files under {}", src.display()));
    }
    for path in &files {
        let text = std::fs::read_to_string(path)
            .map_err(|e| format!("read {}: {e}", path.display()))?;
        let rel = rel_str(&src, path);
        report.files_scanned += 1;
        report.findings.extend(rules::scan_source_file(&rel, &text));
        if rel == "lib.rs" {
            report.findings.extend(rules::check_lint_table(&rel, &text));
        }
        if rel == "quant/mod.rs" {
            report.findings.extend(rules::check_kernels_allow(&rel, &text));
        }
        if rel == "quant/kernels.rs" {
            report.findings.extend(rules::check_const_proof(&rel, &text));
        }
        for (fn_name, bound) in rules::guarded_entry_points(&rel) {
            report.findings.extend(rules::check_guard_present(&rel, &text, fn_name, bound));
        }
        if rel == rules::NATIVE_FILE {
            report.findings.extend(rules::scan_native_engine(&rel, &text));
        }
        if (rel.starts_with("coordinator/") || rel.starts_with("obs/")) && rel != rules::CLOCK_FILE
        {
            report.findings.extend(rules::scan_clock_discipline(&rel, &text));
        }
        if rel == "ssm/qmamba.rs" {
            let (fs, n) = scales::audit_scales(&rel, &text);
            report.findings.extend(fs);
            report.scales_checked += n;
        }
        let tiers = shapes::collect_tier_literals(&rel, &text);
        report.tiers_checked += tiers.len();
        for t in &tiers {
            report.findings.extend(shapes::check_tier(t));
        }
    }

    // --- tests/ + benches/: MambaTier literals must also respect the
    // proven K bound (a bench shape past the bound would "measure" a
    // kernel that silently wraps)
    for sub in ["tests", "benches"] {
        let dir = crate_dir.join(sub);
        let mut extra = Vec::new();
        walk_rs(&dir, &mut extra);
        for path in &extra {
            let text = std::fs::read_to_string(path)
                .map_err(|e| format!("read {}: {e}", path.display()))?;
            let rel = format!("{sub}/{}", rel_str(&dir, path));
            report.files_scanned += 1;
            let tiers = shapes::collect_tier_literals(&rel, &text);
            report.tiers_checked += tiers.len();
            for t in &tiers {
                report.findings.extend(shapes::check_tier(t));
            }
        }
    }

    // --- committed bench baseline: gemm/conv shape strings
    let baseline = crate_dir.join("benches/BENCH_native_decode.baseline.json");
    if let Ok(text) = std::fs::read_to_string(&baseline) {
        report
            .findings
            .extend(shapes::audit_bench_json("benches/BENCH_native_decode.baseline.json", &text));
    }

    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn find_src_root_accepts_all_layouts() {
        // the test binary runs from the crate dir (or the repo root,
        // depending on the harness); both must resolve
        let here = std::env::current_dir().unwrap();
        let mut probe = here.clone();
        let mut found = find_src_root(&probe).is_some();
        // also accept being launched from a subdirectory of the repo
        while !found && probe.pop() {
            found = find_src_root(&probe).is_some();
        }
        assert!(found, "no source root reachable from {}", here.display());
    }

    #[test]
    fn display_formats_as_file_line_rule() {
        let f = Finding {
            rule: "unsafe-confinement",
            file: "ssm/scan.rs".into(),
            line: 12,
            message: "unsafe outside quant/kernels.rs".into(),
        };
        assert_eq!(
            f.to_string(),
            "ssm/scan.rs:12: [unsafe-confinement] unsafe outside quant/kernels.rs"
        );
    }
}
