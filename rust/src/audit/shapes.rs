//! Accumulator-overflow shape audit: every `MambaTier { .. }` literal
//! in the tree and every gemm/conv shape string in the committed bench
//! baseline must keep its K-role dimensions within
//! [`crate::quant::MAX_SAFE_K`] — the compile-time-proven bound on how
//! many |i8·i8| ≤ 2¹⁴ products one i32 accumulator can absorb. Bench
//! rows for the packed W4A8 tier (op name contains `w4a8`) get the 16×
//! looser [`crate::quant::MAX_SAFE_K_I4`] instead: |i4·i8| ≤ 2¹⁰.
//!
//! Which dimension plays K where (mirrors the `debug_assert!` guards
//! in the kernel entry points):
//!
//! | dim       | K role                                             |
//! |-----------|----------------------------------------------------|
//! | `d_model` | K of the in_proj GEMM and the tied-head GEMM       |
//! | `d_inner` | K of the x_proj GEMM and the folded out_proj GEMM  |
//! | `dt_rank` | K of the dt_proj GEMM                              |
//! | `d_conv`  | tap count of the fused integer conv                |
//! | `d_state` | n_state of the quantized scan (future-proof guard) |
//!
//! The runtime `debug_assert!` guards only fire on shapes a test
//! actually runs; this pass covers every shape the tree *mentions* —
//! src, tests, benches, and the bench baseline JSON — so an
//! out-of-bound tier can't land even in not-yet-executed code.

use super::Finding;
use crate::quant::{MAX_SAFE_K, MAX_SAFE_K_I4};
use crate::util::json;

/// One `MambaTier { .. }` struct literal with its integer-literal
/// dimension fields. Fields bound to expressions (e.g. `d_model: d`)
/// are not recorded — the literal is still counted, and the expression
/// value is covered at runtime by the kernel guards.
#[derive(Debug, Clone)]
pub struct TierShape {
    pub file: String,
    /// 1-based line of the `MambaTier {` opener
    pub line: usize,
    /// (field, value) pairs parsed from integer literals
    pub dims: Vec<(String, usize)>,
}

const DIM_FIELDS: [&str; 7] =
    ["d_model", "n_layer", "d_state", "d_conv", "d_inner", "dt_rank", "vocab"];

/// K role played by each audited dimension (None = not a K-role dim).
fn k_role(field: &str) -> Option<&'static str> {
    match field {
        "d_model" => Some("K of the in_proj / tied-head GEMMs"),
        "d_inner" => Some("K of the x_proj / folded out_proj GEMMs"),
        "dt_rank" => Some("K of the dt_proj GEMM"),
        "d_conv" => Some("tap count of the fused integer conv"),
        "d_state" => Some("n_state of the quantized scan"),
        _ => None,
    }
}

/// Collect every `MambaTier { .. }` literal in `text` (line-level
/// brace tracking on comment/string-stripped code; tier literals in
/// this tree are one-field-per-line, which the repo's rustfmt layout
/// guarantees).
pub fn collect_tier_literals(rel: &str, text: &str) -> Vec<TierShape> {
    let mut out = Vec::new();
    let mut cur: Option<(TierShape, i64)> = None; // (literal, open depth)
    for (i, raw) in text.lines().enumerate() {
        let code = super::rules::code_portion(raw);
        if let Some((tier, depth)) = cur.as_mut() {
            let trimmed = code.trim();
            if let Some(colon) = trimmed.find(':') {
                let name = trimmed[..colon].trim();
                if DIM_FIELDS.contains(&name) {
                    let val = trimmed[colon + 1..].trim().trim_end_matches(',').trim();
                    if let Ok(v) = val.replace('_', "").parse::<usize>() {
                        tier.dims.push((name.to_string(), v));
                    }
                }
            }
            *depth += brace_delta(&code);
            if *depth <= 0 {
                out.push(cur.take().unwrap().0);
            }
            continue;
        }
        if super::rules::has_token(&code, "MambaTier") {
            if let Some(pos) = code.find('{') {
                let delta = brace_delta(&code[pos..]);
                let tier = TierShape { file: rel.to_string(), line: i + 1, dims: Vec::new() };
                if delta <= 0 {
                    out.push(tier); // single-line literal (no dims parsed)
                } else {
                    cur = Some((tier, delta));
                }
            }
        }
    }
    out
}

fn brace_delta(code: &str) -> i64 {
    let mut d = 0i64;
    for c in code.chars() {
        match c {
            '{' => d += 1,
            '}' => d -= 1,
            _ => {}
        }
    }
    d
}

/// Check one tier literal's K-role dims against the proven bound.
pub fn check_tier(t: &TierShape) -> Vec<Finding> {
    let mut out = Vec::new();
    for (field, value) in &t.dims {
        if let Some(role) = k_role(field) {
            if *value > MAX_SAFE_K {
                out.push(Finding {
                    rule: "k-bound",
                    file: t.file.clone(),
                    line: t.line,
                    message: format!(
                        "MambaTier.{field} = {value} exceeds MAX_SAFE_K = {MAX_SAFE_K} \
                         ({role}): a worst-case i8·i8 reduction of this length \
                         overflows the i32 accumulator"
                    ),
                });
            }
        }
    }
    out
}

/// The proven K bound for one bench op: W4A8 GEMM rows (`"w4a8"` in
/// the op name) absorb |i4·i8| ≤ 2¹⁰ products, so they get the 16×
/// looser [`MAX_SAFE_K_I4`]; every other gemm/conv row is i8×i8 and
/// stays on [`MAX_SAFE_K`].
fn k_bound_for(op: &str) -> (usize, &'static str) {
    if op.contains("w4a8") {
        (MAX_SAFE_K_I4, "MAX_SAFE_K_I4")
    } else {
        (MAX_SAFE_K, "MAX_SAFE_K")
    }
}

/// Audit the committed bench baseline: every `gemm_*` entry's K (the
/// middle of its `MxKxN` shape token) and every `conv_*` entry's `w=`
/// tap count must stay within the proven bound for its tier (see
/// [`k_bound_for`]) — a baseline row past it would "measure" a kernel
/// that silently wraps.
pub fn audit_bench_json(rel: &str, text: &str) -> Vec<Finding> {
    let mut out = Vec::new();
    let doc = match json::parse(text) {
        Ok(d) => d,
        Err(e) => {
            return vec![Finding {
                rule: "bench-shape",
                file: rel.to_string(),
                line: 0,
                message: format!("baseline does not parse as JSON: {e}"),
            }];
        }
    };
    let Some(entries) = doc.get("entries").as_arr() else {
        return vec![Finding {
            rule: "bench-shape",
            file: rel.to_string(),
            line: 0,
            message: "baseline has no `entries` array".into(),
        }];
    };
    for (i, e) in entries.iter().enumerate() {
        let op = e.get("op").as_str().unwrap_or("");
        let shape = e.get("shape").as_str().unwrap_or("");
        let bad = |message: String| Finding {
            rule: "bench-shape",
            file: rel.to_string(),
            line: 0,
            message: format!("entries[{i}] ({op} \"{shape}\"): {message}"),
        };
        let (k_max, k_max_name) = k_bound_for(op);
        if op.starts_with("gemm_") {
            // shape token is "MxKxN" (an optional " (label)" suffix follows)
            let tok = shape.split_whitespace().next().unwrap_or("");
            let dims: Vec<usize> =
                tok.split('x').filter_map(|p| p.parse::<usize>().ok()).collect();
            if dims.len() != 3 {
                out.push(bad("gemm shape is not MxKxN".into()));
            } else if dims[1] > k_max {
                out.push(bad(format!("gemm K = {} exceeds {k_max_name} = {k_max}", dims[1])));
            }
        } else if op.starts_with("conv_") {
            let w = shape
                .split_whitespace()
                .find_map(|t| t.strip_prefix("w=").and_then(|v| v.parse::<usize>().ok()));
            match w {
                None => out.push(bad("conv shape has no parseable `w=` tap count".into())),
                Some(w) if w > k_max => {
                    out.push(bad(format!("conv w = {w} exceeds {k_max_name} = {k_max}")));
                }
                _ => {}
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    const TIER: &str = "fn tier() -> MambaTier {\n\
                        \x20   MambaTier {\n\
                        \x20       name: \"tiny\".into(),\n\
                        \x20       d_model: 16,\n\
                        \x20       n_layer: 2,\n\
                        \x20       d_state: 4,\n\
                        \x20       d_conv: 3,\n\
                        \x20       d_inner: 32,\n\
                        \x20       dt_rank: 2,\n\
                        \x20       vocab: 256,\n\
                        \x20   }\n\
                        }\n";

    #[test]
    fn collects_and_passes_in_bound_tier() {
        let tiers = collect_tier_literals("tests/x.rs", TIER);
        assert_eq!(tiers.len(), 1);
        assert_eq!(tiers[0].line, 2);
        assert_eq!(tiers[0].dims.len(), 7);
        assert!(check_tier(&tiers[0]).is_empty());
    }

    #[test]
    fn flags_out_of_bound_d_model() {
        let src = TIER.replace("d_model: 16,", "d_model: 200_000,");
        let tiers = collect_tier_literals("tests/x.rs", &src);
        let fs = check_tier(&tiers[0]);
        assert_eq!(fs.len(), 1, "{fs:?}");
        assert_eq!(fs[0].rule, "k-bound");
        assert!(fs[0].message.contains("d_model = 200000"), "{}", fs[0].message);
    }

    #[test]
    fn expression_dims_are_skipped_not_flagged() {
        let src = TIER.replace("d_model: 16,", "d_model: d,");
        let tiers = collect_tier_literals("tests/x.rs", &src);
        assert_eq!(tiers.len(), 1);
        assert_eq!(tiers[0].dims.len(), 6);
        assert!(check_tier(&tiers[0]).is_empty());
    }

    #[test]
    fn bench_json_k_bound_fires() {
        let good = r#"{"entries": [
            {"op": "gemm_i8_blocked_simd", "shape": "8x64x256 (in_proj decode)"},
            {"op": "conv_i8_fused_simd", "shape": "B=8 di=128 w=4"},
            {"op": "ttft_p50", "shape": "serve n=16 chunk=64"}
        ]}"#;
        assert!(audit_bench_json("b.json", good).is_empty());
        let bad = r#"{"entries": [
            {"op": "gemm_i8_blocked", "shape": "8x200000x256"},
            {"op": "conv_i8_fused_simd", "shape": "B=8 di=128 w=140000"}
        ]}"#;
        let fs = audit_bench_json("b.json", bad);
        assert_eq!(fs.len(), 2, "{fs:?}");
        assert!(fs.iter().all(|f| f.rule == "bench-shape"));
    }

    #[test]
    fn bench_json_selects_the_bound_per_tier() {
        // a K between the two bounds: fatal for an i8×i8 row, fine for
        // a w4a8 row (|i4·i8| ≤ 2¹⁰ gives 16× the headroom)
        let mid_k = (MAX_SAFE_K + MAX_SAFE_K_I4) / 2;
        let src = format!(
            r#"{{"entries": [
                {{"op": "gemm_w4a8", "shape": "8x{mid_k}x256"}},
                {{"op": "gemm_i8_blocked", "shape": "8x{mid_k}x256"}},
                {{"op": "gemm_w4a8_simd", "shape": "8x{over}x256"}}
            ]}}"#,
            over = MAX_SAFE_K_I4 + 1
        );
        let fs = audit_bench_json("b.json", &src);
        assert_eq!(fs.len(), 2, "{fs:?}");
        assert!(
            fs.iter().any(|f| f.message.contains("entries[1]")
                && f.message.contains("MAX_SAFE_K =")),
            "mid-K i8 row must flag against MAX_SAFE_K: {fs:?}"
        );
        assert!(
            fs.iter().any(|f| f.message.contains("entries[2]")
                && f.message.contains("MAX_SAFE_K_I4 =")),
            "past-bound w4a8 row must flag against MAX_SAFE_K_I4: {fs:?}"
        );
    }

    #[test]
    fn bench_json_malformed_shapes_are_findings() {
        let src = r#"{"entries": [
            {"op": "gemm_i8_blocked", "shape": "wat"},
            {"op": "conv_i8_fused_simd", "shape": "B=8 di=128"}
        ]}"#;
        assert_eq!(audit_bench_json("b.json", src).len(), 2);
        assert_eq!(audit_bench_json("b.json", "not json").len(), 1);
    }
}
