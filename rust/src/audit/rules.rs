//! Line-level source rules: unsafe confinement, `// SAFETY:` and
//! `#[target_feature]` discipline inside the kernels module, the
//! crate lint table, kernel-guard presence, and cast hygiene.
//!
//! The scanner strips strings and line comments per line
//! ([`code_portion`]), tracks `mod avx2` / `mod neon` / `mod tests`
//! context, and treats everything after the first `#[cfg(test)]` as
//! test region (the crate's convention keeps unit tests at the bottom
//! of each file). It is deliberately std-only — no `syn` in the
//! offline vendor set — and every rule has a seeded-violation fixture
//! in `tests/audit.rs` proving it actually fires.

use super::Finding;

/// Strip the line-comment suffix and the *contents* of string
/// literals from one source line, so token scans don't trip on text
/// inside strings, docs, or comments. Quote characters themselves are
/// kept (emptied), escapes are honored; char literals are not tracked
/// (the tree has no `'"'`-style literals, and a false string-open
/// would only make the scanner stricter on that one line).
pub fn code_portion(line: &str) -> String {
    let mut out = String::with_capacity(line.len());
    let mut chars = line.chars().peekable();
    let mut in_str = false;
    let mut escaped = false;
    while let Some(c) = chars.next() {
        if in_str {
            if escaped {
                escaped = false;
            } else if c == '\\' {
                escaped = true;
            } else if c == '"' {
                in_str = false;
                out.push('"');
            }
            continue;
        }
        if c == '"' {
            in_str = true;
            out.push('"');
            continue;
        }
        if c == '/' && chars.peek() == Some(&'/') {
            break; // line comment (also covers /// and //!)
        }
        out.push(c);
    }
    out
}

/// Does `code` contain `tok` as a whole word (neighbors are not
/// `[A-Za-z0-9_]`)? Keeps `unsafe_code` / `unused_unsafe` attribute
/// payloads from matching the `unsafe` keyword.
pub fn has_token(code: &str, tok: &str) -> bool {
    let bytes = code.as_bytes();
    let mut start = 0;
    while let Some(pos) = code[start..].find(tok) {
        let at = start + pos;
        let end = at + tok.len();
        let pre_ok = at == 0 || !is_word(bytes[at - 1]);
        let post_ok = end >= bytes.len() || !is_word(bytes[end]);
        if pre_ok && post_ok {
            return true;
        }
        start = at + 1;
    }
    false
}

fn is_word(b: u8) -> bool {
    b.is_ascii_alphanumeric() || b == b'_'
}

fn is_attr(trimmed: &str) -> bool {
    trimmed.starts_with("#[") || trimmed.starts_with("#![")
}

fn is_comment(trimmed: &str) -> bool {
    trimmed.starts_with("//")
}

/// The one file allowed to contain `unsafe`.
pub const KERNELS_FILE: &str = "quant/kernels.rs";

/// Scan one `src/` file: unsafe confinement everywhere, plus the
/// SAFETY/target_feature discipline inside the kernels module and
/// cast hygiene in `quant/` + `ssm/`.
pub fn scan_source_file(rel: &str, text: &str) -> Vec<Finding> {
    let mut out = Vec::new();
    if rel == KERNELS_FILE {
        out.extend(scan_kernels(rel, text));
    } else {
        out.extend(scan_unsafe_free(rel, text));
        if (rel.starts_with("quant/") || rel.starts_with("ssm/")) && rel.ends_with(".rs") {
            out.extend(scan_casts(rel, text));
        }
    }
    out
}

/// Outside the kernels module, any `unsafe` token in non-test code is
/// a confinement violation (the crate also carries
/// `#![deny(unsafe_code)]`, but that attribute is itself editable —
/// the auditor is the second, independent witness). The scan stops at
/// the first `#[cfg(test)]`: a per-line scanner cannot see that a
/// continuation line of a multi-line string fixture is still inside a
/// string, and test regions stay covered by the compile-time lint.
pub fn scan_unsafe_free(rel: &str, text: &str) -> Vec<Finding> {
    let mut out = Vec::new();
    for (i, line) in text.lines().enumerate() {
        if line.trim().starts_with("#[cfg(test)]") {
            break;
        }
        let code = code_portion(line);
        if has_token(&code, "unsafe") && !is_attr(code.trim()) {
            out.push(Finding {
                rule: "unsafe-confinement",
                file: rel.to_string(),
                line: i + 1,
                message: format!("`unsafe` outside {KERNELS_FILE}: {}", line.trim()),
            });
        }
    }
    out
}

/// Inside `quant/kernels.rs`: every unsafe *block* needs a
/// `// SAFETY:` comment in the contiguous comment/attribute run above
/// it; every `unsafe fn` needs a `# Safety` doc section; every fn in
/// an arch module (`mod avx2` / `mod neon`) needs a
/// `#[target_feature(enable = "...")]` naming that module's feature;
/// and a `target_feature` attribute may not name a different feature
/// than its module (nor appear outside one).
pub fn scan_kernels(rel: &str, text: &str) -> Vec<Finding> {
    let mut out = Vec::new();
    let lines: Vec<&str> = text.lines().collect();
    // arch-module context: which target feature this region's
    // intrinsics require (None = dispatch/scalar code). Stops at the
    // first #[cfg(test)] like scan_unsafe_free, and for the same
    // reason (per-line scans can't track multi-line string fixtures).
    let mut arch: Option<&'static str> = None;
    for (i, raw) in lines.iter().enumerate() {
        if raw.trim().starts_with("#[cfg(test)]") {
            break;
        }
        let code = code_portion(raw);
        let trimmed = code.trim();
        if has_token(&code, "mod") {
            arch = if has_token(&code, "avx2") {
                Some("avx2")
            } else if has_token(&code, "neon") {
                Some("neon")
            } else {
                None // mod scalar / mod tests / anything else
            };
        }
        // target_feature attribute consistency (detect on the
        // comment-stripped code so prose mentioning the attribute
        // doesn't count; extract the feature name from the raw line
        // because it lives in a string literal)
        if code.contains("#[target_feature") {
            match (feature_of(raw), arch) {
                (Some(feat), Some(want)) if feat != want => out.push(Finding {
                    rule: "target-feature",
                    file: rel.to_string(),
                    line: i + 1,
                    message: format!(
                        "#[target_feature(enable = \"{feat}\")] inside the {want} module"
                    ),
                }),
                (_, None) => out.push(Finding {
                    rule: "target-feature",
                    file: rel.to_string(),
                    line: i + 1,
                    message: "#[target_feature] outside an arch module".into(),
                }),
                _ => {}
            }
        }
        if !has_token(&code, "unsafe") || is_attr(trimmed) {
            continue;
        }
        if has_token(&code, "fn") {
            // `unsafe fn` declaration: needs a `# Safety` doc section,
            // and — inside an arch module — a matching target_feature
            let head = preceding_run(&lines, i);
            if !head.iter().any(|l| l.contains("# Safety")) {
                out.push(Finding {
                    rule: "safety-comment",
                    file: rel.to_string(),
                    line: i + 1,
                    message: format!("unsafe fn without a `# Safety` doc: {}", raw.trim()),
                });
            }
            if let Some(want) = arch {
                let feat = head.iter().find_map(|l| feature_of(l));
                if feat.as_deref() != Some(want) {
                    out.push(Finding {
                        rule: "target-feature",
                        file: rel.to_string(),
                        line: i + 1,
                        message: format!(
                            "fn in the {want} module lacks #[target_feature(enable = \"{want}\")]: {}",
                            raw.trim()
                        ),
                    });
                }
            }
        } else {
            // unsafe block: the contiguous comment/attribute run above
            // must contain a `// SAFETY:` justification
            let head = preceding_run(&lines, i);
            let documented = head
                .iter()
                .any(|l| is_comment(l.trim()) && l.contains("SAFETY:"));
            if !documented {
                out.push(Finding {
                    rule: "safety-comment",
                    file: rel.to_string(),
                    line: i + 1,
                    message: format!("unsafe block without a `// SAFETY:` comment: {}", raw.trim()),
                });
            }
        }
    }
    out
}

/// The contiguous run of comment / doc / attribute / blank lines
/// directly above line `i` (nearest first), capped for sanity.
fn preceding_run<'a>(lines: &[&'a str], i: usize) -> Vec<&'a str> {
    let mut head = Vec::new();
    let mut j = i;
    while j > 0 && head.len() < 24 {
        j -= 1;
        let t = lines[j].trim();
        if t.is_empty() || is_comment(t) || is_attr(t) {
            head.push(lines[j]);
        } else {
            break;
        }
    }
    head
}

/// Extract `X` from `#[target_feature(enable = "X")]` (raw line — the
/// feature name lives in a string literal).
fn feature_of(raw: &str) -> Option<String> {
    let idx = raw.find("enable")?;
    let rest = &raw[idx..];
    let q0 = rest.find('"')?;
    let rest = &rest[q0 + 1..];
    let q1 = rest.find('"')?;
    Some(rest[..q1].to_string())
}

/// Cast hygiene for non-test `quant/` + `ssm/` code (kernels.rs is
/// exempt — it *is* the sanctioned implementation layer): no bare
/// ` as i8`/` as u8`/` as i16` narrowing and no bare `as f32 *`
/// dequant idiom. Sanctioned escapes: the documented helpers in
/// `quant::{code_to_i8, dq_i8, dq_i32}`, or an `// audit:allow(cast)`
/// marker on the line (or the line above) with a written rationale.
pub fn scan_casts(rel: &str, text: &str) -> Vec<Finding> {
    let mut out = Vec::new();
    let mut prev_raw = "";
    for (i, raw) in text.lines().enumerate() {
        let trimmed = raw.trim();
        // test region: unit tests sit at the bottom of each file
        if trimmed.starts_with("#[cfg(test)]") {
            break;
        }
        let allowed = raw.contains("audit:allow(cast)") || prev_raw.contains("audit:allow(cast)");
        prev_raw = raw;
        if allowed {
            continue;
        }
        let code = code_portion(raw);
        for pat in [" as i8", " as u8", " as i16"] {
            // token-boundary check on the type name (` as i8x` is not a cast to i8)
            let mut start = 0;
            while let Some(pos) = code[start..].find(pat) {
                let at = start + pos;
                let end = at + pat.len();
                if end >= code.len() || !is_word(code.as_bytes()[end]) {
                    out.push(Finding {
                        rule: "bare-cast",
                        file: rel.to_string(),
                        line: i + 1,
                        message: format!(
                            "bare `{}` narrowing — use quant::code_to_i8 (or mark audit:allow(cast)): {}",
                            pat.trim(),
                            trimmed
                        ),
                    });
                    break;
                }
                start = at + 1;
            }
        }
        if code.contains(" as f32 *") {
            out.push(Finding {
                rule: "bare-cast",
                file: rel.to_string(),
                line: i + 1,
                message: format!(
                    "bare `as f32 *` dequant — use quant::dq_i8 / quant::dq_i32 \
                     (or mark audit:allow(cast)): {trimmed}"
                ),
            });
        }
    }
    out
}

/// `lib.rs` must keep the unsafe-hygiene core of the lint table: the
/// crate-wide `deny(unsafe_code)` (the kernels module holds the single
/// allow), `deny(unsafe_op_in_unsafe_fn)`, and the clippy
/// undocumented-unsafe-blocks warning that backs the SAFETY rule.
pub fn check_lint_table(rel: &str, text: &str) -> Vec<Finding> {
    let mut out = Vec::new();
    for required in [
        "#![deny(unsafe_code)]",
        "#![deny(unsafe_op_in_unsafe_fn)]",
        "#![warn(clippy::undocumented_unsafe_blocks)]",
    ] {
        if !text.lines().any(|l| l.trim() == required) {
            out.push(Finding {
                rule: "lint-table",
                file: rel.to_string(),
                line: 0,
                message: format!("crate lint table is missing `{required}`"),
            });
        }
    }
    out
}

/// `quant/mod.rs` must carry the single sanctioned
/// `#[allow(unsafe_code)]`, attached to the `kernels` module.
pub fn check_kernels_allow(rel: &str, text: &str) -> Vec<Finding> {
    let lines: Vec<&str> = text.lines().collect();
    for (i, line) in lines.iter().enumerate() {
        if line.trim().starts_with("pub mod kernels") {
            let head = preceding_run(&lines, i);
            if head.iter().any(|l| l.trim() == "#[allow(unsafe_code)]") {
                return Vec::new();
            }
            return vec![Finding {
                rule: "lint-table",
                file: rel.to_string(),
                line: i + 1,
                message: "`pub mod kernels` lacks its `#[allow(unsafe_code)]`".into(),
            }];
        }
    }
    vec![Finding {
        rule: "lint-table",
        file: rel.to_string(),
        line: 0,
        message: "no `pub mod kernels` declaration found".into(),
    }]
}

/// `quant/kernels.rs` must define the headroom constants and the
/// compile-time proofs for BOTH accumulator tiers — the i8×i8 bound
/// ⌊(2³¹−1)/2¹⁴⌋ and the looser i4×i8 bound ⌊(2³¹−1)/2¹⁰⌋ — and the
/// constants must still encode those quotients (checked against the
/// live values this auditor was compiled with).
pub fn check_const_proof(rel: &str, text: &str) -> Vec<Finding> {
    let mut out = Vec::new();
    for required in [
        "pub const MAX_ABS_PROD_I8",
        "pub const MAX_SAFE_K",
        "pub const MAX_ABS_PROD_I4I8",
        "pub const MAX_SAFE_K_I4",
        "const _: () = assert!",
    ] {
        if !text.contains(required) {
            out.push(Finding {
                rule: "const-proof",
                file: rel.to_string(),
                line: 0,
                message: format!("kernels module is missing `{required}`"),
            });
        }
    }
    // live cross-check: the constants this binary was compiled with
    // must equal the independently re-derived bounds
    let derived = (i32::MAX as i64 / (1i64 << 14)) as usize;
    if crate::quant::MAX_SAFE_K != derived {
        out.push(Finding {
            rule: "const-proof",
            file: rel.to_string(),
            line: 0,
            message: format!(
                "MAX_SAFE_K = {} but ⌊i32::MAX / 2¹⁴⌋ = {derived}",
                crate::quant::MAX_SAFE_K
            ),
        });
    }
    let derived_i4 = (i32::MAX as i64 / (1i64 << 10)) as usize;
    if crate::quant::MAX_SAFE_K_I4 != derived_i4 {
        out.push(Finding {
            rule: "const-proof",
            file: rel.to_string(),
            line: 0,
            message: format!(
                "MAX_SAFE_K_I4 = {} but ⌊i32::MAX / 2¹⁰⌋ = {derived_i4}",
                crate::quant::MAX_SAFE_K_I4
            ),
        });
    }
    out
}

/// Which files carry mandatory `debug_assert!(.. bound ..)` runtime
/// guards: (entry point, required bound constant) pairs. The W4A8 GEMM
/// enjoys the looser |i4·i8| ≤ 2¹⁰ product bound, so its guard names
/// `MAX_SAFE_K_I4`; everything i8×i8 stays on `MAX_SAFE_K`.
pub fn guarded_entry_points(rel: &str) -> &'static [(&'static str, &'static str)] {
    match rel {
        "quant/qlinear.rs" => {
            &[("matmul_i8_blocked_with", "MAX_SAFE_K"), ("matmul_w4a8_with", "MAX_SAFE_K_I4")]
        }
        "ssm/qmamba.rs" => &[("fused_conv_silu_i8_with", "MAX_SAFE_K")],
        "ssm/scan.rs" => &[("selective_scan_q_into_with", "MAX_SAFE_K")],
        _ => &[],
    }
}

/// The named entry point must contain a `debug_assert!` mentioning the
/// required bound constant as a whole token (so a `MAX_SAFE_K_I4`
/// guard cannot satisfy a `MAX_SAFE_K` requirement or vice versa) —
/// the overflow guard the overflow-edge tests exercise.
pub fn check_guard_present(rel: &str, text: &str, fn_name: &str, bound: &str) -> Vec<Finding> {
    let Some(start) = text.find(&format!("fn {fn_name}")) else {
        return vec![Finding {
            rule: "accumulator-bound",
            file: rel.to_string(),
            line: 0,
            message: format!("guarded entry point `{fn_name}` not found"),
        }];
    };
    let body = body_after(text, start);
    if body.contains("debug_assert!") && has_token(&body, bound) {
        Vec::new()
    } else {
        vec![Finding {
            rule: "accumulator-bound",
            file: rel.to_string(),
            line: 0,
            message: format!("`{fn_name}` lacks its `debug_assert!(.. {bound} ..)` guard"),
        }]
    }
}

/// The native serving engine file the failure-model rules apply to.
pub const NATIVE_FILE: &str = "coordinator/native.rs";

/// ISSUE 7 failure-model rules for `coordinator/native.rs` (non-test
/// code only — the scan stops at the first `#[cfg(test)]`, same
/// convention as [`scan_unsafe_free`]):
///
/// * `engine-no-unwrap` — no `.unwrap(` / `.expect(` tokens: every
///   admission / step / harvest path must degrade to a typed
///   [`FinishReason`](crate::coordinator::request::FinishReason)
///   response, never a process abort. (`unreachable!` with a written
///   argument and `debug_assert!` remain acceptable.)
/// * `slot-reclaim` — `live.swap_remove(` and `pool.release(` are
///   confined to the body of `fn finish_live`, THE documented reclaim
///   point, so every early-return and error path in the engine
///   provably retires live requests — releasing exactly their own
///   pool slot — through one place. A file without `fn finish_live`
///   at all is a whole-file violation.
pub fn scan_native_engine(rel: &str, text: &str) -> Vec<Finding> {
    let mut out = Vec::new();
    // anchor on the *definition* line (comment/string-stripped), not any
    // raw occurrence — doc comments legitimately name `fn finish_live`
    let mut reclaim_span = None;
    let mut offset = 0usize;
    for (i, raw) in text.lines().enumerate() {
        match raw.find("fn finish_live") {
            Some(col) if code_portion(raw).contains("fn finish_live") => {
                let first = i + 1;
                let body = body_after(text, offset + col);
                reclaim_span = Some((first, first + body.matches('\n').count()));
                break;
            }
            _ => {}
        }
        offset += raw.len() + 1;
    }
    if reclaim_span.is_none() {
        out.push(Finding {
            rule: "slot-reclaim",
            file: rel.to_string(),
            line: 0,
            message: "`fn finish_live` (the documented slot-reclaim point) not found".to_string(),
        });
    }
    for (i, raw) in text.lines().enumerate() {
        let line = i + 1;
        if raw.trim_start().starts_with("#[cfg(test)]") {
            break;
        }
        let code = code_portion(raw);
        for tok in [".unwrap(", ".expect("] {
            if code.contains(tok) {
                out.push(Finding {
                    rule: "engine-no-unwrap",
                    file: rel.to_string(),
                    line,
                    message: format!(
                        "`{tok}..)` in engine code — return a typed failure instead \
                         (FinishReason::Failed / Result), panics here bypass slot reclaim"
                    ),
                });
            }
        }
        for tok in ["live.swap_remove(", "pool.release("] {
            if code.contains(tok) {
                let confined = match reclaim_span {
                    Some((lo, hi)) => line >= lo && line <= hi,
                    None => false,
                };
                if !confined {
                    out.push(Finding {
                        rule: "slot-reclaim",
                        file: rel.to_string(),
                        line,
                        message: format!(
                            "`{tok}..)` outside `fn finish_live` — all slot reclamation \
                             must funnel through the single documented reclaim point"
                        ),
                    });
                }
            }
        }
    }
    out
}

/// The one serving-layer file allowed to read the wall clock: the
/// `Clock` / `WallAnchor` implementation every other coordinator and
/// obs timestamp must route through.
pub const CLOCK_FILE: &str = "coordinator/faults.rs";

/// ISSUE 9 observability rule (`clock-discipline`): non-test code in
/// `coordinator/` and `obs/` must take timestamps from the injectable
/// engine clock — [`CLOCK_FILE`]'s `WallAnchor` / `Clock` — never
/// from raw `Instant::now()` / `SystemTime::now()`. A raw read
/// silently breaks `Clock::Manual` determinism: flight-recorder
/// dumps and metrics snapshots stop being byte-identical run-to-run.
/// Stops at the first `#[cfg(test)]`, same convention as
/// [`scan_unsafe_free`].
pub fn scan_clock_discipline(rel: &str, text: &str) -> Vec<Finding> {
    let mut out = Vec::new();
    for (i, raw) in text.lines().enumerate() {
        if raw.trim_start().starts_with("#[cfg(test)]") {
            break;
        }
        let code = code_portion(raw);
        for tok in ["Instant::now(", "SystemTime::now("] {
            if code.contains(tok) {
                out.push(Finding {
                    rule: "clock-discipline",
                    file: rel.to_string(),
                    line: i + 1,
                    message: format!(
                        "raw `{}..)` in serving code — route timestamps through the \
                         injectable engine clock (WallAnchor / Clock in {CLOCK_FILE}) \
                         so Clock::Manual stays deterministic",
                        tok.trim_end_matches('(')
                    ),
                });
            }
        }
    }
    out
}

/// The brace-balanced body starting at the first `{` at/after `start`
/// (string/comment-stripped brace counting).
pub fn body_after(text: &str, start: usize) -> String {
    let mut depth = 0usize;
    let mut started = false;
    let mut body = String::new();
    for line in text[start..].lines() {
        let code = code_portion(line);
        body.push_str(line);
        body.push('\n');
        for c in code.chars() {
            match c {
                '{' => {
                    depth += 1;
                    started = true;
                }
                '}' => depth = depth.saturating_sub(1),
                _ => {}
            }
        }
        if started && depth == 0 {
            break;
        }
    }
    body
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn code_portion_strips_comments_and_strings() {
        assert_eq!(code_portion("let x = 1; // unsafe { }"), "let x = 1; ");
        assert_eq!(code_portion(r#"panic!("unsafe outside")"#), r#"panic!("")"#);
        assert_eq!(code_portion(r#"let s = "a\"unsafe\"b";"#), r#"let s = "";"#);
        assert_eq!(code_portion("/// docs mention unsafe"), "");
    }

    #[test]
    fn has_token_respects_word_boundaries() {
        assert!(has_token("unsafe {", "unsafe"));
        assert!(!has_token("#[allow(unused_unsafe)]", "unsafe"));
        assert!(!has_token("#![deny(unsafe_code)]", "unsafe"));
        assert!(has_token("pub unsafe fn f()", "unsafe"));
    }

    #[test]
    fn unsafe_free_rule_fires_and_clears() {
        let bad = "fn f() {\n    unsafe { do_evil() }\n}\n";
        let fs = scan_unsafe_free("ssm/scan.rs", bad);
        assert_eq!(fs.len(), 1);
        assert_eq!(fs[0].rule, "unsafe-confinement");
        assert_eq!(fs[0].line, 2);
        let good = "fn f() {\n    // unsafe only in comments\n    let s = \"unsafe\";\n}\n";
        assert!(scan_unsafe_free("ssm/scan.rs", good).is_empty());
    }

    #[test]
    fn kernels_rule_accepts_documented_block() {
        let src = "mod avx2 {\n\
                   \x20   /// # Safety\n\
                   \x20   /// caller checks\n\
                   \x20   #[target_feature(enable = \"avx2\")]\n\
                   \x20   pub unsafe fn f() {\n\
                   \x20       // SAFETY: contract above\n\
                   \x20       unsafe { g() }\n\
                   \x20   }\n\
                   }\n";
        assert!(scan_kernels(KERNELS_FILE, src).is_empty());
    }

    #[test]
    fn kernels_rule_flags_missing_safety_comment() {
        let src = "mod neon {\n\
                   \x20   /// # Safety\n\
                   \x20   /// caller checks\n\
                   \x20   #[target_feature(enable = \"neon\")]\n\
                   \x20   pub unsafe fn f() {\n\
                   \x20       unsafe { g() }\n\
                   \x20   }\n\
                   }\n";
        let fs = scan_kernels(KERNELS_FILE, src);
        assert_eq!(fs.len(), 1, "{fs:?}");
        assert_eq!(fs[0].rule, "safety-comment");
        assert_eq!(fs[0].line, 6);
    }

    #[test]
    fn kernels_rule_flags_wrong_target_feature() {
        let src = "mod avx2 {\n\
                   \x20   /// # Safety\n\
                   \x20   /// caller checks\n\
                   \x20   #[target_feature(enable = \"sse2\")]\n\
                   \x20   pub unsafe fn f() {\n\
                   \x20       // SAFETY: contract above\n\
                   \x20       unsafe { g() }\n\
                   \x20   }\n\
                   }\n";
        let fs = scan_kernels(KERNELS_FILE, src);
        assert!(fs.iter().any(|f| f.rule == "target-feature"), "{fs:?}");
    }

    #[test]
    fn kernels_rule_flags_missing_target_feature() {
        let src = "mod neon {\n\
                   \x20   /// # Safety\n\
                   \x20   /// caller checks\n\
                   \x20   pub unsafe fn f() {\n\
                   \x20       // SAFETY: contract above\n\
                   \x20       unsafe { g() }\n\
                   \x20   }\n\
                   }\n";
        let fs = scan_kernels(KERNELS_FILE, src);
        assert!(fs.iter().any(|f| f.rule == "target-feature"), "{fs:?}");
    }

    #[test]
    fn unsafe_free_rule_stops_at_test_region() {
        // a multi-line string fixture inside a test module would look
        // like bare `unsafe` to a per-line scanner — the rule must not
        // read past #[cfg(test)] (the compile-time deny covers tests)
        let src = "fn f() {}\n\
                   #[cfg(test)]\n\
                   mod tests {\n\
                   \x20   const FIXTURE: &str = \"line one\n\
                   \x20       unsafe { g() }\n\
                   \x20   \";\n\
                   }\n";
        assert!(scan_unsafe_free("ssm/scan.rs", src).is_empty());
    }

    #[test]
    fn cast_rule_fires_on_bare_narrowing_and_dequant() {
        let bad = "fn f(v: i32, s: f32) -> f32 {\n\
                   \x20   let c = v as i8;\n\
                   \x20   c as f32 * s\n\
                   }\n";
        let fs = scan_casts("quant/mod.rs", bad);
        assert_eq!(fs.len(), 2, "{fs:?}");
        assert!(fs.iter().all(|f| f.rule == "bare-cast"));
    }

    #[test]
    fn cast_rule_honors_allow_marker_and_test_region() {
        let ok = "fn f(v: i32) -> i8 {\n\
                  \x20   v as i8 // audit:allow(cast) — range-checked\n\
                  }\n\
                  #[cfg(test)]\n\
                  mod tests {\n\
                  \x20   fn g(v: i32) -> i8 { v as i8 }\n\
                  }\n";
        assert!(scan_casts("quant/mod.rs", ok).is_empty());
    }

    #[test]
    fn guard_check_reads_only_the_named_body() {
        let src = "pub fn matmul_i8_blocked_with(k: usize) {\n\
                   \x20   debug_assert!(k <= MAX_SAFE_K);\n\
                   }\n\
                   pub fn other() {}\n";
        assert!(check_guard_present("quant/qlinear.rs", src, "matmul_i8_blocked_with", "MAX_SAFE_K")
            .is_empty());
        let missing = "pub fn matmul_i8_blocked_with(k: usize) {\n}\n\
                       // MAX_SAFE_K mentioned elsewhere, debug_assert! too — but\n\
                       // outside the body, so it must NOT satisfy the rule\n\
                       pub fn other() { debug_assert!(true); let _ = MAX_SAFE_K; }\n";
        assert_eq!(
            check_guard_present("quant/qlinear.rs", missing, "matmul_i8_blocked_with", "MAX_SAFE_K")
                .len(),
            1
        );
    }

    #[test]
    fn clock_discipline_fires_on_raw_reads_and_honors_conventions() {
        let bad = "fn f() {\n\
                   \x20   let t0 = std::time::Instant::now();\n\
                   \x20   let _ = SystemTime::now();\n\
                   }\n";
        let fs = scan_clock_discipline("coordinator/engine.rs", bad);
        assert_eq!(fs.len(), 2, "{fs:?}");
        assert!(fs.iter().all(|f| f.rule == "clock-discipline"));
        assert_eq!(fs[0].line, 2);
        // comments / strings / test regions don't count
        let ok = "fn f() {\n\
                  \x20   // Instant::now() is banned here\n\
                  \x20   let s = \"Instant::now()\";\n\
                  }\n\
                  #[cfg(test)]\n\
                  mod tests {\n\
                  \x20   fn t() { let _ = std::time::Instant::now(); }\n\
                  }\n";
        assert!(scan_clock_discipline("obs/trace.rs", ok).is_empty());
    }

    #[test]
    fn guard_check_distinguishes_the_two_bound_constants() {
        // an i8-bound guard must NOT satisfy the i4 requirement (the
        // whole-token match is what makes the tiers non-interchangeable)
        let i8_guard = "pub fn matmul_w4a8_with(k: usize) {\n\
                        \x20   debug_assert!(k <= MAX_SAFE_K);\n\
                        }\n";
        let fs = check_guard_present("quant/qlinear.rs", i8_guard, "matmul_w4a8_with", "MAX_SAFE_K_I4");
        assert_eq!(fs.len(), 1, "{fs:?}");
        assert!(fs[0].message.contains("MAX_SAFE_K_I4"), "{}", fs[0].message);
        // ...and the i4-bound guard must not satisfy the i8 requirement
        let i4_guard = "pub fn matmul_i8_blocked_with(k: usize) {\n\
                        \x20   debug_assert!(k <= MAX_SAFE_K_I4);\n\
                        }\n";
        assert_eq!(
            check_guard_present("quant/qlinear.rs", i4_guard, "matmul_i8_blocked_with", "MAX_SAFE_K")
                .len(),
            1
        );
        let i4_ok = "pub fn matmul_w4a8_with(k: usize) {\n\
                     \x20   debug_assert!(k <= quant::MAX_SAFE_K_I4);\n\
                     }\n";
        assert!(check_guard_present("quant/qlinear.rs", i4_ok, "matmul_w4a8_with", "MAX_SAFE_K_I4")
            .is_empty());
    }
}
