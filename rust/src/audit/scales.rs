//! Scale-propagation audit over `ssm/qmamba.rs`: every activation
//! scale baked at calibration must flow through the execution paths
//! exactly as it was folded.
//!
//! The ground truth is the source itself, so the audit is structural:
//!
//! 1. **field inventory** — every `s_*` field of `QLayer` and
//!    `QuantizedMambaModel` (the static per-tensor scales of the
//!    paper's W8A8 recipe) is discovered from the struct bodies, not
//!    hard-coded, so adding a scale automatically extends the audit.
//! 2. **produced exactly once** — each scale is bound exactly once in
//!    the `from_calibration` constructor body (field init `name:` or
//!    shorthand `name,`). A second binding site is how a stale/
//!    conflicting scale sneaks in.
//! 3. **consumed by both execution bodies** — each scale is read
//!    (`.name`) in `prefill_batch_impl` *and* `step_into`; the two
//!    paths must stay numerically identical (the prefill/decode
//!    bit-exactness contract), and a scale consumed by one but not the
//!    other is exactly how they'd diverge.
//! 4. **fold consistency** — the algebraic folds carry their written
//!    form: `s_conv = s_cin * conv_sw` (conv dequant folds the weight
//!    scale), the out_proj `fold_scale(1.0 / di ..)` (the Hadamard
//!    H·W_out fold absorbs 1/di into the weight scale), and
//!    `fwht.apply_rows` precedes `out_proj.forward_into` in both
//!    bodies (quantization happens in the rotated space — the entire
//!    point of the Hadamard transform).

use super::rules::{body_after, code_portion, has_token};
use super::Finding;

/// Audit `ssm/qmamba.rs` (`text`); returns findings plus the number of
/// scale fields traced.
pub fn audit_scales(rel: &str, text: &str) -> (Vec<Finding>, usize) {
    let mut out = Vec::new();
    let whole = |message: String, rule: &'static str| Finding {
        rule,
        file: rel.to_string(),
        line: 0,
        message,
    };

    // 1. field inventory from the struct bodies
    let mut fields = Vec::new();
    for strukt in ["QLayer", "QuantizedMambaModel"] {
        match text.find(&format!("struct {strukt}")) {
            Some(at) => fields.extend(scale_fields(&body_after(text, at))),
            None => out.push(whole(format!("struct {strukt} not found"), "scale-flow")),
        }
    }
    if fields.is_empty() {
        out.push(whole("no s_* scale fields discovered".into(), "scale-flow"));
        return (out, 0);
    }

    let Some(ctor_at) = text.find("fn from_calibration") else {
        out.push(whole("fn from_calibration not found".into(), "scale-flow"));
        return (out, fields.len());
    };
    let ctor = body_after(text, ctor_at);

    let mut exec_bodies = Vec::new();
    for exec in ["prefill_batch_impl", "step_into"] {
        match text.find(&format!("fn {exec}")) {
            Some(at) => exec_bodies.push((exec, body_after(text, at))),
            None => out.push(whole(format!("fn {exec} not found"), "scale-flow")),
        }
    }

    for name in &fields {
        // 2. produced exactly once in from_calibration
        let produced = ctor
            .lines()
            .map(|l| {
                let t = code_portion(l);
                let t = t.trim();
                usize::from(t.starts_with(&format!("{name}:")) || t == format!("{name},"))
            })
            .sum::<usize>();
        if produced != 1 {
            out.push(whole(
                format!("scale `{name}` initialized {produced} times in from_calibration (want exactly 1)"),
                "scale-flow",
            ));
        }
        // 3. consumed by both execution bodies
        for (exec, body) in &exec_bodies {
            if !consumes(body, name) {
                out.push(whole(
                    format!(
                        "scale `{name}` is never read (`.{name}`) in `{exec}` — the \
                         prefill/decode paths would diverge from the calibrated fold"
                    ),
                    "scale-flow",
                ));
            }
        }
    }

    // 4. fold consistency
    let conv_fold = ctor.lines().any(|l| {
        let c = code_portion(l);
        c.contains("s_conv:") && has_token(&c, "s_cin") && c.contains('*')
    });
    if fields.iter().any(|f| f == "s_conv") && !conv_fold {
        out.push(whole(
            "`s_conv` is not folded from `s_cin * <conv weight scale>` in from_calibration".into(),
            "scale-flow",
        ));
    }
    let out_fold = ctor
        .lines()
        .any(|l| l.contains("out_proj:") && l.contains("fold_scale(1.0 / di"));
    if !out_fold {
        out.push(whole(
            "out_proj is not built with `fold_scale(1.0 / di ..)` — the Hadamard \
             H·W_out fold must absorb 1/di into the weight scale"
                .into(),
            "scale-flow",
        ));
    }
    for (exec, body) in &exec_bodies {
        let rot = body.find("fwht.apply_rows");
        let proj = body.find("out_proj.forward_into");
        match (rot, proj) {
            (Some(r), Some(p)) if r < p => {}
            _ => out.push(whole(
                format!(
                    "`{exec}` must rotate (`fwht.apply_rows`) before projecting \
                     (`out_proj.forward_into`) — out_proj scales live in the rotated space"
                ),
                "scale-flow",
            )),
        }
    }

    (out, fields.len())
}

/// `s_*`-named fields declared in a struct body (one per line,
/// `name: Type,` — rustfmt layout).
fn scale_fields(body: &str) -> Vec<String> {
    let mut out = Vec::new();
    for line in body.lines() {
        let code = code_portion(line);
        let t = code.trim().trim_start_matches("pub ").trim_start_matches("pub(crate) ");
        if let Some(colon) = t.find(':') {
            let name = t[..colon].trim();
            if name.starts_with("s_")
                && name.bytes().all(|b| b.is_ascii_lowercase() || b == b'_' || b.is_ascii_digit())
            {
                out.push(name.to_string());
            }
        }
    }
    out
}

/// Is `.name` read anywhere in `body` (word boundary after the name,
/// so `.s_x` doesn't match `.s_xin`)?
fn consumes(body: &str, name: &str) -> bool {
    let pat = format!(".{name}");
    let bytes = body.as_bytes();
    let mut start = 0;
    while let Some(pos) = body[start..].find(&pat) {
        let end = start + pos + pat.len();
        if end >= bytes.len() || !(bytes[end].is_ascii_alphanumeric() || bytes[end] == b'_') {
            return true;
        }
        start = start + pos + 1;
    }
    false
}

#[cfg(test)]
mod tests {
    use super::*;

    // a minimal qmamba-shaped source the rules can chew on; built by
    // concatenation so the audit of *this* file's own source doesn't
    // see struct/fn tokens inside the fixture
    fn fixture() -> String {
        [
            "struct QLayer {",
            "    s_xin: f32,",
            "    s_cin: f32,",
            "    s_conv: f32,",
            "    other: usize,",
            "}",
            "struct QuantizedMambaModel {",
            "    s_head_in: f32,",
            "}",
            "impl QuantizedMambaModel {",
            "    fn from_calibration() -> Self {",
            "        let s_cin = scale(1.0);",
            "        layers.push(QLayer {",
            "            s_xin: scale(2.0),",
            "            s_cin,",
            "            s_conv: s_cin * conv_sw,",
            "            out_proj: QLinear::from_f32(&w, di, d, None).fold_scale(1.0 / di as f32),",
            "        });",
            "        Self {",
            "            s_head_in: scale(3.0),",
            "        }",
            "    }",
            "    fn prefill_batch_impl(&self) {",
            "        use_scale(ql.s_xin, ql.s_cin, ql.s_conv, self.s_head_in);",
            "        ql.fwht.apply_rows(gated);",
            "        ql.out_proj.forward_into(kers, gated);",
            "    }",
            "    fn step_into(&self) {",
            "        use_scale(ql.s_xin, ql.s_cin, ql.s_conv, self.s_head_in);",
            "        ql.fwht.apply_rows(gated);",
            "        ql.out_proj.forward_into(kers, gated);",
            "    }",
            "}",
        ]
        .join("\n")
    }

    #[test]
    fn clean_fixture_passes_and_counts_scales() {
        let (fs, n) = audit_scales("ssm/qmamba.rs", &fixture());
        assert!(fs.is_empty(), "{fs:?}");
        assert_eq!(n, 4); // s_xin, s_cin, s_conv, s_head_in
    }

    #[test]
    fn unconsumed_scale_is_flagged_per_exec_body() {
        let src = fixture().replace(
            "use_scale(ql.s_xin, ql.s_cin, ql.s_conv, self.s_head_in);\n        ql.fwht.apply_rows(gated);\n        ql.out_proj.forward_into(kers, gated);\n    }\n    fn step_into",
            "use_scale(ql.s_cin, ql.s_conv, self.s_head_in);\n        ql.fwht.apply_rows(gated);\n        ql.out_proj.forward_into(kers, gated);\n    }\n    fn step_into",
        );
        assert_ne!(src, fixture(), "replacement must hit prefill_batch_impl");
        let (fs, _) = audit_scales("ssm/qmamba.rs", &src);
        assert_eq!(fs.len(), 1, "{fs:?}");
        assert!(fs[0].message.contains("s_xin") && fs[0].message.contains("prefill_batch_impl"));
    }

    #[test]
    fn double_production_is_flagged() {
        let src = fixture().replace(
            "            s_xin: scale(2.0),",
            "            s_xin: scale(2.0),\n            s_xin: scale(9.0),",
        );
        let (fs, _) = audit_scales("ssm/qmamba.rs", &src);
        assert_eq!(fs.len(), 1, "{fs:?}");
        assert!(fs[0].message.contains("initialized 2 times"), "{}", fs[0].message);
    }

    #[test]
    fn broken_conv_fold_is_flagged() {
        let src = fixture().replace("s_conv: s_cin * conv_sw,", "s_conv: scale(4.0),");
        let (fs, _) = audit_scales("ssm/qmamba.rs", &src);
        assert_eq!(fs.len(), 1, "{fs:?}");
        assert!(fs[0].message.contains("s_conv"), "{}", fs[0].message);
    }

    #[test]
    fn rotate_after_project_is_flagged() {
        let src = fixture().replace(
            "    fn step_into(&self) {\n        use_scale(ql.s_xin, ql.s_cin, ql.s_conv, self.s_head_in);\n        ql.fwht.apply_rows(gated);\n        ql.out_proj.forward_into(kers, gated);",
            "    fn step_into(&self) {\n        use_scale(ql.s_xin, ql.s_cin, ql.s_conv, self.s_head_in);\n        ql.out_proj.forward_into(kers, gated);\n        ql.fwht.apply_rows(gated);",
        );
        assert_ne!(src, fixture());
        let (fs, _) = audit_scales("ssm/qmamba.rs", &src);
        assert_eq!(fs.len(), 1, "{fs:?}");
        assert!(fs[0].message.contains("step_into"), "{}", fs[0].message);
    }

    #[test]
    fn prefix_scales_do_not_shadow_each_other() {
        // `.s_x` must not be satisfied by `.s_xin`
        assert!(consumes("a.s_xin; b.s_x;", "s_x"));
        assert!(!consumes("a.s_xin;", "s_x"));
    }
}
