//! Evaluation harness (the lm-eval substitute): perplexity over token
//! streams and likelihood-scored zero-shot tasks, all through the AOT
//! prefill graphs — the same code path serving uses, so every accuracy
//! number in the tables reflects the deployed quantized model.

use anyhow::{anyhow, Result};

use crate::config::Manifest;
use crate::data::{Example, Task};
use crate::runtime::Runtime;
use crate::tensor::{DType, Tensor};

/// Perplexity of a (tier, method) model over a token stream, evaluated
/// on non-overlapping windows through the (B=4, T) prefill graph.
pub struct PplResult {
    pub ppl: f64,
    pub nll_sum: f64,
    pub n_tokens: usize,
    pub n_windows: usize,
}

fn log_softmax_pick(logits: &[f32], v: usize, pick: usize) -> f64 {
    let m = logits.iter().take(v).cloned().fold(f32::NEG_INFINITY, f32::max);
    let mut z = 0.0f64;
    for &l in logits.iter().take(v) {
        z += ((l - m) as f64).exp();
    }
    (logits[pick] - m) as f64 - z.ln()
}

fn zero_states(mani: &Manifest, tier: &str, b: usize) -> Result<(Tensor, Tensor)> {
    let t = mani
        .tiers
        .get(tier)
        .ok_or_else(|| anyhow!("unknown tier {tier}"))?;
    Ok((
        Tensor::zeros(DType::F32, &[t.n_layer, b, t.d_conv - 1, t.d_inner]),
        Tensor::zeros(DType::F32, &[t.n_layer, b, t.d_inner, t.d_state]),
    ))
}

fn transformer_zero(mani: &Manifest, tier: &str, b: usize) -> Result<(Tensor, Tensor)> {
    let t = mani
        .transformer_tiers
        .get(tier)
        .ok_or_else(|| anyhow!("unknown transformer tier {tier}"))?;
    let shape = [t.n_layer, b, t.max_ctx, t.n_head, t.d_model / t.n_head];
    Ok((Tensor::zeros(DType::F32, &shape), Tensor::zeros(DType::F32, &shape)))
}

/// Run a prefill graph on a batch of fixed-length windows; returns the
/// logits tensor (B, T, V).
pub fn run_prefill(rt: &mut Runtime, graph: &str, tokens: &[i32], b: usize, t: usize) -> Result<Tensor> {
    let info = rt
        .manifest()
        .graphs
        .get(graph)
        .ok_or_else(|| anyhow!("unknown graph {graph}"))?
        .clone();
    let tok = Tensor::from_i32(&[b, t], tokens);
    let outputs = match info.family.as_str() {
        "transformer" => {
            let (k, v) = transformer_zero(rt.manifest(), &info.tier, b)?;
            let clen = Tensor::from_i32(&[], &[0]);
            rt.execute(graph, &[tok, k, v, clen])?
        }
        "hybrid" => rt.execute(graph, &[tok])?, // stateless jamba combos
        _ => {
            let (conv, ssm) = zero_states(rt.manifest(), &info.tier, b)?;
            rt.execute(graph, &[tok, conv, ssm])?
        }
    };
    Ok(outputs.into_iter().next().unwrap())
}

pub fn perplexity(
    rt: &mut Runtime,
    tier: &str,
    method: &str,
    stream: &[u16],
    max_windows: usize,
) -> Result<PplResult> {
    // prefer the B=4 eval graph; fall back to B=1
    let mani = rt.manifest();
    let vocab = mani.vocab_size;
    let (graph, b, t) = pick_ppl_graph(mani, tier, method)?;
    let per_call = b * t;
    let mut nll = 0.0f64;
    let mut count = 0usize;
    let mut windows = 0usize;
    let mut pos = 0usize;
    while pos + per_call + 1 <= stream.len() && windows < max_windows {
        let mut toks = Vec::with_capacity(per_call);
        for i in 0..per_call {
            toks.push(stream[pos + i] as i32);
        }
        let logits = run_prefill(rt, &graph, &toks, b, t)?;
        let lf = logits.to_f32();
        let v = logits.shape[2];
        for bi in 0..b {
            for ti in 0..t - 1 {
                let next = stream[pos + bi * t + ti + 1] as usize;
                let row = &lf[(bi * t + ti) * v..(bi * t + ti + 1) * v];
                nll -= log_softmax_pick(row, vocab, next);
                count += 1;
            }
        }
        pos += per_call;
        windows += b;
    }
    if count == 0 {
        return Err(anyhow!("stream too short for {graph}"));
    }
    Ok(PplResult {
        ppl: (nll / count as f64).exp(),
        nll_sum: nll,
        n_tokens: count,
        n_windows: windows,
    })
}

fn pick_ppl_graph(mani: &Manifest, tier: &str, method: &str) -> Result<(String, usize, usize)> {
    for want_b in [4usize, 1] {
        let mut best: Option<(&str, usize, usize)> = None;
        for g in mani.graphs.values() {
            if g.tier == tier && g.method == method && g.kind == "prefill" && g.batch == want_b
                && g.seq >= 64
            {
                if best.map(|(_, _, s)| g.seq < s).unwrap_or(true) {
                    best = Some((&g.name, g.batch, g.seq));
                }
            }
        }
        if let Some((n, b, t)) = best {
            return Ok((n.to_string(), b, t));
        }
    }
    Err(anyhow!("no prefill graph for {tier}/{method}"))
}

/// Task accuracy via likelihood scoring through the (B=8, T_task)
/// prefill graph. Sequences are right-padded; only live positions are
/// read. Returns per-task accuracy in task order.
pub fn run_tasks(
    rt: &mut Runtime,
    tier: &str,
    method: &str,
    tasks: &[Task],
    max_examples: usize,
) -> Result<Vec<(String, f64)>> {
    let mani = rt.manifest();
    let vocab = mani.vocab_size;
    let (graph, b, t) = pick_task_graph(mani, tier, method)?;

    // Flatten every (example, choice) into one scored sequence.
    struct Seq {
        tokens: Vec<u16>,
        score_from: usize, // first predicted position (prompt_len - 1)
        task: usize,
        example: usize,
        choice: usize, // usize::MAX = exact-match probe
        target: u16,
    }
    let mut seqs = Vec::new();
    for (tidx, task) in tasks.iter().enumerate() {
        for (eidx, ex) in task.examples.iter().take(max_examples).enumerate() {
            match ex {
                Example::ExactLast { prompt, target } => {
                    let mut toks = prompt.clone();
                    toks.truncate(t);
                    seqs.push(Seq {
                        score_from: toks.len() - 1,
                        tokens: toks,
                        task: tidx,
                        example: eidx,
                        choice: usize::MAX,
                        target: target[0],
                    });
                }
                Example::Choice { prompt, choices, .. } => {
                    for (ci, ch) in choices.iter().enumerate() {
                        let mut toks = prompt.clone();
                        let keep_prompt = prompt.len().min(t - ch.len());
                        toks.truncate(keep_prompt);
                        let score_from = toks.len() - 1;
                        toks.extend_from_slice(ch);
                        seqs.push(Seq {
                            tokens: toks,
                            score_from,
                            task: tidx,
                            example: eidx,
                            choice: ci,
                            target: 0,
                        });
                    }
                }
            }
        }
    }

    // score all sequences in batches of `b`
    let mut scores = vec![0.0f64; seqs.len()];
    let mut exact_hits = vec![false; seqs.len()];
    for chunk_start in (0..seqs.len()).step_by(b) {
        let chunk = &seqs[chunk_start..(chunk_start + b).min(seqs.len())];
        let mut toks = vec![0i32; b * t];
        for (bi, s) in chunk.iter().enumerate() {
            for (i, &tk) in s.tokens.iter().enumerate().take(t) {
                toks[bi * t + i] = tk as i32;
            }
        }
        let logits = run_prefill(rt, &graph, &toks, b, t)?;
        let lf = logits.to_f32();
        let v = logits.shape[2];
        for (bi, s) in chunk.iter().enumerate() {
            if s.choice == usize::MAX {
                // exact match: argmax over the last prompt position
                let row = &lf[(bi * t + s.score_from) * v..(bi * t + s.score_from + 1) * v];
                let mut arg = 0usize;
                for j in 1..vocab {
                    if row[j] > row[arg] {
                        arg = j;
                    }
                }
                exact_hits[chunk_start + bi] = arg == s.target as usize;
            } else {
                let mut lp = 0.0f64;
                for i in s.score_from..s.tokens.len() - 1 {
                    let row = &lf[(bi * t + i) * v..(bi * t + i + 1) * v];
                    lp += log_softmax_pick(row, vocab, s.tokens[i + 1] as usize);
                }
                scores[chunk_start + bi] = lp;
            }
        }
    }

    // aggregate per task
    let mut results = Vec::new();
    for (tidx, task) in tasks.iter().enumerate() {
        let n = task.examples.len().min(max_examples);
        if n == 0 {
            results.push((task.name.clone(), f64::NAN));
            continue;
        }
        let mut correct = 0usize;
        match task.kind.as_str() {
            "exact_last" => {
                for (si, s) in seqs.iter().enumerate() {
                    if s.task == tidx && exact_hits[si] {
                        correct += 1;
                    }
                }
            }
            kind => {
                let norm = kind == "choice_norm";
                for (eidx, ex) in task.examples.iter().take(max_examples).enumerate() {
                    if let Example::Choice { choices, gold, .. } = ex {
                        let mut best = (f64::NEG_INFINITY, 0usize);
                        for (si, s) in seqs.iter().enumerate() {
                            if s.task == tidx && s.example == eidx && s.choice != usize::MAX {
                                let len = choices[s.choice].len().max(1) as f64;
                                let sc = if norm { scores[si] / len } else { scores[si] };
                                if sc > best.0 {
                                    best = (sc, s.choice);
                                }
                            }
                        }
                        if best.1 == *gold {
                            correct += 1;
                        }
                    }
                }
            }
        }
        results.push((task.name.clone(), correct as f64 / n as f64));
    }
    Ok(results)
}

fn pick_task_graph(mani: &Manifest, tier: &str, method: &str) -> Result<(String, usize, usize)> {
    for want_b in [8usize, 4, 1] {
        let mut best: Option<(&str, usize, usize)> = None;
        for g in mani.graphs.values() {
            if g.tier == tier && g.method == method && g.kind == "prefill" && g.batch == want_b {
                if best.map(|(_, _, s)| g.seq < s).unwrap_or(true) {
                    best = Some((&g.name, g.batch, g.seq));
                }
            }
        }
        if let Some((n, b, t)) = best {
            return Ok((n.to_string(), b, t));
        }
    }
    Err(anyhow!("no task graph for {tier}/{method}"))
}

/// Average of the per-task accuracies (the paper's "Avg." column).
pub fn average_accuracy(results: &[(String, f64)]) -> f64 {
    let vals: Vec<f64> = results.iter().map(|(_, a)| *a).filter(|a| !a.is_nan()).collect();
    vals.iter().sum::<f64>() / vals.len().max(1) as f64
}
