//! `NativeEngine`: the artifact-free serving backend. Same scheduler
//! shape as [`super::engine::Engine`] — prefill-priority admission,
//! bucketed continuous decode batching via [`super::batcher`], the
//! constant-size [`SsmStatePool`] — but execution goes through a
//! [`StepModel`] (fp32 reference or the W8A8
//! [`crate::ssm::QuantizedMambaModel`]) instead of AOT XLA graphs.
//! This is the "no-artifact edge serving" scenario: a coordinator that
//! can come up on a bare machine with nothing but weights (or a
//! synthetic tier) and still expose the identical
//! `submit`/`step`/`run_to_completion`/`Metrics` surface.

use std::collections::VecDeque;

use anyhow::Result;

use crate::coordinator::batcher;
use crate::coordinator::metrics::Metrics;
use crate::coordinator::request::{LiveRequest, Request, Response};
use crate::coordinator::sampler::Sampler;
use crate::coordinator::state::SsmStatePool;
use crate::data::BOS;
use crate::ssm::{MambaState, StepModel};

#[derive(Debug, Clone)]
pub struct NativeEngineConfig {
    /// state-pool capacity (max concurrent requests)
    pub capacity: usize,
    /// admission limit per tick
    pub max_prefills_per_tick: usize,
    /// decode-round lane buckets (ascending). The native backend can
    /// run any batch size, but bucketing keeps the scheduling identical
    /// to the AOT deployment shape so the two backends are comparable.
    pub decode_buckets: Vec<usize>,
}

impl Default for NativeEngineConfig {
    fn default() -> Self {
        NativeEngineConfig {
            capacity: 32,
            max_prefills_per_tick: 2,
            decode_buckets: vec![1, 2, 4, 8],
        }
    }
}

pub struct NativeEngine {
    pub cfg: NativeEngineConfig,
    model: Box<dyn StepModel + Send>,
    pool: SsmStatePool,
    queue: VecDeque<Request>,
    live: Vec<LiveRequest>,
    done: Vec<Response>,
    sampler: Sampler,
    pub metrics: Metrics,
    vocab: usize,
}

impl NativeEngine {
    pub fn new(model: Box<dyn StepModel + Send>, cfg: NativeEngineConfig) -> NativeEngine {
        assert!(!cfg.decode_buckets.is_empty(), "need at least one decode bucket");
        let t = model.tier();
        let pool = SsmStatePool::with_dims(t.n_layer, t.d_inner, t.d_conv, t.d_state, cfg.capacity);
        let vocab = t.vocab;
        NativeEngine {
            pool,
            queue: VecDeque::new(),
            live: Vec::new(),
            done: Vec::new(),
            sampler: Sampler::new(0xC0FFEE),
            metrics: Metrics::new(),
            vocab,
            model,
            cfg,
        }
    }

    pub fn decode_buckets(&self) -> &[usize] {
        &self.cfg.decode_buckets
    }

    pub fn submit(&mut self, req: Request) {
        self.queue.push_back(req);
    }

    pub fn n_queued(&self) -> usize {
        self.queue.len()
    }

    pub fn n_live(&self) -> usize {
        self.live.len()
    }

    pub fn state_bytes_per_request(&self) -> usize {
        self.pool.bytes_per_request()
    }

    /// Tokens generated so far (live requests + completed).
    pub fn tokens_generated(&self) -> usize {
        self.live.iter().map(|lr| lr.generated.len()).sum::<usize>()
            + self.metrics.tokens_out as usize
    }

    /// Run one scheduler tick: admit + prefill a few queued requests,
    /// then one decode round over all live requests. Returns finished
    /// responses (also retained for `take_done`). Result-typed for
    /// interface parity with [`super::engine::Engine::step`]; the
    /// native path itself cannot fail.
    pub fn step(&mut self) -> Result<Vec<Response>> {
        for _ in 0..self.cfg.max_prefills_per_tick {
            if self.queue.is_empty() || self.pool.in_use() >= self.pool.capacity() {
                break;
            }
            let req = self.queue.pop_front().unwrap();
            self.prefill(req);
        }
        if !self.live.is_empty() {
            self.decode_tick();
        }
        let mut finished = Vec::new();
        let mut i = 0;
        while i < self.live.len() {
            if self.live[i].done() {
                let lr = self.live.swap_remove(i);
                self.pool.release(lr.state_slot);
                let resp = lr.into_response();
                self.metrics.record_response(
                    resp.ttft_ms,
                    resp.tpot_ms,
                    resp.ttlt_ms,
                    resp.tokens.len(),
                );
                finished.push(resp);
            } else {
                i += 1;
            }
        }
        self.done.extend(finished.iter().cloned());
        Ok(finished)
    }

    /// Drive until everything queued + live has finished.
    pub fn run_to_completion(&mut self) -> Result<Vec<Response>> {
        while !self.queue.is_empty() || !self.live.is_empty() {
            self.step()?;
        }
        Ok(std::mem::take(&mut self.done))
    }

    pub fn take_done(&mut self) -> Vec<Response> {
        std::mem::take(&mut self.done)
    }

    fn prefill(&mut self, req: Request) {
        let slot = self.pool.alloc().expect("state pool exhausted (checked above)");
        // no graph-length padding: the native model ingests any T, so
        // empty prompts just become a lone BOS
        let prompt: Vec<u16> =
            if req.prompt.is_empty() { vec![BOS] } else { req.prompt.clone() };
        let mut lr = LiveRequest::new(req, slot);
        let t0 = std::time::Instant::now();
        let mut state = MambaState::new(self.model.tier(), 1);
        let logits = self.model.prefill(&prompt, &mut state);
        self.metrics.prefill_ms.record(t0.elapsed().as_secs_f64() * 1e3);
        let (conv, ssm) = state.into_raw();
        self.pool.scatter_raw(&[slot], 1, &conv, &ssm);
        let t = prompt.len();
        let v = self.vocab;
        let row = &logits[(t - 1) * v..t * v];
        let tok = self.sampler.sample(row, v, &lr.req.params);
        lr.generated.push(tok);
        lr.prefill_done = Some(std::time::Instant::now());
        lr.last_token = lr.prefill_done;
        self.live.push(lr);
    }

    fn decode_tick(&mut self) {
        let n = self.live.len();
        let plan = batcher::plan_rounds(n, &self.cfg.decode_buckets);
        let groups = batcher::assign(n, &plan);
        for (gi, group) in groups.iter().enumerate() {
            let b = plan[gi];
            self.metrics.record_round(b, group.len());
            self.decode_round(group, b);
        }
    }

    fn decode_round(&mut self, group: &[usize], b: usize) {
        let slots: Vec<usize> = group.iter().map(|&i| self.live[i].state_slot).collect();
        let (conv, ssm) = self.pool.gather_raw(&slots, b);
        let mut toks = vec![BOS; b]; // padded lanes run a throwaway BOS
        for (bi, &i) in group.iter().enumerate() {
            toks[bi] = self.live[i].next_input_token();
        }
        let mut state = MambaState::from_raw(self.model.tier(), b, conv, ssm);
        let t0 = std::time::Instant::now();
        let logits = self.model.step(&toks, &mut state);
        self.metrics.decode_step_ms.record(t0.elapsed().as_secs_f64() * 1e3);
        let (conv_o, ssm_o) = state.into_raw();
        // only live slots are scattered back; padded-lane outputs drop
        self.pool.scatter_raw(&slots, b, &conv_o, &ssm_o);
        let v = self.vocab;
        for (bi, &i) in group.iter().enumerate() {
            let row = &logits[bi * v..(bi + 1) * v];
            let lr = &mut self.live[i];
            let tok = self.sampler.sample(row, v, &lr.req.params);
            lr.generated.push(tok);
            let now = std::time::Instant::now();
            if let Some(last) = lr.last_token {
                lr.decode_ms.push((now - last).as_secs_f64() * 1e3);
            }
            lr.last_token = Some(now);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::SamplingParams;
    use crate::ssm::{MambaModel, MambaTier};

    fn tier() -> MambaTier {
        MambaTier {
            name: "nat".into(),
            d_model: 8,
            n_layer: 2,
            d_state: 4,
            d_conv: 4,
            d_inner: 16,
            dt_rank: 2,
            vocab: 16,
        }
    }

    fn req(id: u64, prompt: Vec<u16>, max_new: usize) -> Request {
        Request {
            id,
            prompt,
            max_new_tokens: max_new,
            params: SamplingParams::default(),
            stop_at_eos: false,
        }
    }

    #[test]
    fn serves_multi_request_workload() {
        let model = MambaModel::synthetic(tier(), 13);
        let mut eng = NativeEngine::new(Box::new(model), NativeEngineConfig::default());
        for i in 0..10u64 {
            let plen = 2 + (i as usize % 5);
            eng.submit(req(i, (0..plen).map(|j| (j % 16) as u16).collect(), 5 + i as usize % 4));
        }
        let done = eng.run_to_completion().unwrap();
        assert_eq!(done.len(), 10);
        assert_eq!(eng.metrics.requests_done, 10);
        for r in &done {
            let want = 5 + r.id as usize % 4;
            assert_eq!(r.tokens.len(), want, "request {} token count", r.id);
        }
        assert_eq!(eng.n_live(), 0);
        assert_eq!(eng.n_queued(), 0);
    }

    #[test]
    fn empty_prompt_served_as_bos() {
        let model = MambaModel::synthetic(tier(), 13);
        let mut eng = NativeEngine::new(Box::new(model), NativeEngineConfig::default());
        eng.submit(req(1, vec![], 3));
        let done = eng.run_to_completion().unwrap();
        assert_eq!(done[0].tokens.len(), 3);
    }

    #[test]
    fn capacity_backpressure_queues_excess() {
        let model = MambaModel::synthetic(tier(), 13);
        let cfg = NativeEngineConfig { capacity: 2, max_prefills_per_tick: 8, ..Default::default() };
        let mut eng = NativeEngine::new(Box::new(model), cfg);
        for i in 0..5u64 {
            eng.submit(req(i, vec![1, 2, 3], 4));
        }
        eng.step().unwrap();
        assert!(eng.n_live() <= 2);
        assert!(eng.n_queued() >= 3);
        let done = eng.run_to_completion().unwrap();
        assert_eq!(done.len(), 5);
    }
}
