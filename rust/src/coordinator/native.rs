//! `NativeEngine`: the artifact-free serving backend, driven by a
//! **unified chunked-prefill scheduler**.
//!
//! Where the XLA [`super::engine::Engine`] must run two-phase ticks
//! (inline whole-prompt prefill at admission, then bucketed decode
//! rounds — its AOT graphs cannot pause mid-prompt), this engine runs
//! ONE step-loop: every tick assembles a single mixed work plan
//! ([`batcher::plan_tick`]) under a token budget
//! (`max_tokens_per_tick`) that packs
//!
//! * all decode lanes (1 token each — inter-token latency is the
//!   protected quantity), batched into minimum-padding bucket rounds
//!   exactly as before, and
//! * prefill **chunks**: every in-flight prompt advances by up to
//!   `prefill_chunk` tokens, all scheduled prompts together as one
//!   (B, T) batched execution ([`StepModel::prefill_batch_into`] —
//!   ragged chunks padded to the chunk grid, projections as one
//!   B·T_max-row int8 GEMM, conv/scan per lane over carried state).
//!
//! A 2k-token prompt therefore no longer freezes every live lane for
//! a whole prompt's worth of compute: it advances `prefill_chunk`
//! tokens per tick while decode keeps ticking (paper §1 / Table 1:
//! bounded generation latency under request-intensive load). SSMs are
//! uniquely suited to this — the recurrent state is constant-size, so
//! a prefill pauses at any token boundary for free, and chunking is
//! **bit-exact** (`rust/tests/chunked_prefill.rs`).
//!
//! Cold, warm (prefix-cache hit) and resumed prefills all flow
//! through the same chunk queue: admission probes the trie, restores
//! the longest cached prefix into the request's pool slot and enqueues
//! the *suffix* as an ordinary partially-consumed prompt
//! ([`Phase::Prefilling`]); a full-prompt hit samples from the cached
//! logits row and joins decode with zero model execution. Chunk ends
//! snap to the `snapshot_stride` grid, so chunked prefills emit the
//! identical nested-prefix snapshots the old whole-prompt path did.
//!
//! Hot-path properties (PR 2–5):
//! * decode rounds execute out of per-round reusable
//!   [`StepScratch`]es — no per-step allocation in the model after
//!   warmup (asserted in `rust/tests/zero_alloc.rs`, which also holds
//!   the chunked (B, T) prefill body to the zero-alloc standard);
//! * quantized models get an i8 conv-window pool
//!   ([`SsmStatePool::with_quantized_conv`], quarter the conv state
//!   bytes);
//! * `threads > 1` parallelizes decode across groups (or lanes of a
//!   lone group) — **bit-identical** to `threads = 1`;
//! * the int8 hot paths run on the [`Kernels`] SIMD dispatch
//!   (`NativeEngineConfig::kernel_backend`) — bit-identical across
//!   backends;
//! * every request samples from its **own** RNG stream
//!   ([`LiveRequest::rng`]): chunk size, token budget, cache hits and
//!   thread count can move *when* a request's tokens are produced,
//!   never *which* tokens — the scheduler is latency policy, not
//!   sampling policy.
//!
//! # Failure model (ISSUE 7)
//!
//! The engine degrades, it does not die (`docs/ARCHITECTURE.md` §7):
//!
//! * **admission control** — `max_queue` bounds the submit queue;
//!   overflow is rejected immediately with a typed
//!   [`FinishReason::Rejected`] response ([`Self::try_submit`]);
//! * **deadlines** — TTFT / total-latency deadlines are swept at tick
//!   boundaries against the injectable [`Clock`], so expiry is
//!   deterministic under `Clock::Manual`;
//! * **cancellation** — [`Self::cancel`] retires a queued or live
//!   request, keeping its partial tokens;
//! * **panic isolation** — decode rounds, prefill sub-rounds and
//!   snapshot inserts run inside `catch_unwind`. The model only ever
//!   executes against a *copy* of the pool state
//!   ([`SsmStatePool::gather_state`]) and writes back only after a
//!   clean run, so a panicked round leaves the pool pristine: the
//!   victim fails alone ([`FinishReason::Failed`]) and the survivors
//!   re-execute **bit-identically** to a run where the victim was
//!   never admitted (same invariant shape as cache-moves-TTFT-never-
//!   tokens);
//! * **one reclaim point** — every request leaves the live set through
//!   [`Self::finish_live`], which releases exactly its pool slot;
//!   `quamba-audit`'s `slot-reclaim` rule machine-checks that
//!   confinement, and the chaos suite (`rust/tests/chaos.rs`) fuzzes
//!   seeded [`FaultPlan`] schedules asserting slot/request
//!   conservation after every tick.

use std::any::Any;
use std::collections::VecDeque;
use std::panic::{catch_unwind, AssertUnwindSafe};

use anyhow::Result;

use crate::cache::{CacheStats, PrefixCache, PrefixCacheConfig, Snapshot};
use crate::coordinator::batcher;
use crate::coordinator::engine::DEFAULT_SAMPLER_SEED;
use crate::coordinator::faults::{
    panic_message, Clock, FaultPlan, FaultSite, InjectedFault, WallAnchor,
};
use crate::coordinator::metrics::{Metrics, MetricsSnapshot};
use crate::coordinator::request::{
    FinishReason, LiveRequest, Phase, Request, RequestId, Response, SpecState,
};
use crate::coordinator::sampler;
use crate::coordinator::state::{SsmSlab, SsmStatePool};
use crate::data::BOS;
use crate::obs::trace::{SpanKind, SpanRecord, TraceRing, NO_REQ};
use crate::quant::{KernelBackend, Kernels};
use crate::ssm::{verify_row, MambaState, StepModel, StepScratch};
use crate::util::rng::Pcg32;

/// Which draft-model family the CLI builds for the speculative-decode
/// tier (`quamba serve --spec-draft`). Advisory metadata like
/// `NativeEngineConfig::weight_bits`: the engine itself receives a
/// pre-built draft [`StepModel`] via [`NativeEngine::with_draft`], so
/// this records the choice for telemetry and CLI plumbing.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum SpecDraft {
    /// W4A8 packed-nibble twin of the W8A8 target (default): same
    /// calibration, half the GEMM weight bytes — the memory-bound
    /// decode GEMMs run ~2× lighter, and the shared calibration keeps
    /// acceptance high
    #[default]
    W4A8,
    /// the fp32 reference model drafting for a quantized target (the
    /// configurable alternative; higher-fidelity proposals at fp32
    /// compute cost)
    Fp32,
}

impl SpecDraft {
    /// CLI label (`--spec-draft w4a8|fp32`).
    pub fn label(self) -> &'static str {
        match self {
            SpecDraft::W4A8 => "w4a8",
            SpecDraft::Fp32 => "fp32",
        }
    }

    /// Parse a `--spec-draft` argument.
    pub fn parse(s: &str) -> Option<SpecDraft> {
        match s {
            "w4a8" => Some(SpecDraft::W4A8),
            "fp32" => Some(SpecDraft::Fp32),
            _ => None,
        }
    }
}

/// Consecutive zero-accept speculative rounds before a lane degrades
/// to plain decode permanently (`SpecState::dry_rounds` threshold):
/// adversarial prompts stop paying the draft cost.
const SPEC_DRY_LIMIT: u32 = 4;

#[derive(Debug, Clone)]
pub struct NativeEngineConfig {
    /// state-pool capacity (max concurrent requests)
    pub capacity: usize,
    /// admissions per tick into the chunk queue (backpressure on the
    /// scheduler's bookkeeping; prompt *work* is paced by
    /// `prefill_chunk` / `max_tokens_per_tick`, not by this)
    pub max_prefills_per_tick: usize,
    /// decode-round lane buckets (ascending). The native backend can
    /// run any batch size, but bucketing keeps the scheduling identical
    /// to the AOT deployment shape so the two backends are comparable.
    pub decode_buckets: Vec<usize>,
    /// decode worker threads. 1 (default) is the fully sequential
    /// path; >1 runs decode rounds on at most `threads` scoped workers
    /// (and lane-splits a lone round) — output tokens are bit-identical
    /// either way.
    pub threads: usize,
    /// engine-level sampler seed; each request derives its own RNG
    /// stream from (this, request id, `SamplingParams::seed`), so
    /// scheduling order never perturbs sampling
    pub sampler_seed: u64,
    /// int8 kernel backend for the model hot paths. `None` (default)
    /// auto-selects once per process (`QUAMBA_KERNELS` env override,
    /// else runtime detection); `Some(b)` forces backend `b` for this
    /// engine — panics at construction if the machine cannot run it.
    /// Every backend yields **bit-identical** tokens (tested).
    pub kernel_backend: Option<KernelBackend>,
    /// prefix-cache byte budget; 0 (default) disables the cache. SSM
    /// snapshots are constant-size, so this is simply
    /// budget / (state bytes + overhead) cacheable prefixes, whatever
    /// their token lengths.
    pub cache_bytes: usize,
    /// with the cache on, also snapshot every `snapshot_stride` prompt
    /// tokens (nested-prefix reuse); 0 = end-of-prompt snapshots only.
    /// Chunk boundaries snap to this grid so chunked prefills emit the
    /// same snapshot keys as whole-prompt prefills.
    pub snapshot_stride: usize,
    /// max prompt tokens one in-flight prefill advances per tick;
    /// 0 (default) = unchunked (a prompt completes in the tick it is
    /// scheduled). Small values bound the inter-token latency decode
    /// lanes observe while long prompts stream in — chunking moves
    /// latency, **never tokens** (`rust/tests/chunked_prefill.rs`).
    pub prefill_chunk: usize,
    /// per-tick token budget across decode lanes (1 each) + prefill
    /// chunks; 0 (default) = unlimited. When decode alone saturates
    /// the budget, the oldest prefill still advances 1 token/tick
    /// (see [`batcher::plan_tick`]).
    pub max_tokens_per_tick: usize,
    /// admission control: submissions beyond this many queued requests
    /// are rejected immediately with [`FinishReason::Rejected`]
    /// — overload degrades to fast typed rejections instead of
    /// unbounded queue growth. 0 (default) = unbounded.
    pub max_queue: usize,
    /// total-latency deadline applied to requests that don't set
    /// `SamplingParams::deadline_ms`; 0.0 (default) = none.
    pub default_deadline_ms: f64,
    /// time source for the deadline sweeps — `Clock::Wall` in
    /// production, `Clock::Manual` for deterministic tests
    pub clock: Clock,
    /// deterministic fault injection ([`FaultPlan::none`] default:
    /// zero faults, near-zero hot-path cost)
    pub faults: FaultPlan,
    /// weight width of the model this engine serves (8 = W8A8, 4 =
    /// W4A8 packed nibble). Advisory/reporting metadata: the engine
    /// receives a pre-built [`StepModel`], so the width is decided at
    /// model construction (`QuantConfig::weight_bits`) — this field
    /// records it for telemetry and `quamba serve --bits` plumbing.
    pub weight_bits: u8,
    /// speculative decoding (ISSUE 10): per round, a cheap draft model
    /// proposes up to `spec_tokens` tokens per decoding lane and the
    /// target model verifies all of them (plus the pending token) in
    /// ONE batched prefill; accepted tokens commit, the first
    /// rejection restores the lane's constant-size pre-verify state
    /// snapshot (O(1) rollback) and resamples from the target's own
    /// logits row. Token streams are **bit-identical** to plain decode
    /// for greedy and temperature sampling — speculation moves
    /// latency, never tokens. 0 (default) = off. Requires a draft
    /// model ([`NativeEngine::with_draft`]); ignored without one.
    pub spec_tokens: usize,
    /// which draft family the CLI builds when `spec_tokens > 0`
    /// (advisory metadata — see [`SpecDraft`])
    pub spec_draft: SpecDraft,
    /// flight-recorder tick tracing (ISSUE 9): record one
    /// [`SpanRecord`] per tick phase into a preallocated overwrite-
    /// oldest [`TraceRing`], dumpable as Chrome trace-event JSON
    /// ([`NativeEngine::dump_trace`]). Off (default) costs one
    /// `Option` discriminant check per phase; on, each span is one
    /// clock read + one O(1) ring write — no allocation either way.
    pub trace: bool,
    /// span slots preallocated for the flight recorder (min 1); the
    /// ring retains the most recent `trace_capacity` spans
    pub trace_capacity: usize,
}

impl Default for NativeEngineConfig {
    fn default() -> Self {
        NativeEngineConfig {
            capacity: 32,
            max_prefills_per_tick: 2,
            decode_buckets: vec![1, 2, 4, 8],
            threads: 1,
            sampler_seed: DEFAULT_SAMPLER_SEED,
            kernel_backend: None,
            cache_bytes: 0,
            snapshot_stride: 0,
            prefill_chunk: 0,
            max_tokens_per_tick: 0,
            max_queue: 0,
            default_deadline_ms: 0.0,
            clock: Clock::Wall,
            faults: FaultPlan::none(),
            weight_bits: 8,
            spec_tokens: 0,
            spec_draft: SpecDraft::W4A8,
            trace: false,
            trace_capacity: 65_536,
        }
    }
}

/// Reusable per-round workspace: the model scratch plus its logits
/// output buffer. One per concurrent decode group, reused every tick.
struct RoundScratch {
    scratch: StepScratch,
    logits: Vec<f32>,
}

impl RoundScratch {
    fn new(kernels: Kernels) -> RoundScratch {
        RoundScratch { scratch: StepScratch::with_kernels(1, kernels), logits: Vec::new() }
    }
}

/// One decode round's gathered inputs/state (built per tick).
struct RoundIo {
    /// live-vec indices of this round's real lanes (padding excluded)
    lanes: Vec<usize>,
    slots: Vec<usize>,
    toks: Vec<u16>,
    state: MambaState,
    /// model execution time for this round (recorded into
    /// `Metrics::decode_step_ms`, one sample per round — same
    /// semantics as the XLA engine)
    step_ms: f64,
    /// panic payload captured by the round's `catch_unwind` (injected
    /// fault or genuine model bug); resolved in the commit phase
    panic: Option<Box<dyn Any + Send>>,
}

/// One prefilling lane's allotment for this tick: advance
/// `live[live_i]` from `next` up to `target` (both prompt-token
/// indices), possibly across several stride-aligned sub-rounds.
struct LanePlan {
    live_i: usize,
    next: usize,
    target: usize,
}

/// A not-yet-admitted request plus its submission time on the engine
/// clock (deadline sweeps measure queue age from this).
struct QueuedRequest {
    req: Request,
    submit_ms: f64,
}

/// Per-request deadline, falling back to the engine default (0 = none).
fn effective_deadline(param: Option<f64>, default_ms: f64) -> Option<f64> {
    param.or((default_ms > 0.0).then_some(default_ms))
}

/// Execute one gathered decode round against the model inside the
/// panic boundary. Fault hooks run inside the same boundary, so
/// injected panics and genuine model panics take the identical
/// isolation path. On panic the payload lands in `r.panic` and —
/// critically — the pool is untouched: the model only saw `r.state`,
/// a *copy* ([`SsmStatePool::gather_state`]), so a retry without the
/// victim re-executes the survivors bit-identically.
fn run_round(
    model: &(dyn StepModel + Send + Sync),
    faults: &FaultPlan,
    live: &[LiveRequest],
    threads: usize,
    r: &mut RoundIo,
    ws: &mut RoundScratch,
) {
    ws.scratch.threads = threads;
    // per-round model wall time (WallAnchor keeps the raw Instant
    // confined to faults.rs per the clock-discipline audit rule);
    // intentionally real time even under Clock::Manual — it feeds the
    // perf-facing decode_step_ms histogram, not the snapshot/trace path
    let t0 = WallAnchor::new();
    let lanes = &r.lanes;
    let toks = &r.toks;
    let state = &mut r.state;
    let res = catch_unwind(AssertUnwindSafe(|| {
        for &li in lanes {
            let lr = &live[li];
            faults.check(FaultSite::Decode, lr.req.id, lr.generated.len() as u64);
        }
        model.step_into(toks, state, &mut ws.scratch, &mut ws.logits);
    }));
    r.step_ms = t0.elapsed_ms();
    if let Err(p) = res {
        r.panic = Some(p);
    }
}

pub struct NativeEngine {
    pub cfg: NativeEngineConfig,
    model: Box<dyn StepModel + Send + Sync>,
    pool: SsmStatePool,
    queue: VecDeque<QueuedRequest>,
    live: Vec<LiveRequest>,
    done: Vec<Response>,
    pub metrics: Metrics,
    vocab: usize,
    scratches: Vec<RoundScratch>,
    kernels: Kernels,
    /// prefix-sharing snapshot cache (`cfg.cache_bytes > 0`); dropped
    /// at runtime if an insert panics (degrade to cold serving)
    cache: Option<PrefixCache>,
    /// monotonic admission counter — the chunk queue's FIFO key
    /// (`LiveRequest::admitted_seq`); the live vec itself is reordered
    /// by harvest's `swap_remove`
    next_admission_seq: u64,
    /// tick counter — the `Clock::Manual` time base and the fault
    /// plan's latency key
    tick: u64,
    /// injected latency accumulated under `Clock::Manual` (wall-clock
    /// engines sleep instead)
    manual_extra_ms: f64,
    /// wall anchor for `Clock::Wall` deadline sweeps and trace stamps
    anchor: WallAnchor,
    /// flight recorder (`cfg.trace`): fixed-capacity span ring, written
    /// once per tick phase, overwrite-oldest. `None` = tracing off.
    trace: Option<TraceRing>,
    /// speculative-decode draft model ([`Self::with_draft`]); `None`
    /// serves plain decode regardless of `cfg.spec_tokens`
    draft: Option<Box<dyn StepModel + Send + Sync>>,
    /// per-lane draft-state slabs, same capacity as the target pool so
    /// every live lane can speculate; slots attach lazily
    /// ([`SpecState`]) and release only through [`Self::finish_live`]
    draft_pool: Option<SsmStatePool>,
}

impl NativeEngine {
    pub fn new(model: Box<dyn StepModel + Send + Sync>, cfg: NativeEngineConfig) -> NativeEngine {
        assert!(!cfg.decode_buckets.is_empty(), "need at least one decode bucket");
        let kernels = match cfg.kernel_backend {
            Some(b) => Kernels::for_backend(b),
            None => Kernels::auto(),
        };
        let t = model.tier();
        let mut pool =
            SsmStatePool::with_dims(t.n_layer, t.d_inner, t.d_conv, t.d_state, cfg.capacity);
        if model.quantized_conv_state() {
            pool = pool.with_quantized_conv();
        }
        let vocab = t.vocab;
        let cache = (cfg.cache_bytes > 0).then(|| {
            PrefixCache::new(PrefixCacheConfig {
                capacity_bytes: cfg.cache_bytes,
                snapshot_stride: cfg.snapshot_stride,
            })
        });
        NativeEngine {
            pool,
            queue: VecDeque::new(),
            live: Vec::new(),
            done: Vec::new(),
            metrics: Metrics::new(),
            vocab,
            scratches: vec![RoundScratch::new(kernels)],
            kernels,
            cache,
            next_admission_seq: 0,
            tick: 0,
            manual_extra_ms: 0.0,
            anchor: WallAnchor::new(),
            trace: cfg.trace.then(|| TraceRing::new(cfg.trace_capacity)),
            draft: None,
            draft_pool: None,
            model,
            cfg,
        }
    }

    /// Build an engine with a speculative-decode draft model (ISSUE
    /// 10). The draft proposes tokens that the target model verifies;
    /// the two must share a vocabulary but may differ in every other
    /// dimension (the canonical pairing is a W4A8 twin drafting for
    /// the W8A8 target — same calibration, half the weight bytes).
    /// Speculation activates when `cfg.spec_tokens > 0`; with a draft
    /// but `spec_tokens = 0` the engine serves plain decode.
    pub fn with_draft(
        model: Box<dyn StepModel + Send + Sync>,
        draft: Box<dyn StepModel + Send + Sync>,
        cfg: NativeEngineConfig,
    ) -> NativeEngine {
        let mut eng = NativeEngine::new(model, cfg);
        let dt = draft.tier();
        assert_eq!(
            dt.vocab, eng.vocab,
            "draft/target vocab mismatch: the verify step compares token ids"
        );
        let mut dpool =
            SsmStatePool::with_dims(dt.n_layer, dt.d_inner, dt.d_conv, dt.d_state, eng.cfg.capacity);
        if draft.quantized_conv_state() {
            dpool = dpool.with_quantized_conv();
        }
        eng.draft_pool = Some(dpool);
        eng.draft = Some(draft);
        eng
    }

    /// Whether speculative decoding is active (draft present and
    /// `cfg.spec_tokens > 0`).
    pub fn spec_enabled(&self) -> bool {
        self.cfg.spec_tokens > 0 && self.draft.is_some()
    }

    /// Draft-pool slots currently attached to live lanes (tests /
    /// chaos-suite conservation checks). 0 without a draft.
    pub fn draft_pool_in_use(&self) -> usize {
        self.draft_pool.as_ref().map_or(0, |p| p.in_use())
    }

    /// Prefix-cache counters; `None` when serving with the cache off.
    pub fn cache_stats(&self) -> Option<CacheStats> {
        self.cache.as_ref().map(|c| c.stats())
    }

    pub fn decode_buckets(&self) -> &[usize] {
        &self.cfg.decode_buckets
    }

    /// The int8 kernel dispatch this engine executes with (for logging
    /// / bench labeling).
    pub fn kernels(&self) -> Kernels {
        self.kernels
    }

    /// Engine-clock reading for deadline bookkeeping (ms since engine
    /// start under `Clock::Wall`; tick count × ms-per-tick plus
    /// injected latency under `Clock::Manual`).
    fn now_ms(&self) -> f64 {
        match self.cfg.clock {
            Clock::Wall => self.anchor.elapsed_ms(),
            Clock::Manual { ms_per_tick } => self.tick as f64 * ms_per_tick + self.manual_extra_ms,
        }
    }

    /// Span-open stamp for the flight recorder: the engine clock when
    /// tracing is on, a dead constant when it is off — so the disabled
    /// path costs one `Option` discriminant check per phase.
    #[inline]
    fn span_start(&self) -> f64 {
        if self.trace.is_some() {
            self.now_ms()
        } else {
            0.0
        }
    }

    /// Close a phase span opened at `start_ms`. No-op (no clock read,
    /// no write) when tracing is off; zero-allocation O(1) ring write
    /// when on.
    #[inline]
    fn push_span(&mut self, kind: SpanKind, start_ms: f64, req_id: u64, tokens: u32, lanes: u32) {
        if self.trace.is_none() {
            return;
        }
        let end_ms = self.now_ms();
        let tick = self.tick;
        if let Some(ring) = self.trace.as_mut() {
            ring.record(SpanRecord { kind, tick, start_ms, end_ms, req_id, tokens, lanes });
        }
    }

    /// Typed metrics snapshot stamped with the engine clock —
    /// deterministic (equal run-to-run) under `Clock::Manual`.
    pub fn metrics_snapshot(&self) -> MetricsSnapshot {
        self.metrics.snapshot(self.now_ms())
    }

    /// Chrome trace-event JSON dump of the retained flight-recorder
    /// spans (`chrome://tracing` / `ui.perfetto.dev`); `None` when the
    /// engine was built with `cfg.trace = false`.
    pub fn dump_trace(&self) -> Option<String> {
        self.trace.as_ref().map(|t| t.to_chrome_json())
    }

    /// Direct view of the flight recorder (tests/tooling).
    pub fn trace_ring(&self) -> Option<&TraceRing> {
        self.trace.as_ref()
    }

    /// Admission control: reject immediately when the bounded submit
    /// queue is full, so overload degrades to fast typed rejections
    /// instead of unbounded memory growth. `None` = accepted into the
    /// queue; `Some(resp)` = rejected (the response is also retained
    /// for `take_done`, mirroring harvested responses).
    pub fn try_submit(&mut self, req: Request) -> Option<Response> {
        if self.cfg.max_queue > 0 && self.queue.len() >= self.cfg.max_queue {
            self.metrics.record_failure(FinishReason::Rejected);
            let resp = Response::terminal(
                req.id,
                FinishReason::Rejected,
                format!(
                    "submit queue full ({} queued, max_queue={})",
                    self.queue.len(),
                    self.cfg.max_queue
                ),
            );
            self.done.push(resp.clone());
            return Some(resp);
        }
        let submit_ms = self.now_ms();
        self.queue.push_back(QueuedRequest { req, submit_ms });
        None
    }

    /// Fire-and-forget submit (kept for callers that don't observe
    /// rejections; the typed response still lands in `take_done`).
    pub fn submit(&mut self, req: Request) {
        let _ = self.try_submit(req);
    }

    /// Cancel a queued or live request: frees its state-pool slot and
    /// returns a [`FinishReason::Cancelled`] response carrying
    /// whatever tokens were already generated. `None` = unknown id
    /// (already finished or never submitted) — cancelling a completed
    /// request is a no-op, the cancel-vs-harvest race modeled in
    /// `rust/tests/loom_model.rs`.
    pub fn cancel(&mut self, id: RequestId) -> Option<Response> {
        if let Some(pos) = self.queue.iter().position(|q| q.req.id == id) {
            let q = self.queue.remove(pos)?;
            self.metrics.record_failure(FinishReason::Cancelled);
            let resp =
                Response::terminal(q.req.id, FinishReason::Cancelled, "cancelled while queued");
            self.done.push(resp.clone());
            return Some(resp);
        }
        let i = self.live.iter().position(|lr| lr.req.id == id)?;
        self.live[i].fault = Some((FinishReason::Cancelled, "cancelled by client".to_string()));
        let resp = self.finish_live(i);
        self.done.push(resp.clone());
        Some(resp)
    }

    pub fn n_queued(&self) -> usize {
        self.queue.len()
    }

    pub fn n_live(&self) -> usize {
        self.live.len()
    }

    /// Live requests still consuming their prompt (the chunk queue).
    pub fn n_prefilling(&self) -> usize {
        self.live.iter().filter(|lr| lr.prefill_remaining() > 0).count()
    }

    pub fn state_bytes_per_request(&self) -> usize {
        self.pool.bytes_per_request()
    }

    /// Tokens generated so far (live requests + completed).
    pub fn tokens_generated(&self) -> usize {
        self.live.iter().map(|lr| lr.generated.len()).sum::<usize>()
            + self.metrics.tokens_out as usize
    }

    pub fn pool_in_use(&self) -> usize {
        self.pool.in_use()
    }

    pub fn pool_capacity(&self) -> usize {
        self.pool.capacity()
    }

    pub fn live_ids(&self) -> Vec<RequestId> {
        self.live.iter().map(|lr| lr.req.id).collect()
    }

    pub fn queued_ids(&self) -> Vec<RequestId> {
        self.queue.iter().map(|q| q.req.id).collect()
    }

    /// Chaos-suite invariant: pool free-list accounting is intact,
    /// every live request owns exactly one slot, and no two live
    /// requests share one.
    pub fn check_slot_conservation(&self) -> Result<(), String> {
        self.pool.check_conservation()?;
        if self.pool.in_use() != self.live.len() {
            return Err(format!(
                "{} slots in use for {} live requests (leak or double-book)",
                self.pool.in_use(),
                self.live.len()
            ));
        }
        let mut slots: Vec<usize> = self.live.iter().map(|lr| lr.state_slot).collect();
        slots.sort_unstable();
        slots.dedup();
        if slots.len() != self.live.len() {
            return Err("duplicate state_slot among live requests".to_string());
        }
        if let Some(dp) = &self.draft_pool {
            dp.check_conservation()?;
            let n_spec = self.live.iter().filter(|lr| lr.spec.is_some()).count();
            if dp.in_use() != n_spec {
                return Err(format!(
                    "{} draft slots in use for {} speculating lanes (leak or double-book)",
                    dp.in_use(),
                    n_spec
                ));
            }
            let mut dslots: Vec<usize> =
                self.live.iter().filter_map(|lr| lr.spec.map(|s| s.draft_slot)).collect();
            dslots.sort_unstable();
            dslots.dedup();
            if dslots.len() != n_spec {
                return Err("duplicate draft_slot among speculating lanes".to_string());
            }
        }
        Ok(())
    }

    /// Run one unified scheduler tick:
    /// 0. **clock & faults** — advance the tick counter, apply any
    ///    injected latency, sweep TTFT/total deadlines (queued
    ///    requests shed without ever taking a slot);
    /// 1. **admission** — pop queued requests into the live set (pool
    ///    capacity gates), probing the prefix cache: hits restore the
    ///    cached slab and enqueue only the suffix; full-prompt hits
    ///    join decode immediately;
    /// 2. **plan** — one mixed decode+prefill plan under the token
    ///    budget ([`batcher::plan_tick`]);
    /// 3. **decode rounds** — every decoding lane advances 1 token
    ///    (bucketed, minimum padding, optionally threaded), inside the
    ///    panic boundary;
    /// 4. **prefill chunk batch** — all scheduled prompts advance up
    ///    to `prefill_chunk` tokens as one (B, T) batched execution;
    ///    prompts that finish sample their first token and flip to
    ///    [`Phase::Decoding`];
    /// 5. **harvest** — finished and fault-retired requests become
    ///    [`Response`]s via [`Self::finish_live`].
    ///
    /// Returns finished responses (also retained for `take_done`).
    /// Result-typed for interface parity with
    /// [`super::engine::Engine::step`]; the native path cannot fail.
    pub fn step(&mut self) -> Result<Vec<Response>> {
        self.tick += 1;
        let lat = self.cfg.faults.injected_latency_ms(self.tick);
        if lat > 0.0 {
            match self.cfg.clock {
                // deterministic runs: latency advances the manual clock
                Clock::Manual { .. } => self.manual_extra_ms += lat,
                Clock::Wall => std::thread::sleep(std::time::Duration::from_secs_f64(lat / 1e3)),
            }
        }
        // tick timing: start stamp for the per-tick histogram (always)
        // and the enclosing Tick span (when tracing). Under
        // `Clock::Manual` both stamps of a tick coincide, so tick_ms
        // is deterministically 0 and traces are byte-stable.
        let t_tick = self.now_ms();
        let trace_on = self.trace.is_some();
        let tok_before = if trace_on { self.tokens_generated() } else { 0 };
        let mut finished = Vec::new();
        let t_adm = self.span_start();
        let seq_before = self.next_admission_seq;
        self.sweep_deadlines(&mut finished);
        self.admit(&mut finished);
        if trace_on {
            let admitted = (self.next_admission_seq - seq_before) as u32;
            self.push_span(SpanKind::Admission, t_adm, NO_REQ, admitted, self.live.len() as u32);
        }
        let t_plan = self.span_start();
        // lane split: decoding lanes with an attached draft slot run
        // the speculative verify path; the rest run plain decode
        // rounds. Attachment is lazy — a decoding lane picks up a
        // draft slot the first tick one is free — and permanent until
        // harvest, so a lane never flip-flops between the two paths
        // within a round's bookkeeping.
        let spec_on = self.spec_enabled();
        let mut dec_idx: Vec<usize> = Vec::new();
        let mut spec_idx: Vec<usize> = Vec::new();
        for i in 0..self.live.len() {
            if self.live[i].phase != Phase::Decoding || self.live[i].fault.is_some() {
                continue;
            }
            if spec_on && self.live[i].spec.is_none() {
                let slot = self.draft_pool.as_mut().and_then(|dp| dp.alloc());
                if let Some(draft_slot) = slot {
                    let s = self.live[i].prompt.len() + self.live[i].generated.len();
                    // the target slab of a decoding lane has consumed
                    // everything but the pending token
                    self.live[i].spec = Some(SpecState {
                        draft_slot,
                        target_next: s - 1,
                        draft_next: 0,
                        k: self.cfg.spec_tokens,
                        dry_rounds: 0,
                    });
                }
            }
            if self.live[i].spec.is_some() {
                spec_idx.push(i);
            } else {
                dec_idx.push(i);
            }
        }
        let spec_asks: Vec<usize> =
            spec_idx.iter().map(|&i| self.live[i].spec.map_or(0, |s| s.k)).collect();
        let mut pf_idx: Vec<usize> = (0..self.live.len())
            .filter(|&i| {
                matches!(self.live[i].phase, Phase::Prefilling { .. })
                    && self.live[i].fault.is_none()
            })
            .collect();
        // true FIFO over admissions: harvest's swap_remove scrambles
        // live-vec order, so the budget (and the minimum-progress
        // guarantee) must key on admission order, not position
        pf_idx.sort_by_key(|&i| self.live[i].admitted_seq);
        let remaining: Vec<usize> =
            pf_idx.iter().map(|&i| self.live[i].prefill_remaining()).collect();
        let plan = batcher::plan_tick(
            dec_idx.len(),
            &spec_asks,
            &remaining,
            &self.cfg.decode_buckets,
            self.cfg.prefill_chunk,
            self.cfg.max_tokens_per_tick,
        );
        if trace_on {
            let planned: usize = dec_idx.len()
                + spec_idx.len()
                + plan.spec_tokens()
                + plan.chunks.iter().map(|c| c.tokens).sum::<usize>();
            self.push_span(SpanKind::Plan, t_plan, NO_REQ, planned as u32, dec_idx.len() as u32);
        }
        // decode first: the latency-critical lanes never wait behind
        // this tick's prefill work
        if !dec_idx.is_empty() {
            self.decode_tick(&dec_idx, &plan.decode_rounds);
        }
        if !spec_idx.is_empty() {
            self.spec_tick(&spec_idx, &plan.spec_ks);
        }
        if !plan.chunks.is_empty() {
            self.prefill_tick(&pf_idx, &plan.chunks);
        }
        // harvest: natural completions + this tick's fault verdicts
        // (cancellations landed mid-tick, deadline expiry, isolated
        // panics) — all through the single reclaim point
        let t_harv = self.span_start();
        let live_at_harvest = self.live.len();
        let harvested_before = finished.len();
        let mut i = 0;
        while i < self.live.len() {
            if self.live[i].done() || self.live[i].fault.is_some() {
                finished.push(self.finish_live(i));
            } else {
                i += 1;
            }
        }
        if trace_on {
            let harvested = (finished.len() - harvested_before) as u32;
            self.push_span(SpanKind::Harvest, t_harv, NO_REQ, harvested, live_at_harvest as u32);
            let tok_delta = self.tokens_generated().saturating_sub(tok_before) as u32;
            self.push_span(SpanKind::Tick, t_tick, NO_REQ, tok_delta, self.live.len() as u32);
        }
        let t_end = self.now_ms();
        self.metrics.record_tick(t_end - t_tick, self.queue.len());
        self.done.extend(finished.iter().cloned());
        Ok(finished)
    }

    /// Drive until everything queued + live has finished.
    pub fn run_to_completion(&mut self) -> Result<Vec<Response>> {
        while !self.queue.is_empty() || !self.live.is_empty() {
            self.step()?;
        }
        Ok(std::mem::take(&mut self.done))
    }

    pub fn take_done(&mut self) -> Vec<Response> {
        std::mem::take(&mut self.done)
    }

    /// THE slot-reclaim point: every path that retires a live request
    /// — natural completion, cancellation, deadline expiry, panic
    /// isolation — funnels through here, so the invariant "a request
    /// leaves the live set exactly once, releasing exactly its own
    /// pool slot" lives in one documented place. Machine-checked:
    /// `quamba-audit`'s `slot-reclaim` rule confines `live.swap_remove`
    /// and `pool.release` in this file to this function.
    fn finish_live(&mut self, i: usize) -> Response {
        let now = self.now_ms();
        let lr = self.live.swap_remove(i);
        self.pool.release(lr.state_slot);
        if let (Some(spec), Some(dp)) = (lr.spec, self.draft_pool.as_mut()) {
            dp.release(spec.draft_slot);
        }
        let resp = lr.into_response(now);
        if resp.finish.is_ok() {
            self.metrics.record_response(
                resp.ttft_ms,
                resp.tpot_ms,
                resp.ttlt_ms,
                resp.tokens.len(),
                &resp.itl_ms,
            );
        } else {
            self.metrics.record_failure(resp.finish);
        }
        resp
    }

    /// Tick-boundary deadline sweep (deterministic under
    /// `Clock::Manual`): queued requests past their total deadline are
    /// shed without ever taking a slot; live requests past their TTFT
    /// deadline (no token yet) or total deadline retire with
    /// [`FinishReason::DeadlineExceeded`], keeping the tokens
    /// generated so far.
    fn sweep_deadlines(&mut self, out: &mut Vec<Response>) {
        let now = self.now_ms();
        let default_ms = self.cfg.default_deadline_ms;
        let mut qi = 0;
        while qi < self.queue.len() {
            let q = &self.queue[qi];
            let expired = effective_deadline(q.req.params.deadline_ms, default_ms)
                .is_some_and(|d| now - q.submit_ms > d);
            if !expired {
                qi += 1;
                continue;
            }
            let Some(q) = self.queue.remove(qi) else { break };
            self.metrics.record_failure(FinishReason::DeadlineExceeded);
            out.push(Response::terminal(
                q.req.id,
                FinishReason::DeadlineExceeded,
                format!(
                    "deadline expired after {:.1} ms queued (never admitted)",
                    now - q.submit_ms
                ),
            ));
        }
        let mut i = 0;
        while i < self.live.len() {
            let lr = &self.live[i];
            let age = now - lr.submitted_ms;
            let missed_total = effective_deadline(lr.req.params.deadline_ms, default_ms)
                .is_some_and(|d| age > d);
            let missed_ttft = lr.generated.is_empty()
                && lr.req.params.ttft_deadline_ms.is_some_and(|d| age > d);
            if missed_total || missed_ttft {
                let what = if missed_total { "total-latency" } else { "TTFT" };
                self.live[i].fault = Some((
                    FinishReason::DeadlineExceeded,
                    format!("{what} deadline expired after {age:.1} ms"),
                ));
                out.push(self.finish_live(i));
            } else {
                i += 1;
            }
        }
    }

    /// Admission: allocate a pool slot, probe the prefix cache, and
    /// enqueue whatever prompt suffix is left as chunked-prefill work.
    /// No model execution happens here — that is the point: a burst of
    /// long prompts costs this tick only a trie probe and a slab
    /// restore per request, and their *compute* is paced by the
    /// planner across the following ticks.
    fn admit(&mut self, out: &mut Vec<Response>) {
        let now = self.now_ms();
        for _ in 0..self.cfg.max_prefills_per_tick {
            if self.queue.is_empty() || self.pool.in_use() >= self.pool.capacity() {
                break;
            }
            let Some(QueuedRequest { req, submit_ms }) = self.queue.pop_front() else {
                break;
            };
            if self.cfg.faults.should_fail(FaultSite::Alloc, req.id, 0) {
                // injected allocation failure: the request fails alone,
                // before it ever holds a slot
                self.metrics.record_failure(FinishReason::Failed);
                out.push(Response::terminal(
                    req.id,
                    FinishReason::Failed,
                    format!("injected fault: Alloc for request {}", req.id),
                ));
                continue;
            }
            let Some(slot) = self.pool.alloc() else {
                // defensive: the loop head just checked capacity, so an
                // empty free list means broken accounting. Never panic
                // the serving loop — requeue and let the chaos suite's
                // conservation audit name the bug.
                self.queue.push_front(QueuedRequest { req, submit_ms });
                break;
            };
            let mut lr = LiveRequest::new(req, slot, self.cfg.sampler_seed);
            lr.submitted_ms = submit_ms;
            lr.admitted_ms = now;
            lr.admitted_seq = self.next_admission_seq;
            self.next_admission_seq += 1;
            let hit = match self.cache.as_mut() {
                Some(c) if !lr.req.params.no_cache => c.lookup(&lr.prompt),
                _ => None,
            };
            if let Some(h) = hit {
                if let Some(row) = h.logits_row {
                    // full-prompt hit: restore the end-of-prompt state
                    // and sample from the cached row — zero model
                    // execution, straight into the decode phase
                    self.pool.write(slot, h.slab);
                    let tok = sampler::sample_row(&mut lr.rng, &row, self.vocab, &lr.req.params);
                    lr.generated.push(tok);
                    lr.phase = Phase::Decoding;
                    lr.prefill_done_ms = Some(now);
                    lr.last_token_ms = lr.prefill_done_ms;
                } else if h.len < lr.prompt.len() {
                    // partial hit: the restored prefix is this model's
                    // deterministic state for those tokens, so the
                    // suffix enters the chunk queue like any cold
                    // prompt admitted mid-prefill — one scheduler path
                    self.pool.write(slot, h.slab);
                    lr.phase = Phase::Prefilling { next: h.len };
                }
                // else: a full-length hit without a logits row should
                // be unreachable (lookup filters those); fall through
                // to a cold prefill over the freshly-zeroed slab
                // rather than panicking the serving loop
            }
            self.live.push(lr);
        }
        // one stats sync per tick — the counters are cumulative, so
        // only the post-admission snapshot matters
        if let Some(c) = &self.cache {
            self.metrics.record_cache_stats(c.stats());
        }
    }

    /// Pack `lanes` (live-vec indices) into a `b`-wide gathered round.
    fn gather_round(&self, lanes: &[usize], b: usize) -> RoundIo {
        let slots: Vec<usize> = lanes.iter().map(|&li| self.live[li].state_slot).collect();
        let mut toks = vec![BOS; b]; // padded lanes run a throwaway BOS
        for (bi, &li) in lanes.iter().enumerate() {
            toks[bi] = self.live[li].next_input_token();
        }
        let state = self.pool.gather_state(self.model.tier(), &slots, b);
        RoundIo { lanes: lanes.to_vec(), slots, toks, state, step_ms: 0.0, panic: None }
    }

    /// One decode pass over the decoding lanes `dec` (indices into
    /// `self.live`), following the plan's bucket rounds.
    fn decode_tick(&mut self, dec: &[usize], rounds: &[usize]) {
        let groups = batcher::assign(dec.len(), rounds);
        // gather phase: pack every group's lanes/tokens/state
        let mut io: Vec<RoundIo> = Vec::with_capacity(groups.len());
        for (gi, group) in groups.iter().enumerate() {
            let b = rounds[gi];
            self.metrics.record_round(b, group.len());
            let lanes: Vec<usize> = group.iter().map(|&p| dec[p]).collect();
            io.push(self.gather_round(&lanes, b));
        }
        while self.scratches.len() < io.len() {
            self.scratches.push(RoundScratch::new(self.kernels));
        }
        // execute phase
        let threads = self.cfg.threads.max(1);
        if threads > 1 && io.len() > 1 {
            // group-level parallelism, capped at `threads` scoped
            // workers: each worker runs a contiguous chunk of rounds
            // sequentially (within-step threading off — the workers
            // already cover the cores). Commit stays in group order
            // below, so tokens match the sequential schedule exactly.
            // Panics are caught *inside* each worker (run_round), so a
            // poisoned round never tears down the scope.
            let t0 = self.span_start();
            {
                let model = &*self.model;
                let faults = &self.cfg.faults;
                let live = &self.live;
                let scratches = &mut self.scratches;
                let per = io.len().div_ceil(threads);
                std::thread::scope(|sc| {
                    for (rs, wss) in io.chunks_mut(per).zip(scratches.chunks_mut(per)) {
                        sc.spawn(move || {
                            for (r, ws) in rs.iter_mut().zip(wss.iter_mut()) {
                                run_round(model, faults, live, 1, r, ws);
                            }
                        });
                    }
                });
            }
            // the rounds overlapped in time across workers, so the
            // recorder keeps ONE DecodeRound span covering the whole
            // parallel section (per-round spans would double-count the
            // window in span-sum accounting)
            let real: usize = io.iter().map(|r| r.lanes.len()).sum();
            let padded: usize = rounds[..io.len()].iter().sum();
            self.push_span(SpanKind::DecodeRound, t0, NO_REQ, real as u32, padded as u32);
        } else {
            for i in 0..io.len() {
                let t0 = self.span_start();
                run_round(
                    &*self.model,
                    &self.cfg.faults,
                    &self.live,
                    threads,
                    &mut io[i],
                    &mut self.scratches[i],
                );
                let (real, b) = (io[i].lanes.len() as u32, rounds[i] as u32);
                self.push_span(SpanKind::DecodeRound, t0, NO_REQ, real, b);
            }
        }
        // one latency sample per round, in deterministic group order
        // (same metric semantics as the XLA engine's decode_round)
        for r in &io {
            self.metrics.decode_step_ms.record(r.step_ms);
        }
        // commit phase (deterministic order): resolve panics, scatter
        // states, sample
        let v = self.vocab;
        for (gi, mut r) in io.into_iter().enumerate() {
            // panic isolation: retire the victim the payload names (or
            // the whole round if unattributable), then re-run the
            // survivors from their pristine pool state. Bit-parity
            // holds because scatter only ever follows a clean run and
            // batch composition never changes tokens.
            while let Some(p) = r.panic.take() {
                let msg = panic_message(&*p);
                let injected = p.downcast_ref::<InjectedFault>().map(|f| f.req_id);
                let mut survivors = Vec::with_capacity(r.lanes.len());
                for &li in &r.lanes {
                    let is_victim = match injected {
                        Some(id) => self.live[li].req.id == id,
                        None => true,
                    };
                    if is_victim {
                        self.live[li].fault = Some((FinishReason::Failed, msg.clone()));
                    } else {
                        survivors.push(li);
                    }
                }
                if survivors.is_empty() {
                    r.lanes.clear();
                    break;
                }
                let b = survivors.len();
                r = self.gather_round(&survivors, b);
                run_round(
                    &*self.model,
                    &self.cfg.faults,
                    &self.live,
                    1,
                    &mut r,
                    &mut self.scratches[gi],
                );
            }
            if r.lanes.is_empty() {
                continue;
            }
            let RoundIo { lanes, slots, state, .. } = r;
            // only live slots are scattered back; padded-lane outputs drop
            self.pool.scatter_state(&slots, state);
            // one engine-clock stamp per committed round: ITL gaps are
            // inter-tick quantities, and the engine clock keeps them
            // deterministic under Clock::Manual
            let now = self.now_ms();
            let logits = &self.scratches[gi].logits;
            for (bi, &li) in lanes.iter().enumerate() {
                let row = &logits[bi * v..(bi + 1) * v];
                let lr = &mut self.live[li];
                let tok = sampler::sample_row(&mut lr.rng, row, v, &lr.req.params);
                lr.generated.push(tok);
                if let Some(last) = lr.last_token_ms {
                    lr.decode_ms.push(now - last);
                }
                lr.last_token_ms = Some(now);
            }
        }
    }

    /// Snapshot-insert with validation and isolation: the slab copy is
    /// sanity-checked before insert (fault injection corrupts it here;
    /// a non-finite h-state would poison every future warm hit), a
    /// rejected snapshot is simply dropped — the cache only ever moves
    /// TTFT, never tokens, so dropping an insert is always safe — and
    /// a panic inside the cache retires the *cache*, not the process.
    fn insert_snapshot(&mut self, live_i: usize, end: usize, logits_row: Option<Vec<f32>>) {
        if self.cache.is_none() {
            return;
        }
        let t0 = self.span_start();
        let req_id = self.live[live_i].req.id;
        self.insert_snapshot_inner(live_i, end, logits_row);
        self.push_span(SpanKind::SnapshotInsert, t0, req_id, end as u32, 1);
    }

    fn insert_snapshot_inner(&mut self, live_i: usize, end: usize, logits_row: Option<Vec<f32>>) {
        let req_id = self.live[live_i].req.id;
        let mut slab = self.pool.snapshot(self.live[live_i].state_slot);
        if self.cfg.faults.should_fail(FaultSite::Snapshot, req_id, end as u64) {
            // deterministic corruption; the validation below must
            // catch it and drop the insert (token-neutral)
            if let Some(x) = slab.ssm.first_mut() {
                *x = f32::NAN;
            }
        }
        let finite = slab.ssm.iter().all(|x| x.is_finite())
            && slab.conv.iter().all(|x| x.is_finite());
        if !finite {
            self.metrics.snapshot_drops += 1;
            return;
        }
        let key = &self.live[live_i].prompt[..end];
        let snap = Snapshot { slab, logits_row };
        let res = {
            let Some(cache) = self.cache.as_mut() else { return };
            catch_unwind(AssertUnwindSafe(|| cache.insert(key, snap)))
        };
        if res.is_err() {
            // a panicking cache is poisoned mid-mutation: drop it and
            // keep serving cold — degradation, not process death
            self.cache = None;
            self.metrics.snapshot_drops += 1;
        }
    }

    /// The tick's (B, T) batched prefill work over the scheduled
    /// chunks (`pf` maps planner positions to `self.live` indices).
    /// Every lane consumes its WHOLE allotment (`ca.tokens`, capped at
    /// prompt end) this tick — the planner's token budget is spent
    /// exactly, and `prefill_chunk = 0` keeps its "prompt completes in
    /// the tick it is scheduled" meaning with the cache on. The stride
    /// grid shapes *sub-rounds*, not the amount of work: each
    /// sub-round advances all unfinished lanes to their next global
    /// stride cut (or target / prompt end) as one batched execution,
    /// inserting interior/end-of-prompt snapshots at exactly the keys
    /// the old inline whole-prompt path used. With the cache off (or
    /// `snapshot_stride = 0`) this collapses to a single sub-round.
    fn prefill_tick(&mut self, pf: &[usize], chunks: &[batcher::ChunkAssignment]) {
        let stride = self.cache.as_ref().map_or(0, |c| c.config().snapshot_stride);
        let mut lanes: Vec<LanePlan> = Vec::with_capacity(chunks.len());
        for ca in chunks {
            let live_i = pf[ca.idx];
            let lr = &self.live[live_i];
            let next = match lr.phase {
                Phase::Prefilling { next } => next,
                Phase::Decoding => unreachable!("planner only schedules prefilling requests"),
            };
            let target = lr.prompt.len().min(next + ca.tokens);
            debug_assert!(target > next, "planner scheduled an empty chunk");
            lanes.push(LanePlan { live_i, next, target });
        }
        // the chunk batch gets a throwaway scratch: its buffers are
        // sized by B·T_chunk rows, and parking them in the engine's
        // round workspaces would pin O(B·T·vocab) heap for the whole
        // session (decode only ever needs B rows). The model itself is
        // allocation-free inside the call (tests/zero_alloc.rs).
        let mut scratch = StepScratch::with_kernels(1, self.kernels);
        let mut logits: Vec<f32> = Vec::new();
        let v = self.vocab;
        while lanes.iter().any(|l| l.next < l.target) {
            let t_chunk = self.span_start();
            // this sub-round's spans: (index into `lanes`, start, end),
            // ends snapped to the global stride grid so interior
            // snapshots land on one aligned cut set whatever chunk
            // size or resume point a request came in with (cutting
            // never changes bits, only snapshot placement)
            let mut round: Vec<(usize, usize, usize)> = Vec::new();
            for (i, l) in lanes.iter().enumerate() {
                if l.next >= l.target {
                    continue;
                }
                let mut end = l.target;
                if stride > 0 && !self.live[l.live_i].req.params.no_cache {
                    end = end.min((l.next / stride + 1) * stride);
                }
                round.push((i, l.next, end));
            }
            let b = round.len();
            let Some(t_max) = round.iter().map(|&(_, s, e)| e - s).max() else {
                break;
            };
            let slots: Vec<usize> = round
                .iter()
                .map(|&(i, _, _)| self.live[lanes[i].live_i].state_slot)
                .collect();
            let mut state = self.pool.gather_state(self.model.tier(), &slots, b);
            let exec = {
                let live = &self.live;
                let faults = &self.cfg.faults;
                let model = &*self.model;
                let chunk_slices: Vec<&[u16]> = round
                    .iter()
                    .map(|&(i, s, e)| &live[lanes[i].live_i].prompt[s..e])
                    .collect();
                let t0 = WallAnchor::new();
                let res = catch_unwind(AssertUnwindSafe(|| {
                    for &(i, s, _) in &round {
                        let lr = &live[lanes[i].live_i];
                        faults.check(FaultSite::Prefill, lr.req.id, s as u64);
                    }
                    model.prefill_batch_into(&chunk_slices, &mut state, &mut scratch, &mut logits);
                }));
                // prefill_ms samples per batched sub-round (the unit
                // the scheduler actually executes), like decode_step_ms
                self.metrics.prefill_ms.record(t0.elapsed_ms());
                res
            };
            // the chunk span closes on the model execution, before the
            // commit bookkeeping: a panicked sub-round still records
            // its span (tokens = the planned allotment)
            let planned: usize = round.iter().map(|&(_, s, e)| e - s).sum();
            let chunk_req =
                if b == 1 { self.live[lanes[round[0].0].live_i].req.id } else { NO_REQ };
            self.push_span(SpanKind::PrefillChunk, t_chunk, chunk_req, planned as u32, b as u32);
            if let Err(p) = exec {
                // panic isolation: mark the victim (or, when the
                // payload is unattributable, every lane in this
                // sub-round) and drop it from the chunk loop. The pool
                // is untouched — the model only saw the gathered copy —
                // so the next sub-round re-executes the survivors
                // bit-identically.
                let msg = panic_message(&*p);
                let injected = p.downcast_ref::<InjectedFault>().map(|f| f.req_id);
                for &(i, _, _) in &round {
                    let li = lanes[i].live_i;
                    let is_victim = match injected {
                        Some(id) => self.live[li].req.id == id,
                        None => true,
                    };
                    if is_victim {
                        self.live[li].fault = Some((FinishReason::Failed, msg.clone()));
                        lanes[i].target = lanes[i].next;
                    }
                }
                continue;
            }
            self.pool.scatter_state(&slots, state);
            let now = self.now_ms();
            for (bi, &(i, start, end)) in round.iter().enumerate() {
                let tl = end - start;
                let live_i = lanes[i].live_i;
                let finished = end == self.live[live_i].prompt.len();
                let lane_cache =
                    self.cache.is_some() && !self.live[live_i].req.params.no_cache;
                if lane_cache {
                    if !finished && stride > 0 && end % stride == 0 {
                        // interior stride snapshot (nested-prefix reuse)
                        self.insert_snapshot(live_i, end, None);
                    }
                    if finished {
                        // end-of-prompt snapshot keeps the last logits
                        // row, so an exact resubmission never runs the
                        // model
                        let row =
                            logits[(bi * t_max + tl - 1) * v..(bi * t_max + tl) * v].to_vec();
                        self.insert_snapshot(live_i, end, Some(row));
                    }
                }
                let lr = &mut self.live[live_i];
                if finished {
                    let row = &logits[(bi * t_max + tl - 1) * v..(bi * t_max + tl) * v];
                    let tok = sampler::sample_row(&mut lr.rng, row, v, &lr.req.params);
                    lr.generated.push(tok);
                    lr.phase = Phase::Decoding;
                    lr.prefill_done_ms = Some(now);
                    lr.last_token_ms = lr.prefill_done_ms;
                } else {
                    lr.phase = Phase::Prefilling { next: end };
                }
                lanes[i].next = end;
            }
        }
        if let Some(c) = &self.cache {
            self.metrics.record_cache_stats(c.stats());
        }
    }

    /// One speculative decode round over the speculating lanes `spec`
    /// (indices into `self.live`) with per-lane draft grants `ks` from
    /// the planner (ISSUE 10). Three sub-phases:
    ///
    /// 1. **draft catch-up** — lanes whose draft slab lags the stream
    ///    replay the missing tokens as one batched draft prefill
    ///    (tokens committed on the target in earlier rounds re-enter
    ///    the draft here — the draft trails, it never speculates about
    ///    its own past);
    /// 2. **proposals** — up to `k` draft steps on a gathered COPY of
    ///    the draft state (never scattered back, so a rejected run
    ///    needs no draft-side rollback). Greedy lanes propose via the
    ///    shared deterministic argmax; temperature lanes sample with a
    ///    CLONE of the lane RNG, so the draft predicts exactly the
    ///    draw sequence the verify walk will consume;
    /// 3. **verify + commit** — ONE batched target prefill over every
    ///    lane's unverified stream suffix plus its proposals, then a
    ///    commit walk that samples each verify row with the lane's
    ///    TRUE RNG. Acceptance is `sampled == drafted`, so the emitted
    ///    stream is plain decode's **by construction** — for greedy
    ///    and temperature alike — and each token costs exactly the
    ///    draws plain decode would spend. The first rejection restores
    ///    the lane's constant-size pre-verify snapshot — the **O(1)
    ///    rollback** the SSM's fixed-size recurrent state makes free,
    ///    where a KV-cache transformer would truncate a token-length-
    ///    proportional cache — and the rejecting row's sample IS the
    ///    corrective token.
    ///
    /// Fault isolation mirrors decode/prefill: the model only ever
    /// sees gathered copies; scatter follows clean runs. A draft-side
    /// panic is never fatal — affected lanes verify `k = 0` (a plain
    /// decode step through the verify path) this tick and retry later.
    /// A verify panic retires the named victim exactly like a decode
    /// panic; survivors emit nothing this tick (no RNG draws, pool
    /// untouched) and re-verify next tick — streams stay
    /// bit-identical, only tick alignment moves.
    fn spec_tick(&mut self, spec: &[usize], ks: &[usize]) {
        debug_assert_eq!(spec.len(), ks.len());
        let v = self.vocab;
        let spec_max = self.cfg.spec_tokens;
        // per-lane draft grant this tick (plan-capped ask); draft-side
        // faults shrink it, never past the proposals actually drafted
        let mut tick_k: Vec<usize> = ks.to_vec();
        let mut states: Vec<SpecState> = Vec::with_capacity(spec.len());
        for &li in spec {
            match self.live[li].spec {
                Some(sp) => states.push(sp),
                // defensive: the lane split only routes attached lanes
                // here — never panic the serving loop
                None => return,
            }
        }
        // full stream (prompt ++ generated) per lane; catch-up and
        // verify chunks slice into these
        let streams: Vec<Vec<u16>> = spec
            .iter()
            .map(|&li| {
                let lr = &self.live[li];
                let mut s = Vec::with_capacity(lr.prompt.len() + lr.generated.len());
                s.extend_from_slice(&lr.prompt);
                s.extend_from_slice(&lr.generated);
                s
            })
            .collect();
        let mut proposals: Vec<Vec<u16>> = vec![Vec::new(); spec.len()];
        if tick_k.iter().any(|&k| k > 0) {
            self.spec_draft_phase(spec, &states, &streams, &mut tick_k, &mut proposals);
        }
        for j in 0..spec.len() {
            tick_k[j] = tick_k[j].min(proposals[j].len());
        }
        // --- sub-phase 3: one batched target verify + commit walk ---
        let t_verify = self.span_start();
        // chunk per lane: unverified stream suffix (the pending token,
        // plus any tokens emitted-then-rolled-back in earlier rounds)
        // ++ this round's proposals
        let chunks_data: Vec<Vec<u16>> = (0..spec.len())
            .map(|j| {
                let mut c = streams[j][states[j].target_next..].to_vec();
                c.extend_from_slice(&proposals[j][..tick_k[j]]);
                c
            })
            .collect();
        // pre-verify snapshots for lanes that can reject (k >= 1): the
        // constant-size slab IS the O(1) rollback
        let snaps: Vec<Option<SsmSlab>> = (0..spec.len())
            .map(|j| (tick_k[j] > 0).then(|| self.pool.snapshot(self.live[spec[j]].state_slot)))
            .collect();
        let b = spec.len();
        let slots: Vec<usize> = spec.iter().map(|&li| self.live[li].state_slot).collect();
        let t_max = chunks_data.iter().map(|c| c.len()).max().unwrap_or(1);
        let mut state = self.pool.gather_state(self.model.tier(), &slots, b);
        let mut scratch = StepScratch::with_kernels(1, self.kernels);
        let mut logits: Vec<f32> = Vec::new();
        let exec = {
            let live = &self.live;
            let faults = &self.cfg.faults;
            let model = &*self.model;
            let chunk_slices: Vec<&[u16]> = chunks_data.iter().map(|c| c.as_slice()).collect();
            let t0 = WallAnchor::new();
            let res = catch_unwind(AssertUnwindSafe(|| {
                for &li in spec {
                    let lr = &live[li];
                    faults.check(FaultSite::Verify, lr.req.id, lr.generated.len() as u64);
                }
                model.prefill_batch_into(&chunk_slices, &mut state, &mut scratch, &mut logits);
            }));
            // the verify is decode work routed through the prefill
            // path; its latency samples the decode-step histogram
            self.metrics.decode_step_ms.record(t0.elapsed_ms());
            res
        };
        let total: usize = chunks_data.iter().map(|c| c.len()).sum();
        self.push_span(SpanKind::VerifyChunk, t_verify, NO_REQ, total as u32, b as u32);
        if let Err(p) = exec {
            // verify is target-model execution: the named victim fails
            // exactly like a decode panic. Survivors emitted nothing —
            // no RNG draws, pool untouched (the model only saw the
            // gathered copy) — so they re-verify next tick: streams
            // stay bit-identical, only tick alignment moves.
            let msg = panic_message(&*p);
            let injected = p.downcast_ref::<InjectedFault>().map(|f| f.req_id);
            for &li in spec {
                let is_victim = match injected {
                    Some(id) => self.live[li].req.id == id,
                    None => true,
                };
                if is_victim {
                    self.live[li].fault = Some((FinishReason::Failed, msg.clone()));
                }
            }
            return;
        }
        self.pool.scatter_state(&slots, state);
        let now = self.now_ms();
        for (bi, &li) in spec.iter().enumerate() {
            let chunk_len = chunks_data[bi].len();
            let k = tick_k[bi];
            let c = chunk_len - k; // catch-up rows incl. pending token, >= 1
            let mut accepted = 0usize;
            let mut rejected = false;
            // the commit walk: rows (c-1)..=(c-1+k) are the target's
            // next-token distributions at and past the stream tip
            for t in 0..=k {
                if self.live[li].done() {
                    // max_new / EOS reached mid-walk: the lane is
                    // harvested this tick, remaining rows are unused
                    // (and crucially unsampled — no stray RNG draws)
                    break;
                }
                let tok = {
                    let row = verify_row(&logits, bi, t_max, c - 1 + t, v);
                    let lr = &mut self.live[li];
                    sampler::sample_row(&mut lr.rng, row, v, &lr.req.params)
                };
                let lr = &mut self.live[li];
                lr.generated.push(tok);
                if let Some(last) = lr.last_token_ms {
                    lr.decode_ms.push(now - last);
                }
                lr.last_token_ms = Some(now);
                if t == k {
                    // the bonus token after a fully-accepted draft run
                    break;
                }
                if tok != chunks_data[bi][c + t] {
                    // first mismatch: `tok` IS the corrective sample,
                    // taken from the target's own logits row
                    rejected = true;
                    break;
                }
                accepted += 1;
            }
            if rejected {
                // O(1) rollback: restore the constant-size pre-verify
                // slab. The tokens emitted this round re-enter the
                // verify chunk as catch-up next round.
                if let Some(snap) = snaps[bi].as_ref() {
                    let slot = self.live[li].state_slot;
                    self.pool.restore(slot, snap);
                }
            }
            if k > 0 {
                self.metrics.record_spec_round(k, accepted);
            }
            if let Some(sp) = self.live[li].spec.as_mut() {
                if !rejected {
                    // clean walk: the slab consumed the whole chunk
                    sp.target_next += chunk_len;
                }
                if k > 0 {
                    if rejected {
                        // shrink toward 1 on rejection; after
                        // SPEC_DRY_LIMIT consecutive zero-accept
                        // rounds, degrade to plain decode permanently
                        sp.k = (sp.k / 2).max(1);
                        if accepted == 0 {
                            sp.dry_rounds += 1;
                            if sp.dry_rounds >= SPEC_DRY_LIMIT {
                                sp.k = 0;
                            }
                        } else {
                            sp.dry_rounds = 0;
                        }
                    } else if accepted == k {
                        // full accept: grow back toward the cap
                        sp.k = (sp.k + 1).min(spec_max);
                        sp.dry_rounds = 0;
                    }
                }
            }
        }
    }

    /// Sub-phases 1–2 of [`Self::spec_tick`]: batched draft catch-up
    /// (scattered back only on a clean run) plus proposal steps on a
    /// gathered copy. On return `proposals[j]` holds lane `j`'s
    /// drafted tokens; `tick_k[j]` shrinks (possibly to 0 — plain
    /// decode this tick) when a draft-side panic interrupts the work.
    fn spec_draft_phase(
        &mut self,
        spec: &[usize],
        states: &[SpecState],
        streams: &[Vec<u16>],
        tick_k: &mut [usize],
        proposals: &mut [Vec<u16>],
    ) {
        let v = self.vocab;
        let t0 = self.span_start();
        // --- sub-phase 1: catch-up lanes whose draft slab lags the
        // pending-token point (first round: the whole prompt) ---
        let cu: Vec<usize> = (0..spec.len())
            .filter(|&j| tick_k[j] > 0 && states[j].draft_next + 1 < streams[j].len())
            .collect();
        if !cu.is_empty() {
            let b = cu.len();
            let slots: Vec<usize> = cu.iter().map(|&j| states[j].draft_slot).collect();
            let ok = {
                let Some(draft) = self.draft.as_deref() else { return };
                let Some(dpool) = self.draft_pool.as_mut() else { return };
                let mut state = dpool.gather_state(draft.tier(), &slots, b);
                let mut scratch = StepScratch::with_kernels(1, self.kernels);
                let mut logits: Vec<f32> = Vec::new();
                let chunk_slices: Vec<&[u16]> = cu
                    .iter()
                    .map(|&j| &streams[j][states[j].draft_next..streams[j].len() - 1])
                    .collect();
                let live = &self.live;
                let faults = &self.cfg.faults;
                let res = catch_unwind(AssertUnwindSafe(|| {
                    for &j in &cu {
                        let lr = &live[spec[j]];
                        faults.check(FaultSite::Draft, lr.req.id, lr.generated.len() as u64);
                    }
                    draft.prefill_batch_into(&chunk_slices, &mut state, &mut scratch, &mut logits);
                }));
                match res {
                    Ok(()) => {
                        dpool.scatter_state(&slots, state);
                        true
                    }
                    Err(_) => false,
                }
            };
            if ok {
                for &j in &cu {
                    if let Some(sp) = self.live[spec[j]].spec.as_mut() {
                        sp.draft_next = streams[j].len() - 1;
                    }
                }
            } else {
                // a draft fault is never fatal: the lane verifies k=0
                // (a plain decode step) this tick and the untouched
                // draft slab retries its catch-up next round
                for &j in &cu {
                    tick_k[j] = 0;
                }
            }
        }
        // --- sub-phase 2: proposals on a gathered, never-scattered
        // copy of the draft state ---
        let pj: Vec<usize> = (0..spec.len()).filter(|&j| tick_k[j] > 0).collect();
        if !pj.is_empty() {
            let b = pj.len();
            let k_max = pj.iter().map(|&j| tick_k[j]).max().unwrap_or(0);
            let slots: Vec<usize> = pj.iter().map(|&j| states[j].draft_slot).collect();
            // temperature lanes propose with a CLONE of the lane RNG —
            // the true stream advances only when the verify walk emits
            let mut prop_rng: Vec<Pcg32> =
                pj.iter().map(|&j| self.live[spec[j]].rng.clone()).collect();
            // first draft input: the stream's pending token
            let mut toks: Vec<u16> =
                pj.iter().map(|&j| streams[j][streams[j].len() - 1]).collect();
            let Some(draft) = self.draft.as_deref() else { return };
            let Some(dpool) = self.draft_pool.as_ref() else { return };
            let mut state = dpool.gather_state(draft.tier(), &slots, b);
            let mut scratch = StepScratch::with_kernels(1, self.kernels);
            let mut logits: Vec<f32> = Vec::new();
            let live = &self.live;
            let faults = &self.cfg.faults;
            for si in 0..k_max {
                let res = catch_unwind(AssertUnwindSafe(|| {
                    for &j in &pj {
                        if si < tick_k[j] {
                            let lr = &live[spec[j]];
                            faults.check(
                                FaultSite::Draft,
                                lr.req.id,
                                (lr.generated.len() + 1 + si) as u64,
                            );
                        }
                    }
                    draft.step_into(&toks, &mut state, &mut scratch, &mut logits);
                }));
                if res.is_err() {
                    // keep what was drafted so far; lanes verify a
                    // shorter (possibly empty) proposal run
                    break;
                }
                for (bi, &j) in pj.iter().enumerate() {
                    if si >= tick_k[j] {
                        // shorter grant: this lane's copy keeps
                        // stepping as batch padding, output unused
                        continue;
                    }
                    let row = &logits[bi * v..(bi + 1) * v];
                    let lr = &live[spec[j]];
                    let tok = sampler::sample_row(&mut prop_rng[bi], row, v, &lr.req.params);
                    proposals[j].push(tok);
                    toks[bi] = tok;
                }
            }
        }
        let proposed: usize = proposals.iter().map(|p| p.len()).sum();
        self.push_span(SpanKind::DraftRound, t0, NO_REQ, proposed as u32, pj.len() as u32);
    }
}
#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::SamplingParams;
    use crate::ssm::{MambaModel, MambaTier, QuantConfig, QuantizedMambaModel};

    fn tier() -> MambaTier {
        MambaTier {
            name: "nat".into(),
            d_model: 8,
            n_layer: 2,
            d_state: 4,
            d_conv: 4,
            d_inner: 16,
            dt_rank: 2,
            vocab: 16,
        }
    }

    fn req(id: u64, prompt: Vec<u16>, max_new: usize) -> Request {
        Request {
            id,
            prompt,
            max_new_tokens: max_new,
            params: SamplingParams::default(),
            stop_at_eos: false,
        }
    }

    fn sampled_req(id: u64, prompt: Vec<u16>, max_new: usize) -> Request {
        Request {
            id,
            prompt,
            max_new_tokens: max_new,
            params: SamplingParams { temperature: 0.8, top_k: 8, ..Default::default() },
            stop_at_eos: false,
        }
    }

    #[test]
    fn serves_multi_request_workload() {
        let model = MambaModel::synthetic(tier(), 13);
        let mut eng = NativeEngine::new(Box::new(model), NativeEngineConfig::default());
        for i in 0..10u64 {
            let plen = 2 + (i as usize % 5);
            eng.submit(req(i, (0..plen).map(|j| (j % 16) as u16).collect(), 5 + i as usize % 4));
        }
        let done = eng.run_to_completion().unwrap();
        assert_eq!(done.len(), 10);
        assert_eq!(eng.metrics.requests_done, 10);
        for r in &done {
            let want = 5 + r.id as usize % 4;
            assert_eq!(r.tokens.len(), want, "request {} token count", r.id);
        }
        assert_eq!(eng.n_live(), 0);
        assert_eq!(eng.n_queued(), 0);
    }

    #[test]
    fn empty_prompt_served_as_bos() {
        let model = MambaModel::synthetic(tier(), 13);
        let mut eng = NativeEngine::new(Box::new(model), NativeEngineConfig::default());
        eng.submit(req(1, vec![], 3));
        let done = eng.run_to_completion().unwrap();
        assert_eq!(done[0].tokens.len(), 3);
    }

    #[test]
    fn capacity_backpressure_queues_excess() {
        let model = MambaModel::synthetic(tier(), 13);
        let cfg = NativeEngineConfig { capacity: 2, max_prefills_per_tick: 8, ..Default::default() };
        let mut eng = NativeEngine::new(Box::new(model), cfg);
        for i in 0..5u64 {
            eng.submit(req(i, vec![1, 2, 3], 4));
        }
        eng.step().unwrap();
        assert!(eng.n_live() <= 2);
        assert!(eng.n_queued() >= 3);
        let done = eng.run_to_completion().unwrap();
        assert_eq!(done.len(), 5);
    }

    #[test]
    fn chunked_prefill_advances_across_ticks() {
        // a 20-token prompt with prefill_chunk=4 consumes its prompt
        // over ceil(20/4)=5 ticks, then decodes; the first token shows
        // up only once the whole prompt is in
        let model = MambaModel::synthetic(tier(), 13);
        let cfg = NativeEngineConfig { prefill_chunk: 4, ..Default::default() };
        let mut eng = NativeEngine::new(Box::new(model), cfg);
        eng.submit(req(1, (0..20).map(|j| (j % 16) as u16).collect(), 3));
        for tick in 0..4 {
            eng.step().unwrap();
            assert_eq!(eng.n_prefilling(), 1, "tick {tick}: prompt must still be in flight");
            assert_eq!(eng.tokens_generated(), 0, "tick {tick}: no token before prompt done");
        }
        eng.step().unwrap(); // 5th chunk finishes the prompt → first token
        assert_eq!(eng.n_prefilling(), 0);
        assert_eq!(eng.tokens_generated(), 1);
        let done = eng.run_to_completion().unwrap();
        assert_eq!(done[0].tokens.len(), 3);
    }

    #[test]
    fn token_budget_paces_prefill_behind_decode() {
        // budget 6 with 4 decode lanes leaves 2 prefill tokens/tick:
        // a 10-token prompt admitted mid-decode needs 5 ticks of chunks
        let model = MambaModel::synthetic(tier(), 13);
        let cfg = NativeEngineConfig { max_tokens_per_tick: 6, ..Default::default() };
        let mut eng = NativeEngine::new(Box::new(model), cfg);
        for i in 0..4u64 {
            eng.submit(req(i, vec![1, 2], 32));
        }
        // two admission ticks (max_prefills_per_tick=2) get all 4 decoding
        eng.step().unwrap();
        eng.step().unwrap();
        assert_eq!(eng.n_prefilling(), 0);
        eng.submit(req(9, (0..10).map(|j| (j % 16) as u16).collect(), 2));
        let mut ticks_in_flight = 0;
        while eng.n_live() > 4 || eng.n_queued() > 0 {
            eng.step().unwrap();
            if eng.n_prefilling() > 0 {
                ticks_in_flight += 1;
            }
        }
        assert!(
            ticks_in_flight >= 4,
            "10-token prompt at 2 tokens/tick must stay in flight ≥ 4 ticks \
             (got {ticks_in_flight})"
        );
    }

    fn run_workload(cfg: NativeEngineConfig, quantized: bool) -> Vec<(u64, Vec<u16>)> {
        let t = tier();
        let model = MambaModel::synthetic(t.clone(), 13);
        let mut eng = if quantized {
            let qm = QuantizedMambaModel::from_model(
                &model,
                &(0..64u16).map(|i| i % t.vocab as u16).collect::<Vec<_>>(),
                &QuantConfig::default(),
            );
            NativeEngine::new(Box::new(qm), cfg)
        } else {
            NativeEngine::new(Box::new(model), cfg)
        };
        for i in 0..9u64 {
            let plen = 2 + (i as usize % 4);
            eng.submit(sampled_req(
                i,
                (0..plen).map(|j| ((i as usize + j) % 16) as u16).collect(),
                6 + i as usize % 3,
            ));
        }
        let mut done: Vec<(u64, Vec<u16>)> = eng
            .run_to_completion()
            .unwrap()
            .into_iter()
            .map(|r| (r.id, r.tokens))
            .collect();
        done.sort_by_key(|(id, _)| *id);
        done
    }

    /// Mixed greedy/temperature workload through `eng` — the plain and
    /// speculative arms of the bit-identity tests run the same one.
    fn run_mixed(eng: &mut NativeEngine) -> Vec<(u64, Vec<u16>)> {
        for i in 0..9u64 {
            let plen = 2 + (i as usize % 4);
            let prompt: Vec<u16> = (0..plen).map(|j| ((i as usize + j) % 16) as u16).collect();
            let r = if i % 2 == 0 {
                req(i, prompt, 6 + i as usize % 5)
            } else {
                sampled_req(i, prompt, 6 + i as usize % 5)
            };
            eng.submit(r);
        }
        let mut done: Vec<(u64, Vec<u16>)> = eng
            .run_to_completion()
            .unwrap()
            .into_iter()
            .map(|r| (r.id, r.tokens))
            .collect();
        done.sort_by_key(|(id, _)| *id);
        done
    }

    fn w8a8_target() -> Box<dyn StepModel + Send + Sync> {
        let t = tier();
        let model = MambaModel::synthetic(t.clone(), 13);
        let calib: Vec<u16> = (0..64u16).map(|i| i % t.vocab as u16).collect();
        Box::new(QuantizedMambaModel::from_model(&model, &calib, &QuantConfig::default()))
    }

    fn w4a8_draft() -> Box<dyn StepModel + Send + Sync> {
        let t = tier();
        let model = MambaModel::synthetic(t.clone(), 13);
        let calib: Vec<u16> = (0..64u16).map(|i| i % t.vocab as u16).collect();
        Box::new(QuantizedMambaModel::from_model(
            &model,
            &calib,
            &QuantConfig { weight_bits: 4, ..QuantConfig::default() },
        ))
    }

    #[test]
    fn spec_decode_streams_bit_identical_to_plain() {
        // tentpole acceptance (unit scale): for K in {2, 4, 8}, the
        // W4A8-drafted speculative engine emits exactly the plain
        // W8A8 engine's streams — greedy and temperature lanes alike
        let mut base = NativeEngine::new(w8a8_target(), NativeEngineConfig::default());
        let plain = run_mixed(&mut base);
        for k in [2usize, 4, 8] {
            let cfg = NativeEngineConfig { spec_tokens: k, ..Default::default() };
            let mut eng = NativeEngine::with_draft(w8a8_target(), w4a8_draft(), cfg);
            let spec = run_mixed(&mut eng);
            assert_eq!(spec, plain, "spec_tokens={k} changed the token streams");
            assert!(eng.metrics.spec_rounds > 0, "speculation never engaged at k={k}");
            assert!(
                eng.metrics.spec_accepted_tokens > 0,
                "the W4A8 twin accepted nothing at k={k}"
            );
            assert_eq!(eng.draft_pool_in_use(), 0, "draft slots leaked at k={k}");
            eng.check_slot_conservation().unwrap();
        }
        // spec_tokens = 0 with a draft attached is exactly plain decode
        let cfg = NativeEngineConfig::default();
        let mut z = NativeEngine::with_draft(w8a8_target(), w4a8_draft(), cfg);
        let zs = run_mixed(&mut z);
        assert_eq!(zs, plain);
        assert_eq!(z.metrics.spec_rounds, 0, "spec_tokens=0 must not speculate");
    }

    #[test]
    fn spec_degrades_to_plain_on_hopeless_draft() {
        // a draft from an unrelated model proposes garbage: streams
        // must still be bit-identical (acceptance just collapses, and
        // dry lanes degrade to k = 0 instead of thrashing forever)
        let mut base = NativeEngine::new(w8a8_target(), NativeEngineConfig::default());
        let plain = run_mixed(&mut base);
        let bad: Box<dyn StepModel + Send + Sync> = Box::new(MambaModel::synthetic(tier(), 99));
        let cfg = NativeEngineConfig { spec_tokens: 4, ..Default::default() };
        let mut eng = NativeEngine::with_draft(w8a8_target(), bad, cfg);
        let spec = run_mixed(&mut eng);
        assert_eq!(spec, plain, "a bad draft may cost speed, never tokens");
        assert!(eng.metrics.spec_rounds > 0);
        assert!(
            eng.metrics.spec_accepted_tokens < eng.metrics.spec_drafted_tokens,
            "an unrelated draft should not be fully accepted"
        );
        eng.check_slot_conservation().unwrap();
    }

    #[test]
    fn spec_lane_cancel_releases_draft_slot() {
        let cfg = NativeEngineConfig { spec_tokens: 4, ..Default::default() };
        let mut eng = NativeEngine::with_draft(w8a8_target(), w4a8_draft(), cfg);
        eng.submit(sampled_req(1, vec![1, 2, 3], 64));
        eng.step().unwrap(); // admit + prefill + first token
        eng.step().unwrap(); // first speculative round
        assert_eq!(eng.draft_pool_in_use(), 1, "decoding lane must attach a draft slot");
        eng.check_slot_conservation().unwrap();
        eng.cancel(1).expect("live request must be cancellable");
        assert_eq!(eng.draft_pool_in_use(), 0, "cancel must release the draft slot");
        eng.check_slot_conservation().unwrap();
    }

    #[test]
    fn same_sampler_seed_same_tokens_across_engines() {
        // satellite acceptance: two engines sharing a sampler seed
        // reproduce each other token-for-token under temperature
        // sampling; the seed is configuration, not a constant
        let cfg = NativeEngineConfig { sampler_seed: 0xDECAF, ..Default::default() };
        let a = run_workload(cfg.clone(), false);
        let b = run_workload(cfg, false);
        assert_eq!(a, b, "same seed must reproduce the token streams");
        // and the seed must actually be wired through: a different seed
        // has to change at least one sampled token (temperature 0.8,
        // top-k 8, ~60 draws — coincidence would mean the config is
        // being ignored, the exact bug this field fixes)
        let c = run_workload(
            NativeEngineConfig { sampler_seed: 0xB16_5EED, ..Default::default() },
            false,
        );
        assert_ne!(a, c, "different sampler seeds produced identical streams — seed ignored?");
    }

    #[test]
    fn threaded_decode_bit_identical_to_sequential() {
        // ISSUE 2 acceptance: threads > 1 produces bit-identical
        // tokens to threads = 1, fp32 and W8A8, incl. sampler state
        for quantized in [false, true] {
            let seq = run_workload(NativeEngineConfig::default(), quantized);
            let par = run_workload(
                NativeEngineConfig { threads: 4, ..Default::default() },
                quantized,
            );
            assert_eq!(
                seq, par,
                "threaded decode diverged from sequential (quantized={quantized})"
            );
        }
    }

    #[test]
    fn forced_kernel_backend_serves_identical_tokens() {
        // ISSUE 3 satellite acceptance: a forced scalar backend, every
        // detected SIMD backend, and auto selection produce
        // bit-identical token streams through the full engine
        // (W8A8 prefill + batched decode + temperature sampling)
        let scalar_cfg = NativeEngineConfig {
            kernel_backend: Some(KernelBackend::Scalar),
            ..Default::default()
        };
        let base = run_workload(scalar_cfg, true);
        for backend in Kernels::available() {
            let cfg = NativeEngineConfig {
                kernel_backend: Some(backend),
                ..Default::default()
            };
            let got = run_workload(cfg, true);
            assert_eq!(base, got, "kernel backend {} changed served tokens", backend.label());
        }
        let auto = run_workload(NativeEngineConfig::default(), true);
        assert_eq!(base, auto, "auto kernel selection diverged from forced scalar");
    }

    #[test]
    fn quantized_pool_shrinks_state_bytes() {
        let t = tier();
        let model = MambaModel::synthetic(t.clone(), 13);
        let qm = QuantizedMambaModel::from_model(&model, &[1, 2, 3, 4], &QuantConfig::default());
        let f_eng = NativeEngine::new(
            Box::new(MambaModel::synthetic(t.clone(), 13)),
            NativeEngineConfig::default(),
        );
        let q_eng = NativeEngine::new(Box::new(qm), NativeEngineConfig::default());
        let cpl = t.n_layer * (t.d_conv - 1) * t.d_inner;
        assert_eq!(
            f_eng.state_bytes_per_request() - q_eng.state_bytes_per_request(),
            3 * cpl,
            "i8 conv window must save 3 bytes per entry"
        );
    }

    // ----- failure model (ISSUE 7) -----

    use crate::coordinator::faults::{
        silence_injected_panics, Clock, FaultPlan, FaultSite, TargetedFault,
    };

    fn fresh_engine(cfg: NativeEngineConfig) -> NativeEngine {
        NativeEngine::new(Box::new(MambaModel::synthetic(tier(), 13)), cfg)
    }

    #[test]
    fn bounded_queue_rejects_with_typed_response() {
        let cfg = NativeEngineConfig { capacity: 1, max_queue: 2, ..Default::default() };
        let mut eng = fresh_engine(cfg);
        let mut rejected = 0;
        for i in 0..5u64 {
            if let Some(resp) = eng.try_submit(sampled_req(i, vec![1, 2], 3)) {
                assert_eq!(resp.finish, FinishReason::Rejected);
                assert!(resp.tokens.is_empty());
                assert!(
                    resp.error.as_deref().unwrap_or("").contains("queue full"),
                    "{:?}",
                    resp.error
                );
                rejected += 1;
            }
        }
        assert_eq!(rejected, 3, "queue of 2 must shed 3 of 5 upfront submissions");
        assert_eq!(eng.metrics.rejected, 3);
        let done = eng.run_to_completion().unwrap();
        assert_eq!(done.len(), 5, "every submission reaches a terminal outcome");
        assert_eq!(done.iter().filter(|r| r.finish.is_ok()).count(), 2);
        assert!(eng.metrics.shed_rate() > 0.5);
        eng.check_slot_conservation().unwrap();
    }

    #[test]
    fn cancel_mid_flight_frees_slot_and_keeps_tokens() {
        let mut eng = fresh_engine(NativeEngineConfig::default());
        eng.submit(sampled_req(1, vec![1, 2, 3], 32));
        eng.step().unwrap(); // admit + prefill + first token
        eng.step().unwrap(); // one decode token
        assert_eq!(eng.n_live(), 1);
        let resp = eng.cancel(1).expect("live request must be cancellable");
        assert_eq!(resp.finish, FinishReason::Cancelled);
        assert_eq!(resp.tokens.len(), 2, "partial tokens survive cancellation");
        assert_eq!(eng.n_live(), 0);
        assert_eq!(eng.pool_in_use(), 0, "cancel must release the slot");
        assert_eq!(eng.metrics.cancelled, 1);
        assert!(eng.cancel(1).is_none(), "double cancel is a no-op");
        assert!(eng.cancel(99).is_none(), "unknown id is a no-op");
        // queued cancellation: never admitted, empty tokens
        let cfg = NativeEngineConfig { max_prefills_per_tick: 0, ..Default::default() };
        let mut eng2 = fresh_engine(cfg);
        eng2.submit(sampled_req(7, vec![1], 4));
        let resp2 = eng2.cancel(7).expect("queued request must be cancellable");
        assert_eq!(resp2.finish, FinishReason::Cancelled);
        assert!(resp2.tokens.is_empty());
        assert_eq!(eng2.n_queued(), 0);
    }

    #[test]
    fn deadline_exceeded_deterministically_on_manual_clock() {
        let run = || {
            let cfg = NativeEngineConfig {
                clock: Clock::Manual { ms_per_tick: 1.0 },
                ..Default::default()
            };
            let mut eng = fresh_engine(cfg);
            let mut r = sampled_req(1, vec![1, 2], 100);
            r.params.deadline_ms = Some(3.0);
            eng.submit(r);
            let done = eng.run_to_completion().unwrap();
            assert_eq!(done.len(), 1);
            done.into_iter().next().unwrap()
        };
        let a = run();
        assert_eq!(a.finish, FinishReason::DeadlineExceeded);
        assert!(!a.tokens.is_empty(), "tokens generated before expiry are kept");
        assert!(a.tokens.len() < 100);
        let b = run();
        assert_eq!(a.tokens, b.tokens, "manual-clock deadline runs must be bit-reproducible");
        assert_eq!(a.error, b.error);
    }

    #[test]
    fn ttft_deadline_sheds_slow_prefill_with_zero_tokens() {
        let cfg = NativeEngineConfig {
            clock: Clock::Manual { ms_per_tick: 1.0 },
            prefill_chunk: 1,
            ..Default::default()
        };
        let mut eng = fresh_engine(cfg);
        let mut r = sampled_req(1, (0..12).map(|j| (j % 16) as u16).collect(), 4);
        r.params.ttft_deadline_ms = Some(4.0);
        eng.submit(r);
        let done = eng.run_to_completion().unwrap();
        assert_eq!(done.len(), 1);
        assert_eq!(done[0].finish, FinishReason::DeadlineExceeded);
        assert!(done[0].tokens.is_empty(), "12-token prompt at 1 tok/tick cannot beat TTFT 4ms");
        assert!(done[0].error.as_deref().unwrap_or("").contains("TTFT"));
        eng.check_slot_conservation().unwrap();
    }

    #[test]
    fn default_deadline_applies_to_unmarked_requests() {
        let cfg = NativeEngineConfig {
            clock: Clock::Manual { ms_per_tick: 1.0 },
            default_deadline_ms: 2.0,
            ..Default::default()
        };
        let mut eng = fresh_engine(cfg);
        eng.submit(sampled_req(1, vec![1], 100));
        let done = eng.run_to_completion().unwrap();
        assert_eq!(done[0].finish, FinishReason::DeadlineExceeded);
        assert_eq!(eng.metrics.deadline_missed, 1);
    }

    #[test]
    fn injected_decode_panic_fails_exactly_one_request() {
        silence_injected_panics();
        // clean run first: the survivor-parity oracle
        let clean: Vec<(u64, Vec<u16>)> = {
            let mut eng = fresh_engine(NativeEngineConfig::default());
            for i in 1..=3u64 {
                eng.submit(sampled_req(i, vec![1, 2, 3], 4));
            }
            let mut d: Vec<(u64, Vec<u16>)> =
                eng.run_to_completion().unwrap().into_iter().map(|r| (r.id, r.tokens)).collect();
            d.sort_by_key(|(id, _)| *id);
            d
        };
        let cfg = NativeEngineConfig {
            faults: FaultPlan {
                targeted: vec![TargetedFault { site: FaultSite::Decode, req_id: 2, step: 2 }],
                ..FaultPlan::none()
            },
            ..Default::default()
        };
        let mut eng = fresh_engine(cfg);
        for i in 1..=3u64 {
            eng.submit(sampled_req(i, vec![1, 2, 3], 4));
        }
        let mut done = eng.run_to_completion().unwrap();
        done.sort_by_key(|r| r.id);
        assert_eq!(done.len(), 3);
        let victim = &done[1];
        assert_eq!(victim.id, 2);
        assert_eq!(victim.finish, FinishReason::Failed, "exactly the targeted request fails");
        assert_eq!(victim.tokens.len(), 2, "tokens before the injected step survive");
        assert!(victim.error.as_deref().unwrap_or("").contains("injected"), "{:?}", victim.error);
        for (resp, (cid, ctoks)) in [&done[0], &done[2]].iter().zip([&clean[0], &clean[2]]) {
            assert_eq!(resp.id, *cid);
            assert!(resp.finish.is_ok());
            assert_eq!(
                &resp.tokens, ctoks,
                "survivor {} must be bit-identical to the fault-free run",
                resp.id
            );
        }
        assert_eq!(eng.metrics.failed, 1);
        eng.check_slot_conservation().unwrap();
        // the engine keeps serving after the isolated panic
        eng.submit(sampled_req(9, vec![4, 5], 3));
        let after = eng.run_to_completion().unwrap();
        assert_eq!(after.len(), 1);
        assert!(after[0].finish.is_ok());
        assert_eq!(after[0].tokens.len(), 3);
    }

    #[test]
    fn injected_prefill_panic_fails_alone() {
        silence_injected_panics();
        let clean = {
            let mut eng = fresh_engine(NativeEngineConfig::default());
            eng.submit(sampled_req(2, vec![5, 6], 3));
            eng.run_to_completion().unwrap().remove(0).tokens
        };
        let cfg = NativeEngineConfig {
            faults: FaultPlan {
                targeted: vec![TargetedFault { site: FaultSite::Prefill, req_id: 1, step: 0 }],
                ..FaultPlan::none()
            },
            ..Default::default()
        };
        let mut eng = fresh_engine(cfg);
        eng.submit(sampled_req(1, vec![1, 2, 3, 4], 3));
        eng.submit(sampled_req(2, vec![5, 6], 3));
        let mut done = eng.run_to_completion().unwrap();
        done.sort_by_key(|r| r.id);
        assert_eq!(done[0].finish, FinishReason::Failed);
        assert!(done[0].tokens.is_empty(), "panic at prompt start → no tokens");
        assert!(done[1].finish.is_ok());
        assert_eq!(done[1].tokens, clean, "co-scheduled prefill lane unaffected");
        eng.check_slot_conservation().unwrap();
    }

    #[test]
    fn injected_alloc_failure_fails_request_alone() {
        let cfg = NativeEngineConfig {
            faults: FaultPlan {
                targeted: vec![TargetedFault { site: FaultSite::Alloc, req_id: 2, step: 0 }],
                ..FaultPlan::none()
            },
            ..Default::default()
        };
        let mut eng = fresh_engine(cfg);
        for i in 1..=3u64 {
            eng.submit(sampled_req(i, vec![1, 2], 3));
        }
        let mut done = eng.run_to_completion().unwrap();
        done.sort_by_key(|r| r.id);
        assert_eq!(done[1].finish, FinishReason::Failed);
        assert!(done[1].error.as_deref().unwrap_or("").contains("Alloc"));
        assert!(done[0].finish.is_ok() && done[2].finish.is_ok());
        assert_eq!(eng.pool_in_use(), 0);
    }

    #[test]
    fn corrupt_snapshots_are_dropped_tokens_unchanged() {
        let base = NativeEngineConfig {
            cache_bytes: 64 << 10,
            snapshot_stride: 4,
            prefill_chunk: 3,
            ..Default::default()
        };
        let clean = run_workload(base.clone(), false);
        let cfg = NativeEngineConfig {
            faults: FaultPlan { snapshot_corrupt: 1.0, ..FaultPlan::none() },
            ..base
        };
        let t = tier();
        let mut eng = fresh_engine(cfg);
        for i in 0..9u64 {
            let plen = 2 + (i as usize % 4);
            eng.submit(sampled_req(
                i,
                (0..plen).map(|j| ((i as usize + j) % t.vocab) as u16).collect(),
                6 + i as usize % 3,
            ));
        }
        let mut got: Vec<(u64, Vec<u16>)> =
            eng.run_to_completion().unwrap().into_iter().map(|r| (r.id, r.tokens)).collect();
        got.sort_by_key(|(id, _)| *id);
        assert_eq!(got, clean, "dropping every snapshot insert must not move tokens");
        assert!(eng.metrics.snapshot_drops > 0, "validation must have fired");
        let stats = eng.cache_stats().expect("cache still attached");
        assert_eq!(stats.entries, 0, "no corrupt snapshot may enter the cache");
    }
}
