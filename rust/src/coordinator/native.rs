//! `NativeEngine`: the artifact-free serving backend. Same scheduler
//! shape as [`super::engine::Engine`] — prefill-priority admission,
//! bucketed continuous decode batching via [`super::batcher`], the
//! constant-size [`SsmStatePool`] — but execution goes through a
//! [`StepModel`] (fp32 reference or the W8A8
//! [`crate::ssm::QuantizedMambaModel`]) instead of AOT XLA graphs.
//! This is the "no-artifact edge serving" scenario: a coordinator that
//! can come up on a bare machine with nothing but weights (or a
//! synthetic tier) and still expose the identical
//! `submit`/`step`/`run_to_completion`/`Metrics` surface.
//!
//! Hot-path properties (PR 2):
//! * decode rounds execute out of per-round reusable
//!   [`StepScratch`]es — no per-step allocation in the model after
//!   warmup (W8A8 path; asserted in `rust/tests/zero_alloc.rs`);
//! * quantized models get an i8 conv-window pool
//!   ([`SsmStatePool::with_quantized_conv`], quarter the conv state
//!   bytes) gathered/scattered via the `*_raw_q` pair;
//! * `threads > 1` parallelizes decode across groups (one scoped
//!   worker per round) or, for a single group, across lanes inside the
//!   step. Tokens are **bit-identical** to `threads = 1`: lane math is
//!   independent and sampling stays in deterministic group order;
//! * the int8 hot paths run on the [`Kernels`] SIMD dispatch
//!   (`NativeEngineConfig::kernel_backend`, default auto-detected /
//!   `QUAMBA_KERNELS`) — also bit-identical across backends, so
//!   forcing `scalar` vs `avx2` only moves latency, never tokens;
//! * `cache_bytes > 0` arms the prefix-sharing state cache (PR 4,
//!   [`crate::cache::PrefixCache`]): admission probes the token trie,
//!   a hit restores the cached constant-size slab and prefills only
//!   the *suffix* tokens (a full-prompt hit skips prefill entirely via
//!   the cached last logits row), and misses insert snapshots at
//!   `snapshot_stride` cut points + end of prompt. Warm paths are
//!   **bit-identical** to cold — the cache moves TTFT, never tokens
//!   (`rust/tests/prefix_cache.rs`); `SamplingParams::no_cache` opts a
//!   request out entirely.

use std::collections::VecDeque;

use anyhow::Result;

use crate::cache::{CacheStats, PrefixCache, PrefixCacheConfig, Snapshot};
use crate::coordinator::batcher;
use crate::coordinator::engine::DEFAULT_SAMPLER_SEED;
use crate::coordinator::metrics::Metrics;
use crate::coordinator::request::{LiveRequest, Request, Response};
use crate::coordinator::sampler::Sampler;
use crate::coordinator::state::{SsmSlab, SsmStatePool};
use crate::data::BOS;
use crate::quant::{KernelBackend, Kernels};
use crate::ssm::{MambaState, StepModel, StepScratch};

#[derive(Debug, Clone)]
pub struct NativeEngineConfig {
    /// state-pool capacity (max concurrent requests)
    pub capacity: usize,
    /// admission limit per tick
    pub max_prefills_per_tick: usize,
    /// decode-round lane buckets (ascending). The native backend can
    /// run any batch size, but bucketing keeps the scheduling identical
    /// to the AOT deployment shape so the two backends are comparable.
    pub decode_buckets: Vec<usize>,
    /// decode worker threads. 1 (default) is the fully sequential
    /// path; >1 runs decode rounds on at most `threads` scoped workers
    /// (and lane-splits a lone round) — output tokens are bit-identical
    /// either way. Note: lane-splitting spawns scoped threads per
    /// conv/scan section (2 per layer per step), so it only pays off
    /// when per-lane work is large (big d_inner/d_state); the
    /// round-parallel path amortizes spawns over a whole round.
    pub threads: usize,
    /// token sampler seed (determinism across engines is seed-keyed)
    pub sampler_seed: u64,
    /// int8 kernel backend for the model hot paths. `None` (default)
    /// auto-selects once per process (`QUAMBA_KERNELS` env override,
    /// else runtime detection); `Some(b)` forces backend `b` for this
    /// engine — panics at construction if the machine cannot run it.
    /// Every backend yields **bit-identical** tokens (tested), so this
    /// knob only changes wall-clock.
    pub kernel_backend: Option<KernelBackend>,
    /// prefix-cache byte budget; 0 (default) disables the cache. SSM
    /// snapshots are constant-size, so this is simply
    /// budget / (state bytes + overhead) cacheable prefixes, whatever
    /// their token lengths.
    pub cache_bytes: usize,
    /// with the cache on, also snapshot every `snapshot_stride` prompt
    /// tokens (nested-prefix reuse, e.g. a system prompt shared below
    /// a longer template); 0 = end-of-prompt snapshots only.
    pub snapshot_stride: usize,
}

impl Default for NativeEngineConfig {
    fn default() -> Self {
        NativeEngineConfig {
            capacity: 32,
            max_prefills_per_tick: 2,
            decode_buckets: vec![1, 2, 4, 8],
            threads: 1,
            sampler_seed: DEFAULT_SAMPLER_SEED,
            kernel_backend: None,
            cache_bytes: 0,
            snapshot_stride: 0,
        }
    }
}

/// Reusable per-round workspace: the model scratch plus its logits
/// output buffer. One per concurrent decode group, reused every tick.
struct RoundScratch {
    scratch: StepScratch,
    logits: Vec<f32>,
}

impl RoundScratch {
    fn new(kernels: Kernels) -> RoundScratch {
        RoundScratch { scratch: StepScratch::with_kernels(1, kernels), logits: Vec::new() }
    }
}

/// One decode round's gathered inputs/state (built per tick).
struct RoundIo {
    slots: Vec<usize>,
    b: usize,
    toks: Vec<u16>,
    state: MambaState,
    /// model execution time for this round (recorded into
    /// `Metrics::decode_step_ms`, one sample per round — same
    /// semantics as the XLA engine)
    step_ms: f64,
}

/// Clone a finished/ongoing B=1 prefill state as a pool-layout slab —
/// the prefix-cache snapshot payload ((L, 1, …) flattens to exactly
/// the pool's per-slot (L, …) layout).
fn slab_of(state: &MambaState) -> SsmSlab {
    debug_assert_eq!(state.b, 1, "snapshots are per-request (B=1) states");
    SsmSlab { conv: state.conv.clone(), conv_q: state.conv_q.clone(), ssm: state.ssm.clone() }
}

/// Move a finished B=1 prefill state into a pool-layout slab (no copy).
fn into_slab(state: MambaState) -> SsmSlab {
    debug_assert_eq!(state.b, 1);
    if state.is_quantized_conv() {
        let (conv_q, ssm) = state.into_raw_q();
        SsmSlab { conv: Vec::new(), conv_q, ssm }
    } else {
        let (conv, ssm) = state.into_raw();
        SsmSlab { conv, conv_q: Vec::new(), ssm }
    }
}

pub struct NativeEngine {
    pub cfg: NativeEngineConfig,
    model: Box<dyn StepModel + Send + Sync>,
    pool: SsmStatePool,
    queue: VecDeque<Request>,
    live: Vec<LiveRequest>,
    done: Vec<Response>,
    sampler: Sampler,
    pub metrics: Metrics,
    vocab: usize,
    scratches: Vec<RoundScratch>,
    kernels: Kernels,
    /// prefix-sharing snapshot cache (`cfg.cache_bytes > 0`)
    cache: Option<PrefixCache>,
}

impl NativeEngine {
    pub fn new(model: Box<dyn StepModel + Send + Sync>, cfg: NativeEngineConfig) -> NativeEngine {
        assert!(!cfg.decode_buckets.is_empty(), "need at least one decode bucket");
        let kernels = match cfg.kernel_backend {
            Some(b) => Kernels::for_backend(b),
            None => Kernels::auto(),
        };
        let t = model.tier();
        let mut pool =
            SsmStatePool::with_dims(t.n_layer, t.d_inner, t.d_conv, t.d_state, cfg.capacity);
        if model.quantized_conv_state() {
            pool = pool.with_quantized_conv();
        }
        let vocab = t.vocab;
        let cache = (cfg.cache_bytes > 0).then(|| {
            PrefixCache::new(PrefixCacheConfig {
                capacity_bytes: cfg.cache_bytes,
                snapshot_stride: cfg.snapshot_stride,
            })
        });
        NativeEngine {
            pool,
            queue: VecDeque::new(),
            live: Vec::new(),
            done: Vec::new(),
            sampler: Sampler::new(cfg.sampler_seed),
            metrics: Metrics::new(),
            vocab,
            scratches: vec![RoundScratch::new(kernels)],
            kernels,
            cache,
            model,
            cfg,
        }
    }

    /// Prefix-cache counters; `None` when serving with the cache off.
    pub fn cache_stats(&self) -> Option<CacheStats> {
        self.cache.as_ref().map(|c| c.stats())
    }

    pub fn decode_buckets(&self) -> &[usize] {
        &self.cfg.decode_buckets
    }

    /// The int8 kernel dispatch this engine executes with (for logging
    /// / bench labeling).
    pub fn kernels(&self) -> Kernels {
        self.kernels
    }

    pub fn submit(&mut self, req: Request) {
        self.queue.push_back(req);
    }

    pub fn n_queued(&self) -> usize {
        self.queue.len()
    }

    pub fn n_live(&self) -> usize {
        self.live.len()
    }

    pub fn state_bytes_per_request(&self) -> usize {
        self.pool.bytes_per_request()
    }

    /// Tokens generated so far (live requests + completed).
    pub fn tokens_generated(&self) -> usize {
        self.live.iter().map(|lr| lr.generated.len()).sum::<usize>()
            + self.metrics.tokens_out as usize
    }

    /// Run one scheduler tick: admit + prefill a few queued requests,
    /// then one decode round over all live requests. Returns finished
    /// responses (also retained for `take_done`). Result-typed for
    /// interface parity with [`super::engine::Engine::step`]; the
    /// native path itself cannot fail.
    pub fn step(&mut self) -> Result<Vec<Response>> {
        for _ in 0..self.cfg.max_prefills_per_tick {
            if self.queue.is_empty() || self.pool.in_use() >= self.pool.capacity() {
                break;
            }
            let req = self.queue.pop_front().unwrap();
            self.prefill(req);
        }
        if !self.live.is_empty() {
            self.decode_tick();
        }
        let mut finished = Vec::new();
        let mut i = 0;
        while i < self.live.len() {
            if self.live[i].done() {
                let lr = self.live.swap_remove(i);
                self.pool.release(lr.state_slot);
                let resp = lr.into_response();
                self.metrics.record_response(
                    resp.ttft_ms,
                    resp.tpot_ms,
                    resp.ttlt_ms,
                    resp.tokens.len(),
                );
                finished.push(resp);
            } else {
                i += 1;
            }
        }
        self.done.extend(finished.iter().cloned());
        Ok(finished)
    }

    /// Drive until everything queued + live has finished.
    pub fn run_to_completion(&mut self) -> Result<Vec<Response>> {
        while !self.queue.is_empty() || !self.live.is_empty() {
            self.step()?;
        }
        Ok(std::mem::take(&mut self.done))
    }

    pub fn take_done(&mut self) -> Vec<Response> {
        std::mem::take(&mut self.done)
    }

    fn prefill(&mut self, req: Request) {
        let slot = self.pool.alloc().expect("state pool exhausted (checked above)");
        // no graph-length padding: the native model ingests any T, so
        // empty prompts just become a lone BOS
        let prompt: Vec<u16> =
            if req.prompt.is_empty() { vec![BOS] } else { req.prompt.clone() };
        let use_cache = self.cache.is_some() && !req.params.no_cache;
        let mut lr = LiveRequest::new(req, slot);
        let t0 = std::time::Instant::now();
        let quantized = self.model.quantized_conv_state();
        let tl = prompt.len();
        // warm start: restore the longest cached prefix into a fresh
        // B=1 state and prefill only the suffix; a full-prompt hit also
        // carries the last logits row and skips prefill entirely. The
        // restored slab is this model's deterministic state for that
        // prefix, so the warm path replays the cold bits exactly.
        let hit = if use_cache { self.cache.as_mut().unwrap().lookup(&prompt) } else { None };
        let (mut state, consumed, cached_row) = match hit {
            Some(h) => {
                let st = if quantized {
                    MambaState::from_raw_q(self.model.tier(), 1, h.slab.conv_q, h.slab.ssm)
                } else {
                    MambaState::from_raw(self.model.tier(), 1, h.slab.conv, h.slab.ssm)
                };
                (st, h.len, h.logits_row)
            }
            None => (MambaState::new_for(self.model.tier(), 1, quantized), 0, None),
        };
        // prefill gets a throwaway scratch: its buffers are sized by
        // the prompt length T, and parking them in the engine's round
        // workspaces would pin O(T·vocab) heap for the whole session
        // (decode only ever needs B rows)
        let mut scratch = StepScratch::with_kernels(1, self.kernels);
        let mut logits = Vec::new();
        let mut last_rows = 0usize; // logits rows of the final segment
        let stride = self.cache.as_ref().map_or(0, |c| c.config().snapshot_stride);
        let mut start = consumed;
        while start < tl {
            // with the cache on, stop at global stride multiples so
            // interior snapshots land on one aligned cut grid whatever
            // prefix a request resumed from (segment composition is
            // bit-exact, so cutting never changes bits)
            let end = if use_cache && stride > 0 {
                tl.min((start / stride + 1) * stride)
            } else {
                tl
            };
            self.model.prefill_resume_into(
                &prompt[start..end],
                &mut state,
                &mut scratch,
                &mut logits,
            );
            last_rows = end - start;
            if use_cache && end < tl {
                let snap = Snapshot { slab: slab_of(&state), logits_row: None };
                self.cache.as_mut().unwrap().insert(&prompt[..end], snap);
            }
            start = end;
        }
        if use_cache && last_rows > 0 {
            // end-of-prompt snapshot keeps the last logits row, so an
            // exact resubmission never runs the model at all
            let v = self.vocab;
            let row = logits[(last_rows - 1) * v..last_rows * v].to_vec();
            let snap = Snapshot { slab: slab_of(&state), logits_row: Some(row) };
            self.cache.as_mut().unwrap().insert(&prompt, snap);
        }
        self.metrics.prefill_ms.record(t0.elapsed().as_secs_f64() * 1e3);
        if let Some(c) = &self.cache {
            self.metrics.record_cache_stats(c.stats());
        }
        // end-of-prompt state into the request's slot: the slab is
        // already owned, so it moves through the validated `write`
        // (same stale-slot assertion as `restore`, no extra copy) —
        // this replaces the old gather/scatter round-trip
        self.pool.write(slot, into_slab(state));
        let v = self.vocab;
        let row: &[f32] = match &cached_row {
            Some(r) => r.as_slice(),
            None => &logits[(last_rows - 1) * v..last_rows * v],
        };
        let tok = self.sampler.sample(row, v, &lr.req.params);
        lr.generated.push(tok);
        lr.prefill_done = Some(std::time::Instant::now());
        lr.last_token = lr.prefill_done;
        self.live.push(lr);
    }

    fn decode_tick(&mut self) {
        let n = self.live.len();
        let plan = batcher::plan_rounds(n, &self.cfg.decode_buckets);
        let groups = batcher::assign(n, &plan);
        let quantized = self.model.quantized_conv_state();
        // gather phase: pack every group's lanes/tokens/state
        let mut rounds: Vec<RoundIo> = Vec::with_capacity(groups.len());
        for (gi, group) in groups.iter().enumerate() {
            let b = plan[gi];
            self.metrics.record_round(b, group.len());
            let slots: Vec<usize> = group.iter().map(|&i| self.live[i].state_slot).collect();
            let mut toks = vec![BOS; b]; // padded lanes run a throwaway BOS
            for (bi, &i) in group.iter().enumerate() {
                toks[bi] = self.live[i].next_input_token();
            }
            let state = if quantized {
                let (conv_q, ssm) = self.pool.gather_raw_q(&slots, b);
                MambaState::from_raw_q(self.model.tier(), b, conv_q, ssm)
            } else {
                let (conv, ssm) = self.pool.gather_raw(&slots, b);
                MambaState::from_raw(self.model.tier(), b, conv, ssm)
            };
            rounds.push(RoundIo { slots, b, toks, state, step_ms: 0.0 });
        }
        while self.scratches.len() < rounds.len() {
            self.scratches.push(RoundScratch::new(self.kernels));
        }
        // execute phase
        let model = &*self.model;
        let scratches = &mut self.scratches;
        let threads = self.cfg.threads.max(1);
        if threads > 1 && rounds.len() > 1 {
            // group-level parallelism, capped at `threads` scoped
            // workers: each worker runs a contiguous chunk of rounds
            // sequentially (within-step threading off — the workers
            // already cover the cores). Commit stays in group order
            // below, so tokens match the sequential schedule exactly.
            let per = rounds.len().div_ceil(threads);
            std::thread::scope(|sc| {
                for (rs, wss) in rounds.chunks_mut(per).zip(scratches.chunks_mut(per)) {
                    sc.spawn(move || {
                        for (r, ws) in rs.iter_mut().zip(wss.iter_mut()) {
                            ws.scratch.threads = 1;
                            let t0 = std::time::Instant::now();
                            model.step_into(
                                &r.toks,
                                &mut r.state,
                                &mut ws.scratch,
                                &mut ws.logits,
                            );
                            r.step_ms = t0.elapsed().as_secs_f64() * 1e3;
                        }
                    });
                }
            });
        } else {
            for (r, ws) in rounds.iter_mut().zip(scratches.iter_mut()) {
                ws.scratch.threads = threads;
                let t0 = std::time::Instant::now();
                model.step_into(&r.toks, &mut r.state, &mut ws.scratch, &mut ws.logits);
                r.step_ms = t0.elapsed().as_secs_f64() * 1e3;
            }
        }
        // one latency sample per round, in deterministic group order
        // (same metric semantics as the XLA engine's decode_round)
        for r in &rounds {
            self.metrics.decode_step_ms.record(r.step_ms);
        }
        // commit phase (deterministic order): scatter states, sample
        let v = self.vocab;
        for (gi, r) in rounds.into_iter().enumerate() {
            let RoundIo { slots, b, state, .. } = r;
            // only live slots are scattered back; padded-lane outputs drop
            if quantized {
                let (conv_q, ssm) = state.into_raw_q();
                self.pool.scatter_raw_q(&slots, b, &conv_q, &ssm);
            } else {
                let (conv, ssm) = state.into_raw();
                self.pool.scatter_raw(&slots, b, &conv, &ssm);
            }
            let logits = &self.scratches[gi].logits;
            for (bi, &i) in groups[gi].iter().enumerate() {
                let row = &logits[bi * v..(bi + 1) * v];
                let lr = &mut self.live[i];
                let tok = self.sampler.sample(row, v, &lr.req.params);
                lr.generated.push(tok);
                let now = std::time::Instant::now();
                if let Some(last) = lr.last_token {
                    lr.decode_ms.push((now - last).as_secs_f64() * 1e3);
                }
                lr.last_token = Some(now);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::SamplingParams;
    use crate::ssm::{MambaModel, MambaTier, QuantConfig, QuantizedMambaModel};

    fn tier() -> MambaTier {
        MambaTier {
            name: "nat".into(),
            d_model: 8,
            n_layer: 2,
            d_state: 4,
            d_conv: 4,
            d_inner: 16,
            dt_rank: 2,
            vocab: 16,
        }
    }

    fn req(id: u64, prompt: Vec<u16>, max_new: usize) -> Request {
        Request {
            id,
            prompt,
            max_new_tokens: max_new,
            params: SamplingParams::default(),
            stop_at_eos: false,
        }
    }

    fn sampled_req(id: u64, prompt: Vec<u16>, max_new: usize) -> Request {
        Request {
            id,
            prompt,
            max_new_tokens: max_new,
            params: SamplingParams { temperature: 0.8, top_k: 8, ..Default::default() },
            stop_at_eos: false,
        }
    }

    #[test]
    fn serves_multi_request_workload() {
        let model = MambaModel::synthetic(tier(), 13);
        let mut eng = NativeEngine::new(Box::new(model), NativeEngineConfig::default());
        for i in 0..10u64 {
            let plen = 2 + (i as usize % 5);
            eng.submit(req(i, (0..plen).map(|j| (j % 16) as u16).collect(), 5 + i as usize % 4));
        }
        let done = eng.run_to_completion().unwrap();
        assert_eq!(done.len(), 10);
        assert_eq!(eng.metrics.requests_done, 10);
        for r in &done {
            let want = 5 + r.id as usize % 4;
            assert_eq!(r.tokens.len(), want, "request {} token count", r.id);
        }
        assert_eq!(eng.n_live(), 0);
        assert_eq!(eng.n_queued(), 0);
    }

    #[test]
    fn empty_prompt_served_as_bos() {
        let model = MambaModel::synthetic(tier(), 13);
        let mut eng = NativeEngine::new(Box::new(model), NativeEngineConfig::default());
        eng.submit(req(1, vec![], 3));
        let done = eng.run_to_completion().unwrap();
        assert_eq!(done[0].tokens.len(), 3);
    }

    #[test]
    fn capacity_backpressure_queues_excess() {
        let model = MambaModel::synthetic(tier(), 13);
        let cfg = NativeEngineConfig { capacity: 2, max_prefills_per_tick: 8, ..Default::default() };
        let mut eng = NativeEngine::new(Box::new(model), cfg);
        for i in 0..5u64 {
            eng.submit(req(i, vec![1, 2, 3], 4));
        }
        eng.step().unwrap();
        assert!(eng.n_live() <= 2);
        assert!(eng.n_queued() >= 3);
        let done = eng.run_to_completion().unwrap();
        assert_eq!(done.len(), 5);
    }

    fn run_workload(cfg: NativeEngineConfig, quantized: bool) -> Vec<(u64, Vec<u16>)> {
        let t = tier();
        let model = MambaModel::synthetic(t.clone(), 13);
        let mut eng = if quantized {
            let qm = QuantizedMambaModel::from_model(
                &model,
                &(0..64u16).map(|i| i % t.vocab as u16).collect::<Vec<_>>(),
                &QuantConfig::default(),
            );
            NativeEngine::new(Box::new(qm), cfg)
        } else {
            NativeEngine::new(Box::new(model), cfg)
        };
        for i in 0..9u64 {
            let plen = 2 + (i as usize % 4);
            eng.submit(sampled_req(
                i,
                (0..plen).map(|j| ((i as usize + j) % 16) as u16).collect(),
                6 + i as usize % 3,
            ));
        }
        let mut done: Vec<(u64, Vec<u16>)> = eng
            .run_to_completion()
            .unwrap()
            .into_iter()
            .map(|r| (r.id, r.tokens))
            .collect();
        done.sort_by_key(|(id, _)| *id);
        done
    }

    #[test]
    fn same_sampler_seed_same_tokens_across_engines() {
        // satellite acceptance: two engines sharing a sampler seed
        // reproduce each other token-for-token under temperature
        // sampling; the seed is configuration, not a constant
        let cfg = NativeEngineConfig { sampler_seed: 0xDECAF, ..Default::default() };
        let a = run_workload(cfg.clone(), false);
        let b = run_workload(cfg, false);
        assert_eq!(a, b, "same seed must reproduce the token streams");
        // and the seed must actually be wired through: a different seed
        // has to change at least one sampled token (temperature 0.8,
        // top-k 8, ~60 draws — coincidence would mean the config is
        // being ignored, the exact bug this field fixes)
        let c = run_workload(
            NativeEngineConfig { sampler_seed: 0xB16_5EED, ..Default::default() },
            false,
        );
        assert_ne!(a, c, "different sampler seeds produced identical streams — seed ignored?");
    }

    #[test]
    fn threaded_decode_bit_identical_to_sequential() {
        // ISSUE 2 acceptance: threads > 1 produces bit-identical
        // tokens to threads = 1, fp32 and W8A8, incl. sampler state
        for quantized in [false, true] {
            let seq = run_workload(NativeEngineConfig::default(), quantized);
            let par = run_workload(
                NativeEngineConfig { threads: 4, ..Default::default() },
                quantized,
            );
            assert_eq!(
                seq, par,
                "threaded decode diverged from sequential (quantized={quantized})"
            );
        }
    }

    #[test]
    fn forced_kernel_backend_serves_identical_tokens() {
        // ISSUE 3 satellite acceptance: a forced scalar backend, every
        // detected SIMD backend, and auto selection produce
        // bit-identical token streams through the full engine
        // (W8A8 prefill + batched decode + temperature sampling)
        let scalar_cfg = NativeEngineConfig {
            kernel_backend: Some(KernelBackend::Scalar),
            ..Default::default()
        };
        let base = run_workload(scalar_cfg, true);
        for backend in Kernels::available() {
            let cfg = NativeEngineConfig {
                kernel_backend: Some(backend),
                ..Default::default()
            };
            let got = run_workload(cfg, true);
            assert_eq!(base, got, "kernel backend {} changed served tokens", backend.label());
        }
        let auto = run_workload(NativeEngineConfig::default(), true);
        assert_eq!(base, auto, "auto kernel selection diverged from forced scalar");
    }

    #[test]
    fn quantized_pool_shrinks_state_bytes() {
        let t = tier();
        let model = MambaModel::synthetic(t.clone(), 13);
        let qm = QuantizedMambaModel::from_model(&model, &[1, 2, 3, 4], &QuantConfig::default());
        let f_eng = NativeEngine::new(
            Box::new(MambaModel::synthetic(t.clone(), 13)),
            NativeEngineConfig::default(),
        );
        let q_eng = NativeEngine::new(Box::new(qm), NativeEngineConfig::default());
        let cpl = t.n_layer * (t.d_conv - 1) * t.d_inner;
        assert_eq!(
            f_eng.state_bytes_per_request() - q_eng.state_bytes_per_request(),
            3 * cpl,
            "i8 conv window must save 3 bytes per entry"
        );
    }
}
